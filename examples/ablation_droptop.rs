//! Ablation playground: flip KAKURENBO's HE/MB/RF/LR switches and the
//! DropTop fraction from the command line (paper Table 6 / Appendix D).
//!
//!     cargo run --release --example ablation_droptop -- \
//!         --bits v1011 --fraction 0.4 --droptop 0.02 --preset deepcam

use kakurenbo::cli::Args;
use kakurenbo::config::{presets, Components, StrategyConfig};
use kakurenbo::coordinator::run_experiment;
use kakurenbo::hiding::selector::SelectMode;
use kakurenbo::runtime::XlaRuntime;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    let bits = args.flag_or("bits", "v1111");
    let fraction = args.flag_parse::<f64>("fraction")?.unwrap_or(0.4);
    let droptop = args.flag_parse::<f64>("droptop")?.unwrap_or(0.0);
    let preset = args.flag_or("preset", "imagenet_resnet50");

    let rt = XlaRuntime::new(&kakurenbo::runtime::default_artifacts_dir())?;
    let mut cfg = presets::by_name(preset)?;

    // baseline reference
    cfg.strategy = StrategyConfig::Baseline;
    cfg.name = "ablation/baseline".into();
    let base = run_experiment(&rt, cfg.clone())?;

    cfg.strategy = StrategyConfig::Kakurenbo {
        max_fraction: fraction,
        tau: args.flag_parse::<f32>("tau")?.unwrap_or(0.7),
        components: Components::from_bits(bits)?,
        drop_top: droptop,
        select_mode: SelectMode::QuickSelect,
    };
    cfg.name = format!("ablation/{bits}");
    let run = run_experiment(&rt, cfg)?;

    println!("\nbaseline acc {:.2}% time {:.1}s", base.best_acc * 100.0, base.total_time);
    println!(
        "{bits} (F={fraction}, droptop={droptop}) acc {:.2}% ({:+.2}) time {:.1}s ({:+.1}%)",
        run.best_acc * 100.0,
        (run.best_acc - base.best_acc) * 100.0,
        run.total_time,
        (run.total_time / base.total_time - 1.0) * 100.0,
    );
    Ok(())
}
