//! End-to-end validation driver (DESIGN.md deliverable (b), headline run).
//!
//! Full-system exercise on the ImageNet-proxy workload at its standard
//! scale: all three layers compose (rust coordinator -> PJRT -> AOT HLO
//! containing the Pallas kernels), training runs for a realistic number
//! of epochs, and the paper's headline metric — training-time reduction
//! at matched accuracy — is measured and printed, with per-epoch loss
//! curves logged to results/e2e_classification.json.
//!
//!     cargo run --release --example e2e_classification [-- --quick]

use kakurenbo::config::{presets, StrategyConfig};
use kakurenbo::coordinator::run_experiment;
use kakurenbo::report::convergence_json;
use kakurenbo::runtime::XlaRuntime;
use kakurenbo::util::table::Table;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let rt = XlaRuntime::new(&kakurenbo::runtime::default_artifacts_dir())?;
    let mut cfg = presets::by_name("imagenet_resnet50")?;
    if quick {
        cfg.epochs = 8;
        if let kakurenbo::config::DatasetConfig::ImagenetProxy(ref mut c) = cfg.dataset {
            c.n_train = 2048;
            c.n_val = 512;
        }
    }
    println!(
        "e2e: ImageNet-proxy, {} train samples, {} epochs, variant {}",
        match &cfg.dataset {
            kakurenbo::config::DatasetConfig::ImagenetProxy(c) => c.n_train,
            _ => 0,
        },
        cfg.epochs,
        cfg.variant
    );

    let mut runs = Vec::new();
    for (label, strat) in [
        ("baseline", StrategyConfig::Baseline),
        ("kakurenbo", StrategyConfig::kakurenbo(0.3)),
    ] {
        let mut c = cfg.clone();
        c.strategy = strat;
        c.name = format!("e2e/{label}");
        let mut r = run_experiment(&rt, c)?;
        r.strategy = label.into();
        // per-epoch loss curve (the run's own log already prints it live)
        println!("\n{label} loss curve:");
        for rec in &r.records {
            println!(
                "  epoch {:>3}  train_loss {:.4}  val_acc {}  {:.2}s (hidden {})",
                rec.epoch,
                rec.train_loss,
                if rec.val_acc.is_finite() { format!("{:.4}", rec.val_acc) } else { "-".into() },
                rec.time_total,
                rec.hidden,
            );
        }
        runs.push(r);
    }

    let (b, k) = (&runs[0], &runs[1]);
    let mut t = Table::new("E2E headline result").header(&[
        "strategy", "best acc", "final acc", "train time (s)", "modeled @4 workers (s)",
    ]);
    for r in &runs {
        t.row(vec![
            r.strategy.clone(),
            format!("{:.2}%", r.best_acc * 100.0),
            format!("{:.2}%", r.final_acc * 100.0),
            format!("{:.2}", r.total_time),
            format!("{:.2}", r.total_modeled_time),
        ]);
    }
    t.print();
    let dt = (1.0 - k.total_time / b.total_time) * 100.0;
    let da = (k.best_acc - b.best_acc) * 100.0;
    println!("HEADLINE: KAKURENBO reduces training time by {dt:.1}% with {da:+.2}% accuracy impact");
    println!("          (paper: ImageNet-1K 10.4% reduction, -0.4%..+0.26% accuracy)");

    std::fs::create_dir_all("results")?;
    std::fs::write(
        "results/e2e_classification.json",
        convergence_json(&runs).to_pretty(),
    )?;
    println!("[saved results/e2e_classification.json]");
    Ok(())
}
