//! Quickstart: train a small classifier with KAKURENBO vs the baseline.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Shows the minimal public-API path: pick a preset, choose a strategy,
//! run, inspect the result.

use kakurenbo::config::{presets, StrategyConfig};
use kakurenbo::coordinator::run_experiment;
use kakurenbo::runtime::XlaRuntime;

fn main() -> anyhow::Result<()> {
    // 1. a runtime over the AOT artifacts (Python already ran at build time)
    let rt = XlaRuntime::new(&kakurenbo::runtime::default_artifacts_dir())?;

    // 2. a preset experiment (CIFAR-100-like proxy + MLP), scaled down
    let mut cfg = presets::by_name("cifar100_wrn")?;
    cfg.epochs = 10;

    // 3. baseline run
    cfg.strategy = StrategyConfig::Baseline;
    let baseline = run_experiment(&rt, cfg.clone())?;

    // 4. KAKURENBO run: hide up to 30% of samples per epoch
    cfg.strategy = StrategyConfig::kakurenbo(0.3);
    let kakurenbo = run_experiment(&rt, cfg)?;

    println!("\n--- quickstart summary ---");
    println!(
        "baseline : acc {:.2}%  time {:.2}s",
        baseline.best_acc * 100.0,
        baseline.total_time
    );
    println!(
        "kakurenbo: acc {:.2}%  time {:.2}s  ({:+.1}% time, {:+.2} acc)",
        kakurenbo.best_acc * 100.0,
        kakurenbo.total_time,
        (kakurenbo.total_time / baseline.total_time - 1.0) * 100.0,
        (kakurenbo.best_acc - baseline.best_acc) * 100.0,
    );
    Ok(())
}
