//! Transfer-learning scenario (paper Table 4): pretrain on the fractal
//! proxy with KAKURENBO hiding, then fine-tune the trunk on a downstream
//! classification task and compare against a from-scratch run.
//!
//!     cargo run --release --example transfer_learning

use kakurenbo::config::{presets, DatasetConfig, StrategyConfig};
use kakurenbo::coordinator::Trainer;
use kakurenbo::data::synth::GaussMixtureCfg;
use kakurenbo::runtime::XlaRuntime;
use kakurenbo::util::table::Table;

fn main() -> anyhow::Result<()> {
    let rt = XlaRuntime::new(&kakurenbo::runtime::default_artifacts_dir())?;

    // --- upstream: pretrain with KAKURENBO on the fractal proxy ------------
    let mut up = presets::by_name("fractal_pretrain")?;
    up.strategy = StrategyConfig::kakurenbo(0.3);
    up.name = "transfer/upstream".into();
    let mut up_tr = Trainer::new(&rt, up)?;
    let up_run = up_tr.run()?;
    let trunk = up_tr.exec.export_named_params()?;
    println!(
        "upstream: final loss {:.3}, time {:.1}s",
        up_run.records.last().unwrap().train_loss,
        up_run.total_time
    );

    // --- downstream: fine-tune vs from-scratch -------------------------------
    let mk_cfg = || -> anyhow::Result<_> {
        let mut c = presets::by_name("transfer_downstream")?;
        c.dataset = DatasetConfig::GaussMixture(GaussMixtureCfg {
            classes: 10,
            n_train: 2048,
            n_val: 512,
            ..Default::default()
        });
        Ok(c)
    };

    let mut scratch_cfg = mk_cfg()?;
    scratch_cfg.name = "transfer/scratch".into();
    let scratch = Trainer::new(&rt, scratch_cfg)?.run()?;

    let mut ft_cfg = mk_cfg()?;
    ft_cfg.name = "transfer/finetune".into();
    let mut ft = Trainer::new(&rt, ft_cfg)?;
    let imported = ft.exec.import_named_params(&trunk)?;
    println!("imported {imported} trunk leaves (head re-initialized: class count differs)");
    let finetuned = ft.run()?;

    let mut t = Table::new("downstream (CIFAR-10 proxy)").header(&["run", "best acc", "time (s)"]);
    t.row(vec!["from scratch".into(), format!("{:.2}%", scratch.best_acc * 100.0), format!("{:.1}", scratch.total_time)]);
    t.row(vec!["fine-tuned (KAKURENBO upstream)".into(), format!("{:.2}%", finetuned.best_acc * 100.0), format!("{:.1}", finetuned.total_time)]);
    t.print();
    println!(
        "transfer delta: {:+.2}% (paper: hiding upstream samples does not hurt downstream accuracy)",
        (finetuned.best_acc - scratch.best_acc) * 100.0
    );
    Ok(())
}
