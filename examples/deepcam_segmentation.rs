//! DeepCAM-proxy segmentation scenario: KAKURENBO on a per-pixel
//! segmentation task, including the DropTop extension (paper Appendix D).
//!
//!     cargo run --release --example deepcam_segmentation

use kakurenbo::config::{presets, Components, StrategyConfig};
use kakurenbo::coordinator::run_experiment;
use kakurenbo::hiding::selector::SelectMode;
use kakurenbo::runtime::XlaRuntime;
use kakurenbo::util::table::Table;

fn main() -> anyhow::Result<()> {
    let rt = XlaRuntime::new(&kakurenbo::runtime::default_artifacts_dir())?;
    let cfg = presets::by_name("deepcam")?;
    println!("deepcam proxy: segnet, {} epochs, {} virtual workers", cfg.epochs, cfg.workers);

    let strategies = [
        ("baseline", StrategyConfig::Baseline),
        ("kakurenbo-0.3", StrategyConfig::kakurenbo(0.3)),
        (
            "kakurenbo+droptop",
            StrategyConfig::Kakurenbo {
                max_fraction: 0.3,
                tau: 0.7,
                components: Components::ALL,
                drop_top: 0.02,
                select_mode: SelectMode::QuickSelect,
            },
        ),
        ("iswr", StrategyConfig::Iswr),
    ];

    let mut t = Table::new("DeepCAM proxy — segmentation").header(&[
        "strategy", "acc (PA)", "time (s)", "modeled @8w (s)",
    ]);
    for (label, strat) in strategies {
        let mut c = cfg.clone();
        c.strategy = strat;
        c.name = format!("deepcam_example/{label}");
        let r = run_experiment(&rt, c)?;
        t.row(vec![
            label.to_string(),
            format!("{:.2}%", r.best_acc * 100.0),
            format!("{:.2}", r.total_time),
            format!("{:.2}", r.total_modeled_time),
        ]);
    }
    t.print();
    println!("PA = fraction of validation samples with pixel accuracy > 75% (paper's DeepCAM metric analogue)");
    Ok(())
}
