//! Hand-rolled CLI parsing (no clap offline): subcommand + `--key value` /
//! `--key=value` flags + positional args.

use std::collections::BTreeMap;

/// A parsed command line: subcommand, positionals, and `--key` flags.
#[derive(Debug, Default)]
pub struct Args {
    /// The leading subcommand ("" when the first arg was a flag).
    pub command: String,
    /// Non-flag arguments in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` pairs (bare `--flag` maps to "true").
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with('-') {
                args.command = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                anyhow::ensure!(!body.is_empty(), "bare -- not supported");
                if let Some((k, v)) = body.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else {
                    // --flag value, or boolean --flag
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            args.flags.insert(body.to_string(), v);
                        }
                        _ => {
                            args.flags.insert(body.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn parse_env() -> anyhow::Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// The raw value of `--key`, if present.
    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// The value of `--key`, or `default` when absent.
    pub fn flag_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flag(key).unwrap_or(default)
    }

    /// Parse `--key`'s value as `T` (`Ok(None)` when absent, `Err` with
    /// the flag name on a parse failure).
    pub fn flag_parse<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.flag(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }

    /// Whether `--key` was given a truthy value (or stood bare).
    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(self.flag(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["train", "--preset", "deepcam", "--epochs=5", "--verbose"]);
        assert_eq!(a.command, "train");
        assert_eq!(a.flag("preset"), Some("deepcam"));
        assert_eq!(a.flag("epochs"), Some("5"));
        assert!(a.bool_flag("verbose"));
    }

    #[test]
    fn positional_args() {
        let a = parse(&["bench", "table2", "--quick"]);
        assert_eq!(a.command, "bench");
        assert_eq!(a.positional, vec!["table2"]);
        assert!(a.bool_flag("quick"));
    }

    #[test]
    fn typed_flag_parsing() {
        let a = parse(&["x", "--frac", "0.3"]);
        assert_eq!(a.flag_parse::<f64>("frac").unwrap(), Some(0.3));
        assert_eq!(a.flag_parse::<f64>("missing").unwrap(), None);
        let bad = parse(&["x", "--frac", "abc"]);
        assert!(bad.flag_parse::<f64>("frac").is_err());
    }

    #[test]
    fn no_subcommand() {
        let a = parse(&["--help"]);
        assert_eq!(a.command, "");
        assert!(a.bool_flag("help"));
    }
}
