//! EL2N pruning (Paul et al. [15], paper Table 1 / Appendix E):
//! score each sample by the L2 norm of its error vector ||p - onehot(y)||
//! early in training (a cheap proxy for the gradient norm), prune the
//! lowest-scoring fraction permanently, and keep training.
//!
//! Unlike FORGET, EL2N does not need a restart — its selling point is
//! scoring "early in training" — but the optional `restart` flag
//! reproduces the train-from-scratch protocol of the original paper.

use super::{EpochPlan, PlanCtx, Strategy};
use crate::data::batch::BatchAssembler;
use crate::sampler::shuffled;

/// EL2N: score early by error-vector norm, prune the lowest-scoring
/// fraction permanently (optional restart; see module docs).
pub struct El2n {
    /// Epoch at which scores are computed and pruning happens.
    pub score_epoch: usize,
    /// Fraction of the dataset to prune (lowest EL2N scores).
    pub fraction: f64,
    /// Re-initialize the model after pruning (original protocol).
    pub restart: bool,
    kept: Option<Vec<u32>>,
}

impl El2n {
    /// Score at `score_epoch` (min 1), prune `fraction`, optionally
    /// restart from scratch.
    pub fn new(score_epoch: usize, fraction: f64, restart: bool) -> Self {
        El2n { score_epoch: score_epoch.max(1), fraction, restart, kept: None }
    }

    /// EL2N score for every sample: ||softmax(z) - onehot(y)||_2 from the
    /// fwd_embed artifact's probability output.
    fn scores(&self, ctx: &mut PlanCtx) -> anyhow::Result<Vec<f32>> {
        let exec = ctx
            .exec
            .as_deref_mut()
            .ok_or_else(|| anyhow::anyhow!("EL2N needs executor access (fwd_embed)"))?;
        let data = ctx.data;
        let b = exec.meta.batch;
        let classes = exec.meta.classes;
        let mut scores = vec![0.0f32; data.n];
        let mut asm = BatchAssembler::new(data, b);
        let all: Vec<u32> = (0..data.n as u32).collect();
        for chunk in all.chunks(b) {
            asm.fill(data, chunk, None);
            let es = exec.fwd_embed(&asm.x, &asm.y)?;
            for (slot, &sample) in chunk.iter().enumerate() {
                let label = data.label(sample as usize) as usize;
                let mut acc = 0.0f32;
                for c in 0..classes {
                    let p = es.probs[slot * classes + c];
                    let t = if c == label { 1.0 } else { 0.0 };
                    acc += (p - t) * (p - t);
                }
                scores[sample as usize] = acc.sqrt();
            }
        }
        Ok(scores)
    }
}

impl Strategy for El2n {
    fn name(&self) -> String {
        "el2n".into()
    }

    fn fraction_ceiling(&self, _epoch: usize) -> f64 {
        self.fraction
    }

    fn plan_epoch(&mut self, ctx: &mut PlanCtx) -> anyhow::Result<EpochPlan> {
        if ctx.epoch < self.score_epoch {
            return Ok(EpochPlan::plain(crate::sampler::epoch_permutation(
                ctx.data.n, ctx.rng,
            )));
        }
        if ctx.epoch == self.score_epoch {
            let scores = self.scores(ctx)?;
            let n = ctx.data.n;
            let k_prune = ((n as f64) * self.fraction).floor() as usize;
            let pruned = crate::util::stats::argselect_smallest(&scores, k_prune);
            let mut is_pruned = vec![false; n];
            for &i in &pruned {
                is_pruned[i as usize] = true;
            }
            let kept: Vec<u32> = (0..n as u32).filter(|&i| !is_pruned[i as usize]).collect();
            crate::info!(
                "EL2N: pruned {k_prune} of {n} at epoch {} (restart={})",
                ctx.epoch,
                self.restart
            );
            self.kept = Some(kept);
            let mut plan = EpochPlan::plain(shuffled(self.kept.as_ref().unwrap(), ctx.rng));
            plan.reset_params = self.restart;
            return Ok(plan);
        }
        let kept = self
            .kept
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("EL2N: score epoch skipped"))?;
        Ok(EpochPlan::plain(shuffled(kept, ctx.rng)))
    }

    fn refresh_hidden_stats(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::testutil::*;

    #[test]
    fn full_epochs_before_scoring() {
        let tv = tiny_data(30);
        let mut state = graded_state(30);
        let mut s = El2n::new(4, 0.3, false);
        let plan = run_plan(&mut s, 2, &tv.train, &mut state);
        assert_eq!(plan.order.len(), 30);
    }

    #[test]
    fn errors_without_executor_at_score_epoch() {
        // run_plan passes exec: None — the scoring epoch must surface that
        let tv = tiny_data(30);
        let mut state = graded_state(30);
        let mut s = El2n::new(2, 0.3, false);
        let mut rng = crate::util::rng::Rng::new(1);
        let mut ctx = crate::strategies::PlanCtx {
            epoch: 2,
            total_epochs: 10,
            data: &tv.train,
            state: &mut state,
            rng: &mut rng,
            exec: None,
            features: None,
        };
        assert!(s.plan_epoch(&mut ctx).is_err());
    }
}
