//! Random hiding baseline (paper Appendix C.4 / Table 9 "Random"):
//! hide a uniformly random fraction F of samples each epoch.  Shows that
//! KAKURENBO's gains come from *which* samples it hides, not merely from
//! training on fewer samples per epoch.

use super::{EpochPlan, PlanCtx, Strategy};

/// Hide a uniformly random fraction each epoch (the "Random" control).
pub struct RandomHiding {
    /// Fraction of the dataset hidden every epoch.
    pub fraction: f64,
}

impl RandomHiding {
    /// Hide a random `fraction` of samples each epoch.
    pub fn new(fraction: f64) -> Self {
        RandomHiding { fraction }
    }
}

impl Strategy for RandomHiding {
    fn name(&self) -> String {
        "random".into()
    }

    fn fraction_ceiling(&self, _epoch: usize) -> f64 {
        self.fraction
    }

    fn plan_epoch(&mut self, ctx: &mut PlanCtx) -> anyhow::Result<EpochPlan> {
        ctx.state.roll_epoch();
        let n = ctx.data.n;
        let k_hide = ((n as f64) * self.fraction).floor() as usize;
        let mut perm = crate::sampler::epoch_permutation(n, ctx.rng);
        let hidden = perm.split_off(n - k_hide);
        ctx.state.set_hidden(&hidden);
        Ok(EpochPlan {
            order: perm,
            hidden,
            max_hidden: k_hide,
            ..EpochPlan::plain(vec![])
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::testutil::*;

    #[test]
    fn hides_exact_fraction_uniformly() {
        let tv = tiny_data(50);
        let mut state = graded_state(50);
        let mut s = RandomHiding::new(0.2);
        let plan = run_plan(&mut s, 1, &tv.train, &mut state);
        assert_eq!(plan.hidden.len(), 10);
        assert_eq!(plan.order.len(), 40);
        let mut all: Vec<u32> = plan.order.iter().chain(&plan.hidden).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn hidden_set_varies_across_epochs() {
        let tv = tiny_data(60);
        let mut state = graded_state(60);
        let mut s = RandomHiding::new(0.3);
        let a = run_plan(&mut s, 1, &tv.train, &mut state);
        let b = run_plan(&mut s, 2, &tv.train, &mut state);
        let mut ha = a.hidden.clone();
        let mut hb = b.hidden.clone();
        ha.sort_unstable();
        hb.sort_unstable();
        assert_ne!(ha, hb);
    }
}
