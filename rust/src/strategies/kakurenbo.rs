//! KAKURENBO (paper §3): adaptive sample hiding with move-back,
//! fraction scheduling, and learning-rate compensation.
//!
//! Component switches reproduce the Table 6 ablation grid (HE/MB/RF/LR)
//! and the optional DropTop extension reproduces Appendix D.

use super::{EpochPlan, PlanCtx, Strategy};
use crate::config::Components;
use crate::hiding::droptop::drop_top;
use crate::hiding::fraction::FractionSchedule;
use crate::hiding::lr::lr_scale;
use crate::hiding::selector::{select, SelectMode, SelectorCfg};
use crate::sampler::shuffled;

/// KAKURENBO (paper §3): hide the lowest-loss confidently-predicted
/// fraction each epoch, move back uncertain candidates, decay the ceiling,
/// and compensate the learning rate.
pub struct Kakurenbo {
    /// Initial maximum hidden fraction F (the RF schedule decays it).
    pub max_fraction: f64,
    /// Prediction-confidence threshold τ for the move-back rule (§3.1).
    pub tau: f32,
    /// HE/MB/RF/LR component switches (Table 6 ablation grid).
    pub components: Components,
    /// Fraction of highest-loss samples to cut per epoch (Appendix D;
    /// 0.0 disables DropTop).
    pub drop_top: f64,
    /// Candidate selection algorithm (quickselect vs full sort).
    pub select_mode: SelectMode,
    schedule: FractionSchedule,
}

impl Kakurenbo {
    /// Build the strategy with the paper-default fraction schedule over
    /// `total_epochs`, honoring the component switches.
    pub fn new(
        max_fraction: f64,
        tau: f32,
        components: Components,
        drop_top: f64,
        select_mode: SelectMode,
        total_epochs: usize,
    ) -> Self {
        let mut schedule = FractionSchedule::paper_default(max_fraction, total_epochs);
        schedule.enabled = components.reduce_fraction;
        Kakurenbo { max_fraction, tau, components, drop_top, select_mode, schedule }
    }
}

impl Strategy for Kakurenbo {
    fn name(&self) -> String {
        if self.components == Components::ALL && self.drop_top == 0.0 {
            "kakurenbo".into()
        } else if self.drop_top > 0.0 {
            format!("kakurenbo+droptop{:.2}", self.drop_top)
        } else {
            format!("kakurenbo-{}", self.components.label())
        }
    }

    fn fraction_ceiling(&self, epoch: usize) -> f64 {
        self.schedule.at(epoch)
    }

    fn plan_epoch(&mut self, ctx: &mut PlanCtx) -> anyhow::Result<EpochPlan> {
        ctx.state.roll_epoch();

        if !self.components.hide || ctx.epoch == 0 {
            // Epoch 0 trains on everything: losses are not yet known
            // (optimistic +inf init also enforces this; see state/mod.rs).
            return Ok(EpochPlan::plain(crate::sampler::epoch_permutation(
                ctx.data.n, ctx.rng,
            )));
        }

        // B.1-B.3: sort by lagging loss, cut F_e, move back low-confidence.
        let f_e = self.schedule.at(ctx.epoch);
        let sel_cfg = SelectorCfg {
            tau: self.tau,
            move_back: self.components.move_back,
            mode: self.select_mode,
        };
        let sel = select(ctx.state, f_e, &sel_cfg);
        let max_hidden = sel.hidden.len() + sel.moved_back;

        // Appendix D: optionally drop the highest-loss tail from training.
        // (Dropped samples are *not* stats-refreshed; their loss lags, as
        // in the paper's filter-from-batch-stream implementation.)
        let train_list = if self.drop_top > 0.0 {
            let (kept, _dropped) = drop_top(ctx.state, &sel.train, self.drop_top);
            kept
        } else {
            sel.train
        };

        ctx.state.set_hidden(&sel.hidden);

        // C.2 / Eq. 8: LR compensation by the *effective* hidden fraction.
        let scale = if self.components.adjust_lr {
            lr_scale(sel.hidden.len() as f64 / ctx.data.n.max(1) as f64)
        } else {
            1.0
        };

        Ok(EpochPlan {
            order: shuffled(&train_list, ctx.rng),
            weights: None,
            lr_scale: scale,
            hidden: sel.hidden,
            max_hidden,
            moved_back: sel.moved_back,
            reset_params: false,
            batch_mode: super::BatchMode::Plain,
            pruned_pre_forward: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::testutil::*;

    fn kakurenbo(frac: f64) -> Kakurenbo {
        Kakurenbo::new(frac, 0.7, Components::ALL, 0.0, SelectMode::QuickSelect, 20)
    }

    #[test]
    fn epoch0_trains_on_everything() {
        let tv = tiny_data(40);
        let mut state = graded_state(40);
        let mut k = kakurenbo(0.3);
        let plan = run_plan(&mut k, 0, &tv.train, &mut state);
        assert_eq!(plan.order.len(), 40);
        assert!(plan.hidden.is_empty());
    }

    #[test]
    fn hides_confident_low_loss_and_scales_lr() {
        let tv = tiny_data(40);
        let mut state = graded_state(40); // even idx confident-correct
        let mut k = kakurenbo(0.3);
        let plan = run_plan(&mut k, 1, &tv.train, &mut state);
        // candidates = 12 lowest-loss (idx 0..12); odd ones move back
        assert_eq!(plan.max_hidden, 12);
        assert_eq!(plan.moved_back, 6);
        assert_eq!(plan.hidden.len(), 6);
        assert!(plan.hidden.iter().all(|&i| i % 2 == 0 && i < 12));
        assert_eq!(plan.order.len(), 34);
        let expected = 1.0 / (1.0 - 6.0 / 40.0);
        assert!((plan.lr_scale - expected).abs() < 1e-12);
        // state is marked
        assert_eq!(state.hidden_count(), 6);
    }

    #[test]
    fn ablation_no_mb_hides_all_candidates() {
        let tv = tiny_data(40);
        let mut state = graded_state(40);
        let comps = crate::config::Components::from_bits("v1011").unwrap();
        let mut k = Kakurenbo::new(0.3, 0.7, comps, 0.0, SelectMode::QuickSelect, 20);
        let plan = run_plan(&mut k, 1, &tv.train, &mut state);
        assert_eq!(plan.hidden.len(), 12);
        assert_eq!(plan.moved_back, 0);
    }

    #[test]
    fn ablation_no_lr_keeps_scale_one() {
        let tv = tiny_data(40);
        let mut state = graded_state(40);
        let comps = crate::config::Components::from_bits("v1110").unwrap();
        let mut k = Kakurenbo::new(0.3, 0.7, comps, 0.0, SelectMode::QuickSelect, 20);
        let plan = run_plan(&mut k, 1, &tv.train, &mut state);
        assert!(plan.hidden.len() > 0);
        assert_eq!(plan.lr_scale, 1.0);
    }

    #[test]
    fn rf_reduces_fraction_late_in_training() {
        let tv = tiny_data(100);
        let mut k = kakurenbo(0.4);
        let mut state = graded_state(100);
        let early = run_plan(&mut k, 1, &tv.train, &mut state);
        let mut state2 = graded_state(100);
        let late = run_plan(&mut k, 19, &tv.train, &mut state2);
        assert!(late.max_hidden < early.max_hidden);
    }

    #[test]
    fn droptop_removes_top_losses_from_order() {
        let tv = tiny_data(50);
        let mut state = graded_state(50);
        let mut k = Kakurenbo::new(0.2, 0.7, Components::ALL, 0.1, SelectMode::QuickSelect, 20);
        let plan = run_plan(&mut k, 1, &tv.train, &mut state);
        // top losses are the highest indices; 5 should be dropped
        assert!(!plan.order.contains(&49));
        assert!(!plan.order.contains(&48));
        // hidden + order + dropped <= n
        assert!(plan.order.len() + plan.hidden.len() < 50);
    }

    #[test]
    fn order_and_hidden_are_disjoint() {
        let tv = tiny_data(64);
        let mut state = graded_state(64);
        let mut k = kakurenbo(0.4);
        let plan = run_plan(&mut k, 2, &tv.train, &mut state);
        for h in &plan.hidden {
            assert!(!plan.order.contains(h));
        }
    }
}
