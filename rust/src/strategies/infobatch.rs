//! InfoBatch (Qin et al. [28], discussed in paper Appendix C.4):
//! *unbiased* dynamic data pruning — implemented as an extension strategy
//! so the repo can reproduce the paper's discussion of it.
//!
//! Each epoch, samples whose lagging loss is below the epoch mean are
//! pruned with probability `r`; the surviving below-mean samples have
//! their gradient rescaled by 1/(1-r), which keeps the expected gradient
//! equal to the full-data gradient (the "lossless" claim).  In the final
//! `anneal` fraction of training, pruning is disabled so every sample is
//! revisited before convergence.

use super::{EpochPlan, PlanCtx, Strategy};
use crate::sampler::shuffled;

/// InfoBatch: unbiased dynamic pruning with 1/(1-r) gradient rescaling
/// and a final annealing window (see module docs).
pub struct InfoBatch {
    /// Prune probability r for below-mean-loss samples.
    pub r: f64,
    /// Fraction of final epochs with pruning disabled (paper [28]: 12.5%).
    pub anneal: f64,
}

impl InfoBatch {
    /// Prune below-mean samples with probability `r` (anneal 12.5%).
    pub fn new(r: f64) -> Self {
        InfoBatch { r, anneal: 0.125 }
    }
}

impl Strategy for InfoBatch {
    fn name(&self) -> String {
        "infobatch".into()
    }

    fn fraction_ceiling(&self, _epoch: usize) -> f64 {
        self.r
    }

    fn plan_epoch(&mut self, ctx: &mut PlanCtx) -> anyhow::Result<EpochPlan> {
        ctx.state.roll_epoch();
        let n = ctx.data.n;
        let annealing = ctx.epoch as f64 >= ctx.total_epochs as f64 * (1.0 - self.anneal);
        if ctx.epoch == 0 || annealing {
            return Ok(EpochPlan::plain(crate::sampler::epoch_permutation(n, ctx.rng)));
        }
        // mean of known losses
        let finite: Vec<f32> = ctx.state.loss.iter().copied().filter(|l| l.is_finite()).collect();
        if finite.is_empty() {
            return Ok(EpochPlan::plain(crate::sampler::epoch_permutation(n, ctx.rng)));
        }
        let mean = crate::util::stats::mean(&finite) as f32;

        let mut kept: Vec<u32> = Vec::with_capacity(n);
        let mut weights: Vec<f32> = Vec::with_capacity(n);
        let mut hidden: Vec<u32> = Vec::new();
        let rescale = (1.0 / (1.0 - self.r)) as f32;
        for i in 0..n as u32 {
            let l = ctx.state.loss[i as usize];
            let below = l.is_finite() && l < mean;
            if below && ctx.rng.chance(self.r) {
                hidden.push(i);
            } else {
                kept.push(i);
                weights.push(if below { rescale } else { 1.0 });
            }
        }
        ctx.state.set_hidden(&hidden);
        // shuffle kept + weights together
        let mut idx: Vec<u32> = (0..kept.len() as u32).collect();
        idx = shuffled(&idx, ctx.rng);
        let order: Vec<u32> = idx.iter().map(|&k| kept[k as usize]).collect();
        let w: Vec<f32> = idx.iter().map(|&k| weights[k as usize]).collect();
        let max_hidden = hidden.len();
        Ok(EpochPlan {
            order,
            weights: Some(w),
            hidden,
            max_hidden,
            ..EpochPlan::plain(vec![])
        })
    }

    /// InfoBatch does not refresh pruned-sample stats (its pruning is
    /// probabilistic, so stale losses self-correct when re-drawn).
    fn refresh_hidden_stats(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::testutil::*;

    #[test]
    fn epoch0_and_anneal_train_everything() {
        let tv = tiny_data(40);
        let mut state = graded_state(40);
        let mut s = InfoBatch::new(0.5);
        let p0 = run_plan(&mut s, 0, &tv.train, &mut state);
        assert_eq!(p0.order.len(), 40);
        // run_plan uses total_epochs = 20; epoch 19 is in the anneal window
        let p19 = run_plan(&mut s, 19, &tv.train, &mut state);
        assert_eq!(p19.order.len(), 40);
        assert!(p19.weights.is_none());
    }

    #[test]
    fn prunes_only_below_mean_and_rescales() {
        let tv = tiny_data(100);
        let mut state = graded_state(100); // loss(i) = i, mean ~ 49.5
        let mut s = InfoBatch::new(0.5);
        let plan = run_plan(&mut s, 3, &tv.train, &mut state);
        // every hidden sample has below-mean loss
        for &h in &plan.hidden {
            assert!((h as f32) < 49.5, "pruned above-mean sample {h}");
        }
        // roughly r * (below-mean count) pruned
        assert!(plan.hidden.len() > 10 && plan.hidden.len() < 40, "{}", plan.hidden.len());
        // kept below-mean samples carry weight 2.0, others 1.0
        let w = plan.weights.as_ref().unwrap();
        for (pos, &i) in plan.order.iter().enumerate() {
            if (i as f32) < 49.5 {
                assert!((w[pos] - 2.0).abs() < 1e-6);
            } else {
                assert!((w[pos] - 1.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn expected_gradient_mass_is_unbiased() {
        // sum of weights over kept ~= n (the full-data gradient mass)
        let tv = tiny_data(2000);
        let mut state = graded_state(2000);
        let mut s = InfoBatch::new(0.4);
        let plan = run_plan(&mut s, 2, &tv.train, &mut state);
        let total: f32 = plan.weights.as_ref().unwrap().iter().sum();
        let rel = (total - 2000.0).abs() / 2000.0;
        assert!(rel < 0.05, "weight mass off by {rel}");
    }
}
