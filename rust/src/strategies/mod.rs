//! Training strategies: KAKURENBO and every baseline the paper compares
//! against (Table 2/3).  Each strategy turns per-sample state into an
//! `EpochPlan` that the coordinator executes.
//!
//! The full catalog — citations, scoring rules, fraction-ceiling
//! behaviour, and the config flags driving each strategy — lives in
//! docs/strategies.md.

pub mod baseline;
pub mod el2n;
pub mod forget;
pub mod infobatch;
pub mod gradmatch;
pub mod iswr;
pub mod kakurenbo;
pub mod pfb;
pub mod random_hiding;
pub mod sb;

use crate::config::StrategyConfig;
use crate::data::Dataset;
use crate::runtime::ModelExecutor;
use crate::state::{FeatureCache, SampleState};
use crate::util::rng::Rng;

/// How the coordinator consumes the plan's order.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BatchMode {
    /// Train on `order` directly, batch by batch.
    Plain,
    /// Selective-Backprop: forward-select from `order`, backprop only the
    /// selected (loss-CDF^beta acceptance).
    SelectiveBackprop { beta: f64 },
}

/// One epoch's worth of scheduling decisions.
#[derive(Clone, Debug)]
pub struct EpochPlan {
    /// Samples to feed to training, in order (may contain repeats for
    /// with-replacement strategies).
    pub order: Vec<u32>,
    /// Per-position gradient weights (importance re-weighting); None = 1.0.
    pub weights: Option<Vec<f32>>,
    /// Multiplier applied to the epoch's base learning rate (Eq. 8).
    pub lr_scale: f64,
    /// Hidden list to stats-refresh at epoch end (forward-only pass).
    pub hidden: Vec<u32>,
    /// Number of hide *candidates* before move-back (Fig. 8 "max hidden").
    pub max_hidden: usize,
    /// How many candidates the MB rule returned to the training list.
    pub moved_back: usize,
    /// Re-initialize model parameters before this epoch (FORGET restart).
    pub reset_params: bool,
    /// How the engine consumes `order` (plain train vs SB select-train).
    pub batch_mode: BatchMode,
    /// Samples excluded from the epoch *before* any forward pass ran on
    /// them this epoch (PFB's cached-feature pruning): the plan decided
    /// from cached scores alone, so these cost zero device work.
    pub pruned_pre_forward: usize,
}

impl EpochPlan {
    /// A plain full-train plan over `order`: no weights, no hiding, no LR
    /// scaling — the shape every strategy starts from.
    pub fn plain(order: Vec<u32>) -> Self {
        EpochPlan {
            order,
            weights: None,
            lr_scale: 1.0,
            hidden: vec![],
            max_hidden: 0,
            moved_back: 0,
            reset_params: false,
            batch_mode: BatchMode::Plain,
            pruned_pre_forward: 0,
        }
    }
}

/// Context handed to `plan_epoch`.  `exec` is available for strategies
/// that need an extra model pass to select (GradMatch's embedding pass).
pub struct PlanCtx<'a> {
    /// Current epoch index (0-based).
    pub epoch: usize,
    /// Total epochs the run is configured for (schedules need the span).
    pub total_epochs: usize,
    /// The training dataset being planned over.
    pub data: &'a Dataset,
    /// Per-sample lagging loss / prediction store (read and updated).
    pub state: &'a mut SampleState,
    /// The trainer's persistent RNG stream (shuffles, acceptance draws).
    pub rng: &'a mut Rng,
    /// The executor, for strategies that run an extra selection pass
    /// (GradMatch / EL2N `fwd_embed`); `None` in executor-free tests.
    pub exec: Option<&'a mut ModelExecutor>,
    /// The coordinator's feature cache (PFB scores from it instead of
    /// running a forward pass); `None` when the trainer keeps no cache.
    pub features: Option<&'a FeatureCache>,
}

/// One per-epoch planning policy: turns per-sample state into the epoch's
/// [`EpochPlan`] (train order, hidden list, weights, LR scale).
pub trait Strategy: Send {
    /// Display name (config naming, logs, result JSON).
    fn name(&self) -> String;
    /// Plan one epoch: selection, ordering, weights, LR scaling.
    fn plan_epoch(&mut self, ctx: &mut PlanCtx) -> anyhow::Result<EpochPlan>;
    /// Whether the coordinator should refresh hidden-list stats at epoch
    /// end (paper step D.1).  ISWR instead needs *all* stats fresh, which
    /// it gets from the with-replacement training pass itself.
    fn refresh_hidden_stats(&self) -> bool {
        true
    }
    /// The epoch's maximum hidden/pruned-fraction ceiling F_e (Fig. 8 /
    /// EpochRecord diagnostics).  Each strategy reports its own ceiling —
    /// the coordinator must not re-derive it from config, so new
    /// strategies can't silently drift.  Strategies that never hide
    /// (baseline, ISWR, SB) keep the 0.0 default.
    fn fraction_ceiling(&self, _epoch: usize) -> f64 {
        0.0
    }
    /// If `Some(n)`, the coordinator harvests penultimate-layer embeddings
    /// into the feature cache every `n` epochs (the engine's
    /// `StepMode::Embed` sweep at the epoch's Refresh phase).  Strategies
    /// that never score from cached features keep the `None` default and
    /// pay no harvest cost.
    fn feature_refresh_every(&self) -> Option<usize> {
        None
    }
}

/// Instantiate a strategy from config.
pub fn build(cfg: &StrategyConfig, total_epochs: usize) -> Box<dyn Strategy> {
    match cfg {
        StrategyConfig::Baseline => Box::new(baseline::Baseline),
        StrategyConfig::Kakurenbo { max_fraction, tau, components, drop_top, select_mode } => {
            Box::new(kakurenbo::Kakurenbo::new(
                *max_fraction,
                *tau,
                *components,
                *drop_top,
                *select_mode,
                total_epochs,
            ))
        }
        StrategyConfig::Iswr => Box::new(iswr::Iswr::default()),
        StrategyConfig::SelectiveBackprop { beta } => Box::new(sb::SelectiveBackprop::new(*beta)),
        StrategyConfig::Forget { prune_epoch, fraction } => {
            Box::new(forget::Forget::new(*prune_epoch, *fraction))
        }
        StrategyConfig::GradMatch { fraction, every_r } => {
            Box::new(gradmatch::GradMatch::new(*fraction, *every_r))
        }
        StrategyConfig::RandomHiding { fraction } => {
            Box::new(random_hiding::RandomHiding::new(*fraction))
        }
        StrategyConfig::InfoBatch { r } => Box::new(infobatch::InfoBatch::new(*r)),
        StrategyConfig::El2n { score_epoch, fraction, restart } => {
            Box::new(el2n::El2n::new(*score_epoch, *fraction, *restart))
        }
        StrategyConfig::Pfb { fraction, refresh_every } => {
            Box::new(pfb::Pfb::new(*fraction, *refresh_every))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Components;
    use crate::hiding::selector::SelectMode;

    /// The per-epoch ceiling must come from the strategy itself and match
    /// what its own schedule/config produces (the coordinator no longer
    /// re-derives it from `StrategyConfig`).
    #[test]
    fn fraction_ceiling_reported_by_strategy() {
        let total = 100;
        let cfgs = [
            StrategyConfig::Baseline,
            StrategyConfig::Iswr,
            StrategyConfig::SelectiveBackprop { beta: 1.0 },
            StrategyConfig::kakurenbo(0.3),
            StrategyConfig::RandomHiding { fraction: 0.2 },
            StrategyConfig::Forget { prune_epoch: 10, fraction: 0.25 },
            StrategyConfig::El2n { score_epoch: 5, fraction: 0.15, restart: false },
            StrategyConfig::GradMatch { fraction: 0.3, every_r: 2 },
            StrategyConfig::InfoBatch { r: 0.5 },
            StrategyConfig::Pfb { fraction: 0.3, refresh_every: 3 },
        ];
        for cfg in &cfgs {
            let s = build(cfg, total);
            let expected = |epoch: usize| -> f64 {
                match cfg {
                    StrategyConfig::Kakurenbo { max_fraction, components, .. } => {
                        let mut sched = crate::hiding::fraction::FractionSchedule::paper_default(
                            *max_fraction,
                            total,
                        );
                        sched.enabled = components.reduce_fraction;
                        sched.at(epoch)
                    }
                    StrategyConfig::RandomHiding { fraction }
                    | StrategyConfig::Forget { fraction, .. }
                    | StrategyConfig::El2n { fraction, .. }
                    | StrategyConfig::GradMatch { fraction, .. }
                    | StrategyConfig::Pfb { fraction, .. } => *fraction,
                    StrategyConfig::InfoBatch { r } => *r,
                    _ => 0.0,
                }
            };
            for epoch in [0, 1, 30, 60, 99] {
                assert_eq!(
                    s.fraction_ceiling(epoch),
                    expected(epoch),
                    "{} epoch {epoch}",
                    s.name()
                );
            }
        }
    }

    /// RF ablation: a kakurenbo variant with reduce_fraction off reports a
    /// constant ceiling.
    #[test]
    fn fraction_ceiling_respects_rf_switch() {
        let comps = Components::from_bits("v1101").unwrap();
        let k = kakurenbo::Kakurenbo::new(0.3, 0.7, comps, 0.0, SelectMode::QuickSelect, 100);
        assert_eq!(k.fraction_ceiling(0), 0.3);
        assert_eq!(k.fraction_ceiling(99), 0.3);
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::data::synth::{gauss_mixture, GaussMixtureCfg};
    use crate::data::TrainVal;

    pub fn tiny_data(n: usize) -> TrainVal {
        gauss_mixture(
            &GaussMixtureCfg {
                n_train: n,
                n_val: 16,
                dim: 8,
                classes: 4,
                ..Default::default()
            },
            9,
        )
    }

    /// State where sample i has loss = i (ascending), confident-correct for
    /// even i, low-confidence for odd i.
    pub fn graded_state(n: usize) -> SampleState {
        let mut s = SampleState::new(n);
        for i in 0..n {
            s.record(i, i as f32, i % 2 == 0, if i % 2 == 0 { 0.95 } else { 0.4 }, 0);
        }
        s
    }

    pub fn run_plan(
        strat: &mut dyn Strategy,
        epoch: usize,
        data: &Dataset,
        state: &mut SampleState,
    ) -> EpochPlan {
        run_plan_with_features(strat, epoch, data, state, None)
    }

    /// Like [`run_plan`], with an optional feature cache (PFB's scored
    /// epochs read from it; everything else ignores it).
    pub fn run_plan_with_features(
        strat: &mut dyn Strategy,
        epoch: usize,
        data: &Dataset,
        state: &mut SampleState,
        features: Option<&crate::state::FeatureCache>,
    ) -> EpochPlan {
        // per-epoch RNG stream, as the trainer's persistent RNG would give
        let mut rng = Rng::new(7 + 1000 * epoch as u64);
        let mut ctx = PlanCtx {
            epoch,
            total_epochs: 20,
            data,
            state,
            rng: &mut rng,
            exec: None,
            features,
        };
        strat.plan_epoch(&mut ctx).unwrap()
    }
}
