//! Partial Forward Blocking (Dong et al., arXiv 2506.23674) as an
//! extension strategy: per-epoch pruning decided *before* any forward
//! pass runs, from a cached-feature redundancy proxy.
//!
//! Where KAKURENBO ranks samples by their lagging training loss (which
//! the training pass itself keeps fresh), PFB scores samples in feature
//! space: penultimate-layer embeddings are harvested once every
//! `refresh_every` epochs (the coordinator's `StepMode::Embed` sweep at
//! the epoch's Refresh phase, see [`Strategy::feature_refresh_every`]),
//! and every plan in between reads the cached rows.  A sample's score is
//! its Euclidean distance to its own class centroid
//! ([`FeatureCache::centroid_distances`]): samples *closest* to the
//! centroid are the most redundant — the model has consolidated them —
//! so the `fraction` smallest distances are pruned for the epoch.
//!
//! The pruning is "pre-forward" in the PFB paper's sense: in the
//! cache-reuse epochs the decision costs zero device forwards (the
//! invariant battery pins this with MockBackend call counters), unlike
//! loss-based hiding which needs the sample to have passed through the
//! model at least once per scoring window.  Pruned samples are still
//! marked hidden in [`SampleState`] for the Fig. 6-8 diagnostics, but
//! the coordinator does not stats-refresh them
//! ([`Strategy::refresh_hidden_stats`] is false) — their next embedding
//! harvest refreshes both rows and stats in the same sweep.
//!
//! [`FeatureCache::centroid_distances`]: crate::state::FeatureCache::centroid_distances
//! [`SampleState`]: crate::state::SampleState

use super::{EpochPlan, PlanCtx, Strategy};
use crate::sampler::shuffled;
use crate::util::stats::argselect_smallest;

/// PFB: prune the `fraction` most redundant samples per epoch, scored
/// from the cached-feature centroid-distance proxy (see module docs).
pub struct Pfb {
    /// Fraction of the dataset pruned per scored epoch.
    pub fraction: f64,
    /// Re-harvest the feature cache every N epochs.
    pub refresh_every: usize,
}

impl Pfb {
    /// Prune `fraction` per epoch from a cache refreshed every
    /// `refresh_every` epochs (min 1).
    pub fn new(fraction: f64, refresh_every: usize) -> Self {
        Pfb { fraction, refresh_every: refresh_every.max(1) }
    }
}

impl Strategy for Pfb {
    fn name(&self) -> String {
        "pfb".into()
    }

    fn fraction_ceiling(&self, _epoch: usize) -> f64 {
        self.fraction
    }

    fn feature_refresh_every(&self) -> Option<usize> {
        Some(self.refresh_every)
    }

    fn plan_epoch(&mut self, ctx: &mut PlanCtx) -> anyhow::Result<EpochPlan> {
        ctx.state.roll_epoch();
        let n = ctx.data.n;
        // No committed harvest yet (epoch 0, post-restart, or a resume
        // that predates the first harvest): train the full epoch and let
        // the Refresh-phase cadence fill the cache.
        let ready = ctx.features.is_some_and(|f| f.ready());
        if !ready {
            return Ok(EpochPlan::plain(crate::sampler::epoch_permutation(n, ctx.rng)));
        }
        let cache = ctx.features.unwrap();
        let scores = cache.centroid_distances(ctx.data)?;
        let k = ((n as f64) * self.fraction).floor() as usize;
        let hidden = argselect_smallest(&scores, k);
        let mut is_hidden = vec![false; n];
        for &i in &hidden {
            is_hidden[i as usize] = true;
        }
        let kept: Vec<u32> = (0..n as u32).filter(|&i| !is_hidden[i as usize]).collect();
        ctx.state.set_hidden(&hidden);
        let order = shuffled(&kept, ctx.rng);
        let max_hidden = hidden.len();
        let pruned_pre_forward = hidden.len();
        Ok(EpochPlan {
            order,
            hidden,
            max_hidden,
            pruned_pre_forward,
            ..EpochPlan::plain(vec![])
        })
    }

    /// PFB never stats-refreshes the pruned list: the decision came from
    /// cached features (not lagging loss), and the next embedding harvest
    /// refreshes rows *and* stats in one sweep.  An extra refresh pass
    /// would break the zero-extra-forwards promise of cache-reuse epochs.
    fn refresh_hidden_stats(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::state::FeatureCache;
    use crate::strategies::testutil::*;

    /// A committed cache where sample i's row is [i, 0, ...]: within each
    /// class the lowest-index members sit closest to the class centroid's
    /// low side, and the distances are strictly graded.
    fn graded_cache(n: usize, dim: usize) -> FeatureCache {
        let mut c = FeatureCache::new(n);
        c.begin(dim).unwrap();
        for i in 0..n {
            let mut row = vec![0.0f32; dim];
            row[0] = i as f32;
            c.store_row(i, &row).unwrap();
        }
        c.commit(0);
        c
    }

    #[test]
    fn cold_cache_trains_full_epoch() {
        let tv = tiny_data(32);
        let mut state = graded_state(32);
        let mut s = Pfb::new(0.25, 3);
        // no cache at all
        let plan = run_plan(&mut s, 0, &tv.train, &mut state);
        assert_eq!(plan.order.len(), 32);
        assert!(plan.hidden.is_empty());
        assert_eq!(plan.pruned_pre_forward, 0);
        // a cache that exists but has no committed harvest
        let cold = FeatureCache::new(32);
        let plan = run_plan_with_features(&mut s, 1, &tv.train, &mut state, Some(&cold));
        assert_eq!(plan.order.len(), 32);
        assert!(plan.hidden.is_empty());
    }

    #[test]
    fn warm_cache_prunes_fraction_pre_forward() {
        let n = 40;
        let tv = tiny_data(n);
        let mut state = graded_state(n);
        let cache = graded_cache(n, 4);
        let mut s = Pfb::new(0.25, 3);
        let plan = run_plan_with_features(&mut s, 2, &tv.train, &mut state, Some(&cache));
        let k = (n as f64 * 0.25).floor() as usize;
        assert_eq!(plan.hidden.len(), k);
        assert_eq!(plan.pruned_pre_forward, k);
        assert_eq!(plan.max_hidden, k);
        assert_eq!(plan.order.len(), n - k);
        assert!(plan.weights.is_none());
        // hidden and trained sets are disjoint and cover the dataset
        let mut seen = vec![false; n];
        for &i in plan.hidden.iter().chain(plan.order.iter()) {
            assert!(!seen[i as usize], "sample {i} appears twice");
            seen[i as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        // state marks exactly the hidden set
        assert_eq!(state.hidden_count(), k);
        for &i in &plan.hidden {
            assert!(state.hidden[i as usize]);
        }
    }

    #[test]
    fn identical_cache_and_seed_replan_bitwise() {
        let n = 24;
        let tv = tiny_data(n);
        let cache = graded_cache(n, 3);
        let mut a = Pfb::new(0.3, 2);
        let mut b = Pfb::new(0.3, 2);
        let mut sa = graded_state(n);
        let mut sb = graded_state(n);
        let pa = run_plan_with_features(&mut a, 5, &tv.train, &mut sa, Some(&cache));
        let pb = run_plan_with_features(&mut b, 5, &tv.train, &mut sb, Some(&cache));
        assert_eq!(pa.order, pb.order);
        assert_eq!(pa.hidden, pb.hidden);
    }

    #[test]
    fn reports_refresh_cadence_and_ceiling() {
        let s = Pfb::new(0.15, 4);
        assert_eq!(s.feature_refresh_every(), Some(4));
        assert_eq!(s.fraction_ceiling(0), 0.15);
        assert!(!s.refresh_hidden_stats());
        // refresh_every is clamped to at least 1 (config validation
        // rejects 0 before it gets here, but the clamp keeps the type safe)
        assert_eq!(Pfb::new(0.1, 0).refresh_every, 1);
    }
}
