//! Selective-Backprop (Jiang et al. [17]).
//!
//! Forward pass on every sample, backprop only on samples accepted with
//! probability CDF_loss(ℓ)^beta (beta=1 cuts ~50%, the paper's setting).
//! The acceptance CDF comes from a rolling reservoir of recent losses,
//! as in the reference implementation.
//!
//! The strategy emits a full epoch order with `BatchMode::SelectiveBackprop`;
//! the coordinator performs the fwd-select-train loop (it owns the
//! executor), calling back into [`SbSelector`] for accept decisions.

use super::{BatchMode, EpochPlan, PlanCtx, Strategy};
use crate::sampler::epoch_permutation;
use crate::util::rng::Rng;

/// Rolling loss history + acceptance rule, shared with the coordinator.
pub struct SbSelector {
    /// Selectivity exponent: accept with probability CDF(loss)^beta.
    pub beta: f64,
    history: Vec<f32>,
    cap: usize,
    cursor: usize,
}

impl SbSelector {
    /// A selector with exponent `beta` over a `cap`-entry loss reservoir.
    pub fn new(beta: f64, cap: usize) -> Self {
        SbSelector { beta, history: Vec::with_capacity(cap), cap, cursor: 0 }
    }

    /// Push a loss into the rolling history (overwrites oldest at cap).
    pub fn record(&mut self, loss: f32) {
        if self.history.len() < self.cap {
            self.history.push(loss);
        } else {
            self.history[self.cursor] = loss;
            self.cursor = (self.cursor + 1) % self.cap;
        }
    }

    /// Empirical CDF of `loss` within the rolling history.
    pub fn cdf(&self, loss: f32) -> f64 {
        if self.history.is_empty() {
            return 1.0;
        }
        let below = self.history.iter().filter(|&&h| h <= loss).count();
        below as f64 / self.history.len() as f64
    }

    /// Accept-for-backprop probability: CDF(loss)^beta.
    pub fn accept(&mut self, loss: f32, rng: &mut Rng) -> bool {
        let p = self.cdf(loss).powf(self.beta);
        self.record(loss);
        rng.chance(p)
    }

    /// The rolling history and overwrite cursor, in storage order — what
    /// `coordinator/resume.rs` persists so an SB `--resume` replays the
    /// acceptance stream bit-exactly.
    pub fn export_history(&self) -> (&[f32], usize) {
        (&self.history, self.cursor)
    }

    /// Restore a history captured by [`SbSelector::export_history`].
    /// Entries beyond the reservoir cap are dropped; the cursor is only
    /// meaningful once the reservoir is full (it stays 0 while filling,
    /// matching how [`SbSelector::record`] evolves it).
    pub fn import_history(&mut self, history: &[f32], cursor: usize) {
        self.history = history.to_vec();
        self.history.truncate(self.cap);
        self.cursor = if self.history.len() < self.cap { 0 } else { cursor % self.cap };
    }

    /// Expected selectivity over the current history (diagnostics).
    pub fn mean_accept_prob(&self) -> f64 {
        if self.history.is_empty() {
            return 1.0;
        }
        self.history
            .iter()
            .map(|&l| self.cdf(l).powf(self.beta))
            .sum::<f64>()
            / self.history.len() as f64
    }
}

/// The Selective-Backprop strategy: emits full-epoch candidate orders
/// with `BatchMode::SelectiveBackprop`; the engine's SB sink performs the
/// fwd-select-train loop.
pub struct SelectiveBackprop {
    /// Selectivity exponent (1.0 cuts ~50% of backprops, paper setting).
    pub beta: f64,
    /// The acceptance selector (informational copy; the trainer owns the
    /// live one that the SB sink consults).
    pub selector: SbSelector,
}

impl SelectiveBackprop {
    /// Strategy with selectivity exponent `beta`.
    pub fn new(beta: f64) -> Self {
        SelectiveBackprop { beta, selector: SbSelector::new(beta, 4096) }
    }
}

impl Strategy for SelectiveBackprop {
    fn name(&self) -> String {
        "sb".into()
    }

    fn plan_epoch(&mut self, ctx: &mut PlanCtx) -> anyhow::Result<EpochPlan> {
        let mut plan = EpochPlan::plain(epoch_permutation(ctx.data.n, ctx.rng));
        plan.batch_mode = BatchMode::SelectiveBackprop { beta: self.beta };
        Ok(plan)
    }

    fn refresh_hidden_stats(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_monotone() {
        let mut s = SbSelector::new(1.0, 100);
        for i in 0..100 {
            s.record(i as f32);
        }
        assert!(s.cdf(10.0) < s.cdf(50.0));
        assert!(s.cdf(99.0) >= 0.99);
    }

    #[test]
    fn beta1_accepts_about_half() {
        let mut s = SbSelector::new(1.0, 1000);
        let mut rng = Rng::new(1);
        // warm the history with uniform losses
        for i in 0..1000 {
            s.record((i % 100) as f32);
        }
        let mut accepted = 0;
        let total = 5000;
        for i in 0..total {
            if s.accept((i % 100) as f32, &mut rng) {
                accepted += 1;
            }
        }
        let frac = accepted as f64 / total as f64;
        // E[CDF(U)^1] = 0.5 for uniform losses
        assert!((frac - 0.5).abs() < 0.05, "frac={frac}");
    }

    #[test]
    fn high_loss_always_preferred() {
        let mut s = SbSelector::new(1.0, 100);
        for i in 0..100 {
            s.record(i as f32);
        }
        let mut rng = Rng::new(2);
        let (mut hi, mut lo) = (0, 0);
        for _ in 0..500 {
            if s.accept(99.0, &mut rng) {
                hi += 1;
            }
            if s.accept(1.0, &mut rng) {
                lo += 1;
            }
        }
        // interleaved accepts keep recording 99s and 1s, so the history
        // settles at cdf(99)=1.0 vs cdf(1)~0.5: expect hi ~ 2x lo.
        assert!(hi as f64 > lo as f64 * 1.7, "hi={hi} lo={lo}");
        assert!(hi > 450, "hi={hi}"); // top-loss nearly always kept
    }

    #[test]
    fn empty_history_accepts_everything() {
        let mut s = SbSelector::new(1.0, 10);
        let mut rng = Rng::new(3);
        assert!(s.accept(0.0, &mut rng));
    }

    /// Export → import reproduces the selector exactly: the restored
    /// copy makes the identical accept decisions on the same RNG stream,
    /// including cursor-wrapped reservoirs.
    #[test]
    fn history_export_import_is_exact() {
        let mut a = SbSelector::new(1.0, 16);
        // overfill so the cursor has wrapped
        for i in 0..40 {
            a.record((i % 7) as f32);
        }
        let (hist, cursor) = a.export_history();
        let (hist, cursor) = (hist.to_vec(), cursor);
        let mut b = SbSelector::new(1.0, 16);
        b.import_history(&hist, cursor);
        let mut rng_a = Rng::new(11);
        let mut rng_b = Rng::new(11);
        for i in 0..200 {
            let loss = (i % 13) as f32 * 0.5;
            assert_eq!(a.accept(loss, &mut rng_a), b.accept(loss, &mut rng_b), "step {i}");
        }
    }
}
