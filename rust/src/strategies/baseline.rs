//! Baseline: uniform sampling without replacement over the full dataset —
//! the training regime every other strategy is judged against.

use super::{EpochPlan, PlanCtx, Strategy};
use crate::sampler::epoch_permutation;

/// The paper's "Baseline": a fresh full permutation every epoch, nothing
/// hidden, weights 1.0.
pub struct Baseline;

impl Strategy for Baseline {
    fn name(&self) -> String {
        "baseline".into()
    }

    fn plan_epoch(&mut self, ctx: &mut PlanCtx) -> anyhow::Result<EpochPlan> {
        Ok(EpochPlan::plain(epoch_permutation(ctx.data.n, ctx.rng)))
    }

    fn refresh_hidden_stats(&self) -> bool {
        false // nothing hidden
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::testutil::*;

    #[test]
    fn full_permutation_every_epoch() {
        let tv = tiny_data(32);
        let mut state = graded_state(32);
        let mut s = Baseline;
        let plan = run_plan(&mut s, 0, &tv.train, &mut state);
        assert_eq!(plan.order.len(), 32);
        assert!(plan.hidden.is_empty());
        assert_eq!(plan.lr_scale, 1.0);
        let mut sorted = plan.order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..32).collect::<Vec<u32>>());
    }
}
