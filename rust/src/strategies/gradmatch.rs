//! GradMatch (Killamsetty et al. [18]), simplified as in the paper's
//! comparison setting (Table 3: single worker, CIFAR-scale).
//!
//! Every R epochs, select a subset (and per-sample weights) whose weighted
//! gradient sum matches the full-data gradient.  Following the reference
//! implementation's practical approximations:
//!   * last-layer gradients only:  g_i = (p_i - onehot(y_i)) ⊗ emb_i
//!     (obtained from the `fwd_embed` artifact),
//!   * per-class decomposition: OMP runs independently within each class
//!     with a proportional budget,
//!   * between selection epochs the same subset + weights are reused.
//!
//! The matching itself is orthogonal matching pursuit (greedy residual
//! projection) with non-negative weights, per class.

use super::{EpochPlan, PlanCtx, Strategy};
use crate::data::batch::BatchAssembler;
use crate::sampler::shuffled;

/// GradMatch: every R epochs, per-class OMP picks a weighted subset whose
/// gradient sum matches the full-data gradient (see module docs).
pub struct GradMatch {
    /// Fraction of the dataset to *remove* (subset size = (1-F)·N).
    pub fraction: f64,
    /// Re-select every R epochs.
    pub every_r: usize,
    subset: Option<(Vec<u32>, Vec<f32>)>,
}

impl GradMatch {
    /// Remove `fraction` of the data, re-selecting every `every_r` epochs.
    pub fn new(fraction: f64, every_r: usize) -> Self {
        GradMatch { fraction, every_r: every_r.max(1), subset: None }
    }

    /// Greedy matching pursuit: pick samples maximizing the projection of
    /// the residual (class mean gradient minus weighted selected sum).
    /// Returns (local indices, weights).
    fn omp(gradients: &[Vec<f32>], budget: usize) -> (Vec<usize>, Vec<f32>) {
        let n = gradients.len();
        if n == 0 || budget == 0 {
            return (vec![], vec![]);
        }
        let dim = gradients[0].len();
        // target: mean gradient of the class
        let mut residual = vec![0.0f32; dim];
        for g in gradients {
            for (r, &v) in residual.iter_mut().zip(g) {
                *r += v / n as f32;
            }
        }
        let norms: Vec<f32> = gradients
            .iter()
            .map(|g| g.iter().map(|v| v * v).sum::<f32>().max(1e-12))
            .collect();
        let mut selected: Vec<usize> = Vec::with_capacity(budget);
        let mut weights: Vec<f32> = Vec::with_capacity(budget);
        let mut used = vec![false; n];
        for _ in 0..budget.min(n) {
            // best projection onto the residual
            let mut best = None;
            let mut best_score = 0.0f32;
            for (i, g) in gradients.iter().enumerate() {
                if used[i] {
                    continue;
                }
                let dot: f32 = residual.iter().zip(g).map(|(r, v)| r * v).sum();
                let score = dot / norms[i].sqrt();
                if best.is_none() || score > best_score {
                    best = Some(i);
                    best_score = score;
                }
            }
            let Some(i) = best else { break };
            used[i] = true;
            let dot: f32 = residual.iter().zip(&gradients[i]).map(|(r, v)| r * v).sum();
            let w = (dot / norms[i]).max(0.0);
            for (r, &v) in residual.iter_mut().zip(&gradients[i]) {
                *r -= w * v;
            }
            selected.push(i);
            weights.push(w);
        }
        // Rescale weights so the subset's total gradient mass matches the
        // class population (unbiased magnitude after subsetting).
        let wsum: f32 = weights.iter().sum();
        if wsum > 1e-9 {
            let scale = n as f32 / wsum / gradients.len().max(1) as f32 * selected.len() as f32;
            for w in weights.iter_mut() {
                *w *= scale;
            }
        } else {
            weights.iter_mut().for_each(|w| *w = 1.0);
        }
        (selected, weights)
    }

    /// Full selection pass: embed every sample, build per-class last-layer
    /// gradients, run per-class OMP with budget (1-F)·|class|.
    fn select_subset(&self, ctx: &mut PlanCtx) -> anyhow::Result<(Vec<u32>, Vec<f32>)> {
        let exec = ctx
            .exec
            .as_deref_mut()
            .ok_or_else(|| anyhow::anyhow!("GradMatch needs executor access (fwd_embed)"))?;
        let data = ctx.data;
        let b = exec.meta.batch;
        let classes = exec.meta.classes;
        let emb_dim = exec.meta.embed_dim;
        anyhow::ensure!(emb_dim > 0, "variant {} has no fwd_embed", exec.meta.name);

        // Gather per-sample last-layer gradient features.
        let mut per_class: Vec<Vec<(u32, Vec<f32>)>> = vec![Vec::new(); classes];
        let mut asm = BatchAssembler::new(data, b);
        let all: Vec<u32> = (0..data.n as u32).collect();
        for chunk in all.chunks(b) {
            asm.fill(data, chunk, None);
            let es = exec.fwd_embed(&asm.x, &asm.y)?;
            for (slot, &sample) in chunk.iter().enumerate() {
                let label = data.label(sample as usize) as usize;
                // g = (p - onehot) ⊗ emb, flattened [classes*emb_dim] is
                // large; use the memory-light equivalent feature
                // [emb * (1 - p_y), p_residual_norm * emb] approximation:
                // we keep the exact per-class factor (p - onehot)_y times
                // emb, which is the gradient row w.r.t. the true class —
                // the dominant row and the one GradMatch's per-class
                // decomposition matches on.
                let py = es.probs[slot * classes + label];
                let coeff = py - 1.0; // (p - onehot)_y
                let g: Vec<f32> = es.emb[slot * emb_dim..(slot + 1) * emb_dim]
                    .iter()
                    .map(|&e| coeff * e)
                    .collect();
                per_class[label].push((sample, g));
            }
        }

        let keep_frac = 1.0 - self.fraction;
        let mut subset = Vec::new();
        let mut weights = Vec::new();
        for members in per_class.iter() {
            if members.is_empty() {
                continue;
            }
            let budget = ((members.len() as f64) * keep_frac).round().max(1.0) as usize;
            let grads: Vec<Vec<f32>> = members.iter().map(|(_, g)| g.clone()).collect();
            let (sel, ws) = Self::omp(&grads, budget);
            for (li, w) in sel.into_iter().zip(ws) {
                subset.push(members[li].0);
                // Bounded influence: raw MP weights are spiky at many-class budgets
                // (C=100 -> ~40 samples/class); clamp keeps mean~1, var bounded.
                weights.push(w.clamp(0.5, 2.0));
            }
        }
        // Renormalize after clamping so the subset's mean gradient weight
        // is exactly 1 (clamping would otherwise shrink the effective LR).
        let mean: f32 = weights.iter().sum::<f32>() / weights.len().max(1) as f32;
        if mean > 1e-6 {
            for w in weights.iter_mut() {
                *w /= mean;
            }
        }
        Ok((subset, weights))
    }
}

impl Strategy for GradMatch {
    fn name(&self) -> String {
        "gradmatch".into()
    }

    fn fraction_ceiling(&self, _epoch: usize) -> f64 {
        self.fraction
    }

    fn plan_epoch(&mut self, ctx: &mut PlanCtx) -> anyhow::Result<EpochPlan> {
        if ctx.epoch == 0 {
            return Ok(EpochPlan::plain(crate::sampler::epoch_permutation(
                ctx.data.n, ctx.rng,
            )));
        }
        if (ctx.epoch - 1) % self.every_r == 0 || self.subset.is_none() {
            let sub = self.select_subset(ctx)?;
            crate::debug!(
                "gradmatch: selected {} / {} samples at epoch {}",
                sub.0.len(),
                ctx.data.n,
                ctx.epoch
            );
            self.subset = Some(sub);
        }
        let (subset, weights) = self.subset.as_ref().unwrap();
        // shuffle subset and weights together
        let mut idx: Vec<u32> = (0..subset.len() as u32).collect();
        idx = shuffled(&idx, ctx.rng);
        let order: Vec<u32> = idx.iter().map(|&i| subset[i as usize]).collect();
        let w: Vec<f32> = idx.iter().map(|&i| weights[i as usize]).collect();
        Ok(EpochPlan {
            order,
            weights: Some(w),
            ..EpochPlan::plain(vec![])
        })
    }

    fn refresh_hidden_stats(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn omp_prefers_representative_gradients() {
        // class mean points along +x; sample 0 matches it, sample 1 is
        // orthogonal, sample 2 is anti-aligned.
        let grads = vec![
            vec![1.0, 0.1],
            vec![0.0, 1.0],
            vec![-1.0, 0.0],
            vec![0.9, -0.1],
        ];
        let (sel, w) = GradMatch::omp(&grads, 2);
        assert_eq!(sel.len(), 2);
        assert!(sel.contains(&0) || sel.contains(&3), "sel={sel:?}");
        assert!(w.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn omp_empty_and_zero_budget() {
        let (s, w) = GradMatch::omp(&[], 3);
        assert!(s.is_empty() && w.is_empty());
        let (s, w) = GradMatch::omp(&[vec![1.0]], 0);
        assert!(s.is_empty() && w.is_empty());
    }

    #[test]
    fn omp_budget_caps_selection() {
        let grads: Vec<Vec<f32>> = (0..10).map(|i| vec![1.0 + i as f32 * 0.01, 0.5]).collect();
        let (sel, _) = GradMatch::omp(&grads, 4);
        assert!(sel.len() <= 4);
        // no duplicates
        let mut s = sel.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), sel.len());
    }
}
