//! ISWR: Importance Sampling With Replacement (Katharopoulos & Fleuret
//! [11], as configured in the paper's §4 comparison).
//!
//! Per epoch, N samples are drawn *with replacement* proportionally to
//! their lagging loss (so the model still sees N samples — no step-count
//! savings), with the standard 1/(N·p_i) bias-correction weights applied
//! to the gradient.  The per-epoch O(N) weight build + O(1)-per-draw alias
//! table is exactly the bookkeeping overhead the paper measures: ISWR gets
//! *slower* than the baseline on large datasets (Fig. 2) even when it
//! converges in fewer epochs.

use super::{EpochPlan, PlanCtx, Strategy};
use crate::sampler::alias::AliasTable;

/// Importance Sampling With Replacement: N loss-proportional draws per
/// epoch with 1/(N·p_i) bias-correction weights (see module docs).
#[derive(Default)]
pub struct Iswr {
    /// Clamp for the importance weights (stability; [11] uses smoothing).
    pub max_weight: f32,
    /// Uniform-mixing coefficient: p = mix*uniform + (1-mix)*loss-prop.
    /// Katharopoulos & Fleuret's robust variant; prevents the late-epoch
    /// collapse where a handful of unlearnable samples dominate draws.
    pub uniform_mix: f64,
}

impl Iswr {
    /// The paper-comparison configuration (clamp 8.0, mix 0.7).
    pub fn new() -> Self {
        Iswr { max_weight: 8.0, uniform_mix: 0.7 }
    }
}

impl Strategy for Iswr {
    fn name(&self) -> String {
        "iswr".into()
    }

    fn plan_epoch(&mut self, ctx: &mut PlanCtx) -> anyhow::Result<EpochPlan> {
        let n = ctx.data.n;
        if ctx.epoch == 0 {
            // No losses yet: uniform epoch.
            return Ok(EpochPlan::plain(crate::sampler::epoch_permutation(n, ctx.rng)));
        }
        let max_w = if self.max_weight > 0.0 { self.max_weight } else { 8.0 };
        let mix = if self.uniform_mix > 0.0 { self.uniform_mix } else { 0.7 };
        // p_i ∝ mix/N + (1-mix)·loss_i/Σloss (robust smoothed importance).
        let raw: Vec<f64> = ctx
            .state
            .loss
            .iter()
            .map(|&l| if l.is_finite() { (l as f64).max(1e-3) } else { 1.0 })
            .collect();
        let raw_total: f64 = raw.iter().sum();
        let losses: Vec<f64> = raw
            .iter()
            .map(|&l| mix / n as f64 + (1.0 - mix) * l / raw_total)
            .collect();
        let total: f64 = losses.iter().sum();
        let table = AliasTable::new(&losses);
        let order = table.draw_many(n, ctx.rng);
        // Bias correction: w_i = 1/(N p_i), clamped.
        let weights: Vec<f32> = order
            .iter()
            .map(|&i| {
                let p = losses[i as usize] / total;
                ((1.0 / (n as f64 * p)) as f32).min(max_w)
            })
            .collect();
        Ok(EpochPlan {
            order,
            weights: Some(weights),
            ..EpochPlan::plain(vec![])
        })
    }

    fn refresh_hidden_stats(&self) -> bool {
        false // nothing hidden; stats refresh happens via training passes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::testutil::*;

    #[test]
    fn draws_n_samples_with_replacement_biased_to_loss() {
        let tv = tiny_data(64);
        let mut state = graded_state(64); // loss(i) = i
        let mut s = Iswr::new();
        let plan = run_plan(&mut s, 1, &tv.train, &mut state);
        assert_eq!(plan.order.len(), 64);
        // high-loss half should be drawn more often than low-loss half:
        // with mix=0.5, P(high half) = 0.7*0.5 + 0.3*0.754 ~ 0.58
        let high = plan.order.iter().filter(|&&i| i >= 32).count();
        assert!(high > 34, "high-loss draws: {high}");
        // weights present and positive
        let w = plan.weights.as_ref().unwrap();
        assert_eq!(w.len(), 64);
        assert!(w.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn bias_correction_weights_inverse_to_probability() {
        // w_i must equal 1/(N p_i) for the smoothed distribution
        let tv = tiny_data(32);
        let mut state = graded_state(32);
        let mut s = Iswr::new();
        let plan = run_plan(&mut s, 1, &tv.train, &mut state);
        let w = plan.weights.as_ref().unwrap();
        let n = 32.0f64;
        let raw_total: f64 = (0..32).map(|i| (i as f64).max(1e-3)).sum();
        for (pos, &i) in plan.order.iter().enumerate() {
            let raw = (i as f64).max(1e-3);
            let p = (0.7 / n + 0.3 * raw / raw_total)
                / (0..32)
                    .map(|j| 0.7 / n + 0.3 * (j as f64).max(1e-3) / raw_total)
                    .sum::<f64>();
            let expect = (1.0 / (n * p)).min(8.0) as f32;
            assert!(
                (w[pos] - expect).abs() / expect < 1e-4,
                "w[{pos}]={} expect {expect}",
                w[pos]
            );
        }
    }

    #[test]
    fn epoch0_uniform() {
        let tv = tiny_data(16);
        let mut state = crate::state::SampleState::new(16);
        let mut s = Iswr::new();
        let plan = run_plan(&mut s, 0, &tv.train, &mut state);
        let mut o = plan.order.clone();
        o.sort_unstable();
        assert_eq!(o, (0..16).collect::<Vec<u32>>());
        assert!(plan.weights.is_none());
    }
}
