//! FORGET: the paper's online variant of forgetting-event pruning
//! (Toneva et al. [13], §4 "FORGET" baseline).
//!
//! Train on the full dataset for `prune_epoch` epochs while counting
//! forgetting events (correct -> incorrect transitions, tracked by
//! `SampleState`).  Then permanently prune the fraction F of *least
//! forgettable* samples (ordered by ascending forgetting count; never-
//! correct samples count as most forgettable, as in [13]) and restart
//! training from scratch on the pruned set.  The reported training time
//! includes the prologue — which is why FORGET loses wall-clock on short
//! schedules (Table 2 / §4.2).

use super::{EpochPlan, PlanCtx, Strategy};
use crate::sampler::shuffled;

/// Online forgetting-event pruning: full-data prologue, one permanent
/// prune of the least-forgettable fraction, restart from scratch.
pub struct Forget {
    /// Epoch at which forgetting counts are read and pruning happens.
    pub prune_epoch: usize,
    /// Fraction of the dataset to prune (least forgettable first).
    pub fraction: f64,
    kept: Option<Vec<u32>>,
}

impl Forget {
    /// Prune `fraction` of the dataset at `prune_epoch`, then restart.
    pub fn new(prune_epoch: usize, fraction: f64) -> Self {
        Forget { prune_epoch, fraction, kept: None }
    }

    /// Ordering key: forgetting events, with never-learned samples treated
    /// as infinitely forgettable (pruned last), matching [13] footnote 1.
    fn prune(&self, ctx: &PlanCtx) -> Vec<u32> {
        let n = ctx.data.n;
        let k_prune = ((n as f64) * self.fraction).floor() as usize;
        let keys: Vec<f32> = (0..n)
            .map(|i| {
                if !ctx.state.ever_correct[i] {
                    f32::INFINITY // unlearned: most forgettable, keep
                } else {
                    ctx.state.forget_events[i] as f32
                }
            })
            .collect();
        // prune the k smallest keys (never/least forgotten)
        let pruned = crate::util::stats::argselect_smallest(&keys, k_prune);
        let mut is_pruned = vec![false; n];
        for &i in &pruned {
            is_pruned[i as usize] = true;
        }
        (0..n as u32).filter(|&i| !is_pruned[i as usize]).collect()
    }
}

impl Strategy for Forget {
    fn name(&self) -> String {
        "forget".into()
    }

    fn fraction_ceiling(&self, _epoch: usize) -> f64 {
        self.fraction
    }

    fn plan_epoch(&mut self, ctx: &mut PlanCtx) -> anyhow::Result<EpochPlan> {
        if ctx.epoch < self.prune_epoch {
            return Ok(EpochPlan::plain(crate::sampler::epoch_permutation(
                ctx.data.n, ctx.rng,
            )));
        }
        if ctx.epoch == self.prune_epoch {
            let kept = self.prune(ctx);
            crate::info!(
                "FORGET: pruned {} of {} samples at epoch {}; restarting",
                ctx.data.n - kept.len(),
                ctx.data.n,
                ctx.epoch
            );
            self.kept = Some(kept);
            let mut plan = EpochPlan::plain(shuffled(self.kept.as_ref().unwrap(), ctx.rng));
            plan.reset_params = true; // restart training from scratch
            return Ok(plan);
        }
        let kept = self
            .kept
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("FORGET: prune epoch skipped"))?;
        Ok(EpochPlan::plain(shuffled(kept, ctx.rng)))
    }

    fn refresh_hidden_stats(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::testutil::*;

    #[test]
    fn full_dataset_before_prune() {
        let tv = tiny_data(30);
        let mut state = graded_state(30);
        let mut f = Forget::new(5, 0.3);
        let plan = run_plan(&mut f, 2, &tv.train, &mut state);
        assert_eq!(plan.order.len(), 30);
        assert!(!plan.reset_params);
    }

    #[test]
    fn prunes_least_forgettable_and_resets() {
        let tv = tiny_data(30);
        let mut state = graded_state(30);
        // make samples 0..10 never-forgotten-but-learned (events=0,
        // ever_correct), 10..20 forgotten twice, 20..30 never learned
        for i in 0..30 {
            state.forget_events[i] = if (10..20).contains(&i) { 2 } else { 0 };
            state.ever_correct[i] = i < 20;
        }
        let mut f = Forget::new(3, 0.333);
        let plan = run_plan(&mut f, 3, &tv.train, &mut state);
        assert!(plan.reset_params);
        assert_eq!(plan.order.len(), 21); // 9 pruned (floor(30*0.333))
        // pruned must all come from the never-forgotten learned group 0..10
        let pruned: Vec<u32> = (0..30u32).filter(|i| !plan.order.contains(i)).collect();
        assert_eq!(pruned.len(), 9);
        assert!(pruned.iter().all(|&i| i < 10), "pruned={pruned:?}");
        // subsequent epochs reuse the pruned set without reset
        let plan2 = run_plan(&mut f, 4, &tv.train, &mut state);
        assert!(!plan2.reset_params);
        assert_eq!(plan2.order.len(), 21);
    }

    #[test]
    fn never_learned_samples_survive_pruning() {
        let tv = tiny_data(20);
        let mut state = graded_state(20);
        for i in 0..20 {
            state.ever_correct[i] = i < 10; // 10..20 never learned
            state.forget_events[i] = 0;
        }
        let mut f = Forget::new(1, 0.5);
        let plan = run_plan(&mut f, 1, &tv.train, &mut state);
        // all 10 pruned samples must be the learned ones
        for &i in &plan.order {
            assert!(i >= 10);
        }
    }
}
