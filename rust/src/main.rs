//! `kakurenbo` — the launcher.
//!
//! Subcommands:
//!
//! ```text
//! train     --preset <name> --strategy <name> [overrides]   one training run
//! compare   --preset <name> [--strategies a,b,c]            strategy comparison table
//! presets                                                   list presets
//! variants                                                  list artifact variants
//! ```
//!
//! Overrides (any subset): `--epochs --seed --workers --dp --base_lr
//! --momentum --max_fraction --tau --drop_top --variant --eval_every
//! --detailed_metrics --service-lane --checkpoint_every --checkpoint_dir
//! --resume --checkpoint-pool --checkpoint-verify --checkpoint-compress
//! --fault-policy --straggler-timeout-ms --serve --serve-threads
//! --serve-replicas --serve-batch --serve-batch-wait-us --serve-retain
//! --pfb-fraction --pfb-refresh-every`

use kakurenbo::cli::Args;
use kakurenbo::config::{presets, StrategyConfig};
use kakurenbo::coordinator::{run_comparison, run_experiment};
use kakurenbo::runtime::{default_artifacts_dir, XlaRuntime};
use kakurenbo::util::logging::{set_level, Level};
use kakurenbo::util::table::{diff_pct, pct, speedup_pct, Table};

const OVERRIDE_KEYS: &[&str] = &[
    "epochs", "seed", "workers", "dp", "base_lr", "warmup_epochs", "momentum",
    "max_fraction", "tau", "drop_top", "variant", "eval_every", "detailed_metrics",
    "checkpoint_every", "checkpoint_dir", "resume", "service-lane", "service_lane",
    "checkpoint_pool", "checkpoint-pool", "checkpoint_verify", "checkpoint-verify",
    "checkpoint_compress", "checkpoint-compress", "fault_policy", "fault-policy",
    "straggler_timeout_ms", "straggler-timeout-ms", "serve", "serve_threads",
    "serve-threads", "serve_replicas", "serve-replicas", "serve_batch", "serve-batch",
    "serve_batch_wait_us", "serve-batch-wait-us", "serve_retain", "serve-retain",
    "pfb_fraction", "pfb-fraction", "pfb_refresh_every", "pfb-refresh-every",
];

fn strategy_by_name(name: &str, fraction: f64) -> anyhow::Result<StrategyConfig> {
    Ok(match name {
        "baseline" => StrategyConfig::Baseline,
        "kakurenbo" => StrategyConfig::kakurenbo(fraction),
        "iswr" => StrategyConfig::Iswr,
        "sb" => StrategyConfig::SelectiveBackprop { beta: 1.0 },
        "forget" => StrategyConfig::Forget { prune_epoch: 5, fraction },
        "gradmatch" => StrategyConfig::GradMatch { fraction, every_r: 3 },
        "random" => StrategyConfig::RandomHiding { fraction },
        "infobatch" => StrategyConfig::InfoBatch { r: fraction },
        "el2n" => StrategyConfig::El2n { score_epoch: 4, fraction, restart: false },
        "pfb" => StrategyConfig::Pfb { fraction, refresh_every: 3 },
        other if other.starts_with("kakurenbo-v") => {
            let comps = kakurenbo::config::Components::from_bits(&other["kakurenbo-".len()..])?;
            StrategyConfig::Kakurenbo {
                max_fraction: fraction,
                tau: 0.7,
                components: comps,
                drop_top: 0.0,
                select_mode: kakurenbo::hiding::selector::SelectMode::QuickSelect,
            }
        }
        other => anyhow::bail!(
            "unknown strategy {other:?}; available: baseline kakurenbo kakurenbo-vXXXX iswr sb forget gradmatch random infobatch el2n pfb"
        ),
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    if args.bool_flag("verbose") {
        set_level(Level::Debug);
    }
    if args.bool_flag("quiet") {
        set_level(Level::Warn);
    }
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "compare" => cmd_compare(&args),
        "presets" => {
            for p in presets::ALL {
                println!("{p}");
            }
            Ok(())
        }
        "variants" => {
            let rt_dir = default_artifacts_dir();
            let manifest = kakurenbo::runtime::Manifest::load(&rt_dir)?;
            let mut t = Table::new("artifact variants")
                .header(&["variant", "family", "batch", "classes", "params"]);
            for (name, m) in &manifest.models {
                t.row(vec![
                    name.clone(),
                    m.family.clone(),
                    m.batch.to_string(),
                    m.classes.to_string(),
                    m.param_count.to_string(),
                ]);
            }
            t.print();
            Ok(())
        }
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?} (try `kakurenbo help`)"),
    }
}

fn build_config(args: &Args) -> anyhow::Result<kakurenbo::config::ExperimentConfig> {
    let preset = args.flag_or("preset", "imagenet_resnet50");
    let mut cfg = presets::by_name(preset)?;
    let fraction = args.flag_parse::<f64>("max_fraction")?.unwrap_or(0.3);
    if let Some(strategy) = args.flag("strategy") {
        cfg.strategy = strategy_by_name(strategy, fraction)?;
    }
    for key in OVERRIDE_KEYS {
        if let Some(v) = args.flag(key) {
            // strategy-dependent keys may not apply; ignore mismatches for
            // generic sweeps but surface truly unknown keys
            if let Err(e) = cfg.apply_override(key, v) {
                kakurenbo::warn_!("override --{key}={v} skipped: {e}");
            }
        }
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = build_config(args)?;
    let rt = XlaRuntime::new(&cfg.artifacts_dir)?;
    let name = format!("{}_{}", cfg.name, cfg.strategy.name());
    let result = run_experiment(&rt, cfg)?;
    let mut t = Table::new(format!("run: {name}")).header(&[
        "strategy", "final acc", "best acc", "time (s)", "modeled (s)",
    ]);
    t.row(vec![
        result.strategy.clone(),
        pct(result.final_acc),
        pct(result.best_acc),
        format!("{:.1}", result.total_time),
        format!("{:.1}", result.total_modeled_time),
    ]);
    t.print();
    if let Some(dir) = args.flag("out") {
        result.save(std::path::Path::new(dir), &name)?;
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let cfg = build_config(args)?;
    let fraction = args.flag_parse::<f64>("max_fraction")?.unwrap_or(0.3);
    let list = args.flag_or("strategies", "baseline,kakurenbo,iswr,sb");
    let strategies: Vec<StrategyConfig> = list
        .split(',')
        .map(|s| strategy_by_name(s.trim(), fraction))
        .collect::<anyhow::Result<_>>()?;
    let rt = XlaRuntime::new(&cfg.artifacts_dir)?;
    let results = run_comparison(&rt, &cfg, &strategies)?;
    let base = &results[0];
    let mut t = Table::new(format!("comparison: {} (F={fraction})", cfg.name)).header(&[
        "strategy", "acc", "diff", "time (s)", "vs base", "modeled (s)", "vs base",
    ]);
    for r in &results {
        t.row(vec![
            r.strategy.clone(),
            pct(r.best_acc),
            if r.strategy == base.strategy { "".into() } else { diff_pct(r.best_acc, base.best_acc) },
            format!("{:.1}", r.total_time),
            if r.strategy == base.strategy { "".into() } else { speedup_pct(r.total_time, base.total_time) },
            format!("{:.1}", r.total_modeled_time),
            if r.strategy == base.strategy {
                "".into()
            } else {
                speedup_pct(r.total_modeled_time, base.total_modeled_time)
            },
        ]);
    }
    t.print();
    if let Some(dir) = args.flag("out") {
        for r in &results {
            r.save(std::path::Path::new(dir), &r.name.replace('/', "_"))?;
        }
    }
    Ok(())
}

const HELP: &str = "\
kakurenbo — NeurIPS'23 'Adaptively Hiding Samples' reproduction (rust+JAX+Pallas)

USAGE:
  kakurenbo train   --preset imagenet_resnet50 --strategy kakurenbo [--max_fraction 0.3] [--epochs N] [--out results/]
  kakurenbo compare --preset deepcam --strategies baseline,kakurenbo,iswr
  kakurenbo presets
  kakurenbo variants

Strategies: baseline kakurenbo kakurenbo-vXXXX (ablation bits HE/MB/RF/LR)
            iswr sb forget gradmatch random infobatch el2n pfb
            (catalog with citations + flags: docs/strategies.md)
Overrides:  --epochs --seed --workers --dp --base_lr --warmup_epochs
            --momentum --max_fraction --tau --drop_top --variant
            --pfb-fraction --pfb-refresh-every
            --eval_every --service-lane --checkpoint_every
            --checkpoint_dir --resume --checkpoint-pool
            --checkpoint-verify --checkpoint-compress
            --fault-policy --straggler-timeout-ms
            --serve --serve-threads --serve-replicas --serve-batch
            --serve-batch-wait-us --serve-retain
Flags:      --verbose --quiet --out <dir>

--workers N executes data-parallel: the epoch order is sharded across N
pooled worker lanes behind a deterministic bulk-synchronous reduction.
--dp picks the schedule (docs/worker-model.md):
  serial-equivalent  (default) bitwise identical to the serial
                     single-stream simulation of the same N
  average            true synchronous SGD: per-worker executor replicas,
                     parameters averaged at every step barrier; needs
                     --workers > 1 and a non-weighted, non-SB strategy

--service-lane {on,off} moves validation eval + checkpoint serialization
onto a persistent background lane (its own executor replica) that works
on exact parameter snapshots while training continues; results fold back
in fixed epoch order and are bitwise identical to the serial path
(default: off).  --checkpoint_every K + --checkpoint_dir D write full
checkpoints (params + momentum + trainer state); --resume continues a
run from D bit-exactly.

--serve <addr> serves live snapshots over HTTP while training
(docs/serving.md): a fleet of serving replicas subscribed to per-epoch
params snapshots answers POST /v1/stats, POST /v1/embed,
GET /v1/snapshot, GET /healthz on <addr> (host:port; port 0 picks a
free port).  --serve-threads N sizes the HTTP worker pool (default 2);
--serve-replicas R spawns R replica lanes (default 1, least-loaded
routing, one dead lane degrades only itself); --serve-batch N coalesces
up to N concurrent queries into one device forward, waiting at most
--serve-batch-wait-us (default 250) for company — answers are bitwise
identical to per-query execution; --serve-retain K bounds the hub to
the K most recent publications (default 2).  Serving never perturbs
training: records are bitwise identical with it on or off.

--strategy pfb prunes pre-forward from a cached-feature proxy:
--pfb-fraction F drops the F most redundant samples per scored epoch,
--pfb-refresh-every N re-harvests penultimate-layer embeddings every N
epochs (one fwd_embed sweep; the N-1 epochs in between score from the
cache with zero extra device forwards).

--fault-policy {fail,elastic} picks what a multi-worker run does when a
lane dies or stalls mid-epoch (docs/worker-model.md \"Fault tolerance\"):
  fail     (default) abort with a named error; combine with --resume
  elastic  retire the lane and re-issue its remaining shard slices
           deterministically — bitwise identical to the undisturbed run
--straggler-timeout-ms N treats a lane silent for N ms at a step barrier
as faulty (0 = disabled, the default).

Checkpoints are content-addressed sha256 artifacts (docs/snapshots.md):
  --checkpoint-pool N        leaf write-pool threads (0 = auto, 1 = serial)
  --checkpoint-verify on|off verify per-leaf digests on load (default on)
  --checkpoint-compress on|off LZSS momentum leaves (default on; params
                             are always raw)
";
