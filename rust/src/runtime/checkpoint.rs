//! Checkpointing: save/restore the executor's full mutable model state
//! (parameters **and** SGD momentum) as a directory of `.npy` files plus
//! a JSON index — inspectable from Python (`np.load`) and stable across
//! runs.
//!
//! Layout: `<dir>/checkpoint.json` (variant, epoch, leaf index) and one
//! array per leaf per generation: `p000_fc1_w.e7.npy` (parameter) +
//! `v000_fc1_w.e7.npy` (momentum), where `.e7` is the epoch the save
//! belongs to.  Momentum is part of the checkpoint so a resumed run
//! continues the optimizer trajectory bit-exactly (see
//! `coordinator/resume.rs` for the coordinator-side state that rides
//! along).
//!
//! # Crash safety
//!
//! A save never overwrites the files the current `checkpoint.json`
//! points at: payload files carry the epoch in their name, the index is
//! replaced atomically (temp + rename, [`crate::util::fsutil`]) only
//! after every payload file is on disk, and the superseded generation is
//! garbage-collected last.  A crash at any point leaves a directory
//! whose index references a complete, single-epoch set — there is no
//! window in which `--resume` can read mixed-epoch parameters.  This
//! matters doubly with the async service lane, where the model write for
//! epoch `e` can still be in flight while the trainer runs epoch `e+1`.
//!
//! Legacy params-only checkpoints (no `vel` entries) still load:
//! parameters restore by name through the typed params-only snapshot
//! tier ([`crate::engine::Snapshot`]), momentum keeps its current
//! (zero-initialized) values.
//!
//! [`save_snapshot`] serializes an exported typed snapshot without
//! touching the executor — the entry point the async checkpoint lane
//! uses to write a checkpoint for epoch `e` while the executor trains
//! epoch `e+1`; it rejects params-only snapshots, so a non-resumable
//! checkpoint can never reach disk.  [`save_state`] is the flat-layout
//! equivalent.

use std::path::Path;

use crate::engine::{Snapshot, SnapshotTier, StateExchange};
use crate::runtime::artifact::VariantMeta;
use crate::runtime::executor::ModelExecutor;
use crate::util::fsutil::{gc_files, write_atomic};
use crate::util::json::{parse_file, Json};
use crate::util::npy;

/// Save the executor's full state at `dir` (created if needed).
pub fn save(exec: &ModelExecutor, dir: &Path, epoch: usize) -> anyhow::Result<()> {
    let snap = exec.export_snapshot(SnapshotTier::Full)?;
    save_snapshot(&exec.meta, &snap, dir, epoch)
}

/// Whether a directory entry is a checkpoint leaf payload file
/// (`p###_*.npy` / `v###_*.npy`, any generation) — the set the
/// post-save garbage sweep is allowed to touch.
fn is_leaf_file(name: &str) -> bool {
    let b = name.as_bytes();
    b.len() > 4
        && (b[0] == b'p' || b[0] == b'v')
        && b[1].is_ascii_digit()
        && b[2].is_ascii_digit()
        && b[3].is_ascii_digit()
        && name.ends_with(".npy")
}

/// Serialize a typed full-state snapshot as a checkpoint at `dir`,
/// without touching the executor.  Byte-identical to [`save`] on the
/// executor the snapshot was exported from, and crash-safe (see the
/// module docs).  Rejects params-only snapshots — a checkpoint without
/// momentum could not resume the optimizer trajectory bit-exactly.
pub fn save_snapshot(
    meta: &VariantMeta,
    snap: &Snapshot,
    dir: &Path,
    epoch: usize,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        snap.tier() >= SnapshotTier::Full,
        "checkpoint for variant {} needs a full-state snapshot, got the {} tier",
        meta.name,
        snap.tier().name()
    );
    let momentum = snap.momentum().ok_or_else(|| {
        anyhow::anyhow!("full-state snapshot for {} is missing its momentum section", meta.name)
    })?;
    save_leaves(meta, snap.params(), momentum, dir, epoch)
}

/// Serialize a flat full exported state (params then momentum, in
/// manifest leaf order — the `StateExchange::export_state` layout) as a
/// checkpoint at `dir`.  The flat-layout twin of [`save_snapshot`].
pub fn save_state(
    meta: &VariantMeta,
    state: &[Vec<f32>],
    dir: &Path,
    epoch: usize,
) -> anyhow::Result<()> {
    let n = meta.params.len();
    anyhow::ensure!(
        state.len() == 2 * n,
        "state has {} leaves, variant {} expects {}",
        state.len(),
        meta.name,
        2 * n
    );
    save_leaves(meta, &state[..n], &state[n..], dir, epoch)
}

/// Shared serialization body: one `.npy` per parameter leaf (`p###_*`)
/// and one per momentum leaf (`v###_*`), then the atomic index flip and
/// the post-save sweep.
fn save_leaves(
    meta: &VariantMeta,
    params: &[Vec<f32>],
    vel: &[Vec<f32>],
    dir: &Path,
    epoch: usize,
) -> anyhow::Result<()> {
    let n = meta.params.len();
    anyhow::ensure!(
        params.len() == n && vel.len() == n,
        "snapshot has {} param / {} momentum leaves, variant {} expects {n} each",
        params.len(),
        vel.len(),
        meta.name
    );
    std::fs::create_dir_all(dir)?;
    let mut index = Vec::new();
    let mut keep = Vec::with_capacity(2 * n);
    for (i, m) in meta.params.iter().enumerate() {
        anyhow::ensure!(
            params[i].len() == m.numel() && vel[i].len() == m.numel(),
            "state leaf {i} shape mismatch for {}",
            m.name
        );
        let stem = m.name.replace('/', "_");
        let fname = format!("p{i:03}_{stem}.e{epoch}.npy");
        let vname = format!("v{i:03}_{stem}.e{epoch}.npy");
        npy::write_f32(&dir.join(&fname), &params[i], &m.shape)?;
        npy::write_f32(&dir.join(&vname), &vel[i], &m.shape)?;
        index.push(crate::jobj![
            ("name", m.name.as_str()),
            ("file", fname.as_str()),
            ("vel", vname.as_str()),
        ]);
        keep.push(fname);
        keep.push(vname);
    }
    let manifest = crate::jobj![
        ("variant", meta.name.as_str()),
        ("epoch", epoch),
        ("param_count", meta.param_count),
        ("params", Json::Arr(index)),
    ];
    // payloads must be on stable storage before the manifest references
    // them (a journaled rename can otherwise hit disk first)
    for f in &keep {
        crate::util::fsutil::sync_file(&dir.join(f))?;
    }
    // atomic pointer flip: readers see the old complete index or this one
    write_atomic(&dir.join("checkpoint.json"), &manifest.to_pretty())?;
    // sweep the superseded generation (best effort; stale files that a
    // crashed sweep leaves behind are never referenced by the index)
    gc_files(dir, &keep, is_leaf_file);
    Ok(())
}

/// Load a checkpoint into the executor.  The checkpoint's variant must
/// match (same parameter names/shapes).  Both generations route through
/// the typed snapshot path: full checkpoints (with momentum) restore as
/// a [`SnapshotTier::Full`] snapshot (complete optimizer state); legacy
/// params-only checkpoints restore as a [`SnapshotTier::Params`]
/// snapshot — weights by name, momentum untouched.  Returns the saved
/// epoch.
pub fn load(exec: &mut ModelExecutor, dir: &Path) -> anyhow::Result<usize> {
    let m = parse_file(&dir.join("checkpoint.json"))?;
    let variant = m.req("variant")?.as_str().unwrap_or_default();
    anyhow::ensure!(
        variant == exec.meta.name,
        "checkpoint is for variant {variant:?}, executor is {:?}",
        exec.meta.name
    );
    let entries = m.req("params")?.as_arr().unwrap_or(&[]);
    let full = !entries.is_empty() && entries.iter().all(|p| p.get("vel").is_some());
    if full {
        // positional restore — so the leaf names must line up with the
        // executor's manifest order, or same-sized leaves could land in
        // the wrong slots
        anyhow::ensure!(
            entries.len() == exec.meta.params.len(),
            "checkpoint has {} leaves, executor expects {}",
            entries.len(),
            exec.meta.params.len()
        );
        let mut params = Vec::with_capacity(entries.len());
        let mut vels = Vec::with_capacity(entries.len());
        for (p, leaf) in entries.iter().zip(&exec.meta.params) {
            let name = p.req("name")?.as_str().unwrap_or_default();
            anyhow::ensure!(
                name == leaf.name,
                "checkpoint leaf {name:?} does not match executor leaf {:?}",
                leaf.name
            );
            let file = p.req("file")?.as_str().unwrap_or_default();
            params.push(npy::read_f32(&dir.join(file))?.0);
            let vfile = p.req("vel")?.as_str().unwrap_or_default();
            vels.push(npy::read_f32(&dir.join(vfile))?.0);
        }
        exec.import_snapshot(&Snapshot::full(params, Some(vels)))?;
    } else {
        // legacy params-only generation: resolve each manifest leaf by
        // (name, size), then restore through the params-only snapshot
        // tier — momentum keeps its current values, as before
        let mut source = Vec::new();
        for p in entries {
            let name = p.req("name")?.as_str().unwrap_or_default().to_string();
            let file = p.req("file")?.as_str().unwrap_or_default();
            let (data, _shape) = npy::read_f32(&dir.join(file))?;
            source.push((name, data));
        }
        let mut ordered = Vec::with_capacity(exec.meta.params.len());
        for m in &exec.meta.params {
            // move the leaf out of `source` (no second full-parameter
            // copy on top of the npy buffers)
            let pos = source
                .iter()
                .position(|(n, d)| n == &m.name && d.len() == m.numel())
                .ok_or_else(|| {
                    anyhow::anyhow!("checkpoint is missing leaf {:?} ({} elems)", m.name, m.numel())
                })?;
            ordered.push(source.swap_remove(pos).1);
        }
        exec.import_snapshot(&Snapshot::params_only(ordered))?;
    }
    Ok(m.req("epoch")?.as_usize().unwrap_or(0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{default_artifacts_dir, XlaRuntime};

    #[test]
    fn leaf_file_pattern() {
        assert!(is_leaf_file("p000_fc1_w.e7.npy"));
        assert!(is_leaf_file("v012_conv_b.npy"));
        assert!(!is_leaf_file("state_loss.e7.npy"));
        assert!(!is_leaf_file("checkpoint.json"));
        assert!(!is_leaf_file("px00_fc1_w.npy"));
    }

    #[test]
    fn save_load_roundtrip() {
        let Ok(rt) = XlaRuntime::new(&default_artifacts_dir()) else { return };
        let dir = std::env::temp_dir().join(format!("kakurenbo_ckpt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut a = ModelExecutor::new(&rt, "mlp_c10_b64", 11).unwrap();
        // perturb params *and* momentum so we're not just checking the
        // seeded init
        let x = vec![0.3f32; 64 * 64];
        let y = vec![1i32; 64];
        let sw = vec![1.0f32; 64];
        a.train_step(&x, &y, &sw, 0.1).unwrap();
        save(&a, &dir, 7).unwrap();

        let mut b = ModelExecutor::new(&rt, "mlp_c10_b64", 999).unwrap();
        let epoch = load(&mut b, &dir).unwrap();
        assert_eq!(epoch, 7);
        // the full state (params + momentum) round-trips bit-exactly
        let sa = a.export_state().unwrap();
        let sb = b.export_state().unwrap();
        assert_eq!(sa.len(), sb.len());
        for (la, lb) in sa.iter().zip(&sb) {
            let ba: Vec<u32> = la.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = lb.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ba, bb);
        }
        // a later save into the same dir sweeps the old generation
        a.train_step(&x, &y, &sw, 0.1).unwrap();
        save(&a, &dir, 9).unwrap();
        let stale: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.unwrap().file_name().into_string().ok())
            .filter(|n| is_leaf_file(n) && n.contains(".e7."))
            .collect();
        assert!(stale.is_empty(), "old generation not swept: {stale:?}");
        assert_eq!(load(&mut b, &dir).unwrap(), 9);
        // wrong variant rejected
        let mut c = ModelExecutor::new(&rt, "mlp_c100_b64", 1).unwrap();
        assert!(load(&mut c, &dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_state_matches_save() {
        let Ok(rt) = XlaRuntime::new(&default_artifacts_dir()) else { return };
        let base = std::env::temp_dir()
            .join(format!("kakurenbo_ckpt_state_{}", std::process::id()));
        std::fs::remove_dir_all(&base).ok();
        let (da, db) = (base.join("a"), base.join("b"));
        let mut a = ModelExecutor::new(&rt, "mlp_c10_b64", 5).unwrap();
        let x = vec![0.2f32; 64 * 64];
        let y = vec![2i32; 64];
        let sw = vec![1.0f32; 64];
        a.train_step(&x, &y, &sw, 0.05).unwrap();
        save(&a, &da, 3).unwrap();
        let snap = a.export_state().unwrap();
        save_state(&a.meta, &snap, &db, 3).unwrap();
        // every file the two checkpoints wrote is byte-identical
        for entry in std::fs::read_dir(&da).unwrap() {
            let name = entry.unwrap().file_name();
            let fa = std::fs::read(da.join(&name)).unwrap();
            let fb = std::fs::read(db.join(&name)).unwrap();
            assert_eq!(fa, fb, "{name:?} differs");
        }
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn reordered_index_names_rejected() {
        let Ok(rt) = XlaRuntime::new(&default_artifacts_dir()) else { return };
        let dir = std::env::temp_dir()
            .join(format!("kakurenbo_ckpt_names_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let a = ModelExecutor::new(&rt, "mlp_c10_b64", 2).unwrap();
        save(&a, &dir, 1).unwrap();
        // swap two index entries: positional load must refuse the
        // name mismatch instead of loading leaves into wrong slots
        let path = dir.join("checkpoint.json");
        let mut m = parse_file(&path).unwrap();
        if let Json::Obj(obj) = &mut m {
            if let Some(Json::Arr(entries)) = obj.get_mut("params") {
                entries.swap(0, 1);
            }
        }
        std::fs::write(&path, m.to_pretty()).unwrap();
        let mut b = ModelExecutor::new(&rt, "mlp_c10_b64", 3).unwrap();
        let err = load(&mut b, &dir).unwrap_err().to_string();
        assert!(err.contains("does not match"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
