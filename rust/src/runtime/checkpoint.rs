//! Checkpointing: save/restore the executor's full mutable model state
//! (parameters **and** SGD momentum) as a content-addressed store of
//! framed leaf artifacts plus a JSON manifest.
//!
//! # Layout (format 2)
//!
//! `<dir>/checkpoint.json` records the variant, epoch, and one entry per
//! parameter leaf: the leaf's name plus the sha256 **digests** of its
//! param and momentum payloads.  Payloads live in `obj_<digest>.art`
//! files — an `.npy` byte image (`util/npy.rs`, so leaves stay
//! inspectable after unframing) wrapped in the artifact frame
//! (`util/artifact.rs`: magic, codec, raw length).  Params are stored
//! raw for fast eval-replica loads; momentum is LZSS-compressed when the
//! save enables compression.  Because files are named by content:
//!
//! * a leaf unchanged since the previous generation dedups to the
//!   existing object — no write at all;
//! * loads re-hash every object and compare against the manifest digest
//!   (the `--checkpoint-verify` knob), so bit rot and torn writes fail
//!   with a named-leaf error before any payload parsing runs;
//! * GC is refcount-by-manifest: after the manifest flip, every artifact
//!   the manifest does not reference is superseded and swept.
//!
//! # The write pool
//!
//! [`save_snapshot`] fans the per-leaf serializations (encode → optional
//! compress → hash → atomic write) across a [`WritePool`] and joins all
//! workers **before** the manifest flip, so checkpoint latency scales
//! with the largest leaf instead of the sum of all leaves.  Per-leaf
//! timing folds into the returned [`WriteStats`] (surfaced through the
//! service lane into the epoch record and the overhead bench).
//!
//! # Crash safety
//!
//! A save never overwrites anything the current `checkpoint.json` points
//! at: objects are immutable once published (temp + fsync + rename, so a
//! digest-named file either exists complete or not at all), the manifest
//! is replaced atomically only after every object is durable, and the
//! sweep runs last.  A crash at any point leaves a manifest referencing
//! a complete, single-generation set — there is no window in which
//! `--resume` can read mixed-generation parameters.  This matters doubly
//! with the async service lane, where the model write for epoch `e` can
//! still be in flight while the trainer runs epoch `e+1`.
//!
//! # Legacy generations
//!
//! Both earlier on-disk formats still load: epoch-suffixed full
//! checkpoints (`p###_*.e7.npy` + `v###_*.e7.npy` with `vel` index
//! entries) restore as a [`SnapshotTier::Full`] snapshot, and the oldest
//! params-only layout restores by name through the params-only tier
//! (momentum keeps its current values).  The GC predicate recognizes
//! legacy and digest-named payloads coexisting in one directory, so the
//! first new-format save cleanly supersedes a legacy generation.

use std::path::Path;
use std::sync::Arc;

use crate::engine::{SharedSnapshot, Snapshot, SnapshotTier, StateExchange};
use crate::runtime::artifact::VariantMeta;
use crate::runtime::executor::ModelExecutor;
use crate::util::artifact::{
    is_object_file, load_leaf, store_leaf, Codec, WritePool, WriteJob, WriteStats,
};
use crate::util::fsutil::{gc_files, write_atomic};
use crate::util::json::{parse_file, Json};
use crate::util::npy;

/// On-disk manifest format written by this module.
pub const MANIFEST_FORMAT: usize = 2;

/// Save the executor's full state at `dir` (created if needed), serial
/// writes, compression on — the convenience wrapper tests and one-shot
/// callers use.  Hot paths hold a persistent pool and call
/// [`save_snapshot`] directly.
pub fn save(exec: &ModelExecutor, dir: &Path, epoch: usize) -> anyhow::Result<WriteStats> {
    let snap: SharedSnapshot = Arc::new(exec.export_snapshot(SnapshotTier::Full)?);
    save_snapshot(&exec.meta, &snap, dir, epoch, &WritePool::serial(), true)
}

/// Whether a directory entry belongs to the checkpoint payload store —
/// the set the post-save garbage sweep is allowed to touch.  Matches
/// legacy epoch-suffixed leaves (`p###_*.npy` / `v###_*.npy`),
/// digest-named artifacts (`obj_<64 hex>.art`), and orphaned artifact
/// temp files a crashed writer left behind (`obj_*.tmp`); both naming
/// generations can coexist in one directory and GC keeps exactly what
/// the current manifest references.
fn is_leaf_file(name: &str) -> bool {
    let b = name.as_bytes();
    let legacy = b.len() > 4
        && (b[0] == b'p' || b[0] == b'v')
        && b[1].is_ascii_digit()
        && b[2].is_ascii_digit()
        && b[3].is_ascii_digit()
        && name.ends_with(".npy");
    legacy
        || is_object_file(name)
        || (name.starts_with("obj_") && name.ends_with(".tmp"))
}

/// Serialize a typed full-state snapshot as a checkpoint at `dir`,
/// without touching the executor: leaf jobs fan out across `pool`
/// (params raw; momentum LZSS when `compress`), the manifest flips
/// atomically after the join, and unreferenced artifacts are swept.
/// This is the entry point the async checkpoint lane and the sync epoch
/// phase both use.  Rejects params-only snapshots — a checkpoint without
/// momentum could not resume the optimizer trajectory bit-exactly.
pub fn save_snapshot(
    meta: &VariantMeta,
    snap: &SharedSnapshot,
    dir: &Path,
    epoch: usize,
    pool: &WritePool,
    compress: bool,
) -> anyhow::Result<WriteStats> {
    anyhow::ensure!(
        snap.tier() >= SnapshotTier::Full,
        "checkpoint for variant {} needs a full-state snapshot, got the {} tier",
        meta.name,
        snap.tier().name()
    );
    let momentum = snap.momentum().ok_or_else(|| {
        anyhow::anyhow!("full-state snapshot for {} is missing its momentum section", meta.name)
    })?;
    let n = meta.params.len();
    anyhow::ensure!(
        snap.params().len() == n && momentum.len() == n,
        "snapshot has {} param / {} momentum leaves, variant {} expects {n} each",
        snap.params().len(),
        momentum.len(),
        meta.name
    );
    for (i, m) in meta.params.iter().enumerate() {
        anyhow::ensure!(
            snap.params()[i].len() == m.numel() && momentum[i].len() == m.numel(),
            "state leaf {i} shape mismatch for {}",
            m.name
        );
    }
    std::fs::create_dir_all(dir)?;

    // one job per leaf half; jobs capture the shared snapshot by Arc so
    // pool workers can outlive this stack frame's borrows
    let mut jobs: Vec<WriteJob> = Vec::with_capacity(2 * n);
    for i in 0..n {
        let snap = snap.clone();
        let dir = dir.to_path_buf();
        let shape = meta.params[i].shape.clone();
        jobs.push(Box::new(move || {
            let bytes = npy::encode_f32(&snap.params()[i], &shape)?;
            store_leaf(&dir, &bytes, false)
        }));
    }
    for i in 0..n {
        let snap = snap.clone();
        let dir = dir.to_path_buf();
        let shape = meta.params[i].shape.clone();
        jobs.push(Box::new(move || {
            let vel = snap.momentum().expect("tier checked above");
            let bytes = npy::encode_f32(&vel[i], &shape)?;
            store_leaf(&dir, &bytes, compress)
        }));
    }
    let metas = pool.run(jobs)?;

    let mut index = Vec::with_capacity(n);
    let mut keep = Vec::with_capacity(2 * n);
    let mut stats = WriteStats::default();
    for i in 0..n {
        let (p, v) = (&metas[i], &metas[n + i]);
        stats.absorb(p);
        stats.absorb(v);
        index.push(crate::jobj![
            ("name", meta.params[i].name.as_str()),
            ("digest", p.digest.as_str()),
            ("codec", p.codec.name()),
            ("vel_digest", v.digest.as_str()),
            ("vel_codec", v.codec.name()),
        ]);
        keep.push(p.file.clone());
        keep.push(v.file.clone());
    }
    let manifest = crate::jobj![
        ("variant", meta.name.as_str()),
        ("epoch", epoch),
        ("format", MANIFEST_FORMAT),
        ("param_count", meta.param_count),
        ("params", Json::Arr(index)),
    ];
    // every object is already durable (store_leaf publishes via temp +
    // fsync + rename); the manifest flip is the atomic commit point
    write_atomic(&dir.join("checkpoint.json"), &manifest.to_pretty())?;
    // refcount-by-manifest sweep: any payload (either naming generation)
    // the fresh manifest does not reference is superseded
    gc_files(dir, &keep, is_leaf_file);
    Ok(stats)
}

/// Serialize a flat full exported state (params then momentum, in
/// manifest leaf order — the `StateExchange::export_state` layout) as a
/// checkpoint at `dir`.  The flat-layout twin of [`save`], with the same
/// serial-pool + compression defaults, so the two produce byte-identical
/// stores for identical state.
pub fn save_state(
    meta: &VariantMeta,
    state: &[Vec<f32>],
    dir: &Path,
    epoch: usize,
) -> anyhow::Result<WriteStats> {
    let n = meta.params.len();
    anyhow::ensure!(
        state.len() == 2 * n,
        "state has {} leaves, variant {} expects {}",
        state.len(),
        meta.name,
        2 * n
    );
    let snap: SharedSnapshot =
        Arc::new(Snapshot::full(state[..n].to_vec(), Some(state[n..].to_vec())));
    save_snapshot(meta, &snap, dir, epoch, &WritePool::serial(), true)
}

/// Load a checkpoint into the executor with digest verification on —
/// see [`load_with`].
pub fn load(exec: &mut ModelExecutor, dir: &Path) -> anyhow::Result<usize> {
    load_with(exec, dir, true)
}

/// Load a checkpoint into the executor.  The checkpoint's variant must
/// match (same parameter names/shapes).  All three on-disk generations
/// route through the typed snapshot path — see [`load_snapshot`].
/// Returns the saved epoch.
pub fn load_with(exec: &mut ModelExecutor, dir: &Path, verify: bool) -> anyhow::Result<usize> {
    let (snap, epoch) = load_snapshot(&exec.meta, dir, verify)?;
    exec.import_snapshot(&snap)?;
    Ok(epoch)
}

/// Host-side checkpoint read: parse the manifest, fetch + (optionally)
/// digest-verify every leaf, and build the typed snapshot — no executor
/// or PJRT device involved, which is what lets crash-injection and
/// corruption tests run on any host.  Format-2 manifests restore params
/// + momentum as a [`SnapshotTier::Full`] snapshot; legacy epoch-suffix
/// checkpoints likewise; the oldest params-only layout restores by name
/// as a [`SnapshotTier::Params`] snapshot.  Returns the snapshot and the
/// saved epoch.
pub fn load_snapshot(
    meta: &VariantMeta,
    dir: &Path,
    verify: bool,
) -> anyhow::Result<(Snapshot, usize)> {
    let m = parse_file(&dir.join("checkpoint.json"))?;
    let variant = m.req("variant")?.as_str().unwrap_or_default();
    anyhow::ensure!(
        variant == meta.name,
        "checkpoint is for variant {variant:?}, executor is {:?}",
        meta.name
    );
    let epoch = m.req("epoch")?.as_usize().unwrap_or(0);
    let entries = m.req("params")?.as_arr().unwrap_or(&[]);
    let format = m.get("format").and_then(|f| f.as_usize()).unwrap_or(1);
    let snap = if format >= 2 {
        load_artifact_leaves(meta, dir, entries, verify)?
    } else {
        let full = !entries.is_empty() && entries.iter().all(|p| p.get("vel").is_some());
        if full {
            load_legacy_full(meta, dir, entries)?
        } else {
            load_legacy_params_only(meta, dir, entries)?
        }
    };
    Ok((snap, epoch))
}

/// Format-2 body: positional restore from the content-addressed store.
/// Leaf names must line up with the variant manifest order, or
/// same-sized leaves could land in the wrong slots.
fn load_artifact_leaves(
    meta: &VariantMeta,
    dir: &Path,
    entries: &[Json],
    verify: bool,
) -> anyhow::Result<Snapshot> {
    anyhow::ensure!(
        entries.len() == meta.params.len(),
        "checkpoint has {} leaves, executor expects {}",
        entries.len(),
        meta.params.len()
    );
    let mut params = Vec::with_capacity(entries.len());
    let mut vels = Vec::with_capacity(entries.len());
    for (p, leaf) in entries.iter().zip(&meta.params) {
        let name = p.req("name")?.as_str().unwrap_or_default();
        anyhow::ensure!(
            name == leaf.name,
            "checkpoint leaf {name:?} does not match executor leaf {:?}",
            leaf.name
        );
        // codecs are recorded for tooling; the frame self-describes, so
        // parsing here just validates the manifest
        Codec::parse(p.req("codec")?.as_str().unwrap_or_default())?;
        Codec::parse(p.req("vel_codec")?.as_str().unwrap_or_default())?;
        for (digest_key, out) in [("digest", &mut params), ("vel_digest", &mut vels)] {
            let digest = p.req(digest_key)?.as_str().unwrap_or_default();
            let bytes = load_leaf(dir, digest, verify)
                .map_err(|e| anyhow::anyhow!("leaf {:?} ({digest_key}): {e}", leaf.name))?;
            let (data, _shape) = npy::decode_f32(&bytes)?;
            anyhow::ensure!(
                data.len() == leaf.numel(),
                "leaf {:?} has {} elems, expected {}",
                leaf.name,
                data.len(),
                leaf.numel()
            );
            out.push(data);
        }
    }
    Ok(Snapshot::full(params, Some(vels)))
}

/// Legacy epoch-suffixed full generation (`file` + `vel` index entries).
fn load_legacy_full(
    meta: &VariantMeta,
    dir: &Path,
    entries: &[Json],
) -> anyhow::Result<Snapshot> {
    anyhow::ensure!(
        entries.len() == meta.params.len(),
        "checkpoint has {} leaves, executor expects {}",
        entries.len(),
        meta.params.len()
    );
    let mut params = Vec::with_capacity(entries.len());
    let mut vels = Vec::with_capacity(entries.len());
    for (p, leaf) in entries.iter().zip(&meta.params) {
        let name = p.req("name")?.as_str().unwrap_or_default();
        anyhow::ensure!(
            name == leaf.name,
            "checkpoint leaf {name:?} does not match executor leaf {:?}",
            leaf.name
        );
        let file = p.req("file")?.as_str().unwrap_or_default();
        params.push(npy::read_f32(&dir.join(file))?.0);
        let vfile = p.req("vel")?.as_str().unwrap_or_default();
        vels.push(npy::read_f32(&dir.join(vfile))?.0);
    }
    Ok(Snapshot::full(params, Some(vels)))
}

/// Oldest params-only generation: resolve each manifest leaf by
/// (name, size), then restore through the params-only snapshot tier —
/// momentum keeps its current values, as before.
fn load_legacy_params_only(
    meta: &VariantMeta,
    dir: &Path,
    entries: &[Json],
) -> anyhow::Result<Snapshot> {
    let mut source = Vec::new();
    for p in entries {
        let name = p.req("name")?.as_str().unwrap_or_default().to_string();
        let file = p.req("file")?.as_str().unwrap_or_default();
        let (data, _shape) = npy::read_f32(&dir.join(file))?;
        source.push((name, data));
    }
    let mut ordered = Vec::with_capacity(meta.params.len());
    for m in &meta.params {
        // move the leaf out of `source` (no second full-parameter copy
        // on top of the npy buffers)
        let pos = source
            .iter()
            .position(|(n, d)| n == &m.name && d.len() == m.numel())
            .ok_or_else(|| {
                anyhow::anyhow!("checkpoint is missing leaf {:?} ({} elems)", m.name, m.numel())
            })?;
        ordered.push(source.swap_remove(pos).1);
    }
    Ok(Snapshot::params_only(ordered))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::ParamMeta;
    use crate::runtime::{default_artifacts_dir, XlaRuntime};

    /// A synthetic variant (no PJRT artifacts needed) for host-only
    /// save/load tests.
    pub(crate) fn synth_meta(leaves: usize, numel: usize) -> VariantMeta {
        let params: Vec<ParamMeta> = (0..leaves)
            .map(|i| ParamMeta {
                name: format!("block{i}/w"),
                shape: vec![numel],
                init_std: 0.1,
            })
            .collect();
        VariantMeta {
            name: "synthetic".to_string(),
            family: "test".to_string(),
            batch: 8,
            input_shape: vec![4],
            label_shape: vec![1],
            classes: 2,
            embed_dim: 0,
            param_count: leaves * numel,
            params,
            artifacts: std::collections::BTreeMap::new(),
        }
    }

    fn synth_snapshot(meta: &VariantMeta, seed: f32) -> SharedSnapshot {
        let params: Vec<Vec<f32>> = meta
            .params
            .iter()
            .enumerate()
            .map(|(i, m)| (0..m.numel()).map(|j| seed + i as f32 + j as f32 * 0.25).collect())
            .collect();
        // momentum full of repeated values, like late-training tensors
        let vel: Vec<Vec<f32>> = meta
            .params
            .iter()
            .map(|m| vec![seed * 0.5; m.numel()])
            .collect();
        Arc::new(Snapshot::full(params, Some(vel)))
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("kakurenbo_ckpt_{name}_{}", std::process::id()))
    }

    fn assert_snapshots_eq(a: &Snapshot, b: &Snapshot) {
        assert_eq!(a.params().len(), b.params().len());
        for (la, lb) in a.params().iter().zip(b.params()) {
            let ba: Vec<u32> = la.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = lb.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ba, bb);
        }
        match (a.momentum(), b.momentum()) {
            (Some(va), Some(vb)) => {
                assert_eq!(va.len(), vb.len());
                for (la, lb) in va.iter().zip(vb) {
                    let ba: Vec<u32> = la.iter().map(|v| v.to_bits()).collect();
                    let bb: Vec<u32> = lb.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(ba, bb);
                }
            }
            (None, None) => {}
            _ => panic!("momentum presence differs"),
        }
    }

    #[test]
    fn leaf_file_pattern() {
        // legacy generation
        assert!(is_leaf_file("p000_fc1_w.e7.npy"));
        assert!(is_leaf_file("v012_conv_b.npy"));
        // digest-named artifacts + crashed-writer temp litter
        let digest = "c".repeat(64);
        assert!(is_leaf_file(&format!("obj_{digest}.art")));
        assert!(is_leaf_file(&format!("obj_{digest}.art.3.tmp")));
        // never touched by the sweep
        assert!(!is_leaf_file("state_loss.e7.npy"));
        assert!(!is_leaf_file("checkpoint.json"));
        assert!(!is_leaf_file("checkpoint.json.tmp"));
        assert!(!is_leaf_file("px00_fc1_w.npy"));
        assert!(!is_leaf_file("obj_short.art"));
    }

    /// Host-only: format-2 save → load round-trips bit-exactly through
    /// the serial and the pooled writer alike, and every artifact left
    /// in the directory is referenced by the manifest.
    #[test]
    fn artifact_roundtrip_serial_and_pooled() {
        let meta = synth_meta(6, 300);
        let snap = synth_snapshot(&meta, 1.5);
        for (label, pool) in [("serial", WritePool::serial()), ("pooled", WritePool::new(4))] {
            let dir = tmp(&format!("rt_{label}"));
            std::fs::remove_dir_all(&dir).ok();
            let stats = save_snapshot(&meta, &snap, &dir, 7, &pool, true).unwrap();
            assert_eq!(stats.leaves, 12, "{label}");
            assert!(stats.written_bytes > 0, "{label}");
            let (loaded, epoch) = load_snapshot(&meta, &dir, true).unwrap();
            assert_eq!(epoch, 7);
            assert_snapshots_eq(&loaded, &snap);
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    /// Serial and pooled saves of the same snapshot produce identical
    /// stores (same digests, same manifest modulo nothing) — the
    /// service-lane determinism contract extended to the pool.
    #[test]
    fn pooled_store_matches_serial_store() {
        let meta = synth_meta(5, 200);
        let snap = synth_snapshot(&meta, -0.75);
        let (da, db) = (tmp("det_a"), tmp("det_b"));
        std::fs::remove_dir_all(&da).ok();
        std::fs::remove_dir_all(&db).ok();
        save_snapshot(&meta, &snap, &da, 3, &WritePool::serial(), true).unwrap();
        save_snapshot(&meta, &snap, &db, 3, &WritePool::new(4), true).unwrap();
        for entry in std::fs::read_dir(&da).unwrap() {
            let name = entry.unwrap().file_name();
            let fa = std::fs::read(da.join(&name)).unwrap();
            let fb = std::fs::read(db.join(&name)).unwrap();
            assert_eq!(fa, fb, "{name:?} differs");
        }
        std::fs::remove_dir_all(&da).ok();
        std::fs::remove_dir_all(&db).ok();
    }

    /// Unchanged leaves dedup across generations: re-saving the same
    /// snapshot writes zero new payload bytes, and GC keeps exactly the
    /// manifest-referenced objects.
    #[test]
    fn unchanged_leaves_dedup_across_generations() {
        let meta = synth_meta(4, 250);
        let snap = synth_snapshot(&meta, 2.0);
        let dir = tmp("dedup");
        std::fs::remove_dir_all(&dir).ok();
        let pool = WritePool::serial();
        let first = save_snapshot(&meta, &snap, &dir, 1, &pool, true).unwrap();
        assert_eq!(first.deduped, 0);
        let second = save_snapshot(&meta, &snap, &dir, 2, &pool, true).unwrap();
        assert_eq!(second.deduped, 8, "every leaf should hit the store");
        assert_eq!(second.written_bytes, 0);
        // generation 2 loads fine and the store holds only referenced objects
        let (loaded, epoch) = load_snapshot(&meta, &dir, true).unwrap();
        assert_eq!(epoch, 2);
        assert_snapshots_eq(&loaded, &snap);
        let m = parse_file(&dir.join("checkpoint.json")).unwrap();
        let referenced: std::collections::BTreeSet<String> = m
            .req("params")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .flat_map(|p| {
                ["digest", "vel_digest"].into_iter().map(|k| {
                    crate::util::artifact::object_file(p.req(k).unwrap().as_str().unwrap())
                })
            })
            .collect();
        for entry in std::fs::read_dir(&dir).unwrap() {
            let name = entry.unwrap().file_name().into_string().unwrap();
            if is_object_file(&name) {
                assert!(referenced.contains(&name), "orphan object {name}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A new-format save into a directory holding a legacy generation
    /// sweeps the superseded `.npy` leaves (mixed-format GC).
    #[test]
    fn new_save_supersedes_legacy_generation() {
        let meta = synth_meta(3, 100);
        let dir = tmp("mixed_gc");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        for legacy in ["p000_block0_w.e1.npy", "v000_block0_w.e1.npy"] {
            std::fs::write(dir.join(legacy), b"stale").unwrap();
        }
        // coordinator state files must survive the sweep
        std::fs::write(dir.join("state_loss.e1.npy"), b"keep").unwrap();
        let snap = synth_snapshot(&meta, 0.25);
        save_snapshot(&meta, &snap, &dir, 2, &WritePool::serial(), true).unwrap();
        assert!(!dir.join("p000_block0_w.e1.npy").exists());
        assert!(!dir.join("v000_block0_w.e1.npy").exists());
        assert!(dir.join("state_loss.e1.npy").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn params_only_snapshot_rejected() {
        let meta = synth_meta(2, 50);
        let snap: SharedSnapshot =
            Arc::new(Snapshot::params_only(vec![vec![0.0; 50], vec![0.0; 50]]));
        let err = save_snapshot(&meta, &snap, &tmp("reject"), 0, &WritePool::serial(), true)
            .unwrap_err()
            .to_string();
        assert!(err.contains("full-state snapshot"), "{err}");
    }

    #[test]
    fn save_load_roundtrip() {
        let Ok(rt) = XlaRuntime::new(&default_artifacts_dir()) else { return };
        let dir = tmp("pjrt");
        std::fs::remove_dir_all(&dir).ok();
        let mut a = ModelExecutor::new(&rt, "mlp_c10_b64", 11).unwrap();
        // perturb params *and* momentum so we're not just checking the
        // seeded init
        let x = vec![0.3f32; 64 * 64];
        let y = vec![1i32; 64];
        let sw = vec![1.0f32; 64];
        a.train_step(&x, &y, &sw, 0.1).unwrap();
        let stats = save(&a, &dir, 7).unwrap();
        assert!(stats.leaves > 0 && stats.written_bytes > 0);

        let mut b = ModelExecutor::new(&rt, "mlp_c10_b64", 999).unwrap();
        let epoch = load(&mut b, &dir).unwrap();
        assert_eq!(epoch, 7);
        // the full state (params + momentum) round-trips bit-exactly
        let sa = a.export_state().unwrap();
        let sb = b.export_state().unwrap();
        assert_eq!(sa.len(), sb.len());
        for (la, lb) in sa.iter().zip(&sb) {
            let ba: Vec<u32> = la.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = lb.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ba, bb);
        }
        // a later save into the same dir keeps only what its manifest
        // references (refcount-by-manifest GC)
        a.train_step(&x, &y, &sw, 0.1).unwrap();
        save(&a, &dir, 9).unwrap();
        let m = parse_file(&dir.join("checkpoint.json")).unwrap();
        let referenced: Vec<String> = m
            .req("params")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .flat_map(|p| {
                ["digest", "vel_digest"].into_iter().map(|k| {
                    crate::util::artifact::object_file(p.req(k).unwrap().as_str().unwrap())
                })
            })
            .collect();
        for entry in std::fs::read_dir(&dir).unwrap() {
            let name = entry.unwrap().file_name().into_string().unwrap();
            if is_leaf_file(&name) {
                assert!(referenced.contains(&name), "unreferenced payload {name} survived GC");
            }
        }
        assert_eq!(load(&mut b, &dir).unwrap(), 9);
        // wrong variant rejected
        let mut c = ModelExecutor::new(&rt, "mlp_c100_b64", 1).unwrap();
        assert!(load(&mut c, &dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_state_matches_save() {
        let Ok(rt) = XlaRuntime::new(&default_artifacts_dir()) else { return };
        let base = tmp("state");
        std::fs::remove_dir_all(&base).ok();
        let (da, db) = (base.join("a"), base.join("b"));
        let mut a = ModelExecutor::new(&rt, "mlp_c10_b64", 5).unwrap();
        let x = vec![0.2f32; 64 * 64];
        let y = vec![2i32; 64];
        let sw = vec![1.0f32; 64];
        a.train_step(&x, &y, &sw, 0.05).unwrap();
        save(&a, &da, 3).unwrap();
        let snap = a.export_state().unwrap();
        save_state(&a.meta, &snap, &db, 3).unwrap();
        // every file the two checkpoints wrote is byte-identical
        for entry in std::fs::read_dir(&da).unwrap() {
            let name = entry.unwrap().file_name();
            let fa = std::fs::read(da.join(&name)).unwrap();
            let fb = std::fs::read(db.join(&name)).unwrap();
            assert_eq!(fa, fb, "{name:?} differs");
        }
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn reordered_index_names_rejected() {
        let meta = synth_meta(3, 80);
        let dir = tmp("names");
        std::fs::remove_dir_all(&dir).ok();
        let snap = synth_snapshot(&meta, 4.0);
        save_snapshot(&meta, &snap, &dir, 1, &WritePool::serial(), true).unwrap();
        // swap two index entries: positional load must refuse the
        // name mismatch instead of loading leaves into wrong slots
        let path = dir.join("checkpoint.json");
        let mut m = parse_file(&path).unwrap();
        if let Json::Obj(obj) = &mut m {
            if let Some(Json::Arr(entries)) = obj.get_mut("params") {
                entries.swap(0, 1);
            }
        }
        std::fs::write(&path, m.to_pretty()).unwrap();
        let err = load_snapshot(&meta, &dir, true).unwrap_err().to_string();
        assert!(err.contains("does not match"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
