//! Checkpointing: save/restore model parameters as a directory of `.npy`
//! files plus a JSON index — inspectable from Python (`np.load`) and
//! stable across runs.
//!
//! Layout: `<dir>/checkpoint.json` (variant, epoch, param index) and
//! `<dir>/p000_fc1_w.npy ...` (one array per parameter leaf).

use std::path::Path;

use crate::runtime::executor::ModelExecutor;
use crate::util::json::{parse_file, Json};
use crate::util::npy;

/// Save the executor's parameters at `dir` (created if needed).
pub fn save(exec: &ModelExecutor, dir: &Path, epoch: usize) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    let params = exec.export_params()?;
    let mut index = Vec::new();
    for (i, ((name, data), meta)) in params.iter().zip(&exec.meta.params).enumerate() {
        let fname = format!("p{:03}_{}.npy", i, name.replace('/', "_"));
        npy::write_f32(&dir.join(&fname), data, &meta.shape)?;
        index.push(crate::jobj![("name", name.as_str()), ("file", fname.as_str())]);
    }
    let manifest = crate::jobj![
        ("variant", exec.meta.name.as_str()),
        ("epoch", epoch),
        ("param_count", exec.meta.param_count),
        ("params", Json::Arr(index)),
    ];
    std::fs::write(dir.join("checkpoint.json"), manifest.to_pretty())?;
    Ok(())
}

/// Load a checkpoint into the executor.  The checkpoint's variant must
/// match (same parameter names/shapes).  Returns the saved epoch.
pub fn load(exec: &mut ModelExecutor, dir: &Path) -> anyhow::Result<usize> {
    let m = parse_file(&dir.join("checkpoint.json"))?;
    let variant = m.req("variant")?.as_str().unwrap_or_default();
    anyhow::ensure!(
        variant == exec.meta.name,
        "checkpoint is for variant {variant:?}, executor is {:?}",
        exec.meta.name
    );
    let mut source = Vec::new();
    for p in m.req("params")?.as_arr().unwrap_or(&[]) {
        let name = p.req("name")?.as_str().unwrap_or_default().to_string();
        let file = p.req("file")?.as_str().unwrap_or_default();
        let (data, _shape) = npy::read_f32(&dir.join(file))?;
        source.push((name, data));
    }
    let imported = exec.import_params(&source)?;
    anyhow::ensure!(
        imported == exec.meta.params.len(),
        "checkpoint restored only {imported}/{} leaves",
        exec.meta.params.len()
    );
    Ok(m.req("epoch")?.as_usize().unwrap_or(0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{default_artifacts_dir, XlaRuntime};

    #[test]
    fn save_load_roundtrip() {
        let Ok(rt) = XlaRuntime::new(&default_artifacts_dir()) else { return };
        let dir = std::env::temp_dir().join(format!("kakurenbo_ckpt_{}", std::process::id()));
        let mut a = ModelExecutor::new(&rt, "mlp_c10_b64", 11).unwrap();
        // perturb params so we're not just checking the seeded init
        let x = vec![0.3f32; 64 * 64];
        let y = vec![1i32; 64];
        let sw = vec![1.0f32; 64];
        a.train_step(&x, &y, &sw, 0.1).unwrap();
        save(&a, &dir, 7).unwrap();

        let mut b = ModelExecutor::new(&rt, "mlp_c10_b64", 999).unwrap();
        let epoch = load(&mut b, &dir).unwrap();
        assert_eq!(epoch, 7);
        let pa = a.export_params().unwrap();
        let pb = b.export_params().unwrap();
        for ((n1, d1), (n2, d2)) in pa.iter().zip(&pb) {
            assert_eq!(n1, n2);
            assert_eq!(d1, d2);
        }
        // wrong variant rejected
        let mut c = ModelExecutor::new(&rt, "mlp_c100_b64", 1).unwrap();
        assert!(load(&mut c, &dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
