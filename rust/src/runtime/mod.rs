//! Runtime: PJRT CPU client + AOT artifact loading + model execution.
//! Python never runs here — artifacts are produced once by `make artifacts`.

pub mod artifact;
pub mod checkpoint;
pub mod client;
pub mod executor;

pub use artifact::{default_artifacts_dir, Manifest, VariantMeta};
pub use client::XlaRuntime;
pub use executor::{BatchStats, EmbedStats, ModelExecutor};
