//! PJRT client wrapper: loads HLO-text artifacts and compiles them once.
//!
//! Pattern follows /opt/xla-example/src/bin/load_hlo.rs: HLO *text* ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile`.  Compiled executables are cached per artifact path so
//! repeated `ModelExecutor` constructions (benches, multi-run sweeps)
//! don't pay XLA compilation twice.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::runtime::artifact::Manifest;

/// The PJRT CPU client plus the loaded artifacts manifest and the
/// per-path executable cache.  One runtime per thread: the client and
/// its executables are not `Send` — data-parallel replica lanes each
/// construct their own (see `engine::DataParallel`).
pub struct XlaRuntime {
    /// The PJRT CPU client executing compiled artifacts.
    pub client: xla::PjRtClient,
    /// The artifacts manifest this runtime compiles from.
    pub manifest: Manifest,
    cache: Mutex<HashMap<PathBuf, Arc<xla::PjRtLoadedExecutable>>>,
    /// Per-variant calibrated cost models (calibration is noisy on a busy
    /// host; one measurement per variant keeps comparisons consistent).
    cost_cache: Mutex<HashMap<String, crate::coordinator::costmodel::CostModel>>,
}

impl XlaRuntime {
    /// Load the manifest at `artifacts_dir` and stand up a PJRT CPU
    /// client; artifacts compile lazily (and cached) on first use.
    pub fn new(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        crate::info!(
            "PJRT client up: platform={} devices={} ({} artifact variants)",
            client.platform_name(),
            client.device_count(),
            manifest.models.len()
        );
        Ok(XlaRuntime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            cost_cache: Mutex::new(HashMap::new()),
        })
    }

    /// Load + compile one HLO-text artifact (cached).
    pub fn compile(&self, path: &Path) -> anyhow::Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(exe.clone());
        }
        let t = crate::util::timer::Timer::start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e:?}"))?,
        );
        crate::debug!("compiled {path:?} in {:.2}s", t.elapsed_s());
        self.cache.lock().unwrap().insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    /// Calibrated cost model for a variant (cached across trainers).
    pub fn cost_model(
        &self,
        exec: &mut crate::runtime::executor::ModelExecutor,
    ) -> anyhow::Result<crate::coordinator::costmodel::CostModel> {
        let key = exec.meta.name.clone();
        if let Some(m) = self.cost_cache.lock().unwrap().get(&key) {
            return Ok(m.clone());
        }
        let m = crate::coordinator::costmodel::CostModel::calibrate(exec, 8)?;
        self.cost_cache.lock().unwrap().insert(key, m.clone());
        Ok(m)
    }

    /// Compile a variant's artifact by kind.
    pub fn compile_kind(
        &self,
        variant: &str,
        kind: &str,
    ) -> anyhow::Result<Arc<xla::PjRtLoadedExecutable>> {
        let meta = self.manifest.variant(variant)?;
        let path = self.manifest.artifact_path(meta, kind)?;
        self.compile(&path)
    }
}
