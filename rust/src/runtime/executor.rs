//! ModelExecutor: owns one model variant's parameters + momentum and runs
//! the AOT-compiled train/eval steps on the PJRT client.
//!
//! Calling convention (must match python/compile/model.py):
//!   train_step(params.., vel.., x, y, sw, lr, mu)
//!       -> tuple(params'.., vel'.., loss[B], correct[B], conf[B])
//!   fwd_stats(params.., x, y) -> tuple(loss, correct, conf)
//!   fwd_embed(params.., x, y) -> tuple(loss, correct, conf, emb, probs)
//!
//! Parameters are kept as XLA literals and threaded output->input across
//! steps; the per-step host traffic is the batch upload plus the 3 stat
//! vectors (exactly what KAKURENBO's selector consumes).

use std::path::PathBuf;
use std::sync::Arc;

use crate::runtime::artifact::VariantMeta;
use crate::runtime::client::XlaRuntime;
use crate::util::rng::Rng;

/// Per-batch statistics returned by every step (paper Fig. 1 "D: update
/// loss and prediction info").
#[derive(Clone, Debug, Default)]
pub struct BatchStats {
    /// Per-slot training loss.
    pub loss: Vec<f32>,
    /// Per-slot correctness indicator (1.0 = top-1 correct).
    pub correct: Vec<f32>,
    /// Per-slot prediction confidence (softmax probability of the label).
    pub conf: Vec<f32>,
}

/// Forward-pass output with embeddings (GradMatch / EL2N selection).
#[derive(Clone, Debug, Default)]
pub struct EmbedStats {
    /// The standard per-slot loss / correct / confidence stats.
    pub stats: BatchStats,
    /// [B, embed_dim] row-major penultimate features.
    pub emb: Vec<f32>,
    /// [B, classes] row-major softmax probabilities.
    pub probs: Vec<f32>,
}

/// Owns one model variant's parameters + momentum as PJRT device literals
/// and runs the AOT-compiled train/eval steps (the production
/// `StepBackend`; see the module docs for the calling convention).
pub struct ModelExecutor {
    /// The artifact variant this executor runs (shapes, batch, leaves).
    pub meta: VariantMeta,
    /// Artifacts directory the executor was compiled from — a replica
    /// builder re-opens it to construct a runtime on its own lane thread.
    artifacts_dir: PathBuf,
    train_exe: Arc<xla::PjRtLoadedExecutable>,
    fwd_exe: Arc<xla::PjRtLoadedExecutable>,
    embed_exe: Option<Arc<xla::PjRtLoadedExecutable>>,
    params: Vec<xla::Literal>,
    vel: Vec<xla::Literal>,
    /// SGD momentum coefficient (mu).
    pub momentum: f32,
    /// Cumulative executed train steps (diagnostics).
    pub steps: u64,
}

fn lit_f32(data: &[f32], dims: &[usize]) -> anyhow::Result<xla::Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("literal reshape {dims:?}: {e:?}"))
}

fn lit_i32(data: &[i32], dims: &[usize]) -> anyhow::Result<xla::Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("literal reshape {dims:?}: {e:?}"))
}

impl ModelExecutor {
    /// Compile (cached) the variant's artifacts on `rt` and seed the
    /// parameters; see [`ModelExecutor::reset_params`] for the init rule.
    pub fn new(rt: &XlaRuntime, variant: &str, seed: u64) -> anyhow::Result<Self> {
        let meta = rt.manifest.variant(variant)?.clone();
        let train_exe = rt.compile_kind(variant, "train_step")?;
        let fwd_exe = rt.compile_kind(variant, "fwd_stats")?;
        let embed_exe = if meta.artifacts.contains_key("fwd_embed") {
            Some(rt.compile_kind(variant, "fwd_embed")?)
        } else {
            None
        };
        let mut ex = ModelExecutor {
            meta,
            artifacts_dir: rt.manifest.dir.clone(),
            train_exe,
            fwd_exe,
            embed_exe,
            params: vec![],
            vel: vec![],
            momentum: 0.9,
            steps: 0,
        };
        ex.reset_params(seed)?;
        Ok(ex)
    }

    /// (Re-)initialize parameters: N(0, init_std) weights, zero biases,
    /// zero momentum.  Deterministic in `seed` (used by FORGET's restart
    /// and the seed-robustness bench, Table 9).
    pub fn reset_params(&mut self, seed: u64) -> anyhow::Result<()> {
        let mut rng = Rng::new(seed ^ 0x7061_7261);
        self.params = self
            .meta
            .params
            .iter()
            .map(|p| {
                let data: Vec<f32> = if p.init_std == 0.0 {
                    vec![0.0; p.numel()]
                } else {
                    (0..p.numel())
                        .map(|_| rng.normal_f32(0.0, p.init_std as f32))
                        .collect()
                };
                lit_f32(&data, &p.shape)
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        self.vel = self
            .meta
            .params
            .iter()
            .map(|p| lit_f32(&vec![0.0; p.numel()], &p.shape))
            .collect::<anyhow::Result<Vec<_>>>()?;
        self.steps = 0;
        Ok(())
    }

    fn x_dims(&self) -> Vec<usize> {
        let mut d = vec![self.meta.batch];
        d.extend_from_slice(&self.meta.input_shape);
        d
    }

    fn y_dims(&self) -> Vec<usize> {
        let mut d = vec![self.meta.batch];
        d.extend_from_slice(&self.meta.label_shape);
        d
    }

    /// One SGD step on a full batch.  `x`, `y`, `sw` must match the
    /// artifact batch size (pad via `BatchAssembler`).
    pub fn train_step(
        &mut self,
        x: &[f32],
        y: &[i32],
        sw: &[f32],
        lr: f32,
    ) -> anyhow::Result<BatchStats> {
        let b = self.meta.batch;
        anyhow::ensure!(sw.len() == b, "sw len {} != batch {b}", sw.len());
        let n = self.params.len();
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(2 * n + 5);
        args.extend(self.params.iter());
        args.extend(self.vel.iter());
        let xl = lit_f32(x, &self.x_dims())?;
        let yl = lit_i32(y, &self.y_dims())?;
        let swl = lit_f32(sw, &[b])?;
        let lrl = xla::Literal::from(lr);
        let mul = xla::Literal::from(self.momentum);
        args.push(&xl);
        args.push(&yl);
        args.push(&swl);
        args.push(&lrl);
        args.push(&mul);

        let result = self
            .train_exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("train_step execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("train_step download: {e:?}"))?;
        let mut parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("train_step untuple: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == 2 * n + 3,
            "train_step returned {} outputs, expected {}",
            parts.len(),
            2 * n + 3
        );
        let conf = parts.pop().unwrap();
        let correct = parts.pop().unwrap();
        let loss = parts.pop().unwrap();
        self.vel = parts.split_off(n);
        self.params = parts;
        self.steps += 1;
        Ok(BatchStats {
            loss: loss.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            correct: correct.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            conf: conf.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
        })
    }

    /// Forward-only stats (hidden-list refresh, eval, SB selection pass).
    pub fn fwd_stats(&self, x: &[f32], y: &[i32]) -> anyhow::Result<BatchStats> {
        let n = self.params.len();
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(n + 2);
        args.extend(self.params.iter());
        let xl = lit_f32(x, &self.x_dims())?;
        let yl = lit_i32(y, &self.y_dims())?;
        args.push(&xl);
        args.push(&yl);
        let result = self
            .fwd_exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("fwd_stats execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fwd_stats download: {e:?}"))?;
        let (loss, correct, conf) = result
            .to_tuple3()
            .map_err(|e| anyhow::anyhow!("fwd_stats untuple: {e:?}"))?;
        Ok(BatchStats {
            loss: loss.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            correct: correct.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            conf: conf.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
        })
    }

    /// Forward pass with embeddings + probs (GradMatch selection).
    pub fn fwd_embed(&self, x: &[f32], y: &[i32]) -> anyhow::Result<EmbedStats> {
        let exe = self
            .embed_exe
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("{} has no fwd_embed artifact", self.meta.name))?;
        let n = self.params.len();
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(n + 2);
        args.extend(self.params.iter());
        let xl = lit_f32(x, &self.x_dims())?;
        let yl = lit_i32(y, &self.y_dims())?;
        args.push(&xl);
        args.push(&yl);
        let result = exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("fwd_embed execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fwd_embed download: {e:?}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("fwd_embed untuple: {e:?}"))?;
        anyhow::ensure!(parts.len() == 5, "fwd_embed returned {} outputs", parts.len());
        let as_vec = |l: &xla::Literal| -> anyhow::Result<Vec<f32>> {
            l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))
        };
        Ok(EmbedStats {
            stats: BatchStats {
                loss: as_vec(&parts[0])?,
                correct: as_vec(&parts[1])?,
                conf: as_vec(&parts[2])?,
            },
            emb: as_vec(&parts[3])?,
            probs: as_vec(&parts[4])?,
        })
    }

    /// Snapshot the full mutable state (parameters then momentum, in
    /// manifest leaf order) as host tensors.
    pub fn export_state(&self) -> anyhow::Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(2 * self.params.len());
        for l in self.params.iter().chain(&self.vel) {
            out.push(l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?);
        }
        Ok(out)
    }

    /// Snapshot only the parameter leaves (manifest leaf order) — the
    /// params-only export tier: half the device→host traffic of
    /// [`ModelExecutor::export_state`], and all a forward pass reads.
    pub fn export_param_state(&self) -> anyhow::Result<Vec<Vec<f32>>> {
        self.params
            .iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}")))
            .collect()
    }

    /// Restore the parameter leaves positionally (manifest leaf order),
    /// leaving momentum untouched — the import half of the params-only
    /// tier (eval replicas, legacy params-only checkpoints).
    pub fn import_param_state(&mut self, params: &[Vec<f32>]) -> anyhow::Result<()> {
        let n = self.meta.params.len();
        anyhow::ensure!(
            params.len() == n,
            "params have {} leaves, executor expects {n}",
            params.len()
        );
        for (i, m) in self.meta.params.iter().enumerate() {
            anyhow::ensure!(
                params[i].len() == m.numel(),
                "param leaf {i} shape mismatch for {}",
                m.name
            );
        }
        for (i, m) in self.meta.params.iter().enumerate() {
            self.params[i] = lit_f32(&params[i], &m.shape)?;
        }
        Ok(())
    }

    /// Restore state previously produced by [`ModelExecutor::export_state`]
    /// (or an elementwise average of several such snapshots).
    pub fn import_state(&mut self, state: &[Vec<f32>]) -> anyhow::Result<()> {
        let n = self.meta.params.len();
        anyhow::ensure!(
            state.len() == 2 * n,
            "state has {} leaves, executor expects {}",
            state.len(),
            2 * n
        );
        for (i, m) in self.meta.params.iter().enumerate() {
            anyhow::ensure!(
                state[i].len() == m.numel() && state[n + i].len() == m.numel(),
                "state leaf {i} shape mismatch for {}",
                m.name
            );
            self.params[i] = lit_f32(&state[i], &m.shape)?;
            self.vel[i] = lit_f32(&state[n + i], &m.shape)?;
        }
        Ok(())
    }

    /// Export parameters by name (transfer learning / legacy checkpoint
    /// inspection).  For the positional fast path the engine's snapshot
    /// tiers use, see [`ModelExecutor::export_param_state`].
    pub fn export_named_params(&self) -> anyhow::Result<Vec<(String, Vec<f32>)>> {
        self.meta
            .params
            .iter()
            .zip(&self.params)
            .map(|(m, l)| {
                Ok((
                    m.name.clone(),
                    l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
                ))
            })
            .collect()
    }

    /// Import matching parameters by (name, shape); others keep their
    /// current values.  Returns how many leaves were imported.  Used by the
    /// transfer-learning pipeline: trunk transfers, head re-initializes.
    pub fn import_named_params(&mut self, source: &[(String, Vec<f32>)]) -> anyhow::Result<usize> {
        let mut imported = 0;
        for (i, m) in self.meta.params.iter().enumerate() {
            if let Some((_, data)) = source
                .iter()
                .find(|(n, d)| n == &m.name && d.len() == m.numel())
            {
                self.params[i] = lit_f32(data, &m.shape)?;
                imported += 1;
            }
        }
        Ok(imported)
    }

    /// L2 norm of all parameters (drift diagnostics in tests).
    pub fn param_norm(&self) -> anyhow::Result<f64> {
        let mut acc = 0.0f64;
        for l in &self.params {
            for v in l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))? {
                acc += (v as f64) * (v as f64);
            }
        }
        Ok(acc.sqrt())
    }
}

/// The step entry points the execution engine drives.  The executor *is*
/// the production backend; the engine never sees literals or PJRT types,
/// only host buffers in / per-slot stats out.
impl crate::engine::StepBackend for ModelExecutor {
    fn train_step(
        &mut self,
        x: &[f32],
        y: &[i32],
        sw: &[f32],
        lr: f32,
    ) -> anyhow::Result<BatchStats> {
        ModelExecutor::train_step(self, x, y, sw, lr)
    }

    fn fwd_stats(&mut self, x: &[f32], y: &[i32]) -> anyhow::Result<BatchStats> {
        ModelExecutor::fwd_stats(self, x, y)
    }

    fn fwd_embed(&mut self, x: &[f32], y: &[i32]) -> anyhow::Result<EmbedStats> {
        ModelExecutor::fwd_embed(self, x, y)
    }
}

/// The export/import round-trip preserves f32 bit patterns exactly
/// (host `Vec<f32>` ↔ device literal is a lossless copy), so the pool's
/// fixed worker-order averaging fold is deterministic.  The params-only
/// tier ([`crate::engine::StateExchange::export_params`]) downloads the
/// `n` parameter literals and skips the `n` momentum literals — the
/// halved critical-path export eval-only epochs ride.
impl crate::engine::StateExchange for ModelExecutor {
    fn export_state(&self) -> anyhow::Result<Vec<Vec<f32>>> {
        ModelExecutor::export_state(self)
    }

    fn import_state(&mut self, state: &[Vec<f32>]) -> anyhow::Result<()> {
        ModelExecutor::import_state(self, state)
    }

    fn export_params(&self) -> anyhow::Result<Vec<Vec<f32>>> {
        self.export_param_state()
    }

    fn export_momentum(&self) -> anyhow::Result<Option<Vec<Vec<f32>>>> {
        let mut out = Vec::with_capacity(self.vel.len());
        for l in &self.vel {
            out.push(l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?);
        }
        Ok(Some(out))
    }

    fn import_params(&mut self, params: &[Vec<f32>]) -> anyhow::Result<()> {
        self.import_param_state(params)
    }

    /// Leaf-wise typed restore (no flat-state concatenation): params
    /// always; momentum when the snapshot carries it.  A `Full`-tier
    /// snapshot without a momentum section is rejected — this executor's
    /// full state includes the optimizer trajectory.
    fn import_snapshot(&mut self, snap: &crate::engine::Snapshot) -> anyhow::Result<()> {
        use crate::engine::SnapshotTier;
        match (snap.tier(), snap.momentum()) {
            (SnapshotTier::Params, _) => self.import_param_state(snap.params()),
            (SnapshotTier::Full, Some(momentum)) => {
                let n = self.meta.params.len();
                anyhow::ensure!(
                    momentum.len() == n,
                    "momentum has {} leaves, executor expects {n}",
                    momentum.len()
                );
                for (i, m) in self.meta.params.iter().enumerate() {
                    anyhow::ensure!(
                        momentum[i].len() == m.numel(),
                        "momentum leaf {i} shape mismatch for {}",
                        m.name
                    );
                }
                self.import_param_state(snap.params())?;
                for (i, m) in self.meta.params.iter().enumerate() {
                    self.vel[i] = lit_f32(&momentum[i], &m.shape)?;
                }
                Ok(())
            }
            (SnapshotTier::Full, None) => anyhow::bail!(
                "full-state snapshot for {} is missing its momentum section",
                self.meta.name
            ),
        }
    }
}

/// Replica management for the worker pool's data-parallel mode.
///
/// A `ModelExecutor` is **not** `Send` — parameters live as PJRT device
/// literals — so a replica can never be constructed here and moved to a
/// worker thread.  The builder instead carries only `Send` host data (the
/// artifacts directory, the variant name, and an exported state snapshot)
/// and *rebuilds* the executor on the lane thread that invokes it: its
/// own PJRT client, its own compiled executables, its own literals.  The
/// replica starts bitwise-identical to `self` at builder-creation time
/// (the export/import round-trip is exact), and the worker pool keeps
/// lane threads alive across epochs so this per-thread setup cost is paid
/// once per training run.
impl crate::engine::DataParallel for ModelExecutor {
    fn replica_builder(&self) -> anyhow::Result<crate::engine::ReplicaBuilder> {
        let artifacts_dir = self.artifacts_dir.clone();
        let variant = self.meta.name.clone();
        let momentum = self.momentum;
        let steps = self.steps;
        let state = self.export_state()?;
        Ok(Box::new(move || {
            let rt = XlaRuntime::new(&artifacts_dir)?;
            let mut ex = ModelExecutor::new(&rt, &variant, 0)?;
            ex.momentum = momentum;
            ex.steps = steps;
            ex.import_state(&state)?;
            Ok(Box::new(ex) as Box<dyn crate::engine::ReplicaBackend>)
        }))
    }

    /// Lanes are reusable only for the same variant compiled from the
    /// same artifacts; any other executor respawns them.
    fn replica_cache_key(&self) -> String {
        format!("{}:{}", self.artifacts_dir.display(), self.meta.name)
    }
}
