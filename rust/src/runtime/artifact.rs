//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes artifacts/manifest.json + *.hlo.txt) and the Rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::{parse_file, Json};

/// One parameter leaf's metadata (name, shape, init rule).
#[derive(Clone, Debug, PartialEq)]
pub struct ParamMeta {
    /// Leaf name as exported by the compiler (checkpoint key).
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Std-dev for normal init; 0.0 means zeros (biases).
    pub init_std: f64,
}

impl ParamMeta {
    /// Total element count of the leaf.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One compiled model variant: shapes, parameter leaves, artifact files.
#[derive(Clone, Debug)]
pub struct VariantMeta {
    /// Manifest key, e.g. "cnn_c32_b64".
    pub name: String,
    /// Model family ("mlp", "cnn", ...; drives bench groupings).
    pub family: String,
    /// Device batch size the artifacts were lowered at.
    pub batch: usize,
    /// Per-sample input shape (flattened by [`VariantMeta::sample_dim`]).
    pub input_shape: Vec<usize>,
    /// Per-sample label shape (1 for classification).
    pub label_shape: Vec<usize>,
    /// Number of output classes.
    pub classes: usize,
    /// Penultimate-feature width of `fwd_embed` (0 when absent).
    pub embed_dim: usize,
    /// Total parameter count across leaves (validated on load).
    pub param_count: usize,
    /// Parameter leaves in execution order.
    pub params: Vec<ParamMeta>,
    /// kind ("train_step" | "fwd_stats" | "fwd_embed") -> file name.
    pub artifacts: BTreeMap<String, String>,
}

impl VariantMeta {
    /// Flattened input size per sample.
    pub fn sample_dim(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Labels per sample (1 for classification, H*W for segmentation).
    pub fn label_len(&self) -> usize {
        self.label_shape.iter().product()
    }

    fn from_json(name: &str, v: &Json) -> anyhow::Result<Self> {
        let params = v
            .req("params")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("params not array"))?
            .iter()
            .map(|p| -> anyhow::Result<ParamMeta> {
                Ok(ParamMeta {
                    name: p.req("name")?.as_str().unwrap_or_default().to_string(),
                    shape: p.req("shape")?.usize_list()?,
                    init_std: p.req("init_std")?.as_f64().unwrap_or(0.0),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let artifacts = v
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("artifacts not object"))?
            .iter()
            .map(|(k, f)| (k.clone(), f.as_str().unwrap_or_default().to_string()))
            .collect();
        let meta = VariantMeta {
            name: name.to_string(),
            family: v.req("family")?.as_str().unwrap_or_default().to_string(),
            batch: v.req("batch")?.as_usize().unwrap_or(0),
            input_shape: v.req("input_shape")?.usize_list()?,
            label_shape: v.req("label_shape")?.usize_list()?,
            classes: v.req("classes")?.as_usize().unwrap_or(0),
            embed_dim: v.req("embed_dim")?.as_usize().unwrap_or(0),
            param_count: v.req("param_count")?.as_usize().unwrap_or(0),
            params,
            artifacts,
        };
        anyhow::ensure!(meta.batch > 0, "{name}: zero batch");
        anyhow::ensure!(
            meta.param_count == meta.params.iter().map(ParamMeta::numel).sum::<usize>(),
            "{name}: param_count mismatch"
        );
        anyhow::ensure!(
            meta.artifacts.contains_key("train_step") && meta.artifacts.contains_key("fwd_stats"),
            "{name}: missing core artifacts"
        );
        Ok(meta)
    }
}

/// The loaded artifacts manifest: every compiled variant plus the
/// directory the HLO files live in.
#[derive(Debug)]
pub struct Manifest {
    /// Directory holding manifest.json and the *.hlo.txt artifacts.
    pub dir: PathBuf,
    /// Compiler fingerprint (Python-side config hash, diagnostics).
    pub fingerprint: String,
    /// Variant name -> metadata.
    pub models: BTreeMap<String, VariantMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json` and validate the referenced HLO files exist.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let v = parse_file(&dir.join("manifest.json"))?;
        let mut models = BTreeMap::new();
        for (name, m) in v
            .req("models")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("models not object"))?
        {
            let meta = VariantMeta::from_json(name, m)?;
            for f in meta.artifacts.values() {
                anyhow::ensure!(dir.join(f).exists(), "missing artifact file {f}");
            }
            models.insert(name.clone(), meta);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            fingerprint: v
                .req("fingerprint")?
                .as_str()
                .unwrap_or_default()
                .to_string(),
            models,
        })
    }

    /// Look up a variant by manifest key.
    pub fn variant(&self, name: &str) -> anyhow::Result<&VariantMeta> {
        self.models.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown model variant {name:?}; available: {:?}",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Absolute path of one of a variant's artifact files.
    pub fn artifact_path(&self, meta: &VariantMeta, kind: &str) -> anyhow::Result<PathBuf> {
        let f = meta
            .artifacts
            .get(kind)
            .ok_or_else(|| anyhow::anyhow!("{}: no {kind} artifact", meta.name))?;
        Ok(self.dir.join(f))
    }
}

/// Default artifacts directory: $KAKURENBO_ARTIFACTS or ./artifacts.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("KAKURENBO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Manifest> {
        let dir = default_artifacts_dir();
        Manifest::load(&dir).ok()
    }

    #[test]
    fn loads_repo_manifest_when_present() {
        // `make artifacts` must have run for this to exercise fully; the
        // test is skipped (not failed) when artifacts are absent so pure
        // cargo-test environments stay green.
        let Some(m) = repo_artifacts() else { return };
        assert!(!m.models.is_empty());
        let v = m.variant("cnn_c32_b64").unwrap();
        assert_eq!(v.batch, 64);
        assert_eq!(v.sample_dim(), 8 * 8 * 3);
        assert_eq!(v.label_len(), 1);
        assert!(v.embed_dim > 0);
        assert!(m.artifact_path(v, "train_step").unwrap().exists());
    }

    #[test]
    fn rejects_bad_variant_lookup() {
        let Some(m) = repo_artifacts() else { return };
        assert!(m.variant("nonexistent").is_err());
    }
}
