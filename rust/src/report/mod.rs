//! Shared bench harness (criterion is unavailable offline): scale control,
//! result persistence, convergence-series export, and the comparison-table
//! runner reused by most paper-table benches.

use std::path::PathBuf;

use crate::config::{ExperimentConfig, StrategyConfig};
use crate::coordinator::run_experiment;
use crate::metrics::RunResult;
use crate::runtime::XlaRuntime;
use crate::util::json::Json;
use crate::util::table::{diff_pct, pct, speedup_pct, Table};

/// Bench context: scale flag (`cargo bench -- --quick`), output directory,
/// shared XLA runtime.
pub struct BenchCtx {
    /// Reduced-scale mode (`--quick` / `KAKURENBO_QUICK`).
    pub quick: bool,
    /// Where result JSON payloads land (`results/`).
    pub out_dir: PathBuf,
    /// The shared PJRT runtime every bench run compiles against.
    pub rt: XlaRuntime,
}

impl BenchCtx {
    /// Parse bench argv (after the `--`), init the runtime.
    pub fn init(bench_name: &str) -> anyhow::Result<Self> {
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("KAKURENBO_QUICK").is_ok();
        // `cargo bench` passes --bench; tolerate any unknown flags.
        let out_dir = PathBuf::from("results");
        std::fs::create_dir_all(&out_dir)?;
        let rt = XlaRuntime::new(&crate::runtime::default_artifacts_dir())?;
        crate::util::logging::set_level(crate::util::logging::Level::Warn);
        println!("=== {bench_name}{} ===", if quick { " (quick)" } else { "" });
        Ok(BenchCtx { quick, out_dir, rt })
    }

    /// Scale an epoch/sample count down in quick mode.
    pub fn scale(&self, full: usize, quick: usize) -> usize {
        if self.quick { quick } else { full }
    }

    /// Shrink the dataset sizes of a config in quick mode.
    pub fn scale_config(&self, cfg: &mut ExperimentConfig) {
        if !self.quick {
            return;
        }
        cfg.epochs = cfg.epochs.div_ceil(3);
        use crate::config::DatasetConfig::*;
        match &mut cfg.dataset {
            GaussMixture(c) => {
                c.n_train = (c.n_train / 4).max(256);
                c.n_val = (c.n_val / 4).max(128);
            }
            ImagenetProxy(c) => {
                c.n_train = (c.n_train / 4).max(256);
                c.n_val = (c.n_val / 4).max(128);
            }
            DeepcamProxy(c) => {
                c.n_train = (c.n_train / 4).max(128);
                c.n_val = (c.n_val / 4).max(64);
            }
            Fractal(c) => {
                c.n_train = (c.n_train / 4).max(256);
                c.n_val = (c.n_val / 4).max(128);
            }
        }
    }

    /// Persist a set of run results under `results/<exp>.json`.
    pub fn save_runs(&self, exp: &str, runs: &[RunResult]) -> anyhow::Result<()> {
        let j = Json::Arr(runs.iter().map(|r| r.to_json()).collect());
        let path = self.out_dir.join(format!("{exp}.json"));
        std::fs::write(&path, j.to_pretty())?;
        println!("[saved {}]", path.display());
        Ok(())
    }

    /// Persist an arbitrary JSON payload.
    pub fn save_json(&self, exp: &str, j: &Json) -> anyhow::Result<()> {
        let path = self.out_dir.join(format!("{exp}.json"));
        std::fs::write(&path, j.to_pretty())?;
        println!("[saved {}]", path.display());
        Ok(())
    }
}

/// Run `base` once per strategy and print the paper-style comparison table
/// (accuracy, diff vs baseline, measured + modeled time, speedups).
/// The first strategy is treated as the baseline row.
pub fn comparison_table(
    ctx: &BenchCtx,
    title: &str,
    base: &ExperimentConfig,
    strategies: &[(String, StrategyConfig)],
) -> anyhow::Result<Vec<RunResult>> {
    let mut runs = Vec::new();
    for (label, s) in strategies {
        let mut cfg = base.clone();
        cfg.strategy = s.clone();
        cfg.name = format!("{}/{}", base.name, label);
        // FORGET trains a pruning prologue *plus* the full budget (the
        // paper reports total time including the extra epochs, §4.2).
        if let StrategyConfig::Forget { prune_epoch, .. } = s {
            cfg.epochs += prune_epoch;
        }
        let t = crate::util::timer::Timer::start();
        let mut r = run_experiment(&ctx.rt, cfg)?;
        r.strategy = label.clone();
        println!(
            "  {label:<16} acc {:.4}  time {:.1}s  modeled {:.1}s  ({:.1}s wall)",
            r.best_acc,
            r.total_time,
            r.total_modeled_time,
            t.elapsed_s()
        );
        runs.push(r);
    }
    print_comparison(title, &runs);
    Ok(runs)
}

/// Print the paper-style comparison table for already-computed runs
/// (first run is the baseline row).
pub fn print_comparison(title: &str, runs: &[RunResult]) {
    let base = &runs[0];
    let mut t = Table::new(title).header(&[
        "Setting", "Acc.", "Diff.", "Time (s)", "Impr.", "Modeled (s)", "Impr.",
    ]);
    for r in runs {
        let is_base = std::ptr::eq(r, base);
        t.row(vec![
            r.strategy.clone(),
            pct(r.best_acc),
            if is_base { "-".into() } else { diff_pct(r.best_acc, base.best_acc) },
            format!("{:.1}", r.total_time),
            if is_base { "-".into() } else { speedup_pct(r.total_time, base.total_time) },
            format!("{:.1}", r.total_modeled_time),
            if is_base {
                "-".into()
            } else {
                speedup_pct(r.total_modeled_time, base.total_modeled_time)
            },
        ]);
    }
    t.print();
}

/// Export per-epoch convergence series (Fig. 2/3-style) as JSON.
pub fn convergence_json(runs: &[RunResult]) -> Json {
    Json::Arr(
        runs.iter()
            .map(|r| {
                let epochs: Vec<usize> = r.records.iter().map(|x| x.epoch).collect();
                let acc: Vec<f64> = r.records.iter().map(|x| x.val_acc).collect();
                let time: Vec<f64> = r
                    .records
                    .iter()
                    .scan(0.0, |t, x| {
                        *t += x.time_total;
                        Some(*t)
                    })
                    .collect();
                let modeled: Vec<f64> = r
                    .records
                    .iter()
                    .scan(0.0, |t, x| {
                        *t += x.modeled_time;
                        Some(*t)
                    })
                    .collect();
                crate::jobj![
                    ("strategy", r.strategy.as_str()),
                    ("epoch", epochs),
                    ("val_acc", acc),
                    ("elapsed_s", time),
                    ("modeled_s", modeled),
                ]
            })
            .collect(),
    )
}

/// Standard strategy set for Table 2-style comparisons.
pub fn paper_strategies(fraction: f64, prune_epoch: usize) -> Vec<(String, StrategyConfig)> {
    vec![
        ("Baseline".into(), StrategyConfig::Baseline),
        ("ISWR".into(), StrategyConfig::Iswr),
        ("FORGET".into(), StrategyConfig::Forget { prune_epoch, fraction }),
        ("SB".into(), StrategyConfig::SelectiveBackprop { beta: 1.0 }),
        ("KAKURENBO".into(), StrategyConfig::kakurenbo(fraction)),
    ]
}
