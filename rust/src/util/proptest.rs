//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` generated inputs; on failure it
//! re-seeds and greedily shrinks via the generator's `shrink` hook, then
//! panics with the minimal counterexample and the seed needed to replay.
//!
//! Used by the coordinator's invariant tests: hiding selector, fraction
//! schedule, samplers, sharder, state store.

use super::rng::Rng;

/// A generator of random test cases with optional shrinking.
pub trait Gen {
    /// The generated case type.
    type Value: std::fmt::Debug + Clone;
    /// Draw one random case.
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller versions of a failing value (simplest first).
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `prop` on `cases` random inputs; panics with minimal counterexample.
pub fn check<G: Gen>(name: &str, seed: u64, cases: usize, gen_: &G, prop: impl Fn(&G::Value) -> Result<(), String>) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let value = gen_.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            // Greedy shrink: repeatedly take the first shrunk candidate that
            // still fails, until none fail.
            let mut cur = value;
            let mut cur_msg = msg;
            'outer: loop {
                for cand in gen_.shrink(&cur) {
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property {name:?} failed (seed={seed}, case={case}):\n  {cur_msg}\n  minimal counterexample: {cur:?}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Common generators
// ---------------------------------------------------------------------------

/// `Vec<f32>` of length in `[min_len, max_len]`, values in `[lo, hi]`.
pub struct VecF32 {
    /// Minimum generated length.
    pub min_len: usize,
    /// Maximum generated length.
    pub max_len: usize,
    /// Lower value bound.
    pub lo: f32,
    /// Upper value bound.
    pub hi: f32,
}

impl Gen for VecF32 {
    type Value = Vec<f32>;

    fn generate(&self, rng: &mut Rng) -> Vec<f32> {
        let n = self.min_len + rng.below(self.max_len - self.min_len + 1);
        (0..n)
            .map(|_| self.lo + (self.hi - self.lo) * rng.f32())
            .collect()
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..v.len() / 2].to_vec().into_iter().collect());
            out.push(v[..v.len() - 1].to_vec());
        }
        out.retain(|c: &Vec<f32>| c.len() >= self.min_len);
        out
    }
}

/// usize in [lo, hi].
pub struct USize {
    /// Lower bound (inclusive).
    pub lo: usize,
    /// Upper bound (inclusive).
    pub hi: usize,
}

impl Gen for USize {
    type Value = usize;

    fn generate(&self, rng: &mut Rng) -> usize {
        self.lo + rng.below(self.hi - self.lo + 1)
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
        }
        out.dedup();
        out
    }
}

/// Tuple combinator.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sorted-idempotent", 1, 50, &VecF32 { min_len: 0, max_len: 40, lo: -5.0, hi: 5.0 }, |v| {
            let mut a = v.clone();
            a.sort_by(|x, y| x.total_cmp(y));
            let mut b = a.clone();
            b.sort_by(|x, y| x.total_cmp(y));
            if a == b { Ok(()) } else { Err("sort not idempotent".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks() {
        check("len<5", 1, 200, &VecF32 { min_len: 0, max_len: 64, lo: 0.0, hi: 1.0 }, |v| {
            if v.len() < 5 { Ok(()) } else { Err(format!("len={}", v.len())) }
        });
    }

    #[test]
    fn pair_generator() {
        check("pair", 3, 50, &Pair(USize { lo: 1, hi: 10 }, USize { lo: 0, hi: 5 }), |&(a, b)| {
            if a >= 1 && b <= 5 { Ok(()) } else { Err("bounds".into()) }
        });
    }
}
