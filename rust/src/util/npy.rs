//! Minimal NumPy `.npy` v1.0 reader/writer for f32 arrays.
//!
//! Used by the checkpoint module so saved parameters can be inspected
//! from Python (`np.load`) — handy when debugging the Rust/JAX boundary.

use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"\x93NUMPY";

/// Encode a C-contiguous f32 array to an in-memory `.npy` v1.0 byte
/// image — byte-identical to what [`write_f32`] puts on disk.  The
/// checkpoint artifact layer frames these bytes rather than re-deriving
/// the format.
pub fn encode_f32(data: &[f32], shape: &[usize]) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!(
        data.len() == shape.iter().product::<usize>(),
        "data/shape mismatch"
    );
    let shape_str = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // pad so that magic(6)+ver(2)+len(2)+header is a multiple of 64
    let unpadded = 10 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    let mut out = Vec::with_capacity(10 + header.len() + data.len() * 4);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&[1, 0]);
    out.extend_from_slice(&(header.len() as u16).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    Ok(out)
}

/// Decode an in-memory `.npy` v1.0 byte image; returns (data, shape).
pub fn decode_f32(bytes: &[u8]) -> anyhow::Result<(Vec<f32>, Vec<usize>)> {
    anyhow::ensure!(bytes.len() >= 10, "npy image truncated");
    anyhow::ensure!(&bytes[..6] == MAGIC, "not an npy file");
    anyhow::ensure!(bytes[6] == 1, "unsupported npy version {}", bytes[6]);
    let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
    anyhow::ensure!(bytes.len() >= 10 + hlen, "npy header truncated");
    let header = std::str::from_utf8(&bytes[10..10 + hlen])?;
    anyhow::ensure!(header.contains("'<f4'"), "only <f4 supported: {header}");
    anyhow::ensure!(header.contains("False"), "fortran order unsupported");
    // parse shape tuple
    let s = header
        .split("'shape':")
        .nth(1)
        .and_then(|t| t.split('(').nth(1))
        .and_then(|t| t.split(')').next())
        .ok_or_else(|| anyhow::anyhow!("bad header {header}"))?;
    let shape: Vec<usize> = s
        .split(',')
        .filter_map(|p| {
            let p = p.trim();
            if p.is_empty() { None } else { Some(p.parse()) }
        })
        .collect::<Result<_, _>>()?;
    let n: usize = shape.iter().product();
    let payload = &bytes[10 + hlen..];
    anyhow::ensure!(payload.len() >= n * 4, "truncated npy payload");
    let data = payload[..n * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((data, shape))
}

/// Write a C-contiguous f32 array.
pub fn write_f32(path: &Path, data: &[f32], shape: &[usize]) -> anyhow::Result<()> {
    let bytes = encode_f32(data, shape)?;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(&bytes)?;
    Ok(())
}

/// Read an f32 `.npy` file; returns (data, shape).
pub fn read_f32(path: &Path) -> anyhow::Result<(Vec<f32>, Vec<usize>)> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    decode_f32(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("kakurenbo_npy_{name}_{}.npy", std::process::id()))
    }

    #[test]
    fn roundtrip_2d() {
        let path = tmp("2d");
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        write_f32(&path, &data, &[3, 4]).unwrap();
        let (d, s) = read_f32(&path).unwrap();
        assert_eq!(s, vec![3, 4]);
        assert_eq!(d, data);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_1d_and_scalar() {
        let path = tmp("1d");
        write_f32(&path, &[1.5, -2.5], &[2]).unwrap();
        let (d, s) = read_f32(&path).unwrap();
        assert_eq!(s, vec![2]);
        assert_eq!(d, vec![1.5, -2.5]);
        std::fs::remove_file(&path).ok();

        let path = tmp("0d");
        write_f32(&path, &[7.0], &[]).unwrap();
        let (d, s) = read_f32(&path).unwrap();
        assert!(s.is_empty());
        assert_eq!(d, vec![7.0]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_shape_mismatch() {
        assert!(write_f32(&tmp("bad"), &[1.0], &[2, 2]).is_err());
    }

    #[test]
    fn encode_matches_file_bytes() {
        let path = tmp("encode");
        let data: Vec<f32> = (0..20).map(|i| i as f32 - 9.5).collect();
        write_f32(&path, &data, &[4, 5]).unwrap();
        let from_disk = std::fs::read(&path).unwrap();
        assert_eq!(encode_f32(&data, &[4, 5]).unwrap(), from_disk);
        let (d, s) = decode_f32(&from_disk).unwrap();
        assert_eq!(s, vec![4, 5]);
        assert_eq!(d, data);
        std::fs::remove_file(&path).ok();
    }
}
