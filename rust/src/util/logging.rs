//! Leveled stderr logging with a global verbosity switch (no env_logger
//! offline).  Benches keep stdout clean for table output; all diagnostics
//! go to stderr through these macros.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ordered from always-shown to most verbose.
#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable problems (always shown).
    Error = 0,
    /// Suspicious-but-survivable conditions.
    Warn = 1,
    /// Per-epoch progress (the default verbosity).
    Info = 2,
    /// Per-phase diagnostics.
    Debug = 3,
}

static VERBOSITY: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global verbosity threshold.
pub fn set_level(level: Level) {
    VERBOSITY.store(level as u8, Ordering::Relaxed);
}

/// Whether messages at `level` currently pass the threshold.
pub fn enabled(level: Level) -> bool {
    level as u8 <= VERBOSITY.load(Ordering::Relaxed)
}

/// Emit one message at `level` (prefer the `info!`/`warn_!`/`debug!`/
/// `error!` macros).
pub fn log(level: Level, args: std::fmt::Arguments) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {args}");
    }
}

/// Log at [`Level::Info`] with `format!` syntax.
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) };
}
/// Log at [`Level::Warn`] with `format!` syntax (trailing `_` avoids the
/// built-in `warn` attribute name).
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) };
}
/// Log at [`Level::Debug`] with `format!` syntax.
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) };
}
/// Log at [`Level::Error`] with `format!` syntax.
#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
