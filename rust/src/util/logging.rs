//! Leveled stderr logging with a global verbosity switch (no env_logger
//! offline).  Benches keep stdout clean for table output; all diagnostics
//! go to stderr through these macros.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static VERBOSITY: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    VERBOSITY.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= VERBOSITY.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
