//! Filesystem helpers for crash-safe persistence.
//!
//! Checkpoint and resume manifests are the *pointer* to a set of payload
//! files; writing them through [`write_atomic`] (temp file + rename, the
//! POSIX atomic-replace idiom) means a reader either sees the complete
//! old manifest or the complete new one, never a torn write.  Payload
//! files get epoch-suffixed names so a new save never overwrites the set
//! the current manifest points at; [`gc_files`] sweeps the superseded
//! generation once the new manifest is durable.

use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Write `contents` to `path` atomically **and durably**: the bytes land
/// in a `.tmp` sibling, are fsynced to stable storage, and the file is
/// renamed over the destination — so even across power loss a reader
/// sees either the complete old file or the complete new one, never a
/// prefix or a rename pointing at unwritten blocks.
pub fn write_atomic(path: &Path, contents: &str) -> anyhow::Result<()> {
    let file = path
        .file_name()
        .and_then(|f| f.to_str())
        .ok_or_else(|| anyhow::anyhow!("write_atomic: bad path {path:?}"))?;
    let tmp = path.with_file_name(format!("{file}.tmp"));
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(contents.as_bytes())?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Monotonic counter making concurrent temp-file names unique: two write
/// pool workers storing the *same* content-addressed object must not
/// clobber each other's in-flight temp file.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Byte-payload twin of [`write_atomic`], safe under concurrency: the
/// temp sibling carries a process-unique sequence number
/// (`{file}.{seq}.tmp`), so parallel writers racing on the same
/// destination each rename a complete, durable file into place.
pub fn write_atomic_bytes(path: &Path, contents: &[u8]) -> anyhow::Result<()> {
    let file = path
        .file_name()
        .and_then(|f| f.to_str())
        .ok_or_else(|| anyhow::anyhow!("write_atomic_bytes: bad path {path:?}"))?;
    let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = path.with_file_name(format!("{file}.{seq}.tmp"));
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(contents)?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Flush an already-written payload file to stable storage (fsync).
/// Called on every payload before the manifest flip, so a durable
/// manifest never references data still sitting in the page cache.
pub fn sync_file(path: &Path) -> anyhow::Result<()> {
    std::fs::File::open(path)?.sync_all()?;
    Ok(())
}

/// Best-effort sweep of superseded payload files: removes every entry of
/// `dir` for which `matches` returns true that is not named in `keep`.
/// Errors are swallowed — garbage from a failed sweep is harmless (the
/// manifest never references it), a failed save is not.
pub fn gc_files(dir: &Path, keep: &[String], matches: impl Fn(&str) -> bool) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for e in entries.flatten() {
        let name = e.file_name();
        let Some(name) = name.to_str() else { continue };
        if matches(name) && !keep.iter().any(|k| k == name) {
            let _ = std::fs::remove_file(e.path());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("kakurenbo_fsutil_{name}_{}", std::process::id()))
    }

    #[test]
    fn write_atomic_replaces_and_leaves_no_tmp() {
        let dir = tmp("atomic");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("manifest.json");
        write_atomic(&path, "old").unwrap();
        write_atomic(&path, "new").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "new");
        assert!(!dir.join("manifest.json.tmp").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_atomic_bytes_concurrent_same_destination() {
        let dir = tmp("atomic_bytes");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("obj.art");
        let payload = vec![0x5au8; 4096];
        std::thread::scope(|s| {
            for _ in 0..4 {
                let path = &path;
                let payload = &payload;
                s.spawn(move || write_atomic_bytes(path, payload).unwrap());
            }
        });
        assert_eq!(std::fs::read(&path).unwrap(), payload);
        // no temp litter survives the race
        let leftovers: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().into_string().unwrap())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_removes_only_matching_unkept_files() {
        let dir = tmp("gc");
        std::fs::create_dir_all(&dir).unwrap();
        for f in ["a.e1.npy", "a.e2.npy", "other.txt"] {
            std::fs::write(dir.join(f), "x").unwrap();
        }
        gc_files(&dir, &["a.e2.npy".to_string()], |n| n.ends_with(".npy"));
        assert!(!dir.join("a.e1.npy").exists());
        assert!(dir.join("a.e2.npy").exists());
        assert!(dir.join("other.txt").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
