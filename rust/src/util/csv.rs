//! Tiny CSV writer (RFC-4180 quoting) for exporting metric records to
//! spreadsheet-friendly files alongside the JSON dumps.

use std::io::Write;
use std::path::Path;

/// A header-checked CSV file writer.
pub struct CsvWriter {
    out: std::io::BufWriter<std::fs::File>,
    columns: usize,
}

fn quote(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

impl CsvWriter {
    /// Create `path` (and parent dirs) and write the header row.
    pub fn create(path: &Path, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = CsvWriter {
            out: std::io::BufWriter::new(std::fs::File::create(path)?),
            columns: header.len(),
        };
        w.write_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>())?;
        Ok(w)
    }

    /// Write one row (must match the header's column count).
    pub fn write_row(&mut self, cells: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(
            cells.len() == self.columns,
            "row has {} cells, header has {}",
            cells.len(),
            self.columns
        );
        let line: Vec<String> = cells.iter().map(|c| quote(c)).collect();
        writeln!(self.out, "{}", line.join(","))?;
        Ok(())
    }

    /// Flush and close the file.
    pub fn finish(mut self) -> anyhow::Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Export a run's epoch records as CSV.
pub fn export_run(run: &crate::metrics::RunResult, path: &Path) -> anyhow::Result<()> {
    let mut w = CsvWriter::create(
        path,
        &[
            "epoch", "lr", "fraction_ceiling", "hidden", "moved_back", "hidden_again",
            "trained", "backprop", "train_loss", "val_acc", "time_total", "modeled_time",
        ],
    )?;
    for r in &run.records {
        w.write_row(&[
            r.epoch.to_string(),
            format!("{}", r.lr),
            format!("{}", r.fraction_ceiling),
            r.hidden.to_string(),
            r.moved_back.to_string(),
            r.hidden_again.to_string(),
            r.trained_samples.to_string(),
            r.backprop_samples.to_string(),
            format!("{}", r.train_loss),
            format!("{}", r.val_acc),
            format!("{}", r.time_total),
            format!("{}", r.modeled_time),
        ])?;
    }
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_quotes() {
        let path = std::env::temp_dir().join(format!("kakurenbo_csv_{}.csv", std::process::id()));
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.write_row(&["1".into(), "x,y".into()]).unwrap();
        w.write_row(&["2".into(), "say \"hi\"".into()]).unwrap();
        assert!(w.write_row(&["only-one".into()]).is_err());
        w.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().next().unwrap(), "a,b");
        assert!(text.contains("\"x,y\""));
        assert!(text.contains("\"say \"\"hi\"\"\""));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn export_run_produces_rows() {
        let run = crate::metrics::RunResult::from_records(
            "t",
            "baseline",
            vec![crate::metrics::EpochRecord { epoch: 0, val_acc: 0.5, ..Default::default() }],
        );
        let path = std::env::temp_dir().join(format!("kakurenbo_run_{}.csv", std::process::id()));
        export_run(&run, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_file(&path).ok();
    }
}
