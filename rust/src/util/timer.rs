//! Wall-clock timing + a named-section time accounting ledger.
//!
//! The paper's speedup accounting (§4.2, Fig. 4) separates epoch time into
//! training compute, hidden-list forward refresh, sorting/selection
//! overhead, and (in the cost model) communication.  `TimeLedger` gives each
//! component a named bucket so every epoch record can report the same
//! breakdown.

use std::collections::BTreeMap;
use std::time::Instant;

/// A one-shot wall-clock stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Seconds since [`Timer::start`].
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds since [`Timer::start`].
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// Accumulates seconds per named section.
#[derive(Default, Clone, Debug)]
pub struct TimeLedger {
    buckets: BTreeMap<&'static str, f64>,
}

impl TimeLedger {
    /// An empty ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `seconds` to the `name` bucket.
    pub fn add(&mut self, name: &'static str, seconds: f64) {
        *self.buckets.entry(name).or_insert(0.0) += seconds;
    }

    /// Time `f` and charge it to `name`.
    pub fn time<T>(&mut self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add(name, t.elapsed_s());
        out
    }

    /// Seconds charged to `name` so far (0 for unknown buckets).
    pub fn get(&self, name: &str) -> f64 {
        self.buckets.get(name).copied().unwrap_or(0.0)
    }

    /// Sum across all buckets.
    pub fn total(&self) -> f64 {
        self.buckets.values().sum()
    }

    /// Iterate `(bucket, seconds)` pairs in name order.
    pub fn entries(&self) -> impl Iterator<Item = (&'static str, f64)> + '_ {
        self.buckets.iter().map(|(&k, &v)| (k, v))
    }

    /// Fold another ledger's buckets into this one.
    pub fn merge(&mut self, other: &TimeLedger) {
        for (k, v) in other.entries() {
            self.add(k, v);
        }
    }

    /// Clear every bucket.
    pub fn reset(&mut self) {
        self.buckets.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates() {
        let mut l = TimeLedger::new();
        l.add("train", 1.0);
        l.add("train", 2.0);
        l.add("sort", 0.5);
        assert_eq!(l.get("train"), 3.0);
        assert_eq!(l.total(), 3.5);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut l = TimeLedger::new();
        let v = l.time("x", || 41 + 1);
        assert_eq!(v, 42);
        assert!(l.get("x") >= 0.0);
    }

    #[test]
    fn merge_sums() {
        let mut a = TimeLedger::new();
        a.add("t", 1.0);
        let mut b = TimeLedger::new();
        b.add("t", 2.0);
        b.add("u", 3.0);
        a.merge(&b);
        assert_eq!(a.get("t"), 3.0);
        assert_eq!(a.get("u"), 3.0);
    }
}
