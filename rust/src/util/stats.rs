//! Small numeric/statistics helpers shared across the coordinator:
//! summary stats, percentiles, histograms (paper Figs. 5 & 11), EMA.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f32]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// q-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f32], q: f64) -> f32 {
    assert!((0.0..=100.0).contains(&q));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = q / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let t = (pos - lo as f64) as f32;
        v[lo] * (1.0 - t) + v[hi] * t
    }
}

/// Fixed-bin histogram over [lo, hi]; values outside clamp to edge bins.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Lower range edge.
    pub lo: f32,
    /// Upper range edge.
    pub hi: f32,
    /// Per-bin counts.
    pub counts: Vec<u64>,
}

impl Histogram {
    /// An empty histogram with `bins` bins over `[lo, hi]`.
    pub fn new(lo: f32, hi: f32, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo);
        Histogram { lo, hi, counts: vec![0; bins] }
    }

    /// Histogram of `xs` with `bins` bins over `[lo, hi]`.
    pub fn of(xs: &[f32], lo: f32, hi: f32, bins: usize) -> Self {
        let mut h = Histogram::new(lo, hi, bins);
        for &x in xs {
            h.add(x);
        }
        h
    }

    /// Count one value (clamped to the edge bins).
    pub fn add(&mut self, x: f32) {
        let bins = self.counts.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * bins as f32) as i64;
        let idx = t.clamp(0, bins as i64 - 1) as usize;
        self.counts[idx] += 1;
    }

    /// Total count across all bins.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Normalized bin densities.
    pub fn densities(&self) -> Vec<f64> {
        let n = self.total().max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / n).collect()
    }

    /// Bin centers, for plotting/reporting.
    pub fn centers(&self) -> Vec<f32> {
        let w = (self.hi - self.lo) / self.counts.len() as f32;
        (0..self.counts.len())
            .map(|i| self.lo + w * (i as f32 + 0.5))
            .collect()
    }

    /// Render a one-line ASCII sparkline (for log output).
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.counts.iter().copied().max().unwrap_or(0).max(1);
        self.counts
            .iter()
            .map(|&c| GLYPHS[(c * 7 / max) as usize])
            .collect()
    }
}

/// Exponential moving average.
#[derive(Clone, Copy, Debug)]
pub struct Ema {
    /// Smoothing factor in (0, 1]; higher tracks faster.
    pub alpha: f64,
    value: Option<f64>,
}

impl Ema {
    /// An empty EMA with smoothing factor `alpha`.
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }

    /// Fold one observation in; returns the updated average.
    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    /// The current average (`None` before the first push).
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// argsort ascending by key (stable); the hiding selector's O(N log N) core.
pub fn argsort_by_f32(keys: &[f32]) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..keys.len() as u32).collect();
    idx.sort_by(|&a, &b| keys[a as usize].total_cmp(&keys[b as usize]));
    idx
}

/// Indices of the k smallest keys, O(N) average via select_nth (quickselect),
/// unordered within the selected set.  Used by the optimized hiding path.
pub fn argselect_smallest(keys: &[f32], k: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..keys.len() as u32).collect();
    if k == 0 {
        return Vec::new();
    }
    if k >= idx.len() {
        return idx;
    }
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        keys[a as usize].total_cmp(&keys[b as usize])
    });
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-9);
        assert!((std_dev(&xs) - 1.118033988).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs = [3.0f32, 1.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-6);
    }

    #[test]
    fn histogram_counts_and_clamping() {
        let h = Histogram::of(&[0.1, 0.2, 0.9, 5.0, -3.0], 0.0, 1.0, 10);
        assert_eq!(h.total(), 5);
        assert_eq!(h.counts[9], 2); // 0.9 and clamped 5.0
        assert_eq!(h.counts[0], 1); // clamped -3.0
        assert_eq!(h.centers().len(), 10);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        e.push(0.0);
        for _ in 0..20 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-3);
    }

    #[test]
    fn argsort_orders() {
        let keys = [3.0f32, 1.0, 2.0];
        assert_eq!(argsort_by_f32(&keys), vec![1, 2, 0]);
    }

    #[test]
    fn argselect_matches_argsort_prefix_set() {
        let keys: Vec<f32> = (0..100).map(|i| ((i * 37) % 100) as f32).collect();
        for k in [0, 1, 10, 50, 99, 100] {
            let mut a = argselect_smallest(&keys, k);
            let mut b = argsort_by_f32(&keys)[..k].to_vec();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "k={k}");
        }
    }

    #[test]
    fn argsort_handles_nan_total_order() {
        let keys = [f32::NAN, 1.0, 0.5];
        let idx = argsort_by_f32(&keys);
        assert_eq!(&idx[..2], &[2, 1]); // NaN sorts last under total_cmp
    }
}
