//! Offline substrates: everything a crates.io-connected project would pull
//! in as dependencies, implemented in-tree (see DESIGN.md §4).

/// Framed, digest-named checkpoint leaf store + the leaf write pool.
pub mod artifact;
/// CSV writer for result exports.
pub mod csv;
/// Crash-safe filesystem primitives (atomic writes, fsync, GC sweeps).
pub mod fsutil;
/// Minimal JSON parser / serializer.
pub mod json;
/// Leveled stderr logging.
pub mod logging;
/// NumPy `.npy` array read/write.
pub mod npy;
/// ASCII line plots for convergence curves.
pub mod plot;
/// Minimal property-testing harness.
pub mod proptest;
/// Deterministic splittable PRNG.
pub mod rng;
/// In-tree SHA-256 (FIPS 180-4) for artifact digests.
pub mod sha256;
/// Histograms, percentiles, and running statistics.
pub mod stats;
/// ASCII table rendering for bench output.
pub mod table;
/// Host thread-count helpers.
pub mod threadpool;
/// Wall-clock timers and per-phase time ledgers.
pub mod timer;
