//! Offline substrates: everything a crates.io-connected project would pull
//! in as dependencies, implemented in-tree (see DESIGN.md §4).

pub mod csv;
pub mod fsutil;
pub mod json;
pub mod logging;
pub mod npy;
pub mod plot;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
pub mod timer;
