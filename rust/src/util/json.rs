//! Minimal JSON parser / serializer.
//!
//! The offline build has no `serde`; this module covers everything the
//! coordinator needs: the artifact manifest written by `python/compile/aot.py`,
//! experiment configs, and metric dumps under `results/`.
//!
//! Full JSON value model, recursive-descent parser with line/column errors,
//! escape handling, and a compact + pretty serializer.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (key-sorted for stable serialization).
    Obj(BTreeMap<String, Json>),
}

/// A parse failure with its source position.
#[derive(Debug)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// 1-based source line.
    pub line: usize,
    /// 1-based source column.
    pub col: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for JsonError {}

// ---------------------------------------------------------------------------
// Accessors
// ---------------------------------------------------------------------------

impl Json {
    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that reports *which* key was missing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?} in {self:.60?}"))
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to `usize`, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key/value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Parse an all-number array into `usize`s.
    pub fn usize_list(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("expected number")))
            .collect()
    }
}

// Builders for ergonomic construction in metric writers.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<f32> for Json {
    fn from(n: f32) -> Self {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// `obj![("k", v), ...]` convenience.
#[macro_export]
macro_rules! jobj {
    ($(($k:expr, $v:expr)),* $(,)?) => {{
        let mut m = std::collections::BTreeMap::new();
        $( m.insert($k.to_string(), $crate::util::json::Json::from($v)); )*
        $crate::util::json::Json::Obj(m)
    }};
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Maximum container nesting accepted by the parser.  The parser is
/// recursive-descent, and since the serve endpoints put it on an
/// untrusted network boundary a hostile `[[[[…` must produce a
/// positioned error, not a stack overflow.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    line: usize,
    col: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Self {
        Parser { bytes: s.as_bytes(), pos: 0, line: 1, col: 1, depth: 0 }
    }

    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { msg: msg.into(), line: self.line, col: self.col })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        if b == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.bump();
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        match self.bump() {
            Some(c) if c == b => Ok(()),
            other => self.err(format!("expected {:?}, got {:?}", b as char, other.map(|c| c as char))),
        }
    }

    fn parse_value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.parse_obj(),
            Some(b'[') => self.parse_arr(),
            Some(b'"') => Ok(Json::Str(self.parse_string()?)),
            Some(b't') => self.parse_lit("true", Json::Bool(true)),
            Some(b'f') => self.parse_lit("false", Json::Bool(false)),
            Some(b'n') => self.parse_lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.parse_num(),
            other => self.err(format!("unexpected {:?}", other.map(|c| c as char))),
        }
    }

    fn parse_lit(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        for &b in lit.as_bytes() {
            self.expect(b)?;
        }
        Ok(v)
    }

    fn parse_num(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.bump();
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            // Rust parses out-of-range literals ("1e999") to ±inf instead
            // of erroring; JSON has no infinities, so reject them here —
            // accepting one would re-encode as null and break round-trips
            Ok(n) if n.is_finite() => Ok(Json::Num(n)),
            Ok(_) => self.err(format!("number {s:?} overflows f64")),
            Err(_) => self.err(format!("bad number {s:?}")),
        }
    }

    fn parse_string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = Vec::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => break,
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push(b'"'),
                    Some(b'\\') => out.push(b'\\'),
                    Some(b'/') => out.push(b'/'),
                    Some(b'n') => out.push(b'\n'),
                    Some(b't') => out.push(b'\t'),
                    Some(b'r') => out.push(b'\r'),
                    Some(b'b') => out.push(0x08),
                    Some(b'f') => out.push(0x0C),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or(JsonError {
                                msg: "bad \\u".into(),
                                line: self.line,
                                col: self.col,
                            })?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or(JsonError {
                                    msg: "bad \\u digit".into(),
                                    line: self.line,
                                    col: self.col,
                                })?;
                        }
                        let ch = char::from_u32(code).unwrap_or('\u{FFFD}');
                        let mut buf = [0u8; 4];
                        out.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                    }
                    other => {
                        return self.err(format!("bad escape {:?}", other.map(|c| c as char)))
                    }
                },
                Some(b) => out.push(b),
            }
        }
        String::from_utf8(out).map_err(|_| JsonError {
            msg: "invalid utf8".into(),
            line: self.line,
            col: self.col,
        })
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return self.err("nesting too deep");
        }
        Ok(())
    }

    fn parse_arr(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.bump();
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.parse_value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                other => return self.err(format!("expected , or ], got {:?}", other.map(|c| c as char))),
            }
        }
    }

    fn parse_obj(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.bump();
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.parse_value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                other => return self.err(format!("expected , or }}, got {:?}", other.map(|c| c as char))),
            }
        }
    }
}

/// Parse a complete JSON document (rejects trailing data).
pub fn parse(s: &str) -> Result<Json, JsonError> {
    let mut p = Parser::new(s);
    let v = p.parse_value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing data");
    }
    Ok(v)
}

/// Read and parse a JSON file, naming the path in any error.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))
}

// ---------------------------------------------------------------------------
// Serializer
// ---------------------------------------------------------------------------

fn esc(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn fmt_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no NaN/Inf
    } else if n == n.trunc() && n.abs() < 1e15 && !(n == 0.0 && n.is_sign_negative()) {
        // exact integral values keep their plain form ("3", not "3.0");
        // -0.0 must not take this path — `n as i64` drops the sign bit
        out.push_str(&format!("{}", n as i64));
    } else {
        // Debug formatting is shortest-round-trip and switches to
        // exponent notation at extreme magnitudes, so every finite f64
        // (and any f32 widened into one) re-parses to the exact bits —
        // served logits survive the wire losslessly.
        out.push_str(&format!("{n:?}"));
    }
}

impl Json {
    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => fmt_num(*n, out),
            Json::Str(s) => esc(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    x.write(out, indent, depth + 1);
                }
                if let Some(w) = indent {
                    if !v.is_empty() {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * depth));
                    }
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    esc(k, out);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                if let Some(w) = indent {
                    if !m.is_empty() {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * depth));
                    }
                }
                out.push('}');
            }
        }
    }

    /// Serialize without whitespace.
    pub fn to_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize indented (one space per depth level).
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"models": {"cnn": {"batch": 64, "params": [{"name": "w", "shape": [3, 3]}], "ok": true, "x": null}}, "v": 1.5e-3}"#;
        let v = parse(src).unwrap();
        assert_eq!(
            v.get("models").unwrap().get("cnn").unwrap().get("batch").unwrap().as_usize(),
            Some(64)
        );
        let re = parse(&v.to_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = parse(&v.to_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn parses_negative_and_exponent() {
        assert_eq!(parse("-3.25").unwrap().as_f64(), Some(-3.25));
        assert_eq!(parse("2e3").unwrap().as_f64(), Some(2000.0));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\n\"b\"A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\"b\"A"));
        let out = v.to_compact();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn error_positions() {
        let e = parse("{\n  \"a\": x\n}").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn jobj_macro() {
        let v = jobj![("a", 1usize), ("b", "hi"), ("c", vec![1.0f64, 2.0])];
        assert_eq!(v.get("a").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("b").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("c").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_compact(), "null");
    }

    #[test]
    fn integers_still_format_plain() {
        assert_eq!(Json::Num(3.0).to_compact(), "3");
        assert_eq!(Json::Num(-17.0).to_compact(), "-17");
        assert_eq!(Json::Num(0.0).to_compact(), "0");
    }

    #[test]
    fn negative_zero_keeps_its_sign() {
        // regression: the integral fast path formatted -0.0 via `as i64`,
        // printing "0" and silently flipping the sign bit on re-parse
        let s = Json::Num(-0.0).to_compact();
        let back = parse(&s).unwrap().as_f64().unwrap();
        assert_eq!(back.to_bits(), (-0.0f64).to_bits(), "serialized as {s:?}");
    }

    #[test]
    fn extreme_floats_roundtrip_bit_exact() {
        for v in [
            5e-324, // smallest denormal
            2.2250738585072011e-308,
            f64::MIN_POSITIVE,
            -f64::MIN_POSITIVE,
            1e300,
            -1e300,
            f64::MAX,
            f64::MIN,
            1e15, // just past the integral fast path
            0.1,
            1.0 / 3.0,
            -0.0,
        ] {
            let s = Json::Num(v).to_compact();
            let back = parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{v:?} -> {s}");
            // and the second encode is byte-stable
            assert_eq!(Json::Num(back).to_compact(), s, "re-encode of {v:?}");
        }
    }

    #[test]
    fn f32_logits_roundtrip_bit_exact() {
        // served logits are f32 widened to f64 on the wire
        for v in [0.1f32, -0.0, f32::MIN_POSITIVE, 1e-45, 3.4e38, 1.0 / 3.0] {
            let s = Json::from(v).to_compact();
            let back = parse(&s).unwrap().as_f64().unwrap() as f32;
            assert_eq!(back.to_bits(), v.to_bits(), "{v:?} -> {s}");
        }
    }

    #[test]
    fn overlong_numbers_are_positioned_errors_not_infinities() {
        // regression: Rust parses out-of-range literals to ±inf, which
        // would survive as Json::Num(inf) and re-encode as null
        let long = format!("9{}", "0".repeat(400));
        for src in ["1e999", "-1e999", long.as_str()] {
            let e = parse(src).unwrap_err();
            assert!(e.msg.contains("overflows"), "{src} -> {e}");
            assert!(e.line >= 1 && e.col >= 1, "{src} -> {e}");
        }
        // underflow clamps to zero (finite), which JSON permits
        assert_eq!(parse("1e-999").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn hostile_nesting_errors_instead_of_overflowing() {
        let deep = "[".repeat(100_000);
        let e = parse(&deep).unwrap_err();
        assert!(e.msg.contains("nesting too deep"), "{e}");
        // at the bound, both container kinds still parse
        let ok = format!("{}1{}", "[".repeat(128), "]".repeat(128));
        assert!(parse(&ok).is_ok());
        let over = format!("{}1{}", "[".repeat(129), "]".repeat(129));
        assert!(parse(&over).is_err());
    }
}
