//! The checkpoint artifact layer: framed, digest-named, optionally
//! compressed leaf payloads plus the persistent write pool that fans
//! leaf serialization across worker threads.
//!
//! # Frame format
//!
//! Every stored leaf file wraps an inner payload (an `.npy` byte image,
//! `util/npy.rs`) in a 13-byte frame:
//!
//! ```text
//!   offset  size  field
//!   0       4     magic  "KKA1"
//!   4       1     codec  (0 = raw, 1 = lzss)
//!   5       8     raw payload length, u64 little-endian
//!   13      ..    payload (raw bytes, or the LZSS stream)
//! ```
//!
//! # Content addressing + integrity
//!
//! [`store_leaf`] hashes the **stored** bytes (frame included) with
//! in-tree SHA-256 (`util/sha256.rs`) and writes them to
//! `obj_<digest>.art` via an atomic temp + fsync + rename, so the file
//! for a digest either exists complete or not at all.  The digest is what
//! manifests record, so (a) a load can verify the exact bytes it reads
//! *before* any decompression or `.npy` parsing touches them, and (b) an
//! unchanged leaf re-saved in a later generation hits the existing file
//! and skips the write entirely (dedup; GC then becomes
//! keep-what-the-manifest-references, see `runtime/checkpoint.rs`).
//!
//! # Compression
//!
//! The in-tree codec is byte-oriented LZSS (4 KiB window, 3..=18-byte
//! matches) — modest ratios on float data, but momentum tensors late in
//! training are full of repeated byte patterns (zeros, saturated
//! exponents) and shrink meaningfully, while the frame falls back to raw
//! whenever compression does not pay, so storing can never lose.
//!
//! # The write pool
//!
//! [`WritePool`] owns N persistent worker threads consuming boxed
//! `FnOnce` jobs (each one leaf's encode → compress → hash → write) from
//! a shared queue; [`WritePool::run`] submits a batch and blocks until
//! every job replies, returning results in submission order.  Checkpoint
//! latency then scales with the largest leaf instead of the sum of all
//! leaves.  The pool is deliberately generic over jobs (it lives in
//! `util`, below the engine/runtime layers that use it).

use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::util::sha256::Sha256;
use crate::util::timer::Timer;

/// Stored-frame magic for checkpoint leaf artifacts.
pub const FRAME_MAGIC: &[u8; 4] = b"KKA1";
/// Frame header length (magic + codec byte + raw length).
pub const FRAME_HEADER_LEN: usize = 13;

/// How a frame's payload is encoded on disk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// Payload bytes stored verbatim.
    Raw,
    /// In-tree LZSS (4 KiB window, 3..=18-byte matches).
    Lzss,
}

impl Codec {
    /// Manifest spelling.
    pub fn name(self) -> &'static str {
        match self {
            Codec::Raw => "raw",
            Codec::Lzss => "lzss",
        }
    }

    /// Parse the manifest spelling.
    pub fn parse(name: &str) -> anyhow::Result<Self> {
        match name {
            "raw" => Ok(Codec::Raw),
            "lzss" => Ok(Codec::Lzss),
            other => anyhow::bail!("unknown artifact codec {other:?}"),
        }
    }

    fn tag(self) -> u8 {
        match self {
            Codec::Raw => 0,
            Codec::Lzss => 1,
        }
    }

    fn from_tag(tag: u8) -> anyhow::Result<Self> {
        match tag {
            0 => Ok(Codec::Raw),
            1 => Ok(Codec::Lzss),
            other => anyhow::bail!("unknown artifact codec tag {other}"),
        }
    }
}

// --- LZSS codec -----------------------------------------------------------

const LZ_WINDOW: usize = 4096;
const LZ_MIN_MATCH: usize = 3;
const LZ_MAX_MATCH: usize = 18;

/// Compress `data` with LZSS.  Token stream: one flag byte per 8 tokens
/// (bit set ⇒ literal byte follows; clear ⇒ a 2-byte match: 12-bit
/// backward distance − 1, 4-bit length − 3).
pub fn lzss_compress(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 16);
    // single-slot hash table over 3-byte prefixes: last position seen
    let mut table = vec![usize::MAX; 1 << 13];
    let hash = |a: u8, b: u8, c: u8| -> usize {
        ((a as usize) ^ ((b as usize) << 4) ^ ((c as usize) << 8)) & ((1 << 13) - 1)
    };
    let mut i = 0usize;
    let mut flag_pos = 0usize;
    let mut flag_bit = 8u8; // 8 forces a fresh flag byte on the first token
    let mut push_token = |out: &mut Vec<u8>, literal: Option<u8>, m: Option<(usize, usize)>| {
        if flag_bit == 8 {
            flag_pos = out.len();
            out.push(0);
            flag_bit = 0;
        }
        if let Some(b) = literal {
            out[flag_pos] |= 1 << flag_bit;
            out.push(b);
        } else if let Some((dist, len)) = m {
            let d = (dist - 1) as u16; // 0..4095
            let l = (len - LZ_MIN_MATCH) as u16; // 0..15
            let word = (d << 4) | l;
            out.push((word >> 8) as u8);
            out.push((word & 0xff) as u8);
        }
        flag_bit += 1;
    };
    while i < data.len() {
        let mut best: Option<(usize, usize)> = None;
        if i + LZ_MIN_MATCH <= data.len() {
            let h = hash(data[i], data[i + 1], data[i + 2]);
            let cand = table[h];
            table[h] = i;
            if cand != usize::MAX && cand < i && i - cand <= LZ_WINDOW {
                let max_len = (data.len() - i).min(LZ_MAX_MATCH);
                let mut len = 0usize;
                while len < max_len && data[cand + len] == data[i + len] {
                    len += 1;
                }
                if len >= LZ_MIN_MATCH {
                    best = Some((i - cand, len));
                }
            }
        }
        match best {
            Some((dist, len)) => {
                push_token(&mut out, None, Some((dist, len)));
                // seed the table through the matched span so later
                // occurrences can still find these positions
                let end = i + len;
                i += 1;
                while i < end && i + LZ_MIN_MATCH <= data.len() {
                    table[hash(data[i], data[i + 1], data[i + 2])] = i;
                    i += 1;
                }
                i = end;
            }
            None => {
                push_token(&mut out, Some(data[i]), None);
                i += 1;
            }
        }
    }
    out
}

/// Decompress an LZSS stream produced by [`lzss_compress`] into exactly
/// `raw_len` bytes; any mismatch (truncation, trailing garbage, a
/// distance pointing before the start) is an error, never a panic.
pub fn lzss_decompress(data: &[u8], raw_len: usize) -> anyhow::Result<Vec<u8>> {
    let mut out = Vec::with_capacity(raw_len);
    let mut i = 0usize;
    while out.len() < raw_len {
        anyhow::ensure!(i < data.len(), "lzss stream truncated (flag byte)");
        let flags = data[i];
        i += 1;
        for bit in 0..8 {
            if out.len() == raw_len {
                break;
            }
            if flags & (1 << bit) != 0 {
                anyhow::ensure!(i < data.len(), "lzss stream truncated (literal)");
                out.push(data[i]);
                i += 1;
            } else {
                anyhow::ensure!(i + 1 < data.len(), "lzss stream truncated (match)");
                let word = ((data[i] as u16) << 8) | data[i + 1] as u16;
                i += 2;
                let dist = (word >> 4) as usize + 1;
                let len = (word & 0xf) as usize + LZ_MIN_MATCH;
                anyhow::ensure!(
                    dist <= out.len(),
                    "lzss match distance {dist} exceeds output ({})",
                    out.len()
                );
                anyhow::ensure!(
                    out.len() + len <= raw_len,
                    "lzss match overruns declared raw length"
                );
                let start = out.len() - dist;
                // byte-at-a-time: matches may overlap themselves
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            }
        }
    }
    anyhow::ensure!(i == data.len(), "lzss stream has trailing bytes");
    Ok(out)
}

// --- frame ----------------------------------------------------------------

/// Wrap `raw` payload bytes in a stored frame.  With `try_compress`, the
/// payload is LZSS-compressed and kept only when it actually shrinks;
/// otherwise (and always when `try_compress` is false) the frame stores
/// raw.  Returns the stored bytes, the codec used, and the seconds spent
/// compressing.
pub fn encode_frame(raw: &[u8], try_compress: bool) -> (Vec<u8>, Codec, f64) {
    let (payload, codec, compress_s) = if try_compress {
        let t = Timer::start();
        let packed = lzss_compress(raw);
        let secs = t.elapsed_s();
        if packed.len() < raw.len() {
            (packed, Codec::Lzss, secs)
        } else {
            (raw.to_vec(), Codec::Raw, secs)
        }
    } else {
        (raw.to_vec(), Codec::Raw, 0.0)
    };
    let mut out = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
    out.extend_from_slice(FRAME_MAGIC);
    out.push(codec.tag());
    out.extend_from_slice(&(raw.len() as u64).to_le_bytes());
    out.extend_from_slice(&payload);
    (out, codec, compress_s)
}

/// Unwrap a stored frame back to its raw payload bytes.
pub fn decode_frame(stored: &[u8]) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!(stored.len() >= FRAME_HEADER_LEN, "artifact frame truncated");
    anyhow::ensure!(&stored[..4] == FRAME_MAGIC, "not a checkpoint artifact frame");
    let codec = Codec::from_tag(stored[4])?;
    let mut len8 = [0u8; 8];
    len8.copy_from_slice(&stored[5..13]);
    let raw_len = u64::from_le_bytes(len8) as usize;
    let payload = &stored[FRAME_HEADER_LEN..];
    match codec {
        Codec::Raw => {
            anyhow::ensure!(payload.len() == raw_len, "raw frame length mismatch");
            Ok(payload.to_vec())
        }
        Codec::Lzss => lzss_decompress(payload, raw_len),
    }
}

// --- content-addressed store ----------------------------------------------

/// File name for a stored leaf with this digest.
pub fn object_file(digest: &str) -> String {
    format!("obj_{digest}.art")
}

/// Whether a directory entry is a content-addressed leaf object
/// (`obj_<64 hex>.art`).
pub fn is_object_file(name: &str) -> bool {
    name.len() == 4 + 64 + 4
        && name.starts_with("obj_")
        && name.ends_with(".art")
        && name[4..4 + 64].bytes().all(|b| b.is_ascii_hexdigit() && !b.is_ascii_uppercase())
}

/// One stored leaf's metadata + timing, as [`store_leaf`] returns it and
/// the checkpoint manifest records it.
#[derive(Clone, Debug)]
pub struct LeafMeta {
    /// SHA-256 of the stored file bytes, 64 lowercase hex chars.
    pub digest: String,
    /// Stored file name (`obj_<digest>.art`).
    pub file: String,
    /// Codec the frame actually used (compression may fall back to raw).
    pub codec: Codec,
    /// Bytes of the stored file (frame + payload).
    pub stored_bytes: usize,
    /// Bytes of the raw (uncompressed) payload.
    pub raw_bytes: usize,
    /// True when an identical object already existed and the write was
    /// skipped (content-address hit from a previous generation).
    pub deduped: bool,
    /// Seconds spent writing + fsyncing (0 when deduped).
    pub write_s: f64,
    /// Seconds spent hashing the stored bytes.
    pub hash_s: f64,
    /// Seconds spent compressing (0 for raw-only frames).
    pub compress_s: f64,
}

/// Serialize one leaf into the content-addressed store at `dir`:
/// frame (+ optional compression) → hash → atomic write, skipping the
/// write when `obj_<digest>.art` already exists (identical content by
/// construction — the digest covers every stored byte, and objects are
/// only ever published complete via temp + rename).
pub fn store_leaf(dir: &Path, raw: &[u8], try_compress: bool) -> anyhow::Result<LeafMeta> {
    let (stored, codec, compress_s) = encode_frame(raw, try_compress);
    let t = Timer::start();
    let mut h = Sha256::new();
    h.update(&stored);
    let digest = h.finalize_hex();
    let hash_s = t.elapsed_s();
    let file = object_file(&digest);
    let path = dir.join(&file);
    let mut meta = LeafMeta {
        digest,
        file,
        codec,
        stored_bytes: stored.len(),
        raw_bytes: raw.len(),
        deduped: false,
        write_s: 0.0,
        hash_s,
        compress_s,
    };
    if path.exists() {
        meta.deduped = true;
        return Ok(meta);
    }
    let t = Timer::start();
    crate::util::fsutil::write_atomic_bytes(&path, &stored)?;
    meta.write_s = t.elapsed_s();
    Ok(meta)
}

/// Read one leaf back from the store.  With `verify`, the stored bytes
/// are re-hashed and must match `digest` — corruption surfaces here as a
/// typed error *before* any decompression or payload parsing runs.
/// Returns the raw payload bytes.
pub fn load_leaf(dir: &Path, digest: &str, verify: bool) -> anyhow::Result<Vec<u8>> {
    let path = dir.join(object_file(digest));
    let stored = std::fs::read(&path)?;
    if verify {
        let mut h = Sha256::new();
        h.update(&stored);
        let actual = h.finalize_hex();
        anyhow::ensure!(
            actual == digest,
            "sha256 mismatch for {path:?}: manifest records {digest}, stored bytes hash to {actual}"
        );
    }
    decode_frame(&stored)
}

// --- aggregate write statistics -------------------------------------------

/// Aggregate timing + volume for one checkpoint save, folded from every
/// leaf's [`LeafMeta`].  Rides the service lane's fold-in event into the
/// epoch record and the bench checkpoint-write table.
#[derive(Clone, Copy, Debug, Default)]
pub struct WriteStats {
    /// Leaves serialized (params + momentum).
    pub leaves: usize,
    /// Stored bytes actually written (deduped leaves excluded).
    pub written_bytes: usize,
    /// Raw (uncompressed) payload bytes across all leaves.
    pub raw_bytes: usize,
    /// Leaves skipped because an identical object already existed.
    pub deduped: usize,
    /// Total seconds in write + fsync across leaves (sum over workers).
    pub write_s: f64,
    /// Total seconds hashing stored bytes across leaves.
    pub hash_s: f64,
    /// Total seconds compressing across leaves.
    pub compress_s: f64,
}

impl WriteStats {
    /// Fold one leaf's metadata in.
    pub fn absorb(&mut self, m: &LeafMeta) {
        self.leaves += 1;
        self.raw_bytes += m.raw_bytes;
        if m.deduped {
            self.deduped += 1;
        } else {
            self.written_bytes += m.stored_bytes;
        }
        self.write_s += m.write_s;
        self.hash_s += m.hash_s;
        self.compress_s += m.compress_s;
    }

    /// Fold another aggregate in (multi-save accumulation).
    pub fn merge(&mut self, o: &WriteStats) {
        self.leaves += o.leaves;
        self.written_bytes += o.written_bytes;
        self.raw_bytes += o.raw_bytes;
        self.deduped += o.deduped;
        self.write_s += o.write_s;
        self.hash_s += o.hash_s;
        self.compress_s += o.compress_s;
    }
}

// --- the persistent write pool --------------------------------------------

/// One leaf-serialization job: runs on a pool worker, returns the stored
/// leaf's metadata.  Jobs are `'static` — callers capture shared payload
/// data by `Arc` (e.g. a [`crate::engine`] `SharedSnapshot`).
pub type WriteJob = Box<dyn FnOnce() -> anyhow::Result<LeafMeta> + Send + 'static>;

/// A persistent pool of leaf-write workers.  Construct once (per
/// checkpoint lane / trainer), [`WritePool::run`] per save: the batch
/// fans out across the workers and `run` blocks until every job has
/// replied, preserving submission order in the returned vector.  With
/// `threads <= 1` no threads are spawned and jobs run inline on the
/// caller (the serial reference the bench table compares against).
pub struct WritePool {
    job_tx: Option<Sender<(usize, WriteJob)>>,
    done_rx: Option<Receiver<(usize, anyhow::Result<LeafMeta>)>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl WritePool {
    /// A pool with `threads` persistent workers (`0` = one per available
    /// CPU, `1` = inline serial execution, no threads).
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            crate::util::threadpool::default_threads()
        } else {
            threads
        };
        if threads <= 1 {
            return WritePool { job_tx: None, done_rx: None, handles: Vec::new(), threads: 1 };
        }
        let (job_tx, job_rx) = channel::<(usize, WriteJob)>();
        let (done_tx, done_rx) = channel::<(usize, anyhow::Result<LeafMeta>)>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let mut handles = Vec::with_capacity(threads);
        for w in 0..threads {
            let job_rx = job_rx.clone();
            let done_tx = done_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ckpt-write-{w}"))
                .spawn(move || loop {
                    // hold the lock only for the dequeue, not the job
                    let job = match job_rx.lock() {
                        Ok(rx) => rx.recv(),
                        Err(_) => break,
                    };
                    let Ok((idx, job)) = job else { break };
                    if done_tx.send((idx, job())).is_err() {
                        break;
                    }
                })
                .expect("spawn checkpoint write worker");
            handles.push(handle);
        }
        WritePool { job_tx: Some(job_tx), done_rx: Some(done_rx), handles, threads }
    }

    /// Serial pool (no worker threads; jobs run inline).
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Worker count (1 for the inline serial pool).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run a batch of jobs to completion; results come back in submission
    /// order.  The first job error is returned — after every outstanding
    /// reply has been collected, so the pool stays consistent for the
    /// next batch even when a job fails.
    pub fn run(&self, jobs: Vec<WriteJob>) -> anyhow::Result<Vec<LeafMeta>> {
        let n = jobs.len();
        let (Some(job_tx), Some(done_rx)) = (&self.job_tx, &self.done_rx) else {
            // inline serial execution
            return jobs.into_iter().map(|job| job()).collect();
        };
        for (idx, job) in jobs.into_iter().enumerate() {
            job_tx
                .send((idx, job))
                .map_err(|_| anyhow::anyhow!("checkpoint write pool died"))?;
        }
        let mut slots: Vec<Option<anyhow::Result<LeafMeta>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (idx, res) = done_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("checkpoint write pool died mid-batch"))?;
            slots[idx] = Some(res);
        }
        slots
            .into_iter()
            .map(|s| s.expect("every job replied exactly once"))
            .collect()
    }
}

impl Drop for WritePool {
    fn drop(&mut self) {
        drop(self.job_tx.take()); // disconnect: workers' recv fails and they exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("kakurenbo_artifact_{name}_{}", std::process::id()))
    }

    #[test]
    fn lzss_roundtrips() {
        let cases: Vec<Vec<u8>> = vec![
            vec![],
            b"a".to_vec(),
            b"abcabcabcabcabcabc".to_vec(),
            vec![0u8; 10_000],
            (0..5000u32).map(|i| (i % 7) as u8).collect(),
            (0..5000u32).map(|i| (i * 2654435761u32.wrapping_mul(i)) as u8).collect(),
        ];
        for data in cases {
            let packed = lzss_compress(&data);
            let back = lzss_decompress(&packed, data.len()).unwrap();
            assert_eq!(back, data);
        }
    }

    #[test]
    fn lzss_compresses_repetitive_data() {
        let data = vec![0u8; 4096];
        let packed = lzss_compress(&data);
        assert!(packed.len() < data.len() / 4, "{} bytes", packed.len());
    }

    #[test]
    fn lzss_rejects_corrupt_streams() {
        let data: Vec<u8> = (0..200u8).collect();
        let packed = lzss_compress(&data);
        // truncation
        assert!(lzss_decompress(&packed[..packed.len() - 1], data.len()).is_err());
        // wrong declared length
        assert!(lzss_decompress(&packed, data.len() + 1).is_err());
    }

    #[test]
    fn frame_roundtrips_both_codecs() {
        let compressible = vec![7u8; 9000];
        let (stored, codec, _) = encode_frame(&compressible, true);
        assert_eq!(codec, Codec::Lzss);
        assert!(stored.len() < compressible.len());
        assert_eq!(decode_frame(&stored).unwrap(), compressible);

        let (stored, codec, _) = encode_frame(&compressible, false);
        assert_eq!(codec, Codec::Raw);
        assert_eq!(decode_frame(&stored).unwrap(), compressible);
    }

    #[test]
    fn incompressible_data_falls_back_to_raw() {
        // a pseudo-random byte soup LZSS cannot shrink
        let noise: Vec<u8> = (0..4096u64)
            .map(|i| (i.wrapping_mul(0x9e3779b97f4a7c15) >> 33) as u8)
            .collect();
        let (stored, codec, _) = encode_frame(&noise, true);
        assert_eq!(codec, Codec::Raw);
        assert_eq!(decode_frame(&stored).unwrap(), noise);
    }

    #[test]
    fn object_file_pattern() {
        let d = "a".repeat(64);
        assert!(is_object_file(&object_file(&d)));
        assert!(!is_object_file("obj_short.art"));
        assert!(!is_object_file("p000_fc1_w.e7.npy"));
        assert!(!is_object_file(&format!("obj_{}.art.tmp", d)));
        let upper = format!("obj_{}.art", "A".repeat(64));
        assert!(!is_object_file(&upper));
    }

    #[test]
    fn store_load_roundtrip_and_dedup() {
        let dir = tmp("store");
        std::fs::create_dir_all(&dir).unwrap();
        let raw = vec![3u8; 5000];
        let m1 = store_leaf(&dir, &raw, true).unwrap();
        assert!(!m1.deduped);
        assert_eq!(m1.codec, Codec::Lzss);
        // identical content dedups against the existing object
        let m2 = store_leaf(&dir, &raw, true).unwrap();
        assert!(m2.deduped);
        assert_eq!(m2.digest, m1.digest);
        assert_eq!(load_leaf(&dir, &m1.digest, true).unwrap(), raw);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn verify_catches_a_flipped_byte() {
        let dir = tmp("verify");
        std::fs::create_dir_all(&dir).unwrap();
        let raw: Vec<u8> = (0..3000u32).map(|i| (i % 251) as u8).collect();
        let m = store_leaf(&dir, &raw, false).unwrap();
        let path = dir.join(&m.file);
        let mut bytes = std::fs::read(&path).unwrap();
        let at = bytes.len() - 10;
        bytes[at] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_leaf(&dir, &m.digest, true).unwrap_err().to_string();
        assert!(err.contains("sha256 mismatch"), "{err}");
        // verification off: bytes load (differently) without the check
        let loaded = load_leaf(&dir, &m.digest, false).unwrap();
        assert_ne!(loaded, raw);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pool_preserves_submission_order() {
        for threads in [1usize, 4] {
            let pool = WritePool::new(threads);
            let jobs: Vec<WriteJob> = (0..16usize)
                .map(|i| {
                    Box::new(move || {
                        Ok(LeafMeta {
                            digest: format!("{i}"),
                            file: String::new(),
                            codec: Codec::Raw,
                            stored_bytes: i,
                            raw_bytes: i,
                            deduped: false,
                            write_s: 0.0,
                            hash_s: 0.0,
                            compress_s: 0.0,
                        })
                    }) as WriteJob
                })
                .collect();
            let out = pool.run(jobs).unwrap();
            let order: Vec<usize> = out.iter().map(|m| m.stored_bytes).collect();
            assert_eq!(order, (0..16).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn pool_surfaces_job_errors_and_stays_usable() {
        let pool = WritePool::new(2);
        let jobs: Vec<WriteJob> = vec![
            Box::new(|| anyhow::bail!("leaf 0 exploded")),
            Box::new(|| {
                Ok(LeafMeta {
                    digest: String::new(),
                    file: String::new(),
                    codec: Codec::Raw,
                    stored_bytes: 0,
                    raw_bytes: 0,
                    deduped: false,
                    write_s: 0.0,
                    hash_s: 0.0,
                    compress_s: 0.0,
                })
            }),
        ];
        let err = pool.run(jobs).unwrap_err().to_string();
        assert!(err.contains("leaf 0 exploded"), "{err}");
        // a failed batch must not wedge the pool for the next one
        let ok: Vec<WriteJob> = vec![Box::new(|| {
            Ok(LeafMeta {
                digest: "ok".into(),
                file: String::new(),
                codec: Codec::Raw,
                stored_bytes: 1,
                raw_bytes: 1,
                deduped: false,
                write_s: 0.0,
                hash_s: 0.0,
                compress_s: 0.0,
            })
        })];
        assert_eq!(pool.run(ok).unwrap()[0].digest, "ok");
    }

    #[test]
    fn stats_fold_leaves_and_dedup() {
        let mut s = WriteStats::default();
        s.absorb(&LeafMeta {
            digest: String::new(),
            file: String::new(),
            codec: Codec::Raw,
            stored_bytes: 100,
            raw_bytes: 90,
            deduped: false,
            write_s: 0.5,
            hash_s: 0.25,
            compress_s: 0.0,
        });
        s.absorb(&LeafMeta {
            digest: String::new(),
            file: String::new(),
            codec: Codec::Lzss,
            stored_bytes: 40,
            raw_bytes: 90,
            deduped: true,
            write_s: 0.0,
            hash_s: 0.25,
            compress_s: 0.1,
        });
        assert_eq!(s.leaves, 2);
        assert_eq!(s.written_bytes, 100); // deduped leaf not counted
        assert_eq!(s.raw_bytes, 180);
        assert_eq!(s.deduped, 1);
        assert!((s.write_s - 0.5).abs() < 1e-12);
        assert!((s.hash_s - 0.5).abs() < 1e-12);
    }
}
