//! Terminal line charts for convergence curves (Fig. 2/3 style output):
//! multiple named series rendered onto an ASCII canvas with axes.

/// One named line series for [`line_chart`].
pub struct Series<'a> {
    /// Legend label.
    pub name: &'a str,
    /// X coordinates (same length as `ys`).
    pub xs: &'a [f64],
    /// Y coordinates.
    pub ys: &'a [f64],
}

/// Render series onto a width x height canvas; x/y ranges auto-fit.
/// Each series gets a distinct glyph; a legend line follows the chart.
pub fn line_chart(title: &str, series: &[Series], width: usize, height: usize) -> String {
    const GLYPHS: [char; 8] = ['*', 'o', '+', 'x', '#', '@', '%', '&'];
    let finite = |v: f64| v.is_finite();
    let mut xmin = f64::INFINITY;
    let mut xmax = f64::NEG_INFINITY;
    let mut ymin = f64::INFINITY;
    let mut ymax = f64::NEG_INFINITY;
    for s in series {
        for (&x, &y) in s.xs.iter().zip(s.ys) {
            if finite(x) && finite(y) {
                xmin = xmin.min(x);
                xmax = xmax.max(x);
                ymin = ymin.min(y);
                ymax = ymax.max(y);
            }
        }
    }
    if !xmin.is_finite() || xmax <= xmin {
        return format!("== {title} == (no data)\n");
    }
    if ymax <= ymin {
        ymax = ymin + 1.0;
    }
    let mut canvas = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let glyph = GLYPHS[si % GLYPHS.len()];
        for (&x, &y) in s.xs.iter().zip(s.ys) {
            if !finite(x) || !finite(y) {
                continue;
            }
            let cx = ((x - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let cy = ((y - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            canvas[row][cx.min(width - 1)] = glyph;
        }
    }
    let mut out = format!("== {title} ==\n");
    for (i, row) in canvas.iter().enumerate() {
        let label = if i == 0 {
            format!("{ymax:8.3} |")
        } else if i == height - 1 {
            format!("{ymin:8.3} |")
        } else {
            "         |".to_string()
        };
        out.push_str(&label);
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!(
        "          +{}\n           {:<10.3}{:>width$.3}\n",
        "-".repeat(width),
        xmin,
        xmax,
        width = width - 10
    ));
    out.push_str("  legend: ");
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("{}={} ", GLYPHS[si % GLYPHS.len()], s.name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_points_within_bounds() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| (x / 5.0).sin()).collect();
        let s = line_chart("t", &[Series { name: "sin", xs: &xs, ys: &ys }], 40, 10);
        assert!(s.contains("legend: *=sin"));
        assert!(s.lines().count() >= 12);
        assert!(s.contains('*'));
    }

    #[test]
    fn empty_series_no_panic() {
        let s = line_chart("t", &[Series { name: "e", xs: &[], ys: &[] }], 20, 5);
        assert!(s.contains("no data"));
    }

    #[test]
    fn multiple_series_distinct_glyphs() {
        let xs = [0.0, 1.0, 2.0];
        let y1 = [0.0, 1.0, 2.0];
        let y2 = [2.0, 1.0, 0.0];
        let s = line_chart(
            "t",
            &[
                Series { name: "a", xs: &xs, ys: &y1 },
                Series { name: "b", xs: &xs, ys: &y2 },
            ],
            20,
            8,
        );
        assert!(s.contains('*') && s.contains('o'));
    }

    #[test]
    fn nan_points_skipped() {
        let xs = [0.0, 1.0, 2.0];
        let ys = [0.5, f64::NAN, 1.5];
        let s = line_chart("t", &[Series { name: "a", xs: &xs, ys: &ys }], 20, 6);
        assert!(s.contains('*'));
    }
}
