//! Deterministic, seedable PRNG — xoshiro256** with SplitMix64 seeding.
//!
//! The offline build has no `rand` crate; every stochastic component of the
//! coordinator (shuffling, sampling, synthetic data generation, property
//! tests) draws from this generator so whole experiments replay bit-exactly
//! from a single `--seed`.

/// xoshiro256** 1.0 (Blackman & Vigna, public domain reference).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed a generator (SplitMix64-expanded, so nearby seeds diverge).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-worker / per-component RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Snapshot the generator state (checkpoint / exact resume).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot; the restored
    /// stream continues bit-exactly where the snapshot was taken.
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    /// The next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller (cached second value).
    pub fn normal(&mut self) -> f64 {
        // Marsaglia polar method: no trig, numerically tame.
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal draw with the given mean and standard deviation, as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices drawn uniformly from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n);
        let mut idx: Vec<u32> = (0..n as u32).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Draw from a categorical distribution given (unnormalized) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_continues_the_stream() {
        let mut a = Rng::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.06, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let ks = r.sample_indices(50, 20);
        assert_eq!(ks.len(), 20);
        let mut s = ks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.5, "ratio={ratio}");
    }
}
