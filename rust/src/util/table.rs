//! Paper-style ASCII table rendering for the bench harness output.
//!
//! Every `bench_*` binary prints its reproduction of a paper table through
//! this module so rows line up and can be diffed against EXPERIMENTS.md.

/// A titled ASCII table accumulated row by row.
#[derive(Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with a title line.
    pub fn new(title: impl Into<String>) -> Self {
        Table { title: title.into(), ..Default::default() }
    }

    /// Set the header row (builder style).
    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    /// Append one data row.
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        self.rows.push(cells);
        self
    }

    /// Render to a string with aligned columns and separators.
    pub fn render(&self) -> String {
        let ncols = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for i in 0..ncols {
                let c = cells.get(i).map(String::as_str).unwrap_or("");
                s.push_str(&format!("| {:width$} ", c, width = widths[i]));
            }
            s.push('|');
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&sep);
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Accuracy as a percent with two decimals (paper style).
pub fn pct(x: f64) -> String {
    format!("{:.2}", x * 100.0)
}

/// Signed accuracy delta vs a baseline, in parens (paper style).
pub fn diff_pct(x: f64, baseline: f64) -> String {
    let d = (x - baseline) * 100.0;
    if d >= 0.0 {
        format!("(+{d:.2})")
    } else {
        format!("({d:.2})")
    }
}

/// Seconds with one decimal.
pub fn secs(x: f64) -> String {
    format!("{x:.1}")
}

/// Signed relative-time delta vs a baseline, in parens (paper style).
pub fn speedup_pct(time: f64, baseline: f64) -> String {
    let d = (time / baseline - 1.0) * 100.0;
    if d >= 0.0 {
        format!("(+{d:.1}%)")
    } else {
        format!("({d:.1}%)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T").header(&["Setting", "Acc."]);
        t.row(vec!["Baseline".into(), "77.49".into()]);
        t.row(vec!["KAKURENBO".into(), "77.21".into()]);
        let s = t.render();
        assert!(s.contains("| Baseline  |"));
        assert!(s.lines().all(|l| l.len() <= 40));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(pct(0.7749), "77.49");
        assert_eq!(diff_pct(0.7721, 0.7749), "(-0.28)");
        assert_eq!(speedup_pct(78.3, 100.0), "(-21.7%)");
    }
}
