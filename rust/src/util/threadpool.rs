//! Scoped data-parallel helpers (no rayon offline).
//!
//! `parallel_for_chunks` splits an index range across threads with
//! `std::thread::scope`.  On this image (1 core) it degrades to the serial
//! path automatically; on multi-core hosts the synthetic dataset
//! generation, full-dataset stat refreshes, and sorting shards fan out.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use for host-side parallel sections.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_start, chunk_end)` over disjoint chunks of `0..n` on up to
/// `threads` OS threads.  Falls back to a single call when threads == 1 or
/// the range is small.
pub fn parallel_for_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads == 1 || n < 1024 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let f = &f;
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo < hi {
                scope.spawn(move || f(lo, hi));
            }
        }
    });
}

/// Parallel map over `0..n` producing a `Vec<T>`, preserving order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_for_chunks(n, threads, move |lo, hi| {
        // Force whole-struct capture (edition-2021 disjoint capture would
        // otherwise grab the raw pointer field, which is !Sync).
        let p = out_ptr;
        // SAFETY: chunks are disjoint; each index is written exactly once.
        for i in lo..hi {
            unsafe { *p.0.add(i) = f(i) };
        }
    });
    out
}

struct SendPtr<T>(*mut T);
// Manual Clone/Copy: derive would wrongly require T: Copy.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Atomic work-stealing-ish dynamic scheduler for irregular tasks.
pub fn parallel_for_dynamic<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let f = &f;
            let next = &next;
            scope.spawn(move || loop {
                let lo = next.fetch_add(grain, Ordering::Relaxed);
                if lo >= n {
                    break;
                }
                for i in lo..(lo + grain).min(n) {
                    f(i);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_range_exactly() {
        let sum = AtomicU64::new(0);
        parallel_for_chunks(10_000, 4, |lo, hi| {
            let mut s = 0u64;
            for i in lo..hi {
                s += i as u64;
            }
            sum.fetch_add(s, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10_000 * 9_999 / 2);
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(5000, 4, |i| i * 2);
        assert!(v.iter().enumerate().all(|(i, &x)| x == i * 2));
    }

    #[test]
    fn dynamic_visits_all() {
        let count = AtomicU64::new(0);
        parallel_for_dynamic(3000, 3, 64, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 3000);
    }
}
