//! Per-epoch metric records + run results, with JSON export — the raw
//! material every bench table/figure is rendered from.

use std::path::Path;

use crate::util::artifact::WriteStats;
use crate::util::json::Json;
use crate::util::stats::Histogram;

/// Everything measured in one epoch (paper Figs. 2, 4-8 are projections
/// of these fields over epochs).
#[derive(Clone, Debug, Default)]
pub struct EpochRecord {
    /// The epoch index this record describes.
    pub epoch: usize,
    /// Base LR after scheduler, before KAKURENBO scaling.
    pub base_lr: f64,
    /// Actual LR used (includes 1/(1-F) adjustment).
    pub lr: f64,
    /// Maximum hidden fraction ceiling F_e for the epoch.
    pub fraction_ceiling: f64,
    /// Hide candidates before move-back (Fig. 8 "max hidden").
    pub max_hidden: usize,
    /// Actually hidden samples (Fig. 8 "hidden").
    pub hidden: usize,
    /// Hidden in this *and* the previous epoch (Fig. 8 "hidden again").
    pub hidden_again: usize,
    /// Candidates returned to training by the MB rule.
    pub moved_back: usize,
    /// Samples trained on (SGD steps × batch ≈ this).
    pub trained_samples: usize,
    /// Backward passes actually executed (differs from trained for SB).
    pub backprop_samples: usize,
    /// Mean training loss over the epoch's training passes.
    pub train_loss: f64,
    /// Validation top-1 accuracy (NaN when not evaluated this epoch).
    pub val_acc: f64,
    /// Mean validation loss (0 when not evaluated this epoch).
    pub val_loss: f64,
    /// Measured wall-clock seconds: select + train + refresh (the
    /// paper's epoch timing; excludes eval/checkpoint).
    pub time_total: f64,
    /// Seconds in the training pass.
    pub time_train: f64,
    /// Seconds in strategy selection (the Plan phase).
    pub time_select: f64,
    /// Seconds in the hidden-list stat refresh.
    pub time_refresh: f64,
    /// Seconds the Eval phase spent on the critical path (snapshot
    /// export + submit when the service lane is on; the full forward
    /// sweep when off; 0 on epochs without an eval).
    pub time_eval: f64,
    /// Seconds the worker pool's reduction loop spent blocked on gather
    /// lanes / the step barrier during the *training* pass (0 for
    /// single-stream epochs).
    pub time_barrier: f64,
    /// Seconds the hidden-refresh pass spent blocked on gather lanes (its
    /// own stall, no longer conflated into `time_barrier`; 0 when the
    /// refresh ran single-stream).
    pub time_refresh_stall: f64,
    /// Seconds the checkpoint phase spent on the critical path (snapshot
    /// export + submit when the service lane is on; full serialization
    /// when off; 0 on epochs without a checkpoint).
    pub time_checkpoint: f64,
    /// Seconds the async service lane spent on this epoch's jobs (eval
    /// forward passes, checkpoint serialization) — work overlapped with
    /// the next epoch's training, *not* part of `time_total`.
    pub time_service: f64,
    /// Parameter-averaging reductions performed this epoch (only when the
    /// `--dp average` schedule trained the epoch; 0 otherwise).
    pub dp_syncs: usize,
    /// Measured seconds finalizing + broadcasting the averaged parameters
    /// across those reductions (the host-side allreduce cost).
    pub time_average: f64,
    /// Modeled paper-scale allreduce seconds for the same reductions
    /// (cost-model projection of the averaging overhead at W workers).
    pub modeled_sync: f64,
    /// Per-worker executed sample counts when the epoch ran through the
    /// worker pool (empty for single-stream epochs).
    pub worker_samples: Vec<usize>,
    /// Modeled epoch seconds at paper scale (cost model, W workers).
    pub modeled_time: f64,
    /// Per-class hidden counts (only when detailed_metrics).
    pub hidden_per_class: Vec<usize>,
    /// Loss histogram over the full dataset (only when detailed_metrics).
    pub loss_hist: Option<Histogram>,
    /// Checkpoint leaves serialized this epoch (params + momentum; 0 on
    /// epochs without a checkpoint).
    pub ckpt_leaves: usize,
    /// Bytes actually written to the checkpoint store (post-compression,
    /// deduplicated leaves excluded).
    pub ckpt_bytes: usize,
    /// Leaves skipped because an identical digest already existed in the
    /// content-addressed store.
    pub ckpt_deduped: usize,
    /// Seconds spent in checkpoint leaf file writes (summed across pool
    /// workers, so this can exceed wall-clock).
    pub ckpt_write_s: f64,
    /// Seconds spent hashing checkpoint leaves (sha256, summed across
    /// pool workers).
    pub ckpt_hash_s: f64,
    /// Seconds spent compressing checkpoint leaves (LZSS, summed across
    /// pool workers).
    pub ckpt_compress_s: f64,
    /// Worker-pool lanes retired mid-epoch after a death or straggler
    /// timeout (elastic fault policy; 0 on undisturbed epochs).
    pub lanes_dropped: usize,
    /// Recovery lanes brought up to adopt the retired lanes' remaining
    /// shard slices (elastic fault policy).
    pub lanes_rejoined: usize,
    /// Seconds spent standing up those recovery lanes (the elastic
    /// re-issue latency).
    pub time_reissue: f64,
    /// Service-lane job failures folded into this epoch under the
    /// elastic fault policy (eval, checkpoint, or serve lane; under the
    /// fail policy the first such failure aborts the run instead).
    pub service_errors: usize,
    /// Snapshot publications to the inference lane's hub this epoch
    /// (1 when `--serve` is on, 0 otherwise).
    pub serve_publishes: usize,
    /// Inference queries the serve fleet answered since the previous
    /// epoch barrier (0 when `--serve` is off or no clients queried).
    pub serve_queries: usize,
    /// Batched device forwards the serve fleet dispatched since the
    /// previous epoch barrier — with `--serve-batch N > 1` coalescing,
    /// several queries share one forward, so this is ≤ `serve_queries`.
    pub serve_batches: usize,
    /// Mean queries per dispatched serve batch this epoch
    /// (`serve_queries / serve_batches`; 0 when nothing was served).
    /// 1.0 means no coalescing happened, > 1 means queries shared
    /// device forwards.
    pub serve_batch_fill: f64,
    /// Per-lane answered-query counts this epoch (index = serve lane
    /// id; empty when `--serve` is off or no clients queried) — shows
    /// how evenly the fleet's least-loaded routing spread the traffic.
    pub serve_lane_queries: Vec<usize>,
    /// Seconds spent exporting + publishing this epoch's snapshot to the
    /// hub (0 when the publication reused the epoch's cached export).
    pub time_publish: f64,
    /// Epochs the feature cache's rows lagged this epoch's plan (PFB:
    /// 0 on harvest-plan epochs and for strategies without a cache).
    pub feature_cache_age: usize,
    /// Seconds the Refresh phase spent in the embedding harvest sweep
    /// that refilled the feature cache (0 on cache-reuse epochs — the
    /// zero-extra-forwards epochs PFB amortizes its scoring into).
    pub time_feature_refresh: f64,
    /// Samples this epoch's plan excluded *before* any forward pass ran
    /// on them (PFB's cached-feature pruning; 0 for loss-based hiding).
    pub pruned_pre_forward: usize,
}

impl EpochRecord {
    /// Fold a checkpoint write's [`WriteStats`] into the record (called
    /// both on the sync path and when an async service-lane checkpoint
    /// report folds back in).
    pub fn fold_ckpt_stats(&mut self, s: &WriteStats) {
        self.ckpt_leaves += s.leaves;
        self.ckpt_bytes += s.written_bytes;
        self.ckpt_deduped += s.deduped;
        self.ckpt_write_s += s.write_s;
        self.ckpt_hash_s += s.hash_s;
        self.ckpt_compress_s += s.compress_s;
    }

    /// Serialize every scalar field (plus the optional per-class /
    /// histogram extras) for `results/*.json`.
    pub fn to_json(&self) -> Json {
        let mut o = crate::jobj![
            ("epoch", self.epoch),
            ("base_lr", self.base_lr),
            ("lr", self.lr),
            ("fraction_ceiling", self.fraction_ceiling),
            ("max_hidden", self.max_hidden),
            ("hidden", self.hidden),
            ("hidden_again", self.hidden_again),
            ("moved_back", self.moved_back),
            ("trained_samples", self.trained_samples),
            ("backprop_samples", self.backprop_samples),
            ("train_loss", self.train_loss),
            ("val_acc", self.val_acc),
            ("val_loss", self.val_loss),
            ("time_total", self.time_total),
            ("time_train", self.time_train),
            ("time_select", self.time_select),
            ("time_refresh", self.time_refresh),
            ("time_eval", self.time_eval),
            ("time_barrier", self.time_barrier),
            ("time_refresh_stall", self.time_refresh_stall),
            ("time_checkpoint", self.time_checkpoint),
            ("time_service", self.time_service),
            ("dp_syncs", self.dp_syncs),
            ("time_average", self.time_average),
            ("modeled_sync", self.modeled_sync),
            ("modeled_time", self.modeled_time),
            ("ckpt_leaves", self.ckpt_leaves),
            ("ckpt_bytes", self.ckpt_bytes),
            ("ckpt_deduped", self.ckpt_deduped),
            ("ckpt_write_s", self.ckpt_write_s),
            ("ckpt_hash_s", self.ckpt_hash_s),
            ("ckpt_compress_s", self.ckpt_compress_s),
            ("lanes_dropped", self.lanes_dropped),
            ("lanes_rejoined", self.lanes_rejoined),
            ("time_reissue", self.time_reissue),
            ("service_errors", self.service_errors),
            ("serve_publishes", self.serve_publishes),
            ("serve_queries", self.serve_queries),
            ("serve_batches", self.serve_batches),
            ("serve_batch_fill", self.serve_batch_fill),
            ("time_publish", self.time_publish),
            ("feature_cache_age", self.feature_cache_age),
            ("time_feature_refresh", self.time_feature_refresh),
            ("pruned_pre_forward", self.pruned_pre_forward),
        ];
        if let Json::Obj(m) = &mut o {
            if !self.worker_samples.is_empty() {
                m.insert(
                    "worker_samples".into(),
                    Json::from(self.worker_samples.clone()),
                );
            }
            if !self.serve_lane_queries.is_empty() {
                m.insert(
                    "serve_lane_queries".into(),
                    Json::from(self.serve_lane_queries.clone()),
                );
            }
            if !self.hidden_per_class.is_empty() {
                m.insert(
                    "hidden_per_class".into(),
                    Json::from(self.hidden_per_class.clone()),
                );
            }
            if let Some(h) = &self.loss_hist {
                m.insert(
                    "loss_hist".into(),
                    crate::jobj![
                        ("lo", h.lo),
                        ("hi", h.hi),
                        (
                            "counts",
                            h.counts.iter().map(|&c| c as usize).collect::<Vec<_>>()
                        )
                    ],
                );
            }
        }
        o
    }
}

/// A complete training run.
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    /// Experiment name the run was filed under.
    pub name: String,
    /// Strategy display name.
    pub strategy: String,
    /// Per-epoch records in epoch order.
    pub records: Vec<EpochRecord>,
    /// Validation accuracy at the last evaluated epoch.
    pub final_acc: f64,
    /// Best validation accuracy across the run.
    pub best_acc: f64,
    /// Sum of measured epoch seconds (`time_total`).
    pub total_time: f64,
    /// Sum of modeled paper-scale epoch seconds.
    pub total_modeled_time: f64,
}

impl RunResult {
    /// Roll per-epoch records up into a run result (final/best accuracy
    /// ignore NaN not-evaluated epochs).
    pub fn from_records(name: &str, strategy: &str, records: Vec<EpochRecord>) -> Self {
        let evals: Vec<f64> = records
            .iter()
            .map(|r| r.val_acc)
            .filter(|a| a.is_finite())
            .collect();
        RunResult {
            name: name.to_string(),
            strategy: strategy.to_string(),
            final_acc: evals.last().copied().unwrap_or(f64::NAN),
            best_acc: evals.iter().copied().fold(f64::NAN, f64::max),
            total_time: records.iter().map(|r| r.time_total).sum(),
            total_modeled_time: records.iter().map(|r| r.modeled_time).sum(),
            records,
        }
    }

    /// First wall-clock second at which validation accuracy reached
    /// `target` (time-to-accuracy, Fig. 2's "speedup" metric);
    /// None if never reached.
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        let mut elapsed = 0.0;
        for r in &self.records {
            elapsed += r.time_total;
            if r.val_acc.is_finite() && r.val_acc >= target {
                return Some(elapsed);
            }
        }
        None
    }

    /// Same in modeled (paper-scale) time.
    pub fn modeled_time_to_accuracy(&self, target: f64) -> Option<f64> {
        let mut elapsed = 0.0;
        for r in &self.records {
            elapsed += r.modeled_time;
            if r.val_acc.is_finite() && r.val_acc >= target {
                return Some(elapsed);
            }
        }
        None
    }

    /// Serialize the run (aggregates + every epoch record).
    pub fn to_json(&self) -> Json {
        crate::jobj![
            ("name", self.name.as_str()),
            ("strategy", self.strategy.as_str()),
            ("final_acc", self.final_acc),
            ("best_acc", self.best_acc),
            ("total_time", self.total_time),
            ("total_modeled_time", self.total_modeled_time),
            (
                "records",
                Json::Arr(self.records.iter().map(|r| r.to_json()).collect::<Vec<_>>())
            ),
        ]
    }

    /// Write the run result under `results/<file>.json`.
    pub fn save(&self, dir: &Path, file: &str) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{file}.json"));
        std::fs::write(&path, self.to_json().to_pretty())?;
        crate::info!("wrote {path:?}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(epoch: usize, acc: f64, t: f64) -> EpochRecord {
        EpochRecord { epoch, val_acc: acc, time_total: t, ..Default::default() }
    }

    #[test]
    fn run_result_aggregates() {
        let r = RunResult::from_records(
            "t",
            "baseline",
            vec![rec(0, 0.3, 1.0), rec(1, 0.7, 1.0), rec(2, 0.6, 1.0)],
        );
        assert_eq!(r.final_acc, 0.6);
        assert_eq!(r.best_acc, 0.7);
        assert_eq!(r.total_time, 3.0);
        assert_eq!(r.time_to_accuracy(0.65), Some(2.0));
        assert_eq!(r.time_to_accuracy(0.9), None);
    }

    #[test]
    fn json_roundtrip_parses() {
        let r = RunResult::from_records("t", "iswr", vec![rec(0, 0.5, 2.0)]);
        let j = r.to_json().to_pretty();
        let parsed = crate::util::json::parse(&j).unwrap();
        assert_eq!(parsed.get("strategy").unwrap().as_str(), Some("iswr"));
        assert_eq!(
            parsed.get("records").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn ckpt_stats_fold_and_serialize() {
        let mut r = rec(0, 0.5, 1.0);
        let s = WriteStats {
            leaves: 4,
            written_bytes: 1000,
            raw_bytes: 1500,
            deduped: 2,
            write_s: 0.25,
            hash_s: 0.5,
            compress_s: 0.125,
        };
        r.fold_ckpt_stats(&s);
        r.fold_ckpt_stats(&s); // sync + async reports accumulate
        assert_eq!(r.ckpt_leaves, 8);
        assert_eq!(r.ckpt_bytes, 2000);
        assert_eq!(r.ckpt_deduped, 4);
        assert_eq!(r.ckpt_hash_s, 1.0);
        let j = r.to_json();
        assert_eq!(j.get("ckpt_leaves").unwrap().as_usize(), Some(8));
        assert_eq!(j.get("ckpt_bytes").unwrap().as_usize(), Some(2000));
    }

    #[test]
    fn fault_fields_default_zero_and_serialize() {
        let mut r = rec(0, 0.5, 1.0);
        assert_eq!(r.lanes_dropped, 0);
        assert_eq!(r.service_errors, 0);
        r.lanes_dropped = 1;
        r.lanes_rejoined = 1;
        r.time_reissue = 0.25;
        r.service_errors = 2;
        let j = r.to_json();
        assert_eq!(j.get("lanes_dropped").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("lanes_rejoined").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("service_errors").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn serve_fields_default_zero_and_serialize() {
        let mut r = rec(0, 0.5, 1.0);
        assert_eq!(r.serve_publishes, 0);
        assert_eq!(r.serve_queries, 0);
        assert_eq!(r.serve_batches, 0);
        assert_eq!(r.serve_batch_fill, 0.0);
        assert!(r.serve_lane_queries.is_empty());
        assert_eq!(r.time_publish, 0.0);
        // a quiet epoch serializes no per-lane split
        let j = r.to_json();
        assert!(j.get("serve_lane_queries").is_none());
        r.serve_publishes = 1;
        r.serve_queries = 12;
        r.serve_batches = 3;
        r.serve_batch_fill = 4.0;
        r.serve_lane_queries = vec![7, 5];
        r.time_publish = 0.125;
        let j = r.to_json();
        assert_eq!(j.get("serve_publishes").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("serve_queries").unwrap().as_usize(), Some(12));
        assert_eq!(j.get("serve_batches").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("serve_batch_fill").unwrap().as_f64(), Some(4.0));
        let lanes = j.get("serve_lane_queries").unwrap().as_arr().unwrap();
        assert_eq!(lanes.len(), 2);
        assert_eq!(lanes[0].as_usize(), Some(7));
        assert_eq!(lanes[1].as_usize(), Some(5));
        assert_eq!(j.get("time_publish").unwrap().as_f64(), Some(0.125));
    }

    #[test]
    fn nan_val_acc_skipped_in_aggregates() {
        let r = RunResult::from_records(
            "t",
            "b",
            vec![rec(0, f64::NAN, 1.0), rec(1, 0.4, 1.0)],
        );
        assert_eq!(r.final_acc, 0.4);
        assert_eq!(r.best_acc, 0.4);
    }
}
