//! The pipelined step-execution engine: the single owner of the per-step
//! hot path (gather -> device step -> stat bookkeeping) for every
//! training-loop mode.
//!
//! # Where this sits in the architecture
//!
//! The repo is layered (see README.md / docs/worker-model.md):
//!   * **L1/L2** (`python/`, build time): JAX models + Pallas kernels,
//!     AOT-lowered to HLO artifacts.
//!   * **runtime**: the PJRT client executing those artifacts
//!     (`ModelExecutor` exposes the per-step entry points; the engine
//!     drives them through the [`StepBackend`] trait).
//!   * **L3 coordinator** (`coordinator/trainer.rs`): *planning* — builds
//!     each epoch's `EpochPlan` (selection, LR, sharding) and hands the
//!     resulting index order to this engine for execution.
//!
//! # Overlap model
//!
//! KAKURENBO's wall-clock win (paper §5, Fig. 9) requires the host-side
//! epoch work — sample gather, selection bookkeeping, stat recording — to
//! stay off the device's critical path.  The engine double-buffers
//! `BatchAssembler`s and overlaps the *gather of batch k+1* with the
//! *device execution of batch k*:
//!
//! ```text
//!   prefetch thread:  fill(k+1) | fill(k+2) |   ...
//!   main thread:      exec(k)+sink(k) | exec(k+1)+sink(k+1) | ...
//! ```
//!
//! A single prefetch thread (std scoped thread, buffers handed over by
//! value through channels) fills the spare buffer while the main thread
//! runs the device step and feeds the [`StepSink`].  The gather is a pure
//! memcpy from the immutable dataset, so the pipelined schedule performs
//! the *identical* sequence of device calls on *identical* buffer contents
//! as the serial reference — results are bitwise identical (enforced by
//! `tests/engine_determinism.rs`).
//!
//! Sinks that derive follow-up batches from step results (Selective-
//! Backprop's accept queue) issue them immediately through
//! [`StepCtx::step_now`]; those steps are inherently serial but the
//! candidate forward stream around them keeps prefetching.
//!
//! # Scaling out: the worker pool
//!
//! Multi-worker execution lives in [`pool`]: `cfg.workers > 1` shards the
//! epoch order ([`crate::data::shard::shard_order_aligned`]) and executes
//! it through [`WorkerPool`] — N of these double-buffered gather lanes
//! running concurrently behind one bulk-synchronous step barrier with a
//! deterministic `(step, worker)` reduction.  The default schedule is
//! bitwise identical to the single-stream interleaved run (the same
//! determinism contract as the overlap switch above); see
//! docs/worker-model.md for the full execution model.
//!
//! # Off the critical path: the service lanes
//!
//! [`service`] hosts the split [`ServiceLanes`]: a persistent **eval
//! lane** (its own executor replica, built on the same
//! [`ReplicaBuilder`] contract as the pool's replica lanes, consuming
//! params-tier snapshots) and an independent **checkpoint lane** (no
//! replica; serializes full-state snapshots), each with its own FIFO
//! queue, running while the primary executor trains the next epoch.
//! What a snapshot carries is typed — [`snapshot`] defines the
//! [`Snapshot`] / [`SnapshotTier`] pair and docs/snapshots.md the
//! lifecycle.  Async eval is bitwise identical to sync eval (the lane
//! evaluates an exact snapshot with the identical accumulation order) —
//! enforced by `tests/service_lane_determinism.rs`.  A third,
//! query-driven lane lives in [`serve`]: the online inference fleet's
//! [`SnapshotHub`] (live snapshot publications, retention-bounded) and
//! [`ServeFleet`] (one or more serving replicas with query coalescing),
//! fronted by the HTTP layer in [`crate::serve`]; see docs/serving.md.

pub mod backend;
pub mod chaos;
pub mod modes;
pub mod pool;
pub mod serve;
pub mod service;
pub mod snapshot;
pub mod testbed;

pub use backend::{DataParallel, ReplicaBackend, ReplicaBuilder, StateExchange, StepBackend};
pub use chaos::{ChaosAction, ChaosBackend, ChaosEvent, ChaosPlan};
pub use modes::{
    execute_feature_harvest, execute_plan, execute_sharded_average, execute_sharded_harvest,
    execute_sharded_plain, EmbedSink, EpochOutcome, EvalSink, RefreshSink, SbSink, TrainSink,
};
pub use pool::{PoolOutcome, WorkerPool, WorkerReport};
pub use serve::{Published, ServeAnswer, ServeBatching, ServeClient, ServeFleet, SnapshotHub};
pub use service::{CheckpointWriter, ServiceEvent, ServiceLaneKind, ServiceLanes};
pub use snapshot::{SharedSnapshot, Snapshot, SnapshotTier};

use crate::data::batch::{BatchAssembler, DoubleBuffer};
use crate::data::Dataset;
use crate::runtime::{BatchStats, EmbedStats};

/// Which device entry point each assembled batch goes through.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepMode {
    /// Full SGD step (`train_step`) at the given learning rate.
    Train {
        /// Learning rate applied by the device step.
        lr: f32,
    },
    /// Forward-only stats pass (`fwd_stats`).
    Forward,
    /// Forward pass through the embedding head (`fwd_embed`): per-slot
    /// stats plus penultimate-layer features, delivered to sinks via
    /// [`StepSink::on_embed`].  Errors on backends without an embedding
    /// artifact.
    Embed,
}

/// What one dispatched device step produced: plain stats, or stats plus
/// the embedding payload when the step ran through the embedding head.
pub(crate) enum StepOutput {
    /// `train_step` / `fwd_stats` result.
    Stats(BatchStats),
    /// `fwd_embed` result (stats + features + probabilities).
    Embed(EmbedStats),
}

impl StepOutput {
    /// Collapse to the per-slot stats, dropping any embedding payload.
    pub(crate) fn into_stats(self) -> BatchStats {
        match self {
            StepOutput::Stats(s) => s,
            StepOutput::Embed(e) => e.stats,
        }
    }
}

pub(crate) fn dispatch(
    backend: &mut dyn StepBackend,
    mode: StepMode,
    buf: &BatchAssembler,
) -> anyhow::Result<StepOutput> {
    Ok(match mode {
        StepMode::Train { lr } => {
            StepOutput::Stats(backend.train_step(&buf.x, &buf.y, &buf.sw, lr)?)
        }
        StepMode::Forward => StepOutput::Stats(backend.fwd_stats(&buf.x, &buf.y)?),
        StepMode::Embed => StepOutput::Embed(backend.fwd_embed(&buf.x, &buf.y)?),
    })
}

/// Feed one dispatched step's output to the sink through the matching
/// entry point — the single routing spot shared by the serial and
/// overlapped schedules (and the worker pool's reduction loop).
pub(crate) fn feed_sink(
    sink: &mut dyn StepSink,
    ctx: &mut StepCtx,
    slots: &[u32],
    real: usize,
    out: &StepOutput,
) -> anyhow::Result<()> {
    match out {
        StepOutput::Stats(stats) => sink.on_batch(ctx, slots, real, stats),
        StepOutput::Embed(es) => sink.on_embed(ctx, slots, real, es),
    }
}

/// Handed to sinks per batch: lets a sink issue immediate, unpipelined
/// follow-up steps (SB backprops full batches of accepted samples as soon
/// as the queue fills).
pub struct StepCtx<'a> {
    backend: &'a mut dyn StepBackend,
    scratch: &'a mut BatchAssembler,
    data: &'a Dataset,
}

impl StepCtx<'_> {
    /// Gather `indices` into the scratch buffer and execute one step right
    /// now, bypassing the prefetch pipeline.  Ragged batches are padded
    /// with zero-weight slots exactly like the pipelined path.
    pub fn step_now(
        &mut self,
        indices: &[u32],
        weights: Option<&[f32]>,
        mode: StepMode,
    ) -> anyhow::Result<BatchStats> {
        self.scratch.fill(self.data, indices, weights);
        Ok(dispatch(self.backend, mode, self.scratch)?.into_stats())
    }
}

/// Consumes each executed batch's results.  `slots[..real]` are the sample
/// indices the batch held (padding slots beyond `real` carry `u32::MAX`).
pub trait StepSink {
    /// Consume one executed batch's stats (called once per device step, in
    /// execution order).
    fn on_batch(
        &mut self,
        ctx: &mut StepCtx,
        slots: &[u32],
        real: usize,
        stats: &BatchStats,
    ) -> anyhow::Result<()>;

    /// Consume one executed embedding step's output ([`StepMode::Embed`]).
    /// The default forwards the embedded stats to [`StepSink::on_batch`],
    /// so stat-only sinks work unchanged under the embed mode; sinks that
    /// actually harvest features (the coordinator's feature-cache scoring
    /// pass) override it.
    fn on_embed(
        &mut self,
        ctx: &mut StepCtx,
        slots: &[u32],
        real: usize,
        es: &EmbedStats,
    ) -> anyhow::Result<()> {
        self.on_batch(ctx, slots, real, &es.stats)
    }

    /// Called once after the last batch (SB flushes its partial queue).
    fn finish(&mut self, _ctx: &mut StepCtx) -> anyhow::Result<()> {
        Ok(())
    }
}

/// The step-execution driver.  Owns the double-buffered assemblers (reused
/// across epochs *and* across train/refresh/eval runs) plus a scratch
/// assembler for sink-issued immediate steps.
pub struct Engine {
    buffers: DoubleBuffer,
    scratch: BatchAssembler,
    batch: usize,
    /// Overlap host gather with device execution.  Defaults to on when the
    /// host has more than one core; serial and overlapped schedules are
    /// bitwise identical, so this is purely a performance switch.
    pub overlap: bool,
}

impl Engine {
    /// The backend's artifact batch size (slots per step).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// An engine sized for `data`'s sample layout at device batch `batch`.
    pub fn new(data: &Dataset, batch: usize) -> Self {
        Engine {
            buffers: DoubleBuffer::new(data, batch),
            scratch: BatchAssembler::new(data, batch),
            batch,
            overlap: crate::util::threadpool::default_threads() > 1,
        }
    }

    /// Drive `order` through the backend batch by batch, feeding `sink`.
    /// `weights` (if any) are per-position gradient weights aligned with
    /// `order`; the ragged tail is padded with zero-weight slots.
    pub fn run(
        &mut self,
        backend: &mut dyn StepBackend,
        data: &Dataset,
        order: &[u32],
        weights: Option<&[f32]>,
        mode: StepMode,
        sink: &mut dyn StepSink,
    ) -> anyhow::Result<()> {
        if let Some(w) = weights {
            anyhow::ensure!(
                w.len() == order.len(),
                "weights len {} != order len {}",
                w.len(),
                order.len()
            );
        }
        if !self.scratch.matches(data) {
            self.scratch = BatchAssembler::new(data, self.batch);
        }
        let b = self.batch;
        let chunks: Vec<&[u32]> = order.chunks(b).collect();
        if self.overlap && chunks.len() > 1 {
            self.run_overlapped(backend, data, &chunks, weights, mode, sink)
        } else {
            self.run_serial(backend, data, &chunks, weights, mode, sink)
        }
    }

    fn run_serial(
        &mut self,
        backend: &mut dyn StepBackend,
        data: &Dataset,
        chunks: &[&[u32]],
        weights: Option<&[f32]>,
        mode: StepMode,
        sink: &mut dyn StepSink,
    ) -> anyhow::Result<()> {
        let b = self.batch;
        // On an error return the buffer is dropped, not parked;
        // `DoubleBuffer::take` re-creates it lazily on the next run.
        let mut cur = self.buffers.take(data);
        for (ci, chunk) in chunks.iter().enumerate() {
            let w = weights.map(|ws| &ws[ci * b..ci * b + chunk.len()]);
            cur.fill(data, chunk, w);
            let out = dispatch(&mut *backend, mode, &cur)?;
            let mut ctx =
                StepCtx { backend: &mut *backend, scratch: &mut self.scratch, data };
            feed_sink(sink, &mut ctx, &cur.slots, cur.real, &out)?;
        }
        let mut ctx = StepCtx { backend, scratch: &mut self.scratch, data };
        sink.finish(&mut ctx)?;
        self.buffers.put(cur);
        Ok(())
    }

    fn run_overlapped(
        &mut self,
        backend: &mut dyn StepBackend,
        data: &Dataset,
        chunks: &[&[u32]],
        weights: Option<&[f32]>,
        mode: StepMode,
        sink: &mut dyn StepSink,
    ) -> anyhow::Result<()> {
        let b = self.batch;
        let first = self.buffers.take(data);
        let spare = self.buffers.take(data);
        let scratch = &mut self.scratch;

        let result = std::thread::scope(|scope| -> anyhow::Result<Vec<BatchAssembler>> {
            let (fill_tx, fill_rx) = std::sync::mpsc::channel::<(BatchAssembler, usize)>();
            let (done_tx, done_rx) = std::sync::mpsc::channel::<BatchAssembler>();
            scope.spawn(move || {
                while let Ok((mut buf, ci)) = fill_rx.recv() {
                    let chunk = chunks[ci];
                    let w = weights.map(|ws| &ws[ci * b..ci * b + chunk.len()]);
                    buf.fill(data, chunk, w);
                    if done_tx.send(buf).is_err() {
                        break;
                    }
                }
            });

            let mut free = vec![spare];
            fill_tx
                .send((first, 0))
                .map_err(|_| anyhow::anyhow!("prefetch worker unavailable"))?;
            for ci in 0..chunks.len() {
                let cur = done_rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("prefetch worker died"))?;
                if ci + 1 < chunks.len() {
                    let next = free.pop().expect("double-buffer invariant");
                    fill_tx
                        .send((next, ci + 1))
                        .map_err(|_| anyhow::anyhow!("prefetch worker unavailable"))?;
                }
                // Device step + sink run while the worker gathers ci+1.
                let out = dispatch(&mut *backend, mode, &cur)?;
                let mut ctx =
                    StepCtx { backend: &mut *backend, scratch: &mut *scratch, data };
                feed_sink(sink, &mut ctx, &cur.slots, cur.real, &out)?;
                free.push(cur);
            }
            drop(fill_tx); // worker drains and exits
            let mut ctx = StepCtx { backend, scratch, data };
            sink.finish(&mut ctx)?;
            Ok(free)
        });

        match result {
            Ok(bufs) => {
                for buf in bufs {
                    self.buffers.put(buf);
                }
                Ok(())
            }
            // Buffers in flight are dropped; DoubleBuffer::take re-creates
            // them lazily, so an error here cannot poison later runs.
            Err(e) => Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testbed::MockBackend;
    use super::*;
    use crate::data::synth::{gauss_mixture, GaussMixtureCfg};

    struct Collect {
        losses: Vec<u32>,
    }

    impl StepSink for Collect {
        fn on_batch(
            &mut self,
            _ctx: &mut StepCtx,
            _slots: &[u32],
            real: usize,
            stats: &BatchStats,
        ) -> anyhow::Result<()> {
            self.losses.extend(stats.loss[..real].iter().map(|l| l.to_bits()));
            Ok(())
        }
    }

    fn tiny() -> crate::data::Dataset {
        gauss_mixture(
            &GaussMixtureCfg { n_train: 53, n_val: 4, dim: 6, classes: 3, ..Default::default() },
            7,
        )
        .train
    }

    fn run_once(overlap: bool, mode: StepMode) -> (Vec<u32>, Vec<u64>, u32) {
        let d = tiny();
        let order: Vec<u32> = (0..53u32).rev().collect();
        let mut eng = Engine::new(&d, 8);
        eng.overlap = overlap;
        let mut be = MockBackend::new();
        let mut sink = Collect { losses: vec![] };
        eng.run(&mut be, &d, &order, None, mode, &mut sink).unwrap();
        (sink.losses, be.trace, be.param.to_bits())
    }

    #[test]
    fn overlapped_forward_is_bitwise_serial() {
        assert_eq!(run_once(false, StepMode::Forward), run_once(true, StepMode::Forward));
    }

    #[test]
    fn overlapped_train_is_bitwise_serial() {
        let mode = StepMode::Train { lr: 0.05 };
        let (ls, ts, ps) = run_once(false, mode);
        let (lo, to, po) = run_once(true, mode);
        assert_eq!(ls, lo);
        assert_eq!(ts, to);
        assert_eq!(ps, po);
        assert_eq!(ts.len(), 7); // ceil(53 / 8) train steps
    }

    #[test]
    fn ragged_tail_sees_zero_weight_padding() {
        let d = tiny();
        let mut eng = Engine::new(&d, 8);
        eng.overlap = false;
        let mut be = MockBackend::new();
        struct Tail {
            last_real: usize,
        }
        impl StepSink for Tail {
            fn on_batch(
                &mut self,
                _ctx: &mut StepCtx,
                slots: &[u32],
                real: usize,
                _stats: &BatchStats,
            ) -> anyhow::Result<()> {
                self.last_real = real;
                assert!(slots[real..].iter().all(|&s| s == u32::MAX));
                Ok(())
            }
        }
        let order: Vec<u32> = (0..13).collect();
        let mut sink = Tail { last_real: 0 };
        eng.run(&mut be, &d, &order, None, StepMode::Forward, &mut sink).unwrap();
        assert_eq!(sink.last_real, 5); // 13 = 8 + 5
    }

    #[test]
    fn weights_align_with_order_chunks() {
        let d = tiny();
        let order: Vec<u32> = (0..20).collect();
        let weights: Vec<f32> = (0..20).map(|i| i as f32 * 0.1).collect();
        struct WSink {
            seen: Vec<u32>,
        }
        impl StepSink for WSink {
            fn on_batch(
                &mut self,
                _ctx: &mut StepCtx,
                _slots: &[u32],
                real: usize,
                stats: &BatchStats,
            ) -> anyhow::Result<()> {
                self.seen.extend(stats.loss[..real].iter().map(|l| l.to_bits()));
                Ok(())
            }
        }
        let mut runs = vec![];
        for overlap in [false, true] {
            let mut eng = Engine::new(&d, 8);
            eng.overlap = overlap;
            let mut be = MockBackend::new();
            let mut sink = WSink { seen: vec![] };
            eng.run(
                &mut be,
                &d,
                &order,
                Some(&weights),
                StepMode::Train { lr: 0.01 },
                &mut sink,
            )
            .unwrap();
            runs.push(sink.seen);
        }
        assert_eq!(runs[0], runs[1]);
        assert_eq!(runs[0].len(), 20);
    }

    #[test]
    fn weight_length_mismatch_rejected() {
        let d = tiny();
        let mut eng = Engine::new(&d, 8);
        let mut be = MockBackend::new();
        let mut sink = Collect { losses: vec![] };
        let order: Vec<u32> = (0..10).collect();
        let w = vec![1.0f32; 9];
        assert!(eng
            .run(&mut be, &d, &order, Some(&w), StepMode::Forward, &mut sink)
            .is_err());
    }

    #[test]
    fn empty_order_is_a_noop() {
        let d = tiny();
        let mut eng = Engine::new(&d, 8);
        let mut be = MockBackend::new();
        let mut sink = Collect { losses: vec![] };
        eng.run(&mut be, &d, &[], None, StepMode::Forward, &mut sink).unwrap();
        assert!(sink.losses.is_empty());
    }

    #[test]
    fn buffers_survive_a_failed_run() {
        struct Failing;
        impl StepBackend for Failing {
            fn train_step(
                &mut self,
                _x: &[f32],
                _y: &[i32],
                _sw: &[f32],
                _lr: f32,
            ) -> anyhow::Result<BatchStats> {
                anyhow::bail!("device lost")
            }
            fn fwd_stats(&mut self, _x: &[f32], _y: &[i32]) -> anyhow::Result<BatchStats> {
                anyhow::bail!("device lost")
            }
        }
        let d = tiny();
        let order: Vec<u32> = (0..20).collect();
        for overlap in [false, true] {
            let mut eng = Engine::new(&d, 8);
            eng.overlap = overlap;
            let mut sink = Collect { losses: vec![] };
            assert!(eng.run(&mut Failing, &d, &order, None, StepMode::Forward, &mut sink).is_err());
            // engine recovers: a healthy backend still runs afterwards
            let mut be = MockBackend::new();
            let mut sink = Collect { losses: vec![] };
            eng.run(&mut be, &d, &order, None, StepMode::Forward, &mut sink).unwrap();
            assert_eq!(sink.losses.len(), 20);
        }
    }
}
