//! Typed state snapshots: what an export carries, made explicit.
//!
//! Every consumer of exported model state needs one of exactly two
//! payloads:
//!
//! * **Params** — the parameter leaves alone.  Enough for any forward
//!   pass: validation eval, hidden-stat refresh, transfer export.  For an
//!   SGD-momentum backend this is *half* the leaves (and half the
//!   device→host traffic) of a full export, which is why eval-heavy runs
//!   want this tier on their critical path.
//! * **Full** — parameters plus the optimizer state (SGD momentum).
//!   Required wherever the optimizer trajectory must continue bit-exactly:
//!   checkpoints, `--dp average` replica synchronization, resume.
//!
//! [`Snapshot`] carries the tier *in the type*, so a consumer that needs
//! momentum (the checkpoint lane, the pool's averaging sync) can reject a
//! params-only snapshot at submission time instead of corrupting state at
//! import time.  The tier an epoch exports is chosen once, up front, by
//! the epoch pipeline (`coordinator/epoch.rs`): an epoch that both evals
//! and checkpoints exports one `Full` snapshot and shares it; an epoch
//! that only evals exports the cheap `Params` tier.  See
//! docs/snapshots.md for the lifecycle and the export-cost model.
//!
//! Bit-exactness contract: a snapshot is a plain host copy of the
//! backend's `f32` leaves — export followed by import preserves every bit
//! pattern, whichever tier rode along (enforced by
//! `tests/service_lane_determinism.rs` and the doc-test on
//! [`crate::engine::StateExchange::export_params`]).

use std::sync::Arc;

/// How much backend state a [`Snapshot`] carries.
///
/// Ordered: `Params < Full`, so "does this snapshot satisfy that
/// consumer?" is `snapshot.tier() >= needed`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SnapshotTier {
    /// Parameter leaves only — sufficient for forward passes (eval,
    /// refresh), half the export traffic of `Full` on momentum backends.
    Params,
    /// Parameters plus optimizer state — required for checkpoints and
    /// data-parallel replica synchronization.
    Full,
}

impl SnapshotTier {
    /// Display name (bench tables, logs).
    pub fn name(self) -> &'static str {
        match self {
            SnapshotTier::Params => "params",
            SnapshotTier::Full => "full",
        }
    }
}

/// An immutable, typed copy of a backend's exported state: parameter
/// leaves plus — on the [`SnapshotTier::Full`] tier of a backend that has
/// any — the optimizer momentum leaves, in the same stable leaf order.
///
/// A backend without separable optimizer state (the engine testbed's
/// `MockBackend`) exports `Full` snapshots with `momentum() == None`; the
/// tier still records the *intent*, so consumers can require `Full`
/// without knowing the backend's optimizer shape.
#[derive(Clone, Debug)]
pub struct Snapshot {
    tier: SnapshotTier,
    params: Vec<Vec<f32>>,
    momentum: Option<Vec<Vec<f32>>>,
}

impl Snapshot {
    /// A params-only snapshot (the eval-lane fast path).
    pub fn params_only(params: Vec<Vec<f32>>) -> Self {
        Snapshot { tier: SnapshotTier::Params, params, momentum: None }
    }

    /// A full-state snapshot; `momentum` is `None` for backends whose
    /// entire mutable state is their parameters.
    pub fn full(params: Vec<Vec<f32>>, momentum: Option<Vec<Vec<f32>>>) -> Self {
        Snapshot { tier: SnapshotTier::Full, params, momentum }
    }

    /// Wrap a flat full-state export (the legacy
    /// [`crate::engine::StateExchange::export_state`] layout: params then
    /// momentum) as a typed `Full` snapshot.  `param_leaves` is the
    /// parameter leaf count; the flat state must hold exactly
    /// `param_leaves` leaves (stateless backend) or `2 * param_leaves`
    /// (params + momentum).
    pub fn from_state(mut state: Vec<Vec<f32>>, param_leaves: usize) -> anyhow::Result<Self> {
        if state.len() == param_leaves {
            Ok(Snapshot::full(state, None))
        } else if state.len() == 2 * param_leaves {
            let momentum = state.split_off(param_leaves);
            Ok(Snapshot::full(state, Some(momentum)))
        } else {
            anyhow::bail!(
                "flat state has {} leaves, expected {param_leaves} or {}",
                state.len(),
                2 * param_leaves
            )
        }
    }

    /// The tier this snapshot was exported at.
    pub fn tier(&self) -> SnapshotTier {
        self.tier
    }

    /// The parameter leaves, in the backend's stable leaf order.
    pub fn params(&self) -> &[Vec<f32>] {
        &self.params
    }

    /// The optimizer momentum leaves (same order as [`Snapshot::params`]),
    /// when the snapshot carries them.
    pub fn momentum(&self) -> Option<&[Vec<f32>]> {
        self.momentum.as_deref()
    }

    /// Total leaf count across both sections.
    pub fn leaves(&self) -> usize {
        self.params.len() + self.momentum.as_ref().map_or(0, |m| m.len())
    }

    /// Total `f32` element count across both sections (the export-cost
    /// model's unit: host traffic scales linearly in this).
    pub fn elems(&self) -> usize {
        let count = |leaves: &[Vec<f32>]| leaves.iter().map(|l| l.len()).sum::<usize>();
        count(&self.params) + self.momentum.as_deref().map_or(0, count)
    }

    /// Flatten back to the legacy `export_state` layout (params then
    /// momentum).  Fails on a params-only snapshot — that tier cannot
    /// reconstruct optimizer state.
    pub fn to_state(&self) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            self.tier == SnapshotTier::Full,
            "params-only snapshot cannot produce a full state"
        );
        let mut state = self.params.clone();
        if let Some(m) = &self.momentum {
            state.extend(m.iter().cloned());
        }
        Ok(state)
    }
}

/// A snapshot shared across threads without copying (the coordinator
/// hands the same `Arc` to the eval lane, the checkpoint lane, and the
/// pool's replica lanes).
pub type SharedSnapshot = Arc<Snapshot>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_ordering_params_below_full() {
        assert!(SnapshotTier::Params < SnapshotTier::Full);
        assert!(SnapshotTier::Full >= SnapshotTier::Params);
        assert_eq!(SnapshotTier::Params.name(), "params");
        assert_eq!(SnapshotTier::Full.name(), "full");
    }

    #[test]
    fn from_state_splits_momentum_backends() {
        let flat = vec![vec![1.0f32, 2.0], vec![3.0], vec![0.1, 0.2], vec![0.3]];
        let snap = Snapshot::from_state(flat, 2).unwrap();
        assert_eq!(snap.tier(), SnapshotTier::Full);
        assert_eq!(snap.params(), &[vec![1.0, 2.0], vec![3.0]]);
        assert_eq!(snap.momentum().unwrap(), &[vec![0.1, 0.2], vec![0.3]]);
        assert_eq!(snap.leaves(), 4);
        assert_eq!(snap.elems(), 6);
    }

    #[test]
    fn from_state_accepts_stateless_backends() {
        let snap = Snapshot::from_state(vec![vec![1.5f32]], 1).unwrap();
        assert_eq!(snap.tier(), SnapshotTier::Full);
        assert!(snap.momentum().is_none());
        assert!(Snapshot::from_state(vec![vec![1.0]; 3], 2).is_err());
    }

    #[test]
    fn to_state_round_trips_and_rejects_params_only() {
        let flat = vec![vec![1.0f32], vec![2.0], vec![-1.0], vec![-2.0]];
        let snap = Snapshot::from_state(flat.clone(), 2).unwrap();
        assert_eq!(snap.to_state().unwrap(), flat);
        let p = Snapshot::params_only(vec![vec![1.0f32]]);
        assert_eq!(p.tier(), SnapshotTier::Params);
        assert!(p.to_state().is_err());
        assert_eq!(p.elems(), 1);
    }
}
