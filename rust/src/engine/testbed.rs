//! Deterministic host-only backend for tests and benches.
//!
//! [`MockBackend`] stands in for the PJRT executor wherever the engine's
//! scheduling guarantees must be verified without HLO artifacts: a scalar
//! "parameter" folds in every training slot *sequentially* (f32 adds do
//! not commute), and every forward result depends on that parameter — so
//! any reordering, skipped step, or corrupted buffer anywhere in the
//! pipelined engine or the worker pool changes the bit pattern of
//! subsequent outputs.  It also implements [`DataParallel`], making it the
//! reference backend for the pool's parameter-averaging mode.

use super::backend::{DataParallel, ReplicaBackend, ReplicaBuilder, StateExchange, StepBackend};
use crate::runtime::{BatchStats, EmbedStats};

/// Order-sensitive scalar-parameter backend (see module docs).
#[derive(Clone, Debug)]
pub struct MockBackend {
    /// The scalar model parameter every batch folds into.
    pub param: f32,
    /// Bit pattern of `param` after each executed train step, in order.
    pub trace: Vec<u64>,
    /// `train_step` invocations since construction.
    pub train_calls: usize,
    /// `fwd_stats` invocations since construction.
    pub fwd_calls: usize,
    /// `fwd_embed` invocations since construction.  Together with the two
    /// counters above this lets tests assert *device-call budgets* — e.g.
    /// that a cached-feature scoring pass performs zero extra forwards in
    /// epochs that reuse the cache.
    pub embed_calls: usize,
}

impl Default for MockBackend {
    fn default() -> Self {
        MockBackend::new()
    }
}

impl MockBackend {
    /// A fresh backend with `param = 1.0`, an empty trace, and zeroed
    /// call counters.
    pub fn new() -> Self {
        MockBackend { param: 1.0, trace: vec![], train_calls: 0, fwd_calls: 0, embed_calls: 0 }
    }

    /// Total device forwards that are *not* training steps (stat
    /// refreshes, evals, embedding harvests) — the quantity pre-forward
    /// pruning strategies promise to amortize.
    pub fn forward_calls(&self) -> usize {
        self.fwd_calls + self.embed_calls
    }

    fn stats(&self, x: &[f32], y: &[i32], sw: Option<&[f32]>, b: usize) -> BatchStats {
        let dim = x.len() / b;
        let mut s = BatchStats::default();
        for slot in 0..b {
            let xs: f32 = x[slot * dim..(slot + 1) * dim].iter().sum();
            let w = sw.map_or(1.0, |sw| sw[slot]);
            let l = (xs * self.param).abs() + y[slot] as f32 * 0.125 + w * 0.25;
            s.loss.push(l);
            s.correct.push(if l < 2.0 { 1.0 } else { 0.0 });
            s.conf.push(1.0 / (1.0 + l));
        }
        s
    }
}

impl StepBackend for MockBackend {
    fn train_step(
        &mut self,
        x: &[f32],
        y: &[i32],
        sw: &[f32],
        lr: f32,
    ) -> anyhow::Result<BatchStats> {
        let b = sw.len();
        self.train_calls += 1;
        let stats = self.stats(x, y, Some(sw), b);
        for (slot, &w) in sw.iter().enumerate() {
            self.param += stats.loss[slot] * w * lr * 1e-3;
        }
        self.trace.push(self.param.to_bits() as u64);
        Ok(stats)
    }

    fn fwd_stats(&mut self, x: &[f32], y: &[i32]) -> anyhow::Result<BatchStats> {
        let b = y.len();
        self.fwd_calls += 1;
        Ok(self.stats(x, y, None, b))
    }

    /// Deterministic two-wide "embedding": per slot, the feature sum and
    /// its product with `param` — enough structure for serving tests to
    /// verify bitwise transport without an embedding artifact.  The
    /// feature sum is a pure function of the sample index (the dataset is
    /// immutable), and `param` encodes the whole training history, so the
    /// emitted embedding is deterministic per (sample, epoch) without any
    /// hidden state.
    fn fwd_embed(&mut self, x: &[f32], y: &[i32]) -> anyhow::Result<EmbedStats> {
        let b = y.len();
        self.embed_calls += 1;
        let dim = x.len() / b;
        let stats = self.stats(x, y, None, b);
        let mut emb = Vec::with_capacity(b * 2);
        let mut probs = Vec::with_capacity(b);
        for slot in 0..b {
            let xs: f32 = x[slot * dim..(slot + 1) * dim].iter().sum();
            emb.push(xs);
            emb.push(xs * self.param);
            probs.push(stats.conf[slot]);
        }
        Ok(EmbedStats { stats, emb, probs })
    }
}

impl StateExchange for MockBackend {
    fn export_state(&self) -> anyhow::Result<Vec<Vec<f32>>> {
        Ok(vec![vec![self.param]])
    }

    fn import_state(&mut self, state: &[Vec<f32>]) -> anyhow::Result<()> {
        anyhow::ensure!(
            state.len() == 1 && state[0].len() == 1,
            "MockBackend state is one scalar leaf"
        );
        self.param = state[0][0];
        Ok(())
    }
}

impl DataParallel for MockBackend {
    /// Replication is a host-side clone: the builder captures a copy of
    /// the backend (trivially `Send`) and hands it to the lane thread.
    fn replica_builder(&self) -> anyhow::Result<ReplicaBuilder> {
        let replica = self.clone();
        Ok(Box::new(move || Ok(Box::new(replica) as Box<dyn ReplicaBackend>)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_roundtrip_is_exact() {
        let mut a = MockBackend::new();
        a.param = 0.123456789;
        let mut b = (a.replica_builder().unwrap())().unwrap();
        assert_eq!(a.export_state().unwrap(), b.export_state().unwrap());
        b.import_state(&a.export_state().unwrap()).unwrap();
        assert_eq!(
            a.param.to_bits(),
            b.export_state().unwrap()[0][0].to_bits()
        );
        assert!(b.import_state(&[vec![1.0, 2.0]]).is_err());
    }
}
