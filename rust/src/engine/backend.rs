//! The device-side contracts the step engine drives.
//!
//! [`StepBackend`] is the per-step execution surface: `ModelExecutor`
//! (runtime layer) is the production backend; tests and benches use the
//! deterministic host-only [`crate::engine::testbed::MockBackend`] so the
//! pipelined-vs-serial and pool-vs-stream equivalences can be verified
//! without PJRT artifacts.
//!
//! [`DataParallel`] extends it with replica management (replicate /
//! export / import parameter state) for the worker pool's true
//! data-parallel mode, where each worker steps its own replica and the
//! pool averages parameters at the bulk-synchronous step barrier.

use crate::runtime::BatchStats;

/// One device step-execution endpoint: a full SGD step or a forward-only
/// stats pass over one assembled batch.  Buffers follow the
/// `BatchAssembler` layout (row-major x, labels y, per-slot weights sw,
/// padding slots carry sw = 0).
pub trait StepBackend {
    /// One SGD step; returns per-slot loss / correct / confidence.
    fn train_step(
        &mut self,
        x: &[f32],
        y: &[i32],
        sw: &[f32],
        lr: f32,
    ) -> anyhow::Result<BatchStats>;

    /// Forward-only stats (refresh, eval, SB candidate pass).
    fn fwd_stats(&mut self, x: &[f32], y: &[i32]) -> anyhow::Result<BatchStats>;
}

/// A backend whose model state can be replicated across data-parallel
/// workers and merged back by parameter averaging.
///
/// The contract the worker pool relies on:
///
/// * [`DataParallel::replicate`] produces a backend that is
///   *bitwise-identical* in behaviour to `self` (same parameters, same
///   optimizer state), so W freshly replicated workers running forward
///   passes produce exactly the stats a single stream would.
/// * [`DataParallel::export_state`] / [`DataParallel::import_state`]
///   round-trip the full mutable state exactly (f32 bit patterns are
///   preserved), so the pool's fixed worker-order averaging fold is
///   deterministic run to run.
pub trait DataParallel: StepBackend {
    /// Build an independent replica with identical state.
    fn replicate(&self) -> anyhow::Result<Self>
    where
        Self: Sized;

    /// Snapshot the full mutable model state (parameters + optimizer
    /// state) as host tensors, in a stable leaf order.
    fn export_state(&self) -> anyhow::Result<Vec<Vec<f32>>>;

    /// Restore state previously produced by [`DataParallel::export_state`]
    /// (or an elementwise average of several such snapshots).
    fn import_state(&mut self, state: &[Vec<f32>]) -> anyhow::Result<()>;
}

/// Accumulate `other` into `acc` elementwise (one fold step of the pool's
/// fixed worker-order parameter reduction).
pub fn accumulate_state(acc: &mut [Vec<f32>], other: &[Vec<f32>]) -> anyhow::Result<()> {
    anyhow::ensure!(
        acc.len() == other.len(),
        "state leaf count mismatch: {} vs {}",
        acc.len(),
        other.len()
    );
    for (a, o) in acc.iter_mut().zip(other) {
        anyhow::ensure!(a.len() == o.len(), "state leaf shape mismatch");
        for (x, y) in a.iter_mut().zip(o) {
            *x += y;
        }
    }
    Ok(())
}

/// Finish the parameter average: divide every accumulated element by the
/// worker count.  Division (not multiplication by a reciprocal) keeps the
/// W = 1 path exact and powers of two bitwise-lossless.
pub fn finish_average(acc: &mut [Vec<f32>], workers: usize) {
    let w = workers as f32;
    for leaf in acc.iter_mut() {
        for v in leaf.iter_mut() {
            *v /= w;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_of_identical_states_is_identity_for_pow2() {
        let state = vec![vec![0.1f32, -2.5, 3.75], vec![1.0e-7]];
        for w in [1usize, 2, 4] {
            let mut acc = state.clone();
            for _ in 1..w {
                accumulate_state(&mut acc, &state).unwrap();
            }
            finish_average(&mut acc, w);
            let got: Vec<u32> = acc.iter().flatten().map(|v| v.to_bits()).collect();
            let want: Vec<u32> = state.iter().flatten().map(|v| v.to_bits()).collect();
            assert_eq!(got, want, "w={w}");
        }
    }

    #[test]
    fn mismatched_shapes_rejected() {
        let mut a = vec![vec![1.0f32; 3]];
        assert!(accumulate_state(&mut a, &[vec![1.0f32; 2]]).is_err());
        assert!(accumulate_state(&mut a, &[vec![1.0f32; 3], vec![0.0]]).is_err());
    }
}
