//! The device-side contract the step engine drives.
//!
//! `ModelExecutor` (runtime layer) is the production backend; tests use a
//! deterministic host-only mock so the pipelined-vs-serial equivalence can
//! be verified without PJRT artifacts.

use crate::runtime::BatchStats;

/// One device step-execution endpoint: a full SGD step or a forward-only
/// stats pass over one assembled batch.  Buffers follow the
/// `BatchAssembler` layout (row-major x, labels y, per-slot weights sw,
/// padding slots carry sw = 0).
pub trait StepBackend {
    /// One SGD step; returns per-slot loss / correct / confidence.
    fn train_step(
        &mut self,
        x: &[f32],
        y: &[i32],
        sw: &[f32],
        lr: f32,
    ) -> anyhow::Result<BatchStats>;

    /// Forward-only stats (refresh, eval, SB candidate pass).
    fn fwd_stats(&mut self, x: &[f32], y: &[i32]) -> anyhow::Result<BatchStats>;
}
