//! The device-side contracts the step engine drives.
//!
//! [`StepBackend`] is the per-step execution surface: `ModelExecutor`
//! (runtime layer) is the production backend; tests and benches use the
//! deterministic host-only [`crate::engine::testbed::MockBackend`] so the
//! pipelined-vs-serial and pool-vs-stream equivalences can be verified
//! without PJRT artifacts.
//!
//! [`DataParallel`] extends it with replica management for the worker
//! pool's true data-parallel mode, where each worker steps its own replica
//! and the pool averages parameters at the bulk-synchronous step barrier.
//!
//! # Why replicas are *built* on their lane thread
//!
//! The production backend owns PJRT state (device literals, a client
//! handle) that is not [`Send`] — it can never cross a thread boundary,
//! so the pool cannot construct replicas up front and move them into
//! worker threads.  Instead [`DataParallel::replica_builder`] returns a
//! [`ReplicaBuilder`]: a `Send` *constructor* carrying only host-side
//! data (artifact paths, exported parameter tensors).  The pool ships the
//! builder into a lane thread, which invokes it there; the resulting
//! replica — non-`Send` device state and all — is owned by that thread
//! for its whole life and communicates exclusively through `Send` host
//! values ([`crate::data::batch::BatchAssembler`] buffers in,
//! [`crate::runtime::BatchStats`] + exported state out).

use super::snapshot::{Snapshot, SnapshotTier};
use crate::runtime::{BatchStats, EmbedStats};

/// One device step-execution endpoint: a full SGD step or a forward-only
/// stats pass over one assembled batch.  Buffers follow the
/// `BatchAssembler` layout (row-major x, labels y, per-slot weights sw,
/// padding slots carry sw = 0).
pub trait StepBackend {
    /// One SGD step; returns per-slot loss / correct / confidence.
    fn train_step(
        &mut self,
        x: &[f32],
        y: &[i32],
        sw: &[f32],
        lr: f32,
    ) -> anyhow::Result<BatchStats>;

    /// Forward-only stats (refresh, eval, SB candidate pass).
    fn fwd_stats(&mut self, x: &[f32], y: &[i32]) -> anyhow::Result<BatchStats>;

    /// Forward pass that additionally returns penultimate-layer features
    /// and class probabilities (the serving lane's `/v1/embed` endpoint;
    /// future cheap-proxy scoring).  Defaults to an error — only backends
    /// with an embedding head (a compiled `fwd_embed` artifact) override
    /// it, and callers surface the error instead of inventing features.
    fn fwd_embed(&mut self, _x: &[f32], _y: &[i32]) -> anyhow::Result<EmbedStats> {
        anyhow::bail!("this backend has no embedding head (no fwd_embed artifact)")
    }
}

/// Host-side snapshot round-trip of a backend's mutable model state as
/// plain `f32` tensors.
///
/// Two export tiers (see [`crate::engine::snapshot`] and
/// docs/snapshots.md): the flat full-state pair
/// ([`StateExchange::export_state`] / [`StateExchange::import_state`],
/// params + optimizer state — the worker pool's averaging
/// representation), and the params-only fast path
/// ([`StateExchange::export_params`] / [`StateExchange::import_params`])
/// that forward-only consumers (the eval lane) ride, at half the leaf
/// traffic on momentum backends.  [`StateExchange::export_snapshot`] /
/// [`StateExchange::import_snapshot`] wrap both behind the typed
/// [`Snapshot`].
///
/// The contract every consumer relies on: an export followed by the
/// matching import preserves every f32 bit pattern exactly, so replica
/// evals, checkpoints, and the fixed worker-order averaging fold are
/// deterministic run to run.
pub trait StateExchange {
    /// Snapshot the full mutable model state (parameters + optimizer
    /// state) as host tensors, in a stable leaf order.
    fn export_state(&self) -> anyhow::Result<Vec<Vec<f32>>>;

    /// Restore state previously produced by [`StateExchange::export_state`]
    /// (or an elementwise average of several such snapshots).
    fn import_state(&mut self, state: &[Vec<f32>]) -> anyhow::Result<()>;

    /// Snapshot only the parameter leaves — the fast export path for
    /// forward-only consumers, at half the device→host traffic of
    /// [`StateExchange::export_state`] on momentum backends.
    ///
    /// The default forwards to `export_state`, which is exactly right for
    /// backends whose entire mutable state *is* their parameters; momentum
    /// backends override it to skip the optimizer leaves.
    ///
    /// Determinism contract: a forward pass over imported params-only
    /// state is **bitwise identical** to one over imported full state —
    /// optimizer state never feeds a forward pass:
    ///
    /// ```
    /// use kakurenbo::data::synth::{gauss_mixture, GaussMixtureCfg};
    /// use kakurenbo::engine::testbed::MockBackend;
    /// use kakurenbo::engine::{Engine, EvalSink, StateExchange, StepMode};
    ///
    /// let tv = gauss_mixture(
    ///     &GaussMixtureCfg { n_train: 8, n_val: 21, dim: 6, classes: 3, ..Default::default() },
    ///     5,
    /// );
    /// let eval = |be: &mut MockBackend| {
    ///     let order: Vec<u32> = (0..tv.val.n as u32).collect();
    ///     let mut eng = Engine::new(&tv.val, 8);
    ///     let mut sink = EvalSink::default();
    ///     eng.run(be, &tv.val, &order, None, StepMode::Forward, &mut sink).unwrap();
    ///     let (acc, loss) = sink.result();
    ///     (acc.to_bits(), loss.to_bits())
    /// };
    /// let mut primary = MockBackend::new();
    /// primary.param = 1.618034;
    /// // one replica restored from the params-only tier ...
    /// let mut via_params = MockBackend::new();
    /// via_params.import_params(&primary.export_params().unwrap()).unwrap();
    /// // ... one from the full-state tier: their evals match bit for bit
    /// let mut via_full = MockBackend::new();
    /// via_full.import_state(&primary.export_state().unwrap()).unwrap();
    /// assert_eq!(eval(&mut via_params), eval(&mut via_full));
    /// assert_eq!(eval(&mut via_params), eval(&mut primary));
    /// ```
    fn export_params(&self) -> anyhow::Result<Vec<Vec<f32>>> {
        self.export_state()
    }

    /// Snapshot the optimizer-state leaves (same order as
    /// [`StateExchange::export_params`]), or `None` for backends with no
    /// separable optimizer state (the default).
    fn export_momentum(&self) -> anyhow::Result<Option<Vec<Vec<f32>>>> {
        Ok(None)
    }

    /// Restore parameter leaves only, leaving any optimizer state
    /// untouched.  The default forwards to `import_state` (correct for
    /// stateless backends); momentum backends override it.
    fn import_params(&mut self, params: &[Vec<f32>]) -> anyhow::Result<()> {
        self.import_state(params)
    }

    /// Export a typed [`Snapshot`] at the requested tier.
    fn export_snapshot(&self, tier: SnapshotTier) -> anyhow::Result<Snapshot> {
        Ok(match tier {
            SnapshotTier::Params => Snapshot::params_only(self.export_params()?),
            SnapshotTier::Full => {
                Snapshot::full(self.export_params()?, self.export_momentum()?)
            }
        })
    }

    /// Restore from a typed [`Snapshot`]: a params-only snapshot restores
    /// parameters and leaves optimizer state as-is; a full snapshot
    /// restores everything it carries.
    fn import_snapshot(&mut self, snap: &Snapshot) -> anyhow::Result<()> {
        match (snap.tier(), snap.momentum()) {
            (SnapshotTier::Params, _) | (SnapshotTier::Full, None) => {
                self.import_params(snap.params())
            }
            (SnapshotTier::Full, Some(momentum)) => {
                let mut state =
                    Vec::with_capacity(snap.params().len() + momentum.len());
                state.extend_from_slice(snap.params());
                state.extend_from_slice(momentum);
                self.import_state(&state)
            }
        }
    }
}

/// A worker-local backend replica: steps batches and round-trips its
/// state, entirely on the lane thread that built it.  Blanket-implemented
/// for every `StepBackend + StateExchange` type.
pub trait ReplicaBackend: StepBackend + StateExchange {}

impl<T: StepBackend + StateExchange> ReplicaBackend for T {}

// A boxed replica is itself a backend (delegating every method, including
// the defaulted tier fast paths, to the inner implementation) so wrappers
// like `engine::chaos::ChaosBackend` can interpose on replicas produced by
// an arbitrary `ReplicaBuilder` without knowing the concrete type.
impl StepBackend for Box<dyn ReplicaBackend> {
    fn train_step(
        &mut self,
        x: &[f32],
        y: &[i32],
        sw: &[f32],
        lr: f32,
    ) -> anyhow::Result<BatchStats> {
        (**self).train_step(x, y, sw, lr)
    }

    fn fwd_stats(&mut self, x: &[f32], y: &[i32]) -> anyhow::Result<BatchStats> {
        (**self).fwd_stats(x, y)
    }

    fn fwd_embed(&mut self, x: &[f32], y: &[i32]) -> anyhow::Result<EmbedStats> {
        (**self).fwd_embed(x, y)
    }
}

impl StateExchange for Box<dyn ReplicaBackend> {
    fn export_state(&self) -> anyhow::Result<Vec<Vec<f32>>> {
        (**self).export_state()
    }

    fn import_state(&mut self, state: &[Vec<f32>]) -> anyhow::Result<()> {
        (**self).import_state(state)
    }

    fn export_params(&self) -> anyhow::Result<Vec<Vec<f32>>> {
        (**self).export_params()
    }

    fn export_momentum(&self) -> anyhow::Result<Option<Vec<Vec<f32>>>> {
        (**self).export_momentum()
    }

    fn import_params(&mut self, params: &[Vec<f32>]) -> anyhow::Result<()> {
        (**self).import_params(params)
    }

    fn export_snapshot(&self, tier: SnapshotTier) -> anyhow::Result<Snapshot> {
        (**self).export_snapshot(tier)
    }

    fn import_snapshot(&mut self, snap: &Snapshot) -> anyhow::Result<()> {
        (**self).import_snapshot(snap)
    }
}

/// A `Send` constructor for a worker-local replica.
///
/// Invoked once, on the lane thread that will own the replica; the
/// returned backend starts bitwise-identical (same parameters, same
/// optimizer state) to the primary backend the builder was derived from.
/// The builder itself carries only `Send` host data, so the replica's
/// non-`Send` device state never crosses a thread boundary.
pub type ReplicaBuilder = Box<dyn FnOnce() -> anyhow::Result<Box<dyn ReplicaBackend>> + Send>;

/// A backend whose model state can be replicated across data-parallel
/// workers and merged back by parameter averaging.
///
/// The contract the worker pool relies on:
///
/// * [`DataParallel::replica_builder`] yields a constructor whose replica
///   is *bitwise-identical* in behaviour to `self` at builder-creation
///   time, so W freshly built workers running forward passes produce
///   exactly the stats a single stream would.
/// * The [`StateExchange`] round-trip preserves f32 bit patterns exactly,
///   so the pool's fixed worker-order averaging fold is deterministic run
///   to run.
pub trait DataParallel: StepBackend + StateExchange {
    /// A `Send` constructor that builds an independent replica with state
    /// identical to `self`'s current state, on whatever thread invokes it.
    fn replica_builder(&self) -> anyhow::Result<ReplicaBuilder>;

    /// Cache key for replica reuse: the worker pool keeps its persistent
    /// replica lanes only while this key (and the worker count) is
    /// unchanged, so replicas built for one backend are never fed another
    /// backend's state.  Implementations should fold in whatever
    /// identifies the replica's *construction* (model variant, artifact
    /// source) — not its mutable state, which is re-synced every run.
    fn replica_cache_key(&self) -> String {
        "default".into()
    }
}

/// Accumulate `other` into `acc` elementwise (one fold step of the pool's
/// fixed worker-order parameter reduction).
pub fn accumulate_state(acc: &mut [Vec<f32>], other: &[Vec<f32>]) -> anyhow::Result<()> {
    anyhow::ensure!(
        acc.len() == other.len(),
        "state leaf count mismatch: {} vs {}",
        acc.len(),
        other.len()
    );
    for (a, o) in acc.iter_mut().zip(other) {
        anyhow::ensure!(a.len() == o.len(), "state leaf shape mismatch");
        for (x, y) in a.iter_mut().zip(o) {
            *x += y;
        }
    }
    Ok(())
}

/// Finish the parameter average: divide every accumulated element by the
/// worker count.  Division (not multiplication by a reciprocal) keeps the
/// W = 1 path exact and powers of two bitwise-lossless.
pub fn finish_average(acc: &mut [Vec<f32>], workers: usize) {
    let w = workers as f32;
    for leaf in acc.iter_mut() {
        for v in leaf.iter_mut() {
            *v /= w;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_of_identical_states_is_identity_for_pow2() {
        let state = vec![vec![0.1f32, -2.5, 3.75], vec![1.0e-7]];
        for w in [1usize, 2, 4] {
            let mut acc = state.clone();
            for _ in 1..w {
                accumulate_state(&mut acc, &state).unwrap();
            }
            finish_average(&mut acc, w);
            let got: Vec<u32> = acc.iter().flatten().map(|v| v.to_bits()).collect();
            let want: Vec<u32> = state.iter().flatten().map(|v| v.to_bits()).collect();
            assert_eq!(got, want, "w={w}");
        }
    }

    #[test]
    fn mismatched_shapes_rejected() {
        let mut a = vec![vec![1.0f32; 3]];
        assert!(accumulate_state(&mut a, &[vec![1.0f32; 2]]).is_err());
        assert!(accumulate_state(&mut a, &[vec![1.0f32; 3], vec![0.0]]).is_err());
    }

    #[test]
    fn typed_snapshot_round_trip_on_stateless_backend() {
        use crate::engine::testbed::MockBackend;
        let mut a = MockBackend::new();
        a.param = 2.5;
        let p = a.export_snapshot(SnapshotTier::Params).unwrap();
        assert_eq!(p.tier(), SnapshotTier::Params);
        let f = a.export_snapshot(SnapshotTier::Full).unwrap();
        assert_eq!(f.tier(), SnapshotTier::Full);
        // a stateless backend's full tier carries no momentum section
        assert!(f.momentum().is_none());
        let mut b = MockBackend::new();
        b.import_snapshot(&p).unwrap();
        assert_eq!(b.param.to_bits(), a.param.to_bits());
        let mut c = MockBackend::new();
        c.import_snapshot(&f).unwrap();
        assert_eq!(c.param.to_bits(), a.param.to_bits());
    }

    #[test]
    fn builders_cross_threads_and_replicas_match() {
        use crate::engine::testbed::MockBackend;
        let mut primary = MockBackend::new();
        primary.param = 0.6180339;
        let builder = primary.replica_builder().unwrap();
        let bits = std::thread::spawn(move || {
            let replica = builder().unwrap();
            let state = replica.export_state().unwrap();
            state[0][0].to_bits()
        })
        .join()
        .unwrap();
        assert_eq!(bits, primary.param.to_bits());
    }
}
