//! The four thin mode adapters behind the engine's [`StepSink`] interface:
//!
//! * [`TrainSink`]   — train-with-weights: record per-sample stats + mean
//!   loss while the backend takes SGD steps (plain / ISWR / InfoBatch).
//! * [`RefreshSink`] — forward-stats: hidden-list stat refresh (paper
//!   step D.1), records without loss aggregation.
//! * [`SbSink`]      — Selective-Backprop accept-queue: record + CDF^beta
//!   acceptance on the forward stream, immediate backprop of full accepted
//!   batches via [`StepCtx::step_now`].
//! * [`EvalSink`]    — eval-accumulate: top-1 correct + loss sums over the
//!   validation set.
//!
//! [`execute_plan`] is the coordinator-facing entry point: it consumes the
//! strategy's `BatchMode` and routes the epoch order through the right
//! sink, so the trainer never matches on execution modes itself.

use super::{Engine, StepBackend, StepCtx, StepMode, StepSink};
use crate::data::Dataset;
use crate::runtime::BatchStats;
use crate::state::SampleState;
use crate::strategies::sb::SbSelector;
use crate::strategies::BatchMode;
use crate::util::rng::Rng;

/// What one epoch's execution produced (fed into `EpochRecord`).
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochOutcome {
    pub trained_samples: usize,
    pub backprop_samples: usize,
    pub train_loss: f64,
}

/// Train-with-weights adapter: record stats for every real slot and
/// accumulate the epoch's mean training loss.
pub struct TrainSink<'a> {
    state: &'a mut SampleState,
    epoch: u32,
    loss_sum: f64,
    loss_n: usize,
}

impl<'a> TrainSink<'a> {
    pub fn new(state: &'a mut SampleState, epoch: u32) -> Self {
        TrainSink { state, epoch, loss_sum: 0.0, loss_n: 0 }
    }

    pub fn mean_loss(&self) -> f64 {
        self.loss_sum / self.loss_n.max(1) as f64
    }
}

impl StepSink for TrainSink<'_> {
    fn on_batch(
        &mut self,
        _ctx: &mut StepCtx,
        slots: &[u32],
        real: usize,
        stats: &BatchStats,
    ) -> anyhow::Result<()> {
        for (slot, &sample) in slots[..real].iter().enumerate() {
            self.state.record(
                sample as usize,
                stats.loss[slot],
                stats.correct[slot] > 0.5,
                stats.conf[slot],
                self.epoch,
            );
            self.loss_sum += stats.loss[slot] as f64;
            self.loss_n += 1;
        }
        Ok(())
    }
}

/// Forward-stats adapter: hidden-list refresh (record only).
pub struct RefreshSink<'a> {
    state: &'a mut SampleState,
    epoch: u32,
}

impl<'a> RefreshSink<'a> {
    pub fn new(state: &'a mut SampleState, epoch: u32) -> Self {
        RefreshSink { state, epoch }
    }
}

impl StepSink for RefreshSink<'_> {
    fn on_batch(
        &mut self,
        _ctx: &mut StepCtx,
        slots: &[u32],
        real: usize,
        stats: &BatchStats,
    ) -> anyhow::Result<()> {
        for (slot, &sample) in slots[..real].iter().enumerate() {
            self.state.record(
                sample as usize,
                stats.loss[slot],
                stats.correct[slot] > 0.5,
                stats.conf[slot],
                self.epoch,
            );
        }
        Ok(())
    }
}

/// Selective-Backprop adapter: the candidate stream arrives as forward
/// batches; accepted samples queue up and backprop in full batches the
/// moment the queue fills (and once more, padded, at epoch end).
pub struct SbSink<'a> {
    state: &'a mut SampleState,
    sb: &'a mut SbSelector,
    rng: &'a mut Rng,
    queue: &'a mut Vec<u32>,
    batch: usize,
    lr: f32,
    epoch: u32,
    backprop: usize,
    loss_sum: f64,
    loss_n: usize,
}

impl<'a> SbSink<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        state: &'a mut SampleState,
        sb: &'a mut SbSelector,
        rng: &'a mut Rng,
        queue: &'a mut Vec<u32>,
        batch: usize,
        lr: f32,
        epoch: u32,
    ) -> Self {
        queue.clear();
        SbSink {
            state,
            sb,
            rng,
            queue,
            batch,
            lr,
            epoch,
            backprop: 0,
            loss_sum: 0.0,
            loss_n: 0,
        }
    }

    pub fn mean_loss(&self) -> f64 {
        self.loss_sum / self.loss_n.max(1) as f64
    }

    pub fn backprop_samples(&self) -> usize {
        self.backprop
    }
}

impl StepSink for SbSink<'_> {
    fn on_batch(
        &mut self,
        ctx: &mut StepCtx,
        slots: &[u32],
        real: usize,
        stats: &BatchStats,
    ) -> anyhow::Result<()> {
        for (slot, &sample) in slots[..real].iter().enumerate() {
            self.state.record(
                sample as usize,
                stats.loss[slot],
                stats.correct[slot] > 0.5,
                stats.conf[slot],
                self.epoch,
            );
            self.loss_sum += stats.loss[slot] as f64;
            self.loss_n += 1;
            if self.sb.accept(stats.loss[slot], self.rng) {
                self.queue.push(sample);
            }
        }
        while self.queue.len() >= self.batch {
            let batch: Vec<u32> = self.queue.drain(..self.batch).collect();
            ctx.step_now(&batch, None, StepMode::Train { lr: self.lr })?;
            self.backprop += self.batch;
        }
        Ok(())
    }

    fn finish(&mut self, ctx: &mut StepCtx) -> anyhow::Result<()> {
        if !self.queue.is_empty() {
            let batch: Vec<u32> = self.queue.drain(..).collect();
            ctx.step_now(&batch, None, StepMode::Train { lr: self.lr })?;
            self.backprop += batch.len();
        }
        Ok(())
    }
}

/// Eval-accumulate adapter: validation top-1 accuracy + mean loss.
#[derive(Default)]
pub struct EvalSink {
    correct: f64,
    loss: f64,
    n: usize,
}

impl EvalSink {
    /// (top-1 accuracy, mean loss).
    pub fn result(&self) -> (f64, f64) {
        let n = self.n.max(1) as f64;
        (self.correct / n, self.loss / n)
    }
}

impl StepSink for EvalSink {
    fn on_batch(
        &mut self,
        _ctx: &mut StepCtx,
        _slots: &[u32],
        real: usize,
        stats: &BatchStats,
    ) -> anyhow::Result<()> {
        for slot in 0..real {
            self.correct += stats.correct[slot] as f64;
            self.loss += stats.loss[slot] as f64;
            self.n += 1;
        }
        Ok(())
    }
}

/// Execute one planned epoch order: consumes the strategy's `BatchMode`
/// and drives the engine with the matching sink.  The coordinator only
/// plans (selection, sharding, LR); execution-mode dispatch lives here.
#[allow(clippy::too_many_arguments)]
pub fn execute_plan(
    engine: &mut Engine,
    backend: &mut dyn StepBackend,
    data: &Dataset,
    order: &[u32],
    weights: Option<&[f32]>,
    batch_mode: BatchMode,
    lr: f32,
    epoch: u32,
    state: &mut SampleState,
    sb: &mut SbSelector,
    rng: &mut Rng,
    sb_queue: &mut Vec<u32>,
) -> anyhow::Result<EpochOutcome> {
    match batch_mode {
        BatchMode::Plain => {
            let mut sink = TrainSink::new(state, epoch);
            engine.run(backend, data, order, weights, StepMode::Train { lr }, &mut sink)?;
            Ok(EpochOutcome {
                trained_samples: order.len(),
                backprop_samples: order.len(),
                train_loss: sink.mean_loss(),
            })
        }
        // beta lives inside the trainer's SbSelector; the plan's copy is
        // informational (strategy naming / diagnostics).
        BatchMode::SelectiveBackprop { .. } => {
            let batch = engine.batch();
            let mut sink = SbSink::new(state, sb, rng, sb_queue, batch, lr, epoch);
            engine.run(backend, data, order, None, StepMode::Forward, &mut sink)?;
            Ok(EpochOutcome {
                trained_samples: order.len(),
                backprop_samples: sink.backprop_samples(),
                train_loss: sink.mean_loss(),
            })
        }
    }
}
