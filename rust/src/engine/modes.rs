//! The four thin mode adapters behind the engine's [`StepSink`] interface:
//!
//! * [`TrainSink`]   — train-with-weights: record per-sample stats + mean
//!   loss while the backend takes SGD steps (plain / ISWR / InfoBatch).
//! * [`RefreshSink`] — forward-stats: hidden-list stat refresh (paper
//!   step D.1), records without loss aggregation.
//! * [`SbSink`]      — Selective-Backprop accept-queue: record + CDF^beta
//!   acceptance on the forward stream, immediate backprop of full accepted
//!   batches via [`StepCtx::step_now`].
//! * [`EvalSink`]    — eval-accumulate: top-1 correct + loss sums over the
//!   validation set.
//! * [`EmbedSink`]   — feature harvest: store penultimate-layer embedding
//!   rows into the coordinator's [`FeatureCache`] (PFB's amortized
//!   scoring pass), recording the ride-along stats like a refresh.
//!
//! [`execute_plan`] is the coordinator-facing entry point: it consumes the
//! strategy's `BatchMode` and routes the epoch order through the right
//! sink, so the trainer never matches on execution modes itself.

use super::backend::DataParallel;
use super::pool::{PoolOutcome, WorkerPool};
use super::{Engine, StepBackend, StepCtx, StepMode, StepSink};
use crate::data::shard::Shard;
use crate::data::Dataset;
use crate::runtime::{BatchStats, EmbedStats};
use crate::state::{FeatureCache, SampleState};
use crate::strategies::sb::SbSelector;
use crate::strategies::BatchMode;
use crate::util::rng::Rng;

/// What one epoch's execution produced (fed into `EpochRecord`).
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochOutcome {
    /// Samples that went through a training-path forward pass.
    pub trained_samples: usize,
    /// Samples whose gradients were actually applied (differs from
    /// `trained_samples` for Selective-Backprop).
    pub backprop_samples: usize,
    /// Mean training loss over the epoch's training passes.
    pub train_loss: f64,
}

/// Train-with-weights adapter: record stats for every real slot and
/// accumulate the epoch's mean training loss.
pub struct TrainSink<'a> {
    state: &'a mut SampleState,
    epoch: u32,
    loss_sum: f64,
    loss_n: usize,
}

impl<'a> TrainSink<'a> {
    /// A sink recording into `state`, stamping updates with `epoch`.
    pub fn new(state: &'a mut SampleState, epoch: u32) -> Self {
        TrainSink { state, epoch, loss_sum: 0.0, loss_n: 0 }
    }

    /// Mean loss over every real slot consumed so far.
    pub fn mean_loss(&self) -> f64 {
        self.loss_sum / self.loss_n.max(1) as f64
    }
}

impl StepSink for TrainSink<'_> {
    fn on_batch(
        &mut self,
        _ctx: &mut StepCtx,
        slots: &[u32],
        real: usize,
        stats: &BatchStats,
    ) -> anyhow::Result<()> {
        for (slot, &sample) in slots[..real].iter().enumerate() {
            self.state.record(
                sample as usize,
                stats.loss[slot],
                stats.correct[slot] > 0.5,
                stats.conf[slot],
                self.epoch,
            );
            self.loss_sum += stats.loss[slot] as f64;
            self.loss_n += 1;
        }
        Ok(())
    }
}

/// Forward-stats adapter: hidden-list refresh (record only).
pub struct RefreshSink<'a> {
    state: &'a mut SampleState,
    epoch: u32,
}

impl<'a> RefreshSink<'a> {
    /// A sink recording refreshed stats into `state` at `epoch`.
    pub fn new(state: &'a mut SampleState, epoch: u32) -> Self {
        RefreshSink { state, epoch }
    }
}

impl StepSink for RefreshSink<'_> {
    fn on_batch(
        &mut self,
        _ctx: &mut StepCtx,
        slots: &[u32],
        real: usize,
        stats: &BatchStats,
    ) -> anyhow::Result<()> {
        for (slot, &sample) in slots[..real].iter().enumerate() {
            self.state.record(
                sample as usize,
                stats.loss[slot],
                stats.correct[slot] > 0.5,
                stats.conf[slot],
                self.epoch,
            );
        }
        Ok(())
    }
}

/// Selective-Backprop adapter: the candidate stream arrives as forward
/// batches; accepted samples queue up and backprop in full batches the
/// moment the queue fills (and once more, padded, at epoch end).
pub struct SbSink<'a> {
    state: &'a mut SampleState,
    sb: &'a mut SbSelector,
    rng: &'a mut Rng,
    queue: &'a mut Vec<u32>,
    batch: usize,
    lr: f32,
    epoch: u32,
    backprop: usize,
    loss_sum: f64,
    loss_n: usize,
}

impl<'a> SbSink<'a> {
    /// An accept-queue sink over the trainer's persistent `queue`,
    /// backpropagating accepted samples in `batch`-sized steps at `lr`.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        state: &'a mut SampleState,
        sb: &'a mut SbSelector,
        rng: &'a mut Rng,
        queue: &'a mut Vec<u32>,
        batch: usize,
        lr: f32,
        epoch: u32,
    ) -> Self {
        queue.clear();
        SbSink {
            state,
            sb,
            rng,
            queue,
            batch,
            lr,
            epoch,
            backprop: 0,
            loss_sum: 0.0,
            loss_n: 0,
        }
    }

    /// Mean loss over the candidate forward stream.
    pub fn mean_loss(&self) -> f64 {
        self.loss_sum / self.loss_n.max(1) as f64
    }

    /// Samples whose gradients were applied via the accept queue.
    pub fn backprop_samples(&self) -> usize {
        self.backprop
    }
}

impl StepSink for SbSink<'_> {
    fn on_batch(
        &mut self,
        ctx: &mut StepCtx,
        slots: &[u32],
        real: usize,
        stats: &BatchStats,
    ) -> anyhow::Result<()> {
        for (slot, &sample) in slots[..real].iter().enumerate() {
            self.state.record(
                sample as usize,
                stats.loss[slot],
                stats.correct[slot] > 0.5,
                stats.conf[slot],
                self.epoch,
            );
            self.loss_sum += stats.loss[slot] as f64;
            self.loss_n += 1;
            if self.sb.accept(stats.loss[slot], self.rng) {
                self.queue.push(sample);
            }
        }
        while self.queue.len() >= self.batch {
            let batch: Vec<u32> = self.queue.drain(..self.batch).collect();
            ctx.step_now(&batch, None, StepMode::Train { lr: self.lr })?;
            self.backprop += self.batch;
        }
        Ok(())
    }

    fn finish(&mut self, ctx: &mut StepCtx) -> anyhow::Result<()> {
        if !self.queue.is_empty() {
            let batch: Vec<u32> = self.queue.drain(..).collect();
            ctx.step_now(&batch, None, StepMode::Train { lr: self.lr })?;
            self.backprop += batch.len();
        }
        Ok(())
    }
}

/// Eval-accumulate adapter: validation top-1 accuracy + mean loss.
#[derive(Default)]
pub struct EvalSink {
    correct: f64,
    loss: f64,
    n: usize,
}

impl EvalSink {
    /// Fold one executed batch's real slots into the running sums.  This
    /// single accumulation path is shared by the engine's step loop
    /// (via [`StepSink::on_batch`]) and the async service lane's eval
    /// (`engine/service.rs`), so the async-bitwise-equals-sync contract
    /// holds structurally instead of by two hand-synchronized loops.
    pub fn accumulate(&mut self, real: usize, stats: &BatchStats) {
        for slot in 0..real {
            self.correct += stats.correct[slot] as f64;
            self.loss += stats.loss[slot] as f64;
            self.n += 1;
        }
    }

    /// (top-1 accuracy, mean loss).
    pub fn result(&self) -> (f64, f64) {
        let n = self.n.max(1) as f64;
        (self.correct / n, self.loss / n)
    }
}

impl StepSink for EvalSink {
    fn on_batch(
        &mut self,
        _ctx: &mut StepCtx,
        _slots: &[u32],
        real: usize,
        stats: &BatchStats,
    ) -> anyhow::Result<()> {
        self.accumulate(real, stats);
        Ok(())
    }
}

/// Feature-harvest adapter: store each real slot's embedding row into the
/// [`FeatureCache`] and record the ride-along stats (the embed pass
/// doubles as a full stat refresh, so PFB's per-sample diagnostics never
/// go stale even though it skips the hidden-list refresh).
///
/// Only legal under [`StepMode::Embed`]: a batch arriving through
/// [`StepSink::on_batch`] means the caller dispatched the wrong mode, and
/// the sink errors instead of silently caching nothing.
pub struct EmbedSink<'a> {
    cache: &'a mut FeatureCache,
    state: &'a mut SampleState,
    epoch: u32,
    started: bool,
}

impl<'a> EmbedSink<'a> {
    /// A sink harvesting into `cache`, stamping stat updates with `epoch`.
    /// The cache's row width is taken from the first executed batch
    /// (`emb.len() / slots`), so the same sink serves any embedding head.
    pub fn new(cache: &'a mut FeatureCache, state: &'a mut SampleState, epoch: u32) -> Self {
        EmbedSink { cache, state, epoch, started: false }
    }
}

impl StepSink for EmbedSink<'_> {
    fn on_batch(
        &mut self,
        _ctx: &mut StepCtx,
        _slots: &[u32],
        _real: usize,
        _stats: &BatchStats,
    ) -> anyhow::Result<()> {
        anyhow::bail!("EmbedSink consumes embedding steps only (use StepMode::Embed)")
    }

    fn on_embed(
        &mut self,
        _ctx: &mut StepCtx,
        slots: &[u32],
        real: usize,
        es: &EmbedStats,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(!slots.is_empty(), "embed batch with zero slots");
        let dim = es.emb.len() / slots.len();
        if !self.started {
            self.cache.begin(dim)?;
            self.started = true;
        }
        for (slot, &sample) in slots[..real].iter().enumerate() {
            self.cache.store_row(sample as usize, &es.emb[slot * dim..(slot + 1) * dim])?;
            self.state.record(
                sample as usize,
                es.stats.loss[slot],
                es.stats.correct[slot] > 0.5,
                es.stats.conf[slot],
                self.epoch,
            );
        }
        Ok(())
    }
}

/// Execute one feature-harvest sweep single-stream: drive `indices`
/// through the backend's embedding head and commit the resulting rows to
/// `cache`, stamped with `epoch`.  Inherits the engine's double-buffered
/// prefetch like every other mode.
pub fn execute_feature_harvest(
    engine: &mut Engine,
    backend: &mut dyn StepBackend,
    data: &Dataset,
    indices: &[u32],
    epoch: u32,
    state: &mut SampleState,
    cache: &mut FeatureCache,
) -> anyhow::Result<()> {
    let mut sink = EmbedSink::new(cache, state, epoch);
    engine.run(backend, data, indices, None, StepMode::Embed, &mut sink)?;
    cache.commit(epoch);
    Ok(())
}

/// Execute one feature-harvest sweep through the worker pool's
/// serial-equivalent schedule: worker `w` gathers `shards[w]`, every
/// embed step runs on the primary in fixed `(step, worker)` order, and
/// the committed cache is bitwise identical to the single-stream sweep
/// (the same contract as the hidden-stat refresh, chaos/elastic semantics
/// included).  Returns the pool's accounting for the metrics roll-up.
#[allow(clippy::too_many_arguments)]
pub fn execute_sharded_harvest(
    pool: &mut WorkerPool,
    backend: &mut dyn StepBackend,
    data: &Dataset,
    shards: &[Shard],
    epoch: u32,
    state: &mut SampleState,
    cache: &mut FeatureCache,
) -> anyhow::Result<PoolOutcome> {
    let mut sink = EmbedSink::new(cache, state, epoch);
    let pout = pool.run_serial_equivalent(backend, data, shards, StepMode::Embed, &mut sink)?;
    cache.commit(epoch);
    Ok(pout)
}

/// Execute one planned epoch order: consumes the strategy's `BatchMode`
/// and drives the engine with the matching sink.  The coordinator only
/// plans (selection, sharding, LR); execution-mode dispatch lives here.
#[allow(clippy::too_many_arguments)]
pub fn execute_plan(
    engine: &mut Engine,
    backend: &mut dyn StepBackend,
    data: &Dataset,
    order: &[u32],
    weights: Option<&[f32]>,
    batch_mode: BatchMode,
    lr: f32,
    epoch: u32,
    state: &mut SampleState,
    sb: &mut SbSelector,
    rng: &mut Rng,
    sb_queue: &mut Vec<u32>,
) -> anyhow::Result<EpochOutcome> {
    match batch_mode {
        BatchMode::Plain => {
            let mut sink = TrainSink::new(state, epoch);
            engine.run(backend, data, order, weights, StepMode::Train { lr }, &mut sink)?;
            Ok(EpochOutcome {
                trained_samples: order.len(),
                backprop_samples: order.len(),
                train_loss: sink.mean_loss(),
            })
        }
        // beta lives inside the trainer's SbSelector; the plan's copy is
        // informational (strategy naming / diagnostics).
        BatchMode::SelectiveBackprop { .. } => {
            let batch = engine.batch();
            let mut sink = SbSink::new(state, sb, rng, sb_queue, batch, lr, epoch);
            engine.run(backend, data, order, None, StepMode::Forward, &mut sink)?;
            Ok(EpochOutcome {
                trained_samples: order.len(),
                backprop_samples: sink.backprop_samples(),
                train_loss: sink.mean_loss(),
            })
        }
    }
}

/// Execute one planned epoch's plain (unweighted) training pass through
/// the worker pool: worker `w` trains `shards[w]` behind the pool's
/// bulk-synchronous barrier and deterministic `(step, worker)` reduction.
///
/// The serial-equivalent schedule makes this bitwise identical to
/// [`execute_plan`] with `BatchMode::Plain` over
/// [`crate::data::shard::global_batch_order`] — enforced by
/// `tests/worker_pool_determinism.rs`.  Returns the epoch outcome plus the
/// pool's per-worker accounting for the metrics roll-up.
pub fn execute_sharded_plain(
    pool: &mut WorkerPool,
    backend: &mut dyn StepBackend,
    data: &Dataset,
    shards: &[Shard],
    lr: f32,
    epoch: u32,
    state: &mut SampleState,
) -> anyhow::Result<(EpochOutcome, PoolOutcome)> {
    let mut sink = TrainSink::new(state, epoch);
    let pout =
        pool.run_serial_equivalent(backend, data, shards, StepMode::Train { lr }, &mut sink)?;
    let outcome = EpochOutcome {
        trained_samples: pout.samples,
        backprop_samples: pout.samples,
        train_loss: sink.mean_loss(),
    };
    Ok((outcome, pout))
}

/// Execute one planned epoch's plain (unweighted) training pass through
/// the worker pool's **data-parallel** schedule (`--dp average`): worker
/// `w` trains its own replica of `backend` over `shards[w]`, and replica
/// parameters are averaged in fixed worker order at every step barrier —
/// true synchronous SGD with a global batch of `W × B` samples.
///
/// Deterministic run to run (same fixed-order reduction as the
/// serial-equivalent schedule) but *not* bitwise serial-equivalent for
/// train passes: all `W` batches of a step see the same pre-step
/// parameters, where the serial schedule updates between them.  See
/// docs/worker-model.md for when to pick which schedule.
pub fn execute_sharded_average<B: DataParallel>(
    pool: &mut WorkerPool,
    backend: &mut B,
    data: &Dataset,
    shards: &[Shard],
    lr: f32,
    epoch: u32,
    state: &mut SampleState,
) -> anyhow::Result<(EpochOutcome, PoolOutcome)> {
    let mut sink = TrainSink::new(state, epoch);
    let pout = pool.run_data_parallel(backend, data, shards, StepMode::Train { lr }, &mut sink)?;
    let outcome = EpochOutcome {
        trained_samples: pout.samples,
        backprop_samples: pout.samples,
        train_loss: sink.mean_loss(),
    };
    Ok((outcome, pout))
}
