//! Deterministic fault injection for the worker pool — the chaos harness.
//!
//! At fleet scale, worker lanes die mid-epoch, stragglers stall the
//! bulk-synchronous barrier, and state exports fail.  The fault-tolerance
//! contract (docs/worker-model.md, "Fault tolerance") is only provable if
//! those failures can be produced *on demand and reproducibly*; this
//! module is that injection surface.
//!
//! A [`ChaosPlan`] is a scripted (or seeded, via [`ChaosPlan::randomized`])
//! list of [`ChaosEvent`]s — *kill lane `w` at step `s`*, *delay lane `w`
//! by `d` ms at step `s`*, *fail lane `w`'s next state export after step
//! `s`*.  Two consumers execute a plan:
//!
//! * **The pool's gather lanes**
//!   ([`inject_chaos`](crate::engine::WorkerPool::inject_chaos)): a
//!   killed gather lane stops delivering batches (its
//!   channel disconnects, exactly like a crashed prefetch thread), a
//!   delayed lane sleeps before filling — the host-side failure modes of
//!   the serial-equivalent schedule.
//! * **[`ChaosBackend`]**, a [`StepBackend`]/[`StateExchange`]/
//!   [`DataParallel`] wrapper threaded through the [`ReplicaBuilder`]
//!   contract: replicas built from a chaos-wrapped primary inherit the
//!   plan and their worker rank (assigned in builder-creation order, which
//!   is the pool's worker order), so a device-side kill, stall, or export
//!   failure fires on exactly the scripted `(worker, step)` — the replica
//!   failure modes of the `--dp average` schedule.
//!
//! Everything is deterministic: plans are plain data, worker ranks are
//! assigned in a fixed order, and step counters are lane-local — the same
//! plan against the same run produces the same failure at the same
//! barrier, which is what lets `tests/chaos_harness.rs` assert that
//! elastic recovery is *bitwise identical* to the undisturbed run.
//!
//! This is test infrastructure: the wrapper routes every export through
//! the flat [`StateExchange::export_state`] path (so the injected export
//! failure cannot be bypassed by a tier fast path) and is not meant to
//! wrap the production executor in real runs.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::backend::{DataParallel, ReplicaBuilder, StateExchange, StepBackend};
use crate::runtime::BatchStats;
use crate::util::rng::Rng;

/// What a [`ChaosEvent`] does to its target lane when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosAction {
    /// The lane dies: the step fails with a named `chaos:` error and the
    /// lane thread exits, exactly like a crashed worker.
    Kill,
    /// The lane stalls for this many milliseconds before executing the
    /// step — a straggler.
    Delay(u64),
    /// The step itself succeeds but the lane's next state export fails —
    /// a lost allreduce contribution.
    FailExport,
}

/// One scripted injection: `action` fires when lane `worker` reaches its
/// lane-local step `step`.
#[derive(Clone, Copy, Debug)]
pub struct ChaosEvent {
    /// Target worker rank (gather lane or replica lane).
    pub worker: usize,
    /// Lane-local step index at which the action fires.
    pub step: usize,
    /// What happens.
    pub action: ChaosAction,
}

/// A deterministic, scriptable fault-injection plan: an ordered list of
/// [`ChaosEvent`]s.  When several events target the same `(worker, step)`,
/// the first one wins.
#[derive(Clone, Debug, Default)]
pub struct ChaosPlan {
    events: Vec<ChaosEvent>,
}

impl ChaosPlan {
    /// An empty plan (injects nothing).
    pub fn new() -> Self {
        ChaosPlan::default()
    }

    /// Script a lane kill: worker `worker` dies at step `step`.
    pub fn kill(mut self, worker: usize, step: usize) -> Self {
        self.events.push(ChaosEvent { worker, step, action: ChaosAction::Kill });
        self
    }

    /// Script a straggler: worker `worker` stalls `ms` milliseconds before
    /// executing step `step`.
    pub fn delay(mut self, worker: usize, step: usize, ms: u64) -> Self {
        self.events.push(ChaosEvent { worker, step, action: ChaosAction::Delay(ms) });
        self
    }

    /// Script an export failure: worker `worker`'s state export after step
    /// `step` fails (device-side lanes only — gather lanes export nothing).
    pub fn fail_export(mut self, worker: usize, step: usize) -> Self {
        self.events.push(ChaosEvent { worker, step, action: ChaosAction::FailExport });
        self
    }

    /// A seeded random plan over `workers` lanes and `steps` steps: one
    /// kill, plus (when more than one lane exists) sometimes a short delay
    /// on a *different* lane.  Same seed, same plan — the CI chaos matrix
    /// sweeps seeds, not timings.
    pub fn randomized(seed: u64, workers: usize, steps: usize) -> Self {
        let mut plan = ChaosPlan::default();
        if workers == 0 || steps == 0 {
            return plan;
        }
        let mut rng = Rng::new(seed);
        let kw = rng.below(workers);
        plan = plan.kill(kw, rng.below(steps));
        if workers > 1 && rng.chance(0.5) {
            let mut dw = rng.below(workers);
            if dw == kw {
                dw = (dw + 1) % workers;
            }
            plan = plan.delay(dw, rng.below(steps), 1 + rng.below(5) as u64);
        }
        plan
    }

    /// The scripted events, in script order.
    pub fn events(&self) -> &[ChaosEvent] {
        &self.events
    }

    /// Whether the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The action (if any) that fires when `worker` reaches step `step`.
    pub fn action(&self, worker: usize, step: usize) -> Option<ChaosAction> {
        self.events
            .iter()
            .find(|e| e.worker == worker && e.step == step)
            .map(|e| e.action)
    }
}

/// A backend wrapper that executes a [`ChaosPlan`] on the device side.
///
/// Wrap the primary with [`ChaosBackend::primary`] and hand it to the pool
/// as usual: the primary itself is never targeted (its rank is
/// `usize::MAX`), but every replica built through
/// [`DataParallel::replica_builder`] inherits the plan plus the next
/// worker rank (0, 1, … in builder-creation order — the pool builds lane
/// builders sequentially in worker order, so ranks line up with
/// [`crate::data::shard::Shard::worker`]).  Each replica counts its own
/// steps; when its `(worker, step)` matches a scripted event the action
/// fires: [`ChaosAction::Kill`] fails the step with a named error,
/// [`ChaosAction::Delay`] sleeps first, [`ChaosAction::FailExport`] arms
/// a one-shot failure of the next [`StateExchange::export_state`] call.
///
/// Create a fresh wrapper per run: worker ranks are handed out
/// monotonically from the wrapped primary, and replica step counters live
/// for the replica's (persistent-lane) lifetime.
pub struct ChaosBackend<B> {
    inner: B,
    plan: Arc<ChaosPlan>,
    worker: usize,
    step: usize,
    fail_export: Cell<bool>,
    next_worker: Arc<AtomicUsize>,
}

impl<B> ChaosBackend<B> {
    /// Wrap the primary backend; replicas built from it inherit `plan`.
    pub fn primary(inner: B, plan: ChaosPlan) -> Self {
        ChaosBackend {
            inner,
            plan: Arc::new(plan),
            worker: usize::MAX,
            step: 0,
            fail_export: Cell::new(false),
            next_worker: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &B {
        &self.inner
    }

    /// The wrapped backend, mutably.
    pub fn inner_mut(&mut self) -> &mut B {
        &mut self.inner
    }

    /// Consult the plan for this lane's current step (then advance it).
    fn inject(&mut self) -> anyhow::Result<()> {
        let s = self.step;
        self.step += 1;
        match self.plan.action(self.worker, s) {
            Some(ChaosAction::Kill) => {
                anyhow::bail!("chaos: worker {} killed at step {s}", self.worker)
            }
            Some(ChaosAction::Delay(ms)) => std::thread::sleep(Duration::from_millis(ms)),
            Some(ChaosAction::FailExport) => self.fail_export.set(true),
            None => {}
        }
        Ok(())
    }
}

impl<B: StepBackend> StepBackend for ChaosBackend<B> {
    fn train_step(
        &mut self,
        x: &[f32],
        y: &[i32],
        sw: &[f32],
        lr: f32,
    ) -> anyhow::Result<BatchStats> {
        self.inject()?;
        self.inner.train_step(x, y, sw, lr)
    }

    fn fwd_stats(&mut self, x: &[f32], y: &[i32]) -> anyhow::Result<BatchStats> {
        self.inject()?;
        self.inner.fwd_stats(x, y)
    }

    fn fwd_embed(&mut self, x: &[f32], y: &[i32]) -> anyhow::Result<crate::runtime::EmbedStats> {
        self.inject()?;
        self.inner.fwd_embed(x, y)
    }
}

impl<B: StateExchange> StateExchange for ChaosBackend<B> {
    // Only the two required methods are implemented, so every tiered
    // export/import default routes through this pair and the injected
    // export failure cannot be bypassed by a fast path.
    fn export_state(&self) -> anyhow::Result<Vec<Vec<f32>>> {
        if self.fail_export.take() {
            anyhow::bail!("chaos: worker {} state export failed", self.worker);
        }
        self.inner.export_state()
    }

    fn import_state(&mut self, state: &[Vec<f32>]) -> anyhow::Result<()> {
        self.inner.import_state(state)
    }
}

impl<B: DataParallel> DataParallel for ChaosBackend<B> {
    fn replica_builder(&self) -> anyhow::Result<ReplicaBuilder> {
        let worker = self.next_worker.fetch_add(1, Ordering::SeqCst);
        let plan = Arc::clone(&self.plan);
        let build = self.inner.replica_builder()?;
        Ok(Box::new(move || {
            let replica = build()?;
            Ok(Box::new(ChaosBackend {
                inner: replica,
                plan,
                worker,
                step: 0,
                fail_export: Cell::new(false),
                next_worker: Arc::new(AtomicUsize::new(0)),
            }) as Box<dyn super::backend::ReplicaBackend>)
        }))
    }

    fn replica_cache_key(&self) -> String {
        // never share lanes with the unwrapped backend: replicas must
        // carry the plan (and fresh chaos runs should not inherit stale
        // lane step counters from cached lanes)
        format!("chaos:{}", self.inner.replica_cache_key())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::testbed::MockBackend;

    #[test]
    fn plan_lookup_is_positional_and_first_wins() {
        let plan = ChaosPlan::new().kill(1, 3).delay(0, 2, 7).kill(1, 3);
        assert_eq!(plan.action(1, 3), Some(ChaosAction::Kill));
        assert_eq!(plan.action(0, 2), Some(ChaosAction::Delay(7)));
        assert_eq!(plan.action(1, 2), None);
        assert_eq!(plan.action(2, 3), None);
        assert_eq!(plan.events().len(), 3);
        assert!(ChaosPlan::new().is_empty());
    }

    #[test]
    fn randomized_plans_are_seed_deterministic_and_in_bounds() {
        for seed in 0..32u64 {
            let a = ChaosPlan::randomized(seed, 4, 6);
            let b = ChaosPlan::randomized(seed, 4, 6);
            assert_eq!(a.events().len(), b.events().len(), "seed {seed}");
            for (x, y) in a.events().iter().zip(b.events()) {
                assert_eq!((x.worker, x.step, x.action), (y.worker, y.step, y.action));
                assert!(x.worker < 4 && x.step < 6, "seed {seed}");
            }
            assert!(a.events().iter().any(|e| e.action == ChaosAction::Kill));
        }
        assert!(ChaosPlan::randomized(9, 0, 5).is_empty());
        assert!(ChaosPlan::randomized(9, 3, 0).is_empty());
    }

    #[test]
    fn kill_fires_at_the_scripted_step_only() {
        let mut be = ChaosBackend {
            inner: MockBackend::new(),
            plan: Arc::new(ChaosPlan::new().kill(2, 2)),
            worker: 2,
            step: 0,
            fail_export: Cell::new(false),
            next_worker: Arc::new(AtomicUsize::new(0)),
        };
        assert!(be.fwd_stats(&[0.5], &[1]).is_ok());
        assert!(be.fwd_stats(&[0.5], &[1]).is_ok());
        let err = be.fwd_stats(&[0.5], &[1]).unwrap_err().to_string();
        assert!(err.contains("chaos") && err.contains("killed"), "{err}");
        // the primary (rank usize::MAX) is never targeted
        let mut primary = ChaosBackend::primary(MockBackend::new(), ChaosPlan::new().kill(0, 0));
        assert!(primary.fwd_stats(&[0.5], &[1]).is_ok());
    }

    #[test]
    fn fail_export_is_one_shot_and_step_succeeds() {
        let mut be = ChaosBackend {
            inner: MockBackend::new(),
            plan: Arc::new(ChaosPlan::new().fail_export(0, 1)),
            worker: 0,
            step: 0,
            fail_export: Cell::new(false),
            next_worker: Arc::new(AtomicUsize::new(0)),
        };
        assert!(be.train_step(&[0.5], &[1], &[1.0], 0.01).is_ok());
        assert!(be.export_state().is_ok()); // step 0: nothing armed
        assert!(be.train_step(&[0.5], &[1], &[1.0], 0.01).is_ok()); // arms it
        let err = be.export_state().unwrap_err().to_string();
        assert!(err.contains("export failed"), "{err}");
        assert!(be.export_state().is_ok()); // one-shot
    }

    #[test]
    fn replicas_inherit_the_plan_with_sequential_ranks() {
        let primary = ChaosBackend::primary(MockBackend::new(), ChaosPlan::new().kill(1, 0));
        let b0 = primary.replica_builder().unwrap();
        let b1 = primary.replica_builder().unwrap();
        let mut r0 = b0().unwrap();
        let mut r1 = b1().unwrap();
        assert!(r0.fwd_stats(&[0.5], &[1]).is_ok()); // rank 0: untouched
        assert!(r1.fwd_stats(&[0.5], &[1]).is_err()); // rank 1: killed at step 0
        assert!(primary.replica_cache_key().starts_with("chaos:"));
    }

    #[test]
    fn untargeted_wrapper_is_a_pure_delegate() {
        let mut plain = MockBackend::new();
        let mut wrapped = ChaosBackend::primary(MockBackend::new(), ChaosPlan::new().kill(7, 0));
        for _ in 0..3 {
            plain.train_step(&[0.25, 0.5], &[1, 2], &[1.0, 1.0], 0.05).unwrap();
            wrapped.train_step(&[0.25, 0.5], &[1, 2], &[1.0, 1.0], 0.05).unwrap();
        }
        assert_eq!(plain.param.to_bits(), wrapped.inner().param.to_bits());
        assert_eq!(plain.trace, wrapped.inner().trace);
        assert_eq!(
            plain.export_state().unwrap(),
            wrapped.export_state().unwrap()
        );
    }
}
