//! The data-parallel worker pool: N pipelined gather lanes behind one
//! deterministic, bulk-synchronous reduction.
//!
//! # Execution model
//!
//! The coordinator shards each epoch's order with
//! [`crate::data::shard::shard_order_aligned`], so every worker owns the
//! same number of full device batches (ragged shards are tolerated: a
//! lane whose shard exhausts early retires from the step barrier instead
//! of blocking it — see docs/worker-model.md).  Each worker owns its own
//! double-buffered pipelined driver over its [`Shard`]: a gather lane
//! (one prefetch thread + two parked [`BatchAssembler`]s handed over by
//! value through channels, exactly the engine's overlap scheme) that
//! keeps filling batch `s+1` while batch `s` executes.
//!
//! Two schedules consume the lanes:
//!
//! * [`WorkerPool::run_serial_equivalent`] — the default and the
//!   determinism contract.  All device steps execute on the *primary*
//!   backend, in fixed `(step, worker)` order; only the host-side gather
//!   fans out.  The result is **bitwise identical** to a single serial
//!   stream over [`crate::data::shard::global_batch_order`] — N workers
//!   are an execution detail, not a semantics change.
//! * [`WorkerPool::run_data_parallel`] — true synchronous data-parallel
//!   SGD.  Every worker steps its own replica on a persistent *replica
//!   lane* thread; at each step barrier the pool folds the workers'
//!   [`BatchStats`] into the sink in fixed worker order and (for train
//!   steps) averages the replica parameters with the same fixed-order
//!   fold, so results are deterministic run to run.  Forward-only passes
//!   are additionally bitwise identical to the serial-equivalent schedule
//!   (parameters never change); train passes follow global-batch SGD
//!   semantics and are *not* serial-equivalent (documented in
//!   docs/worker-model.md).
//!
//! # Fault tolerance
//!
//! Under the elastic fault policy ([`WorkerPool::set_fault_policy`], the
//! trainer's `--fault-policy elastic`) a lane failure no longer aborts
//! the run.  A gather lane that dies (its channel disconnects) or stalls
//! past the straggler timeout has the unfinished tail of its shard
//! deterministically re-issued to fresh recovery lanes
//! ([`crate::data::shard::reissue_tail`]); in the data-parallel schedule
//! a dead replica lane's remaining steps execute on the primary, restored
//! to the last synchronized snapshot.  Either way every batch still
//! executes at its original `(step, worker)` barrier position, so the
//! recovered run's results are **bitwise identical** to an undisturbed
//! run over the same logical epoch order — detection timing affects
//! wall-clock only.  Under the default `fail` policy a fault aborts with
//! a named error instead of hanging the barrier.  Failures are injected
//! deterministically in tests via [`crate::engine::chaos::ChaosPlan`]
//! ([`WorkerPool::inject_chaos`] targets gather lanes;
//! [`crate::engine::chaos::ChaosBackend`] targets replicas);
//! `tests/chaos_harness.rs` drives the kill/delay/rejoin matrices.
//!
//! # Replica lanes and the `Send` boundary
//!
//! The production backend's device state is not `Send`, so replicas can
//! never be constructed on one thread and moved to another.  Instead the
//! pool ships a [`ReplicaBuilder`] (a `Send` constructor carrying only
//! host data) into each lane thread, which *builds* its replica locally
//! and owns it for the lane's whole life.  Lane threads are persistent —
//! spawned on the first [`WorkerPool::run_data_parallel`] call and reused
//! across epochs — so a PJRT replica's per-thread client and compiled
//! executables are paid once per training run, not once per epoch.  Every
//! run starts by broadcasting the primary's exported state, so replicas
//! are bitwise-synchronized regardless of what earlier runs left behind.
//!
//! # Determinism contract
//!
//! Enforced by `tests/worker_pool_determinism.rs` and the
//! `pool_reduction_matches_serial_interleaved_fold` property test
//! (`tests/property_invariants.rs`): for any (order length, worker
//! count, batch size), the serial-equivalent pool run produces
//! bit-for-bit the stats, sink state, and backend state of the
//! single-stream interleaved run *for that worker count*.  Changing the
//! worker count itself changes the sharding (wrap padding and batch
//! composition), exactly as adding ranks does in a real distributed
//! sampler — the contract is "threads are invisible", not "W is
//! invisible".

use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender,
};
use std::sync::Arc;
use std::time::Duration;

use super::backend::{accumulate_state, finish_average, DataParallel, ReplicaBuilder, StateExchange};
use super::chaos::{ChaosAction, ChaosPlan};
use super::snapshot::{SharedSnapshot, Snapshot, SnapshotTier};
use super::{dispatch, feed_sink, StepBackend, StepCtx, StepMode, StepSink};
use crate::data::batch::{BatchAssembler, DoubleBuffer};
use crate::data::shard::{reissue_tail, Shard};
use crate::data::Dataset;
use crate::runtime::BatchStats;
use crate::util::timer::Timer;

/// Per-worker execution accounting for one pool run.
#[derive(Clone, Debug, Default)]
pub struct WorkerReport {
    /// Worker rank (matches `Shard::worker`).
    pub worker: usize,
    /// Device steps executed for this worker's shard.
    pub steps: usize,
    /// Real (non-padding) samples executed for this worker.
    pub samples: usize,
    /// Seconds the reduction loop spent blocked on this worker's lane.
    /// In the serial-equivalent schedule this is gather starvation on the
    /// device's critical path.  In the data-parallel schedule the
    /// reduction loop has no work of its own, so lane 0's wait absorbs
    /// each step's full gather+compute latency and later lanes measure
    /// only the skew behind lane 0 — use the serial-equivalent figure
    /// when quoting coordination overhead.
    pub wait_s: f64,
}

/// What one pool run executed (rolled up into `EpochRecord`).
#[derive(Clone, Debug, Default)]
pub struct PoolOutcome {
    /// Bulk-synchronous global steps taken (each executes one batch per
    /// worker).
    pub steps: usize,
    /// Total real samples executed across workers.
    pub samples: usize,
    /// Parameter-averaging reductions performed (data-parallel train
    /// schedule only; one per global step there, 0 otherwise).
    pub sync_steps: usize,
    /// Seconds spent finalizing and broadcasting the averaged state
    /// across the syncs above (the host-side allreduce cost).
    pub time_average: f64,
    /// Lanes retired mid-run after a death or straggler timeout (elastic
    /// fault policy only — under the `fail` policy a fault aborts the run
    /// instead of counting here).
    pub dropped_lanes: usize,
    /// Recovery lanes brought up to adopt dropped work: fresh re-issue
    /// gather lanes in the serial-equivalent schedule, the primary
    /// standing in for a dead replica in the data-parallel schedule.
    pub rejoined_lanes: usize,
    /// Seconds spent standing up re-issue lanes after fault detection
    /// (the elastic-recovery latency).
    pub time_reissue: f64,
    /// Per-worker accounting, indexed by worker rank.  A dropped worker's
    /// rows keep accruing: recovered steps are attributed to the *logical*
    /// worker whose shard they came from.
    pub workers: Vec<WorkerReport>,
}

/// Commands the reduction loop sends a persistent replica lane.
enum LaneCmd {
    /// Replace the replica's state with this typed snapshot (the averaged
    /// state at a step barrier, or the primary's state at run start).
    /// Always the [`SnapshotTier::Full`] tier: true synchronous SGD must
    /// keep every replica's *optimizer trajectory* identical, so the
    /// `--dp average` sync never rides the params-only fast path.
    Sync(SharedSnapshot),
    /// Execute one step on an assembled batch; reply with
    /// [`LaneReply::Step`], exporting the post-step state when `export`.
    Step {
        buf: BatchAssembler,
        mode: StepMode,
        export: bool,
    },
}

/// Replies a replica lane sends back to the reduction loop.
enum LaneReply {
    /// The replica finished building; the lane accepts commands.
    Ready,
    /// One executed step: the recycled batch buffer, its stats, and (when
    /// requested) the replica's post-step state snapshot.
    Step {
        buf: BatchAssembler,
        stats: BatchStats,
        state: Option<Vec<Vec<f32>>>,
    },
    /// The lane's replica failed; the run aborts and the lane exits.
    Fail(String),
}

/// A persistent worker thread owning one data-parallel replica.
///
/// The replica is *built on* this thread (via a [`ReplicaBuilder`]) and
/// never leaves it; all communication crosses the channel pair as `Send`
/// host values.  Dropping the lane closes the command channel, which
/// shuts the thread down; `Drop` joins it.
struct ReplicaLane {
    cmd_tx: Option<Sender<LaneCmd>>,
    reply_rx: Receiver<LaneReply>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ReplicaLane {
    /// Spawn the lane thread; the replica builds asynchronously and the
    /// lane reports [`LaneReply::Ready`] (or `Fail`) as its first reply.
    fn spawn(worker: usize, build: ReplicaBuilder) -> anyhow::Result<Self> {
        let (cmd_tx, cmd_rx) = channel::<LaneCmd>();
        let (reply_tx, reply_rx) = channel::<LaneReply>();
        let handle = std::thread::Builder::new()
            .name(format!("dp-replica-{worker}"))
            .spawn(move || lane_main(build, cmd_rx, reply_tx))?;
        Ok(ReplicaLane { cmd_tx: Some(cmd_tx), reply_rx, handle: Some(handle) })
    }

    fn send(&self, cmd: LaneCmd) -> anyhow::Result<()> {
        self.cmd_tx
            .as_ref()
            .expect("lane alive until drop")
            .send(cmd)
            .map_err(|_| anyhow::anyhow!("replica lane died"))
    }

    fn recv(&self) -> anyhow::Result<LaneReply> {
        self.reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("replica lane died"))
    }

    /// Like [`ReplicaLane::recv`], but gives up after `timeout` — the
    /// straggler-detection path.  The caller decides whether a timeout is
    /// fatal (`fail` policy) or retires the lane (`elastic`).
    fn recv_timeout(&self, timeout: Duration) -> Result<LaneReply, RecvTimeoutError> {
        self.reply_rx.recv_timeout(timeout)
    }
}

impl Drop for ReplicaLane {
    fn drop(&mut self) {
        drop(self.cmd_tx.take()); // disconnect: lane_main's recv loop exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Lane thread body: build the replica locally, then serve commands until
/// the pool drops the command channel.
fn lane_main(build: ReplicaBuilder, cmd_rx: Receiver<LaneCmd>, reply_tx: Sender<LaneReply>) {
    let mut replica = match build() {
        Ok(r) => r,
        Err(e) => {
            let _ = reply_tx.send(LaneReply::Fail(format!("replica build: {e}")));
            return;
        }
    };
    if reply_tx.send(LaneReply::Ready).is_err() {
        return;
    }
    while let Ok(cmd) = cmd_rx.recv() {
        match cmd {
            LaneCmd::Sync(snap) => {
                if let Err(e) = replica.import_snapshot(&snap) {
                    let _ = reply_tx.send(LaneReply::Fail(format!("state import: {e}")));
                    return;
                }
            }
            LaneCmd::Step { buf, mode, export } => {
                let result = match mode {
                    StepMode::Train { lr } => {
                        replica.train_step(&buf.x, &buf.y, &buf.sw, lr)
                    }
                    StepMode::Forward => replica.fwd_stats(&buf.x, &buf.y),
                    // replies carry stats only — embeddings never cross
                    // the lane channel (rejected before lanes spin up)
                    StepMode::Embed => Err(anyhow::anyhow!(
                        "StepMode::Embed is not supported on data-parallel replica lanes"
                    )),
                };
                let stats = match result {
                    Ok(s) => s,
                    Err(e) => {
                        let _ = reply_tx.send(LaneReply::Fail(e.to_string()));
                        return;
                    }
                };
                let state = if export {
                    match replica.export_state() {
                        Ok(s) => Some(s),
                        Err(e) => {
                            let _ = reply_tx.send(LaneReply::Fail(format!("state export: {e}")));
                            return;
                        }
                    }
                } else {
                    None
                };
                if reply_tx.send(LaneReply::Step { buf, stats, state }).is_err() {
                    return;
                }
            }
        }
    }
}

/// The multi-worker execution driver.  Owns the per-worker parked batch
/// buffers (reused across epochs and across train/refresh runs), a
/// scratch assembler for sink-issued immediate steps, and the persistent
/// data-parallel replica lanes.
pub struct WorkerPool {
    batch: usize,
    /// Per-worker parked assembler pairs (lane w uses `buffers[w]`).
    buffers: Vec<DoubleBuffer>,
    scratch: BatchAssembler,
    /// Persistent replica lanes for the data-parallel schedule (spawned
    /// on first use, reused across runs; cleared after a failed run so
    /// the next run rebuilds from a clean slate).
    lanes: Vec<ReplicaLane>,
    /// Which backend the lanes' replicas were built for
    /// ([`DataParallel::replica_cache_key`]); a different key respawns
    /// them, so one backend's replicas never receive another's state.
    lanes_key: String,
    /// Elastic fault policy ([`WorkerPool::set_fault_policy`]): survive a
    /// lane failure by re-issuing the dead lane's remaining steps.
    /// `false` (the default, `--fault-policy fail`) aborts with a named
    /// error instead.
    elastic: bool,
    /// Straggler detection: a lane that takes longer than this to deliver
    /// its barrier contribution counts as failed.  `None` (the default)
    /// waits forever.
    straggler_timeout: Option<Duration>,
    /// One-shot scripted fault injection for the next run's gather lanes
    /// ([`WorkerPool::inject_chaos`]; test harness only).
    chaos: Option<Arc<ChaosPlan>>,
}

impl WorkerPool {
    /// A pool sized for `data`'s sample layout at device batch `batch`.
    /// Lanes allocate lazily on first use, so construction is cheap for
    /// single-worker configs.
    pub fn new(data: &Dataset, batch: usize) -> Self {
        WorkerPool {
            batch,
            buffers: Vec::new(),
            scratch: BatchAssembler::new(data, batch),
            lanes: Vec::new(),
            lanes_key: String::new(),
            elastic: false,
            straggler_timeout: None,
            chaos: None,
        }
    }

    /// The device batch size each lane assembles.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Configure the fault policy (docs/worker-model.md, "Fault
    /// tolerance").  With `elastic`, a dead or timed-out lane's remaining
    /// steps are deterministically re-issued and the run's results stay
    /// bitwise identical to an undisturbed run; otherwise a fault aborts
    /// with a named error.  `straggler_timeout_ms = 0` disables straggler
    /// detection (a stalled lane is waited on forever).
    pub fn set_fault_policy(&mut self, elastic: bool, straggler_timeout_ms: u64) {
        self.elastic = elastic;
        self.straggler_timeout = match straggler_timeout_ms {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        };
    }

    /// Arm a scripted [`ChaosPlan`] for the **next** run only (consumed
    /// at run start): scripted kills and delays execute on the matching
    /// gather lanes of either schedule.  Replica-side injection goes
    /// through [`crate::engine::chaos::ChaosBackend`] instead.  Test
    /// harness surface — see `tests/chaos_harness.rs`.
    pub fn inject_chaos(&mut self, plan: ChaosPlan) {
        self.chaos = Some(Arc::new(plan));
    }

    /// Size the lane buffer pools and compute the per-lane and global
    /// step counts.  Returns `(steps, per-lane steps, outcome skeleton)`.
    /// Ragged shards are accepted: a short lane simply retires from the
    /// barrier once its shard is exhausted.
    fn prepare(
        &mut self,
        data: &Dataset,
        shards: &[Shard],
    ) -> anyhow::Result<(usize, Vec<usize>, PoolOutcome)> {
        anyhow::ensure!(!shards.is_empty(), "worker pool needs at least one shard");
        while self.buffers.len() < shards.len() {
            self.buffers.push(DoubleBuffer::new(data, self.batch));
        }
        if !self.scratch.matches(data) {
            self.scratch = BatchAssembler::new(data, self.batch);
        }
        let lane_steps: Vec<usize> = shards.iter().map(|s| s.steps(self.batch)).collect();
        let steps = lane_steps.iter().copied().max().unwrap_or(0);
        let workers = (0..shards.len())
            .map(|w| WorkerReport { worker: w, ..Default::default() })
            .collect();
        Ok((steps, lane_steps, PoolOutcome { steps, workers, ..Default::default() }))
    }

    /// Take the initial assemblers for each lane (two per worker, fewer
    /// when that lane's shard is shorter).
    fn take_lanes(&mut self, data: &Dataset, lane_steps: &[usize]) -> Vec<Vec<BatchAssembler>> {
        let mut lanes = Vec::with_capacity(lane_steps.len());
        for (w, &steps) in lane_steps.iter().enumerate() {
            let mut lane = Vec::with_capacity(steps.min(2));
            for _ in 0..steps.min(2) {
                lane.push(self.buffers[w].take(data));
            }
            lanes.push(lane);
        }
        lanes
    }

    /// Spawn (or respawn, if the worker count or the primary backend
    /// changed) the persistent replica lanes and wait for every replica
    /// to finish building.
    fn ensure_lanes<B: DataParallel>(
        &mut self,
        primary: &B,
        workers: usize,
    ) -> anyhow::Result<()> {
        let key = primary.replica_cache_key();
        if self.lanes.len() == workers && self.lanes_key == key {
            return Ok(());
        }
        self.lanes.clear();
        self.lanes_key = key;
        for w in 0..workers {
            self.lanes.push(ReplicaLane::spawn(w, primary.replica_builder()?)?);
        }
        let mut failed = None;
        for (w, lane) in self.lanes.iter().enumerate() {
            match lane.recv() {
                Ok(LaneReply::Ready) => {}
                Ok(LaneReply::Fail(e)) => {
                    failed = Some(format!("worker {w}: {e}"));
                    break;
                }
                Ok(LaneReply::Step { .. }) => {
                    failed = Some(format!("worker {w}: unexpected step reply"));
                    break;
                }
                Err(e) => {
                    failed = Some(format!("worker {w}: {e}"));
                    break;
                }
            }
        }
        if let Some(e) = failed {
            self.lanes.clear();
            anyhow::bail!("replica lane spawn failed: {e}");
        }
        Ok(())
    }

    /// Execute `shards` through the **serial-equivalent** schedule: worker
    /// gather lanes fill batches concurrently, while every device step
    /// runs on `backend` in fixed `(step, worker)` order.  Bitwise
    /// identical to driving the engine over
    /// [`crate::data::shard::global_batch_order`] on a single stream —
    /// including runs recovered under the elastic fault policy, because a
    /// dead gather lane's batches are re-gathered, not re-ordered.
    pub fn run_serial_equivalent(
        &mut self,
        backend: &mut dyn StepBackend,
        data: &Dataset,
        shards: &[Shard],
        mode: StepMode,
        sink: &mut dyn StepSink,
    ) -> anyhow::Result<PoolOutcome> {
        let (steps, lane_steps, mut outcome) = self.prepare(data, shards)?;
        let w_count = shards.len();
        let bs = self.batch;
        let elastic = self.elastic;
        let straggler = self.straggler_timeout;
        let chaos = self.chaos.take();
        if steps == 0 {
            let mut ctx = StepCtx { backend, scratch: &mut self.scratch, data };
            sink.finish(&mut ctx)?;
            return Ok(outcome);
        }
        let lanes = self.take_lanes(data, &lane_steps);
        let scratch = &mut self.scratch;

        let parked = std::thread::scope(
            |scope| -> anyhow::Result<Vec<(usize, BatchAssembler)>> {
                let mut done_rx: Vec<Option<Receiver<BatchAssembler>>> =
                    Vec::with_capacity(w_count);
                let mut back_tx: Vec<Option<Sender<BatchAssembler>>> =
                    Vec::with_capacity(w_count);
                for (w, (shard, initial)) in shards.iter().zip(lanes).enumerate() {
                    let (d_tx, d_rx) = sync_channel::<BatchAssembler>(1);
                    let (b_tx, b_rx) = channel::<BatchAssembler>();
                    spawn_filler(
                        scope,
                        shard,
                        data,
                        bs,
                        lane_steps[w],
                        initial,
                        b_rx,
                        d_tx,
                        chaos.clone(),
                    );
                    done_rx.push(Some(d_rx));
                    back_tx.push(Some(b_tx));
                }
                // dead[w] holds the recovery lanes serving worker w's
                // re-issued steps once its own gather lane is retired
                let mut dead: Vec<Option<Reissue>> = (0..w_count).map(|_| None).collect();

                let mut parked = Vec::with_capacity(w_count * steps.min(2));
                for s in 0..steps {
                    for w in 0..w_count {
                        if s >= lane_steps[w] {
                            continue; // ragged shard: lane already retired
                        }
                        // Acquire worker w's batch for step s — from its
                        // own gather lane, or (once dropped) from the
                        // recovery lane this step was re-issued to.
                        let (buf, recovered) = loop {
                            if let Some(re) = dead[w].as_ref() {
                                let j = (s - re.from_step) % re.out_rx.len();
                                let t = Timer::start();
                                let buf = re.out_rx[j].recv().map_err(|_| {
                                    anyhow::anyhow!("worker {w} recovery lane died at step {s}")
                                })?;
                                outcome.workers[w].wait_s += t.elapsed_s();
                                break (buf, Some(j));
                            }
                            let rx = done_rx[w].as_ref().expect("live lane has a receiver");
                            let t = Timer::start();
                            let fault = match straggler {
                                Some(to) => match rx.recv_timeout(to) {
                                    Ok(buf) => {
                                        outcome.workers[w].wait_s += t.elapsed_s();
                                        break (buf, None);
                                    }
                                    Err(RecvTimeoutError::Timeout) => LaneFault::Straggler,
                                    Err(RecvTimeoutError::Disconnected) => LaneFault::Dead,
                                },
                                None => match rx.recv() {
                                    Ok(buf) => {
                                        outcome.workers[w].wait_s += t.elapsed_s();
                                        break (buf, None);
                                    }
                                    Err(_) => LaneFault::Dead,
                                },
                            };
                            outcome.workers[w].wait_s += t.elapsed_s();
                            if !elastic {
                                fault.bail("gather", w, s, straggler)?;
                            }
                            // Elastic: retire the lane and re-issue its
                            // remaining steps round-robin across fresh
                            // recovery lanes; the loop then consumes step
                            // s from recovery lane 0.
                            let t = Timer::start();
                            done_rx[w] = None;
                            back_tx[w] = None;
                            let survivors =
                                done_rx.iter().filter(|r| r.is_some()).count().max(1);
                            dead[w] =
                                Some(Reissue::spawn(scope, data, &shards[w], s, bs, survivors));
                            outcome.dropped_lanes += 1;
                            outcome.rejoined_lanes += 1;
                            outcome.time_reissue += t.elapsed_s();
                        };
                        let out = dispatch(&mut *backend, mode, &buf)?;
                        let mut ctx =
                            StepCtx { backend: &mut *backend, scratch: &mut *scratch, data };
                        feed_sink(sink, &mut ctx, &buf.slots, buf.real, &out)?;
                        outcome.samples += buf.real;
                        outcome.workers[w].samples += buf.real;
                        outcome.workers[w].steps += 1;
                        match recovered {
                            // recovery lanes own their buffers (the lane
                            // may already have exited — ignore send errors)
                            Some(j) => {
                                let _ =
                                    dead[w].as_ref().expect("recovery lane").back_tx[j].send(buf);
                            }
                            None => {
                                if s + 2 < lane_steps[w] {
                                    if let Some(tx) = back_tx[w].as_ref() {
                                        let _ = tx.send(buf);
                                    }
                                } else {
                                    parked.push((w, buf));
                                }
                            }
                        }
                    }
                }
                drop(back_tx);
                let mut ctx = StepCtx { backend, scratch, data };
                sink.finish(&mut ctx)?;
                Ok(parked)
            },
        )?;
        for (w, buf) in parked {
            self.buffers[w].put(buf);
        }
        Ok(outcome)
    }

    /// Execute `shards` through the **data-parallel** schedule: worker `w`
    /// steps its own replica of `primary` (built and owned by a persistent
    /// lane thread — see the module docs) over its shard; at each step
    /// barrier the stats fold into `sink` in fixed worker order and (for
    /// [`StepMode::Train`]) replica parameters are averaged with the same
    /// fixed-order fold, after which `primary` receives the final averaged
    /// state.  Deterministic run to run; bitwise serial-equivalent for
    /// forward-only modes.
    ///
    /// The averaging invariant: the reduction folds in fixed
    /// `(step, worker)` order, so the result is a pure function of the
    /// inputs — *independent of lane completion timing* across runs:
    ///
    /// ```
    /// use kakurenbo::data::shard::shard_order_aligned;
    /// use kakurenbo::data::synth::{gauss_mixture, GaussMixtureCfg};
    /// use kakurenbo::engine::testbed::MockBackend;
    /// use kakurenbo::engine::{EvalSink, StepMode, WorkerPool};
    ///
    /// let d = gauss_mixture(
    ///     &GaussMixtureCfg { n_train: 48, n_val: 4, dim: 6, classes: 3, ..Default::default() },
    ///     7,
    /// )
    /// .train;
    /// let order: Vec<u32> = (0..48).collect();
    /// let shards = shard_order_aligned(&order, 4, 8);
    /// let run = || {
    ///     let mut pool = WorkerPool::new(&d, 8);
    ///     let mut be = MockBackend::new();
    ///     let mut sink = EvalSink::default();
    ///     pool.run_data_parallel(&mut be, &d, &shards, StepMode::Train { lr: 0.05 }, &mut sink)
    ///         .unwrap();
    ///     (be.param.to_bits(), sink.result().1.to_bits())
    /// };
    /// // four lanes race; the fixed-order reduction makes the averaged
    /// // parameters and the folded loss bit-for-bit reproducible anyway
    /// assert_eq!(run(), run());
    /// ```
    pub fn run_data_parallel<B: DataParallel>(
        &mut self,
        primary: &mut B,
        data: &Dataset,
        shards: &[Shard],
        mode: StepMode,
        sink: &mut dyn StepSink,
    ) -> anyhow::Result<PoolOutcome> {
        anyhow::ensure!(
            !matches!(mode, StepMode::Embed),
            "StepMode::Embed runs through the serial-equivalent schedule only \
             (replica lane replies carry stats, not embeddings)"
        );
        let out = self.run_data_parallel_inner(primary, data, shards, mode, sink);
        if out.is_err() {
            // an aborted run can leave lanes with out-of-phase commands in
            // flight; rebuild them rather than risk a desynced barrier
            self.lanes.clear();
        }
        out
    }

    fn run_data_parallel_inner<B: DataParallel>(
        &mut self,
        primary: &mut B,
        data: &Dataset,
        shards: &[Shard],
        mode: StepMode,
        sink: &mut dyn StepSink,
    ) -> anyhow::Result<PoolOutcome> {
        let (steps, lane_steps, mut outcome) = self.prepare(data, shards)?;
        let w_count = shards.len();
        let bs = self.batch;
        let elastic = self.elastic;
        let straggler = self.straggler_timeout;
        let chaos = self.chaos.take();
        if steps == 0 {
            let mut ctx = StepCtx { backend: primary, scratch: &mut self.scratch, data };
            sink.finish(&mut ctx)?;
            return Ok(outcome);
        }
        let averaging = matches!(mode, StepMode::Train { .. });
        self.ensure_lanes(primary, w_count)?;
        // Re-synchronize every replica with the primary's current state:
        // lanes persist across runs, so whatever an earlier run (or an
        // earlier epoch's averaging) left behind is overwritten up front.
        // Full tier always — replicas must share the optimizer state too.
        let init: SharedSnapshot = Arc::new(primary.export_snapshot(SnapshotTier::Full)?);
        // leaf count of the params section: the barrier's flat averaged
        // states split back into typed snapshots at this boundary
        let param_leaves = init.params().len();
        for lane in &self.lanes {
            lane.send(LaneCmd::Sync(init.clone()))?;
        }

        let gather_bufs = self.take_lanes(data, &lane_steps);
        let scratch = &mut self.scratch;
        let rep_lanes = &self.lanes;

        type Parked = Vec<(usize, BatchAssembler)>;
        let (parked, last_avg) = std::thread::scope(
            |scope| -> anyhow::Result<(Parked, Option<SharedSnapshot>)> {
                let mut done_rx: Vec<Option<Receiver<BatchAssembler>>> =
                    Vec::with_capacity(w_count);
                let mut back_tx: Vec<Option<Sender<BatchAssembler>>> =
                    Vec::with_capacity(w_count);
                for (w, (shard, initial)) in shards.iter().zip(gather_bufs).enumerate() {
                    let (d_tx, d_rx) = sync_channel::<BatchAssembler>(1);
                    let (b_tx, b_rx) = channel::<BatchAssembler>();
                    spawn_filler(
                        scope,
                        shard,
                        data,
                        bs,
                        lane_steps[w],
                        initial,
                        b_rx,
                        d_tx,
                        chaos.clone(),
                    );
                    done_rx.push(Some(d_rx));
                    back_tx.push(Some(b_tx));
                }

                // A dead lane's remaining steps execute on the primary,
                // restored to `pre_step` — the snapshot every replica
                // held before the current step — so the fold stays
                // bitwise identical to an undisturbed run.
                let mut dead = vec![false; w_count];
                let mut pre_step: SharedSnapshot = init.clone();
                let mut rec_buf: Option<BatchAssembler> = None;
                // retire lane w from the run: stop its gather, count the
                // drop; the primary adopts its remaining steps
                macro_rules! retire {
                    ($w:expr) => {{
                        let t = Timer::start();
                        dead[$w] = true;
                        done_rx[$w] = None;
                        back_tx[$w] = None;
                        outcome.dropped_lanes += 1;
                        outcome.rejoined_lanes += 1;
                        outcome.time_reissue += t.elapsed_s();
                    }};
                }

                let mut parked: Parked = Vec::with_capacity(w_count * steps.min(2));
                let mut last_avg: Option<SharedSnapshot> = None;
                for s in 0..steps {
                    // Fan out: forward each live worker's gathered batch
                    // to its replica lane; all lanes compute concurrently.
                    for w in 0..w_count {
                        if s >= lane_steps[w] || dead[w] {
                            continue;
                        }
                        let rx = done_rx[w].as_ref().expect("live lane has a receiver");
                        let buf = match rx.recv() {
                            Ok(b) => b,
                            Err(_) => {
                                if !elastic {
                                    LaneFault::Dead.bail("gather", w, s, straggler)?;
                                }
                                retire!(w);
                                continue;
                            }
                        };
                        if rep_lanes[w]
                            .send(LaneCmd::Step { buf, mode, export: averaging })
                            .is_err()
                        {
                            if !elastic {
                                LaneFault::Dead.bail("replica", w, s, straggler)?;
                            }
                            retire!(w);
                        }
                    }
                    // Fixed (step, worker) reduction: fold stats (and,
                    // when averaging, states) in worker order regardless
                    // of which lane finished first.  A dead worker's step
                    // executes on the primary at its original fold
                    // position.
                    let mut acc: Option<Vec<Vec<f32>>> = None;
                    let mut participants = 0usize;
                    for w in 0..w_count {
                        if s >= lane_steps[w] {
                            continue; // ragged shard: lane already retired
                        }
                        participants += 1;
                        let step_reply = loop {
                            if dead[w] {
                                break None;
                            }
                            let t = Timer::start();
                            let got: Result<LaneReply, LaneFault> = match straggler {
                                Some(to) => match rep_lanes[w].recv_timeout(to) {
                                    Ok(r) => Ok(r),
                                    Err(RecvTimeoutError::Timeout) => Err(LaneFault::Straggler),
                                    Err(RecvTimeoutError::Disconnected) => Err(LaneFault::Dead),
                                },
                                None => rep_lanes[w].recv().map_err(|_| LaneFault::Dead),
                            };
                            outcome.workers[w].wait_s += t.elapsed_s();
                            match got {
                                Ok(LaneReply::Step { buf, stats, state }) => {
                                    break Some((buf, stats, state));
                                }
                                Ok(LaneReply::Ready) => {
                                    anyhow::bail!("worker {w}: unexpected ready reply")
                                }
                                Ok(LaneReply::Fail(e)) => {
                                    if !elastic {
                                        anyhow::bail!("worker {w} step failed: {e}");
                                    }
                                }
                                Err(fault) => {
                                    if !elastic {
                                        fault.bail("replica", w, s, straggler)?;
                                    }
                                }
                            }
                            // elastic: retire the lane; the next loop
                            // iteration hands the step to the primary
                            retire!(w);
                        };
                        match step_reply {
                            Some((buf, stats, state)) => {
                                let mut ctx = StepCtx {
                                    backend: &mut *primary,
                                    scratch: &mut *scratch,
                                    data,
                                };
                                sink.on_batch(&mut ctx, &buf.slots, buf.real, &stats)?;
                                outcome.samples += buf.real;
                                outcome.workers[w].samples += buf.real;
                                outcome.workers[w].steps += 1;
                                if s + 2 < lane_steps[w] {
                                    if let Some(tx) = back_tx[w].as_ref() {
                                        let _ = tx.send(buf);
                                    }
                                } else {
                                    parked.push((w, buf));
                                }
                                if averaging {
                                    let st = state.ok_or_else(|| {
                                        anyhow::anyhow!("worker {w} reply missing state")
                                    })?;
                                    // fixed fold: w0 + w1 + ... then / W
                                    acc = Some(match acc.take() {
                                        None => st,
                                        Some(mut a) => {
                                            accumulate_state(&mut a, &st)?;
                                            a
                                        }
                                    });
                                }
                            }
                            None => {
                                // The dead worker's step, executed on the
                                // primary from the replicas' pre-step
                                // state — bitwise what the replica would
                                // have computed.
                                if averaging {
                                    primary.import_snapshot(&pre_step)?;
                                }
                                let rb = rec_buf
                                    .get_or_insert_with(|| BatchAssembler::new(data, bs));
                                rb.fill(data, shards[w].step_batch(s, bs), None);
                                let out = dispatch(&mut *primary, mode, rb)?;
                                let mut ctx = StepCtx {
                                    backend: &mut *primary,
                                    scratch: &mut *scratch,
                                    data,
                                };
                                feed_sink(sink, &mut ctx, &rb.slots, rb.real, &out)?;
                                outcome.samples += rb.real;
                                outcome.workers[w].samples += rb.real;
                                outcome.workers[w].steps += 1;
                                if averaging {
                                    let st = primary.export_state()?;
                                    acc = Some(match acc.take() {
                                        None => st,
                                        Some(mut a) => {
                                            accumulate_state(&mut a, &st)?;
                                            a
                                        }
                                    });
                                }
                            }
                        }
                    }
                    if averaging {
                        let t = Timer::start();
                        let mut avg = acc.expect("averaging step folded no state");
                        finish_average(&mut avg, participants);
                        // wrap the flat averaged state back into a typed
                        // full-tier snapshot (a pure split — every f32
                        // bit pattern is preserved) before broadcast
                        let avg: SharedSnapshot =
                            Arc::new(Snapshot::from_state(avg, param_leaves)?);
                        for (w, lane) in rep_lanes.iter().enumerate() {
                            if dead[w] {
                                continue;
                            }
                            if lane.send(LaneCmd::Sync(avg.clone())).is_err() {
                                if !elastic {
                                    LaneFault::Dead.bail("replica", w, s, straggler)?;
                                }
                                retire!(w);
                            }
                        }
                        outcome.sync_steps += 1;
                        outcome.time_average += t.elapsed_s();
                        pre_step = avg.clone();
                        last_avg = Some(avg);
                    }
                }
                drop(back_tx);
                Ok((parked, last_avg))
            },
        )?;
        for (w, buf) in parked {
            self.buffers[w].put(buf);
        }
        if let Some(avg) = last_avg {
            primary.import_snapshot(&avg)?;
        }
        let mut ctx = StepCtx { backend: primary, scratch: &mut self.scratch, data };
        sink.finish(&mut ctx)?;
        if outcome.dropped_lanes > 0 {
            // dead replica lanes (and stragglers we stopped listening to)
            // cannot rejoin the barrier protocol mid-stream; respawn the
            // whole lane set before the next run
            self.lanes.clear();
            self.lanes_key.clear();
        }
        Ok(outcome)
    }
}

/// How a lane failed at the barrier.
enum LaneFault {
    /// The lane's channel disconnected — its thread is gone.
    Dead,
    /// The lane missed the straggler timeout.
    Straggler,
}

impl LaneFault {
    /// The `--fault-policy fail` abort: a named error instead of a hung
    /// barrier.  Always returns `Err`.
    fn bail(
        &self,
        kind: &str,
        worker: usize,
        step: usize,
        timeout: Option<Duration>,
    ) -> anyhow::Result<()> {
        match self {
            LaneFault::Dead => anyhow::bail!(
                "worker {worker} {kind} lane died at step {step} (--fault-policy fail \
                 aborts; elastic re-issues the remaining steps)"
            ),
            LaneFault::Straggler => anyhow::bail!(
                "worker {worker} stalled past the {}ms straggler timeout at step {step} \
                 (--fault-policy fail)",
                timeout.map_or(0, |d| d.as_millis() as u64)
            ),
        }
    }
}

/// The recovery lanes standing in for one dropped worker (elastic fault
/// policy, serial-equivalent schedule): the dead worker's step `t` is
/// served by recovery lane `(t - from_step) % lanes`, matching
/// [`reissue_tail`]'s round-robin assignment.
struct Reissue {
    from_step: usize,
    out_rx: Vec<Receiver<BatchAssembler>>,
    back_tx: Vec<Sender<BatchAssembler>>,
}

impl Reissue {
    /// Re-issue the tail of `shard` (steps `from_step..`) across
    /// `survivors` fresh recovery gather lanes spawned on `scope`.  The
    /// slices are copied out up front ([`reissue_tail`]) so the recovery
    /// threads own their work outright.
    fn spawn<'scope, 'env>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        data: &'env Dataset,
        shard: &Shard,
        from_step: usize,
        batch: usize,
        survivors: usize,
    ) -> Self {
        let mut groups: Vec<Vec<Vec<u32>>> =
            (0..survivors.max(1)).map(|_| Vec::new()).collect();
        for slice in reissue_tail(shard, from_step, batch, survivors) {
            groups[slice.lane].push(slice.indices);
        }
        let mut out_rx = Vec::with_capacity(groups.len());
        let mut back_tx = Vec::with_capacity(groups.len());
        for slices in groups {
            let (d_tx, d_rx) = sync_channel::<BatchAssembler>(1);
            let (b_tx, b_rx) = channel::<BatchAssembler>();
            let initial: Vec<BatchAssembler> = (0..slices.len().min(2))
                .map(|_| BatchAssembler::new(data, batch))
                .collect();
            spawn_reissue_filler(scope, data, slices, initial, b_rx, d_tx);
            out_rx.push(d_rx);
            back_tx.push(b_tx);
        }
        Reissue { from_step, out_rx, back_tx }
    }
}

/// Spawn one worker's gather lane: fills its shard's batches in step
/// order, double-buffered (two assemblers circulating by value through
/// the `back_rx` / `out_tx` channel pair).  A [`ChaosPlan`] targeting
/// `shard.worker` executes here: a scripted kill exits the thread before
/// the step's batch is delivered (the channel disconnect *is* the failure
/// signal, exactly like a crashed prefetch thread), a scripted delay
/// sleeps first.
#[allow(clippy::too_many_arguments)]
fn spawn_filler<'scope, 'env>(
    scope: &'scope std::thread::Scope<'scope, 'env>,
    shard: &'env Shard,
    data: &'env Dataset,
    batch: usize,
    steps: usize,
    mut initial: Vec<BatchAssembler>,
    back_rx: Receiver<BatchAssembler>,
    out_tx: SyncSender<BatchAssembler>,
    chaos: Option<Arc<ChaosPlan>>,
) {
    let worker = shard.worker;
    scope.spawn(move || {
        for s in 0..steps {
            match chaos.as_ref().and_then(|p| p.action(worker, s)) {
                Some(ChaosAction::Kill) => return,
                Some(ChaosAction::Delay(ms)) => {
                    std::thread::sleep(Duration::from_millis(ms))
                }
                Some(ChaosAction::FailExport) | None => {}
            }
            let mut buf = match initial.pop() {
                Some(b) => b,
                None => match back_rx.recv() {
                    Ok(b) => b,
                    Err(_) => return,
                },
            };
            buf.fill(data, shard.step_batch(s, batch), None);
            if out_tx.send(buf).is_err() {
                return;
            }
        }
    });
}

/// A recovery gather lane (elastic fault policy): fills the re-issued
/// slices of a dead worker's shard in re-issue order, double-buffered
/// like [`spawn_filler`] but over *owned* index vectors — the recovery
/// work is computed at fault-detection time and moved in.
fn spawn_reissue_filler<'scope, 'env>(
    scope: &'scope std::thread::Scope<'scope, 'env>,
    data: &'env Dataset,
    slices: Vec<Vec<u32>>,
    mut initial: Vec<BatchAssembler>,
    back_rx: Receiver<BatchAssembler>,
    out_tx: SyncSender<BatchAssembler>,
) {
    scope.spawn(move || {
        for idx in slices {
            let mut buf = match initial.pop() {
                Some(b) => b,
                None => match back_rx.recv() {
                    Ok(b) => b,
                    Err(_) => return,
                },
            };
            buf.fill(data, &idx, None);
            if out_tx.send(buf).is_err() {
                return;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shard::{global_batch_order, shard_order_aligned};
    use crate::data::synth::{gauss_mixture, GaussMixtureCfg};
    use crate::engine::testbed::MockBackend;
    use crate::engine::{Engine, EvalSink};

    const B: usize = 8;

    fn tiny(n: usize) -> Dataset {
        gauss_mixture(
            &GaussMixtureCfg { n_train: n, n_val: 4, dim: 6, classes: 3, ..Default::default() },
            7,
        )
        .train
    }

    fn eval_serial_equiv(n: usize, w: usize, mode: StepMode) -> (f64, f64, u32) {
        let d = tiny(n);
        let order: Vec<u32> = (0..n as u32).rev().collect();
        let shards = shard_order_aligned(&order, w, B);
        let mut pool = WorkerPool::new(&d, B);
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        pool.run_serial_equivalent(&mut be, &d, &shards, mode, &mut sink).unwrap();
        let (acc, loss) = sink.result();
        (acc, loss, be.param.to_bits())
    }

    #[test]
    fn pool_matches_engine_over_interleaved_stream() {
        for w in [1usize, 2, 3, 4] {
            let d = tiny(53);
            let order: Vec<u32> = (0..53u32).rev().collect();
            let shards = shard_order_aligned(&order, w, B);

            let mut eng = Engine::new(&d, B);
            eng.overlap = true;
            let mut ref_be = MockBackend::new();
            let mut ref_sink = EvalSink::default();
            let flat = global_batch_order(&shards, B);
            eng.run(&mut ref_be, &d, &flat, None, StepMode::Train { lr: 0.05 }, &mut ref_sink)
                .unwrap();

            let mut pool = WorkerPool::new(&d, B);
            let mut be = MockBackend::new();
            let mut sink = EvalSink::default();
            let mode = StepMode::Train { lr: 0.05 };
            let out = pool.run_serial_equivalent(&mut be, &d, &shards, mode, &mut sink).unwrap();

            assert_eq!(ref_be.param.to_bits(), be.param.to_bits(), "w={w}");
            assert_eq!(ref_be.trace, be.trace, "w={w}");
            let (ra, rl) = ref_sink.result();
            let (pa, pl) = sink.result();
            assert_eq!(ra.to_bits(), pa.to_bits(), "w={w}");
            assert_eq!(rl.to_bits(), pl.to_bits(), "w={w}");
            assert_eq!(out.samples, flat.len(), "w={w}");
            assert_eq!(out.steps * w, out.workers.iter().map(|r| r.steps).sum::<usize>());
        }
    }

    #[test]
    fn pool_runs_are_reproducible() {
        let a = eval_serial_equiv(53, 4, StepMode::Train { lr: 0.03 });
        let b = eval_serial_equiv(53, 4, StepMode::Train { lr: 0.03 });
        assert_eq!(a.2, b.2);
        assert_eq!(a.1.to_bits(), b.1.to_bits());
    }

    #[test]
    fn empty_and_tiny_epochs_do_not_panic() {
        for w in [1usize, 4] {
            for mode in [StepMode::Forward, StepMode::Train { lr: 0.01 }] {
                // empty epoch (heavy hiding can empty the order entirely)
                let d = tiny(16);
                let shards = shard_order_aligned(&[], w, B);
                let mut pool = WorkerPool::new(&d, B);
                let mut be = MockBackend::new();
                let mut sink = EvalSink::default();
                let out = pool
                    .run_serial_equivalent(&mut be, &d, &shards, mode, &mut sink)
                    .unwrap();
                assert_eq!(out.samples, 0);
                // fewer samples than workers: wrap-padding fills every lane
                let order: Vec<u32> = (0..3).collect();
                let shards = shard_order_aligned(&order, w, B);
                let mut sink = EvalSink::default();
                let out = pool
                    .run_serial_equivalent(&mut be, &d, &shards, mode, &mut sink)
                    .unwrap();
                assert_eq!(out.samples, w * B);
            }
        }
    }

    /// Satellite regression (docs/worker-model.md): a lane whose shard
    /// exhausts early retires from the barrier instead of deadlocking it.
    /// Maximally ragged: one lane takes every step, the other exactly one.
    #[test]
    fn ragged_shards_retire_from_the_barrier() {
        let d = tiny(32);
        let shards = vec![
            Shard { worker: 0, indices: (0..24).collect() }, // 3 steps of B=8
            Shard { worker: 1, indices: (24..26).collect() }, // 1 ragged step
        ];
        let mode = StepMode::Train { lr: 0.05 };
        // reference: a manual (step, worker) loop over the same logical
        // order (the flat global_batch_order re-chunks ragged tails, so
        // the engine-over-flat stream is not the right reference here)
        let mut ref_be = MockBackend::new();
        let mut ref_sink = EvalSink::default();
        let mut buf = BatchAssembler::new(&d, B);
        let mut scratch = BatchAssembler::new(&d, B);
        for s in 0..3 {
            for sh in &shards {
                let idx = sh.step_batch(s, B);
                if idx.is_empty() {
                    continue;
                }
                buf.fill(&d, idx, None);
                let stats = dispatch(&mut ref_be, mode, &buf).unwrap().into_stats();
                let mut ctx =
                    StepCtx { backend: &mut ref_be, scratch: &mut scratch, data: &d };
                ref_sink.on_batch(&mut ctx, &buf.slots, buf.real, &stats).unwrap();
            }
        }
        let mut ctx = StepCtx { backend: &mut ref_be, scratch: &mut scratch, data: &d };
        ref_sink.finish(&mut ctx).unwrap();

        let mut pool = WorkerPool::new(&d, B);
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        let out = pool
            .run_serial_equivalent(&mut be, &d, &shards, mode, &mut sink)
            .unwrap();
        assert_eq!(out.steps, 3);
        assert_eq!(out.workers[0].steps, 3);
        assert_eq!(out.workers[1].steps, 1);
        assert_eq!(out.samples, 26);
        assert_eq!(ref_be.param.to_bits(), be.param.to_bits());
        assert_eq!(ref_be.trace, be.trace);
        let (ra, rl) = ref_sink.result();
        let (pa, pl) = sink.result();
        assert_eq!(ra.to_bits(), pa.to_bits());
        assert_eq!(rl.to_bits(), pl.to_bits());

        // the data-parallel schedule tolerates the same raggedness, and
        // forward passes still match the serial-equivalent results
        let mut be_f = MockBackend::new();
        let mut sink_f = EvalSink::default();
        pool.run_serial_equivalent(&mut be_f, &d, &shards, StepMode::Forward, &mut sink_f)
            .unwrap();
        let mut be_dp = MockBackend::new();
        let mut sink_dp = EvalSink::default();
        let out = pool
            .run_data_parallel(&mut be_dp, &d, &shards, StepMode::Forward, &mut sink_dp)
            .unwrap();
        assert_eq!(out.samples, 26);
        let (fa, fl) = sink_f.result();
        let (da, dl) = sink_dp.result();
        assert_eq!(fa.to_bits(), da.to_bits());
        assert_eq!(fl.to_bits(), dl.to_bits());
    }

    /// Elastic recovery contract: a gather-lane kill mid-run re-issues
    /// the dead shard's tail, and the recovered run is bitwise identical
    /// to the undisturbed one.
    #[test]
    fn elastic_serial_recovery_is_bitwise_identical() {
        let d = tiny(53);
        let order: Vec<u32> = (0..53u32).rev().collect();
        let shards = shard_order_aligned(&order, 4, B);
        let mode = StepMode::Train { lr: 0.05 };

        let mut be_a = MockBackend::new();
        let mut sink_a = EvalSink::default();
        let mut pool_a = WorkerPool::new(&d, B);
        let out_a =
            pool_a.run_serial_equivalent(&mut be_a, &d, &shards, mode, &mut sink_a).unwrap();

        let mut be_b = MockBackend::new();
        let mut sink_b = EvalSink::default();
        let mut pool_b = WorkerPool::new(&d, B);
        pool_b.set_fault_policy(true, 0);
        pool_b.inject_chaos(ChaosPlan::new().kill(2, 1));
        let out_b =
            pool_b.run_serial_equivalent(&mut be_b, &d, &shards, mode, &mut sink_b).unwrap();

        assert_eq!(out_b.dropped_lanes, 1);
        assert_eq!(out_b.rejoined_lanes, 1);
        assert!(out_b.time_reissue >= 0.0);
        assert_eq!(out_a.dropped_lanes, 0);
        assert_eq!(be_a.param.to_bits(), be_b.param.to_bits());
        assert_eq!(be_a.trace, be_b.trace);
        let (aa, al) = sink_a.result();
        let (ba, bl) = sink_b.result();
        assert_eq!(aa.to_bits(), ba.to_bits());
        assert_eq!(al.to_bits(), bl.to_bits());
        assert_eq!(out_a.samples, out_b.samples);
        // recovered steps are attributed to the logical worker
        for (ra, rb) in out_a.workers.iter().zip(&out_b.workers) {
            assert_eq!(ra.steps, rb.steps);
            assert_eq!(ra.samples, rb.samples);
        }
    }

    /// Under the default fail policy a dead gather lane aborts with a
    /// named error instead of hanging the barrier.
    #[test]
    fn fail_policy_gather_death_aborts_with_named_error() {
        let d = tiny(53);
        let order: Vec<u32> = (0..53u32).collect();
        let shards = shard_order_aligned(&order, 2, B);
        let mut pool = WorkerPool::new(&d, B);
        pool.inject_chaos(ChaosPlan::new().kill(1, 0));
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        let err = pool
            .run_serial_equivalent(&mut be, &d, &shards, StepMode::Forward, &mut sink)
            .unwrap_err()
            .to_string();
        assert!(err.contains("worker 1 gather lane died at step 0"), "{err}");
        assert!(err.contains("--fault-policy"), "{err}");
    }

    /// A straggler past the timeout is recovered elastically with
    /// bitwise-identical results; under the fail policy it aborts with a
    /// named straggler error.
    #[test]
    fn straggler_timeout_detection_and_recovery() {
        let d = tiny(53);
        let order: Vec<u32> = (0..53u32).collect();
        let shards = shard_order_aligned(&order, 2, B);
        let mode = StepMode::Train { lr: 0.03 };

        let mut be_a = MockBackend::new();
        let mut sink_a = EvalSink::default();
        let mut pool_a = WorkerPool::new(&d, B);
        pool_a.run_serial_equivalent(&mut be_a, &d, &shards, mode, &mut sink_a).unwrap();

        // elastic: worker 1 stalls 400ms at its step 1, timeout 100ms
        let mut be_b = MockBackend::new();
        let mut sink_b = EvalSink::default();
        let mut pool_b = WorkerPool::new(&d, B);
        pool_b.set_fault_policy(true, 100);
        pool_b.inject_chaos(ChaosPlan::new().delay(1, 1, 400));
        let out =
            pool_b.run_serial_equivalent(&mut be_b, &d, &shards, mode, &mut sink_b).unwrap();
        assert!(out.dropped_lanes >= 1, "stall should trip the timeout");
        assert_eq!(be_a.param.to_bits(), be_b.param.to_bits());
        assert_eq!(be_a.trace, be_b.trace);

        // fail policy: the same stall aborts with a named error
        let mut pool_c = WorkerPool::new(&d, B);
        pool_c.set_fault_policy(false, 100);
        pool_c.inject_chaos(ChaosPlan::new().delay(0, 0, 500));
        let mut be_c = MockBackend::new();
        let mut sink_c = EvalSink::default();
        let err = pool_c
            .run_serial_equivalent(&mut be_c, &d, &shards, mode, &mut sink_c)
            .unwrap_err()
            .to_string();
        assert!(err.contains("straggler timeout"), "{err}");
        assert!(err.contains("worker 0"), "{err}");
    }

    /// Data-parallel elastic recovery: a replica killed mid-run has its
    /// remaining steps adopted by the primary from the pre-step snapshot,
    /// and the averaged parameters stay bitwise identical.
    #[test]
    fn elastic_data_parallel_replica_kill_matches_undisturbed() {
        use crate::engine::chaos::ChaosBackend;
        let d = tiny(48);
        let order: Vec<u32> = (0..48u32).collect();
        let shards = shard_order_aligned(&order, 2, B);
        let mode = StepMode::Train { lr: 0.05 };

        let mut be_a = MockBackend::new();
        let mut sink_a = EvalSink::default();
        let mut pool_a = WorkerPool::new(&d, B);
        pool_a.run_data_parallel(&mut be_a, &d, &shards, mode, &mut sink_a).unwrap();

        for kill_step in [0usize, 1, 2] {
            let mut be_b =
                ChaosBackend::primary(MockBackend::new(), ChaosPlan::new().kill(1, kill_step));
            let mut sink_b = EvalSink::default();
            let mut pool_b = WorkerPool::new(&d, B);
            pool_b.set_fault_policy(true, 0);
            let out =
                pool_b.run_data_parallel(&mut be_b, &d, &shards, mode, &mut sink_b).unwrap();
            assert_eq!(out.dropped_lanes, 1, "kill_step={kill_step}");
            assert_eq!(out.rejoined_lanes, 1, "kill_step={kill_step}");
            assert_eq!(
                be_a.param.to_bits(),
                be_b.inner().param.to_bits(),
                "kill_step={kill_step}"
            );
            let (aa, al) = sink_a.result();
            let (ba, bl) = sink_b.result();
            assert_eq!(aa.to_bits(), ba.to_bits(), "kill_step={kill_step}");
            assert_eq!(al.to_bits(), bl.to_bits(), "kill_step={kill_step}");
        }
    }

    /// Under the fail policy a killed replica aborts the data-parallel
    /// run with the named chaos error (no hang), and the pool recovers.
    #[test]
    fn fail_policy_replica_kill_aborts_with_named_error() {
        use crate::engine::chaos::ChaosBackend;
        let d = tiny(48);
        let order: Vec<u32> = (0..48u32).collect();
        let shards = shard_order_aligned(&order, 2, B);
        let mut be =
            ChaosBackend::primary(MockBackend::new(), ChaosPlan::new().kill(0, 1));
        let mut pool = WorkerPool::new(&d, B);
        let mut sink = EvalSink::default();
        let err = pool
            .run_data_parallel(&mut be, &d, &shards, StepMode::Train { lr: 0.02 }, &mut sink)
            .unwrap_err()
            .to_string();
        assert!(err.contains("worker 0 step failed"), "{err}");
        assert!(err.contains("chaos"), "{err}");
        // lanes were cleared; a healthy run succeeds afterwards
        let mut ok = MockBackend::new();
        let mut sink = EvalSink::default();
        pool.run_data_parallel(&mut ok, &d, &shards, StepMode::Forward, &mut sink).unwrap();
    }

    #[test]
    fn pool_recovers_after_failed_run() {
        struct Failing;
        impl StepBackend for Failing {
            fn train_step(
                &mut self,
                _x: &[f32],
                _y: &[i32],
                _sw: &[f32],
                _lr: f32,
            ) -> anyhow::Result<BatchStats> {
                anyhow::bail!("device lost")
            }
            fn fwd_stats(&mut self, _x: &[f32], _y: &[i32]) -> anyhow::Result<BatchStats> {
                anyhow::bail!("device lost")
            }
        }
        let d = tiny(40);
        let order: Vec<u32> = (0..32).collect();
        let shards = shard_order_aligned(&order, 2, B);
        let mut pool = WorkerPool::new(&d, B);
        let mut sink = EvalSink::default();
        assert!(pool
            .run_serial_equivalent(&mut Failing, &d, &shards, StepMode::Forward, &mut sink)
            .is_err());
        // a healthy backend still runs afterwards (buffers re-created)
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        let out = pool
            .run_serial_equivalent(&mut be, &d, &shards, StepMode::Forward, &mut sink)
            .unwrap();
        assert_eq!(out.samples, 32);
    }

    /// A replica whose steps fail must abort the data-parallel run with an
    /// error (not hang the barrier), and the pool must recover: lanes are
    /// respawned and a healthy run succeeds afterwards.
    #[test]
    fn data_parallel_recovers_after_failed_run() {
        #[derive(Clone)]
        struct FailingDp;
        impl StepBackend for FailingDp {
            fn train_step(
                &mut self,
                _x: &[f32],
                _y: &[i32],
                _sw: &[f32],
                _lr: f32,
            ) -> anyhow::Result<BatchStats> {
                anyhow::bail!("device lost")
            }
            fn fwd_stats(&mut self, _x: &[f32], _y: &[i32]) -> anyhow::Result<BatchStats> {
                anyhow::bail!("device lost")
            }
        }
        impl crate::engine::StateExchange for FailingDp {
            fn export_state(&self) -> anyhow::Result<Vec<Vec<f32>>> {
                Ok(vec![vec![0.0]])
            }
            fn import_state(&mut self, _state: &[Vec<f32>]) -> anyhow::Result<()> {
                Ok(())
            }
        }
        impl DataParallel for FailingDp {
            fn replica_builder(&self) -> anyhow::Result<ReplicaBuilder> {
                Ok(Box::new(move || {
                    Ok(Box::new(FailingDp) as Box<dyn crate::engine::ReplicaBackend>)
                }))
            }
        }
        let d = tiny(40);
        let order: Vec<u32> = (0..32).collect();
        let shards = shard_order_aligned(&order, 2, B);
        let mut pool = WorkerPool::new(&d, B);
        let mut sink = EvalSink::default();
        assert!(pool
            .run_data_parallel(&mut FailingDp, &d, &shards, StepMode::Forward, &mut sink)
            .is_err());
        // lanes were cleared; a healthy backend respawns them and runs
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        let out = pool
            .run_data_parallel(&mut be, &d, &shards, StepMode::Forward, &mut sink)
            .unwrap();
        assert_eq!(out.samples, 32);
    }

    #[test]
    fn data_parallel_forward_matches_serial_equivalent() {
        for w in [1usize, 2, 4] {
            let d = tiny(53);
            let order: Vec<u32> = (0..53u32).collect();
            let shards = shard_order_aligned(&order, w, B);
            let mut pool = WorkerPool::new(&d, B);

            let mut be_a = MockBackend::new();
            let mut sink_a = EvalSink::default();
            pool.run_serial_equivalent(&mut be_a, &d, &shards, StepMode::Forward, &mut sink_a)
                .unwrap();
            let mut be_b = MockBackend::new();
            let mut sink_b = EvalSink::default();
            pool.run_data_parallel(&mut be_b, &d, &shards, StepMode::Forward, &mut sink_b)
                .unwrap();

            let (aa, al) = sink_a.result();
            let (ba, bl) = sink_b.result();
            assert_eq!(aa.to_bits(), ba.to_bits(), "w={w}");
            assert_eq!(al.to_bits(), bl.to_bits(), "w={w}");
        }
    }

    #[test]
    fn data_parallel_train_identical_shards_average_to_single_lane() {
        // Both workers see the same shard, so every replica applies the
        // same update; the W=2 average of identical parameters is exact,
        // and the run must match the single-lane result bitwise.
        let d = tiny(32);
        let half: Vec<u32> = (0..16).collect();
        let doubled: Vec<u32> = half.iter().chain(half.iter()).copied().collect();
        let shards2 = shard_order_aligned(&doubled, 2, B);
        assert_eq!(shards2[0].indices, shards2[1].indices);
        let shards1 = shard_order_aligned(&half, 1, B);

        let mut pool = WorkerPool::new(&d, B);
        let mut be2 = MockBackend::new();
        let mut sink = EvalSink::default();
        pool.run_data_parallel(&mut be2, &d, &shards2, StepMode::Train { lr: 0.05 }, &mut sink)
            .unwrap();
        let mut pool1 = WorkerPool::new(&d, B);
        let mut be1 = MockBackend::new();
        let mut sink = EvalSink::default();
        pool1
            .run_data_parallel(&mut be1, &d, &shards1, StepMode::Train { lr: 0.05 }, &mut sink)
            .unwrap();
        assert_eq!(be1.param.to_bits(), be2.param.to_bits());
    }

    #[test]
    fn data_parallel_train_is_deterministic() {
        let run = || {
            let d = tiny(53);
            let order: Vec<u32> = (0..53u32).collect();
            let shards = shard_order_aligned(&order, 4, B);
            let mut pool = WorkerPool::new(&d, B);
            let mut be = MockBackend::new();
            let mut sink = EvalSink::default();
            pool.run_data_parallel(&mut be, &d, &shards, StepMode::Train { lr: 0.02 }, &mut sink)
                .unwrap();
            let (_, loss) = sink.result();
            (be.param.to_bits(), loss.to_bits())
        };
        assert_eq!(run(), run());
    }

    /// Lanes persist across runs: a second run through the same pool must
    /// re-sync replicas to the primary's *current* state, not continue
    /// from whatever the previous run's averaging left behind.
    #[test]
    fn persistent_lanes_resync_between_runs() {
        let d = tiny(32);
        let order: Vec<u32> = (0..32).collect();
        let shards = shard_order_aligned(&order, 2, B);
        let mode = StepMode::Train { lr: 0.04 };

        // reference: two fresh pools, primary state carried across
        let mut be_ref = MockBackend::new();
        for _ in 0..2 {
            let mut pool = WorkerPool::new(&d, B);
            let mut sink = EvalSink::default();
            pool.run_data_parallel(&mut be_ref, &d, &shards, mode, &mut sink).unwrap();
        }
        // same two epochs through one pool (lanes reused)
        let mut be = MockBackend::new();
        let mut pool = WorkerPool::new(&d, B);
        for _ in 0..2 {
            let mut sink = EvalSink::default();
            pool.run_data_parallel(&mut be, &d, &shards, mode, &mut sink).unwrap();
        }
        assert_eq!(be_ref.param.to_bits(), be.param.to_bits());
    }

    /// The averaging schedule reports its reduction accounting.
    #[test]
    fn averaging_outcome_accounting() {
        let d = tiny(48);
        let order: Vec<u32> = (0..48).collect();
        let shards = shard_order_aligned(&order, 2, B);
        let mut pool = WorkerPool::new(&d, B);
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        let out = pool
            .run_data_parallel(&mut be, &d, &shards, StepMode::Train { lr: 0.01 }, &mut sink)
            .unwrap();
        assert_eq!(out.sync_steps, out.steps);
        assert!(out.time_average >= 0.0);
        // forward passes never average
        let mut sink = EvalSink::default();
        let out = pool
            .run_data_parallel(&mut be, &d, &shards, StepMode::Forward, &mut sink)
            .unwrap();
        assert_eq!(out.sync_steps, 0);
        assert_eq!(out.time_average, 0.0);
    }
}
