//! The data-parallel worker pool: N pipelined gather lanes behind one
//! deterministic, bulk-synchronous reduction.
//!
//! # Execution model
//!
//! The coordinator shards each epoch's order with
//! [`crate::data::shard::shard_order_aligned`], so every worker owns the
//! same number of full device batches (ragged shards are rejected: the
//! step barrier is bulk-synchronous and a short lane would deadlock a real
//! allreduce — see docs/worker-model.md).  Each worker owns its own
//! double-buffered pipelined driver over its [`Shard`]: a gather lane
//! (one prefetch thread + two parked [`BatchAssembler`]s handed over by
//! value through channels, exactly the engine's overlap scheme) that
//! keeps filling batch `s+1` while batch `s` executes.
//!
//! Two schedules consume the lanes:
//!
//! * [`WorkerPool::run_serial_equivalent`] — the default and the
//!   determinism contract.  All device steps execute on the *primary*
//!   backend, in fixed `(step, worker)` order; only the host-side gather
//!   fans out.  The result is **bitwise identical** to a single serial
//!   stream over [`crate::data::shard::global_batch_order`] — N workers
//!   are an execution detail, not a semantics change.
//! * [`WorkerPool::run_data_parallel`] — true synchronous data-parallel
//!   SGD.  Every worker steps its own [`DataParallel`] replica; at each
//!   step barrier the pool folds the workers' [`BatchStats`] into the
//!   sink in fixed worker order and (for train steps) averages the
//!   replica parameters with the same fixed-order fold, so results are
//!   deterministic run to run.  Forward-only passes are additionally
//!   bitwise identical to the serial-equivalent schedule (parameters
//!   never change); train passes follow global-batch SGD semantics and
//!   are *not* serial-equivalent (documented in docs/worker-model.md).
//!
//! # Determinism contract
//!
//! Enforced by `tests/worker_pool_determinism.rs` and the
//! `pool_reduction_matches_serial_interleaved_fold` property test
//! (`tests/property_invariants.rs`): for any (order length, worker
//! count, batch size), the serial-equivalent pool run produces
//! bit-for-bit the stats, sink state, and backend state of the
//! single-stream interleaved run *for that worker count*.  Changing the
//! worker count itself changes the sharding (wrap padding and batch
//! composition), exactly as adding ranks does in a real distributed
//! sampler — the contract is "threads are invisible", not "W is
//! invisible".

use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender};
use std::sync::Arc;

use super::backend::{accumulate_state, finish_average, DataParallel};
use super::{dispatch, StepBackend, StepCtx, StepMode, StepSink};
use crate::data::batch::{BatchAssembler, DoubleBuffer};
use crate::data::shard::Shard;
use crate::data::Dataset;
use crate::runtime::BatchStats;
use crate::util::timer::Timer;

/// Per-worker execution accounting for one pool run.
#[derive(Clone, Debug, Default)]
pub struct WorkerReport {
    /// Worker rank (matches `Shard::worker`).
    pub worker: usize,
    /// Device steps executed for this worker's shard.
    pub steps: usize,
    /// Real (non-padding) samples executed for this worker.
    pub samples: usize,
    /// Seconds the reduction loop spent blocked on this worker's lane.
    /// In the serial-equivalent schedule this is gather starvation on the
    /// device's critical path.  In the data-parallel schedule the
    /// reduction loop has no work of its own, so lane 0's wait absorbs
    /// each step's full gather+compute latency and later lanes measure
    /// only the skew behind lane 0 — use the serial-equivalent figure
    /// when quoting coordination overhead.
    pub wait_s: f64,
}

/// What one pool run executed (rolled up into `EpochRecord`).
#[derive(Clone, Debug, Default)]
pub struct PoolOutcome {
    /// Bulk-synchronous global steps taken (each executes one batch per
    /// worker).
    pub steps: usize,
    /// Total real samples executed across workers.
    pub samples: usize,
    /// Per-worker accounting, indexed by worker rank.
    pub workers: Vec<WorkerReport>,
}

/// Messages a data-parallel worker lane sends to the reduction loop.
enum LaneMsg {
    /// One executed step: its stats plus the slot map of the batch.
    Step { stats: BatchStats, slots: Vec<u32>, real: usize },
    /// The lane's backend failed; the run aborts.
    Fail(String),
}

/// The multi-worker execution driver.  Owns the per-worker parked batch
/// buffers (reused across epochs and across train/refresh runs) plus a
/// scratch assembler for sink-issued immediate steps.
pub struct WorkerPool {
    batch: usize,
    /// Per-worker parked assembler pairs (lane w uses `buffers[w]`).
    buffers: Vec<DoubleBuffer>,
    scratch: BatchAssembler,
}

impl WorkerPool {
    /// A pool sized for `data`'s sample layout at device batch `batch`.
    /// Lanes allocate lazily on first use, so construction is cheap for
    /// single-worker configs.
    pub fn new(data: &Dataset, batch: usize) -> Self {
        WorkerPool { batch, buffers: Vec::new(), scratch: BatchAssembler::new(data, batch) }
    }

    /// The device batch size each lane assembles.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Validate shards, size the lane buffer pools, and compute the step
    /// count.  Returns `(steps, outcome skeleton)`.
    fn prepare(
        &mut self,
        data: &Dataset,
        shards: &[Shard],
    ) -> anyhow::Result<(usize, PoolOutcome)> {
        anyhow::ensure!(!shards.is_empty(), "worker pool needs at least one shard");
        let len = shards[0].len();
        anyhow::ensure!(
            shards.iter().all(|s| s.len() == len),
            "ragged shards: every worker must take the same number of steps \
             (the step barrier is bulk-synchronous; see docs/worker-model.md)"
        );
        while self.buffers.len() < shards.len() {
            self.buffers.push(DoubleBuffer::new(data, self.batch));
        }
        if !self.scratch.matches(data) {
            self.scratch = BatchAssembler::new(data, self.batch);
        }
        let steps = len.div_ceil(self.batch);
        let workers = (0..shards.len())
            .map(|w| WorkerReport { worker: w, ..Default::default() })
            .collect();
        Ok((steps, PoolOutcome { steps, samples: 0, workers }))
    }

    /// Take the initial assemblers for each lane (two per worker, fewer
    /// when the run is shorter).
    fn take_lanes(
        &mut self,
        data: &Dataset,
        workers: usize,
        steps: usize,
    ) -> Vec<Vec<BatchAssembler>> {
        let mut lanes = Vec::with_capacity(workers);
        for w in 0..workers {
            let mut lane = Vec::with_capacity(steps.min(2));
            for _ in 0..steps.min(2) {
                lane.push(self.buffers[w].take(data));
            }
            lanes.push(lane);
        }
        lanes
    }

    /// Execute `shards` through the **serial-equivalent** schedule: worker
    /// gather lanes fill batches concurrently, while every device step
    /// runs on `backend` in fixed `(step, worker)` order.  Bitwise
    /// identical to driving the engine over
    /// [`crate::data::shard::global_batch_order`] on a single stream.
    pub fn run_serial_equivalent(
        &mut self,
        backend: &mut dyn StepBackend,
        data: &Dataset,
        shards: &[Shard],
        mode: StepMode,
        sink: &mut dyn StepSink,
    ) -> anyhow::Result<PoolOutcome> {
        let (steps, mut outcome) = self.prepare(data, shards)?;
        let w_count = shards.len();
        let bs = self.batch;
        if steps == 0 {
            let mut ctx = StepCtx { backend, scratch: &mut self.scratch, data };
            sink.finish(&mut ctx)?;
            return Ok(outcome);
        }
        let lanes = self.take_lanes(data, w_count, steps);
        let scratch = &mut self.scratch;

        let parked = std::thread::scope(
            |scope| -> anyhow::Result<Vec<(usize, BatchAssembler)>> {
                let mut done_rx = Vec::with_capacity(w_count);
                let mut back_tx = Vec::with_capacity(w_count);
                for (shard, initial) in shards.iter().zip(lanes) {
                    let (d_tx, d_rx) = sync_channel::<BatchAssembler>(1);
                    let (b_tx, b_rx) = channel::<BatchAssembler>();
                    spawn_filler(scope, shard, data, bs, steps, initial, b_rx, d_tx);
                    done_rx.push(d_rx);
                    back_tx.push(b_tx);
                }

                let mut parked = Vec::with_capacity(w_count * steps.min(2));
                for s in 0..steps {
                    for w in 0..w_count {
                        let t = Timer::start();
                        let buf = done_rx[w]
                            .recv()
                            .map_err(|_| anyhow::anyhow!("worker {w} gather lane died"))?;
                        outcome.workers[w].wait_s += t.elapsed_s();
                        let stats = dispatch(&mut *backend, mode, &buf)?;
                        let mut ctx =
                            StepCtx { backend: &mut *backend, scratch: &mut *scratch, data };
                        sink.on_batch(&mut ctx, &buf.slots, buf.real, &stats)?;
                        outcome.samples += buf.real;
                        outcome.workers[w].samples += buf.real;
                        outcome.workers[w].steps += 1;
                        if s + 2 < steps {
                            let _ = back_tx[w].send(buf);
                        } else {
                            parked.push((w, buf));
                        }
                    }
                }
                drop(back_tx);
                let mut ctx = StepCtx { backend, scratch, data };
                sink.finish(&mut ctx)?;
                Ok(parked)
            },
        )?;
        for (w, buf) in parked {
            self.buffers[w].put(buf);
        }
        Ok(outcome)
    }

    /// Execute `shards` through the **data-parallel** schedule: worker `w`
    /// steps its own replica of `primary` over its shard; at each step
    /// barrier the stats fold into `sink` in fixed worker order and (for
    /// [`StepMode::Train`]) replica parameters are averaged with the same
    /// fixed-order fold, after which `primary` receives the final averaged
    /// state.  Deterministic run to run; bitwise serial-equivalent for
    /// forward-only modes.
    pub fn run_data_parallel<B: DataParallel + Send>(
        &mut self,
        primary: &mut B,
        data: &Dataset,
        shards: &[Shard],
        mode: StepMode,
        sink: &mut dyn StepSink,
    ) -> anyhow::Result<PoolOutcome> {
        let (steps, mut outcome) = self.prepare(data, shards)?;
        let w_count = shards.len();
        let bs = self.batch;
        if steps == 0 {
            let mut ctx = StepCtx { backend: primary, scratch: &mut self.scratch, data };
            sink.finish(&mut ctx)?;
            return Ok(outcome);
        }
        let averaging = matches!(mode, StepMode::Train { .. });
        let mut replicas: Vec<B> = (0..w_count)
            .map(|_| primary.replicate())
            .collect::<anyhow::Result<_>>()?;
        let lanes = self.take_lanes(data, w_count, steps);
        let scratch = &mut self.scratch;

        let parked = std::thread::scope(
            |scope| -> anyhow::Result<Vec<(usize, BatchAssembler)>> {
                let mut stat_rx = Vec::with_capacity(w_count);
                let mut state_rx = Vec::with_capacity(w_count);
                let mut sync_tx = Vec::with_capacity(w_count);
                let (park_tx, park_rx) = channel::<(usize, BatchAssembler)>();
                for ((w, (shard, initial)), replica) in
                    shards.iter().zip(lanes).enumerate().zip(replicas.iter_mut())
                {
                    let (d_tx, d_rx) = sync_channel::<BatchAssembler>(1);
                    let (b_tx, b_rx) = channel::<BatchAssembler>();
                    spawn_filler(scope, shard, data, bs, steps, initial, b_rx, d_tx);

                    let (st_tx, st_rx) = sync_channel::<LaneMsg>(1);
                    let (sx_tx, sx_rx) = channel::<Vec<Vec<f32>>>();
                    let (av_tx, av_rx) = channel::<Arc<Vec<Vec<f32>>>>();
                    stat_rx.push(st_rx);
                    state_rx.push(sx_rx);
                    sync_tx.push(av_tx);
                    let park = park_tx.clone();
                    scope.spawn(move || {
                        for s in 0..steps {
                            let buf = match d_rx.recv() {
                                Ok(b) => b,
                                Err(_) => return,
                            };
                            let result = dispatch(&mut *replica, mode, &buf);
                            let (slots, real) = (buf.slots.clone(), buf.real);
                            // recycle the buffer before the barrier so the
                            // gather lane keeps running through the wait
                            if s + 2 < steps {
                                let _ = b_tx.send(buf);
                            } else {
                                let _ = park.send((w, buf));
                            }
                            let stats = match result {
                                Ok(stats) => stats,
                                Err(e) => {
                                    let _ = st_tx.send(LaneMsg::Fail(e.to_string()));
                                    return;
                                }
                            };
                            if st_tx.send(LaneMsg::Step { stats, slots, real }).is_err() {
                                return;
                            }
                            if averaging {
                                let state = match replica.export_state() {
                                    Ok(st) => st,
                                    Err(_) => return,
                                };
                                if sx_tx.send(state).is_err() {
                                    return;
                                }
                                let avg = match av_rx.recv() {
                                    Ok(a) => a,
                                    Err(_) => return,
                                };
                                if replica.import_state(&avg).is_err() {
                                    return;
                                }
                            }
                        }
                    });
                }
                drop(park_tx);

                let mut last_avg: Option<Arc<Vec<Vec<f32>>>> = None;
                for _s in 0..steps {
                    for w in 0..w_count {
                        let t = Timer::start();
                        let msg = stat_rx[w]
                            .recv()
                            .map_err(|_| anyhow::anyhow!("worker {w} lane died"))?;
                        outcome.workers[w].wait_s += t.elapsed_s();
                        match msg {
                            LaneMsg::Step { stats, slots, real } => {
                                let mut ctx = StepCtx {
                                    backend: &mut *primary,
                                    scratch: &mut *scratch,
                                    data,
                                };
                                sink.on_batch(&mut ctx, &slots, real, &stats)?;
                                outcome.samples += real;
                                outcome.workers[w].samples += real;
                                outcome.workers[w].steps += 1;
                            }
                            LaneMsg::Fail(e) => {
                                anyhow::bail!("worker {w} step failed: {e}")
                            }
                        }
                    }
                    if averaging {
                        // fixed worker-order fold: w0 + w1 + ... then / W
                        let mut acc = state_rx[0]
                            .recv()
                            .map_err(|_| anyhow::anyhow!("worker 0 state lane died"))?;
                        for rx in state_rx.iter().skip(1) {
                            let st = rx
                                .recv()
                                .map_err(|_| anyhow::anyhow!("worker state lane died"))?;
                            accumulate_state(&mut acc, &st)?;
                        }
                        finish_average(&mut acc, w_count);
                        let avg = Arc::new(acc);
                        for tx in &sync_tx {
                            let _ = tx.send(avg.clone());
                        }
                        last_avg = Some(avg);
                    }
                }

                let mut parked = Vec::with_capacity(w_count * steps.min(2));
                for _ in 0..w_count * steps.min(2) {
                    let pair = park_rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("worker lane died before parking"))?;
                    parked.push(pair);
                }
                if let Some(avg) = last_avg {
                    primary.import_state(&avg)?;
                }
                let mut ctx = StepCtx { backend: primary, scratch, data };
                sink.finish(&mut ctx)?;
                Ok(parked)
            },
        )?;
        for (w, buf) in parked {
            self.buffers[w].put(buf);
        }
        Ok(outcome)
    }
}

/// Spawn one worker's gather lane: fills its shard's batches in step
/// order, double-buffered (two assemblers circulating by value through
/// the `back_rx` / `out_tx` channel pair).
#[allow(clippy::too_many_arguments)]
fn spawn_filler<'scope, 'env>(
    scope: &'scope std::thread::Scope<'scope, 'env>,
    shard: &'env Shard,
    data: &'env Dataset,
    batch: usize,
    steps: usize,
    mut initial: Vec<BatchAssembler>,
    back_rx: Receiver<BatchAssembler>,
    out_tx: SyncSender<BatchAssembler>,
) {
    scope.spawn(move || {
        for s in 0..steps {
            let mut buf = match initial.pop() {
                Some(b) => b,
                None => match back_rx.recv() {
                    Ok(b) => b,
                    Err(_) => return,
                },
            };
            buf.fill(data, shard.step_batch(s, batch), None);
            if out_tx.send(buf).is_err() {
                return;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shard::{global_batch_order, shard_order_aligned};
    use crate::data::synth::{gauss_mixture, GaussMixtureCfg};
    use crate::engine::testbed::MockBackend;
    use crate::engine::{Engine, EvalSink};

    const B: usize = 8;

    fn tiny(n: usize) -> Dataset {
        gauss_mixture(
            &GaussMixtureCfg { n_train: n, n_val: 4, dim: 6, classes: 3, ..Default::default() },
            7,
        )
        .train
    }

    fn eval_serial_equiv(n: usize, w: usize, mode: StepMode) -> (f64, f64, u32) {
        let d = tiny(n);
        let order: Vec<u32> = (0..n as u32).rev().collect();
        let shards = shard_order_aligned(&order, w, B);
        let mut pool = WorkerPool::new(&d, B);
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        pool.run_serial_equivalent(&mut be, &d, &shards, mode, &mut sink).unwrap();
        let (acc, loss) = sink.result();
        (acc, loss, be.param.to_bits())
    }

    #[test]
    fn pool_matches_engine_over_interleaved_stream() {
        for w in [1usize, 2, 3, 4] {
            let d = tiny(53);
            let order: Vec<u32> = (0..53u32).rev().collect();
            let shards = shard_order_aligned(&order, w, B);

            let mut eng = Engine::new(&d, B);
            eng.overlap = true;
            let mut ref_be = MockBackend::new();
            let mut ref_sink = EvalSink::default();
            let flat = global_batch_order(&shards, B);
            eng.run(&mut ref_be, &d, &flat, None, StepMode::Train { lr: 0.05 }, &mut ref_sink)
                .unwrap();

            let mut pool = WorkerPool::new(&d, B);
            let mut be = MockBackend::new();
            let mut sink = EvalSink::default();
            let mode = StepMode::Train { lr: 0.05 };
            let out = pool.run_serial_equivalent(&mut be, &d, &shards, mode, &mut sink).unwrap();

            assert_eq!(ref_be.param.to_bits(), be.param.to_bits(), "w={w}");
            assert_eq!(ref_be.trace, be.trace, "w={w}");
            let (ra, rl) = ref_sink.result();
            let (pa, pl) = sink.result();
            assert_eq!(ra.to_bits(), pa.to_bits(), "w={w}");
            assert_eq!(rl.to_bits(), pl.to_bits(), "w={w}");
            assert_eq!(out.samples, flat.len(), "w={w}");
            assert_eq!(out.steps * w, out.workers.iter().map(|r| r.steps).sum::<usize>());
        }
    }

    #[test]
    fn pool_runs_are_reproducible() {
        let a = eval_serial_equiv(53, 4, StepMode::Train { lr: 0.03 });
        let b = eval_serial_equiv(53, 4, StepMode::Train { lr: 0.03 });
        assert_eq!(a.2, b.2);
        assert_eq!(a.1.to_bits(), b.1.to_bits());
    }

    #[test]
    fn empty_and_tiny_epochs_do_not_panic() {
        for w in [1usize, 4] {
            for mode in [StepMode::Forward, StepMode::Train { lr: 0.01 }] {
                // empty epoch (heavy hiding can empty the order entirely)
                let d = tiny(16);
                let shards = shard_order_aligned(&[], w, B);
                let mut pool = WorkerPool::new(&d, B);
                let mut be = MockBackend::new();
                let mut sink = EvalSink::default();
                let out = pool
                    .run_serial_equivalent(&mut be, &d, &shards, mode, &mut sink)
                    .unwrap();
                assert_eq!(out.samples, 0);
                // fewer samples than workers: wrap-padding fills every lane
                let order: Vec<u32> = (0..3).collect();
                let shards = shard_order_aligned(&order, w, B);
                let mut sink = EvalSink::default();
                let out = pool
                    .run_serial_equivalent(&mut be, &d, &shards, mode, &mut sink)
                    .unwrap();
                assert_eq!(out.samples, w * B);
            }
        }
    }

    #[test]
    fn ragged_shards_rejected() {
        let d = tiny(16);
        let shards = vec![
            Shard { worker: 0, indices: vec![0, 1, 2] },
            Shard { worker: 1, indices: vec![3, 4] },
        ];
        let mut pool = WorkerPool::new(&d, B);
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        assert!(pool
            .run_serial_equivalent(&mut be, &d, &shards, StepMode::Forward, &mut sink)
            .is_err());
        assert!(pool
            .run_data_parallel(&mut be, &d, &shards, StepMode::Forward, &mut sink)
            .is_err());
    }

    #[test]
    fn pool_recovers_after_failed_run() {
        struct Failing;
        impl StepBackend for Failing {
            fn train_step(
                &mut self,
                _x: &[f32],
                _y: &[i32],
                _sw: &[f32],
                _lr: f32,
            ) -> anyhow::Result<BatchStats> {
                anyhow::bail!("device lost")
            }
            fn fwd_stats(&mut self, _x: &[f32], _y: &[i32]) -> anyhow::Result<BatchStats> {
                anyhow::bail!("device lost")
            }
        }
        let d = tiny(40);
        let order: Vec<u32> = (0..32).collect();
        let shards = shard_order_aligned(&order, 2, B);
        let mut pool = WorkerPool::new(&d, B);
        let mut sink = EvalSink::default();
        assert!(pool
            .run_serial_equivalent(&mut Failing, &d, &shards, StepMode::Forward, &mut sink)
            .is_err());
        // a healthy backend still runs afterwards (buffers re-created)
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        let out = pool
            .run_serial_equivalent(&mut be, &d, &shards, StepMode::Forward, &mut sink)
            .unwrap();
        assert_eq!(out.samples, 32);
    }

    #[test]
    fn data_parallel_forward_matches_serial_equivalent() {
        for w in [1usize, 2, 4] {
            let d = tiny(53);
            let order: Vec<u32> = (0..53u32).collect();
            let shards = shard_order_aligned(&order, w, B);
            let mut pool = WorkerPool::new(&d, B);

            let mut be_a = MockBackend::new();
            let mut sink_a = EvalSink::default();
            pool.run_serial_equivalent(&mut be_a, &d, &shards, StepMode::Forward, &mut sink_a)
                .unwrap();
            let mut be_b = MockBackend::new();
            let mut sink_b = EvalSink::default();
            pool.run_data_parallel(&mut be_b, &d, &shards, StepMode::Forward, &mut sink_b)
                .unwrap();

            let (aa, al) = sink_a.result();
            let (ba, bl) = sink_b.result();
            assert_eq!(aa.to_bits(), ba.to_bits(), "w={w}");
            assert_eq!(al.to_bits(), bl.to_bits(), "w={w}");
        }
    }

    #[test]
    fn data_parallel_train_identical_shards_average_to_single_lane() {
        // Both workers see the same shard, so every replica applies the
        // same update; the W=2 average of identical parameters is exact,
        // and the run must match the single-lane result bitwise.
        let d = tiny(32);
        let half: Vec<u32> = (0..16).collect();
        let doubled: Vec<u32> = half.iter().chain(half.iter()).copied().collect();
        let shards2 = shard_order_aligned(&doubled, 2, B);
        assert_eq!(shards2[0].indices, shards2[1].indices);
        let shards1 = shard_order_aligned(&half, 1, B);

        let mut pool = WorkerPool::new(&d, B);
        let mut be2 = MockBackend::new();
        let mut sink = EvalSink::default();
        pool.run_data_parallel(&mut be2, &d, &shards2, StepMode::Train { lr: 0.05 }, &mut sink)
            .unwrap();
        let mut be1 = MockBackend::new();
        let mut sink = EvalSink::default();
        pool.run_data_parallel(&mut be1, &d, &shards1, StepMode::Train { lr: 0.05 }, &mut sink)
            .unwrap();
        assert_eq!(be1.param.to_bits(), be2.param.to_bits());
    }

    #[test]
    fn data_parallel_train_is_deterministic() {
        let run = || {
            let d = tiny(53);
            let order: Vec<u32> = (0..53u32).collect();
            let shards = shard_order_aligned(&order, 4, B);
            let mut pool = WorkerPool::new(&d, B);
            let mut be = MockBackend::new();
            let mut sink = EvalSink::default();
            pool.run_data_parallel(&mut be, &d, &shards, StepMode::Train { lr: 0.02 }, &mut sink)
                .unwrap();
            let (_, loss) = sink.result();
            (be.param.to_bits(), loss.to_bits())
        };
        assert_eq!(run(), run());
    }
}
