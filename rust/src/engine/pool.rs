//! The data-parallel worker pool: N pipelined gather lanes behind one
//! deterministic, bulk-synchronous reduction.
//!
//! # Execution model
//!
//! The coordinator shards each epoch's order with
//! [`crate::data::shard::shard_order_aligned`], so every worker owns the
//! same number of full device batches (ragged shards are rejected: the
//! step barrier is bulk-synchronous and a short lane would deadlock a real
//! allreduce — see docs/worker-model.md).  Each worker owns its own
//! double-buffered pipelined driver over its [`Shard`]: a gather lane
//! (one prefetch thread + two parked [`BatchAssembler`]s handed over by
//! value through channels, exactly the engine's overlap scheme) that
//! keeps filling batch `s+1` while batch `s` executes.
//!
//! Two schedules consume the lanes:
//!
//! * [`WorkerPool::run_serial_equivalent`] — the default and the
//!   determinism contract.  All device steps execute on the *primary*
//!   backend, in fixed `(step, worker)` order; only the host-side gather
//!   fans out.  The result is **bitwise identical** to a single serial
//!   stream over [`crate::data::shard::global_batch_order`] — N workers
//!   are an execution detail, not a semantics change.
//! * [`WorkerPool::run_data_parallel`] — true synchronous data-parallel
//!   SGD.  Every worker steps its own replica on a persistent *replica
//!   lane* thread; at each step barrier the pool folds the workers'
//!   [`BatchStats`] into the sink in fixed worker order and (for train
//!   steps) averages the replica parameters with the same fixed-order
//!   fold, so results are deterministic run to run.  Forward-only passes
//!   are additionally bitwise identical to the serial-equivalent schedule
//!   (parameters never change); train passes follow global-batch SGD
//!   semantics and are *not* serial-equivalent (documented in
//!   docs/worker-model.md).
//!
//! # Replica lanes and the `Send` boundary
//!
//! The production backend's device state is not `Send`, so replicas can
//! never be constructed on one thread and moved to another.  Instead the
//! pool ships a [`ReplicaBuilder`] (a `Send` constructor carrying only
//! host data) into each lane thread, which *builds* its replica locally
//! and owns it for the lane's whole life.  Lane threads are persistent —
//! spawned on the first [`WorkerPool::run_data_parallel`] call and reused
//! across epochs — so a PJRT replica's per-thread client and compiled
//! executables are paid once per training run, not once per epoch.  Every
//! run starts by broadcasting the primary's exported state, so replicas
//! are bitwise-synchronized regardless of what earlier runs left behind.
//!
//! # Determinism contract
//!
//! Enforced by `tests/worker_pool_determinism.rs` and the
//! `pool_reduction_matches_serial_interleaved_fold` property test
//! (`tests/property_invariants.rs`): for any (order length, worker
//! count, batch size), the serial-equivalent pool run produces
//! bit-for-bit the stats, sink state, and backend state of the
//! single-stream interleaved run *for that worker count*.  Changing the
//! worker count itself changes the sharding (wrap padding and batch
//! composition), exactly as adding ranks does in a real distributed
//! sampler — the contract is "threads are invisible", not "W is
//! invisible".

use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;

use super::backend::{accumulate_state, finish_average, DataParallel, ReplicaBuilder, StateExchange};
use super::snapshot::{SharedSnapshot, Snapshot, SnapshotTier};
use super::{dispatch, StepBackend, StepCtx, StepMode, StepSink};
use crate::data::batch::{BatchAssembler, DoubleBuffer};
use crate::data::shard::Shard;
use crate::data::Dataset;
use crate::runtime::BatchStats;
use crate::util::timer::Timer;

/// Per-worker execution accounting for one pool run.
#[derive(Clone, Debug, Default)]
pub struct WorkerReport {
    /// Worker rank (matches `Shard::worker`).
    pub worker: usize,
    /// Device steps executed for this worker's shard.
    pub steps: usize,
    /// Real (non-padding) samples executed for this worker.
    pub samples: usize,
    /// Seconds the reduction loop spent blocked on this worker's lane.
    /// In the serial-equivalent schedule this is gather starvation on the
    /// device's critical path.  In the data-parallel schedule the
    /// reduction loop has no work of its own, so lane 0's wait absorbs
    /// each step's full gather+compute latency and later lanes measure
    /// only the skew behind lane 0 — use the serial-equivalent figure
    /// when quoting coordination overhead.
    pub wait_s: f64,
}

/// What one pool run executed (rolled up into `EpochRecord`).
#[derive(Clone, Debug, Default)]
pub struct PoolOutcome {
    /// Bulk-synchronous global steps taken (each executes one batch per
    /// worker).
    pub steps: usize,
    /// Total real samples executed across workers.
    pub samples: usize,
    /// Parameter-averaging reductions performed (data-parallel train
    /// schedule only; one per global step there, 0 otherwise).
    pub sync_steps: usize,
    /// Seconds spent finalizing and broadcasting the averaged state
    /// across the syncs above (the host-side allreduce cost).
    pub time_average: f64,
    /// Per-worker accounting, indexed by worker rank.
    pub workers: Vec<WorkerReport>,
}

/// Commands the reduction loop sends a persistent replica lane.
enum LaneCmd {
    /// Replace the replica's state with this typed snapshot (the averaged
    /// state at a step barrier, or the primary's state at run start).
    /// Always the [`SnapshotTier::Full`] tier: true synchronous SGD must
    /// keep every replica's *optimizer trajectory* identical, so the
    /// `--dp average` sync never rides the params-only fast path.
    Sync(SharedSnapshot),
    /// Execute one step on an assembled batch; reply with
    /// [`LaneReply::Step`], exporting the post-step state when `export`.
    Step {
        buf: BatchAssembler,
        mode: StepMode,
        export: bool,
    },
}

/// Replies a replica lane sends back to the reduction loop.
enum LaneReply {
    /// The replica finished building; the lane accepts commands.
    Ready,
    /// One executed step: the recycled batch buffer, its stats, and (when
    /// requested) the replica's post-step state snapshot.
    Step {
        buf: BatchAssembler,
        stats: BatchStats,
        state: Option<Vec<Vec<f32>>>,
    },
    /// The lane's replica failed; the run aborts and the lane exits.
    Fail(String),
}

/// A persistent worker thread owning one data-parallel replica.
///
/// The replica is *built on* this thread (via a [`ReplicaBuilder`]) and
/// never leaves it; all communication crosses the channel pair as `Send`
/// host values.  Dropping the lane closes the command channel, which
/// shuts the thread down; `Drop` joins it.
struct ReplicaLane {
    cmd_tx: Option<Sender<LaneCmd>>,
    reply_rx: Receiver<LaneReply>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ReplicaLane {
    /// Spawn the lane thread; the replica builds asynchronously and the
    /// lane reports [`LaneReply::Ready`] (or `Fail`) as its first reply.
    fn spawn(worker: usize, build: ReplicaBuilder) -> anyhow::Result<Self> {
        let (cmd_tx, cmd_rx) = channel::<LaneCmd>();
        let (reply_tx, reply_rx) = channel::<LaneReply>();
        let handle = std::thread::Builder::new()
            .name(format!("dp-replica-{worker}"))
            .spawn(move || lane_main(build, cmd_rx, reply_tx))?;
        Ok(ReplicaLane { cmd_tx: Some(cmd_tx), reply_rx, handle: Some(handle) })
    }

    fn send(&self, cmd: LaneCmd) -> anyhow::Result<()> {
        self.cmd_tx
            .as_ref()
            .expect("lane alive until drop")
            .send(cmd)
            .map_err(|_| anyhow::anyhow!("replica lane died"))
    }

    fn recv(&self) -> anyhow::Result<LaneReply> {
        self.reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("replica lane died"))
    }
}

impl Drop for ReplicaLane {
    fn drop(&mut self) {
        drop(self.cmd_tx.take()); // disconnect: lane_main's recv loop exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Lane thread body: build the replica locally, then serve commands until
/// the pool drops the command channel.
fn lane_main(build: ReplicaBuilder, cmd_rx: Receiver<LaneCmd>, reply_tx: Sender<LaneReply>) {
    let mut replica = match build() {
        Ok(r) => r,
        Err(e) => {
            let _ = reply_tx.send(LaneReply::Fail(format!("replica build: {e}")));
            return;
        }
    };
    if reply_tx.send(LaneReply::Ready).is_err() {
        return;
    }
    while let Ok(cmd) = cmd_rx.recv() {
        match cmd {
            LaneCmd::Sync(snap) => {
                if let Err(e) = replica.import_snapshot(&snap) {
                    let _ = reply_tx.send(LaneReply::Fail(format!("state import: {e}")));
                    return;
                }
            }
            LaneCmd::Step { buf, mode, export } => {
                let result = match mode {
                    StepMode::Train { lr } => {
                        replica.train_step(&buf.x, &buf.y, &buf.sw, lr)
                    }
                    StepMode::Forward => replica.fwd_stats(&buf.x, &buf.y),
                };
                let stats = match result {
                    Ok(s) => s,
                    Err(e) => {
                        let _ = reply_tx.send(LaneReply::Fail(e.to_string()));
                        return;
                    }
                };
                let state = if export {
                    match replica.export_state() {
                        Ok(s) => Some(s),
                        Err(e) => {
                            let _ = reply_tx.send(LaneReply::Fail(format!("state export: {e}")));
                            return;
                        }
                    }
                } else {
                    None
                };
                if reply_tx.send(LaneReply::Step { buf, stats, state }).is_err() {
                    return;
                }
            }
        }
    }
}

/// The multi-worker execution driver.  Owns the per-worker parked batch
/// buffers (reused across epochs and across train/refresh runs), a
/// scratch assembler for sink-issued immediate steps, and the persistent
/// data-parallel replica lanes.
pub struct WorkerPool {
    batch: usize,
    /// Per-worker parked assembler pairs (lane w uses `buffers[w]`).
    buffers: Vec<DoubleBuffer>,
    scratch: BatchAssembler,
    /// Persistent replica lanes for the data-parallel schedule (spawned
    /// on first use, reused across runs; cleared after a failed run so
    /// the next run rebuilds from a clean slate).
    lanes: Vec<ReplicaLane>,
    /// Which backend the lanes' replicas were built for
    /// ([`DataParallel::replica_cache_key`]); a different key respawns
    /// them, so one backend's replicas never receive another's state.
    lanes_key: String,
}

impl WorkerPool {
    /// A pool sized for `data`'s sample layout at device batch `batch`.
    /// Lanes allocate lazily on first use, so construction is cheap for
    /// single-worker configs.
    pub fn new(data: &Dataset, batch: usize) -> Self {
        WorkerPool {
            batch,
            buffers: Vec::new(),
            scratch: BatchAssembler::new(data, batch),
            lanes: Vec::new(),
            lanes_key: String::new(),
        }
    }

    /// The device batch size each lane assembles.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Validate shards, size the lane buffer pools, and compute the step
    /// count.  Returns `(steps, outcome skeleton)`.
    fn prepare(
        &mut self,
        data: &Dataset,
        shards: &[Shard],
    ) -> anyhow::Result<(usize, PoolOutcome)> {
        anyhow::ensure!(!shards.is_empty(), "worker pool needs at least one shard");
        let len = shards[0].len();
        anyhow::ensure!(
            shards.iter().all(|s| s.len() == len),
            "ragged shards: every worker must take the same number of steps \
             (the step barrier is bulk-synchronous; see docs/worker-model.md)"
        );
        while self.buffers.len() < shards.len() {
            self.buffers.push(DoubleBuffer::new(data, self.batch));
        }
        if !self.scratch.matches(data) {
            self.scratch = BatchAssembler::new(data, self.batch);
        }
        let steps = len.div_ceil(self.batch);
        let workers = (0..shards.len())
            .map(|w| WorkerReport { worker: w, ..Default::default() })
            .collect();
        Ok((steps, PoolOutcome { steps, workers, ..Default::default() }))
    }

    /// Take the initial assemblers for each lane (two per worker, fewer
    /// when the run is shorter).
    fn take_lanes(
        &mut self,
        data: &Dataset,
        workers: usize,
        steps: usize,
    ) -> Vec<Vec<BatchAssembler>> {
        let mut lanes = Vec::with_capacity(workers);
        for w in 0..workers {
            let mut lane = Vec::with_capacity(steps.min(2));
            for _ in 0..steps.min(2) {
                lane.push(self.buffers[w].take(data));
            }
            lanes.push(lane);
        }
        lanes
    }

    /// Spawn (or respawn, if the worker count or the primary backend
    /// changed) the persistent replica lanes and wait for every replica
    /// to finish building.
    fn ensure_lanes<B: DataParallel>(
        &mut self,
        primary: &B,
        workers: usize,
    ) -> anyhow::Result<()> {
        let key = primary.replica_cache_key();
        if self.lanes.len() == workers && self.lanes_key == key {
            return Ok(());
        }
        self.lanes.clear();
        self.lanes_key = key;
        for w in 0..workers {
            self.lanes.push(ReplicaLane::spawn(w, primary.replica_builder()?)?);
        }
        let mut failed = None;
        for (w, lane) in self.lanes.iter().enumerate() {
            match lane.recv() {
                Ok(LaneReply::Ready) => {}
                Ok(LaneReply::Fail(e)) => {
                    failed = Some(format!("worker {w}: {e}"));
                    break;
                }
                Ok(LaneReply::Step { .. }) => {
                    failed = Some(format!("worker {w}: unexpected step reply"));
                    break;
                }
                Err(e) => {
                    failed = Some(format!("worker {w}: {e}"));
                    break;
                }
            }
        }
        if let Some(e) = failed {
            self.lanes.clear();
            anyhow::bail!("replica lane spawn failed: {e}");
        }
        Ok(())
    }

    /// Execute `shards` through the **serial-equivalent** schedule: worker
    /// gather lanes fill batches concurrently, while every device step
    /// runs on `backend` in fixed `(step, worker)` order.  Bitwise
    /// identical to driving the engine over
    /// [`crate::data::shard::global_batch_order`] on a single stream.
    pub fn run_serial_equivalent(
        &mut self,
        backend: &mut dyn StepBackend,
        data: &Dataset,
        shards: &[Shard],
        mode: StepMode,
        sink: &mut dyn StepSink,
    ) -> anyhow::Result<PoolOutcome> {
        let (steps, mut outcome) = self.prepare(data, shards)?;
        let w_count = shards.len();
        let bs = self.batch;
        if steps == 0 {
            let mut ctx = StepCtx { backend, scratch: &mut self.scratch, data };
            sink.finish(&mut ctx)?;
            return Ok(outcome);
        }
        let lanes = self.take_lanes(data, w_count, steps);
        let scratch = &mut self.scratch;

        let parked = std::thread::scope(
            |scope| -> anyhow::Result<Vec<(usize, BatchAssembler)>> {
                let mut done_rx = Vec::with_capacity(w_count);
                let mut back_tx = Vec::with_capacity(w_count);
                for (shard, initial) in shards.iter().zip(lanes) {
                    let (d_tx, d_rx) = sync_channel::<BatchAssembler>(1);
                    let (b_tx, b_rx) = channel::<BatchAssembler>();
                    spawn_filler(scope, shard, data, bs, steps, initial, b_rx, d_tx);
                    done_rx.push(d_rx);
                    back_tx.push(b_tx);
                }

                let mut parked = Vec::with_capacity(w_count * steps.min(2));
                for s in 0..steps {
                    for w in 0..w_count {
                        let t = Timer::start();
                        let buf = done_rx[w]
                            .recv()
                            .map_err(|_| anyhow::anyhow!("worker {w} gather lane died"))?;
                        outcome.workers[w].wait_s += t.elapsed_s();
                        let stats = dispatch(&mut *backend, mode, &buf)?;
                        let mut ctx =
                            StepCtx { backend: &mut *backend, scratch: &mut *scratch, data };
                        sink.on_batch(&mut ctx, &buf.slots, buf.real, &stats)?;
                        outcome.samples += buf.real;
                        outcome.workers[w].samples += buf.real;
                        outcome.workers[w].steps += 1;
                        if s + 2 < steps {
                            let _ = back_tx[w].send(buf);
                        } else {
                            parked.push((w, buf));
                        }
                    }
                }
                drop(back_tx);
                let mut ctx = StepCtx { backend, scratch, data };
                sink.finish(&mut ctx)?;
                Ok(parked)
            },
        )?;
        for (w, buf) in parked {
            self.buffers[w].put(buf);
        }
        Ok(outcome)
    }

    /// Execute `shards` through the **data-parallel** schedule: worker `w`
    /// steps its own replica of `primary` (built and owned by a persistent
    /// lane thread — see the module docs) over its shard; at each step
    /// barrier the stats fold into `sink` in fixed worker order and (for
    /// [`StepMode::Train`]) replica parameters are averaged with the same
    /// fixed-order fold, after which `primary` receives the final averaged
    /// state.  Deterministic run to run; bitwise serial-equivalent for
    /// forward-only modes.
    ///
    /// The averaging invariant: the reduction folds in fixed
    /// `(step, worker)` order, so the result is a pure function of the
    /// inputs — *independent of lane completion timing* across runs:
    ///
    /// ```
    /// use kakurenbo::data::shard::shard_order_aligned;
    /// use kakurenbo::data::synth::{gauss_mixture, GaussMixtureCfg};
    /// use kakurenbo::engine::testbed::MockBackend;
    /// use kakurenbo::engine::{EvalSink, StepMode, WorkerPool};
    ///
    /// let d = gauss_mixture(
    ///     &GaussMixtureCfg { n_train: 48, n_val: 4, dim: 6, classes: 3, ..Default::default() },
    ///     7,
    /// )
    /// .train;
    /// let order: Vec<u32> = (0..48).collect();
    /// let shards = shard_order_aligned(&order, 4, 8);
    /// let run = || {
    ///     let mut pool = WorkerPool::new(&d, 8);
    ///     let mut be = MockBackend::new();
    ///     let mut sink = EvalSink::default();
    ///     pool.run_data_parallel(&mut be, &d, &shards, StepMode::Train { lr: 0.05 }, &mut sink)
    ///         .unwrap();
    ///     (be.param.to_bits(), sink.result().1.to_bits())
    /// };
    /// // four lanes race; the fixed-order reduction makes the averaged
    /// // parameters and the folded loss bit-for-bit reproducible anyway
    /// assert_eq!(run(), run());
    /// ```
    pub fn run_data_parallel<B: DataParallel>(
        &mut self,
        primary: &mut B,
        data: &Dataset,
        shards: &[Shard],
        mode: StepMode,
        sink: &mut dyn StepSink,
    ) -> anyhow::Result<PoolOutcome> {
        let out = self.run_data_parallel_inner(primary, data, shards, mode, sink);
        if out.is_err() {
            // an aborted run can leave lanes with out-of-phase commands in
            // flight; rebuild them rather than risk a desynced barrier
            self.lanes.clear();
        }
        out
    }

    fn run_data_parallel_inner<B: DataParallel>(
        &mut self,
        primary: &mut B,
        data: &Dataset,
        shards: &[Shard],
        mode: StepMode,
        sink: &mut dyn StepSink,
    ) -> anyhow::Result<PoolOutcome> {
        let (steps, mut outcome) = self.prepare(data, shards)?;
        let w_count = shards.len();
        let bs = self.batch;
        if steps == 0 {
            let mut ctx = StepCtx { backend: primary, scratch: &mut self.scratch, data };
            sink.finish(&mut ctx)?;
            return Ok(outcome);
        }
        let averaging = matches!(mode, StepMode::Train { .. });
        self.ensure_lanes(primary, w_count)?;
        // Re-synchronize every replica with the primary's current state:
        // lanes persist across runs, so whatever an earlier run (or an
        // earlier epoch's averaging) left behind is overwritten up front.
        // Full tier always — replicas must share the optimizer state too.
        let init: SharedSnapshot = Arc::new(primary.export_snapshot(SnapshotTier::Full)?);
        // leaf count of the params section: the barrier's flat averaged
        // states split back into typed snapshots at this boundary
        let param_leaves = init.params().len();
        for lane in &self.lanes {
            lane.send(LaneCmd::Sync(init.clone()))?;
        }

        let gather_bufs = self.take_lanes(data, w_count, steps);
        let scratch = &mut self.scratch;
        let rep_lanes = &self.lanes;

        type Parked = Vec<(usize, BatchAssembler)>;
        let (parked, last_avg) = std::thread::scope(
            |scope| -> anyhow::Result<(Parked, Option<SharedSnapshot>)> {
                let mut done_rx = Vec::with_capacity(w_count);
                let mut back_tx = Vec::with_capacity(w_count);
                for (shard, initial) in shards.iter().zip(gather_bufs) {
                    let (d_tx, d_rx) = sync_channel::<BatchAssembler>(1);
                    let (b_tx, b_rx) = channel::<BatchAssembler>();
                    spawn_filler(scope, shard, data, bs, steps, initial, b_rx, d_tx);
                    done_rx.push(d_rx);
                    back_tx.push(b_tx);
                }

                let mut parked: Parked = Vec::with_capacity(w_count * steps.min(2));
                let mut last_avg: Option<SharedSnapshot> = None;
                for s in 0..steps {
                    // Fan out: forward each worker's gathered batch to its
                    // replica lane; all lanes compute concurrently.
                    for (w, rx) in done_rx.iter().enumerate() {
                        let buf = rx
                            .recv()
                            .map_err(|_| anyhow::anyhow!("worker {w} gather lane died"))?;
                        rep_lanes[w].send(LaneCmd::Step { buf, mode, export: averaging })?;
                    }
                    // Fixed (step, worker) reduction: fold stats (and, when
                    // averaging, states) in worker order regardless of
                    // which lane finished first.
                    let mut acc: Option<Vec<Vec<f32>>> = None;
                    for w in 0..w_count {
                        let t = Timer::start();
                        let reply = rep_lanes[w].recv()?;
                        outcome.workers[w].wait_s += t.elapsed_s();
                        match reply {
                            LaneReply::Step { buf, stats, state } => {
                                let mut ctx = StepCtx {
                                    backend: &mut *primary,
                                    scratch: &mut *scratch,
                                    data,
                                };
                                sink.on_batch(&mut ctx, &buf.slots, buf.real, &stats)?;
                                outcome.samples += buf.real;
                                outcome.workers[w].samples += buf.real;
                                outcome.workers[w].steps += 1;
                                if s + 2 < steps {
                                    let _ = back_tx[w].send(buf);
                                } else {
                                    parked.push((w, buf));
                                }
                                if averaging {
                                    let st = state.ok_or_else(|| {
                                        anyhow::anyhow!("worker {w} reply missing state")
                                    })?;
                                    // fixed fold: w0 + w1 + ... then / W
                                    acc = Some(match acc.take() {
                                        None => st,
                                        Some(mut a) => {
                                            accumulate_state(&mut a, &st)?;
                                            a
                                        }
                                    });
                                }
                            }
                            LaneReply::Fail(e) => {
                                anyhow::bail!("worker {w} step failed: {e}")
                            }
                            LaneReply::Ready => {
                                anyhow::bail!("worker {w}: unexpected ready reply")
                            }
                        }
                    }
                    if averaging {
                        let t = Timer::start();
                        let mut avg = acc.expect("averaging step folded no state");
                        finish_average(&mut avg, w_count);
                        // wrap the flat averaged state back into a typed
                        // full-tier snapshot (a pure split — every f32
                        // bit pattern is preserved) before broadcast
                        let avg: SharedSnapshot =
                            Arc::new(Snapshot::from_state(avg, param_leaves)?);
                        for lane in rep_lanes {
                            lane.send(LaneCmd::Sync(avg.clone()))?;
                        }
                        outcome.sync_steps += 1;
                        outcome.time_average += t.elapsed_s();
                        last_avg = Some(avg);
                    }
                }
                drop(back_tx);
                Ok((parked, last_avg))
            },
        )?;
        for (w, buf) in parked {
            self.buffers[w].put(buf);
        }
        if let Some(avg) = last_avg {
            primary.import_snapshot(&avg)?;
        }
        let mut ctx = StepCtx { backend: primary, scratch: &mut self.scratch, data };
        sink.finish(&mut ctx)?;
        Ok(outcome)
    }
}

/// Spawn one worker's gather lane: fills its shard's batches in step
/// order, double-buffered (two assemblers circulating by value through
/// the `back_rx` / `out_tx` channel pair).
#[allow(clippy::too_many_arguments)]
fn spawn_filler<'scope, 'env>(
    scope: &'scope std::thread::Scope<'scope, 'env>,
    shard: &'env Shard,
    data: &'env Dataset,
    batch: usize,
    steps: usize,
    mut initial: Vec<BatchAssembler>,
    back_rx: Receiver<BatchAssembler>,
    out_tx: SyncSender<BatchAssembler>,
) {
    scope.spawn(move || {
        for s in 0..steps {
            let mut buf = match initial.pop() {
                Some(b) => b,
                None => match back_rx.recv() {
                    Ok(b) => b,
                    Err(_) => return,
                },
            };
            buf.fill(data, shard.step_batch(s, batch), None);
            if out_tx.send(buf).is_err() {
                return;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shard::{global_batch_order, shard_order_aligned};
    use crate::data::synth::{gauss_mixture, GaussMixtureCfg};
    use crate::engine::testbed::MockBackend;
    use crate::engine::{Engine, EvalSink};

    const B: usize = 8;

    fn tiny(n: usize) -> Dataset {
        gauss_mixture(
            &GaussMixtureCfg { n_train: n, n_val: 4, dim: 6, classes: 3, ..Default::default() },
            7,
        )
        .train
    }

    fn eval_serial_equiv(n: usize, w: usize, mode: StepMode) -> (f64, f64, u32) {
        let d = tiny(n);
        let order: Vec<u32> = (0..n as u32).rev().collect();
        let shards = shard_order_aligned(&order, w, B);
        let mut pool = WorkerPool::new(&d, B);
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        pool.run_serial_equivalent(&mut be, &d, &shards, mode, &mut sink).unwrap();
        let (acc, loss) = sink.result();
        (acc, loss, be.param.to_bits())
    }

    #[test]
    fn pool_matches_engine_over_interleaved_stream() {
        for w in [1usize, 2, 3, 4] {
            let d = tiny(53);
            let order: Vec<u32> = (0..53u32).rev().collect();
            let shards = shard_order_aligned(&order, w, B);

            let mut eng = Engine::new(&d, B);
            eng.overlap = true;
            let mut ref_be = MockBackend::new();
            let mut ref_sink = EvalSink::default();
            let flat = global_batch_order(&shards, B);
            eng.run(&mut ref_be, &d, &flat, None, StepMode::Train { lr: 0.05 }, &mut ref_sink)
                .unwrap();

            let mut pool = WorkerPool::new(&d, B);
            let mut be = MockBackend::new();
            let mut sink = EvalSink::default();
            let mode = StepMode::Train { lr: 0.05 };
            let out = pool.run_serial_equivalent(&mut be, &d, &shards, mode, &mut sink).unwrap();

            assert_eq!(ref_be.param.to_bits(), be.param.to_bits(), "w={w}");
            assert_eq!(ref_be.trace, be.trace, "w={w}");
            let (ra, rl) = ref_sink.result();
            let (pa, pl) = sink.result();
            assert_eq!(ra.to_bits(), pa.to_bits(), "w={w}");
            assert_eq!(rl.to_bits(), pl.to_bits(), "w={w}");
            assert_eq!(out.samples, flat.len(), "w={w}");
            assert_eq!(out.steps * w, out.workers.iter().map(|r| r.steps).sum::<usize>());
        }
    }

    #[test]
    fn pool_runs_are_reproducible() {
        let a = eval_serial_equiv(53, 4, StepMode::Train { lr: 0.03 });
        let b = eval_serial_equiv(53, 4, StepMode::Train { lr: 0.03 });
        assert_eq!(a.2, b.2);
        assert_eq!(a.1.to_bits(), b.1.to_bits());
    }

    #[test]
    fn empty_and_tiny_epochs_do_not_panic() {
        for w in [1usize, 4] {
            for mode in [StepMode::Forward, StepMode::Train { lr: 0.01 }] {
                // empty epoch (heavy hiding can empty the order entirely)
                let d = tiny(16);
                let shards = shard_order_aligned(&[], w, B);
                let mut pool = WorkerPool::new(&d, B);
                let mut be = MockBackend::new();
                let mut sink = EvalSink::default();
                let out = pool
                    .run_serial_equivalent(&mut be, &d, &shards, mode, &mut sink)
                    .unwrap();
                assert_eq!(out.samples, 0);
                // fewer samples than workers: wrap-padding fills every lane
                let order: Vec<u32> = (0..3).collect();
                let shards = shard_order_aligned(&order, w, B);
                let mut sink = EvalSink::default();
                let out = pool
                    .run_serial_equivalent(&mut be, &d, &shards, mode, &mut sink)
                    .unwrap();
                assert_eq!(out.samples, w * B);
            }
        }
    }

    #[test]
    fn ragged_shards_rejected() {
        let d = tiny(16);
        let shards = vec![
            Shard { worker: 0, indices: vec![0, 1, 2] },
            Shard { worker: 1, indices: vec![3, 4] },
        ];
        let mut pool = WorkerPool::new(&d, B);
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        assert!(pool
            .run_serial_equivalent(&mut be, &d, &shards, StepMode::Forward, &mut sink)
            .is_err());
        assert!(pool
            .run_data_parallel(&mut be, &d, &shards, StepMode::Forward, &mut sink)
            .is_err());
    }

    #[test]
    fn pool_recovers_after_failed_run() {
        struct Failing;
        impl StepBackend for Failing {
            fn train_step(
                &mut self,
                _x: &[f32],
                _y: &[i32],
                _sw: &[f32],
                _lr: f32,
            ) -> anyhow::Result<BatchStats> {
                anyhow::bail!("device lost")
            }
            fn fwd_stats(&mut self, _x: &[f32], _y: &[i32]) -> anyhow::Result<BatchStats> {
                anyhow::bail!("device lost")
            }
        }
        let d = tiny(40);
        let order: Vec<u32> = (0..32).collect();
        let shards = shard_order_aligned(&order, 2, B);
        let mut pool = WorkerPool::new(&d, B);
        let mut sink = EvalSink::default();
        assert!(pool
            .run_serial_equivalent(&mut Failing, &d, &shards, StepMode::Forward, &mut sink)
            .is_err());
        // a healthy backend still runs afterwards (buffers re-created)
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        let out = pool
            .run_serial_equivalent(&mut be, &d, &shards, StepMode::Forward, &mut sink)
            .unwrap();
        assert_eq!(out.samples, 32);
    }

    /// A replica whose steps fail must abort the data-parallel run with an
    /// error (not hang the barrier), and the pool must recover: lanes are
    /// respawned and a healthy run succeeds afterwards.
    #[test]
    fn data_parallel_recovers_after_failed_run() {
        #[derive(Clone)]
        struct FailingDp;
        impl StepBackend for FailingDp {
            fn train_step(
                &mut self,
                _x: &[f32],
                _y: &[i32],
                _sw: &[f32],
                _lr: f32,
            ) -> anyhow::Result<BatchStats> {
                anyhow::bail!("device lost")
            }
            fn fwd_stats(&mut self, _x: &[f32], _y: &[i32]) -> anyhow::Result<BatchStats> {
                anyhow::bail!("device lost")
            }
        }
        impl crate::engine::StateExchange for FailingDp {
            fn export_state(&self) -> anyhow::Result<Vec<Vec<f32>>> {
                Ok(vec![vec![0.0]])
            }
            fn import_state(&mut self, _state: &[Vec<f32>]) -> anyhow::Result<()> {
                Ok(())
            }
        }
        impl DataParallel for FailingDp {
            fn replica_builder(&self) -> anyhow::Result<ReplicaBuilder> {
                Ok(Box::new(move || {
                    Ok(Box::new(FailingDp) as Box<dyn crate::engine::ReplicaBackend>)
                }))
            }
        }
        let d = tiny(40);
        let order: Vec<u32> = (0..32).collect();
        let shards = shard_order_aligned(&order, 2, B);
        let mut pool = WorkerPool::new(&d, B);
        let mut sink = EvalSink::default();
        assert!(pool
            .run_data_parallel(&mut FailingDp, &d, &shards, StepMode::Forward, &mut sink)
            .is_err());
        // lanes were cleared; a healthy backend respawns them and runs
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        let out = pool
            .run_data_parallel(&mut be, &d, &shards, StepMode::Forward, &mut sink)
            .unwrap();
        assert_eq!(out.samples, 32);
    }

    #[test]
    fn data_parallel_forward_matches_serial_equivalent() {
        for w in [1usize, 2, 4] {
            let d = tiny(53);
            let order: Vec<u32> = (0..53u32).collect();
            let shards = shard_order_aligned(&order, w, B);
            let mut pool = WorkerPool::new(&d, B);

            let mut be_a = MockBackend::new();
            let mut sink_a = EvalSink::default();
            pool.run_serial_equivalent(&mut be_a, &d, &shards, StepMode::Forward, &mut sink_a)
                .unwrap();
            let mut be_b = MockBackend::new();
            let mut sink_b = EvalSink::default();
            pool.run_data_parallel(&mut be_b, &d, &shards, StepMode::Forward, &mut sink_b)
                .unwrap();

            let (aa, al) = sink_a.result();
            let (ba, bl) = sink_b.result();
            assert_eq!(aa.to_bits(), ba.to_bits(), "w={w}");
            assert_eq!(al.to_bits(), bl.to_bits(), "w={w}");
        }
    }

    #[test]
    fn data_parallel_train_identical_shards_average_to_single_lane() {
        // Both workers see the same shard, so every replica applies the
        // same update; the W=2 average of identical parameters is exact,
        // and the run must match the single-lane result bitwise.
        let d = tiny(32);
        let half: Vec<u32> = (0..16).collect();
        let doubled: Vec<u32> = half.iter().chain(half.iter()).copied().collect();
        let shards2 = shard_order_aligned(&doubled, 2, B);
        assert_eq!(shards2[0].indices, shards2[1].indices);
        let shards1 = shard_order_aligned(&half, 1, B);

        let mut pool = WorkerPool::new(&d, B);
        let mut be2 = MockBackend::new();
        let mut sink = EvalSink::default();
        pool.run_data_parallel(&mut be2, &d, &shards2, StepMode::Train { lr: 0.05 }, &mut sink)
            .unwrap();
        let mut pool1 = WorkerPool::new(&d, B);
        let mut be1 = MockBackend::new();
        let mut sink = EvalSink::default();
        pool1
            .run_data_parallel(&mut be1, &d, &shards1, StepMode::Train { lr: 0.05 }, &mut sink)
            .unwrap();
        assert_eq!(be1.param.to_bits(), be2.param.to_bits());
    }

    #[test]
    fn data_parallel_train_is_deterministic() {
        let run = || {
            let d = tiny(53);
            let order: Vec<u32> = (0..53u32).collect();
            let shards = shard_order_aligned(&order, 4, B);
            let mut pool = WorkerPool::new(&d, B);
            let mut be = MockBackend::new();
            let mut sink = EvalSink::default();
            pool.run_data_parallel(&mut be, &d, &shards, StepMode::Train { lr: 0.02 }, &mut sink)
                .unwrap();
            let (_, loss) = sink.result();
            (be.param.to_bits(), loss.to_bits())
        };
        assert_eq!(run(), run());
    }

    /// Lanes persist across runs: a second run through the same pool must
    /// re-sync replicas to the primary's *current* state, not continue
    /// from whatever the previous run's averaging left behind.
    #[test]
    fn persistent_lanes_resync_between_runs() {
        let d = tiny(32);
        let order: Vec<u32> = (0..32).collect();
        let shards = shard_order_aligned(&order, 2, B);
        let mode = StepMode::Train { lr: 0.04 };

        // reference: two fresh pools, primary state carried across
        let mut be_ref = MockBackend::new();
        for _ in 0..2 {
            let mut pool = WorkerPool::new(&d, B);
            let mut sink = EvalSink::default();
            pool.run_data_parallel(&mut be_ref, &d, &shards, mode, &mut sink).unwrap();
        }
        // same two epochs through one pool (lanes reused)
        let mut be = MockBackend::new();
        let mut pool = WorkerPool::new(&d, B);
        for _ in 0..2 {
            let mut sink = EvalSink::default();
            pool.run_data_parallel(&mut be, &d, &shards, mode, &mut sink).unwrap();
        }
        assert_eq!(be_ref.param.to_bits(), be.param.to_bits());
    }

    /// The averaging schedule reports its reduction accounting.
    #[test]
    fn averaging_outcome_accounting() {
        let d = tiny(48);
        let order: Vec<u32> = (0..48).collect();
        let shards = shard_order_aligned(&order, 2, B);
        let mut pool = WorkerPool::new(&d, B);
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        let out = pool
            .run_data_parallel(&mut be, &d, &shards, StepMode::Train { lr: 0.01 }, &mut sink)
            .unwrap();
        assert_eq!(out.sync_steps, out.steps);
        assert!(out.time_average >= 0.0);
        // forward passes never average
        let mut sink = EvalSink::default();
        let out = pool
            .run_data_parallel(&mut be, &d, &shards, StepMode::Forward, &mut sink)
            .unwrap();
        assert_eq!(out.sync_steps, 0);
        assert_eq!(out.time_average, 0.0);
    }
}
