//! The online inference fleet: live snapshot publication + serving
//! replicas, off the training critical path.
//!
//! Two pieces (the HTTP surface lives in [`crate::serve`]):
//!
//! * [`SnapshotHub`] — the publication point.  The epoch pipeline
//!   publishes each epoch's params-tier snapshot here; query threads
//!   read the latest publication as one `Arc` clone under a short lock,
//!   so a swap can never expose a torn `(epoch, digests, snapshot)`
//!   triple — the epoch a response reports is always the epoch whose
//!   parameters answered it.  The hub retains only the most recent K
//!   publications (`--serve-retain`, default 2): older `Published`
//!   entries are evicted and freed, while in-flight readers stay sound
//!   because a loaded publication is an owned `Arc` that outlives its
//!   eviction.
//! * [`ServeFleet`] — the replica owners.  `--serve-replicas R` builds
//!   R serving replicas, each *on* its own lane thread via the
//!   [`ReplicaBuilder`] contract (PJRT state is not `Send`).  Query
//!   threads hand jobs to the least-loaded live lane through a
//!   [`ServeClient`] and block on a per-query reply channel; a lane
//!   that dies before answering forces a redispatch to a surviving
//!   lane, so every query is answered exactly once.  Each lane
//!   re-imports parameters only when the publication under a query
//!   differs from the one it last synced — queries between
//!   publications pay no import.
//!
//! # Micro-batching
//!
//! With `--serve-batch N > 1` a lane drains its queue into a coalescing
//! buffer: it dispatches as soon as N queries accumulate or the oldest
//! has waited `--serve-batch-wait-us`, packs compatible queries (same
//! publication, same endpoint, same row width) into **one** batched
//! `fwd_stats`/`fwd_embed` device call, and scatters per-row results
//! back to each query's reply channel.  The forward is row-independent,
//! so each query's slice is bitwise identical to what a solo forward
//! would have produced (`tests/inference_serving.rs`).
//!
//! # Failure contract
//!
//! A backend failure on a lane (a killed replica, a failed import)
//! marks **that lane** down, answers its in-flight queries with the
//! error, and emits a named [`ServiceEvent::Error`] tagged
//! [`ServiceLaneKind::Serve`] into the fold-in stream the trainer
//! drains at each epoch barrier — so `--fault-policy fail` aborts the
//! run with a clear message while `elastic` counts the failure and
//! keeps training.  `/healthz` reports **degraded** only when every
//! lane is down (or on an explicit [`SnapshotHub::set_degraded`]); a
//! lane that answers successfully again marks itself back up.
//! Client-side input validation happens in the HTTP layer *before* a
//! job is submitted, so malformed queries never reach the device and
//! never degrade a lane.
//!
//! # Determinism contract
//!
//! Serving is read-only: the lanes touch only their own replicas and
//! the immutable published snapshots, so a run with `--serve` on is
//! bitwise identical to one with it off — under every batching/replica
//! configuration (`tests/inference_serving.rs`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::backend::{ReplicaBackend, ReplicaBuilder, StateExchange, StepBackend};
use super::service::{ServiceEvent, ServiceLaneKind};
use super::snapshot::{SharedSnapshot, Snapshot};
use crate::runtime::BatchStats;
use crate::util::sha256::sha256_hex;
use crate::util::timer::Timer;

/// SHA-256 digest of each parameter leaf's little-endian `f32` bytes —
/// the same byte layout the checkpoint store hashes, so a served digest
/// is comparable to a stored leaf's.
pub fn leaf_digests(snap: &Snapshot) -> Vec<String> {
    snap.params()
        .iter()
        .map(|leaf| {
            let mut bytes = Vec::with_capacity(leaf.len() * 4);
            for v in leaf {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            sha256_hex(&bytes)
        })
        .collect()
}

/// One publication: everything a response reports about the snapshot it
/// was answered against, bundled so a single hub read observes all of
/// it or none of it.
#[derive(Debug)]
pub struct Published {
    /// The epoch this snapshot was exported at.
    pub epoch: usize,
    /// Monotonic publication sequence number (the lanes' sync key —
    /// distinct publications of the same epoch re-import).
    pub seq: u64,
    /// Per-leaf SHA-256 digests of the parameter section.
    pub digests: Vec<String>,
    /// The published snapshot itself.
    pub snapshot: SharedSnapshot,
}

/// How a serve lane coalesces queued queries into shared device
/// forwards (see module docs).  `max_batch == 1` disables coalescing
/// entirely — every query dispatches solo, exactly the pre-batching
/// behavior.
#[derive(Clone, Copy, Debug)]
pub struct ServeBatching {
    /// Dispatch as soon as this many queries have accumulated.
    pub max_batch: usize,
    /// Dispatch once the oldest queued query has waited this long,
    /// even if the batch is not full.
    pub max_wait: Duration,
}

impl Default for ServeBatching {
    fn default() -> Self {
        ServeBatching { max_batch: 1, max_wait: Duration::from_micros(250) }
    }
}

/// The publication point shared by every serve lane (see module docs).
///
/// Readers pay one short lock + `Arc` clone per query; the publisher
/// evicts beyond the K most recent publications, so a run's hub memory
/// is bounded regardless of epoch count.  The hub also carries the
/// fleet's health + throughput counters: per-lane up/down bits, query
/// and batch counts (per-epoch deltas for the fold-in, cumulative
/// totals for `/healthz`).
pub struct SnapshotHub {
    current: Mutex<Option<Arc<Published>>>,
    retained: Mutex<VecDeque<Arc<Published>>>,
    retain: usize,
    seq: AtomicU64,
    publishes: AtomicUsize,
    queries: AtomicUsize,
    batches: AtomicUsize,
    queries_total: AtomicUsize,
    batches_total: AtomicUsize,
    lane_queries: Mutex<Vec<usize>>,
    lanes: AtomicUsize,
    lanes_down: AtomicU64,
    degraded: AtomicBool,
}

impl Default for SnapshotHub {
    fn default() -> Self {
        SnapshotHub::new()
    }
}

impl SnapshotHub {
    /// An empty hub retaining the default 2 most recent publications;
    /// not ready until the first [`SnapshotHub::publish`].
    pub fn new() -> Self {
        SnapshotHub::with_retain(2)
    }

    /// An empty hub retaining at most `retain` publications (clamped to
    /// at least 1 — the live publication is never evicted).
    pub fn with_retain(retain: usize) -> Self {
        SnapshotHub {
            current: Mutex::new(None),
            retained: Mutex::new(VecDeque::new()),
            retain: retain.max(1),
            seq: AtomicU64::new(0),
            publishes: AtomicUsize::new(0),
            queries: AtomicUsize::new(0),
            batches: AtomicUsize::new(0),
            queries_total: AtomicUsize::new(0),
            batches_total: AtomicUsize::new(0),
            lane_queries: Mutex::new(Vec::new()),
            lanes: AtomicUsize::new(0),
            lanes_down: AtomicU64::new(0),
            degraded: AtomicBool::new(false),
        }
    }

    /// Publish `snap` as the live snapshot for `epoch`.  Readers switch
    /// to it atomically; in-flight queries keep the publication they
    /// already loaded (their `Arc` outlives any eviction), and
    /// publications beyond the retention bound are freed here.
    pub fn publish(&self, epoch: usize, snap: SharedSnapshot) -> Arc<Published> {
        let published = Arc::new(Published {
            epoch,
            seq: self.seq.fetch_add(1, Ordering::Relaxed) + 1,
            digests: leaf_digests(&snap),
            snapshot: snap,
        });
        {
            let mut retained = self.retained.lock().unwrap();
            retained.push_back(published.clone());
            while retained.len() > self.retain {
                retained.pop_front();
            }
        }
        *self.current.lock().unwrap() = Some(published.clone());
        self.publishes.fetch_add(1, Ordering::Relaxed);
        published
    }

    /// The latest publication, or `None` before the first publish.
    /// One short lock and an `Arc` clone — never a torn pairing.
    pub fn latest(&self) -> Option<Arc<Published>> {
        self.current.lock().unwrap().clone()
    }

    /// Whether a snapshot has been published (the `/healthz` readiness
    /// signal).
    pub fn ready(&self) -> bool {
        self.current.lock().unwrap().is_some()
    }

    /// Total publications so far.
    pub fn publishes(&self) -> usize {
        self.publishes.load(Ordering::Relaxed)
    }

    /// How many publications the hub currently holds alive (≤ the
    /// retention bound).
    pub fn retained_count(&self) -> usize {
        self.retained.lock().unwrap().len()
    }

    /// Register one serve lane; returns its lane id (the index used by
    /// [`SnapshotHub::lane_down`] / [`SnapshotHub::lane_up`] and the
    /// per-lane query counters).
    pub fn register_lane(&self) -> usize {
        self.lane_queries.lock().unwrap().push(0);
        self.lanes.fetch_add(1, Ordering::Relaxed)
    }

    /// How many serve lanes are registered.
    pub fn lanes(&self) -> usize {
        self.lanes.load(Ordering::Relaxed)
    }

    /// Mark lane `id` down (a backend failure on that lane).
    pub fn lane_down(&self, id: usize) {
        self.lanes_down.fetch_or(1u64 << (id & 63), Ordering::AcqRel);
    }

    /// Mark lane `id` back up (it answered a query successfully).
    pub fn lane_up(&self, id: usize) {
        self.lanes_down.fetch_and(!(1u64 << (id & 63)), Ordering::AcqRel);
    }

    /// How many registered lanes are currently marked down.
    pub fn lanes_down(&self) -> usize {
        self.lanes_down.load(Ordering::Acquire).count_ones() as usize
    }

    /// Count one answered query on lane `lane` (the serve lanes call
    /// this per job, success or failure).
    pub fn record_query(&self, lane: usize) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.queries_total.fetch_add(1, Ordering::Relaxed);
        let mut per = self.lane_queries.lock().unwrap();
        if lane >= per.len() {
            per.resize(lane + 1, 0);
        }
        per[lane] += 1;
    }

    /// Count one dispatched device batch (one coalesced forward).
    pub fn record_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batches_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Queries answered since the last call (the per-epoch fold: each
    /// epoch record absorbs the delta).
    pub fn take_queries(&self) -> usize {
        self.queries.swap(0, Ordering::Relaxed)
    }

    /// Device batches dispatched since the last call (per-epoch fold).
    pub fn take_batches(&self) -> usize {
        self.batches.swap(0, Ordering::Relaxed)
    }

    /// Per-lane answered-query counts since the last call (per-epoch
    /// fold; index = lane id).
    pub fn take_lane_queries(&self) -> Vec<usize> {
        let mut per = self.lane_queries.lock().unwrap();
        let zeroed = vec![0; per.len()];
        std::mem::replace(&mut *per, zeroed)
    }

    /// Cumulative answered queries over the hub's lifetime (`/healthz`).
    pub fn queries_total(&self) -> usize {
        self.queries_total.load(Ordering::Relaxed)
    }

    /// Cumulative dispatched device batches over the hub's lifetime
    /// (`/healthz`).
    pub fn batches_total(&self) -> usize {
        self.batches_total.load(Ordering::Relaxed)
    }

    /// Force the serving path degraded (or un-degraded) regardless of
    /// per-lane health — the explicit override some tests and operators
    /// use.
    pub fn set_degraded(&self, degraded: bool) {
        self.degraded.store(degraded, Ordering::Release);
    }

    /// Whether the serving path is degraded: explicitly forced, or
    /// every registered lane is down.  A fleet with live lanes left
    /// keeps reporting healthy — one dead replica out of R degrades
    /// only its own lane.
    pub fn degraded(&self) -> bool {
        if self.degraded.load(Ordering::Acquire) {
            return true;
        }
        let lanes = self.lanes();
        lanes > 0 && self.lanes_down() >= lanes
    }
}

/// One forward query against a specific publication.  Inputs ride in
/// `Arc`s so a redispatch after a lane death re-sends the same buffers
/// without copying.
struct ServeJob {
    published: Arc<Published>,
    x: Arc<Vec<f32>>,
    y: Arc<Vec<i32>>,
    embed: bool,
    resp: Sender<anyhow::Result<ServeAnswer>>,
}

/// What a served query returns: the stats (and, for embed queries, the
/// feature/probability planes) plus the epoch they were computed at.
#[derive(Clone, Debug)]
pub struct ServeAnswer {
    /// Epoch of the publication that answered the query.
    pub epoch: usize,
    /// Per-slot loss / correct / confidence.
    pub stats: BatchStats,
    /// `[B, embed_dim]` row-major features (embed queries only).
    pub emb: Option<Vec<f32>>,
    /// `[B, classes]` row-major probabilities (embed queries only).
    pub probs: Option<Vec<f32>>,
}

enum ServeReady {
    Ok,
    Fail(String),
}

/// One lane's dispatch slot: the job sender (cleared when the lane is
/// gone) and the number of queries currently waiting on it — the
/// client's least-loaded routing signal.
struct LaneSlot {
    lane_id: usize,
    tx: Mutex<Option<Sender<ServeJob>>>,
    inflight: AtomicUsize,
    /// Set by [`ServeFleet::kill_lane`]: the lane drops queued jobs
    /// *unanswered* (simulating a crash), which is what forces clients
    /// to redispatch.
    stop: AtomicBool,
}

/// A cloneable handle HTTP workers use to hand queries to the fleet and
/// block for the answer.  Each query goes to the live lane with the
/// fewest in-flight queries; if that lane dies before answering, the
/// query redispatches to a survivor — exactly one reply per query, no
/// drops, no duplicates.
#[derive(Clone)]
pub struct ServeClient {
    slots: Arc<Vec<Arc<LaneSlot>>>,
}

impl ServeClient {
    /// Run one forward query against `published` on the least-loaded
    /// live serving replica and wait for the answer.  `embed` selects
    /// `fwd_embed` over `fwd_stats`.
    pub fn query(
        &self,
        published: Arc<Published>,
        x: Vec<f32>,
        y: Vec<i32>,
        embed: bool,
    ) -> anyhow::Result<ServeAnswer> {
        let x = Arc::new(x);
        let y = Arc::new(y);
        let mut attempts = 0usize;
        loop {
            attempts += 1;
            // a dead lane clears its sender on the first failed send, so
            // this loop terminates; the cap is a defensive backstop
            if attempts > 2 * self.slots.len() + 2 {
                anyhow::bail!("serve lanes kept dying mid-query; giving up");
            }
            let mut pick: Option<&Arc<LaneSlot>> = None;
            let mut best = usize::MAX;
            for slot in self.slots.iter() {
                if slot.tx.lock().unwrap().is_none() {
                    continue;
                }
                let load = slot.inflight.load(Ordering::Relaxed);
                if load < best {
                    best = load;
                    pick = Some(slot);
                }
            }
            let Some(slot) = pick else {
                anyhow::bail!("serve lane is gone");
            };
            let (resp, rx) = channel();
            let job = ServeJob {
                published: published.clone(),
                x: x.clone(),
                y: y.clone(),
                embed,
                resp,
            };
            {
                let mut g = slot.tx.lock().unwrap();
                match g.as_ref() {
                    Some(tx) => {
                        if tx.send(job).is_err() {
                            // the lane's receiver is gone: retire the
                            // slot so no one picks it again
                            *g = None;
                            continue;
                        }
                    }
                    None => continue, // retired between pick and send
                }
            }
            slot.inflight.fetch_add(1, Ordering::Relaxed);
            let got = rx.recv();
            slot.inflight.fetch_sub(1, Ordering::Relaxed);
            match got {
                Ok(answer) => return answer,
                // the lane died holding the job without answering — it
                // provably never replied, so redispatching cannot
                // duplicate a reply
                Err(_) => continue,
            }
        }
    }
}

/// The serving replicas' fleet: owns R lane threads, surfaces their
/// failures as fold-in events, and vends [`ServeClient`] handles that
/// route to the least-loaded live lane.
pub struct ServeFleet {
    slots: Arc<Vec<Arc<LaneSlot>>>,
    hub: Arc<SnapshotHub>,
    events_rx: Receiver<ServiceEvent>,
    handles: Vec<Option<std::thread::JoinHandle<()>>>,
}

impl ServeFleet {
    /// Spawn one lane per builder: each replica builds on its own lane
    /// thread (this call blocks until every lane is ready, so build
    /// failures surface here), then the threads serve queries until
    /// every [`ServeClient`] and the fleet itself are dropped.
    pub fn spawn(
        builders: Vec<ReplicaBuilder>,
        hub: Arc<SnapshotHub>,
        batching: ServeBatching,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!builders.is_empty(), "serve fleet needs at least one replica");
        let (events_tx, events_rx) = channel::<ServiceEvent>();
        let mut slots = Vec::new();
        let mut handles = Vec::new();
        let mut readies = Vec::new();
        for (i, build) in builders.into_iter().enumerate() {
            let lane_id = hub.register_lane();
            let (tx, rx) = channel::<ServeJob>();
            let slot = Arc::new(LaneSlot {
                lane_id,
                tx: Mutex::new(Some(tx)),
                inflight: AtomicUsize::new(0),
                stop: AtomicBool::new(false),
            });
            let handle = {
                let slot = slot.clone();
                let hub = hub.clone();
                let events_tx = events_tx.clone();
                let (ready_tx, ready_rx) = channel::<ServeReady>();
                readies.push(ready_rx);
                std::thread::Builder::new()
                    .name(format!("service-serve-{i}"))
                    .spawn(move || lane_main(build, rx, events_tx, ready_tx, hub, slot, batching))?
            };
            slots.push(slot);
            handles.push(Some(handle));
        }
        let mut failure: Option<String> = None;
        for ready_rx in &readies {
            match ready_rx.recv() {
                Ok(ServeReady::Ok) => {}
                Ok(ServeReady::Fail(e)) => failure = Some(e),
                Err(_) => failure = Some("serve lane died during spawn".into()),
            }
        }
        let fleet = ServeFleet { slots: Arc::new(slots), hub, events_rx, handles };
        match failure {
            Some(e) => anyhow::bail!("serve lane spawn failed: {e}"), // fleet drops: healthy lanes join
            None => Ok(fleet),
        }
    }

    /// Spawn a single-lane fleet with coalescing off — the
    /// one-replica, one-query-per-forward configuration.
    pub fn spawn_single(build: ReplicaBuilder, hub: Arc<SnapshotHub>) -> anyhow::Result<Self> {
        ServeFleet::spawn(vec![build], hub, ServeBatching::default())
    }

    /// A query handle for HTTP workers (cloneable, `Send`).
    pub fn client(&self) -> ServeClient {
        ServeClient { slots: self.slots.clone() }
    }

    /// How many lanes this fleet spawned (dead ones included).
    pub fn lanes(&self) -> usize {
        self.slots.len()
    }

    /// Kill lane `i` abruptly (chaos/testing): the lane drops its
    /// queued jobs **unanswered** — as a crashed process would — which
    /// forces their clients to redispatch to surviving lanes; the lane
    /// is marked down on the hub and its thread joined.
    pub fn kill_lane(&mut self, i: usize) {
        let slot = &self.slots[i];
        slot.stop.store(true, Ordering::Release);
        drop(slot.tx.lock().unwrap().take());
        self.hub.lane_down(slot.lane_id);
        if let Some(h) = self.handles[i].take() {
            let _ = h.join();
        }
    }

    /// Non-blocking: every lane failure reported since the last call,
    /// as fold-in [`ServiceEvent::Error`]s.
    pub fn try_events(&mut self) -> Vec<ServiceEvent> {
        let mut out = Vec::new();
        loop {
            match self.events_rx.try_recv() {
                Ok(ev) => out.push(ev),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        out
    }
}

impl Drop for ServeFleet {
    fn drop(&mut self) {
        for slot in self.slots.iter() {
            // graceful: lanes drain + answer queued jobs, then exit
            drop(slot.tx.lock().unwrap().take());
        }
        for h in &mut self.handles {
            if let Some(h) = h.take() {
                let _ = h.join();
            }
        }
    }
}

/// Lane thread body: build the replica, then serve.  With coalescing on
/// the lane blocks for the first query, keeps draining until the batch
/// is full or the oldest query has waited `max_wait`, groups compatible
/// queries, and dispatches each group as one device call.
fn lane_main(
    build: ReplicaBuilder,
    rx: Receiver<ServeJob>,
    events_tx: Sender<ServiceEvent>,
    ready_tx: Sender<ServeReady>,
    hub: Arc<SnapshotHub>,
    slot: Arc<LaneSlot>,
    batching: ServeBatching,
) {
    let mut replica = match build() {
        Ok(r) => r,
        Err(e) => {
            let _ = ready_tx.send(ServeReady::Fail(e.to_string()));
            return;
        }
    };
    if ready_tx.send(ServeReady::Ok).is_err() {
        return;
    }
    let mut synced: Option<u64> = None;
    loop {
        let first = match rx.recv() {
            Ok(job) => job,
            Err(_) => break, // all senders gone: fleet teardown
        };
        let mut pending = vec![first];
        if batching.max_batch > 1 {
            let deadline = Instant::now() + batching.max_wait;
            while pending.len() < batching.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    // past the wait budget: take whatever is already
                    // queued, but don't wait for more
                    match rx.try_recv() {
                        Ok(job) => pending.push(job),
                        Err(_) => break,
                    }
                } else {
                    match rx.recv_timeout(deadline - now) {
                        Ok(job) => pending.push(job),
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
            }
        }
        if slot.stop.load(Ordering::Acquire) {
            // killed: drop the jobs unanswered so clients redispatch
            continue;
        }
        for group in take_groups(pending) {
            dispatch_group(replica.as_mut(), &mut synced, group, &hub, &slot, &events_tx);
        }
    }
}

/// Split drained jobs into coalescible groups — same publication, same
/// endpoint, same row width — preserving arrival order within and
/// across groups.
fn take_groups(pending: Vec<ServeJob>) -> Vec<Vec<ServeJob>> {
    let mut groups: Vec<((u64, bool, usize), Vec<ServeJob>)> = Vec::new();
    for job in pending {
        let rows = job.y.len().max(1);
        let key = (job.published.seq, job.embed, job.x.len() / rows);
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, group)) => group.push(job),
            None => groups.push((key, vec![job])),
        }
    }
    groups.into_iter().map(|(_, group)| group).collect()
}

/// Run one coalesced group and scatter the answers; failures answer
/// every member, mark the lane down, and emit one fold-in event.
fn dispatch_group(
    replica: &mut dyn ReplicaBackend,
    synced: &mut Option<u64>,
    group: Vec<ServeJob>,
    hub: &SnapshotHub,
    slot: &LaneSlot,
    events_tx: &Sender<ServiceEvent>,
) {
    let t = Timer::start();
    let result = run_group(replica, synced, &group);
    hub.record_batch();
    for _ in &group {
        hub.record_query(slot.lane_id);
    }
    match result {
        Ok(answers) => {
            hub.lane_up(slot.lane_id);
            for (job, answer) in group.into_iter().zip(answers) {
                let _ = job.resp.send(Ok(answer));
            }
        }
        Err(e) => {
            // a backend failure, not a client mistake (the HTTP layer
            // validates inputs before submitting): mark this lane down
            // and put a named error in the fold-in stream
            hub.lane_down(slot.lane_id);
            let message = e.to_string();
            let _ = events_tx.send(ServiceEvent::Error {
                epoch: group[0].published.epoch,
                lane: ServiceLaneKind::Serve,
                message: message.clone(),
                secs: t.elapsed_s(),
            });
            for job in group {
                let _ = job.resp.send(Err(anyhow::anyhow!("{message}")));
            }
        }
    }
}

/// Execute one group: sync parameters if the publication changed, then
/// either the solo fast path (identical to pre-batching behavior) or
/// one concatenated forward scattered back by row ranges.
fn run_group(
    replica: &mut dyn ReplicaBackend,
    synced: &mut Option<u64>,
    group: &[ServeJob],
) -> anyhow::Result<Vec<ServeAnswer>> {
    let published = &group[0].published;
    if *synced != Some(published.seq) {
        replica.import_params(published.snapshot.params())?;
        *synced = Some(published.seq);
    }
    let epoch = published.epoch;
    if group.len() == 1 {
        let job = &group[0];
        let answer = if job.embed {
            let es = replica.fwd_embed(&job.x, &job.y)?;
            ServeAnswer { epoch, stats: es.stats, emb: Some(es.emb), probs: Some(es.probs) }
        } else {
            let stats = replica.fwd_stats(&job.x, &job.y)?;
            ServeAnswer { epoch, stats, emb: None, probs: None }
        };
        return Ok(vec![answer]);
    }
    // coalesced: one device forward over the concatenated rows, then
    // per-job row ranges scatter back out — the forward is
    // row-independent, so each slice is bitwise what a solo forward
    // would have produced
    let rows: Vec<usize> = group.iter().map(|job| job.y.len()).collect();
    let total: usize = rows.iter().sum();
    let mut x = Vec::with_capacity(group.iter().map(|job| job.x.len()).sum());
    let mut y = Vec::with_capacity(total);
    for job in group {
        x.extend_from_slice(&job.x);
        y.extend_from_slice(&job.y);
    }
    let mut answers = Vec::with_capacity(group.len());
    if group[0].embed {
        let es = replica.fwd_embed(&x, &y)?;
        let emb_w = es.emb.len() / total.max(1);
        let probs_w = es.probs.len() / total.max(1);
        let mut at = 0usize;
        for b in rows {
            answers.push(ServeAnswer {
                epoch,
                stats: slice_stats(&es.stats, at, b),
                emb: Some(es.emb[at * emb_w..(at + b) * emb_w].to_vec()),
                probs: Some(es.probs[at * probs_w..(at + b) * probs_w].to_vec()),
            });
            at += b;
        }
    } else {
        let stats = replica.fwd_stats(&x, &y)?;
        let mut at = 0usize;
        for b in rows {
            answers.push(ServeAnswer {
                epoch,
                stats: slice_stats(&stats, at, b),
                emb: None,
                probs: None,
            });
            at += b;
        }
    }
    Ok(answers)
}

fn slice_stats(stats: &BatchStats, at: usize, b: usize) -> BatchStats {
    BatchStats {
        loss: stats.loss[at..at + b].to_vec(),
        correct: stats.correct[at..at + b].to_vec(),
        conf: stats.conf[at..at + b].to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::chaos::{ChaosBackend, ChaosPlan};
    use crate::engine::testbed::MockBackend;
    use crate::engine::DataParallel;

    fn snap(param: f32) -> SharedSnapshot {
        Arc::new(Snapshot::params_only(vec![vec![param]]))
    }

    #[test]
    fn hub_starts_unready_and_publishes_atomically() {
        let hub = SnapshotHub::new();
        assert!(!hub.ready());
        assert!(hub.latest().is_none());
        let p = hub.publish(3, snap(1.25));
        assert!(hub.ready());
        assert_eq!(p.epoch, 3);
        assert_eq!(p.digests.len(), 1);
        let got = hub.latest().unwrap();
        assert_eq!(got.epoch, 3);
        assert_eq!(got.seq, p.seq);
        assert_eq!(got.digests, p.digests);
        assert_eq!(hub.publishes(), 1);
    }

    #[test]
    fn latest_always_pairs_epoch_with_its_digests() {
        // a small in-process hammer: writers swap publications while
        // readers assert the (epoch, digests) pairing is never torn
        let hub = Arc::new(SnapshotHub::new());
        let epochs = 16usize;
        let expected: Vec<Vec<String>> = (0..epochs)
            .map(|e| leaf_digests(&Snapshot::params_only(vec![vec![e as f32 + 0.5]])))
            .collect();
        hub.publish(0, snap(0.5));
        let stop = Arc::new(AtomicBool::new(false));
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let hub = hub.clone();
                let expected = expected.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut seen = 0usize;
                    while !stop.load(Ordering::Relaxed) {
                        let p = hub.latest().unwrap();
                        assert_eq!(p.digests, expected[p.epoch], "torn at epoch {}", p.epoch);
                        seen += 1;
                    }
                    seen
                })
            })
            .collect();
        for e in 1..epochs {
            hub.publish(e, snap(e as f32 + 0.5));
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        stop.store(true, Ordering::Relaxed);
        let total: usize = readers.into_iter().map(|r| r.join().unwrap()).sum();
        assert!(total > 0);
    }

    #[test]
    fn retention_is_bounded_and_evicted_readers_stay_sound() {
        let hub = SnapshotHub::with_retain(2);
        // an in-flight reader pins the very first publication...
        let pinned = hub.publish(0, snap(0.5));
        let pinned_digests = pinned.digests.clone();
        // ...while a long run publishes far past the retention bound
        for e in 1..50 {
            hub.publish(e, snap(e as f32 + 0.5));
            assert!(hub.retained_count() <= 2, "retained {} at epoch {e}", hub.retained_count());
        }
        assert_eq!(hub.publishes(), 50);
        assert_eq!(hub.retained_count(), 2);
        // the hub serves the newest publication...
        assert_eq!(hub.latest().unwrap().epoch, 49);
        // ...and the evicted publication is still fully readable through
        // the reader's own Arc: digests, snapshot params, the lot
        assert_eq!(pinned.epoch, 0);
        assert_eq!(pinned.digests, pinned_digests);
        assert_eq!(pinned.snapshot.params()[0][0].to_bits(), 0.5f32.to_bits());
        assert_eq!(leaf_digests(&pinned.snapshot), pinned_digests);
    }

    #[test]
    fn lane_answers_against_the_published_snapshot() {
        let hub = Arc::new(SnapshotHub::new());
        let be = MockBackend::new();
        let fleet = ServeFleet::spawn_single(be.replica_builder().unwrap(), hub.clone()).unwrap();
        let client = fleet.client();
        let p1 = hub.publish(0, snap(0.5));
        let a1 = client.query(p1, vec![0.25, 0.5], vec![1], false).unwrap();
        assert_eq!(a1.epoch, 0);
        // direct reference: a fresh backend with the same params
        let mut direct = MockBackend::new();
        direct.import_params(&[vec![0.5]]).unwrap();
        let want = direct.fwd_stats(&[0.25, 0.5], &[1]).unwrap();
        assert_eq!(a1.stats.loss[0].to_bits(), want.loss[0].to_bits());
        // a new publication re-syncs the replica
        let p2 = hub.publish(1, snap(2.5));
        let a2 = client.query(p2, vec![0.25, 0.5], vec![1], false).unwrap();
        assert_eq!(a2.epoch, 1);
        assert_ne!(a2.stats.loss[0].to_bits(), a1.stats.loss[0].to_bits());
        assert_eq!(hub.take_queries(), 2);
        assert_eq!(hub.take_queries(), 0);
        // solo dispatches still count one device batch per query
        assert_eq!(hub.take_batches(), 2);
        assert_eq!(hub.take_lane_queries(), vec![2]);
        assert_eq!(hub.take_lane_queries(), vec![0]);
    }

    #[test]
    fn embed_queries_ride_the_same_lane() {
        let hub = Arc::new(SnapshotHub::new());
        let be = MockBackend::new();
        let fleet = ServeFleet::spawn_single(be.replica_builder().unwrap(), hub.clone()).unwrap();
        let p = hub.publish(0, snap(1.5));
        let ans = fleet.client().query(p, vec![0.25, 0.5, 0.1, 0.2], vec![1, 2], true).unwrap();
        let emb = ans.emb.unwrap();
        assert_eq!(emb.len(), 4); // 2 slots x 2 features
        assert_eq!(ans.probs.unwrap().len(), 2);
        assert_eq!(emb[1].to_bits(), (emb[0] * 1.5).to_bits());
    }

    #[test]
    fn coalesced_batch_scatters_bitwise_equal_answers() {
        // long max_wait so the lane provably coalesces: the first query
        // opens a 1s window, three more land well inside it, and one
        // device batch answers all four
        let hub = Arc::new(SnapshotHub::new());
        let be = MockBackend::new();
        let batching = ServeBatching { max_batch: 8, max_wait: Duration::from_secs(1) };
        let fleet =
            ServeFleet::spawn(vec![be.replica_builder().unwrap()], hub.clone(), batching).unwrap();
        let p = hub.publish(0, snap(0.75));
        let inputs: Vec<(Vec<f32>, Vec<i32>)> = (0..4)
            .map(|i| (vec![0.1 * (i as f32 + 1.0), 0.2], vec![i as i32 % 3]))
            .collect();
        let workers: Vec<_> = inputs
            .iter()
            .cloned()
            .map(|(x, y)| {
                let client = fleet.client();
                let p = p.clone();
                std::thread::spawn(move || client.query(p, x, y, false).unwrap())
            })
            .collect();
        let answers: Vec<ServeAnswer> =
            workers.into_iter().map(|w| w.join().unwrap()).collect();
        // one coalesced device call answered all four queries
        assert_eq!(hub.take_queries(), 4);
        assert_eq!(hub.take_batches(), 1);
        // each answer is bitwise what a solo forward would produce
        let mut direct = MockBackend::new();
        direct.import_params(&[vec![0.75]]).unwrap();
        for ((x, y), answer) in inputs.iter().zip(&answers) {
            let want = direct.fwd_stats(x, y).unwrap();
            assert_eq!(answer.epoch, 0);
            assert_eq!(answer.stats.loss.len(), 1);
            assert_eq!(answer.stats.loss[0].to_bits(), want.loss[0].to_bits());
            assert_eq!(answer.stats.correct[0].to_bits(), want.correct[0].to_bits());
            assert_eq!(answer.stats.conf[0].to_bits(), want.conf[0].to_bits());
        }
    }

    #[test]
    fn mixed_endpoints_split_into_separate_groups() {
        // stats and embed queries coalesce only with their own kind:
        // both answer correctly out of one drained buffer
        let hub = Arc::new(SnapshotHub::new());
        let be = MockBackend::new();
        let batching = ServeBatching { max_batch: 8, max_wait: Duration::from_secs(1) };
        let fleet =
            ServeFleet::spawn(vec![be.replica_builder().unwrap()], hub.clone(), batching).unwrap();
        let p = hub.publish(0, snap(1.5));
        let workers: Vec<_> = (0..4)
            .map(|i| {
                let client = fleet.client();
                let p = p.clone();
                std::thread::spawn(move || {
                    client.query(p, vec![0.25, 0.5], vec![1], i % 2 == 1).unwrap()
                })
            })
            .collect();
        let answers: Vec<ServeAnswer> =
            workers.into_iter().map(|w| w.join().unwrap()).collect();
        for (i, answer) in answers.iter().enumerate() {
            assert_eq!(answer.emb.is_some(), i % 2 == 1);
            assert_eq!(answer.stats.loss.len(), 1);
        }
        assert_eq!(hub.take_queries(), 4);
        // the drained buffer split by endpoint: at most one batch per kind
        let batches = hub.take_batches();
        assert!(batches >= 2 && batches <= 4, "batches = {batches}");
    }

    #[test]
    fn fleet_routes_across_replicas_and_survives_a_killed_lane() {
        let hub = Arc::new(SnapshotHub::new());
        let be = MockBackend::new();
        let builders = vec![be.replica_builder().unwrap(), be.replica_builder().unwrap()];
        let mut fleet =
            ServeFleet::spawn(builders, hub.clone(), ServeBatching::default()).unwrap();
        assert_eq!(fleet.lanes(), 2);
        assert_eq!(hub.lanes(), 2);
        let client = fleet.client();
        let p = hub.publish(0, snap(0.5));
        for _ in 0..8 {
            assert!(client.query(p.clone(), vec![0.25, 0.5], vec![1], false).is_ok());
        }
        // kill one lane: the fleet stays healthy on the survivor
        fleet.kill_lane(0);
        assert_eq!(hub.lanes_down(), 1);
        assert!(!hub.degraded(), "one live lane must keep the fleet healthy");
        let mut direct = MockBackend::new();
        direct.import_params(&[vec![0.5]]).unwrap();
        let want = direct.fwd_stats(&[0.25, 0.5], &[1]).unwrap();
        for _ in 0..8 {
            let got = client.query(p.clone(), vec![0.25, 0.5], vec![1], false).unwrap();
            assert_eq!(got.stats.loss[0].to_bits(), want.loss[0].to_bits());
        }
        // kill the last lane: now queries fail and the hub is degraded
        fleet.kill_lane(1);
        assert_eq!(hub.lanes_down(), 2);
        assert!(hub.degraded());
        assert!(client.query(p, vec![0.25, 0.5], vec![1], false).is_err());
    }

    #[test]
    fn killed_replica_degrades_and_reports_a_serve_error() {
        let hub = Arc::new(SnapshotHub::new());
        // rank-0 replica dies on its second device call (import counts
        // no steps; fwd_stats does)
        let primary = ChaosBackend::primary(MockBackend::new(), ChaosPlan::new().kill(0, 1));
        let mut fleet =
            ServeFleet::spawn_single(primary.replica_builder().unwrap(), hub.clone()).unwrap();
        let client = fleet.client();
        let p = hub.publish(2, snap(1.0));
        assert!(client.query(p.clone(), vec![0.5], vec![1], false).is_ok());
        assert!(!hub.degraded());
        let err = client.query(p.clone(), vec![0.5], vec![1], false).unwrap_err();
        assert!(err.to_string().contains("chaos"), "{err}");
        assert!(hub.degraded());
        let events = fleet.try_events();
        assert_eq!(events.len(), 1);
        match &events[0] {
            ServiceEvent::Error { epoch: 2, lane: ServiceLaneKind::Serve, message, .. } => {
                assert!(message.contains("chaos"), "{message}");
            }
            other => panic!("expected a serve error event, got {other:?}"),
        }
        // the one-shot kill has fired; the lane keeps serving, and a
        // successful answer marks it back up
        assert!(client.query(p, vec![0.5], vec![1], false).is_ok());
        assert!(!hub.degraded());
    }

    #[test]
    fn failed_builder_surfaces_at_spawn() {
        let build: ReplicaBuilder = Box::new(|| anyhow::bail!("no artifacts"));
        assert!(ServeFleet::spawn_single(build, Arc::new(SnapshotHub::new())).is_err());
    }

    #[test]
    fn failed_builder_in_a_fleet_tears_down_the_healthy_lanes() {
        let be = MockBackend::new();
        let builders: Vec<ReplicaBuilder> = vec![
            be.replica_builder().unwrap(),
            Box::new(|| anyhow::bail!("no artifacts")),
        ];
        let err = ServeFleet::spawn(builders, Arc::new(SnapshotHub::new()), ServeBatching::default())
            .unwrap_err();
        assert!(err.to_string().contains("no artifacts"), "{err}");
    }
}
