//! The online inference lane: live snapshot publication + a serving
//! replica, off the training critical path.
//!
//! Two pieces (the HTTP surface lives in [`crate::serve`]):
//!
//! * [`SnapshotHub`] — the publication point.  The epoch pipeline
//!   publishes each epoch's params-tier snapshot here (one atomic
//!   pointer swap); query threads read the latest publication with a
//!   **single atomic load and no lock**, so a swap can never expose a
//!   torn `(epoch, digests, snapshot)` triple — the epoch a response
//!   reports is always the epoch whose parameters answered it.
//! * [`ServeLane`] — the replica owner.  Like the eval lane
//!   (`engine/service.rs`), the serving replica is built *on* its lane
//!   thread via the [`ReplicaBuilder`] contract (PJRT state is not
//!   `Send`); query threads hand it jobs through a [`ServeClient`] and
//!   block on a per-query reply channel.  The replica re-imports
//!   parameters only when the publication under a query differs from
//!   the one it last synced — queries between publications pay no
//!   import.
//!
//! # Failure contract
//!
//! A backend failure on the lane (a killed replica, a failed import)
//! marks the hub **degraded** (surfaced by `/healthz`), answers the
//! in-flight query with the error, and emits a named
//! [`ServiceEvent::Error`] tagged [`ServiceLaneKind::Serve`] into the
//! fold-in stream the trainer drains at each epoch barrier — so
//! `--fault-policy fail` aborts the run with a clear message while
//! `elastic` counts the failure and keeps training.  Client-side input
//! validation happens in the HTTP layer *before* a job is submitted, so
//! malformed queries never reach the device and never degrade the lane.
//!
//! # Determinism contract
//!
//! Serving is read-only: the lane touches only its own replica and the
//! immutable published snapshots, so a run with `--serve` on is bitwise
//! identical to one with it off (`tests/inference_serving.rs`).

use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};

use super::backend::{ReplicaBackend, ReplicaBuilder, StateExchange, StepBackend};
use super::service::{ServiceEvent, ServiceLaneKind};
use super::snapshot::{SharedSnapshot, Snapshot};
use crate::runtime::BatchStats;
use crate::util::sha256::sha256_hex;
use crate::util::timer::Timer;

/// SHA-256 digest of each parameter leaf's little-endian `f32` bytes —
/// the same byte layout the checkpoint store hashes, so a served digest
/// is comparable to a stored leaf's.
pub fn leaf_digests(snap: &Snapshot) -> Vec<String> {
    snap.params()
        .iter()
        .map(|leaf| {
            let mut bytes = Vec::with_capacity(leaf.len() * 4);
            for v in leaf {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            sha256_hex(&bytes)
        })
        .collect()
}

/// One publication: everything a response reports about the snapshot it
/// was answered against, bundled so a single pointer load observes all
/// of it or none of it.
#[derive(Debug)]
pub struct Published {
    /// The epoch this snapshot was exported at.
    pub epoch: usize,
    /// Monotonic publication sequence number (the lane's sync key —
    /// distinct publications of the same epoch re-import).
    pub seq: u64,
    /// Per-leaf SHA-256 digests of the parameter section.
    pub digests: Vec<String>,
    /// The published snapshot itself.
    pub snapshot: SharedSnapshot,
}

/// The atomically-swapped publication point (see module docs).
///
/// Readers pay one `Acquire` pointer load per query; the publisher pays
/// a short retention-list lock per epoch.  Every publication is retained
/// for the hub's lifetime (bounded: one per epoch), which is what makes
/// the lock-free read sound — a loaded pointer can never dangle.
pub struct SnapshotHub {
    current: AtomicPtr<Published>,
    retained: Mutex<Vec<Arc<Published>>>,
    seq: AtomicU64,
    publishes: AtomicUsize,
    queries: AtomicUsize,
    degraded: AtomicBool,
}

impl Default for SnapshotHub {
    fn default() -> Self {
        SnapshotHub::new()
    }
}

impl SnapshotHub {
    /// An empty hub: not ready until the first [`SnapshotHub::publish`].
    pub fn new() -> Self {
        SnapshotHub {
            current: AtomicPtr::new(std::ptr::null_mut()),
            retained: Mutex::new(Vec::new()),
            seq: AtomicU64::new(0),
            publishes: AtomicUsize::new(0),
            queries: AtomicUsize::new(0),
            degraded: AtomicBool::new(false),
        }
    }

    /// Publish `snap` as the live snapshot for `epoch`.  Readers switch
    /// to it atomically; in-flight queries keep the publication they
    /// already loaded.
    pub fn publish(&self, epoch: usize, snap: SharedSnapshot) -> Arc<Published> {
        let published = Arc::new(Published {
            epoch,
            seq: self.seq.fetch_add(1, Ordering::Relaxed) + 1,
            digests: leaf_digests(&snap),
            snapshot: snap,
        });
        let raw = Arc::as_ptr(&published) as *mut Published;
        // retain BEFORE exposing the pointer: a reader that loads it must
        // always find the allocation alive
        self.retained.lock().unwrap().push(published.clone());
        self.current.store(raw, Ordering::Release);
        self.publishes.fetch_add(1, Ordering::Relaxed);
        published
    }

    /// The latest publication, or `None` before the first publish.
    /// Lock-free: one atomic load, then an `Arc` refcount bump.
    pub fn latest(&self) -> Option<Arc<Published>> {
        let p = self.current.load(Ordering::Acquire);
        if p.is_null() {
            return None;
        }
        // SAFETY: `p` was produced by `Arc::as_ptr` on a publication that
        // `retained` keeps alive for the hub's whole lifetime, so the
        // strong count is >= 1 here and bumping it hands out an owned
        // handle to a live allocation.
        unsafe {
            Arc::increment_strong_count(p);
            Some(Arc::from_raw(p))
        }
    }

    /// Whether a snapshot has been published (the `/healthz` readiness
    /// signal).
    pub fn ready(&self) -> bool {
        !self.current.load(Ordering::Acquire).is_null()
    }

    /// Total publications so far.
    pub fn publishes(&self) -> usize {
        self.publishes.load(Ordering::Relaxed)
    }

    /// Count one answered query (the serve lane calls this per job).
    pub fn record_query(&self) {
        self.queries.fetch_add(1, Ordering::Relaxed);
    }

    /// Queries answered since the last call (the per-epoch fold: each
    /// epoch record absorbs the delta).
    pub fn take_queries(&self) -> usize {
        self.queries.swap(0, Ordering::Relaxed)
    }

    /// Mark the serving path degraded (a replica failure under the
    /// elastic fault policy) or recovered.
    pub fn set_degraded(&self, degraded: bool) {
        self.degraded.store(degraded, Ordering::Release);
    }

    /// Whether the serving path is degraded.
    pub fn degraded(&self) -> bool {
        self.degraded.load(Ordering::Acquire)
    }
}

/// One forward query against a specific publication.
struct ServeJob {
    published: Arc<Published>,
    x: Vec<f32>,
    y: Vec<i32>,
    embed: bool,
    resp: Sender<anyhow::Result<ServeAnswer>>,
}

/// What a served query returns: the stats (and, for embed queries, the
/// feature/probability planes) plus the epoch they were computed at.
#[derive(Clone, Debug)]
pub struct ServeAnswer {
    /// Epoch of the publication that answered the query.
    pub epoch: usize,
    /// Per-slot loss / correct / confidence.
    pub stats: BatchStats,
    /// `[B, embed_dim]` row-major features (embed queries only).
    pub emb: Option<Vec<f32>>,
    /// `[B, classes]` row-major probabilities (embed queries only).
    pub probs: Option<Vec<f32>>,
}

enum ServeReady {
    Ok,
    Fail(String),
}

/// A cloneable handle HTTP workers use to hand queries to the lane and
/// block for the answer.
#[derive(Clone)]
pub struct ServeClient {
    tx: Sender<ServeJob>,
}

impl ServeClient {
    /// Run one forward query on the serving replica against `published`
    /// and wait for the answer.  `embed` selects `fwd_embed` over
    /// `fwd_stats`.
    pub fn query(
        &self,
        published: Arc<Published>,
        x: Vec<f32>,
        y: Vec<i32>,
        embed: bool,
    ) -> anyhow::Result<ServeAnswer> {
        let (resp, rx) = channel();
        self.tx
            .send(ServeJob { published, x, y, embed, resp })
            .map_err(|_| anyhow::anyhow!("serve lane is gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("serve lane dropped the query"))?
    }
}

/// The serving replica's lane: owns the replica thread, surfaces its
/// failures as fold-in events, and vends [`ServeClient`] handles.
pub struct ServeLane {
    tx: Option<Sender<ServeJob>>,
    events_rx: Receiver<ServiceEvent>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ServeLane {
    /// Spawn the lane: the replica builds on the lane thread (blocking
    /// this call until ready, so build failures surface here), then the
    /// thread serves queries until every [`ServeClient`] and the lane
    /// itself are dropped.
    pub fn spawn(build: ReplicaBuilder, hub: Arc<SnapshotHub>) -> anyhow::Result<Self> {
        let (tx, rx) = channel::<ServeJob>();
        let (events_tx, events_rx) = channel::<ServiceEvent>();
        let (ready_tx, ready_rx) = channel::<ServeReady>();
        let handle = std::thread::Builder::new()
            .name("service-serve".into())
            .spawn(move || lane_main(build, rx, events_tx, ready_tx, hub))?;
        match ready_rx.recv() {
            Ok(ServeReady::Ok) => {
                Ok(ServeLane { tx: Some(tx), events_rx, handle: Some(handle) })
            }
            Ok(ServeReady::Fail(e)) => anyhow::bail!("serve lane spawn failed: {e}"),
            Err(_) => anyhow::bail!("serve lane died during spawn"),
        }
    }

    /// A query handle for HTTP workers (cloneable, `Send`).
    pub fn client(&self) -> ServeClient {
        ServeClient { tx: self.tx.as_ref().expect("lane alive until drop").clone() }
    }

    /// Non-blocking: every lane failure reported since the last call,
    /// as fold-in [`ServiceEvent::Error`]s.
    pub fn try_events(&mut self) -> Vec<ServiceEvent> {
        let mut out = Vec::new();
        loop {
            match self.events_rx.try_recv() {
                Ok(ev) => out.push(ev),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        out
    }
}

impl Drop for ServeLane {
    fn drop(&mut self) {
        drop(self.tx.take()); // disconnect; the lane exits once clients are gone
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Lane thread body: build the replica, then answer queries.  Parameters
/// re-import only when the query's publication differs from the last
/// synced one, so steady-state queries are pure forwards.
fn lane_main(
    build: ReplicaBuilder,
    rx: Receiver<ServeJob>,
    events_tx: Sender<ServiceEvent>,
    ready_tx: Sender<ServeReady>,
    hub: Arc<SnapshotHub>,
) {
    let mut replica = match build() {
        Ok(r) => r,
        Err(e) => {
            let _ = ready_tx.send(ServeReady::Fail(e.to_string()));
            return;
        }
    };
    if ready_tx.send(ServeReady::Ok).is_err() {
        return;
    }
    let mut synced: Option<u64> = None;
    while let Ok(job) = rx.recv() {
        let t = Timer::start();
        let answer = serve_one(replica.as_mut(), &mut synced, &job);
        hub.record_query();
        if let Err(e) = &answer {
            // a backend failure, not a client mistake (the HTTP layer
            // validates inputs before submitting): degrade the health
            // signal and put a named error in the fold-in stream
            hub.set_degraded(true);
            let _ = events_tx.send(ServiceEvent::Error {
                epoch: job.published.epoch,
                lane: ServiceLaneKind::Serve,
                message: e.to_string(),
                secs: t.elapsed_s(),
            });
        }
        let _ = job.resp.send(answer);
    }
}

fn serve_one(
    replica: &mut dyn ReplicaBackend,
    synced: &mut Option<u64>,
    job: &ServeJob,
) -> anyhow::Result<ServeAnswer> {
    if *synced != Some(job.published.seq) {
        replica.import_params(job.published.snapshot.params())?;
        *synced = Some(job.published.seq);
    }
    let epoch = job.published.epoch;
    if job.embed {
        let es = replica.fwd_embed(&job.x, &job.y)?;
        Ok(ServeAnswer { epoch, stats: es.stats, emb: Some(es.emb), probs: Some(es.probs) })
    } else {
        let stats = replica.fwd_stats(&job.x, &job.y)?;
        Ok(ServeAnswer { epoch, stats, emb: None, probs: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::chaos::{ChaosBackend, ChaosPlan};
    use crate::engine::testbed::MockBackend;
    use crate::engine::DataParallel;

    fn snap(param: f32) -> SharedSnapshot {
        Arc::new(Snapshot::params_only(vec![vec![param]]))
    }

    #[test]
    fn hub_starts_unready_and_publishes_atomically() {
        let hub = SnapshotHub::new();
        assert!(!hub.ready());
        assert!(hub.latest().is_none());
        let p = hub.publish(3, snap(1.25));
        assert!(hub.ready());
        assert_eq!(p.epoch, 3);
        assert_eq!(p.digests.len(), 1);
        let got = hub.latest().unwrap();
        assert_eq!(got.epoch, 3);
        assert_eq!(got.seq, p.seq);
        assert_eq!(got.digests, p.digests);
        assert_eq!(hub.publishes(), 1);
    }

    #[test]
    fn latest_always_pairs_epoch_with_its_digests() {
        // a small in-process hammer: writers swap publications while
        // readers assert the (epoch, digests) pairing is never torn
        let hub = Arc::new(SnapshotHub::new());
        let epochs = 16usize;
        let expected: Vec<Vec<String>> = (0..epochs)
            .map(|e| leaf_digests(&Snapshot::params_only(vec![vec![e as f32 + 0.5]])))
            .collect();
        hub.publish(0, snap(0.5));
        let stop = Arc::new(AtomicBool::new(false));
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let hub = hub.clone();
                let expected = expected.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut seen = 0usize;
                    while !stop.load(Ordering::Relaxed) {
                        let p = hub.latest().unwrap();
                        assert_eq!(p.digests, expected[p.epoch], "torn at epoch {}", p.epoch);
                        seen += 1;
                    }
                    seen
                })
            })
            .collect();
        for e in 1..epochs {
            hub.publish(e, snap(e as f32 + 0.5));
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        stop.store(true, Ordering::Relaxed);
        let total: usize = readers.into_iter().map(|r| r.join().unwrap()).sum();
        assert!(total > 0);
    }

    #[test]
    fn lane_answers_against_the_published_snapshot() {
        let hub = Arc::new(SnapshotHub::new());
        let be = MockBackend::new();
        let lane = ServeLane::spawn(be.replica_builder().unwrap(), hub.clone()).unwrap();
        let client = lane.client();
        let p1 = hub.publish(0, snap(0.5));
        let a1 = client.query(p1, vec![0.25, 0.5], vec![1], false).unwrap();
        assert_eq!(a1.epoch, 0);
        // direct reference: a fresh backend with the same params
        let mut direct = MockBackend::new();
        direct.import_params(&[vec![0.5]]).unwrap();
        let want = direct.fwd_stats(&[0.25, 0.5], &[1]).unwrap();
        assert_eq!(a1.stats.loss[0].to_bits(), want.loss[0].to_bits());
        // a new publication re-syncs the replica
        let p2 = hub.publish(1, snap(2.5));
        let a2 = client.query(p2, vec![0.25, 0.5], vec![1], false).unwrap();
        assert_eq!(a2.epoch, 1);
        assert_ne!(a2.stats.loss[0].to_bits(), a1.stats.loss[0].to_bits());
        assert_eq!(hub.take_queries(), 2);
        assert_eq!(hub.take_queries(), 0);
    }

    #[test]
    fn embed_queries_ride_the_same_lane() {
        let hub = Arc::new(SnapshotHub::new());
        let be = MockBackend::new();
        let lane = ServeLane::spawn(be.replica_builder().unwrap(), hub.clone()).unwrap();
        let p = hub.publish(0, snap(1.5));
        let ans = lane.client().query(p, vec![0.25, 0.5, 0.1, 0.2], vec![1, 2], true).unwrap();
        let emb = ans.emb.unwrap();
        assert_eq!(emb.len(), 4); // 2 slots x 2 features
        assert_eq!(ans.probs.unwrap().len(), 2);
        assert_eq!(emb[1].to_bits(), (emb[0] * 1.5).to_bits());
    }

    #[test]
    fn killed_replica_degrades_and_reports_a_serve_error() {
        let hub = Arc::new(SnapshotHub::new());
        // rank-0 replica dies on its second device call (import counts
        // no steps; fwd_stats does)
        let primary = ChaosBackend::primary(MockBackend::new(), ChaosPlan::new().kill(0, 1));
        let mut lane =
            ServeLane::spawn(primary.replica_builder().unwrap(), hub.clone()).unwrap();
        let client = lane.client();
        let p = hub.publish(2, snap(1.0));
        assert!(client.query(p.clone(), vec![0.5], vec![1], false).is_ok());
        assert!(!hub.degraded());
        let err = client.query(p.clone(), vec![0.5], vec![1], false).unwrap_err();
        assert!(err.to_string().contains("chaos"), "{err}");
        assert!(hub.degraded());
        let events = lane.try_events();
        assert_eq!(events.len(), 1);
        match &events[0] {
            ServiceEvent::Error { epoch: 2, lane: ServiceLaneKind::Serve, message, .. } => {
                assert!(message.contains("chaos"), "{message}");
            }
            other => panic!("expected a serve error event, got {other:?}"),
        }
        // the one-shot kill has fired; the lane keeps serving
        assert!(client.query(p, vec![0.5], vec![1], false).is_ok());
    }

    #[test]
    fn failed_builder_surfaces_at_spawn() {
        let build: ReplicaBuilder = Box::new(|| anyhow::bail!("no artifacts"));
        assert!(ServeLane::spawn(build, Arc::new(SnapshotHub::new())).is_err());
    }
}
