//! The async service lanes: validation eval and checkpoint serialization
//! off the training critical path, each on its own queue.
//!
//! # Why two lanes, not one
//!
//! Both jobs consume only an *immutable* exported
//! [`Snapshot`](super::snapshot::Snapshot), so
//! neither has to block the next epoch: the primary executor can start
//! training epoch `e+1` the moment epoch `e`'s state is exported.  But
//! the two jobs want different things:
//!
//! * **Eval** needs a live replica (the production backend's device state
//!   is not `Send`, so the lane *builds* its own — the [`ReplicaBuilder`]
//!   contract the worker pool's replica lanes established) and consumes
//!   the cheap [`SnapshotTier::Params`] tier.
//! * **Checkpoint** needs no replica at all — it serializes the snapshot
//!   through a [`CheckpointWriter`] — but requires the
//!   [`SnapshotTier::Full`] tier (momentum must ride along for
//!   bit-exact resume).
//!
//! A single FIFO worker serializes eval behind checkpoint writes: at
//! segmentation-scale parameter counts (the paper's DeepCAM workload) one
//! checkpoint's npy serialization dwarfs an eval and the lane becomes the
//! bottleneck it was built to remove.  [`ServiceLanes`] therefore runs an
//! **eval lane** and a **checkpoint lane** as independent worker threads
//! with independent queues: a slow model write for epoch `e` no longer
//! delays the eval of epoch `e+1`.
//!
//! # Determinism contract
//!
//! Unchanged from the single-lane design, and enforced by
//! `rust/tests/service_lane_determinism.rs`: the eval lane evaluates an
//! **exact** snapshot (the params export/import round-trip preserves
//! every f32 bit pattern) with the same [`BatchAssembler`] fill and the
//! same accumulation order as the synchronous
//! [`crate::engine::EvalSink`] path, so async eval is bitwise identical
//! to sync eval.  Each lane is FIFO, so per-lane completions arrive in
//! submission (fixed epoch) order; across lanes,
//! [`ServiceLanes::try_events`] / [`ServiceLanes::drain`] merge by
//! `(epoch, eval-before-checkpoint)` — the synchronous phase order — and
//! the coordinator folds results into records keyed by epoch, so barrier
//! fold-in is deterministic no matter which lane finishes first.
//!
//! # Job failure
//!
//! A failed *job* (a checkpoint write error, an eval forward error) must
//! not wedge the pipeline: the lane stays alive and the failure comes
//! back as a named [`ServiceEvent::Error`] in the same fold-in stream,
//! so the coordinator can apply the configured fault policy (abort with
//! a clear message under `--fault-policy fail`, count and continue under
//! `elastic`).  Only handler *init* failures (e.g. the eval replica
//! build) kill a lane — those surface synchronously at
//! [`ServiceLanes::spawn`].

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};

use super::backend::{ReplicaBackend, ReplicaBuilder, StateExchange, StepBackend};
use super::modes::EvalSink;
use super::snapshot::{SharedSnapshot, SnapshotTier};
use crate::data::batch::BatchAssembler;
use crate::data::Dataset;
use crate::util::artifact::WriteStats;
use crate::util::timer::Timer;

/// A `Send` closure that serializes one full-state snapshot as a
/// checkpoint for the given epoch, returning the write-pool statistics
/// (leaves, bytes, write/hash/compress seconds) for fold-in.  The
/// coordinator constructs it from the runtime's checkpoint writer (which
/// owns the persistent leaf write pool) plus the executor's parameter
/// metadata, so the engine layer never depends on runtime types.  The
/// snapshot arrives by shared handle — writer internals fan it out
/// across pool threads via `Arc` clones.
pub type CheckpointWriter =
    Box<dyn Fn(SharedSnapshot, usize) -> anyhow::Result<WriteStats> + Send>;

/// Which service lane an event came from — names the lane in
/// [`ServiceEvent::Error`] so fault handling can report *what* failed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServiceLaneKind {
    /// The validation-eval lane (owns the eval replica).
    Eval,
    /// The checkpoint-serialization lane (owns the writer).
    Checkpoint,
    /// The online inference lane (owns the serving replica; see
    /// [`crate::engine::serve`]).  It is query-driven rather than
    /// FIFO-submitted, but its failures ride the same
    /// [`ServiceEvent::Error`] fold-in stream.
    Serve,
}

impl ServiceLaneKind {
    /// Lane name for error messages and JSON records.
    pub fn name(&self) -> &'static str {
        match self {
            ServiceLaneKind::Eval => "eval",
            ServiceLaneKind::Checkpoint => "checkpoint",
            ServiceLaneKind::Serve => "serve",
        }
    }
}

/// One completed service-lane job.
#[derive(Clone, Debug)]
pub enum ServiceEvent {
    /// Validation eval finished for `epoch`.
    Eval {
        /// The epoch whose snapshot was evaluated.
        epoch: usize,
        /// Validation top-1 accuracy (bitwise identical to sync eval).
        acc: f64,
        /// Mean validation loss (bitwise identical to sync eval).
        loss: f64,
        /// Seconds the lane spent on the job (off the critical path).
        secs: f64,
    },
    /// Checkpoint serialization finished for `epoch`.
    Checkpoint {
        /// The epoch whose snapshot was serialized.
        epoch: usize,
        /// Seconds the lane spent on the job (off the critical path).
        secs: f64,
        /// Leaf write-pool statistics the writer reported (leaves,
        /// bytes, dedup hits, write/hash/compress seconds).
        stats: WriteStats,
    },
    /// A job failed; the lane survived and keeps serving its queue.
    Error {
        /// The epoch whose job failed.
        epoch: usize,
        /// Which lane the failed job ran on.
        lane: ServiceLaneKind,
        /// The job error, rendered for fault reporting.
        message: String,
        /// Seconds the lane spent before the job failed.
        secs: f64,
    },
}

impl ServiceEvent {
    /// The epoch the job belonged to.
    pub fn epoch(&self) -> usize {
        match self {
            ServiceEvent::Eval { epoch, .. }
            | ServiceEvent::Checkpoint { epoch, .. }
            | ServiceEvent::Error { epoch, .. } => *epoch,
        }
    }

    /// Lane seconds the job consumed.
    pub fn secs(&self) -> f64 {
        match self {
            ServiceEvent::Eval { secs, .. }
            | ServiceEvent::Checkpoint { secs, .. }
            | ServiceEvent::Error { secs, .. } => *secs,
        }
    }

    /// Barrier fold-in key: epoch first, eval before checkpoint before
    /// serve within an epoch (the synchronous pipeline's phase order).  A
    /// [`ServiceEvent::Error`] sorts where its lane's success event
    /// would have — it replaces exactly one job's completion.
    fn fold_key(&self) -> (usize, u8) {
        match self {
            ServiceEvent::Eval { epoch, .. } => (*epoch, 0),
            ServiceEvent::Checkpoint { epoch, .. } => (*epoch, 1),
            ServiceEvent::Error { epoch, lane, .. } => {
                let slot = match lane {
                    ServiceLaneKind::Eval => 0,
                    ServiceLaneKind::Checkpoint => 1,
                    ServiceLaneKind::Serve => 2,
                };
                (*epoch, slot)
            }
        }
    }
}

/// A job handler living on a lane thread: consumes `(epoch, snapshot)`
/// submissions one at a time.
type JobHandler = Box<dyn FnMut(usize, SharedSnapshot) -> anyhow::Result<ServiceEvent>>;

/// A `Send` constructor for a lane's handler, invoked once on the lane
/// thread (the eval lane builds its non-`Send` replica inside this).
type HandlerInit = Box<dyn FnOnce() -> anyhow::Result<JobHandler> + Send>;

enum LaneReply {
    /// The handler finished initializing; the lane accepts jobs.
    Ready,
    /// One completed job — success or a named [`ServiceEvent::Error`].
    Done(ServiceEvent),
    /// Handler init failed; the lane exits.  Job failures never use this
    /// arm — they ride `Done(ServiceEvent::Error)` and the lane survives.
    Fail(String),
}

/// One FIFO worker thread with its own queue: jobs go in as
/// `(epoch, snapshot)`, [`ServiceEvent`]s come back in submission order.
struct ServiceWorker {
    cmd_tx: Option<Sender<(usize, SharedSnapshot)>>,
    reply_rx: Receiver<LaneReply>,
    handle: Option<std::thread::JoinHandle<()>>,
    pending: usize,
}

impl ServiceWorker {
    /// Spawn the worker and block until its handler reports ready, so
    /// init failures (replica build) surface here and every later submit
    /// is cheap.
    fn spawn(kind: ServiceLaneKind, init: HandlerInit) -> anyhow::Result<Self> {
        let (cmd_tx, cmd_rx) = channel::<(usize, SharedSnapshot)>();
        let (reply_tx, reply_rx) = channel::<LaneReply>();
        let handle = std::thread::Builder::new()
            .name(format!("service-{}", kind.name()))
            .spawn(move || worker_main(kind, init, cmd_rx, reply_tx))?;
        let worker =
            ServiceWorker { cmd_tx: Some(cmd_tx), reply_rx, handle: Some(handle), pending: 0 };
        match worker.reply_rx.recv() {
            Ok(LaneReply::Ready) => Ok(worker),
            Ok(LaneReply::Fail(e)) => anyhow::bail!("service lane spawn failed: {e}"),
            Ok(LaneReply::Done(_)) => anyhow::bail!("service lane: job reply before ready"),
            Err(_) => anyhow::bail!("service lane died during spawn"),
        }
    }

    fn submit(&mut self, epoch: usize, snap: SharedSnapshot) -> anyhow::Result<()> {
        self.cmd_tx
            .as_ref()
            .expect("lane alive until drop")
            .send((epoch, snap))
            .map_err(|_| anyhow::anyhow!("service lane died"))?;
        self.pending += 1;
        Ok(())
    }

    /// Non-blocking: append every completed job so far (in submission
    /// order) to `out`.
    fn collect_ready(&mut self, out: &mut Vec<ServiceEvent>) -> anyhow::Result<()> {
        loop {
            match self.reply_rx.try_recv() {
                Ok(LaneReply::Done(ev)) => {
                    self.pending -= 1;
                    out.push(ev);
                }
                Ok(LaneReply::Fail(e)) => anyhow::bail!("service lane job failed: {e}"),
                Ok(LaneReply::Ready) => anyhow::bail!("service lane: duplicate ready"),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    anyhow::ensure!(
                        self.pending == 0,
                        "service lane died with {} jobs in flight",
                        self.pending
                    );
                    break;
                }
            }
        }
        Ok(())
    }

    /// Blocking: wait out every outstanding job, appending to `out`.
    fn drain_into(&mut self, out: &mut Vec<ServiceEvent>) -> anyhow::Result<()> {
        self.collect_ready(out)?;
        while self.pending > 0 {
            match self.reply_rx.recv() {
                Ok(LaneReply::Done(ev)) => {
                    self.pending -= 1;
                    out.push(ev);
                }
                Ok(LaneReply::Fail(e)) => anyhow::bail!("service lane job failed: {e}"),
                Ok(LaneReply::Ready) => anyhow::bail!("service lane: duplicate ready"),
                Err(_) => {
                    anyhow::bail!("service lane died with {} jobs in flight", self.pending)
                }
            }
        }
        Ok(())
    }
}

impl Drop for ServiceWorker {
    fn drop(&mut self) {
        drop(self.cmd_tx.take()); // disconnect: worker_main's recv loop exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Worker thread body: run the handler init locally, then serve jobs
/// until the owner drops the command channel.  A failed job becomes a
/// named [`ServiceEvent::Error`] and the lane keeps serving — only init
/// failures kill the thread.
fn worker_main(
    kind: ServiceLaneKind,
    init: HandlerInit,
    cmd_rx: Receiver<(usize, SharedSnapshot)>,
    reply_tx: Sender<LaneReply>,
) {
    let mut handler = match init() {
        Ok(h) => h,
        Err(e) => {
            let _ = reply_tx.send(LaneReply::Fail(e.to_string()));
            return;
        }
    };
    if reply_tx.send(LaneReply::Ready).is_err() {
        return;
    }
    while let Ok((epoch, snap)) = cmd_rx.recv() {
        let t = Timer::start();
        let reply = match handler(epoch, snap) {
            Ok(ev) => LaneReply::Done(ev),
            Err(e) => LaneReply::Done(ServiceEvent::Error {
                epoch,
                lane: kind,
                message: e.to_string(),
                secs: t.elapsed_s(),
            }),
        };
        if reply_tx.send(reply).is_err() {
            return;
        }
    }
}

/// The split service lanes: a persistent **eval lane** (own executor
/// replica, consumes params-tier snapshots) and an independent
/// **checkpoint lane** (no replica, consumes full-state snapshots), each
/// with its own FIFO queue, running while the primary trains the next
/// epoch.
///
/// Dropping the lanes closes both command channels; the threads drain
/// their in-flight jobs and exit, and `Drop` joins them.
pub struct ServiceLanes {
    eval: ServiceWorker,
    checkpoint: Option<ServiceWorker>,
}

impl ServiceLanes {
    /// Spawn the lanes.  The eval replica builds on its lane thread
    /// (blocking this call until ready, so build failures surface here);
    /// the checkpoint lane spawns only when a `writer` is configured.
    /// `val` is the validation set the eval lane walks; `batch` the
    /// device batch size.
    pub fn spawn(
        build: ReplicaBuilder,
        val: Dataset,
        batch: usize,
        writer: Option<CheckpointWriter>,
    ) -> anyhow::Result<Self> {
        let eval = ServiceWorker::spawn(
            ServiceLaneKind::Eval,
            Box::new(move || {
                let mut replica = build()?;
                let mut asm = BatchAssembler::new(&val, batch);
                let eval_idx: Vec<u32> = (0..val.n as u32).collect();
                Ok(Box::new(move |epoch: usize, snap: SharedSnapshot| {
                    run_eval(replica.as_mut(), &val, &eval_idx, &mut asm, epoch, &snap)
                }) as JobHandler)
            }),
        )?;
        let checkpoint = match writer {
            Some(w) => Some(ServiceWorker::spawn(
                ServiceLaneKind::Checkpoint,
                Box::new(move || {
                    Ok(Box::new(move |epoch: usize, snap: SharedSnapshot| {
                        let t = Timer::start();
                        let stats = w(snap, epoch)?;
                        Ok(ServiceEvent::Checkpoint { epoch, secs: t.elapsed_s(), stats })
                    }) as JobHandler)
                }),
            )?),
            None => None,
        };
        Ok(ServiceLanes { eval, checkpoint })
    }

    /// Queue a validation eval of `snap` for `epoch` on the eval lane
    /// (returns immediately; the result arrives as a
    /// [`ServiceEvent::Eval`]).  Any tier is accepted — the lane reads
    /// only the parameter section.
    pub fn submit_eval(&mut self, epoch: usize, snap: SharedSnapshot) -> anyhow::Result<()> {
        self.eval.submit(epoch, snap)
    }

    /// Queue checkpoint serialization of `snap` for `epoch` on the
    /// checkpoint lane.  Rejects params-only snapshots (a checkpoint
    /// without momentum could not resume bit-exactly) and configurations
    /// without a writer.  The tier is the only engine-level validation;
    /// writer-specific requirements (e.g. `save_snapshot` demanding a
    /// momentum section from momentum backends) surface as lane errors
    /// at the next barrier.
    pub fn submit_checkpoint(
        &mut self,
        epoch: usize,
        snap: SharedSnapshot,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            snap.tier() >= SnapshotTier::Full,
            "checkpoint needs a full-state snapshot, got the {} tier",
            snap.tier().name()
        );
        self.checkpoint
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("checkpoint submitted but no writer configured"))?
            .submit(epoch, snap)
    }

    /// Jobs submitted but not yet folded back, across both lanes.
    pub fn pending(&self) -> usize {
        self.eval.pending + self.checkpoint.as_ref().map_or(0, |c| c.pending)
    }

    /// Non-blocking: collect every job that has completed so far on
    /// either lane, merged into fold-in order
    /// (`(epoch, eval-before-checkpoint)`).
    pub fn try_events(&mut self) -> anyhow::Result<Vec<ServiceEvent>> {
        let mut out = Vec::new();
        self.eval.collect_ready(&mut out)?;
        if let Some(ckpt) = self.checkpoint.as_mut() {
            ckpt.collect_ready(&mut out)?;
        }
        out.sort_by_key(ServiceEvent::fold_key);
        Ok(out)
    }

    /// Blocking: wait for every submitted job on both lanes; returns all
    /// events (including already-completed ones) in fold-in order.
    pub fn drain(&mut self) -> anyhow::Result<Vec<ServiceEvent>> {
        let mut out = Vec::new();
        self.eval.drain_into(&mut out)?;
        if let Some(ckpt) = self.checkpoint.as_mut() {
            ckpt.drain_into(&mut out)?;
        }
        out.sort_by_key(ServiceEvent::fold_key);
        Ok(out)
    }
}

/// One full validation pass on the eval-lane replica: import the
/// snapshot's parameter section, then walk the validation order in batch
/// chunks through the *same* [`EvalSink::accumulate`] fold the
/// synchronous engine path uses, so the result is bitwise identical to
/// sync eval by construction.
fn run_eval(
    replica: &mut dyn ReplicaBackend,
    val: &Dataset,
    eval_idx: &[u32],
    asm: &mut BatchAssembler,
    epoch: usize,
    snap: &SharedSnapshot,
) -> anyhow::Result<ServiceEvent> {
    let t = Timer::start();
    // params-only restore: whichever tier rode along, the forward pass
    // reads only the parameter section (momentum never feeds an eval)
    replica.import_params(snap.params())?;
    let mut sink = EvalSink::default();
    for chunk in eval_idx.chunks(asm.batch) {
        asm.fill(val, chunk, None);
        let stats = replica.fwd_stats(&asm.x, &asm.y)?;
        sink.accumulate(asm.real, &stats);
    }
    let (acc, loss) = sink.result();
    Ok(ServiceEvent::Eval { epoch, acc, loss, secs: t.elapsed_s() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::data::synth::{gauss_mixture, GaussMixtureCfg};
    use crate::engine::snapshot::Snapshot;
    use crate::engine::testbed::MockBackend;
    use crate::engine::DataParallel;

    const B: usize = 8;

    fn tiny_val(n: usize) -> Dataset {
        gauss_mixture(
            &GaussMixtureCfg { n_train: 8, n_val: n, dim: 6, classes: 3, ..Default::default() },
            7,
        )
        .val
    }

    fn params_snap(param: f32) -> SharedSnapshot {
        Arc::new(Snapshot::params_only(vec![vec![param]]))
    }

    fn full_snap(param: f32) -> SharedSnapshot {
        Arc::new(Snapshot::full(vec![vec![param]], None))
    }

    #[test]
    fn events_come_back_in_submission_order() {
        let be = MockBackend::new();
        let mut lanes =
            ServiceLanes::spawn(be.replica_builder().unwrap(), tiny_val(21), B, None).unwrap();
        for epoch in 0..5 {
            lanes.submit_eval(epoch, params_snap(1.0 + epoch as f32 * 0.25)).unwrap();
        }
        assert_eq!(lanes.pending(), 5);
        let events = lanes.drain().unwrap();
        assert_eq!(lanes.pending(), 0);
        let epochs: Vec<usize> = events.iter().map(|e| e.epoch()).collect();
        assert_eq!(epochs, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn eval_uses_the_submitted_snapshot_not_the_spawn_state() {
        let be = MockBackend::new();
        let val = tiny_val(13);
        let mut lanes =
            ServiceLanes::spawn(be.replica_builder().unwrap(), val.clone(), B, None).unwrap();
        // same snapshot twice => bitwise-identical results
        lanes.submit_eval(0, params_snap(0.5)).unwrap();
        lanes.submit_eval(1, params_snap(0.5)).unwrap();
        // a different snapshot => different forward stats
        lanes.submit_eval(2, params_snap(2.5)).unwrap();
        let events = lanes.drain().unwrap();
        let losses: Vec<f64> = events
            .iter()
            .map(|e| match e {
                ServiceEvent::Eval { loss, .. } => *loss,
                other => panic!("unexpected event {other:?}"),
            })
            .collect();
        assert_eq!(losses[0].to_bits(), losses[1].to_bits());
        assert_ne!(losses[0].to_bits(), losses[2].to_bits());
    }

    /// The params-only tier and the full tier evaluate bitwise
    /// identically — the eval lane reads only the parameter section.
    #[test]
    fn params_tier_eval_matches_full_tier_eval() {
        let be = MockBackend::new();
        let mut lanes =
            ServiceLanes::spawn(be.replica_builder().unwrap(), tiny_val(17), B, None).unwrap();
        lanes.submit_eval(0, params_snap(1.75)).unwrap();
        lanes.submit_eval(1, full_snap(1.75)).unwrap();
        let events = lanes.drain().unwrap();
        let bits: Vec<(u64, u64)> = events
            .iter()
            .map(|e| match e {
                ServiceEvent::Eval { acc, loss, .. } => (acc.to_bits(), loss.to_bits()),
                other => panic!("unexpected event {other:?}"),
            })
            .collect();
        assert_eq!(bits[0], bits[1]);
    }

    #[test]
    fn checkpoint_jobs_call_the_writer() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let calls = Arc::new(AtomicUsize::new(0));
        let seen = calls.clone();
        let writer: CheckpointWriter = Box::new(move |snap, epoch| {
            anyhow::ensure!(
                snap.params().len() == 1 && epoch == 3,
                "wrong job payload"
            );
            seen.fetch_add(1, Ordering::SeqCst);
            Ok(WriteStats { leaves: snap.leaves(), ..WriteStats::default() })
        });
        let be = MockBackend::new();
        let mut lanes =
            ServiceLanes::spawn(be.replica_builder().unwrap(), tiny_val(9), B, Some(writer))
                .unwrap();
        lanes.submit_checkpoint(3, full_snap(1.0)).unwrap();
        let events = lanes.drain().unwrap();
        assert!(matches!(events[0], ServiceEvent::Checkpoint { epoch: 3, .. }));
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    /// The lanes are independent queues: a checkpoint writer stalled on
    /// epoch 0 does not delay the eval lane, and the barrier merge still
    /// comes back in fold-in order.
    #[test]
    fn slow_checkpoint_does_not_block_eval_lane() {
        use std::sync::mpsc::channel;
        use std::sync::Mutex;
        let (gate_tx, gate_rx) = channel::<()>();
        let gate = Mutex::new(gate_rx);
        let writer: CheckpointWriter = Box::new(move |_snap, _epoch| {
            // block until the test releases the gate (bounded, so a
            // test failure can never wedge the lane's Drop-join)
            gate.lock()
                .unwrap()
                .recv_timeout(std::time::Duration::from_secs(60))
                .ok();
            Ok(WriteStats::default())
        });
        let be = MockBackend::new();
        let mut lanes =
            ServiceLanes::spawn(be.replica_builder().unwrap(), tiny_val(11), B, Some(writer))
                .unwrap();
        lanes.submit_checkpoint(0, full_snap(1.0)).unwrap();
        lanes.submit_eval(0, params_snap(1.0)).unwrap();
        lanes.submit_eval(1, params_snap(1.5)).unwrap();
        // evals complete while the checkpoint write is still blocked
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let mut evals = Vec::new();
        while evals.len() < 2 && std::time::Instant::now() < deadline {
            evals.extend(lanes.try_events().unwrap());
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        // release the gate BEFORE asserting: if an assertion fails and
        // unwinds, `lanes` drops (joining the checkpoint thread) while
        // the writer is already unblocked, so the test fails instead of
        // hanging
        let pending_with_ckpt_in_flight = lanes.pending();
        gate_tx.send(()).unwrap();
        assert_eq!(evals.len(), 2, "evals blocked behind the checkpoint lane");
        assert!(evals.iter().all(|e| matches!(e, ServiceEvent::Eval { .. })));
        assert_eq!(pending_with_ckpt_in_flight, 1);
        let rest = lanes.drain().unwrap();
        assert!(matches!(rest[0], ServiceEvent::Checkpoint { epoch: 0, .. }));
        assert_eq!(lanes.pending(), 0);
    }

    /// Fold-in merge order: within an epoch, eval sorts before
    /// checkpoint (the synchronous phase order), whatever the lanes'
    /// completion timing.
    #[test]
    fn drain_merges_lanes_in_fold_in_order() {
        let writer: CheckpointWriter = Box::new(|_snap, _epoch| Ok(WriteStats::default()));
        let be = MockBackend::new();
        let mut lanes =
            ServiceLanes::spawn(be.replica_builder().unwrap(), tiny_val(9), B, Some(writer))
                .unwrap();
        // submit checkpoints first so they tend to finish first
        lanes.submit_checkpoint(0, full_snap(1.0)).unwrap();
        lanes.submit_checkpoint(2, full_snap(1.1)).unwrap();
        lanes.submit_eval(0, params_snap(1.0)).unwrap();
        lanes.submit_eval(2, params_snap(1.1)).unwrap();
        let keys: Vec<(usize, bool)> = lanes
            .drain()
            .unwrap()
            .iter()
            .map(|e| (e.epoch(), matches!(e, ServiceEvent::Checkpoint { .. })))
            .collect();
        assert_eq!(keys, vec![(0, false), (0, true), (2, false), (2, true)]);
    }

    /// Satellite: a checkpoint write error surfaces as a named
    /// [`ServiceEvent::Error`] in the fold-in stream — the lane survives
    /// and serves the next job instead of hanging or dying.
    #[test]
    fn checkpoint_write_error_is_a_named_event_and_the_lane_survives() {
        let writer: CheckpointWriter = Box::new(|_snap, epoch| {
            anyhow::ensure!(epoch != 0, "disk full writing generation {epoch}");
            Ok(WriteStats::default())
        });
        let be = MockBackend::new();
        let mut lanes =
            ServiceLanes::spawn(be.replica_builder().unwrap(), tiny_val(9), B, Some(writer))
                .unwrap();
        lanes.submit_checkpoint(0, full_snap(1.0)).unwrap();
        lanes.submit_checkpoint(1, full_snap(1.0)).unwrap();
        let events = lanes.drain().unwrap();
        assert_eq!(lanes.pending(), 0);
        match &events[0] {
            ServiceEvent::Error { epoch: 0, lane, message, .. } => {
                assert_eq!(*lane, ServiceLaneKind::Checkpoint);
                assert_eq!(lane.name(), "checkpoint");
                assert!(message.contains("disk full"), "{message}");
            }
            other => panic!("expected a checkpoint error event, got {other:?}"),
        }
        assert!(matches!(events[1], ServiceEvent::Checkpoint { epoch: 1, .. }));
    }

    /// Satellite: an eval-lane forward error surfaces as a named
    /// [`ServiceEvent::Error`] tagged with the eval lane, not a hang.
    #[test]
    fn eval_job_error_is_a_named_event() {
        struct BrokenEval;
        impl StepBackend for BrokenEval {
            fn train_step(
                &mut self,
                _x: &[f32],
                _y: &[i32],
                _sw: &[f32],
                _lr: f32,
            ) -> anyhow::Result<crate::runtime::BatchStats> {
                anyhow::bail!("device lost")
            }
            fn fwd_stats(
                &mut self,
                _x: &[f32],
                _y: &[i32],
            ) -> anyhow::Result<crate::runtime::BatchStats> {
                anyhow::bail!("device lost")
            }
        }
        impl StateExchange for BrokenEval {
            fn export_state(&self) -> anyhow::Result<Vec<Vec<f32>>> {
                Ok(vec![vec![0.0]])
            }
            fn import_state(&mut self, _state: &[Vec<f32>]) -> anyhow::Result<()> {
                Ok(())
            }
        }
        let build: ReplicaBuilder = Box::new(|| Ok(Box::new(BrokenEval)));
        let mut lanes = ServiceLanes::spawn(build, tiny_val(9), B, None).unwrap();
        lanes.submit_eval(4, params_snap(1.0)).unwrap();
        let events = lanes.drain().unwrap();
        match &events[0] {
            ServiceEvent::Error { epoch: 4, lane: ServiceLaneKind::Eval, message, .. } => {
                assert!(message.contains("device lost"), "{message}");
            }
            other => panic!("expected an eval error event, got {other:?}"),
        }
    }

    #[test]
    fn checkpoint_without_writer_is_an_error() {
        let be = MockBackend::new();
        let mut lanes =
            ServiceLanes::spawn(be.replica_builder().unwrap(), tiny_val(9), B, None).unwrap();
        assert!(lanes.submit_checkpoint(0, full_snap(1.0)).is_err());
    }

    /// The type system's tier guarantee at the queue boundary: a
    /// params-only snapshot can never reach the checkpoint writer.
    #[test]
    fn params_only_checkpoint_rejected_at_submit() {
        let writer: CheckpointWriter = Box::new(|_snap, _epoch| Ok(WriteStats::default()));
        let be = MockBackend::new();
        let mut lanes =
            ServiceLanes::spawn(be.replica_builder().unwrap(), tiny_val(9), B, Some(writer))
                .unwrap();
        let err = lanes.submit_checkpoint(0, params_snap(1.0)).unwrap_err();
        assert!(err.to_string().contains("full-state"), "{err}");
        assert_eq!(lanes.pending(), 0);
    }

    #[test]
    fn failed_builder_surfaces_at_spawn() {
        let build: ReplicaBuilder = Box::new(|| anyhow::bail!("no artifacts"));
        assert!(ServiceLanes::spawn(build, tiny_val(9), B, None).is_err());
    }

    #[test]
    fn empty_validation_set_is_a_noop_eval() {
        let empty = Dataset {
            name: "empty".into(),
            n: 0,
            sample_dim: 6,
            label_len: 1,
            classes: 3,
            x: vec![],
            y: vec![],
            noisy: vec![],
        };
        let be = MockBackend::new();
        let mut lanes =
            ServiceLanes::spawn(be.replica_builder().unwrap(), empty, B, None).unwrap();
        lanes.submit_eval(0, params_snap(1.0)).unwrap();
        let events = lanes.drain().unwrap();
        match &events[0] {
            ServiceEvent::Eval { acc, loss, .. } => {
                assert_eq!(*acc, 0.0);
                assert_eq!(*loss, 0.0);
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
}
