//! The async service lane: validation eval + checkpoint serialization off
//! the training critical path.
//!
//! # Why a lane, not a thread pool
//!
//! Both jobs the lane runs consume only an *immutable* exported parameter
//! snapshot ([`crate::engine::StateExchange::export_state`]), so nothing
//! about them has to block the next epoch: the primary executor can start
//! training epoch `e+1` the moment epoch `e`'s state is exported.  But the
//! production backend's device state is not `Send` (PJRT literals, a
//! client handle), so the lane cannot borrow the primary executor.  It
//! instead follows the exact replica contract the worker pool's replica
//! lanes established in the data-parallel path (`engine/pool.rs`):
//! a `Send` [`ReplicaBuilder`] is shipped into one persistent
//! background thread, which *builds* its own replica there (own PJRT
//! client, own compiled executables) and owns it for the lane's whole
//! life.  Snapshots cross the channel as `Send` host tensors.
//!
//! # Determinism contract
//!
//! The lane evaluates an **exact** snapshot: the export/import round-trip
//! preserves every f32 bit pattern, the replica runs the same compiled
//! artifacts, and the lane walks the validation set in the same batch
//! order with the same [`BatchAssembler`] fill and the same accumulation
//! order as the synchronous [`crate::engine::EvalSink`] path.  Async eval
//! is therefore bitwise identical to sync eval — enforced by
//! `rust/tests/service_lane_determinism.rs`.  Because the lane is a single
//! FIFO worker, completed events always come back in submission order
//! (fixed epoch order), which is what lets the coordinator fold results
//! into epoch records deterministically.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;

use super::backend::{ReplicaBackend, ReplicaBuilder};
use super::modes::EvalSink;
use crate::data::batch::BatchAssembler;
use crate::data::Dataset;
use crate::util::timer::Timer;

/// An immutable full-state snapshot (params + optimizer state, the
/// [`crate::engine::StateExchange::export_state`] layout) shared between
/// the coordinator and the service lane without copying.
pub type StateSnapshot = Arc<Vec<Vec<f32>>>;

/// A `Send` closure that serializes one state snapshot as a checkpoint for
/// the given epoch.  The coordinator constructs it from the runtime's
/// checkpoint writer plus the executor's parameter metadata, so the engine
/// layer never depends on runtime types.
pub type CheckpointWriter = Box<dyn Fn(&[Vec<f32>], usize) -> anyhow::Result<()> + Send>;

/// Jobs the coordinator submits to the lane.
enum ServiceCmd {
    /// Run a full validation forward pass on the snapshot.
    Eval { epoch: usize, state: StateSnapshot },
    /// Serialize the snapshot through the configured [`CheckpointWriter`].
    Checkpoint { epoch: usize, state: StateSnapshot },
}

/// One completed service-lane job, returned in submission order.
#[derive(Clone, Debug)]
pub enum ServiceEvent {
    /// Validation eval finished for `epoch`.
    Eval {
        /// The epoch whose snapshot was evaluated.
        epoch: usize,
        /// Validation top-1 accuracy (bitwise identical to sync eval).
        acc: f64,
        /// Mean validation loss (bitwise identical to sync eval).
        loss: f64,
        /// Seconds the lane spent on the job (off the critical path).
        secs: f64,
    },
    /// Checkpoint serialization finished for `epoch`.
    Checkpoint {
        /// The epoch whose snapshot was serialized.
        epoch: usize,
        /// Seconds the lane spent on the job (off the critical path).
        secs: f64,
    },
}

impl ServiceEvent {
    /// The epoch the job belonged to.
    pub fn epoch(&self) -> usize {
        match self {
            ServiceEvent::Eval { epoch, .. } | ServiceEvent::Checkpoint { epoch, .. } => *epoch,
        }
    }

    /// Lane seconds the job consumed.
    pub fn secs(&self) -> f64 {
        match self {
            ServiceEvent::Eval { secs, .. } | ServiceEvent::Checkpoint { secs, .. } => *secs,
        }
    }
}

enum ServiceReply {
    /// The replica finished building; the lane accepts jobs.
    Ready,
    /// One completed job.
    Done(ServiceEvent),
    /// The lane's replica or a job failed; the lane exits.
    Fail(String),
}

/// A persistent background thread running validation evals and checkpoint
/// serialization against exported state snapshots, while the primary
/// executor trains the next epoch.
///
/// Dropping the lane closes the command channel; the thread drains any
/// in-flight jobs and exits, and `Drop` joins it.
pub struct ServiceLane {
    cmd_tx: Option<Sender<ServiceCmd>>,
    reply_rx: Receiver<ServiceReply>,
    handle: Option<std::thread::JoinHandle<()>>,
    pending: usize,
}

impl ServiceLane {
    /// Spawn the lane: the replica builds on the lane thread (blocking
    /// this call until it is ready, so spawn failures surface here and
    /// every later submit is cheap).  `val` is the validation set the lane
    /// evaluates; `batch` the device batch size; `checkpoint` the optional
    /// snapshot serializer (checkpoint jobs fail without one).
    pub fn spawn(
        build: ReplicaBuilder,
        val: Dataset,
        batch: usize,
        checkpoint: Option<CheckpointWriter>,
    ) -> anyhow::Result<Self> {
        let (cmd_tx, cmd_rx) = channel::<ServiceCmd>();
        let (reply_tx, reply_rx) = channel::<ServiceReply>();
        let handle = std::thread::Builder::new()
            .name("service-lane".into())
            .spawn(move || service_main(build, val, batch, checkpoint, cmd_rx, reply_tx))?;
        let lane = ServiceLane { cmd_tx: Some(cmd_tx), reply_rx, handle: Some(handle), pending: 0 };
        match lane.reply_rx.recv() {
            Ok(ServiceReply::Ready) => Ok(lane),
            Ok(ServiceReply::Fail(e)) => anyhow::bail!("service lane spawn failed: {e}"),
            Ok(ServiceReply::Done(_)) => anyhow::bail!("service lane: job reply before ready"),
            Err(_) => anyhow::bail!("service lane died during spawn"),
        }
    }

    fn submit(&mut self, cmd: ServiceCmd) -> anyhow::Result<()> {
        self.cmd_tx
            .as_ref()
            .expect("lane alive until drop")
            .send(cmd)
            .map_err(|_| anyhow::anyhow!("service lane died"))?;
        self.pending += 1;
        Ok(())
    }

    /// Queue a validation eval of `state` for `epoch` (returns
    /// immediately; the result arrives as a [`ServiceEvent::Eval`]).
    pub fn submit_eval(&mut self, epoch: usize, state: StateSnapshot) -> anyhow::Result<()> {
        self.submit(ServiceCmd::Eval { epoch, state })
    }

    /// Queue checkpoint serialization of `state` for `epoch`.
    pub fn submit_checkpoint(&mut self, epoch: usize, state: StateSnapshot) -> anyhow::Result<()> {
        self.submit(ServiceCmd::Checkpoint { epoch, state })
    }

    /// Jobs submitted but not yet folded back.
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Non-blocking: collect every job that has completed so far, in
    /// submission (fixed epoch) order.
    pub fn try_events(&mut self) -> anyhow::Result<Vec<ServiceEvent>> {
        let mut out = Vec::new();
        loop {
            match self.reply_rx.try_recv() {
                Ok(ServiceReply::Done(ev)) => {
                    self.pending -= 1;
                    out.push(ev);
                }
                Ok(ServiceReply::Fail(e)) => anyhow::bail!("service lane job failed: {e}"),
                Ok(ServiceReply::Ready) => anyhow::bail!("service lane: duplicate ready"),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    anyhow::ensure!(
                        self.pending == 0,
                        "service lane died with {} jobs in flight",
                        self.pending
                    );
                    break;
                }
            }
        }
        Ok(out)
    }

    /// Blocking: wait for every submitted job to complete; returns all
    /// events (including already-completed ones) in submission order.
    pub fn drain(&mut self) -> anyhow::Result<Vec<ServiceEvent>> {
        let mut out = self.try_events()?;
        while self.pending > 0 {
            match self.reply_rx.recv() {
                Ok(ServiceReply::Done(ev)) => {
                    self.pending -= 1;
                    out.push(ev);
                }
                Ok(ServiceReply::Fail(e)) => anyhow::bail!("service lane job failed: {e}"),
                Ok(ServiceReply::Ready) => anyhow::bail!("service lane: duplicate ready"),
                Err(_) => anyhow::bail!(
                    "service lane died with {} jobs in flight",
                    self.pending
                ),
            }
        }
        Ok(out)
    }
}

impl Drop for ServiceLane {
    fn drop(&mut self) {
        drop(self.cmd_tx.take()); // disconnect: service_main's recv loop exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Lane thread body: build the replica locally, then serve jobs until the
/// coordinator drops the command channel.
fn service_main(
    build: ReplicaBuilder,
    val: Dataset,
    batch: usize,
    checkpoint: Option<CheckpointWriter>,
    cmd_rx: Receiver<ServiceCmd>,
    reply_tx: Sender<ServiceReply>,
) {
    let mut replica = match build() {
        Ok(r) => r,
        Err(e) => {
            let _ = reply_tx.send(ServiceReply::Fail(format!("replica build: {e}")));
            return;
        }
    };
    let mut asm = BatchAssembler::new(&val, batch);
    let eval_idx: Vec<u32> = (0..val.n as u32).collect();
    if reply_tx.send(ServiceReply::Ready).is_err() {
        return;
    }
    while let Ok(cmd) = cmd_rx.recv() {
        let result = match cmd {
            ServiceCmd::Eval { epoch, state } => {
                run_eval(replica.as_mut(), &val, &eval_idx, &mut asm, epoch, &state)
            }
            ServiceCmd::Checkpoint { epoch, state } => {
                let t = Timer::start();
                match &checkpoint {
                    Some(w) => w(&state, epoch)
                        .map(|()| ServiceEvent::Checkpoint { epoch, secs: t.elapsed_s() }),
                    None => Err(anyhow::anyhow!(
                        "checkpoint submitted but no writer configured"
                    )),
                }
            }
        };
        let reply = match result {
            Ok(ev) => ServiceReply::Done(ev),
            Err(e) => {
                let _ = reply_tx.send(ServiceReply::Fail(e.to_string()));
                return;
            }
        };
        if reply_tx.send(reply).is_err() {
            return;
        }
    }
}

/// One full validation pass on the replica: import the snapshot, then walk
/// the validation order in batch chunks through the *same*
/// [`EvalSink::accumulate`] fold the synchronous engine path uses, so the
/// result is bitwise identical to sync eval by construction.
fn run_eval(
    replica: &mut dyn ReplicaBackend,
    val: &Dataset,
    eval_idx: &[u32],
    asm: &mut BatchAssembler,
    epoch: usize,
    state: &StateSnapshot,
) -> anyhow::Result<ServiceEvent> {
    let t = Timer::start();
    replica.import_state(state)?;
    let mut sink = EvalSink::default();
    for chunk in eval_idx.chunks(asm.batch) {
        asm.fill(val, chunk, None);
        let stats = replica.fwd_stats(&asm.x, &asm.y)?;
        sink.accumulate(asm.real, &stats);
    }
    let (acc, loss) = sink.result();
    Ok(ServiceEvent::Eval { epoch, acc, loss, secs: t.elapsed_s() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gauss_mixture, GaussMixtureCfg};
    use crate::engine::testbed::MockBackend;
    use crate::engine::DataParallel;

    const B: usize = 8;

    fn tiny_val(n: usize) -> Dataset {
        gauss_mixture(
            &GaussMixtureCfg { n_train: 8, n_val: n, dim: 6, classes: 3, ..Default::default() },
            7,
        )
        .val
    }

    fn snapshot(param: f32) -> StateSnapshot {
        Arc::new(vec![vec![param]])
    }

    #[test]
    fn events_come_back_in_submission_order() {
        let be = MockBackend::new();
        let mut lane =
            ServiceLane::spawn(be.replica_builder().unwrap(), tiny_val(21), B, None).unwrap();
        for epoch in 0..5 {
            lane.submit_eval(epoch, snapshot(1.0 + epoch as f32 * 0.25)).unwrap();
        }
        assert_eq!(lane.pending(), 5);
        let events = lane.drain().unwrap();
        assert_eq!(lane.pending(), 0);
        let epochs: Vec<usize> = events.iter().map(|e| e.epoch()).collect();
        assert_eq!(epochs, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn eval_uses_the_submitted_snapshot_not_the_spawn_state() {
        let be = MockBackend::new();
        let val = tiny_val(13);
        let mut lane =
            ServiceLane::spawn(be.replica_builder().unwrap(), val.clone(), B, None).unwrap();
        // same snapshot twice => bitwise-identical results
        lane.submit_eval(0, snapshot(0.5)).unwrap();
        lane.submit_eval(1, snapshot(0.5)).unwrap();
        // a different snapshot => different forward stats
        lane.submit_eval(2, snapshot(2.5)).unwrap();
        let events = lane.drain().unwrap();
        let losses: Vec<f64> = events
            .iter()
            .map(|e| match e {
                ServiceEvent::Eval { loss, .. } => *loss,
                other => panic!("unexpected event {other:?}"),
            })
            .collect();
        assert_eq!(losses[0].to_bits(), losses[1].to_bits());
        assert_ne!(losses[0].to_bits(), losses[2].to_bits());
    }

    #[test]
    fn checkpoint_jobs_call_the_writer() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let calls = Arc::new(AtomicUsize::new(0));
        let seen = calls.clone();
        let writer: CheckpointWriter = Box::new(move |state, epoch| {
            anyhow::ensure!(state.len() == 1 && epoch == 3, "wrong job payload");
            seen.fetch_add(1, Ordering::SeqCst);
            Ok(())
        });
        let be = MockBackend::new();
        let mut lane =
            ServiceLane::spawn(be.replica_builder().unwrap(), tiny_val(9), B, Some(writer))
                .unwrap();
        lane.submit_checkpoint(3, snapshot(1.0)).unwrap();
        let events = lane.drain().unwrap();
        assert!(matches!(events[0], ServiceEvent::Checkpoint { epoch: 3, .. }));
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn checkpoint_without_writer_is_an_error() {
        let be = MockBackend::new();
        let mut lane =
            ServiceLane::spawn(be.replica_builder().unwrap(), tiny_val(9), B, None).unwrap();
        lane.submit_checkpoint(0, snapshot(1.0)).unwrap();
        assert!(lane.drain().is_err());
    }

    #[test]
    fn failed_builder_surfaces_at_spawn() {
        let build: ReplicaBuilder = Box::new(|| anyhow::bail!("no artifacts"));
        assert!(ServiceLane::spawn(build, tiny_val(9), B, None).is_err());
    }

    #[test]
    fn empty_validation_set_is_a_noop_eval() {
        let empty = Dataset {
            name: "empty".into(),
            n: 0,
            sample_dim: 6,
            label_len: 1,
            classes: 3,
            x: vec![],
            y: vec![],
            noisy: vec![],
        };
        let be = MockBackend::new();
        let mut lane =
            ServiceLane::spawn(be.replica_builder().unwrap(), empty, B, None).unwrap();
        lane.submit_eval(0, snapshot(1.0)).unwrap();
        let events = lane.drain().unwrap();
        match &events[0] {
            ServiceEvent::Eval { acc, loss, .. } => {
                assert_eq!(*acc, 0.0);
                assert_eq!(*loss, 0.0);
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
}
