//! Minimal HTTP/1.1 wire handling for the inference endpoints.
//!
//! Just enough protocol for `curl` and the serving test battery: parse
//! one request (method, path, headers, `Content-Length`-framed body),
//! write one JSON response.  Connections are **keep-alive** by default
//! (HTTP/1.1 semantics) so a client hammering `/v1/stats` doesn't pay a
//! TCP handshake per query — the caller loops request/response on one
//! stream and honors the parsed [`Request::keep_alive`] flag, closing
//! on `Connection: close`, HTTP/1.0 without `keep-alive`, or its own
//! requests-per-connection bound.  No pipelining, no chunked encoding,
//! no TLS — the lane serves JSON over plain sockets behind whatever
//! front end the deployment puts in front of it.
//!
//! Everything read off the socket is untrusted: the request line and
//! header block are size-capped, the body length is bounded, and
//! malformed framing returns an error (the caller answers 400 and
//! closes) instead of panicking or reading unbounded memory.

use std::io::{Read, Write};
use std::net::TcpStream;

/// Header block cap: a request line + headers larger than this is
/// rejected outright.
const MAX_HEAD: usize = 16 * 1024;

/// Body cap (batched f32 matrices in JSON are ~10 bytes/element; this
/// admits millions of elements while bounding a hostile
/// `Content-Length`).
const MAX_BODY: usize = 64 * 1024 * 1024;

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    /// Request method (`GET`, `POST`, ...), as sent.
    pub method: String,
    /// Request path (query strings are not split off; endpoints match
    /// the full path).
    pub path: String,
    /// The request body (empty when no `Content-Length` was sent).
    pub body: Vec<u8>,
    /// Whether the client wants the connection kept open after the
    /// response: HTTP/1.1 defaults to `true`, HTTP/1.0 to `false`, and
    /// a `Connection:` header overrides either way.
    pub keep_alive: bool,
}

/// Read and parse one HTTP/1.1 request from `stream`.  Returns
/// `Ok(None)` when the client closed the connection cleanly before
/// sending any bytes — the normal end of a keep-alive session, not an
/// error.
pub fn read_request(stream: &mut TcpStream) -> anyhow::Result<Option<Request>> {
    // read until the end of the header block
    let mut head = Vec::new();
    let mut buf = [0u8; 1024];
    let split = loop {
        if let Some(pos) = find_head_end(&head) {
            break pos;
        }
        anyhow::ensure!(head.len() <= MAX_HEAD, "header block exceeds {MAX_HEAD} bytes");
        let n = stream.read(&mut buf)?;
        if n == 0 {
            // clean close between requests is how keep-alive ends;
            // close mid-request is a framing error
            anyhow::ensure!(head.is_empty(), "connection closed mid-request");
            return Ok(None);
        }
        head.extend_from_slice(&buf[..n]);
    };
    let (head_bytes, mut rest) = {
        let (h, r) = head.split_at(split);
        (h.to_vec(), r[4..].to_vec()) // skip the \r\n\r\n
    };
    let head_str = std::str::from_utf8(&head_bytes)
        .map_err(|_| anyhow::anyhow!("non-utf8 request head"))?;
    let mut lines = head_str.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    let version = parts.next().unwrap_or_default();
    anyhow::ensure!(
        !method.is_empty() && path.starts_with('/'),
        "malformed request line {request_line:?}"
    );
    let mut content_length = 0usize;
    // persistence default by protocol version; `Connection:` overrides
    let mut keep_alive = !version.eq_ignore_ascii_case("HTTP/1.0");
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            let name = name.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad content-length {value:?}"))?;
            } else if name.eq_ignore_ascii_case("connection") {
                for token in value.split(',') {
                    let token = token.trim();
                    if token.eq_ignore_ascii_case("close") {
                        keep_alive = false;
                    } else if token.eq_ignore_ascii_case("keep-alive") {
                        keep_alive = true;
                    }
                }
            }
        }
    }
    anyhow::ensure!(content_length <= MAX_BODY, "body exceeds {MAX_BODY} bytes");
    // the body: whatever arrived behind the head, then the remainder.
    // Bytes past the declared length would be a pipelined next request —
    // unsupported, so reject them rather than silently corrupt framing.
    anyhow::ensure!(rest.len() <= content_length, "body longer than content-length");
    let mut body = Vec::with_capacity(content_length);
    body.append(&mut rest);
    while body.len() < content_length {
        let want = (content_length - body.len()).min(buf.len());
        let n = stream.read(&mut buf[..want])?;
        anyhow::ensure!(n > 0, "connection closed mid-body");
        body.extend_from_slice(&buf[..n]);
    }
    Ok(Some(Request { method, path, body, keep_alive }))
}

fn find_head_end(bytes: &[u8]) -> Option<usize> {
    bytes.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Write one JSON response and flush.  `keep_alive` selects the
/// `Connection:` header: `true` invites the client to reuse the stream,
/// `false` announces the caller will drop it after this response.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    body: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    };
    let connection = if keep_alive { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {connection}\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Round-trip one request through a real socket pair.
    fn roundtrip(raw: &[u8]) -> anyhow::Result<Option<Request>> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_vec();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&raw).unwrap();
        });
        let (mut stream, _) = listener.accept().unwrap();
        let req = read_request(&mut stream);
        writer.join().unwrap();
        req
    }

    #[test]
    fn parses_post_with_body() {
        let req = roundtrip(
            b"POST /v1/stats HTTP/1.1\r\nHost: x\r\nContent-Length: 9\r\n\r\n{\"x\":[1]}",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/stats");
        assert_eq!(req.body, b"{\"x\":[1]}");
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn parses_get_without_body() {
        let req = roundtrip(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.body.is_empty());
        assert!(req.keep_alive);
    }

    #[test]
    fn connection_header_and_version_pick_persistence() {
        // HTTP/1.1 + Connection: close -> close
        let req = roundtrip(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive);
        // HTTP/1.0 defaults to close...
        let req = roundtrip(b"GET / HTTP/1.0\r\nHost: x\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive);
        // ...unless the client asks to keep it open (case-insensitive,
        // token list)
        let req = roundtrip(b"GET / HTTP/1.0\r\nConnection: Keep-Alive, TE\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(req.keep_alive);
    }

    #[test]
    fn clean_close_between_requests_is_not_an_error() {
        // zero bytes then EOF: the keep-alive session ended
        assert!(roundtrip(b"").unwrap().is_none());
    }

    #[test]
    fn rejects_malformed_framing() {
        assert!(roundtrip(b"\r\n\r\n").is_err());
        assert!(roundtrip(b"GET\r\n\r\n").is_err());
        assert!(roundtrip(b"POST /x HTTP/1.1\r\nContent-Length: zap\r\n\r\n").is_err());
        // close mid-request-line (bytes arrived, then EOF) is an error,
        // unlike the clean close above
        assert!(roundtrip(b"GET /healthz HT").is_err());
        // hostile content-length far past the cap
        assert!(roundtrip(
            b"POST /x HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n"
        )
        .is_err());
        // body truncated below the declared length
        assert!(roundtrip(b"POST /x HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort").is_err());
    }
}
