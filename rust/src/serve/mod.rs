//! The online inference endpoint: HTTP/JSON over the live snapshot hub.
//!
//! This is the user-facing half of the inference lane (the replica and
//! the publication hub live in [`crate::engine::serve`]): a minimal
//! `std::net::TcpListener` server with a small worker pool, speaking
//! JSON via [`crate::util::json`] — no external dependencies.  Wired
//! through `--serve <addr>` / `--serve-threads N` (throughput knobs:
//! `--serve-replicas`, `--serve-batch`, `--serve-batch-wait-us`,
//! `--serve-retain`); see docs/serving.md for schemas and curl
//! examples.
//!
//! # Endpoints
//!
//! | Endpoint           | Method | Purpose                                     |
//! |--------------------|--------|---------------------------------------------|
//! | `/healthz`         | GET    | readiness (first snapshot published) + degradation |
//! | `/v1/snapshot`     | GET    | live publication: epoch, tier, leaf digests |
//! | `/v1/stats`        | POST   | batched forward stats (`fwd_stats`)         |
//! | `/v1/embed`        | POST   | batched features + probabilities (`fwd_embed`) |
//!
//! `POST` bodies are `{"x": [[f32; dim]; B], "y": [label; B]}` (a single
//! flat `x` row is accepted as `B = 1`).  Responses carry the epoch of
//! the publication that answered, so a client can correlate with
//! `/v1/snapshot` — and because the hub swap is atomic, that pairing is
//! never torn (`tests/inference_serving.rs`).
//!
//! # Query-path properties
//!
//! Workers read the hub's live publication with one short lock + `Arc`
//! clone, validate the payload *before* it can reach the device, and
//! hand actual forwards to the serve fleet — the [`ServeClient`] routes
//! each query to the least-loaded live replica, and with `--serve-batch
//! N > 1` the lanes coalesce concurrent queries into shared device
//! forwards.  Connections are keep-alive (bounded requests per
//! connection, the per-connection IO timeout still applies), so a
//! hammering client pays one TCP handshake, not one per query.  Float
//! transport is lossless: the JSON serializer emits
//! shortest-round-trip numbers, so served logits re-parse to the exact
//! bits the device produced.

pub mod http;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::engine::serve::{ServeClient, SnapshotHub};
use crate::jobj;
use crate::util::json::{self, Json};

/// Per-connection socket timeout: a stalled client can hold a worker at
/// most this long.
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Keep-alive bound: one connection serves at most this many requests
/// before the worker closes it, so a single client can't pin a worker
/// forever while others queue.
const MAX_REQS_PER_CONN: usize = 128;

/// The model's input/label geometry, used to validate query payloads
/// before they are submitted to the replica — a malformed client request
/// must never turn into a device error (which would degrade the lane).
#[derive(Clone, Copy, Debug)]
pub struct ServingShape {
    /// Flattened per-sample feature count (`x` row length).
    pub input_dim: usize,
    /// Number of classes (`y` entries must be in `0..classes`).
    pub classes: usize,
}

/// The HTTP front end: an accept thread feeding `--serve-threads` worker
/// threads over a shared queue.  Dropping the server shuts it down and
/// joins every thread.
pub struct InferenceServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

struct Ctx {
    hub: Arc<SnapshotHub>,
    client: ServeClient,
    shape: Option<ServingShape>,
}

impl InferenceServer {
    /// Bind `addr` (port 0 picks a free port — the bound address is
    /// reported by [`InferenceServer::addr`]) and start serving with
    /// `threads` workers.  `shape`, when known, turns client payload
    /// mistakes into 400s instead of device errors.
    pub fn start(
        addr: &str,
        threads: usize,
        hub: Arc<SnapshotHub>,
        client: ServeClient,
        shape: Option<ServingShape>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(threads >= 1, "the inference server needs at least one worker");
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("--serve {addr}: bind failed: {e}"))?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let (conn_tx, conn_rx) = channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let ctx = Arc::new(Ctx { hub, client, shape });
        let accept = {
            let shutdown = shutdown.clone();
            std::thread::Builder::new()
                .name("serve-accept".into())
                .spawn(move || accept_main(listener, conn_tx, shutdown))?
        };
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let conn_rx = conn_rx.clone();
            let ctx = ctx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_main(conn_rx, ctx))?,
            );
        }
        Ok(InferenceServer { addr: local, shutdown, accept: Some(accept), workers })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // wake the blocking accept with a dummy connection
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // the accept thread dropped conn_tx; workers drain and exit
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn accept_main(listener: TcpListener, conn_tx: Sender<TcpStream>, shutdown: Arc<AtomicBool>) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if shutdown.load(Ordering::SeqCst) {
                    return; // drops conn_tx, releasing the workers
                }
                if conn_tx.send(stream).is_err() {
                    return;
                }
            }
            Err(_) => {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
        }
    }
}

fn worker_main(conn_rx: Arc<Mutex<Receiver<TcpStream>>>, ctx: Arc<Ctx>) {
    loop {
        // hold the queue lock only for the dequeue, never during I/O
        let stream = match conn_rx.lock().unwrap().recv() {
            Ok(s) => s,
            Err(_) => return,
        };
        handle_conn(stream, &ctx);
    }
}

fn handle_conn(mut stream: TcpStream, ctx: &Ctx) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    // keep-alive loop: serve requests on this stream until the client
    // closes, asks to close, errors, or hits the per-connection bound
    for served in 0..MAX_REQS_PER_CONN {
        match http::read_request(&mut stream) {
            Ok(Some(req)) => {
                let keep = req.keep_alive && served + 1 < MAX_REQS_PER_CONN;
                let (status, body) = route(ctx, &req);
                if http::write_response(&mut stream, status, &body.to_compact(), keep).is_err() {
                    return;
                }
                if !keep {
                    return;
                }
            }
            Ok(None) => return, // clean close between requests
            Err(e) => {
                // framing error: answer 400 and drop the stream — we
                // can't trust where the next request would start
                let body = error_body(&format!("bad request: {e}"));
                let _ = http::write_response(&mut stream, 400, &body.to_compact(), false);
                return;
            }
        }
    }
}

fn route(ctx: &Ctx, req: &http::Request) -> (u16, Json) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => health(ctx),
        ("GET", "/v1/snapshot") => snapshot_info(ctx),
        ("POST", "/v1/stats") => forward(ctx, &req.body, false),
        ("POST", "/v1/embed") => forward(ctx, &req.body, true),
        (_, "/healthz" | "/v1/snapshot" | "/v1/stats" | "/v1/embed") => {
            (405, error_body("method not allowed"))
        }
        _ => (404, error_body("no such endpoint")),
    }
}

fn error_body(msg: &str) -> Json {
    jobj![("error", msg)]
}

fn health(ctx: &Ctx) -> (u16, Json) {
    match ctx.hub.latest() {
        None => (503, jobj![("status", "starting"), ("ready", false)]),
        Some(p) => {
            let status = if ctx.hub.degraded() { "degraded" } else { "ok" };
            (
                200,
                jobj![
                    ("status", status),
                    ("ready", true),
                    ("epoch", p.epoch),
                    ("lanes", ctx.hub.lanes()),
                    ("lanes_down", ctx.hub.lanes_down()),
                    ("queries", ctx.hub.queries_total()),
                    ("batches", ctx.hub.batches_total()),
                ],
            )
        }
    }
}

fn snapshot_info(ctx: &Ctx) -> (u16, Json) {
    match ctx.hub.latest() {
        None => (503, error_body("no snapshot published yet")),
        Some(p) => (
            200,
            jobj![
                ("epoch", p.epoch),
                ("tier", p.snapshot.tier().name()),
                ("leaves", p.digests.len()),
                ("digests", p.digests.clone()),
            ],
        ),
    }
}

fn forward(ctx: &Ctx, body: &[u8], embed: bool) -> (u16, Json) {
    let (x, y, batch) = match decode_batch(body, ctx.shape.as_ref()) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let Some(p) = ctx.hub.latest() else {
        return (503, error_body("no snapshot published yet"));
    };
    match ctx.client.query(p, x, y, embed) {
        Ok(ans) => {
            let mut obj = std::collections::BTreeMap::new();
            obj.insert("epoch".into(), Json::from(ans.epoch));
            obj.insert("batch".into(), Json::from(batch));
            obj.insert("loss".into(), Json::from(ans.stats.loss));
            obj.insert("correct".into(), Json::from(ans.stats.correct));
            obj.insert("conf".into(), Json::from(ans.stats.conf));
            if let Some(emb) = ans.emb {
                obj.insert("emb".into(), Json::from(emb));
            }
            if let Some(probs) = ans.probs {
                obj.insert("probs".into(), Json::from(probs));
            }
            (200, Json::Obj(obj))
        }
        Err(e) => (500, error_body(&format!("inference failed: {e}"))),
    }
}

/// Decode `{"x": ..., "y": ...}` into a flat row-major batch, validating
/// against the serving shape when one is configured.  Errors are
/// `(status, body)` responses — parse failures carry the parser's
/// line/column.
#[allow(clippy::type_complexity)]
fn decode_batch(
    body: &[u8],
    shape: Option<&ServingShape>,
) -> Result<(Vec<f32>, Vec<i32>, usize), (u16, Json)> {
    let text = std::str::from_utf8(body)
        .map_err(|_| (400, error_body("body is not utf-8")))?;
    let v = json::parse(text).map_err(|e| {
        (400, jobj![("error", format!("json: {}", e.msg)), ("line", e.line), ("col", e.col)])
    })?;
    let xs = v
        .get("x")
        .and_then(Json::as_arr)
        .ok_or_else(|| (400, error_body("missing array field \"x\"")))?;
    // nested [[...]; B] or one flat row
    let rows: Vec<&[Json]> = if xs.iter().all(|r| matches!(r, Json::Arr(_))) && !xs.is_empty() {
        xs.iter().map(|r| r.as_arr().unwrap()).collect()
    } else {
        vec![xs]
    };
    let batch = rows.len();
    let dim = rows[0].len();
    if dim == 0 {
        return Err((400, error_body("empty sample row in \"x\"")));
    }
    let mut x = Vec::with_capacity(batch * dim);
    for (i, row) in rows.iter().enumerate() {
        if row.len() != dim {
            return Err((400, error_body(&format!("row {i} has {} values, row 0 has {dim}", row.len()))));
        }
        for v in *row {
            match v.as_f64() {
                Some(n) => x.push(n as f32),
                None => return Err((400, error_body("non-numeric value in \"x\""))),
            }
        }
    }
    let ys = v
        .get("y")
        .and_then(Json::as_arr)
        .ok_or_else(|| (400, error_body("missing array field \"y\"")))?;
    if ys.len() != batch {
        return Err((
            400,
            error_body(&format!("\"y\" has {} labels for {batch} samples", ys.len())),
        ));
    }
    let mut y = Vec::with_capacity(batch);
    for v in ys {
        match v.as_f64() {
            Some(n) if n.fract() == 0.0 => y.push(n as i32),
            _ => return Err((400, error_body("non-integer label in \"y\""))),
        }
    }
    if let Some(s) = shape {
        if dim != s.input_dim {
            return Err((
                400,
                error_body(&format!("sample rows have {dim} values, model expects {}", s.input_dim)),
            ));
        }
        if let Some(bad) = y.iter().find(|&&l| l < 0 || l as usize >= s.classes) {
            return Err((
                400,
                error_body(&format!("label {bad} outside 0..{}", s.classes)),
            ));
        }
    }
    Ok((x, y, batch))
}

/// A tiny blocking HTTP client for the serving endpoints (tests, CI
/// smoke, examples): one request, one `(status, body)` back.
pub fn http_request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> anyhow::Result<(u16, String)> {
    use std::io::{Read, Write};
    let mut stream = TcpStream::connect_timeout(&addr, IO_TIMEOUT)?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    let text = String::from_utf8_lossy(&raw);
    let (head, payload) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow::anyhow!("malformed response: {text:?}"))?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("malformed status line: {head:?}"))?;
    Ok((status, payload.to_string()))
}

/// A persistent keep-alive HTTP client: many requests over one TCP
/// connection (tests, CI smoke, hammering examples).  Responses are
/// framed by `Content-Length`, so the stream stays usable for the next
/// request.
pub struct HttpPipe {
    stream: TcpStream,
}

impl HttpPipe {
    /// Connect to a serving endpoint; the connection persists until the
    /// pipe is dropped, the server's per-connection request bound is
    /// hit, or either side closes.
    pub fn connect(addr: SocketAddr) -> anyhow::Result<Self> {
        let stream = TcpStream::connect_timeout(&addr, IO_TIMEOUT)?;
        stream.set_read_timeout(Some(IO_TIMEOUT))?;
        stream.set_write_timeout(Some(IO_TIMEOUT))?;
        Ok(HttpPipe { stream })
    }

    /// Send one request on the persistent connection and read its
    /// `(status, body)` response.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> anyhow::Result<(u16, String)> {
        use std::io::{BufRead, BufReader, Read, Write};
        let body = body.unwrap_or("");
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: pipe\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n{body}",
            body.len()
        );
        self.stream.write_all(req.as_bytes())?;
        self.stream.flush()?;
        // parse the response head line-by-line; the body is framed by
        // Content-Length (read_to_end would block on a live connection)
        let mut reader = BufReader::new(&mut self.stream);
        let mut status_line = String::new();
        anyhow::ensure!(reader.read_line(&mut status_line)? > 0, "server closed the pipe");
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("malformed status line: {status_line:?}"))?;
        let mut content_length = 0usize;
        loop {
            let mut line = String::new();
            anyhow::ensure!(reader.read_line(&mut line)? > 0, "response head truncated");
            let line = line.trim_end();
            if line.is_empty() {
                break;
            }
            if let Some((name, value)) = line.split_once(':') {
                if name.trim().eq_ignore_ascii_case("content-length") {
                    content_length = value.trim().parse()?;
                }
            }
        }
        let mut payload = vec![0u8; content_length];
        reader.read_exact(&mut payload)?;
        Ok((status, String::from_utf8_lossy(&payload).into_owned()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::serve::ServeFleet;
    use crate::engine::snapshot::Snapshot;
    use crate::engine::testbed::MockBackend;
    use crate::engine::DataParallel;

    fn server(shape: Option<ServingShape>) -> (InferenceServer, Arc<SnapshotHub>, ServeFleet) {
        let hub = Arc::new(SnapshotHub::new());
        let fleet =
            ServeFleet::spawn_single(MockBackend::new().replica_builder().unwrap(), hub.clone())
                .unwrap();
        let srv =
            InferenceServer::start("127.0.0.1:0", 2, hub.clone(), fleet.client(), shape).unwrap();
        (srv, hub, fleet)
    }

    fn publish(hub: &SnapshotHub, epoch: usize, param: f32) {
        hub.publish(epoch, Arc::new(Snapshot::params_only(vec![vec![param]])));
    }

    #[test]
    fn healthz_tracks_readiness_and_degradation() {
        let (srv, hub, _fleet) = server(None);
        let (status, body) = http_request(srv.addr(), "GET", "/healthz", None).unwrap();
        assert_eq!(status, 503, "{body}");
        publish(&hub, 0, 1.0);
        let (status, body) = http_request(srv.addr(), "GET", "/healthz", None).unwrap();
        assert_eq!(status, 200);
        let v = json::parse(&body).unwrap();
        assert_eq!(v.get("status").unwrap().as_str(), Some("ok"));
        assert_eq!(v.get("epoch").unwrap().as_usize(), Some(0));
        assert_eq!(v.get("lanes").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("lanes_down").unwrap().as_usize(), Some(0));
        assert_eq!(v.get("queries").unwrap().as_usize(), Some(0));
        assert_eq!(v.get("batches").unwrap().as_usize(), Some(0));
        hub.set_degraded(true);
        let (_, body) = http_request(srv.addr(), "GET", "/healthz", None).unwrap();
        let v = json::parse(&body).unwrap();
        assert_eq!(v.get("status").unwrap().as_str(), Some("degraded"));
    }

    #[test]
    fn snapshot_reports_epoch_tier_and_digests() {
        let (srv, hub, _fleet) = server(None);
        publish(&hub, 4, 2.5);
        let (status, body) = http_request(srv.addr(), "GET", "/v1/snapshot", None).unwrap();
        assert_eq!(status, 200);
        let v = json::parse(&body).unwrap();
        assert_eq!(v.get("epoch").unwrap().as_usize(), Some(4));
        assert_eq!(v.get("tier").unwrap().as_str(), Some("params"));
        let digests = v.get("digests").unwrap().as_arr().unwrap();
        assert_eq!(digests.len(), 1);
        assert_eq!(digests[0].as_str().unwrap().len(), 64);
    }

    #[test]
    fn stats_roundtrip_is_bitwise() {
        let (srv, hub, _fleet) = server(Some(ServingShape { input_dim: 2, classes: 3 }));
        publish(&hub, 1, 0.75);
        let (status, body) = http_request(
            srv.addr(),
            "POST",
            "/v1/stats",
            Some(r#"{"x": [[0.25, 0.5], [0.1, 0.2]], "y": [1, 2]}"#),
        )
        .unwrap();
        assert_eq!(status, 200, "{body}");
        let v = json::parse(&body).unwrap();
        assert_eq!(v.get("epoch").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("batch").unwrap().as_usize(), Some(2));
        // direct reference on an identical backend
        use crate::engine::{StateExchange, StepBackend};
        let mut direct = MockBackend::new();
        direct.import_params(&[vec![0.75]]).unwrap();
        let want = direct.fwd_stats(&[0.25, 0.5, 0.1, 0.2], &[1, 2]).unwrap();
        let got: Vec<f32> = v
            .get("loss")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|n| n.as_f64().unwrap() as f32)
            .collect();
        let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        let want_bits: Vec<u32> = want.loss.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, want_bits);
    }

    #[test]
    fn embed_returns_feature_planes() {
        let (srv, hub, _fleet) = server(None);
        publish(&hub, 0, 1.5);
        let (status, body) = http_request(
            srv.addr(),
            "POST",
            "/v1/embed",
            Some(r#"{"x": [[0.25, 0.5]], "y": [1]}"#),
        )
        .unwrap();
        assert_eq!(status, 200, "{body}");
        let v = json::parse(&body).unwrap();
        assert_eq!(v.get("emb").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("probs").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn client_mistakes_are_400s_and_never_reach_the_device() {
        let (srv, hub, mut fleet) = server(Some(ServingShape { input_dim: 2, classes: 3 }));
        publish(&hub, 0, 1.0);
        for (body, want) in [
            ("{", "json"),
            (r#"{"y": [1]}"#, "\"x\""),
            (r#"{"x": [[1.0, 2.0]]}"#, "\"y\""),
            (r#"{"x": [[1.0, 2.0]], "y": [1, 2]}"#, "labels"),
            (r#"{"x": [[1.0, 2.0], [1.0]], "y": [1, 2]}"#, "row 1"),
            (r#"{"x": [[1.0]], "y": [1]}"#, "model expects"),
            (r#"{"x": [[1.0, 2.0]], "y": [7]}"#, "outside"),
            (r#"{"x": [[1.0, 2.0]], "y": [1.5]}"#, "non-integer"),
        ] {
            let (status, resp) =
                http_request(srv.addr(), "POST", "/v1/stats", Some(body)).unwrap();
            assert_eq!(status, 400, "{body} -> {resp}");
            assert!(resp.contains(want), "{body} -> {resp}");
        }
        // none of those degraded the lane or produced fold-in errors
        assert!(!hub.degraded());
        assert!(fleet.try_events().is_empty());
        // parse errors are positioned
        let (_, resp) = http_request(srv.addr(), "POST", "/v1/stats", Some("{\n  broken")).unwrap();
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("line").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn unknown_paths_and_methods_are_named() {
        let (srv, _hub, _fleet) = server(None);
        let (status, _) = http_request(srv.addr(), "GET", "/nope", None).unwrap();
        assert_eq!(status, 404);
        let (status, _) = http_request(srv.addr(), "POST", "/healthz", None).unwrap();
        assert_eq!(status, 405);
        let (status, _) = http_request(srv.addr(), "POST", "/v1/stats", Some("{}")).unwrap();
        assert_eq!(status, 400);
    }

    #[test]
    fn queries_before_first_publication_are_503() {
        let (srv, _hub, _fleet) = server(None);
        let (status, _) = http_request(
            srv.addr(),
            "POST",
            "/v1/stats",
            Some(r#"{"x": [[1.0]], "y": [0]}"#),
        )
        .unwrap();
        assert_eq!(status, 503);
        let (status, _) = http_request(srv.addr(), "GET", "/v1/snapshot", None).unwrap();
        assert_eq!(status, 503);
    }

    #[test]
    fn keep_alive_serves_many_requests_on_one_connection() {
        let (srv, hub, _fleet) = server(Some(ServingShape { input_dim: 2, classes: 3 }));
        publish(&hub, 5, 0.75);
        // with only one worker-visible connection, every request below
        // landing a correct answer proves the stream stayed usable
        let mut pipe = HttpPipe::connect(srv.addr()).unwrap();
        for i in 0..20 {
            let (status, body) = pipe
                .request("POST", "/v1/stats", Some(r#"{"x": [[0.25, 0.5]], "y": [1]}"#))
                .unwrap();
            assert_eq!(status, 200, "request {i}: {body}");
            let v = json::parse(&body).unwrap();
            assert_eq!(v.get("epoch").unwrap().as_usize(), Some(5));
        }
        // mixed surface over the same connection: a 400 must not poison
        // the framing for the requests behind it
        let (status, _) = pipe.request("GET", "/healthz", None).unwrap();
        assert_eq!(status, 200);
        let (status, _) =
            pipe.request("POST", "/v1/stats", Some(r#"{"y": [1]}"#)).unwrap();
        assert_eq!(status, 400);
        let (status, _) = pipe.request("GET", "/v1/snapshot", None).unwrap();
        assert_eq!(status, 200);
        assert_eq!(hub.queries_total(), 20);
    }
}
