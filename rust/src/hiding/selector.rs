//! Hidden-sample selection: HE (hide lowest-loss fraction) + MB (move back
//! samples that lack a high-confidence correct prediction).  Paper §3.1,
//! boxes B.1-B.3 of Fig. 1.
//!
//! Selection is O(N) (quickselect partition around the F·N-th loss) rather
//! than the O(N log N) full sort the paper reports — the full-sort path is
//! kept behind `SelectMode::FullSort` for the overhead ablation bench.

use crate::state::SampleState;
use crate::util::stats::{argselect_smallest, argsort_by_f32};

/// Which candidate-selection algorithm picks the F·N lowest-loss samples.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SelectMode {
    /// O(N) quickselect partition (default; measured faster — see §Perf).
    QuickSelect,
    /// O(N log N) full sort (paper's description; ablation baseline).
    FullSort,
}

/// Hide/move-back selector configuration (HE + MB, paper §3.1).
#[derive(Clone, Copy, Debug)]
pub struct SelectorCfg {
    /// Prediction-confidence threshold τ for the move-back rule.
    pub tau: f32,
    /// Enable MB (move-back).  Disabled in ablation v1x0x.
    pub move_back: bool,
    /// Candidate selection algorithm.
    pub mode: SelectMode,
}

impl Default for SelectorCfg {
    fn default() -> Self {
        SelectorCfg { tau: 0.7, move_back: true, mode: SelectMode::QuickSelect }
    }
}

/// One epoch's hide/train split, plus move-back accounting.
#[derive(Clone, Debug, Default)]
pub struct Selection {
    /// Samples to hide this epoch.
    pub hidden: Vec<u32>,
    /// Samples to train on this epoch.
    pub train: Vec<u32>,
    /// Of the F·N lowest-loss candidates, how many were moved back.
    pub moved_back: usize,
    /// Effective hiding fraction F* = |hidden| / N.
    pub effective_fraction: f64,
}

/// Select the hidden set for this epoch.
///
/// `max_fraction` is the epoch's ceiling F_e (after the RF schedule).  The
/// candidates are the F_e*N samples with the lowest lagging loss; each
/// candidate is *kept hidden* only if its last prediction was correct with
/// confidence >= tau (PA & PC rule) — otherwise it is moved back to the
/// training list.
pub fn select(state: &SampleState, max_fraction: f64, cfg: &SelectorCfg) -> Selection {
    let n = state.n;
    let k = ((n as f64) * max_fraction).floor() as usize;
    let k = k.min(n);
    if k == 0 {
        return Selection {
            hidden: vec![],
            train: (0..n as u32).collect(),
            moved_back: 0,
            effective_fraction: 0.0,
        };
    }

    let candidates: Vec<u32> = match cfg.mode {
        SelectMode::QuickSelect => argselect_smallest(&state.loss, k),
        SelectMode::FullSort => argsort_by_f32(&state.loss)[..k].to_vec(),
    };

    let mut hidden = Vec::with_capacity(k);
    let mut moved_back = 0usize;
    let mut is_candidate = vec![false; n];
    for &i in &candidates {
        is_candidate[i as usize] = true;
        let keep_hidden = if cfg.move_back {
            state.high_confidence_correct(i as usize, cfg.tau)
        } else {
            true
        };
        // Unseen samples (loss = +inf) can never be candidates unless
        // F*N > number of seen samples; guard anyway.
        let keep_hidden = keep_hidden && state.loss[i as usize].is_finite();
        if keep_hidden {
            hidden.push(i);
        } else {
            moved_back += 1;
        }
    }

    let mut is_hidden = vec![false; n];
    for &i in &hidden {
        is_hidden[i as usize] = true;
    }
    let train: Vec<u32> = (0..n as u32).filter(|&i| !is_hidden[i as usize]).collect();

    Selection {
        effective_fraction: hidden.len() as f64 / n.max(1) as f64,
        moved_back,
        hidden,
        train,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state_with_losses(losses: &[f32]) -> SampleState {
        let mut s = SampleState::new(losses.len());
        for (i, &l) in losses.iter().enumerate() {
            s.record(i, l, true, 0.9, 0); // all confident-correct by default
        }
        s
    }

    #[test]
    fn hides_lowest_loss_fraction() {
        let s = state_with_losses(&[5.0, 1.0, 4.0, 0.5, 3.0, 0.1, 2.0, 6.0, 7.0, 8.0]);
        let sel = select(&s, 0.3, &SelectorCfg::default());
        let mut h = sel.hidden.clone();
        h.sort_unstable();
        assert_eq!(h, vec![1, 3, 5]); // losses 1.0, 0.5, 0.1
        assert_eq!(sel.train.len(), 7);
        assert!((sel.effective_fraction - 0.3).abs() < 1e-9);
    }

    #[test]
    fn move_back_filters_low_confidence() {
        let mut s = state_with_losses(&[0.1, 0.2, 0.3, 10.0]);
        s.record(0, 0.1, true, 0.5, 0); // low confidence -> move back
        s.record(1, 0.2, false, 0.9, 0); // mispredicted -> move back
        let sel = select(&s, 0.75, &SelectorCfg::default());
        assert_eq!(sel.hidden, vec![2]);
        assert_eq!(sel.moved_back, 2);
        let mut t = sel.train.clone();
        t.sort_unstable();
        assert_eq!(t, vec![0, 1, 3]);
    }

    #[test]
    fn move_back_disabled_hides_all_candidates() {
        let mut s = state_with_losses(&[0.1, 0.2, 0.3, 10.0]);
        s.record(0, 0.1, true, 0.5, 0);
        s.record(1, 0.2, false, 0.9, 0);
        let cfg = SelectorCfg { move_back: false, ..Default::default() };
        let sel = select(&s, 0.75, &cfg);
        let mut h = sel.hidden.clone();
        h.sort_unstable();
        assert_eq!(h, vec![0, 1, 2]);
        assert_eq!(sel.moved_back, 0);
    }

    #[test]
    fn quickselect_equals_fullsort_selection() {
        // property: the two modes hide the same *set*
        let mut s = SampleState::new(500);
        for i in 0..500 {
            let loss = ((i * 7919) % 500) as f32 / 100.0;
            let conf = if i % 3 == 0 { 0.9 } else { 0.5 };
            s.record(i, loss, i % 2 == 0, conf, 0);
        }
        for f in [0.0, 0.1, 0.3, 0.9, 1.0] {
            let a = select(&s, f, &SelectorCfg { mode: SelectMode::QuickSelect, ..Default::default() });
            let b = select(&s, f, &SelectorCfg { mode: SelectMode::FullSort, ..Default::default() });
            let mut ha = a.hidden.clone();
            let mut hb = b.hidden.clone();
            ha.sort_unstable();
            hb.sort_unstable();
            assert_eq!(ha, hb, "fraction {f}");
        }
    }

    #[test]
    fn unseen_samples_never_hidden() {
        let mut s = SampleState::new(4); // all losses +inf
        s.record(0, 0.5, true, 0.99, 0);
        let sel = select(&s, 1.0, &SelectorCfg::default());
        assert_eq!(sel.hidden, vec![0]); // only the seen sample can hide
        assert_eq!(sel.train.len(), 3);
    }

    #[test]
    fn zero_fraction_hides_nothing() {
        let s = state_with_losses(&[1.0, 2.0]);
        let sel = select(&s, 0.0, &SelectorCfg::default());
        assert!(sel.hidden.is_empty());
        assert_eq!(sel.train.len(), 2);
    }

    #[test]
    fn train_plus_hidden_partition_dataset() {
        let s = state_with_losses(&[3.0, 1.0, 2.0, 0.1, 5.0, 4.0, 0.2]);
        let sel = select(&s, 0.4, &SelectorCfg::default());
        let mut all: Vec<u32> = sel.train.iter().chain(sel.hidden.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..7).collect::<Vec<u32>>());
    }
}
