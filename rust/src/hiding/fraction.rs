//! RF: the maximum-hidden-fraction schedule (paper §3.3).
//!
//! The ceiling F_e starts at F and decays by a step schedule
//! (α = [1, 0.8, 0.6, ...] at epoch milestones), because late in training
//! most samples have similar near-zero loss and hiding a fixed fraction
//! would cut samples that still matter (Appendix C.1, Fig. 5).

/// The maximum-hidden-fraction step schedule F_e (RF component).
#[derive(Clone, Debug)]
pub struct FractionSchedule {
    /// Initial maximum hidden fraction F (e.g. 0.3).
    pub max_fraction: f64,
    /// Decay multipliers α applied from the matching milestone onward.
    pub decay: Vec<f64>,
    /// Epoch milestones (same length as `decay`).
    pub milestones: Vec<usize>,
    /// RF enabled?  When disabled (ablation v1x0x) F_e = F for all e.
    pub enabled: bool,
}

impl FractionSchedule {
    /// Paper defaults: α=[1, 0.8, 0.6] at [30%, 60%, 80%] of training
    /// (the ImageNet schedule [30, 60, 80]/100 generalized to any run
    /// length, mirroring Appendix B's per-dataset milestone tables).
    pub fn paper_default(max_fraction: f64, total_epochs: usize) -> Self {
        FractionSchedule {
            max_fraction,
            decay: vec![1.0, 0.8, 0.6],
            milestones: vec![
                0,
                (total_epochs as f64 * 0.3) as usize,
                (total_epochs as f64 * 0.6) as usize,
            ],
            enabled: true,
        }
    }

    /// A flat schedule: F_e = `max_fraction` for every epoch (RF off).
    pub fn constant(max_fraction: f64) -> Self {
        FractionSchedule {
            max_fraction,
            decay: vec![1.0],
            milestones: vec![0],
            enabled: false,
        }
    }

    /// Maximum fraction ceiling F_e for epoch e.
    pub fn at(&self, epoch: usize) -> f64 {
        if !self.enabled {
            return self.max_fraction;
        }
        let mut alpha = 1.0;
        for (m, a) in self.milestones.iter().zip(&self.decay) {
            if epoch >= *m {
                alpha = *a;
            }
        }
        self.max_fraction * alpha
    }

    /// Check ranges and milestone monotonicity.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            (0.0..1.0).contains(&self.max_fraction),
            "max_fraction must be in [0,1), got {}",
            self.max_fraction
        );
        anyhow::ensure!(self.decay.len() == self.milestones.len(), "decay/milestone length");
        anyhow::ensure!(
            self.milestones.windows(2).all(|w| w[0] < w[1]),
            "milestones must increase"
        );
        anyhow::ensure!(
            self.decay.iter().all(|&a| (0.0..=1.0).contains(&a)),
            "decay factors in [0,1]"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_steps_down() {
        let s = FractionSchedule::paper_default(0.3, 100);
        assert!((s.at(0) - 0.3).abs() < 1e-12);
        assert!((s.at(29) - 0.3).abs() < 1e-12);
        assert!((s.at(30) - 0.24).abs() < 1e-12);
        assert!((s.at(60) - 0.18).abs() < 1e-12);
        assert!((s.at(99) - 0.18).abs() < 1e-12);
    }

    #[test]
    fn monotone_nonincreasing() {
        let s = FractionSchedule::paper_default(0.4, 200);
        let mut prev = f64::INFINITY;
        for e in 0..200 {
            let f = s.at(e);
            assert!(f <= prev + 1e-12);
            prev = f;
        }
    }

    #[test]
    fn disabled_is_constant() {
        let s = FractionSchedule::constant(0.3);
        assert_eq!(s.at(0), 0.3);
        assert_eq!(s.at(1000), 0.3);
    }

    #[test]
    fn validation() {
        assert!(FractionSchedule::paper_default(0.3, 100).validate().is_ok());
        assert!(FractionSchedule::constant(1.5).validate().is_err());
        let bad = FractionSchedule {
            max_fraction: 0.3,
            decay: vec![1.0, 0.8],
            milestones: vec![10, 5],
            enabled: true,
        };
        assert!(bad.validate().is_err());
    }
}
