//! KAKURENBO §3: the hiding machinery.
//!
//! * `selector` — sort by lagging loss, cut the lowest-loss fraction, move
//!   back samples without high-confidence-correct predictions (HE + MB).
//! * `fraction` — the maximum-hidden-fraction step schedule (RF, §3.3).
//! * `lr`       — the learning-rate compensation rule (LR, Eq. 8).
//! * `droptop`  — Appendix D: additionally drop the top-loss tail.

pub mod droptop;
pub mod fraction;
pub mod lr;
pub mod selector;
