//! LR: learning-rate compensation (paper Eq. 8).
//!
//! Hiding a fraction F_e of samples removes F_e of the SGD iterations of
//! the epoch; §3.2 argues the lost progress admits sharp minima unless the
//! learning rate is scaled up by 1/(1-F_e).  The rule wraps *any* base
//! scheduler, matching the paper's claim of scheduler independence.

/// η_e = η_base,e · 1/(1 - F_e), where F_e is the *effective* hidden
/// fraction of the epoch (|hidden|/N, not the ceiling).
pub fn adjusted_lr(base_lr: f64, effective_fraction: f64) -> f64 {
    assert!(
        (0.0..1.0).contains(&effective_fraction),
        "fraction {effective_fraction} out of range"
    );
    base_lr / (1.0 - effective_fraction)
}

/// Scale factor alone (for logging / the EpochPlan).
pub fn lr_scale(effective_fraction: f64) -> f64 {
    adjusted_lr(1.0, effective_fraction)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_eq8() {
        assert!((adjusted_lr(0.1, 0.0) - 0.1).abs() < 1e-12);
        assert!((adjusted_lr(0.1, 0.3) - 0.1 / 0.7).abs() < 1e-12);
        assert!((adjusted_lr(1.0, 0.5) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn per_sample_update_mass_preserved() {
        // (N - M) steps at η/(1-F) carry the same total step mass as N at η.
        let n = 1000.0;
        for f in [0.1, 0.25, 0.4] {
            let steps = n * (1.0 - f);
            let mass = steps * adjusted_lr(0.1, f);
            assert!((mass - n * 0.1).abs() < 1e-9);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_full_hiding() {
        adjusted_lr(0.1, 1.0);
    }
}
