//! DropTop (paper Appendix D): additionally cut the highest-loss tail.
//!
//! On DeepCAM the top ~2% of samples keep a persistently high loss through
//! the final epochs (Fig. 11) — hard-to-learn or mislabeled data.  Cutting
//! them at each epoch *improved* accuracy (77.16% -> 77.37% at F=0.3).
//! DropTop composes with the main selector: it removes the top fraction
//! from the epoch's training list (they are not added to the hidden list's
//! stat-refresh pass either; their loss stays lagging, like the paper's
//! implementation which simply filters them from the batch stream).

use crate::state::SampleState;
use crate::util::stats::argselect_smallest;

/// Remove the `top_fraction` highest-loss samples from `train`.
/// Returns (kept, dropped).
pub fn drop_top(
    state: &SampleState,
    train: &[u32],
    top_fraction: f64,
) -> (Vec<u32>, Vec<u32>) {
    let k_drop = ((train.len() as f64) * top_fraction).floor() as usize;
    if k_drop == 0 {
        return (train.to_vec(), vec![]);
    }
    // Select the (len - k_drop) smallest-loss entries among `train`.
    let losses: Vec<f32> = train
        .iter()
        .map(|&i| {
            let l = state.loss[i as usize];
            if l.is_finite() { l } else { -1.0 } // unseen: never dropped
        })
        .collect();
    let keep_local = argselect_smallest(&losses, train.len() - k_drop);
    let mut keep_mask = vec![false; train.len()];
    for &li in &keep_local {
        keep_mask[li as usize] = true;
    }
    let mut kept = Vec::with_capacity(train.len() - k_drop);
    let mut dropped = Vec::with_capacity(k_drop);
    for (li, &sample) in train.iter().enumerate() {
        if keep_mask[li] {
            kept.push(sample);
        } else {
            dropped.push(sample);
        }
    }
    (kept, dropped)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state_with(losses: &[f32]) -> SampleState {
        let mut s = SampleState::new(losses.len());
        for (i, &l) in losses.iter().enumerate() {
            s.record(i, l, true, 0.9, 0);
        }
        s
    }

    #[test]
    fn drops_highest_loss() {
        let s = state_with(&[1.0, 9.0, 2.0, 8.0, 3.0]);
        let train: Vec<u32> = (0..5).collect();
        let (kept, dropped) = drop_top(&s, &train, 0.4);
        let mut d = dropped.clone();
        d.sort_unstable();
        assert_eq!(d, vec![1, 3]);
        assert_eq!(kept.len(), 3);
    }

    #[test]
    fn zero_fraction_noop() {
        let s = state_with(&[1.0, 2.0]);
        let (kept, dropped) = drop_top(&s, &[0, 1], 0.0);
        assert_eq!(kept, vec![0, 1]);
        assert!(dropped.is_empty());
    }

    #[test]
    fn unseen_samples_survive() {
        let mut s = state_with(&[1.0, 2.0, 3.0]);
        s.loss[0] = f32::INFINITY; // unseen
        let (kept, dropped) = drop_top(&s, &[0, 1, 2], 0.34);
        assert!(kept.contains(&0));
        assert_eq!(dropped, vec![2]);
    }

    #[test]
    fn partition_preserved() {
        let s = state_with(&[5.0, 1.0, 4.0, 2.0, 3.0, 0.5, 6.0]);
        let train: Vec<u32> = (0..7).collect();
        let (kept, dropped) = drop_top(&s, &train, 0.3);
        let mut all: Vec<u32> = kept.iter().chain(&dropped).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..7).collect::<Vec<u32>>());
    }
}
