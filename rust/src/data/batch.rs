//! Batch assembly: gather sample rows into contiguous host buffers ready
//! for upload as PJRT literals.
//!
//! This is on the per-step hot path, so the assembler reuses its buffers
//! across steps (no per-batch allocation) and the gather is a straight
//! memcpy per sample row.

use super::Dataset;

/// Reusable batch staging buffers.
pub struct BatchAssembler {
    /// Device batch size (slots per step).
    pub batch: usize,
    /// Row-major gathered sample data, `batch * sample_dim` elements.
    pub x: Vec<f32>,
    /// Row-major gathered labels, `batch * label_len` elements.
    pub y: Vec<i32>,
    /// Per-slot gradient weights (padding slots carry 0).
    pub sw: Vec<f32>,
    /// How many real (non-padding) samples the current batch holds.
    pub real: usize,
    /// The sample index each slot holds (padding slots carry the
    /// `u32::MAX` sentinel: not a real sample).
    pub slots: Vec<u32>,
}

impl BatchAssembler {
    /// An assembler sized for `data`'s sample layout at device batch
    /// `batch`.
    pub fn new(data: &Dataset, batch: usize) -> Self {
        BatchAssembler {
            batch,
            x: vec![0.0; batch * data.sample_dim],
            y: vec![0; batch * data.label_len],
            sw: vec![1.0; batch],
            real: 0,
            slots: vec![0; batch],
        }
    }

    /// Whether the staging buffers are sized for `data`'s sample layout.
    pub fn matches(&self, data: &Dataset) -> bool {
        self.x.len() == self.batch * data.sample_dim
            && self.y.len() == self.batch * data.label_len
    }

    /// Gather `indices` (<= batch) into the staging buffers; missing slots
    /// are padded with sample 0 and weight 0 (they contribute nothing to
    /// the weighted objective, preserving SGD semantics on ragged tails).
    pub fn fill(&mut self, data: &Dataset, indices: &[u32], weights: Option<&[f32]>) {
        assert!(indices.len() <= self.batch, "{} > {}", indices.len(), self.batch);
        let sd = data.sample_dim;
        let ll = data.label_len;
        self.real = indices.len();
        for (slot, &i) in indices.iter().enumerate() {
            let i = i as usize;
            self.x[slot * sd..(slot + 1) * sd].copy_from_slice(data.sample_x(i));
            self.y[slot * ll..(slot + 1) * ll].copy_from_slice(data.sample_y(i));
            self.sw[slot] = weights.map_or(1.0, |w| w[slot]);
            self.slots[slot] = i as u32;
        }
        for slot in indices.len()..self.batch {
            self.x[slot * sd..(slot + 1) * sd].copy_from_slice(data.sample_x(0));
            self.y[slot * ll..(slot + 1) * ll].copy_from_slice(data.sample_y(0));
            self.sw[slot] = 0.0; // padding: zero weight => zero gradient
            self.slots[slot] = u32::MAX; // sentinel: not a real sample
        }
    }
}

/// A pair of parked `BatchAssembler`s the step engine rotates between the
/// prefetch thread and the device thread.  Buffers are handed out by value
/// (they cross a channel during pipelined execution) and parked back after
/// each run, so the per-step hot path stays allocation-free across epochs,
/// refreshes, and evals.
///
/// `take` transparently re-creates a buffer when the parked one was lost
/// to an aborted run or was sized for a different dataset layout, so the
/// pool can never poison a later run.
pub struct DoubleBuffer {
    parked: Vec<BatchAssembler>,
    batch: usize,
}

impl DoubleBuffer {
    /// Two parked assemblers sized for `data` at device batch `batch`.
    pub fn new(data: &Dataset, batch: usize) -> Self {
        DoubleBuffer {
            parked: vec![BatchAssembler::new(data, batch), BatchAssembler::new(data, batch)],
            batch,
        }
    }

    /// Borrow one assembler out of the pool (sized for `data`).
    pub fn take(&mut self, data: &Dataset) -> BatchAssembler {
        while let Some(buf) = self.parked.pop() {
            if buf.matches(data) {
                return buf;
            }
        }
        BatchAssembler::new(data, self.batch)
    }

    /// Park an assembler back after a run (keeps at most two).
    pub fn put(&mut self, buf: BatchAssembler) {
        if self.parked.len() < 2 {
            self.parked.push(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gauss_mixture, GaussMixtureCfg};

    fn tiny() -> Dataset {
        gauss_mixture(
            &GaussMixtureCfg { n_train: 10, n_val: 2, dim: 4, classes: 3, ..Default::default() },
            1,
        )
        .train
    }

    #[test]
    fn gathers_rows() {
        let d = tiny();
        let mut a = BatchAssembler::new(&d, 4);
        a.fill(&d, &[3, 1, 7, 0], None);
        assert_eq!(a.real, 4);
        assert_eq!(&a.x[0..4], d.sample_x(3));
        assert_eq!(&a.x[4..8], d.sample_x(1));
        assert_eq!(a.y[2], d.label(7));
        assert!(a.sw.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn pads_ragged_tail_with_zero_weight() {
        let d = tiny();
        let mut a = BatchAssembler::new(&d, 4);
        a.fill(&d, &[5, 2], None);
        assert_eq!(a.real, 2);
        assert_eq!(a.sw, vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(&a.x[8..12], d.sample_x(0)); // padded with sample 0
        assert_eq!(a.slots[2], u32::MAX);
    }

    #[test]
    fn custom_weights() {
        let d = tiny();
        let mut a = BatchAssembler::new(&d, 3);
        a.fill(&d, &[1, 2, 3], Some(&[0.5, 2.0, 1.5]));
        assert_eq!(a.sw, vec![0.5, 2.0, 1.5]);
    }

    #[test]
    fn double_buffer_hands_back_same_allocations() {
        let d = tiny();
        let mut pool = DoubleBuffer::new(&d, 4);
        let a = pool.take(&d);
        let b = pool.take(&d);
        let (pa, pb) = (a.x.as_ptr(), b.x.as_ptr());
        pool.put(a);
        pool.put(b);
        let c = pool.take(&d);
        let e = pool.take(&d);
        let ptrs = [c.x.as_ptr(), e.x.as_ptr()];
        assert!(ptrs.contains(&pa) && ptrs.contains(&pb)); // no reallocation
    }

    #[test]
    fn double_buffer_recreates_lost_or_mismatched() {
        let d = tiny();
        let mut pool = DoubleBuffer::new(&d, 4);
        let a = pool.take(&d);
        drop(a); // "lost" to an aborted run
        let _b = pool.take(&d);
        let c = pool.take(&d); // pool empty: fresh buffer
        assert!(c.matches(&d));
        // a differently-shaped dataset forces a rebuild
        let d2 = gauss_mixture(
            &GaussMixtureCfg { n_train: 10, n_val: 2, dim: 9, classes: 3, ..Default::default() },
            2,
        )
        .train;
        let mut pool = DoubleBuffer::new(&d, 4);
        let f = pool.take(&d2);
        assert!(f.matches(&d2) && !f.matches(&d));
    }

    #[test]
    fn buffers_reused_across_fills() {
        let d = tiny();
        let mut a = BatchAssembler::new(&d, 2);
        a.fill(&d, &[1, 2], None);
        let p1 = a.x.as_ptr();
        a.fill(&d, &[3], None);
        assert_eq!(p1, a.x.as_ptr()); // no reallocation
        assert_eq!(a.real, 1);
    }
}
