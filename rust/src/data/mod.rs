//! Datasets: in-memory sample store + synthetic generators.
//!
//! The paper trains on ImageNet-1K, CIFAR-100, DeepCAM and Fractal-3K.
//! None are shippable in this offline reproduction, so each gets a
//! synthetic proxy (DESIGN.md §3) that preserves the property KAKURENBO's
//! dynamics actually depend on: a loss distribution with a large
//! easy-sample mass and a persistent hard/noisy tail (paper Figs. 5, 11).
//!
//! Generators mark which samples are noisy/hard ground truth so tests and
//! diagnostics can verify the hiding machinery targets the right samples.

/// Batch assembly into reusable staging buffers.
pub mod batch;
/// Image-like proxy generators (ImageNet / DeepCAM scale).
pub mod image;
/// Batch-aligned epoch sharding for the worker pool.
pub mod shard;
/// Synthetic generators (Gaussian mixture, fractal boundary).
pub mod synth;

/// A fully materialized dataset (samples are row-major contiguous f32).
#[derive(Clone)]
pub struct Dataset {
    /// Dataset display name (logs, bench tables).
    pub name: String,
    /// Sample count.
    pub n: usize,
    /// Elements per sample (e.g. 64 for the MLP, 8*8*3 for the CNN).
    pub sample_dim: usize,
    /// Labels per sample: 1 for classification, H*W for segmentation.
    pub label_len: usize,
    /// Number of label classes.
    pub classes: usize,
    /// Row-major sample data, `n * sample_dim` elements.
    pub x: Vec<f32>,
    /// Row-major labels, `n * label_len` elements.
    pub y: Vec<i32>,
    /// Ground-truth marker: sample is label-noised / hard-tail.
    pub noisy: Vec<bool>,
}

impl Dataset {
    /// Sample `i`'s feature row.
    pub fn sample_x(&self, i: usize) -> &[f32] {
        &self.x[i * self.sample_dim..(i + 1) * self.sample_dim]
    }

    /// Sample `i`'s label row.
    pub fn sample_y(&self, i: usize) -> &[i32] {
        &self.y[i * self.label_len..(i + 1) * self.label_len]
    }

    /// Classification label of sample i (first label element).
    pub fn label(&self, i: usize) -> i32 {
        self.y[i * self.label_len]
    }

    /// Check the buffer sizes and label ranges are mutually consistent.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.x.len() == self.n * self.sample_dim, "x size");
        anyhow::ensure!(self.y.len() == self.n * self.label_len, "y size");
        anyhow::ensure!(self.noisy.len() == self.n, "noisy size");
        anyhow::ensure!(
            self.y.iter().all(|&c| c >= 0 && (c as usize) < self.classes),
            "label range"
        );
        Ok(())
    }

    /// Per-class sample counts (diagnostics, Figs. 6/7).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.classes];
        for i in 0..self.n {
            counts[self.label(i) as usize] += 1;
        }
        counts
    }
}

/// Train + validation split produced by every generator.
pub struct TrainVal {
    /// The training split.
    pub train: Dataset,
    /// The validation split.
    pub val: Dataset,
}

#[cfg(test)]
mod tests {
    use super::synth::{gauss_mixture, GaussMixtureCfg};

    #[test]
    fn dataset_accessors() {
        let tv = gauss_mixture(&GaussMixtureCfg {
            n_train: 100,
            n_val: 20,
            dim: 8,
            classes: 4,
            ..Default::default()
        }, 1);
        let d = &tv.train;
        d.validate().unwrap();
        assert_eq!(d.sample_x(3).len(), 8);
        assert_eq!(d.sample_y(3).len(), 1);
        assert_eq!(d.class_counts().iter().sum::<usize>(), 100);
    }
}
