//! Image-shaped synthetic generators.
//!
//! * `imagenet_proxy` — HxWx3 class-template images + per-sample noise for
//!   the CNN (ResNet-50 / EfficientNet-b3 stand-in).
//! * `deepcam_proxy`  — HxWx3 inputs with per-pixel binary masks (blob
//!   "cyclones") for the SegNet (DeepCAM stand-in).  A configurable
//!   fraction of samples carries corrupted masks, producing the persistent
//!   top-2% loss tail of paper Fig. 11 that motivates DropTop (Appendix D).

use super::{Dataset, TrainVal};
use crate::util::rng::Rng;

/// Configuration for [`imagenet_proxy`].
#[derive(Clone, Debug)]
pub struct ImagenetProxyCfg {
    /// Training-split sample count.
    pub n_train: usize,
    /// Validation-split sample count.
    pub n_val: usize,
    /// Image height = width in pixels.
    pub hw: usize,
    /// Image channels.
    pub channels: usize,
    /// Number of label classes.
    pub classes: usize,
    /// Template signal amplitude (higher = easier).
    pub signal: f32,
    /// Noise sigma for the easy-sample mass.
    pub noise_easy: f32,
    /// Noise sigma for the hard tail.
    pub noise_hard: f32,
    /// Fraction of samples drawn from the hard tail.
    pub hard_frac: f64,
    /// Fraction of labels flipped (memorization tail).
    pub label_noise: f64,
}

impl Default for ImagenetProxyCfg {
    fn default() -> Self {
        ImagenetProxyCfg {
            n_train: 8192,
            n_val: 2048,
            hw: 8,
            channels: 3,
            classes: 32,
            signal: 0.9,
            noise_easy: 1.5,
            noise_hard: 3.2,
            hard_frac: 0.22,
            label_noise: 0.02,
        }
    }
}

/// Class-template image classification (the ImageNet-1K proxy).
///
/// Every class gets a smooth random template; a sample is
/// `contrast * template[class] + sigma * noise`, where sigma follows the
/// easy/hard mixture and a small fraction of labels is flipped
/// (memorization tail).  Keeps exactly the loss-distribution shape the
/// hiding dynamics depend on while the compute runs through real conv HLO.
pub fn imagenet_proxy(cfg: &ImagenetProxyCfg, seed: u64) -> TrainVal {
    let mut rng = Rng::new(seed ^ 0x696d_6167);
    let dim = cfg.hw * cfg.hw * cfg.channels;
    // Smooth templates: random low-frequency fields per class.
    let mut templates = vec![0.0f32; cfg.classes * dim];
    for c in 0..cfg.classes {
        let fx = 0.4 + rng.f32() * 1.8;
        let fy = 0.4 + rng.f32() * 1.8;
        let px = rng.f32() * std::f32::consts::TAU;
        let py = rng.f32() * std::f32::consts::TAU;
        for ch in 0..cfg.channels {
            let chs = rng.normal_f32(1.0, 0.3);
            for yy in 0..cfg.hw {
                for xx in 0..cfg.hw {
                    let v = ((fx * xx as f32 + px).sin() + (fy * yy as f32 + py).cos()) * chs;
                    templates[c * dim + (yy * cfg.hw + xx) * cfg.channels + ch] =
                        v * cfg.signal / 2.0;
                }
            }
        }
    }
    let gen = |n: usize, with_tail: bool, name: &str, rng: &mut Rng| -> Dataset {
        let mut x = vec![0.0f32; n * dim];
        let mut y = vec![0i32; n];
        let mut noisy = vec![false; n];
        for i in 0..n {
            let label = rng.below(cfg.classes);
            let hard = with_tail && rng.chance(cfg.hard_frac);
            let flipped = with_tail && rng.chance(cfg.label_noise);
            y[i] = if flipped { rng.below(cfg.classes) as i32 } else { label as i32 };
            noisy[i] = flipped || hard;
            let sigma = if hard { cfg.noise_hard } else { cfg.noise_easy };
            let contrast = rng.normal_f32(1.0, 0.15);
            let mut r = rng.fork(i as u64);
            let row = &mut x[i * dim..(i + 1) * dim];
            for (d, v) in row.iter_mut().enumerate() {
                *v = contrast * templates[label * dim + d] + r.normal_f32(0.0, sigma);
            }
        }
        Dataset {
            name: name.to_string(),
            n,
            sample_dim: dim,
            label_len: 1,
            classes: cfg.classes,
            x,
            y,
            noisy,
        }
    };
    let train = gen(cfg.n_train, true, "imagenet_proxy/train", &mut rng);
    let val = gen(cfg.n_val, false, "imagenet_proxy/val", &mut rng);
    TrainVal { train, val }
}

// ---------------------------------------------------------------------------
// DeepCAM proxy: per-pixel binary segmentation
// ---------------------------------------------------------------------------

/// Configuration for [`deepcam_proxy`].
#[derive(Clone, Debug)]
pub struct DeepcamProxyCfg {
    /// Training-split sample count.
    pub n_train: usize,
    /// Validation-split sample count.
    pub n_val: usize,
    /// Image height = width in pixels.
    pub hw: usize,
    /// Input channels.
    pub channels: usize,
    /// Max number of blobs ("cyclones") per image.
    pub max_blobs: usize,
    /// Input noise level.
    pub noise: f32,
    /// Fraction of samples with corrupted (shifted/flipped) masks — the
    /// persistent high-loss tail of Fig. 11.
    pub corrupt_frac: f64,
}

impl Default for DeepcamProxyCfg {
    fn default() -> Self {
        DeepcamProxyCfg {
            n_train: 4096,
            n_val: 1024,
            hw: 16,
            channels: 3,
            max_blobs: 3,
            noise: 1.4,
            corrupt_frac: 0.02,
        }
    }
}

/// Blob segmentation (the DeepCAM stand-in).  Channels carry a smooth
/// field whose intensity rises inside the blob; the label is the per-pixel
/// blob mask (2 classes).
pub fn deepcam_proxy(cfg: &DeepcamProxyCfg, seed: u64) -> TrainVal {
    let mut rng = Rng::new(seed ^ 0x6463_616d);
    let hw = cfg.hw;
    let dim = hw * hw * cfg.channels;
    let label_len = hw * hw;
    let gen = |n: usize, with_tail: bool, name: &str, rng: &mut Rng| -> Dataset {
        let mut x = vec![0.0f32; n * dim];
        let mut y = vec![0i32; n * label_len];
        let mut noisy = vec![false; n];
        for i in 0..n {
            let nblobs = 1 + rng.below(cfg.max_blobs);
            let corrupt = with_tail && rng.chance(cfg.corrupt_frac);
            noisy[i] = corrupt;
            let mut r = rng.fork(i as u64 ^ 0x424c_4f42);
            let mut mask = vec![0i32; label_len];
            let mut field = vec![0.0f32; label_len];
            for _ in 0..nblobs {
                let cx = r.range_f64(2.0, hw as f64 - 2.0) as f32;
                let cy = r.range_f64(2.0, hw as f64 - 2.0) as f32;
                let rx = r.range_f64(1.2, hw as f64 / 3.5) as f32;
                let ry = r.range_f64(1.2, hw as f64 / 3.5) as f32;
                for yy in 0..hw {
                    for xx in 0..hw {
                        let dx = (xx as f32 - cx) / rx;
                        let dy = (yy as f32 - cy) / ry;
                        let d2 = dx * dx + dy * dy;
                        field[yy * hw + xx] += (-d2).exp();
                        if d2 <= 1.0 {
                            mask[yy * hw + xx] = 1;
                        }
                    }
                }
            }
            if corrupt {
                // Corrupted annotation: roll the mask by half the image —
                // the input no longer explains the label (irreducible loss).
                let shift = hw / 2;
                let orig = mask.clone();
                for yy in 0..hw {
                    for xx in 0..hw {
                        mask[yy * hw + xx] = orig[((yy + shift) % hw) * hw + (xx + shift) % hw];
                    }
                }
            }
            for p in 0..label_len {
                y[i * label_len + p] = mask[p];
            }
            for p in 0..label_len {
                for ch in 0..cfg.channels {
                    let chw = 0.6 + 0.4 * ch as f32; // channels see the field differently
                    x[i * dim + p * cfg.channels + ch] =
                        chw * 2.0 * field[p] + r.normal_f32(0.0, cfg.noise);
                }
            }
        }
        Dataset {
            name: name.to_string(),
            n,
            sample_dim: dim,
            label_len,
            classes: 2,
            x,
            y,
            noisy,
        }
    };
    let train = gen(cfg.n_train, true, "deepcam_proxy/train", &mut rng);
    let val = gen(cfg.n_val, false, "deepcam_proxy/val", &mut rng);
    TrainVal { train, val }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imagenet_proxy_valid_and_deterministic() {
        let cfg = ImagenetProxyCfg { n_train: 128, n_val: 32, ..Default::default() };
        let a = imagenet_proxy(&cfg, 3);
        let b = imagenet_proxy(&cfg, 3);
        a.train.validate().unwrap();
        a.val.validate().unwrap();
        assert_eq!(a.train.x, b.train.x);
        assert_eq!(a.train.sample_dim, 8 * 8 * 3);
    }

    #[test]
    fn deepcam_masks_are_binary_and_nonempty() {
        let cfg = DeepcamProxyCfg { n_train: 64, n_val: 16, ..Default::default() };
        let tv = deepcam_proxy(&cfg, 5);
        tv.train.validate().unwrap();
        let d = &tv.train;
        assert_eq!(d.label_len, 16 * 16);
        let mut any_pos = 0;
        for i in 0..d.n {
            let pos = d.sample_y(i).iter().filter(|&&v| v == 1).count();
            assert!(pos < d.label_len); // never all-blob
            if pos > 0 {
                any_pos += 1;
            }
        }
        assert!(any_pos > d.n / 2, "most samples should contain blobs");
    }

    #[test]
    fn deepcam_corruption_fraction() {
        let cfg = DeepcamProxyCfg {
            n_train: 4000,
            n_val: 10,
            corrupt_frac: 0.1,
            ..Default::default()
        };
        let tv = deepcam_proxy(&cfg, 9);
        let frac = tv.train.noisy.iter().filter(|&&b| b).count() as f64 / 4000.0;
        assert!((frac - 0.1).abs() < 0.02, "corrupt frac {frac}");
        assert!(tv.val.noisy.iter().all(|&b| !b));
    }

    #[test]
    fn blob_field_correlates_with_mask() {
        // mean input intensity inside mask > outside (the task is learnable)
        let cfg = DeepcamProxyCfg { n_train: 32, n_val: 8, corrupt_frac: 0.0, ..Default::default() };
        let tv = deepcam_proxy(&cfg, 2);
        let d = &tv.train;
        let (mut inside, mut outside, mut ni, mut no) = (0.0f64, 0.0f64, 0, 0);
        for i in 0..d.n {
            let xs = d.sample_x(i);
            let ys = d.sample_y(i);
            for p in 0..d.label_len {
                let v = xs[p * 3] as f64;
                if ys[p] == 1 {
                    inside += v;
                    ni += 1;
                } else {
                    outside += v;
                    no += 1;
                }
            }
        }
        assert!(inside / ni.max(1) as f64 > outside / no.max(1) as f64 + 0.3);
    }
}
