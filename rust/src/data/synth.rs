//! Vector-input synthetic generators: Gaussian mixture (CIFAR-100 proxy for
//! the MLP) and sinusoid "fractal" features (Fractal-3K proxy for the
//! transfer-learning pipeline, Table 4).
//!
//! The key knob is the *difficulty profile*: each sample gets a noise scale
//! drawn from a two-component mixture — an easy mass (low noise, quickly
//! learned, loss collapses early: these are what KAKURENBO hides) and a
//! hard tail (high noise and/or flipped labels: loss stays high, paper
//! Fig. 5 / Fig. 11).

use super::{Dataset, TrainVal};
use crate::util::rng::Rng;

/// Configuration for [`gauss_mixture`].
#[derive(Clone, Debug)]
pub struct GaussMixtureCfg {
    /// Training-split sample count.
    pub n_train: usize,
    /// Validation-split sample count.
    pub n_val: usize,
    /// Feature dimension per sample.
    pub dim: usize,
    /// Number of mixture components (= label classes).
    pub classes: usize,
    /// Distance scale between class centers (higher = easier task).
    pub separation: f32,
    /// Base within-class noise for "easy" samples.
    pub noise_easy: f32,
    /// Noise multiplier for hard-tail samples.
    pub noise_hard: f32,
    /// Fraction of samples in the hard tail.
    pub hard_frac: f64,
    /// Fraction of samples whose label is flipped to a random class
    /// (memorization tail — can never be predicted from x).
    pub label_noise: f64,
}

impl Default for GaussMixtureCfg {
    fn default() -> Self {
        GaussMixtureCfg {
            n_train: 4096,
            n_val: 1024,
            dim: 64,
            classes: 100,
            separation: 2.0,
            noise_easy: 1.1,
            noise_hard: 3.0,
            hard_frac: 0.18,
            label_noise: 0.05,
        }
    }
}

fn class_centers(rng: &mut Rng, classes: usize, dim: usize, sep: f32) -> Vec<f32> {
    let mut c = vec![0.0f32; classes * dim];
    for v in c.iter_mut() {
        *v = rng.normal_f32(0.0, sep / 2.0);
    }
    c
}

fn gen_split(
    cfg: &GaussMixtureCfg,
    centers: &[f32],
    n: usize,
    rng: &mut Rng,
    name: &str,
    with_tail: bool,
) -> Dataset {
    let dim = cfg.dim;
    let mut x = vec![0.0f32; n * dim];
    let mut y = vec![0i32; n];
    let mut noisy = vec![false; n];
    // Per-sample metadata drawn serially (determinism), pixels in parallel.
    let mut sigma = vec![0.0f32; n];
    for i in 0..n {
        let label = rng.below(cfg.classes);
        let hard = with_tail && rng.chance(cfg.hard_frac);
        let flipped = with_tail && rng.chance(cfg.label_noise);
        sigma[i] = if hard { cfg.noise_hard } else { cfg.noise_easy };
        y[i] = if flipped {
            noisy[i] = true;
            rng.below(cfg.classes) as i32
        } else {
            noisy[i] = hard;
            label as i32
        };
        // When flipped we still draw x from the *original* class: the label
        // is unlearnable, which is what creates the persistent loss tail.
        let c = label;
        let mut r = rng.fork(i as u64);
        let row = &mut x[i * dim..(i + 1) * dim];
        for (d, v) in row.iter_mut().enumerate() {
            *v = centers[c * dim + d] + r.normal_f32(0.0, sigma[i]);
        }
    }
    let d = Dataset {
        name: name.to_string(),
        n,
        sample_dim: dim,
        label_len: 1,
        classes: cfg.classes,
        x,
        y,
        noisy,
    };
    debug_assert!(d.validate().is_ok());
    d
}

/// Gaussian-mixture classification: the CIFAR-100 / WRN-28-10 stand-in.
pub fn gauss_mixture(cfg: &GaussMixtureCfg, seed: u64) -> TrainVal {
    let mut rng = Rng::new(seed ^ 0x6d69_7874);
    let centers = class_centers(&mut rng, cfg.classes, cfg.dim, cfg.separation);
    let train = gen_split(cfg, &centers, cfg.n_train, &mut rng, "gauss_mixture/train", true);
    // Validation has no label noise / hard tail: clean generalization probe.
    let val = gen_split(cfg, &centers, cfg.n_val, &mut rng, "gauss_mixture/val", false);
    TrainVal { train, val }
}

// ---------------------------------------------------------------------------
// Fractal proxy (upstream pretraining geometry, Table 4)
// ---------------------------------------------------------------------------

/// Configuration for [`fractal_proxy`].
#[derive(Clone, Debug)]
pub struct FractalCfg {
    /// Training-split sample count.
    pub n_train: usize,
    /// Validation-split sample count.
    pub n_val: usize,
    /// Feature dimension per sample.
    pub dim: usize,
    /// Number of fractal-parameter classes.
    pub classes: usize,
    /// Additive feature-noise sigma.
    pub noise: f32,
    /// Fraction of samples in the hard tail.
    pub hard_frac: f64,
    /// Fraction of labels flipped (memorization tail).
    pub label_noise: f64,
}

impl Default for FractalCfg {
    fn default() -> Self {
        FractalCfg {
            n_train: 6144,
            n_val: 1024,
            dim: 64,
            classes: 64,
            noise: 0.35,
            hard_frac: 0.12,
            label_noise: 0.03,
        }
    }
}

/// Sinusoidal class signatures: `x[d] = Σ_k a_ck sin(f_ck d + φ_ck) + noise`.
/// A deliberately different geometry from the Gaussian mixture so that a
/// trunk pretrained here transfers (rather than trivially matching) the
/// downstream task — mirroring Fractal-3K → CIFAR in the paper.
pub fn fractal_proxy(cfg: &FractalCfg, seed: u64) -> TrainVal {
    let mut rng = Rng::new(seed ^ 0x6672_6163);
    let harmonics = 3usize;
    // class signature parameters
    let mut amp = vec![0.0f32; cfg.classes * harmonics];
    let mut freq = vec![0.0f32; cfg.classes * harmonics];
    let mut phase = vec![0.0f32; cfg.classes * harmonics];
    for i in 0..cfg.classes * harmonics {
        amp[i] = 0.5 + rng.f32();
        freq[i] = 0.2 + 2.0 * rng.f32();
        phase[i] = rng.f32() * std::f32::consts::TAU;
    }
    let gen = |n: usize, with_tail: bool, name: &str, rng: &mut Rng| -> Dataset {
        let dim = cfg.dim;
        let mut x = vec![0.0f32; n * dim];
        let mut y = vec![0i32; n];
        let mut noisy = vec![false; n];
        let mut meta: Vec<(usize, f32, u64)> = Vec::with_capacity(n);
        for i in 0..n {
            let label = rng.below(cfg.classes);
            let hard = with_tail && rng.chance(cfg.hard_frac);
            let flipped = with_tail && rng.chance(cfg.label_noise);
            y[i] = if flipped { rng.below(cfg.classes) as i32 } else { label as i32 };
            noisy[i] = flipped || hard;
            let sigma = if hard { cfg.noise * 4.0 } else { cfg.noise };
            meta.push((label, sigma, rng.next_u64()));
        }
        // Row fill: each row re-seeds its own RNG from `meta`, so the result
        // is independent of iteration order (and of any future chunking).
        for (i, row) in x.chunks_mut(dim).enumerate() {
            let (label, sigma, s) = meta[i];
            let mut r = Rng::new(s);
            for (d, v) in row.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for h in 0..harmonics {
                    let k = label * harmonics + h;
                    acc += amp[k] * (freq[k] * d as f32 + phase[k]).sin();
                }
                *v = acc + r.normal_f32(0.0, sigma);
            }
        }
        Dataset {
            name: name.to_string(),
            n,
            sample_dim: dim,
            label_len: 1,
            classes: cfg.classes,
            x,
            y,
            noisy,
        }
    };
    let train = gen(cfg.n_train, true, "fractal/train", &mut rng);
    let val = gen(cfg.n_val, false, "fractal/val", &mut rng);
    TrainVal { train, val }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let cfg = GaussMixtureCfg { n_train: 64, n_val: 16, dim: 8, classes: 4, ..Default::default() };
        let a = gauss_mixture(&cfg, 7);
        let b = gauss_mixture(&cfg, 7);
        assert_eq!(a.train.x, b.train.x);
        assert_eq!(a.train.y, b.train.y);
        let c = gauss_mixture(&cfg, 8);
        assert_ne!(a.train.x, c.train.x);
    }

    #[test]
    fn tail_fractions_approximately_respected() {
        let cfg = GaussMixtureCfg {
            n_train: 8000,
            n_val: 10,
            dim: 4,
            classes: 10,
            hard_frac: 0.2,
            label_noise: 0.05,
            ..Default::default()
        };
        let tv = gauss_mixture(&cfg, 3);
        let frac = tv.train.noisy.iter().filter(|&&b| b).count() as f64 / 8000.0;
        // hard ∪ flipped ≈ 1 - (1-0.2)(1-0.05) ≈ 0.24
        assert!((frac - 0.24).abs() < 0.03, "noisy frac {frac}");
        // validation is clean
        assert!(tv.val.noisy.iter().all(|&b| !b));
    }

    #[test]
    fn classes_are_separable_in_expectation() {
        // mean distance between same-class samples < cross-class distance
        let cfg = GaussMixtureCfg {
            n_train: 400,
            n_val: 10,
            dim: 16,
            classes: 4,
            separation: 4.0,
            noise_easy: 0.5,
            hard_frac: 0.0,
            label_noise: 0.0,
            ..Default::default()
        };
        let tv = gauss_mixture(&cfg, 5);
        let d = &tv.train;
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
        };
        let (mut same, mut cross, mut ns, mut nc) = (0.0f64, 0.0f64, 0, 0);
        for i in 0..100 {
            for j in (i + 1)..100 {
                let dd = dist(d.sample_x(i), d.sample_x(j)) as f64;
                if d.label(i) == d.label(j) {
                    same += dd;
                    ns += 1;
                } else {
                    cross += dd;
                    nc += 1;
                }
            }
        }
        assert!(same / ns as f64 * 1.5 < cross / nc as f64);
    }

    #[test]
    fn fractal_deterministic_and_valid() {
        let cfg = FractalCfg { n_train: 128, n_val: 32, dim: 16, classes: 8, ..Default::default() };
        let a = fractal_proxy(&cfg, 11);
        let b = fractal_proxy(&cfg, 11);
        assert_eq!(a.train.x, b.train.x);
        a.train.validate().unwrap();
        a.val.validate().unwrap();
    }
}
