//! Worker sharding of an epoch's training order (distributed simulation).
//!
//! The paper runs data-parallel training with one MPI rank per GPU (32-1024
//! workers, Appendix B.1).  Our virtual-worker runtime shards the epoch
//! order the same way the PyTorch DistributedSampler does — contiguous
//! equal chunks after the global shuffle, padded by wrap-around so every
//! worker takes the same number of steps (the allreduce is bulk-synchronous:
//! ragged shards would deadlock a real job).

#[derive(Clone, Debug)]
pub struct Shard {
    pub worker: usize,
    pub indices: Vec<u32>,
}

/// Split `order` into `workers` equal shards (wrap-around padding).
pub fn shard_order(order: &[u32], workers: usize) -> Vec<Shard> {
    assert!(workers > 0);
    if order.is_empty() {
        return (0..workers).map(|w| Shard { worker: w, indices: vec![] }).collect();
    }
    let per = order.len().div_ceil(workers);
    (0..workers)
        .map(|w| {
            let mut indices = Vec::with_capacity(per);
            for k in 0..per {
                indices.push(order[(w * per + k) % order.len()]);
            }
            Shard { worker: w, indices }
        })
        .collect()
}

/// Interleave shards back into the global step order: step s consumes
/// shard[w].indices[s] across workers — this is the order the *global
/// batch* (W x b samples) is assembled in by the coordinator.
pub fn global_step_order(shards: &[Shard]) -> Vec<u32> {
    if shards.is_empty() {
        return vec![];
    }
    let steps = shards[0].indices.len();
    let mut out = Vec::with_capacity(steps * shards.len());
    for s in 0..steps {
        for shard in shards {
            out.push(shard.indices[s]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_are_equal_and_cover() {
        let order: Vec<u32> = (0..103).collect();
        let shards = shard_order(&order, 4);
        assert!(shards.iter().all(|s| s.indices.len() == 26));
        let mut all: Vec<u32> = shards.iter().flat_map(|s| s.indices.clone()).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all, order); // every sample appears (padding duplicates allowed)
    }

    #[test]
    fn exact_division_no_padding() {
        let order: Vec<u32> = (0..100).collect();
        let shards = shard_order(&order, 4);
        let all: Vec<u32> = shards.iter().flat_map(|s| s.indices.clone()).collect();
        assert_eq!(all.len(), 100);
        let mut sorted = all.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, order);
    }

    #[test]
    fn global_order_interleaves() {
        let order: Vec<u32> = (0..8).collect();
        let shards = shard_order(&order, 2);
        let g = global_step_order(&shards);
        // worker0 gets 0..4, worker1 gets 4..8; steps interleave
        assert_eq!(g, vec![0, 4, 1, 5, 2, 6, 3, 7]);
    }

    #[test]
    fn empty_order() {
        let shards = shard_order(&[], 3);
        assert_eq!(shards.len(), 3);
        assert!(global_step_order(&shards).is_empty());
    }
}

// ---------------------------------------------------------------------------
// Importance-aware sharding (Mercury-style, paper ref [22])
// ---------------------------------------------------------------------------

/// Shard `order` so that every worker receives approximately equal *total
/// importance* (e.g. lagging loss), not just equal counts — Mercury's
/// importance-aware data sharding.  Greedy LPT assignment: visit samples
/// in descending importance, always assigning to the currently lightest
/// worker; worker-local order is then shuffled by the caller if needed.
///
/// Shards may differ in length by design; `pad_equal` wraps them to the
/// max length so a bulk-synchronous step loop still lines up.
pub fn shard_by_importance(
    order: &[u32],
    importance: &[f32],
    workers: usize,
    pad_equal: bool,
) -> Vec<Shard> {
    assert!(workers > 0);
    let mut shards: Vec<Shard> = (0..workers)
        .map(|w| Shard { worker: w, indices: Vec::new() })
        .collect();
    if order.is_empty() {
        return shards;
    }
    let mut by_imp: Vec<u32> = order.to_vec();
    by_imp.sort_by(|&a, &b| {
        let ia = importance.get(a as usize).copied().unwrap_or(0.0);
        let ib = importance.get(b as usize).copied().unwrap_or(0.0);
        ib.total_cmp(&ia)
    });
    let mut loads = vec![0.0f64; workers];
    for &i in &by_imp {
        let w = loads
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(w, _)| w)
            .unwrap();
        loads[w] += importance.get(i as usize).copied().unwrap_or(0.0).max(0.0) as f64;
        shards[w].indices.push(i);
    }
    if pad_equal {
        let max_len = shards.iter().map(|s| s.indices.len()).max().unwrap_or(0);
        for s in shards.iter_mut() {
            let mut k = 0;
            while s.indices.len() < max_len {
                let v = s.indices[k % s.indices.len().max(1)];
                s.indices.push(v);
                k += 1;
            }
        }
    }
    shards
}

#[cfg(test)]
mod importance_tests {
    use super::*;

    #[test]
    fn balances_total_importance() {
        let order: Vec<u32> = (0..100).collect();
        // skewed importance: sample i has importance i
        let imp: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let shards = shard_by_importance(&order, &imp, 4, false);
        let loads: Vec<f64> = shards
            .iter()
            .map(|s| s.indices.iter().map(|&i| imp[i as usize] as f64).sum())
            .collect();
        let max = loads.iter().cloned().fold(0.0, f64::max);
        let min = loads.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max - min <= 99.0, "loads {loads:?}"); // within one max item
        // all samples assigned exactly once
        let mut all: Vec<u32> = shards.iter().flat_map(|s| s.indices.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, order);
    }

    #[test]
    fn pad_equal_lines_up_steps() {
        let order: Vec<u32> = (0..10).collect();
        let imp = vec![1.0f32; 10];
        let shards = shard_by_importance(&order, &imp, 3, true);
        let len = shards[0].indices.len();
        assert!(shards.iter().all(|s| s.indices.len() == len));
    }

    #[test]
    fn empty_and_single_worker() {
        let shards = shard_by_importance(&[], &[], 2, true);
        assert_eq!(shards.len(), 2);
        let order: Vec<u32> = (0..5).collect();
        let shards = shard_by_importance(&order, &[1.0; 5], 1, false);
        assert_eq!(shards[0].indices.len(), 5);
    }
}
