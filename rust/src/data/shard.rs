//! Worker sharding of an epoch's training order (data-parallel execution).
//!
//! The paper runs data-parallel training with one MPI rank per GPU (32-1024
//! workers, Appendix B.1).  Our runtime shards the epoch order the same way
//! the PyTorch DistributedSampler does — contiguous equal chunks after the
//! global shuffle, padded by wrap-around so every worker takes the same
//! number of steps (a real allreduce is bulk-synchronous; the engine's
//! pool tolerates ragged shards by retiring exhausted lanes from the
//! barrier, but padding keeps every lane productive — see
//! docs/worker-model.md).
//!
//! Two granularities of padding exist:
//!
//! * [`shard_order`] pads shards to equal *sample* counts (the historical
//!   virtual-worker interleave, granularity 1);
//! * [`shard_order_aligned`] additionally rounds each shard up to a whole
//!   number of device batches, so every worker executes the same number of
//!   *full* steps.  This is what the engine's `WorkerPool` consumes: with
//!   batch-aligned shards, the pool's bulk-synchronous `(step, worker)`
//!   execution order is bitwise-identical to a single serial stream over
//!   [`global_batch_order`].

/// One worker's slice of the epoch order: the sample indices worker
/// `worker` trains on this epoch, in its local step order.
#[derive(Clone, Debug)]
pub struct Shard {
    /// Worker rank owning this slice (0-based, dense).
    pub worker: usize,
    /// Sample indices in local execution order (may contain wrap-around
    /// duplicates from padding).
    pub indices: Vec<u32>,
}

impl Shard {
    /// Number of samples in the shard (including wrap-around padding).
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    /// Whether the shard holds no samples (empty epoch).
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Number of bulk-synchronous steps this shard contributes at device
    /// batch size `batch`.  For [`shard_order_aligned`] shards every step
    /// is a full batch; for granularity-1 shards the last step may be
    /// ragged.
    pub fn steps(&self, batch: usize) -> usize {
        assert!(batch > 0);
        self.indices.len().div_ceil(batch)
    }

    /// The sample indices this worker feeds into global step `s` (empty
    /// once `s >= self.steps(batch)`).
    pub fn step_batch(&self, s: usize, batch: usize) -> &[u32] {
        assert!(batch > 0);
        let lo = (s * batch).min(self.indices.len());
        let hi = ((s + 1) * batch).min(self.indices.len());
        &self.indices[lo..hi]
    }
}

/// Split `order` into `workers` equal shards (wrap-around padding),
/// sample granularity.
///
/// Each worker receives a contiguous window of the (already shuffled)
/// epoch order; windows tile the order end-to-start, so their union always
/// covers every sample and padding duplicates only appear when
/// `order.len()` does not divide evenly.
///
/// ```
/// use kakurenbo::data::shard::shard_order;
/// let order: Vec<u32> = (0..103).collect();
/// let shards = shard_order(&order, 4);
/// // equal sizes: ceil(103 / 4) = 26 samples per worker
/// assert!(shards.iter().all(|s| s.len() == 26));
/// // contiguous windows: worker 1 starts where worker 0 ends
/// assert_eq!(shards[1].indices[0], 26);
/// ```
pub fn shard_order(order: &[u32], workers: usize) -> Vec<Shard> {
    shard_order_aligned(order, workers, 1)
}

/// Split `order` into `workers` equal shards, each padded (wrap-around) to
/// a whole number of `batch`-sized steps.
///
/// Every worker ends up with exactly `ceil(ceil(n / W) / batch)` full
/// device batches, so a bulk-synchronous step loop across workers lines up
/// with no ragged tails — the invariant the engine's `WorkerPool` barrier
/// relies on (docs/worker-model.md).
///
/// ```
/// use kakurenbo::data::shard::shard_order_aligned;
/// let order: Vec<u32> = (0..10).collect();
/// let shards = shard_order_aligned(&order, 2, 4);
/// // ceil(10/2) = 5, rounded up to a multiple of 4 => 8 per worker
/// assert!(shards.iter().all(|s| s.len() == 8 && s.steps(4) == 2));
/// // wrap-around padding: worker 1's window continues past the end
/// assert_eq!(shards[1].indices, vec![8, 9, 0, 1, 2, 3, 4, 5]);
/// ```
pub fn shard_order_aligned(order: &[u32], workers: usize, batch: usize) -> Vec<Shard> {
    assert!(workers > 0);
    assert!(batch > 0);
    if order.is_empty() {
        return (0..workers).map(|w| Shard { worker: w, indices: vec![] }).collect();
    }
    let per = order.len().div_ceil(workers).div_ceil(batch) * batch;
    (0..workers)
        .map(|w| {
            let mut indices = Vec::with_capacity(per);
            for k in 0..per {
                indices.push(order[(w * per + k) % order.len()]);
            }
            Shard { worker: w, indices }
        })
        .collect()
}

/// Interleave shards back into the global step order at sample
/// granularity: step s consumes `shard[w].indices[s]` across workers.
///
/// This is the historical virtual-worker stream (one sample per worker
/// per step); the batch-granular equivalent the worker pool executes is
/// [`global_batch_order`].
///
/// ```
/// use kakurenbo::data::shard::{global_step_order, shard_order};
/// let order: Vec<u32> = (0..8).collect();
/// let shards = shard_order(&order, 2);
/// // worker 0 holds 0..4, worker 1 holds 4..8; steps interleave:
/// assert_eq!(global_step_order(&shards), vec![0, 4, 1, 5, 2, 6, 3, 7]);
/// ```
pub fn global_step_order(shards: &[Shard]) -> Vec<u32> {
    global_batch_order(shards, 1)
}

/// Interleave shards into the global *batch* order: global step s emits
/// worker 0's s-th batch, then worker 1's, and so on.
///
/// For batch-aligned shards this flat stream, chunked by `batch`, performs
/// exactly the device calls of the worker pool's bulk-synchronous
/// schedule, in its deterministic `(step, worker)` reduction order — the
/// serial reference the pool is tested against.  Ragged shards are
/// handled the way the pool handles them: a shard contributes nothing at
/// steps past its own length.
///
/// ```
/// use kakurenbo::data::shard::{global_batch_order, shard_order_aligned};
/// let order: Vec<u32> = (0..8).collect();
/// let shards = shard_order_aligned(&order, 2, 2);
/// // step 0: worker0 [0,1], worker1 [4,5]; step 1: [2,3], [6,7]
/// assert_eq!(global_batch_order(&shards, 2), vec![0, 1, 4, 5, 2, 3, 6, 7]);
/// ```
pub fn global_batch_order(shards: &[Shard], batch: usize) -> Vec<u32> {
    if shards.is_empty() {
        return vec![];
    }
    let steps = shards.iter().map(|s| s.steps(batch)).max().unwrap_or(0);
    let mut out = Vec::with_capacity(shards.iter().map(Shard::len).sum());
    for s in 0..steps {
        for shard in shards {
            out.extend_from_slice(shard.step_batch(s, batch));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Elastic re-sharding (fault tolerance)
// ---------------------------------------------------------------------------

/// One slice of a dead lane's unfinished shard, re-issued to a surviving
/// recovery lane under `--fault-policy elastic`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReissuedSlice {
    /// The dead lane's original step index this slice belongs to — the
    /// recovered run still consumes it at exactly this barrier position,
    /// which is what keeps the `(step, worker)` fold order (and therefore
    /// the results, bit for bit) identical to an undisturbed run.
    pub step: usize,
    /// Recovery lane (0-based among the re-issue lanes) that gathers this
    /// slice.
    pub lane: usize,
    /// The sample indices of the slice (the dead shard's `step_batch`).
    pub indices: Vec<u32>,
}

/// Deterministically re-issue the tail of a dead worker's shard — every
/// step from `from_step` onward — across `survivors` recovery lanes,
/// round-robin in step order.
///
/// The assignment is a pure function of `(shard, from_step, batch,
/// survivors)`: no clock, no detection-timing dependence.  Each original
/// step appears exactly once, so the union of the re-issued slices covers
/// the dead lane's remaining batch indices exactly once, in the original
/// step order — the elastic-recovery determinism contract
/// (docs/worker-model.md, "Fault tolerance").
///
/// ```
/// use kakurenbo::data::shard::{reissue_tail, Shard};
/// let dead = Shard { worker: 1, indices: (0..12).collect() };
/// // lane died before its step 1; 2 survivors pick up steps 1..3
/// let slices = reissue_tail(&dead, 1, 4, 2);
/// assert_eq!(slices.len(), 2);
/// assert_eq!((slices[0].step, slices[0].lane), (1, 0));
/// assert_eq!((slices[1].step, slices[1].lane), (2, 1));
/// assert_eq!(slices[0].indices, vec![4, 5, 6, 7]);
/// assert_eq!(slices[1].indices, vec![8, 9, 10, 11]);
/// ```
pub fn reissue_tail(
    shard: &Shard,
    from_step: usize,
    batch: usize,
    survivors: usize,
) -> Vec<ReissuedSlice> {
    let k = survivors.max(1);
    let mut out = Vec::new();
    for t in from_step..shard.steps(batch) {
        out.push(ReissuedSlice {
            step: t,
            lane: (t - from_step) % k,
            indices: shard.step_batch(t, batch).to_vec(),
        });
    }
    out
}

#[cfg(test)]
mod reissue_tests {
    use super::*;

    #[test]
    fn covers_remaining_indices_exactly_once_in_step_order() {
        let shard = Shard { worker: 2, indices: (100..140).collect() };
        for from in 0..shard.steps(8) {
            for k in 1..4usize {
                let slices = reissue_tail(&shard, from, 8, k);
                let flat: Vec<u32> =
                    slices.iter().flat_map(|s| s.indices.clone()).collect();
                assert_eq!(flat, shard.indices[from * 8..], "from={from} k={k}");
                // steps are the original ones, consecutive from `from`
                for (i, s) in slices.iter().enumerate() {
                    assert_eq!(s.step, from + i);
                    assert_eq!(s.lane, i % k);
                }
            }
        }
    }

    #[test]
    fn ragged_tail_and_past_end() {
        let shard = Shard { worker: 0, indices: (0..10).collect() };
        let slices = reissue_tail(&shard, 2, 4, 2);
        // steps(4) = 3: only the ragged step 2 remains
        assert_eq!(slices.len(), 1);
        assert_eq!(slices[0].indices, vec![8, 9]);
        assert!(reissue_tail(&shard, 3, 4, 2).is_empty());
        assert!(reissue_tail(&shard, 99, 4, 2).is_empty());
    }

    #[test]
    fn zero_survivors_clamps_to_one_lane() {
        let shard = Shard { worker: 0, indices: (0..8).collect() };
        let slices = reissue_tail(&shard, 0, 4, 0);
        assert!(slices.iter().all(|s| s.lane == 0));
        assert_eq!(slices.len(), 2);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_are_equal_and_cover() {
        let order: Vec<u32> = (0..103).collect();
        let shards = shard_order(&order, 4);
        assert!(shards.iter().all(|s| s.indices.len() == 26));
        let mut all: Vec<u32> = shards.iter().flat_map(|s| s.indices.clone()).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all, order); // every sample appears (padding duplicates allowed)
    }

    #[test]
    fn exact_division_no_padding() {
        let order: Vec<u32> = (0..100).collect();
        let shards = shard_order(&order, 4);
        let all: Vec<u32> = shards.iter().flat_map(|s| s.indices.clone()).collect();
        assert_eq!(all.len(), 100);
        let mut sorted = all.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, order);
    }

    #[test]
    fn global_order_interleaves() {
        let order: Vec<u32> = (0..8).collect();
        let shards = shard_order(&order, 2);
        let g = global_step_order(&shards);
        // worker0 gets 0..4, worker1 gets 4..8; steps interleave
        assert_eq!(g, vec![0, 4, 1, 5, 2, 6, 3, 7]);
    }

    #[test]
    fn empty_order() {
        let shards = shard_order(&[], 3);
        assert_eq!(shards.len(), 3);
        assert!(global_step_order(&shards).is_empty());
        let shards = shard_order_aligned(&[], 3, 8);
        assert!(shards.iter().all(|s| s.is_empty() && s.steps(8) == 0));
        assert!(global_batch_order(&shards, 8).is_empty());
    }

    #[test]
    fn aligned_shards_take_whole_steps() {
        // n = 83, W = 3, b = 8: per = ceil(83/3) = 28 -> aligned 32
        let order: Vec<u32> = (0..83).collect();
        let shards = shard_order_aligned(&order, 3, 8);
        for s in &shards {
            assert_eq!(s.len(), 32);
            assert_eq!(s.len() % 8, 0);
            assert_eq!(s.steps(8), 4);
        }
        // union still covers every sample
        let mut seen = vec![false; 83];
        for s in &shards {
            for &i in &s.indices {
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn aligned_windows_tile_the_order() {
        // windows start at w*per mod n and are contiguous end-to-start,
        // so coverage holds even when per > n / W
        let order: Vec<u32> = (0..10).collect();
        let shards = shard_order_aligned(&order, 4, 8);
        assert!(shards.iter().all(|s| s.len() == 8));
        let mut seen = vec![false; 10];
        for s in &shards {
            for &i in &s.indices {
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn step_batch_slices() {
        let order: Vec<u32> = (0..20).collect();
        let shards = shard_order_aligned(&order, 2, 4);
        // per = ceil(10/4)*4 = 12: worker 1 wraps past the end
        let s1 = &shards[1];
        assert_eq!(s1.steps(4), 3);
        assert_eq!(s1.step_batch(0, 4), &[12, 13, 14, 15]);
        assert_eq!(s1.step_batch(2, 4), &[0, 1, 2, 3]);
        assert!(s1.step_batch(3, 4).is_empty());
    }

    #[test]
    fn batch_order_handles_ragged_shards() {
        // worker 0 takes 2 steps, worker 1 only 1: the short shard simply
        // stops contributing, matching the pool's ragged-tolerant barrier
        let shards = vec![
            Shard { worker: 0, indices: vec![0, 1, 2, 3] },
            Shard { worker: 1, indices: vec![4, 5] },
        ];
        assert_eq!(global_batch_order(&shards, 2), vec![0, 1, 4, 5, 2, 3]);
        // order is driven by the longest shard even when it is not first
        let shards = vec![
            Shard { worker: 0, indices: vec![0, 1] },
            Shard { worker: 1, indices: vec![4, 5, 6, 7] },
        ];
        assert_eq!(global_batch_order(&shards, 2), vec![0, 1, 4, 5, 6, 7]);
    }

    #[test]
    fn batch_order_chunks_match_pool_schedule() {
        let order: Vec<u32> = (0..16).collect();
        let shards = shard_order_aligned(&order, 2, 4);
        let flat = global_batch_order(&shards, 4);
        // chunk k of the flat stream is worker (k % 2)'s batch (k / 2)
        for (k, chunk) in flat.chunks(4).enumerate() {
            assert_eq!(chunk, shards[k % 2].step_batch(k / 2, 4));
        }
    }
}

// ---------------------------------------------------------------------------
// Importance-aware sharding (Mercury-style, paper ref [22])
// ---------------------------------------------------------------------------

/// Shard `order` so that every worker receives approximately equal *total
/// importance* (e.g. lagging loss), not just equal counts — Mercury's
/// importance-aware data sharding.  Greedy LPT assignment: visit samples
/// in descending importance, always assigning to the currently lightest
/// worker; worker-local order is then shuffled by the caller if needed.
///
/// Shards may differ in length by design; `pad_equal` wraps them to the
/// max length so a bulk-synchronous step loop still lines up.
pub fn shard_by_importance(
    order: &[u32],
    importance: &[f32],
    workers: usize,
    pad_equal: bool,
) -> Vec<Shard> {
    assert!(workers > 0);
    let mut shards: Vec<Shard> = (0..workers)
        .map(|w| Shard { worker: w, indices: Vec::new() })
        .collect();
    if order.is_empty() {
        return shards;
    }
    let mut by_imp: Vec<u32> = order.to_vec();
    by_imp.sort_by(|&a, &b| {
        let ia = importance.get(a as usize).copied().unwrap_or(0.0);
        let ib = importance.get(b as usize).copied().unwrap_or(0.0);
        ib.total_cmp(&ia)
    });
    let mut loads = vec![0.0f64; workers];
    for &i in &by_imp {
        let w = loads
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(w, _)| w)
            .unwrap();
        loads[w] += importance.get(i as usize).copied().unwrap_or(0.0).max(0.0) as f64;
        shards[w].indices.push(i);
    }
    if pad_equal {
        let max_len = shards.iter().map(|s| s.indices.len()).max().unwrap_or(0);
        for s in shards.iter_mut() {
            let mut k = 0;
            while s.indices.len() < max_len {
                let v = s.indices[k % s.indices.len().max(1)];
                s.indices.push(v);
                k += 1;
            }
        }
    }
    shards
}

#[cfg(test)]
mod importance_tests {
    use super::*;

    #[test]
    fn balances_total_importance() {
        let order: Vec<u32> = (0..100).collect();
        // skewed importance: sample i has importance i
        let imp: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let shards = shard_by_importance(&order, &imp, 4, false);
        let loads: Vec<f64> = shards
            .iter()
            .map(|s| s.indices.iter().map(|&i| imp[i as usize] as f64).sum())
            .collect();
        let max = loads.iter().cloned().fold(0.0, f64::max);
        let min = loads.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max - min <= 99.0, "loads {loads:?}"); // within one max item
        // all samples assigned exactly once
        let mut all: Vec<u32> = shards.iter().flat_map(|s| s.indices.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, order);
    }

    #[test]
    fn pad_equal_lines_up_steps() {
        let order: Vec<u32> = (0..10).collect();
        let imp = vec![1.0f32; 10];
        let shards = shard_by_importance(&order, &imp, 3, true);
        let len = shards[0].indices.len();
        assert!(shards.iter().all(|s| s.indices.len() == len));
    }

    #[test]
    fn empty_and_single_worker() {
        let shards = shard_by_importance(&[], &[], 2, true);
        assert_eq!(shards.len(), 2);
        let order: Vec<u32> = (0..5).collect();
        let shards = shard_by_importance(&order, &[1.0; 5], 1, false);
        assert_eq!(shards[0].indices.len(), 5);
    }
}
