//! The coordinator's feature cache: penultimate-layer embeddings
//! harvested by the engine's [`StepMode::Embed`] scoring pass and reused
//! across epochs by pre-forward pruning strategies (PFB).
//!
//! The cache is what makes PFB's scoring *amortized* instead of per-epoch:
//! one `fwd_embed` sweep every `--pfb-refresh-every N` epochs fills it, and
//! the N following plans score samples from the cached rows with a cheap
//! per-class centroid-distance proxy — zero extra device forwards in the
//! cache-reuse epochs.  It is coordinator state: it rides the exact-resume
//! payload (`coordinator/resume.rs`) beside the per-sample stats, so a
//! `--resume` mid-cache-lifetime replays the same scores bit for bit.
//!
//! [`StepMode::Embed`]: crate::engine::StepMode

use crate::data::Dataset;

/// Row-major `[n, dim]` store of per-sample embedding rows plus the epoch
/// whose parameters produced them.  Snapshotted wholesale by the
/// exact-resume path; see the module docs for the lifecycle.
#[derive(Clone, Debug)]
pub struct FeatureCache {
    /// Sample count (fixed at construction; every harvest covers all n).
    n: usize,
    /// Embedding width of the current harvest (0 until the first one).
    dim: usize,
    /// `[n, dim]` row-major features.
    feats: Vec<f32>,
    /// Epoch whose post-training parameters produced the rows, once a
    /// harvest has committed.
    harvest_epoch: Option<u32>,
}

impl FeatureCache {
    /// An empty cache for `n` samples; [`FeatureCache::ready`] is false
    /// until the first committed harvest.
    pub fn new(n: usize) -> Self {
        FeatureCache { n, dim: 0, feats: Vec::new(), harvest_epoch: None }
    }

    /// Sample count the cache was sized for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Embedding width of the current rows (0 when never harvested).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Whether a committed harvest is available to score from.
    pub fn ready(&self) -> bool {
        self.harvest_epoch.is_some() && self.dim > 0
    }

    /// Epoch stamped by the last committed harvest.
    pub fn harvest_epoch(&self) -> Option<u32> {
        self.harvest_epoch
    }

    /// Epochs the cached rows lag `epoch` (0 when not ready — a cache
    /// that cannot be scored from has no meaningful age).
    pub fn age(&self, epoch: u32) -> usize {
        match self.harvest_epoch {
            Some(h) if self.ready() => epoch.saturating_sub(h) as usize,
            _ => 0,
        }
    }

    /// Start a harvest at embedding width `dim`: (re)allocates the row
    /// store and drops the previous stamp, so a harvest that errors
    /// mid-sweep leaves the cache not-ready instead of half-stale.
    pub fn begin(&mut self, dim: usize) -> anyhow::Result<()> {
        anyhow::ensure!(dim > 0, "feature cache rows must be non-empty");
        self.dim = dim;
        self.harvest_epoch = None;
        self.feats.clear();
        self.feats.resize(self.n * dim, 0.0);
        Ok(())
    }

    /// Store one sample's embedding row (during a harvest sweep).
    pub fn store_row(&mut self, sample: usize, row: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(
            row.len() == self.dim && self.dim > 0,
            "feature row width {} != cache dim {} (begin() not called?)",
            row.len(),
            self.dim
        );
        anyhow::ensure!(sample < self.n, "sample {sample} out of range (n = {})", self.n);
        self.feats[sample * self.dim..(sample + 1) * self.dim].copy_from_slice(row);
        Ok(())
    }

    /// Commit the harvest: stamp the rows with the epoch whose parameters
    /// produced them.  Scoring is only legal after this.
    pub fn commit(&mut self, epoch: u32) {
        self.harvest_epoch = Some(epoch);
    }

    /// One sample's cached row.
    pub fn row(&self, sample: usize) -> &[f32] {
        &self.feats[sample * self.dim..(sample + 1) * self.dim]
    }

    /// The PFB proxy (arXiv 2506.23674): per-class centroids in feature
    /// space, then each sample's Euclidean distance to its own class
    /// centroid.  Samples *closest* to their centroid are the most
    /// redundant — pruning the smallest distances removes the examples
    /// the model has already consolidated.  Accumulation runs in fixed
    /// sample-index order with f64 sums, so the scores are deterministic
    /// for identical cached rows (the exact-resume contract).
    pub fn centroid_distances(&self, data: &Dataset) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(self.ready(), "feature cache not ready (no committed harvest)");
        anyhow::ensure!(
            data.n == self.n,
            "dataset n {} != cache n {}",
            data.n,
            self.n
        );
        let dim = self.dim;
        let mut sums = vec![0.0f64; data.classes * dim];
        let mut counts = vec![0usize; data.classes];
        for i in 0..self.n {
            let c = data.label(i) as usize;
            counts[c] += 1;
            let row = self.row(i);
            let acc = &mut sums[c * dim..(c + 1) * dim];
            for (a, &v) in acc.iter_mut().zip(row) {
                *a += v as f64;
            }
        }
        let mut scores = Vec::with_capacity(self.n);
        for i in 0..self.n {
            let c = data.label(i) as usize;
            let count = counts[c].max(1) as f64;
            let centroid = &sums[c * dim..(c + 1) * dim];
            let mut d2 = 0.0f64;
            for (&v, &s) in self.row(i).iter().zip(centroid) {
                let diff = v as f64 - s / count;
                d2 += diff * diff;
            }
            scores.push(d2.sqrt() as f32);
        }
        Ok(scores)
    }

    /// Snapshot the cache for the resume payload: `(dim, harvest_epoch,
    /// rows)`, or `None` when there is nothing to persist.
    pub fn export(&self) -> Option<(usize, u32, &[f32])> {
        let epoch = self.harvest_epoch?;
        if self.dim == 0 {
            return None;
        }
        Some((self.dim, epoch, &self.feats))
    }

    /// Restore a snapshot previously produced by [`FeatureCache::export`].
    pub fn import(&mut self, dim: usize, epoch: u32, feats: Vec<f32>) -> anyhow::Result<()> {
        anyhow::ensure!(dim > 0, "imported feature cache dim must be > 0");
        anyhow::ensure!(
            feats.len() == self.n * dim,
            "imported feature cache len {} != n ({}) * dim ({})",
            feats.len(),
            self.n,
            dim
        );
        self.dim = dim;
        self.feats = feats;
        self.harvest_epoch = Some(epoch);
        Ok(())
    }

    /// Drop any harvested rows (a resume with no cache payload, or a
    /// restart): the next plan falls back to a full epoch until the
    /// strategy's refresh cadence re-harvests.
    pub fn invalidate(&mut self) {
        self.dim = 0;
        self.feats.clear();
        self.harvest_epoch = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gauss_mixture, GaussMixtureCfg};

    fn tiny(n: usize) -> Dataset {
        gauss_mixture(
            &GaussMixtureCfg { n_train: n, n_val: 4, dim: 4, classes: 2, ..Default::default() },
            3,
        )
        .train
    }

    #[test]
    fn lifecycle_begin_store_commit() {
        let mut c = FeatureCache::new(3);
        assert!(!c.ready());
        assert_eq!(c.age(5), 0);
        c.begin(2).unwrap();
        assert!(!c.ready(), "uncommitted harvest must not be scoreable");
        c.store_row(0, &[1.0, 0.0]).unwrap();
        c.store_row(1, &[0.0, 1.0]).unwrap();
        c.store_row(2, &[1.0, 1.0]).unwrap();
        assert!(c.store_row(3, &[0.0, 0.0]).is_err());
        assert!(c.store_row(0, &[0.0]).is_err());
        c.commit(4);
        assert!(c.ready());
        assert_eq!(c.harvest_epoch(), Some(4));
        assert_eq!(c.age(6), 2);
        // a fresh begin() drops the stamp until the new commit
        c.begin(2).unwrap();
        assert!(!c.ready());
    }

    #[test]
    fn centroid_distance_prefers_outliers() {
        let d = tiny(4);
        let mut c = FeatureCache::new(4);
        c.begin(2).unwrap();
        // class layout comes from the synthetic set; score against the
        // rows we store, grouping by the dataset's own labels
        let far: Vec<usize> = (0..4).filter(|&i| i % 2 == 1).collect();
        for i in 0..4 {
            if far.contains(&i) {
                c.store_row(i, &[10.0 + i as f32, -10.0]).unwrap();
            } else {
                c.store_row(i, &[0.1, 0.1]).unwrap();
            }
        }
        c.commit(0);
        let scores = c.centroid_distances(&d).unwrap();
        assert_eq!(scores.len(), 4);
        assert!(scores.iter().all(|s| s.is_finite()));
        // identical rows score identically; scoring is deterministic
        let again = c.centroid_distances(&d).unwrap();
        let a: Vec<u32> = scores.iter().map(|s| s.to_bits()).collect();
        let b: Vec<u32> = again.iter().map(|s| s.to_bits()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn export_import_round_trips_bitwise() {
        let mut c = FeatureCache::new(2);
        assert!(c.export().is_none());
        c.begin(3).unwrap();
        c.store_row(0, &[0.25, -1.5, 3.75]).unwrap();
        c.store_row(1, &[1.0e-7, 2.0, -0.0]).unwrap();
        c.commit(7);
        let (dim, epoch, rows) = c.export().unwrap();
        let rows = rows.to_vec();
        let mut r = FeatureCache::new(2);
        r.import(dim, epoch, rows.clone()).unwrap();
        assert!(r.ready());
        assert_eq!(r.harvest_epoch(), Some(7));
        let a: Vec<u32> = rows.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = (0..2).flat_map(|i| r.row(i).iter().map(|v| v.to_bits())).collect();
        assert_eq!(a, b);
        assert!(r.import(0, 1, vec![]).is_err());
        assert!(r.import(2, 1, vec![0.0; 3]).is_err());
        r.invalidate();
        assert!(!r.ready());
        assert!(r.export().is_none());
    }
}
