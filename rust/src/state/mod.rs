//! Per-sample training state: the "lagging" loss / PA / PC store.
//!
//! Paper §3.4: per-sample statistics are recorded when the sample passes
//! through the training forward pass (so they lag the final model by up to
//! one epoch), and only the hidden list is refreshed with an extra forward
//! pass at epoch end.  This store is the single source of truth that the
//! hiding selector, the baselines (ISWR / SB / FORGET), and all the
//! per-class diagnostics (Figs. 6-8) read from.

pub mod features;

pub use features::FeatureCache;

use crate::data::Dataset;

/// Per-sample lagging statistics: the store the hiding selector, the
/// baselines, and the per-class diagnostics all read from.  Snapshotted
/// wholesale by the exact-resume path (`coordinator/resume.rs`) beside
/// the model checkpoint.
#[derive(Clone)]
pub struct SampleState {
    /// Sample count (every vector below has this length).
    pub n: usize,
    /// Lagging per-sample loss (sorting key for hiding / ISWR weights).
    pub loss: Vec<f32>,
    /// Prediction accuracy (PA): was the sample predicted correctly the
    /// last time we saw it?
    pub correct: Vec<bool>,
    /// Prediction confidence (PC): max softmax prob at last evaluation.
    pub conf: Vec<f32>,
    /// Hidden in the current epoch.
    pub hidden: Vec<bool>,
    /// Hidden in the previous epoch (for the "hidden again" diagnostic,
    /// Fig. 8).
    pub hidden_prev: Vec<bool>,
    /// FORGET baseline: number of correct->incorrect transitions observed.
    pub forget_events: Vec<u32>,
    /// Whether the sample has ever been predicted correctly (samples never
    /// learned count as forgettable in [13]).
    pub ever_correct: Vec<bool>,
    /// Epochs since stats were last updated (staleness diagnostics).
    pub last_update_epoch: Vec<u32>,
    /// How many times the sample has been hidden over the run (Figs. 6/7).
    pub hide_count: Vec<u32>,
    /// Running count of `hidden` bits, maintained incrementally by
    /// `set_hidden`/`roll_epoch` so the per-epoch metrics roll-up is O(1)
    /// instead of a full-N scan (the scans survive as debug assertions).
    hidden_now: usize,
    /// Running count of samples hidden both this epoch and the previous
    /// one (Fig. 8), maintained like `hidden_now`.
    hidden_again_now: usize,
}

impl SampleState {
    /// A fresh store for `n` samples (optimistic init — see below).
    pub fn new(n: usize) -> Self {
        SampleState {
            n,
            // Optimistic init: +inf loss means "never seen, definitely keep"
            // — every sample must be trained on at least once before it can
            // be hidden (matches the paper: hiding starts from epoch 1).
            loss: vec![f32::INFINITY; n],
            correct: vec![false; n],
            conf: vec![0.0; n],
            hidden: vec![false; n],
            hidden_prev: vec![false; n],
            forget_events: vec![0; n],
            ever_correct: vec![false; n],
            last_update_epoch: vec![0; n],
            hide_count: vec![0; n],
            hidden_now: 0,
            hidden_again_now: 0,
        }
    }

    /// Record fresh stats for one sample (from a training or refresh
    /// forward pass).  Tracks forgetting events for the FORGET baseline.
    #[inline]
    pub fn record(&mut self, i: usize, loss: f32, correct: bool, conf: f32, epoch: u32) {
        if self.correct[i] && !correct {
            self.forget_events[i] += 1;
        }
        if correct {
            self.ever_correct[i] = true;
        }
        self.loss[i] = loss;
        self.correct[i] = correct;
        self.conf[i] = conf;
        self.last_update_epoch[i] = epoch;
    }

    /// Move to the next epoch's hidden bookkeeping: `hidden` becomes
    /// `hidden_prev`, and `hidden` is cleared for the selector to refill.
    pub fn roll_epoch(&mut self) {
        std::mem::swap(&mut self.hidden, &mut self.hidden_prev);
        self.hidden.iter_mut().for_each(|h| *h = false);
        self.hidden_now = 0;
        self.hidden_again_now = 0;
    }

    /// Mark the hidden set for this epoch (after selection).
    pub fn set_hidden(&mut self, hidden_indices: &[u32]) {
        for &i in hidden_indices {
            let i = i as usize;
            if !self.hidden[i] {
                self.hidden[i] = true;
                self.hidden_now += 1;
                if self.hidden_prev[i] {
                    self.hidden_again_now += 1;
                }
            }
            self.hide_count[i] += 1;
        }
        debug_assert_eq!(self.hidden_now, self.hidden.iter().filter(|&&h| h).count());
    }

    /// How many samples are hidden this epoch — O(1), incrementally
    /// maintained (the debug build cross-checks against the full scan).
    pub fn hidden_count(&self) -> usize {
        debug_assert_eq!(
            self.hidden_now,
            self.hidden.iter().filter(|&&h| h).count()
        );
        self.hidden_now
    }

    /// Samples hidden both this epoch and the previous one (Fig. 8) —
    /// O(1), incrementally maintained like [`SampleState::hidden_count`].
    pub fn hidden_again_count(&self) -> usize {
        debug_assert_eq!(
            self.hidden_again_now,
            self.hidden
                .iter()
                .zip(&self.hidden_prev)
                .filter(|(&a, &b)| a && b)
                .count()
        );
        self.hidden_again_now
    }

    /// Recompute the incremental counters from the bit vectors — used
    /// after a checkpoint restore writes the vectors wholesale.
    pub fn rebuild_counters(&mut self) {
        self.hidden_now = self.hidden.iter().filter(|&&h| h).count();
        self.hidden_again_now = self
            .hidden
            .iter()
            .zip(&self.hidden_prev)
            .filter(|(&a, &b)| a && b)
            .count();
    }

    /// Per-class hidden counts (Figs. 6/7).
    pub fn hidden_per_class(&self, data: &Dataset) -> Vec<usize> {
        let mut counts = vec![0usize; data.classes];
        for i in 0..self.n {
            if self.hidden[i] {
                counts[data.label(i) as usize] += 1;
            }
        }
        counts
    }

    /// True where the sample was correctly predicted with confidence >= tau
    /// at its last evaluation — the paper's move-back predicate (§3.1).
    #[inline]
    pub fn high_confidence_correct(&self, i: usize, tau: f32) -> bool {
        self.correct[i] && self.conf[i] >= tau
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gauss_mixture, GaussMixtureCfg};

    #[test]
    fn optimistic_init_keeps_unseen_samples() {
        let s = SampleState::new(4);
        assert!(s.loss.iter().all(|l| l.is_infinite()));
        assert!(!s.high_confidence_correct(0, 0.7));
    }

    #[test]
    fn record_tracks_forgetting() {
        let mut s = SampleState::new(2);
        s.record(0, 1.0, true, 0.9, 0);
        assert_eq!(s.forget_events[0], 0);
        s.record(0, 2.0, false, 0.4, 1); // correct -> incorrect: forgotten
        assert_eq!(s.forget_events[0], 1);
        s.record(0, 0.5, true, 0.8, 2);
        s.record(0, 0.4, true, 0.9, 3); // stays correct: no event
        assert_eq!(s.forget_events[0], 1);
        assert!(s.ever_correct[0]);
        assert!(!s.ever_correct[1]);
    }

    #[test]
    fn roll_epoch_moves_hidden() {
        let mut s = SampleState::new(3);
        s.set_hidden(&[1]);
        assert_eq!(s.hidden_count(), 1);
        s.roll_epoch();
        assert_eq!(s.hidden_count(), 0);
        assert!(s.hidden_prev[1]);
        s.set_hidden(&[1, 2]);
        assert_eq!(s.hidden_again_count(), 1); // only idx 1 repeats
        assert_eq!(s.hide_count[1], 2);
    }

    #[test]
    fn move_back_predicate() {
        let mut s = SampleState::new(1);
        s.record(0, 0.1, true, 0.69, 0);
        assert!(!s.high_confidence_correct(0, 0.7));
        s.record(0, 0.1, true, 0.71, 1);
        assert!(s.high_confidence_correct(0, 0.7));
        s.record(0, 0.1, false, 0.99, 2);
        assert!(!s.high_confidence_correct(0, 0.7));
    }

    #[test]
    fn incremental_counters_track_scans() {
        let mut s = SampleState::new(8);
        s.set_hidden(&[0, 2, 4]);
        assert_eq!(s.hidden_count(), 3);
        assert_eq!(s.hidden_again_count(), 0);
        s.roll_epoch();
        s.set_hidden(&[2, 4, 6]);
        assert_eq!(s.hidden_count(), 3);
        assert_eq!(s.hidden_again_count(), 2); // 2 and 4 repeat
        // duplicate marks neither double-count the hidden totals ...
        s.set_hidden(&[2]);
        assert_eq!(s.hidden_count(), 3);
        assert_eq!(s.hidden_again_count(), 2);
        // ... but still bump the per-sample hide tally, as before
        assert_eq!(s.hide_count[2], 3);
        // wholesale vector writes rebuild the counters
        s.hidden = vec![true; 8];
        s.hidden_prev = vec![false; 8];
        s.rebuild_counters();
        assert_eq!(s.hidden_count(), 8);
        assert_eq!(s.hidden_again_count(), 0);
    }

    #[test]
    fn per_class_hidden_counts() {
        let tv = gauss_mixture(
            &GaussMixtureCfg { n_train: 30, n_val: 5, dim: 4, classes: 3, ..Default::default() },
            1,
        );
        let mut s = SampleState::new(30);
        s.set_hidden(&[0, 1, 2, 3, 4]);
        let counts = s.hidden_per_class(&tv.train);
        assert_eq!(counts.iter().sum::<usize>(), 5);
    }
}
