//! Experiment presets: one per paper workload (Appendix B, Table 7/8),
//! scaled to this testbed (proxy datasets, CPU-PJRT compute).
//!
//! Epoch counts and dataset sizes are reduced from the paper's scale (e.g.
//! ImageNet 100 epochs x 1.28M samples -> 24 epochs x 8192 samples): the
//! reproduction targets *relative* behaviour across strategies, and every
//! preset keeps the paper's schedule structure (warmup + step/cosine decay,
//! fraction milestones at the same relative positions).

use super::*;
use crate::schedule::{LrConfig, LrSchedule};

/// CIFAR-100 / WideResNet-28-10 stand-in (paper: 200 epochs, step LR).
pub fn cifar100_wrn() -> ExperimentConfig {
    let mut c = ExperimentConfig::new(
        "cifar100_wrn",
        "mlp_c100_b64",
        DatasetConfig::GaussMixture(GaussMixtureCfg::default()),
        StrategyConfig::Baseline,
    );
    c.epochs = 30;
    c.lr = LrConfig {
        base_lr: 0.08,
        // paper: decay 0.2 at [60,120,160]/200 -> same relative milestones
        schedule: LrSchedule::Step { milestones: vec![9, 18, 24], rate: 0.2 },
        warmup_epochs: 1,
    };
    c
}

/// ImageNet-1K / ResNet-50 (A) stand-in (paper: 100 epochs, step 0.1 at
/// [30,60,80], linear warmup 5).
pub fn imagenet_resnet50() -> ExperimentConfig {
    let mut c = ExperimentConfig::new(
        "imagenet_resnet50",
        "cnn_c32_b64",
        DatasetConfig::ImagenetProxy(ImagenetProxyCfg::default()),
        StrategyConfig::Baseline,
    );
    c.epochs = 24;
    c.lr = LrConfig {
        base_lr: 0.06,
        schedule: LrSchedule::Step { milestones: vec![7, 14, 19], rate: 0.1 },
        warmup_epochs: 2,
    };
    c.workers = 4;
    c
}

/// ImageNet-1K / ResNet-50 (B) stand-in (cosine annealing, 600-epoch
/// regime scaled down).
pub fn imagenet_resnet50_b() -> ExperimentConfig {
    let mut c = imagenet_resnet50();
    c.name = "imagenet_resnet50_b".into();
    c.epochs = 36;
    c.lr = LrConfig {
        base_lr: 0.08,
        schedule: LrSchedule::Cosine { total: 36 },
        warmup_epochs: 2,
    };
    c
}

/// EfficientNet-b3 stand-in (wider CNN, exp decay 0.9 every 2 epochs).
pub fn imagenet_efficientnet() -> ExperimentConfig {
    let mut c = ExperimentConfig::new(
        "imagenet_efficientnet",
        "cnnw_c32_b64",
        DatasetConfig::ImagenetProxy(ImagenetProxyCfg::default()),
        StrategyConfig::Baseline,
    );
    c.epochs = 24;
    c.lr = LrConfig {
        base_lr: 0.05,
        schedule: LrSchedule::ExpEvery { every: 2, rate: 0.9 },
        warmup_epochs: 2,
    };
    c.workers = 4;
    c
}

/// DeepCAM stand-in (paper: 35 epochs, 1024 GPUs, top LR 0.0055).
pub fn deepcam() -> ExperimentConfig {
    let mut c = ExperimentConfig::new(
        "deepcam",
        "segnet_b32",
        DatasetConfig::DeepcamProxy(DeepcamProxyCfg::default()),
        StrategyConfig::Baseline,
    );
    c.epochs = 18;
    c.lr = LrConfig {
        base_lr: 0.04,
        schedule: LrSchedule::Cosine { total: 18 },
        warmup_epochs: 1,
    };
    c.workers = 8;
    c
}

/// Fractal-3K upstream pretraining (DeiT-Tiny stand-in, Table 4).
pub fn fractal_pretrain() -> ExperimentConfig {
    let mut c = ExperimentConfig::new(
        "fractal_pretrain",
        "mlp_c64_b64",
        DatasetConfig::Fractal(FractalCfg::default()),
        StrategyConfig::Baseline,
    );
    c.epochs = 20;
    c.lr = LrConfig {
        base_lr: 0.06,
        schedule: LrSchedule::Cosine { total: 20 },
        warmup_epochs: 2,
    };
    c
}

/// Downstream fine-tuning preset (CIFAR-10 proxy head).
pub fn transfer_downstream() -> ExperimentConfig {
    let mut c = ExperimentConfig::new(
        "transfer_downstream",
        "mlp_c10_b64",
        DatasetConfig::GaussMixture(GaussMixtureCfg {
            classes: 10,
            n_train: 3072,
            n_val: 1024,
            ..Default::default()
        }),
        StrategyConfig::Baseline,
    );
    c.epochs = 16;
    c.lr = LrConfig {
        base_lr: 0.03,
        schedule: LrSchedule::Cosine { total: 16 },
        warmup_epochs: 1,
    };
    c
}

/// Single-GPU GradMatch comparison setting (Table 3: CIFAR-100/ResNet-18).
pub fn gradmatch_setting() -> ExperimentConfig {
    let mut c = ExperimentConfig::new(
        "gradmatch_cifar",
        "cnn_c100_b64",
        DatasetConfig::ImagenetProxy(ImagenetProxyCfg {
            classes: 100,
            n_train: 6144,
            n_val: 1536,
            ..Default::default()
        }),
        StrategyConfig::Baseline,
    );
    c.epochs = 20;
    c.workers = 1;
    c.lr = LrConfig {
        base_lr: 0.06,
        schedule: LrSchedule::Cosine { total: 20 },
        warmup_epochs: 1,
    };
    c
}

/// Look up a preset by name (CLI / launcher).
pub fn by_name(name: &str) -> anyhow::Result<ExperimentConfig> {
    Ok(match name {
        "cifar100_wrn" => cifar100_wrn(),
        "imagenet_resnet50" => imagenet_resnet50(),
        "imagenet_resnet50_b" => imagenet_resnet50_b(),
        "imagenet_efficientnet" => imagenet_efficientnet(),
        "deepcam" => deepcam(),
        "fractal_pretrain" => fractal_pretrain(),
        "transfer_downstream" => transfer_downstream(),
        "gradmatch_cifar" => gradmatch_setting(),
        other => anyhow::bail!(
            "unknown preset {other:?}; available: cifar100_wrn, imagenet_resnet50, \
             imagenet_resnet50_b, imagenet_efficientnet, deepcam, fractal_pretrain, \
             transfer_downstream, gradmatch_cifar"
        ),
    })
}

/// Every preset name [`by_name`] accepts (sweeps, `--help` listings).
pub const ALL: &[&str] = &[
    "cifar100_wrn",
    "imagenet_resnet50",
    "imagenet_resnet50_b",
    "imagenet_efficientnet",
    "deepcam",
    "fractal_pretrain",
    "transfer_downstream",
    "gradmatch_cifar",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_valid() {
        for name in ALL {
            let c = by_name(name).unwrap();
            c.validate().unwrap();
            assert_eq!(&c.name, name);
        }
        assert!(by_name("nope").is_err());
    }
}
