//! Experiment configuration: typed configs, JSON loading, CLI overrides,
//! and presets mirroring the paper's Appendix B hyper-parameter tables.

/// Named experiment presets mirroring the paper's workloads.
pub mod presets;

use std::path::PathBuf;

use crate::data::image::{DeepcamProxyCfg, ImagenetProxyCfg};
use crate::data::synth::{FractalCfg, GaussMixtureCfg};
use crate::data::TrainVal;
use crate::hiding::selector::SelectMode;
use crate::schedule::{LrConfig, LrSchedule};
use crate::util::json::Json;

/// Which synthetic proxy dataset to train on (DESIGN.md §3).
#[derive(Clone, Debug)]
pub enum DatasetConfig {
    /// Gaussian-mixture classification (the CIFAR-scale proxy).
    GaussMixture(GaussMixtureCfg),
    /// Hard/easy split with per-band noise (the ImageNet proxy).
    ImagenetProxy(ImagenetProxyCfg),
    /// Channel-heavy segmentation-style proxy (the DeepCAM workload).
    DeepcamProxy(DeepcamProxyCfg),
    /// Fractal-boundary classes (the FractalDB transfer source).
    Fractal(FractalCfg),
}

impl DatasetConfig {
    /// Generate the train + validation split deterministically in `seed`.
    pub fn generate(&self, seed: u64) -> TrainVal {
        match self {
            DatasetConfig::GaussMixture(c) => crate::data::synth::gauss_mixture(c, seed),
            DatasetConfig::ImagenetProxy(c) => crate::data::image::imagenet_proxy(c, seed),
            DatasetConfig::DeepcamProxy(c) => crate::data::image::deepcam_proxy(c, seed),
            DatasetConfig::Fractal(c) => crate::data::synth::fractal_proxy(c, seed),
        }
    }

    /// Short dataset-family name (logs / result JSON).
    pub fn kind(&self) -> &'static str {
        match self {
            DatasetConfig::GaussMixture(_) => "gauss_mixture",
            DatasetConfig::ImagenetProxy(_) => "imagenet_proxy",
            DatasetConfig::DeepcamProxy(_) => "deepcam_proxy",
            DatasetConfig::Fractal(_) => "fractal",
        }
    }
}

/// KAKURENBO component switches (Table 6 ablation: HE/MB/RF/LR).
#[derive(Clone, Copy, Debug)]
pub struct Components {
    /// HE: hide the highest-loss fraction each epoch.
    pub hide: bool,
    /// MB: move back samples whose prediction flipped to correct.
    pub move_back: bool,
    /// RF: reduce the hidden fraction when the loss spread narrows.
    pub reduce_fraction: bool,
    /// LR: scale the learning rate by the visible-set fraction.
    pub adjust_lr: bool,
}

impl Components {
    /// All four components on — the paper's full KAKURENBO (v1111).
    pub const ALL: Components = Components {
        hide: true,
        move_back: true,
        reduce_fraction: true,
        adjust_lr: true,
    };

    /// Parse the paper's vXXXX naming: v1011 = HE, no MB, RF, LR.
    pub fn from_bits(name: &str) -> anyhow::Result<Self> {
        let bits: Vec<char> = name.trim_start_matches('v').chars().collect();
        anyhow::ensure!(bits.len() == 4, "expected vXXXX, got {name}");
        let b = |i: usize| bits[i] == '1';
        Ok(Components { hide: b(0), move_back: b(1), reduce_fraction: b(2), adjust_lr: b(3) })
    }

    /// Render the paper's vXXXX naming (inverse of
    /// [`Components::from_bits`]).
    pub fn label(&self) -> String {
        format!(
            "v{}{}{}{}",
            self.hide as u8, self.move_back as u8, self.reduce_fraction as u8, self.adjust_lr as u8
        )
    }
}

/// Which sample-selection strategy the run trains with (the catalog in
/// docs/strategies.md).
#[derive(Clone, Debug)]
pub enum StrategyConfig {
    /// Uniform sampling without replacement (paper "Baseline").
    Baseline,
    /// KAKURENBO (§3) with component switches and optional DropTop (App. D).
    Kakurenbo {
        /// Maximum fraction of the dataset hidden per epoch (paper F).
        max_fraction: f64,
        /// Confidence threshold for the move-back test (paper τ).
        tau: f32,
        /// HE/MB/RF/LR component switches (Table 6 ablation).
        components: Components,
        /// DropTop: additionally drop this top-loss fraction (App. D).
        drop_top: f64,
        /// Exact-threshold selection algorithm (sort vs quickselect).
        select_mode: SelectMode,
    },
    /// Importance Sampling With Replacement [11].
    Iswr,
    /// Selective-Backprop [17].
    SelectiveBackprop {
        /// CDF sharpening exponent: accept probability is CDF^beta.
        beta: f64,
    },
    /// Online FORGET pruning [13]: train `prune_epoch` epochs, prune the
    /// least-forgettable fraction, restart.
    Forget {
        /// Epoch at which pruning (and the restart) happens.
        prune_epoch: usize,
        /// Fraction of the dataset pruned at the restart.
        fraction: f64,
    },
    /// GradMatch [18] (simplified per-class last-layer OMP, every R epochs).
    GradMatch {
        /// Coreset fraction kept per selection round.
        fraction: f64,
        /// Re-select the coreset every R epochs.
        every_r: usize,
    },
    /// Random hiding baseline (Table 9 / GradMatch paper).
    RandomHiding {
        /// Fraction hidden uniformly at random each epoch.
        fraction: f64,
    },
    /// InfoBatch [28] extension: unbiased dynamic pruning with rescaling.
    InfoBatch {
        /// Pruning probability applied to the below-mean-loss half.
        r: f64,
    },
    /// EL2N [15] extension: early error-norm scoring + permanent pruning.
    El2n {
        /// Epoch at which EL2N scores are computed.
        score_epoch: usize,
        /// Fraction of the dataset pruned after scoring.
        fraction: f64,
        /// Whether training restarts from scratch after the prune.
        restart: bool,
    },
    /// Partial Forward Blocking (arXiv 2506.23674) extension: per-epoch
    /// pruning scored from a cached-feature centroid-distance proxy, with
    /// the embedding cache refreshed every `refresh_every` epochs.
    Pfb {
        /// Fraction of the dataset pruned (pre-forward) per scored epoch.
        fraction: f64,
        /// Re-harvest the feature cache every N epochs (`--pfb-refresh-every`).
        refresh_every: usize,
    },
}

/// Which worker-pool schedule multi-worker (`--workers N`) training uses.
///
/// See docs/worker-model.md ("The two schedules") for the full trade-off.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DpMode {
    /// Deterministic serial-equivalent schedule (default): all device
    /// steps run on the primary backend in fixed `(step, worker)` order;
    /// only the host gather fans out.  Results are bitwise identical to
    /// the single-stream interleaved run, so every recorded number is
    /// independent of thread scheduling.
    #[default]
    SerialEquivalent,
    /// True synchronous-SGD parameter averaging: each worker trains its
    /// own backend replica and parameters are averaged in fixed worker
    /// order at every step barrier (global batch = `W × B`).  This is the
    /// paper's distributed algorithm — deterministic run to run, but a
    /// different (global-batch) trajectory than the serial schedule.
    Average,
}

impl DpMode {
    /// Parse the `--dp` CLI value.
    pub fn parse(value: &str) -> anyhow::Result<Self> {
        match value {
            "serial-equivalent" | "serial_equivalent" | "serial" => {
                Ok(DpMode::SerialEquivalent)
            }
            "average" | "avg" => Ok(DpMode::Average),
            other => anyhow::bail!(
                "unknown --dp mode {other:?}; expected \"serial-equivalent\" or \"average\""
            ),
        }
    }

    /// Canonical CLI spelling (logs / result JSON).
    pub fn name(&self) -> &'static str {
        match self {
            DpMode::SerialEquivalent => "serial-equivalent",
            DpMode::Average => "average",
        }
    }
}

/// What the worker pool does when a lane dies or stalls past the
/// straggler timeout mid-run (`--fault-policy`).
///
/// See docs/worker-model.md ("Fault tolerance") for the recovery
/// contract and guidance on choosing a policy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FaultPolicy {
    /// Abort the run with a named error at the first lane fault
    /// (default).  Nothing is retried; combine with `--checkpoint-every`
    /// and `--resume` to restart from the last committed generation.
    #[default]
    Fail,
    /// Retire the faulty lane and deterministically re-issue its
    /// unfinished shard slices to surviving lanes.  The `(step, worker)`
    /// fold order is preserved, so the recovered run stays bitwise
    /// identical to an undisturbed run of the same logical order.
    Elastic,
}

impl FaultPolicy {
    /// Parse the `--fault-policy` CLI value.
    pub fn parse(value: &str) -> anyhow::Result<Self> {
        match value {
            "fail" => Ok(FaultPolicy::Fail),
            "elastic" => Ok(FaultPolicy::Elastic),
            other => anyhow::bail!(
                "unknown --fault-policy {other:?}; expected \"fail\" or \"elastic\""
            ),
        }
    }

    /// Canonical CLI spelling (logs / result JSON).
    pub fn name(&self) -> &'static str {
        match self {
            FaultPolicy::Fail => "fail",
            FaultPolicy::Elastic => "elastic",
        }
    }
}

/// Parse an on/off CLI switch (`on`/`off`, with the usual boolean
/// spellings accepted).  `flag` names the option in the error message.
pub fn parse_switch(flag: &str, value: &str) -> anyhow::Result<bool> {
    match value {
        "on" | "true" | "1" | "yes" => Ok(true),
        "off" | "false" | "0" | "no" => Ok(false),
        other => anyhow::bail!(
            "unknown {flag} value {other:?}; expected \"on\" or \"off\""
        ),
    }
}

/// Parse the `--service-lane` CLI value (`on`/`off`, with the usual
/// boolean spellings accepted).
pub fn parse_service_lane(value: &str) -> anyhow::Result<bool> {
    parse_switch("--service-lane", value)
}

impl StrategyConfig {
    /// Full KAKURENBO (all components, paper defaults) at `max_fraction`.
    pub fn kakurenbo(max_fraction: f64) -> Self {
        StrategyConfig::Kakurenbo {
            max_fraction,
            tau: 0.7,
            components: Components::ALL,
            drop_top: 0.0,
            select_mode: SelectMode::QuickSelect,
        }
    }

    /// Display name (logs, result JSON, bench tables).
    pub fn name(&self) -> String {
        match self {
            StrategyConfig::Baseline => "baseline".into(),
            StrategyConfig::Kakurenbo { components, .. } if *components
                == Components::ALL => "kakurenbo".into(),
            StrategyConfig::Kakurenbo { components, .. } => {
                format!("kakurenbo-{}", components.label())
            }
            StrategyConfig::Iswr => "iswr".into(),
            StrategyConfig::SelectiveBackprop { .. } => "sb".into(),
            StrategyConfig::Forget { .. } => "forget".into(),
            StrategyConfig::GradMatch { .. } => "gradmatch".into(),
            StrategyConfig::RandomHiding { .. } => "random".into(),
            StrategyConfig::InfoBatch { .. } => "infobatch".into(),
            StrategyConfig::El2n { .. } => "el2n".into(),
            StrategyConfig::Pfb { .. } => "pfb".into(),
        }
    }

    /// Whether the strategy's training pass is incompatible with the
    /// parameter-averaging schedule (`--dp average`): weighted plans
    /// (ISWR / InfoBatch / GradMatch) tie per-position gradient weights to
    /// the unsharded order, and Selective-Backprop's accept queue derives
    /// follow-up batches from step results — both are single-stream by
    /// construction (the paper also runs these baselines at W = 1).
    pub fn single_stream_only(&self) -> bool {
        matches!(
            self,
            StrategyConfig::Iswr
                | StrategyConfig::InfoBatch { .. }
                | StrategyConfig::GradMatch { .. }
                | StrategyConfig::SelectiveBackprop { .. }
        )
    }
}

impl PartialEq for Components {
    fn eq(&self, o: &Self) -> bool {
        self.hide == o.hide
            && self.move_back == o.move_back
            && self.reduce_fraction == o.reduce_fraction
            && self.adjust_lr == o.adjust_lr
    }
}

/// A complete experiment: model variant + dataset + strategy + schedules.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Experiment display name (results are filed under it).
    pub name: String,
    /// Artifact variant (manifest key), e.g. "cnn_c32_b64".
    pub variant: String,
    /// Which proxy dataset to generate and train on.
    pub dataset: DatasetConfig,
    /// The sample-selection strategy (docs/strategies.md).
    pub strategy: StrategyConfig,
    /// Total training epochs.
    pub epochs: usize,
    /// Master seed: dataset generation, parameter init, and the
    /// coordinator RNG stream all derive from it.
    pub seed: u64,
    /// Learning-rate schedule (base LR, decay, warmup).
    pub lr: LrConfig,
    /// SGD momentum coefficient.
    pub momentum: f32,
    /// Data-parallel worker count.  `> 1` executes plain training passes
    /// and hidden-stat refreshes through the engine's `WorkerPool` (N
    /// concurrent pipelined gather lanes behind a deterministic
    /// bulk-synchronous reduction — docs/worker-model.md) and also feeds
    /// the paper-scale cost-model projection.  The schedule the training
    /// pass uses is picked by [`ExperimentConfig::dp`].
    pub workers: usize,
    /// Worker-pool schedule for multi-worker training passes: the bitwise
    /// serial-equivalent default, or true parameter-averaging synchronous
    /// SGD (`--dp average`).  Ignored when `workers == 1`... except that
    /// `validate` rejects `Average` there outright, since a 1-replica
    /// average is the serial schedule wearing a costume.
    pub dp: DpMode,
    /// Evaluate on the validation set every k epochs (always on last).
    pub eval_every: usize,
    /// Run validation eval + checkpoint serialization on the async
    /// service lane (`--service-lane on`): both consume an exact exported
    /// parameter snapshot on a persistent background replica while the
    /// executor trains the next epoch, and results fold back into the
    /// epoch records in fixed epoch order.  Off (the default) keeps
    /// today's serial behavior.  Async eval is bitwise identical to sync
    /// eval (docs/snapshots.md).
    pub service_lane: bool,
    /// Directory holding the AOT-compiled HLO artifacts.
    pub artifacts_dir: PathBuf,
    /// Collect per-class hidden counts / loss histograms (Figs. 5-8).
    pub detailed_metrics: bool,
    /// Save a parameter checkpoint every k epochs (0 = disabled).
    pub checkpoint_every: usize,
    /// Directory for checkpoints (and resume source when `resume`).
    pub checkpoint_dir: Option<PathBuf>,
    /// Resume from the checkpoint in `checkpoint_dir` before training.
    pub resume: bool,
    /// Leaf write-pool worker threads for checkpoint serialization
    /// (`--checkpoint-pool N`).  0 (the default) auto-sizes from the host
    /// core count; 1 runs leaf writes inline (serial).
    pub checkpoint_pool: usize,
    /// Verify per-leaf sha256 digests against the manifest on checkpoint
    /// load (`--checkpoint-verify on|off`, default on).  Off skips the
    /// hash pass — faster loads, no corruption detection.
    pub checkpoint_verify: bool,
    /// LZSS-compress momentum leaves in Full-tier checkpoints
    /// (`--checkpoint-compress on|off`, default on).  Params are always
    /// stored raw; only the compressed-vs-raw momentum framing changes.
    pub checkpoint_compress: bool,
    /// Lane-fault handling for multi-worker runs (`--fault-policy
    /// fail|elastic`, default fail).  `elastic` retires dead or
    /// timed-out lanes and re-issues their remaining shard slices
    /// deterministically; `fail` aborts with a named error.
    pub fault_policy: FaultPolicy,
    /// Straggler detection timeout in milliseconds
    /// (`--straggler-timeout-ms N`, default 0 = disabled).  A lane that
    /// produces nothing for this long at a step barrier is treated as
    /// faulty under the active [`FaultPolicy`].
    pub straggler_timeout_ms: u64,
    /// Serve live snapshots over HTTP while training (`--serve <addr>`,
    /// e.g. `127.0.0.1:8080`; port 0 picks a free port).  Spawns the
    /// online inference lane: a dedicated serving replica subscribed to
    /// per-epoch params-tier snapshot publications, answering
    /// `/v1/stats` and `/v1/embed` queries (docs/serving.md).  `None`
    /// (the default) disables serving; training records are bitwise
    /// identical either way.
    pub serve: Option<String>,
    /// Worker threads for the inference HTTP front end
    /// (`--serve-threads N`, default 2).  Must be at least 1; forwards
    /// go to the serve fleet, which routes each query to the
    /// least-loaded serving replica.
    pub serve_threads: usize,
    /// Serving replicas (`--serve-replicas R`, default 1).  Each replica
    /// is its own lane thread built via the `ReplicaBuilder` contract,
    /// all reading the same snapshot hub; a failed replica degrades only
    /// its own lane.
    pub serve_replicas: usize,
    /// Micro-batch size for query coalescing (`--serve-batch N`, default
    /// 1 = off).  A serve lane dispatches as soon as N queries are
    /// buffered or the oldest has waited `--serve-batch-wait-us`,
    /// packing them into one batched device forward — answers are
    /// bitwise identical to per-query execution.
    pub serve_batch: usize,
    /// Coalescing wait budget in microseconds
    /// (`--serve-batch-wait-us T`, default 250).  Bounds the extra
    /// latency the first query of a batch can pay waiting for company.
    pub serve_batch_wait_us: u64,
    /// Snapshot publications the hub retains (`--serve-retain K`,
    /// default 2).  Older publications are freed; in-flight queries
    /// keep the publication they already loaded.
    pub serve_retain: usize,
}

impl ExperimentConfig {
    /// A config with the repo-wide defaults (30 epochs, seed 42, step LR
    /// with 2 warmup epochs, single worker, service lane off).
    pub fn new(name: &str, variant: &str, dataset: DatasetConfig, strategy: StrategyConfig) -> Self {
        ExperimentConfig {
            name: name.to_string(),
            variant: variant.to_string(),
            dataset,
            strategy,
            epochs: 30,
            seed: 42,
            lr: LrConfig {
                base_lr: 0.05,
                schedule: LrSchedule::Step { milestones: vec![], rate: 0.1 },
                warmup_epochs: 2,
            },
            momentum: 0.9,
            workers: 1,
            dp: DpMode::SerialEquivalent,
            eval_every: 1,
            service_lane: false,
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            detailed_metrics: false,
            checkpoint_every: 0,
            checkpoint_dir: None,
            resume: false,
            checkpoint_pool: 0,
            checkpoint_verify: true,
            checkpoint_compress: true,
            fault_policy: FaultPolicy::Fail,
            straggler_timeout_ms: 0,
            serve: None,
            serve_threads: 2,
            serve_replicas: 1,
            serve_batch: 1,
            serve_batch_wait_us: 250,
            serve_retain: 2,
        }
    }

    /// Reject inconsistent configs up front (bad ranges, `--dp average`
    /// with one worker or a single-stream strategy, ...).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.epochs > 0, "epochs must be positive");
        anyhow::ensure!(self.workers > 0, "workers must be positive");
        anyhow::ensure!((0.0..=1.0).contains(&(self.momentum as f64)), "momentum");
        if self.dp == DpMode::Average {
            anyhow::ensure!(
                self.workers > 1,
                "--dp average requires --workers > 1: parameter averaging \
                 across a single replica is just the serial-equivalent \
                 schedule (drop --dp, or raise --workers)"
            );
            anyhow::ensure!(
                !self.strategy.single_stream_only(),
                "--dp average is incompatible with strategy {:?}: weighted \
                 plans (iswr, infobatch, gradmatch) and selective-backprop \
                 are single-stream by construction (see docs/worker-model.md); \
                 use the default --dp serial-equivalent",
                self.strategy.name()
            );
        }
        if let StrategyConfig::Kakurenbo { max_fraction, tau, .. } = &self.strategy {
            anyhow::ensure!((0.0..1.0).contains(max_fraction), "max_fraction");
            anyhow::ensure!((0.0..=1.0).contains(&(*tau as f64)), "tau");
        }
        if let StrategyConfig::Forget { prune_epoch, .. } = &self.strategy {
            anyhow::ensure!(*prune_epoch < self.epochs, "prune_epoch >= epochs");
        }
        if let StrategyConfig::Pfb { fraction, refresh_every } = &self.strategy {
            anyhow::ensure!(
                (0.0..1.0).contains(fraction),
                "--pfb-fraction {fraction} out of range: must be in [0, 1) \
                 (pruning the whole dataset leaves nothing to train on)"
            );
            anyhow::ensure!(
                *refresh_every >= 1,
                "--pfb-refresh-every 0: the feature cache must be re-harvested \
                 at least every epoch (use 1 for per-epoch refresh)"
            );
        }
        anyhow::ensure!(
            self.checkpoint_pool <= 256,
            "--checkpoint-pool {} is implausibly large (max 256; 0 = auto)",
            self.checkpoint_pool
        );
        anyhow::ensure!(
            self.straggler_timeout_ms <= 600_000,
            "--straggler-timeout-ms {} is implausibly large (max 600000 = 10min; \
             0 = disabled)",
            self.straggler_timeout_ms
        );
        if let Some(addr) = &self.serve {
            anyhow::ensure!(
                addr.parse::<std::net::SocketAddr>().is_ok(),
                "--serve {addr:?} is not a socket address (expected host:port, \
                 e.g. 127.0.0.1:8080; port 0 picks a free port)"
            );
        }
        anyhow::ensure!(
            self.serve_threads >= 1,
            "--serve-threads 0: the inference server needs at least one worker"
        );
        anyhow::ensure!(
            self.serve_threads <= 256,
            "--serve-threads {} is implausibly large (max 256)",
            self.serve_threads
        );
        anyhow::ensure!(
            self.serve_replicas >= 1,
            "--serve-replicas 0: the serve fleet needs at least one replica"
        );
        anyhow::ensure!(
            self.serve_replicas <= 64,
            "--serve-replicas {} is implausibly large (max 64)",
            self.serve_replicas
        );
        anyhow::ensure!(
            self.serve_batch >= 1,
            "--serve-batch 0: the coalescing buffer needs at least one slot (1 = off)"
        );
        anyhow::ensure!(
            self.serve_batch <= 1024,
            "--serve-batch {} is implausibly large (max 1024)",
            self.serve_batch
        );
        anyhow::ensure!(
            self.serve_batch_wait_us <= 1_000_000,
            "--serve-batch-wait-us {} is implausibly large (max 1000000 = 1s)",
            self.serve_batch_wait_us
        );
        anyhow::ensure!(
            self.serve_retain >= 1,
            "--serve-retain 0: the hub must retain at least the live publication"
        );
        anyhow::ensure!(
            self.serve_retain <= 64,
            "--serve-retain {} is implausibly large (max 64)",
            self.serve_retain
        );
        Ok(())
    }

    /// Apply `--key=value` CLI overrides (a subset of fields that sweeps
    /// and the launcher need).
    pub fn apply_override(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        match key {
            "epochs" => self.epochs = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "workers" => self.workers = value.parse()?,
            "dp" => self.dp = DpMode::parse(value)?,
            "eval_every" => self.eval_every = value.parse()?,
            "service_lane" | "service-lane" => {
                self.service_lane = parse_service_lane(value)?
            }
            "base_lr" => self.lr.base_lr = value.parse()?,
            "warmup_epochs" => self.lr.warmup_epochs = value.parse()?,
            "momentum" => self.momentum = value.parse()?,
            "variant" => self.variant = value.to_string(),
            "detailed_metrics" => self.detailed_metrics = value.parse()?,
            "checkpoint_every" => self.checkpoint_every = value.parse()?,
            "checkpoint_dir" => self.checkpoint_dir = Some(PathBuf::from(value)),
            "resume" => self.resume = value.parse()?,
            "checkpoint_pool" | "checkpoint-pool" => {
                self.checkpoint_pool = value.parse()?
            }
            "checkpoint_verify" | "checkpoint-verify" => {
                self.checkpoint_verify = parse_switch("--checkpoint-verify", value)?
            }
            "checkpoint_compress" | "checkpoint-compress" => {
                self.checkpoint_compress = parse_switch("--checkpoint-compress", value)?
            }
            "fault_policy" | "fault-policy" => {
                self.fault_policy = FaultPolicy::parse(value)?
            }
            "straggler_timeout_ms" | "straggler-timeout-ms" => {
                self.straggler_timeout_ms = value.parse()?
            }
            "serve" => self.serve = Some(value.to_string()),
            "serve_threads" | "serve-threads" => self.serve_threads = value.parse()?,
            "serve_replicas" | "serve-replicas" => self.serve_replicas = value.parse()?,
            "serve_batch" | "serve-batch" => self.serve_batch = value.parse()?,
            "serve_batch_wait_us" | "serve-batch-wait-us" => {
                self.serve_batch_wait_us = value.parse()?
            }
            "serve_retain" | "serve-retain" => self.serve_retain = value.parse()?,
            "max_fraction" => match &mut self.strategy {
                StrategyConfig::Kakurenbo { max_fraction, .. } => *max_fraction = value.parse()?,
                StrategyConfig::Forget { fraction, .. }
                | StrategyConfig::GradMatch { fraction, .. }
                | StrategyConfig::El2n { fraction, .. }
                | StrategyConfig::Pfb { fraction, .. }
                | StrategyConfig::RandomHiding { fraction } => *fraction = value.parse()?,
                StrategyConfig::InfoBatch { r } => *r = value.parse()?,
                _ => anyhow::bail!("strategy has no fraction"),
            },
            "pfb_fraction" | "pfb-fraction" => match &mut self.strategy {
                StrategyConfig::Pfb { fraction, .. } => *fraction = value.parse()?,
                _ => anyhow::bail!("--pfb-fraction only applies to --strategy pfb"),
            },
            "pfb_refresh_every" | "pfb-refresh-every" => match &mut self.strategy {
                StrategyConfig::Pfb { refresh_every, .. } => {
                    *refresh_every = value.parse()?
                }
                _ => anyhow::bail!("--pfb-refresh-every only applies to --strategy pfb"),
            },
            "tau" => match &mut self.strategy {
                StrategyConfig::Kakurenbo { tau, .. } => *tau = value.parse()?,
                _ => anyhow::bail!("strategy has no tau"),
            },
            "drop_top" => match &mut self.strategy {
                StrategyConfig::Kakurenbo { drop_top, .. } => *drop_top = value.parse()?,
                _ => anyhow::bail!("strategy has no drop_top"),
            },
            _ => anyhow::bail!("unknown override key {key:?}"),
        }
        Ok(())
    }

    /// Summary for logs / result JSON.
    pub fn to_json(&self) -> Json {
        crate::jobj![
            ("name", self.name.as_str()),
            ("variant", self.variant.as_str()),
            ("dataset", self.dataset.kind()),
            ("strategy", self.strategy.name()),
            ("epochs", self.epochs),
            ("seed", self.seed as usize),
            ("workers", self.workers),
            ("dp", self.dp.name()),
            ("service_lane", self.service_lane),
            ("base_lr", self.lr.base_lr),
            ("momentum", self.momentum),
            ("checkpoint_pool", self.checkpoint_pool),
            ("checkpoint_verify", self.checkpoint_verify),
            ("checkpoint_compress", self.checkpoint_compress),
            ("fault_policy", self.fault_policy.name()),
            ("straggler_timeout_ms", self.straggler_timeout_ms as usize),
            ("serve", self.serve.clone().map(Json::from).unwrap_or(Json::Null)),
            ("serve_threads", self.serve_threads),
            ("serve_replicas", self.serve_replicas),
            ("serve_batch", self.serve_batch),
            ("serve_batch_wait_us", self.serve_batch_wait_us as usize),
            ("serve_retain", self.serve_retain),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn components_bits_roundtrip() {
        for name in ["v1000", "v1011", "v1111", "v1100"] {
            let c = Components::from_bits(name).unwrap();
            assert_eq!(c.label(), name);
        }
        assert!(Components::from_bits("v10").is_err());
    }

    #[test]
    fn overrides_apply() {
        let mut c = ExperimentConfig::new(
            "t",
            "cnn_c32_b64",
            DatasetConfig::ImagenetProxy(Default::default()),
            StrategyConfig::kakurenbo(0.3),
        );
        c.apply_override("epochs", "7").unwrap();
        c.apply_override("max_fraction", "0.4").unwrap();
        c.apply_override("tau", "0.9").unwrap();
        assert_eq!(c.epochs, 7);
        match c.strategy {
            StrategyConfig::Kakurenbo { max_fraction, tau, .. } => {
                assert_eq!(max_fraction, 0.4);
                assert_eq!(tau, 0.9);
            }
            _ => unreachable!(),
        }
        assert!(c.apply_override("bogus", "1").is_err());
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = ExperimentConfig::new(
            "t",
            "cnn_c32_b64",
            DatasetConfig::ImagenetProxy(Default::default()),
            StrategyConfig::kakurenbo(0.3),
        );
        assert!(c.validate().is_ok());
        c.epochs = 0;
        assert!(c.validate().is_err());
        c.epochs = 10;
        c.strategy = StrategyConfig::Forget { prune_epoch: 20, fraction: 0.3 };
        assert!(c.validate().is_err());
    }

    #[test]
    fn dp_mode_parses_and_rejects() {
        assert_eq!(DpMode::parse("average").unwrap(), DpMode::Average);
        assert_eq!(DpMode::parse("avg").unwrap(), DpMode::Average);
        assert_eq!(
            DpMode::parse("serial-equivalent").unwrap(),
            DpMode::SerialEquivalent
        );
        assert_eq!(DpMode::parse("serial").unwrap(), DpMode::SerialEquivalent);
        let err = DpMode::parse("turbo").unwrap_err().to_string();
        assert!(err.contains("--dp"), "{err}");
        assert_eq!(DpMode::default(), DpMode::SerialEquivalent);
    }

    fn base_cfg(strategy: StrategyConfig) -> ExperimentConfig {
        ExperimentConfig::new(
            "t",
            "cnn_c32_b64",
            DatasetConfig::ImagenetProxy(Default::default()),
            strategy,
        )
    }

    #[test]
    fn dp_average_requires_multiple_workers() {
        let mut c = base_cfg(StrategyConfig::kakurenbo(0.3));
        c.dp = DpMode::Average;
        c.workers = 1;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("--workers > 1"), "{err}");
        c.workers = 4;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn dp_average_rejects_single_stream_strategies() {
        for strategy in [
            StrategyConfig::Iswr,
            StrategyConfig::InfoBatch { r: 0.5 },
            StrategyConfig::GradMatch { fraction: 0.3, every_r: 3 },
            StrategyConfig::SelectiveBackprop { beta: 1.0 },
        ] {
            let mut c = base_cfg(strategy.clone());
            c.workers = 4;
            c.dp = DpMode::Average;
            let err = c.validate().unwrap_err().to_string();
            assert!(
                err.contains("single-stream") && err.contains(&strategy.name()),
                "{}: {err}",
                strategy.name()
            );
            // the same strategy is fine on the serial-equivalent schedule
            c.dp = DpMode::SerialEquivalent;
            assert!(c.validate().is_ok());
        }
        // plain strategies pass under averaging
        for strategy in [
            StrategyConfig::Baseline,
            StrategyConfig::kakurenbo(0.3),
            StrategyConfig::RandomHiding { fraction: 0.2 },
            StrategyConfig::Forget { prune_epoch: 5, fraction: 0.3 },
            StrategyConfig::El2n { score_epoch: 4, fraction: 0.2, restart: false },
            StrategyConfig::Pfb { fraction: 0.3, refresh_every: 3 },
        ] {
            let mut c = base_cfg(strategy);
            c.workers = 2;
            c.dp = DpMode::Average;
            assert!(c.validate().is_ok());
        }
    }

    #[test]
    fn pfb_validation_and_overrides() {
        let mut c = base_cfg(StrategyConfig::Pfb { fraction: 0.3, refresh_every: 3 });
        assert!(c.validate().is_ok());
        c.apply_override("pfb_fraction", "0.4").unwrap();
        c.apply_override("pfb-refresh-every", "5").unwrap();
        match c.strategy {
            StrategyConfig::Pfb { fraction, refresh_every } => {
                assert_eq!(fraction, 0.4);
                assert_eq!(refresh_every, 5);
            }
            _ => unreachable!(),
        }
        // max_fraction aliases the pfb fraction like the other pruners
        c.apply_override("max_fraction", "0.25").unwrap();
        match c.strategy {
            StrategyConfig::Pfb { fraction, .. } => assert_eq!(fraction, 0.25),
            _ => unreachable!(),
        }
        // refresh_every = 0 is rejected with the flag named
        c.apply_override("pfb_refresh_every", "0").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("--pfb-refresh-every"), "{err}");
        // fraction = 1.0 would prune everything
        c.strategy = StrategyConfig::Pfb { fraction: 1.0, refresh_every: 2 };
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("--pfb-fraction"), "{err}");
        // the pfb keys refuse to apply to other strategies
        let mut k = base_cfg(StrategyConfig::kakurenbo(0.3));
        assert!(k.apply_override("pfb_fraction", "0.1").is_err());
        assert!(k.apply_override("pfb-refresh-every", "2").is_err());
    }

    #[test]
    fn dp_override_applies() {
        let mut c = base_cfg(StrategyConfig::Baseline);
        c.apply_override("dp", "average").unwrap();
        assert_eq!(c.dp, DpMode::Average);
        assert!(c.apply_override("dp", "nonsense").is_err());
    }

    #[test]
    fn service_lane_parses_and_rejects() {
        assert!(parse_service_lane("on").unwrap());
        assert!(parse_service_lane("true").unwrap());
        assert!(!parse_service_lane("off").unwrap());
        assert!(!parse_service_lane("false").unwrap());
        let err = parse_service_lane("sideways").unwrap_err().to_string();
        assert!(err.contains("--service-lane"), "{err}");
    }

    #[test]
    fn service_lane_override_applies_both_spellings() {
        let mut c = base_cfg(StrategyConfig::Baseline);
        assert!(!c.service_lane, "default must stay off (serial behavior)");
        c.apply_override("service_lane", "on").unwrap();
        assert!(c.service_lane);
        c.apply_override("service-lane", "off").unwrap();
        assert!(!c.service_lane);
        assert!(c.apply_override("service_lane", "maybe").is_err());
        // both paths validate
        for on in [false, true] {
            c.service_lane = on;
            assert!(c.validate().is_ok());
        }
    }

    #[test]
    fn checkpoint_knob_defaults() {
        let c = base_cfg(StrategyConfig::Baseline);
        assert_eq!(c.checkpoint_pool, 0, "pool defaults to auto");
        assert!(c.checkpoint_verify, "verify defaults on");
        assert!(c.checkpoint_compress, "compress defaults on");
        assert!(c.validate().is_ok());
    }

    #[test]
    fn checkpoint_overrides_apply_both_spellings() {
        let mut c = base_cfg(StrategyConfig::Baseline);
        c.apply_override("checkpoint_pool", "4").unwrap();
        assert_eq!(c.checkpoint_pool, 4);
        c.apply_override("checkpoint-pool", "8").unwrap();
        assert_eq!(c.checkpoint_pool, 8);
        c.apply_override("checkpoint_verify", "off").unwrap();
        assert!(!c.checkpoint_verify);
        c.apply_override("checkpoint-verify", "on").unwrap();
        assert!(c.checkpoint_verify);
        c.apply_override("checkpoint_compress", "0").unwrap();
        assert!(!c.checkpoint_compress);
        c.apply_override("checkpoint-compress", "yes").unwrap();
        assert!(c.checkpoint_compress);
        let err = c.apply_override("checkpoint_verify", "maybe").unwrap_err();
        assert!(err.to_string().contains("--checkpoint-verify"), "{err}");
        assert!(c.apply_override("checkpoint_pool", "lots").is_err());
    }

    #[test]
    fn fault_policy_parses_and_rejects() {
        assert_eq!(FaultPolicy::parse("fail").unwrap(), FaultPolicy::Fail);
        assert_eq!(FaultPolicy::parse("elastic").unwrap(), FaultPolicy::Elastic);
        let err = FaultPolicy::parse("heroic").unwrap_err().to_string();
        assert!(err.contains("--fault-policy"), "{err}");
        assert_eq!(FaultPolicy::default(), FaultPolicy::Fail);
        assert_eq!(FaultPolicy::Elastic.name(), "elastic");
    }

    #[test]
    fn fault_overrides_apply_both_spellings() {
        let mut c = base_cfg(StrategyConfig::Baseline);
        assert_eq!(c.fault_policy, FaultPolicy::Fail, "default must stay fail");
        assert_eq!(c.straggler_timeout_ms, 0, "straggler timeout defaults off");
        c.apply_override("fault_policy", "elastic").unwrap();
        assert_eq!(c.fault_policy, FaultPolicy::Elastic);
        c.apply_override("fault-policy", "fail").unwrap();
        assert_eq!(c.fault_policy, FaultPolicy::Fail);
        c.apply_override("straggler_timeout_ms", "2500").unwrap();
        assert_eq!(c.straggler_timeout_ms, 2500);
        c.apply_override("straggler-timeout-ms", "0").unwrap();
        assert_eq!(c.straggler_timeout_ms, 0);
        assert!(c.apply_override("fault_policy", "maybe").is_err());
        assert!(c.apply_override("straggler_timeout_ms", "soon").is_err());
    }

    #[test]
    fn straggler_timeout_bound_validated() {
        let mut c = base_cfg(StrategyConfig::Baseline);
        c.straggler_timeout_ms = 600_000;
        assert!(c.validate().is_ok());
        c.straggler_timeout_ms = 600_001;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("--straggler-timeout-ms"), "{err}");
    }

    #[test]
    fn serve_defaults_off_and_overrides_apply() {
        let mut c = base_cfg(StrategyConfig::Baseline);
        assert!(c.serve.is_none(), "serving defaults off");
        assert_eq!(c.serve_threads, 2);
        assert_eq!(c.serve_replicas, 1, "one replica by default");
        assert_eq!(c.serve_batch, 1, "coalescing defaults off");
        assert_eq!(c.serve_batch_wait_us, 250);
        assert_eq!(c.serve_retain, 2, "hub retains two publications");
        assert!(c.validate().is_ok());
        c.apply_override("serve", "127.0.0.1:0").unwrap();
        assert_eq!(c.serve.as_deref(), Some("127.0.0.1:0"));
        c.apply_override("serve_threads", "4").unwrap();
        assert_eq!(c.serve_threads, 4);
        c.apply_override("serve-threads", "1").unwrap();
        assert_eq!(c.serve_threads, 1);
        c.apply_override("serve_replicas", "3").unwrap();
        c.apply_override("serve-batch", "8").unwrap();
        c.apply_override("serve_batch_wait_us", "500").unwrap();
        c.apply_override("serve-retain", "4").unwrap();
        assert_eq!(c.serve_replicas, 3);
        assert_eq!(c.serve_batch, 8);
        assert_eq!(c.serve_batch_wait_us, 500);
        assert_eq!(c.serve_retain, 4);
        assert!(c.validate().is_ok());
        assert!(c.apply_override("serve_threads", "many").is_err());
        assert!(c.apply_override("serve-batch", "lots").is_err());
    }

    #[test]
    fn serve_throughput_knob_bounds_validated() {
        let mut c = base_cfg(StrategyConfig::Baseline);
        for (field, bad, needle) in [
            ("serve_replicas", "0", "--serve-replicas 0"),
            ("serve_replicas", "65", "--serve-replicas 65"),
            ("serve_batch", "0", "--serve-batch 0"),
            ("serve_batch", "1025", "--serve-batch 1025"),
            ("serve_batch_wait_us", "1000001", "--serve-batch-wait-us 1000001"),
            ("serve_retain", "0", "--serve-retain 0"),
            ("serve_retain", "65", "--serve-retain 65"),
        ] {
            let mut c2 = base_cfg(StrategyConfig::Baseline);
            c2.apply_override(field, bad).unwrap();
            let err = c2.validate().unwrap_err().to_string();
            assert!(err.contains(needle), "{field}={bad}: {err}");
        }
        // the maxima themselves are fine
        c.serve_replicas = 64;
        c.serve_batch = 1024;
        c.serve_batch_wait_us = 1_000_000;
        c.serve_retain = 64;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn serve_address_and_thread_bounds_validated() {
        let mut c = base_cfg(StrategyConfig::Baseline);
        c.serve = Some("not-an-address".into());
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("--serve") && err.contains("not-an-address"), "{err}");
        // a bare port and a missing port are both rejected
        for bad in ["8080", "127.0.0.1"] {
            c.serve = Some(bad.into());
            assert!(c.validate().is_err(), "{bad:?} should not validate");
        }
        c.serve = Some("127.0.0.1:0".into());
        assert!(c.validate().is_ok(), "port 0 (pick a free port) is fine");
        c.serve_threads = 0;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("--serve-threads 0"), "{err}");
        c.serve_threads = 257;
        assert!(c.validate().is_err());
    }

    #[test]
    fn checkpoint_pool_bound_validated() {
        let mut c = base_cfg(StrategyConfig::Baseline);
        c.checkpoint_pool = 256;
        assert!(c.validate().is_ok());
        c.checkpoint_pool = 257;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("--checkpoint-pool"), "{err}");
    }
}
