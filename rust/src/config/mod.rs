//! Experiment configuration: typed configs, JSON loading, CLI overrides,
//! and presets mirroring the paper's Appendix B hyper-parameter tables.

pub mod presets;

use std::path::PathBuf;

use crate::data::image::{DeepcamProxyCfg, ImagenetProxyCfg};
use crate::data::synth::{FractalCfg, GaussMixtureCfg};
use crate::data::TrainVal;
use crate::hiding::selector::SelectMode;
use crate::schedule::{LrConfig, LrSchedule};
use crate::util::json::Json;

/// Which synthetic proxy dataset to train on (DESIGN.md §3).
#[derive(Clone, Debug)]
pub enum DatasetConfig {
    GaussMixture(GaussMixtureCfg),
    ImagenetProxy(ImagenetProxyCfg),
    DeepcamProxy(DeepcamProxyCfg),
    Fractal(FractalCfg),
}

impl DatasetConfig {
    pub fn generate(&self, seed: u64) -> TrainVal {
        match self {
            DatasetConfig::GaussMixture(c) => crate::data::synth::gauss_mixture(c, seed),
            DatasetConfig::ImagenetProxy(c) => crate::data::image::imagenet_proxy(c, seed),
            DatasetConfig::DeepcamProxy(c) => crate::data::image::deepcam_proxy(c, seed),
            DatasetConfig::Fractal(c) => crate::data::synth::fractal_proxy(c, seed),
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            DatasetConfig::GaussMixture(_) => "gauss_mixture",
            DatasetConfig::ImagenetProxy(_) => "imagenet_proxy",
            DatasetConfig::DeepcamProxy(_) => "deepcam_proxy",
            DatasetConfig::Fractal(_) => "fractal",
        }
    }
}

/// KAKURENBO component switches (Table 6 ablation: HE/MB/RF/LR).
#[derive(Clone, Copy, Debug)]
pub struct Components {
    pub hide: bool,
    pub move_back: bool,
    pub reduce_fraction: bool,
    pub adjust_lr: bool,
}

impl Components {
    pub const ALL: Components = Components {
        hide: true,
        move_back: true,
        reduce_fraction: true,
        adjust_lr: true,
    };

    /// Parse the paper's vXXXX naming: v1011 = HE, no MB, RF, LR.
    pub fn from_bits(name: &str) -> anyhow::Result<Self> {
        let bits: Vec<char> = name.trim_start_matches('v').chars().collect();
        anyhow::ensure!(bits.len() == 4, "expected vXXXX, got {name}");
        let b = |i: usize| bits[i] == '1';
        Ok(Components { hide: b(0), move_back: b(1), reduce_fraction: b(2), adjust_lr: b(3) })
    }

    pub fn label(&self) -> String {
        format!(
            "v{}{}{}{}",
            self.hide as u8, self.move_back as u8, self.reduce_fraction as u8, self.adjust_lr as u8
        )
    }
}

#[derive(Clone, Debug)]
pub enum StrategyConfig {
    /// Uniform sampling without replacement (paper "Baseline").
    Baseline,
    /// KAKURENBO (§3) with component switches and optional DropTop (App. D).
    Kakurenbo {
        max_fraction: f64,
        tau: f32,
        components: Components,
        drop_top: f64,
        select_mode: SelectMode,
    },
    /// Importance Sampling With Replacement [11].
    Iswr,
    /// Selective-Backprop [17].
    SelectiveBackprop { beta: f64 },
    /// Online FORGET pruning [13]: train `prune_epoch` epochs, prune the
    /// least-forgettable fraction, restart.
    Forget { prune_epoch: usize, fraction: f64 },
    /// GradMatch [18] (simplified per-class last-layer OMP, every R epochs).
    GradMatch { fraction: f64, every_r: usize },
    /// Random hiding baseline (Table 9 / GradMatch paper).
    RandomHiding { fraction: f64 },
    /// InfoBatch [28] extension: unbiased dynamic pruning with rescaling.
    InfoBatch { r: f64 },
    /// EL2N [15] extension: early error-norm scoring + permanent pruning.
    El2n { score_epoch: usize, fraction: f64, restart: bool },
}

impl StrategyConfig {
    pub fn kakurenbo(max_fraction: f64) -> Self {
        StrategyConfig::Kakurenbo {
            max_fraction,
            tau: 0.7,
            components: Components::ALL,
            drop_top: 0.0,
            select_mode: SelectMode::QuickSelect,
        }
    }

    pub fn name(&self) -> String {
        match self {
            StrategyConfig::Baseline => "baseline".into(),
            StrategyConfig::Kakurenbo { components, .. } if *components
                == Components::ALL => "kakurenbo".into(),
            StrategyConfig::Kakurenbo { components, .. } => {
                format!("kakurenbo-{}", components.label())
            }
            StrategyConfig::Iswr => "iswr".into(),
            StrategyConfig::SelectiveBackprop { .. } => "sb".into(),
            StrategyConfig::Forget { .. } => "forget".into(),
            StrategyConfig::GradMatch { .. } => "gradmatch".into(),
            StrategyConfig::RandomHiding { .. } => "random".into(),
            StrategyConfig::InfoBatch { .. } => "infobatch".into(),
            StrategyConfig::El2n { .. } => "el2n".into(),
        }
    }
}

impl PartialEq for Components {
    fn eq(&self, o: &Self) -> bool {
        self.hide == o.hide
            && self.move_back == o.move_back
            && self.reduce_fraction == o.reduce_fraction
            && self.adjust_lr == o.adjust_lr
    }
}

/// A complete experiment: model variant + dataset + strategy + schedules.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    /// Artifact variant (manifest key), e.g. "cnn_c32_b64".
    pub variant: String,
    pub dataset: DatasetConfig,
    pub strategy: StrategyConfig,
    pub epochs: usize,
    pub seed: u64,
    pub lr: LrConfig,
    pub momentum: f32,
    /// Data-parallel worker count.  `> 1` executes plain training passes
    /// and hidden-stat refreshes through the engine's `WorkerPool` (N
    /// concurrent pipelined gather lanes behind a deterministic
    /// bulk-synchronous reduction, bitwise identical to the single-stream
    /// interleaved run — docs/worker-model.md) and also feeds the
    /// paper-scale cost-model projection.
    pub workers: usize,
    /// Evaluate on the validation set every k epochs (always on last).
    pub eval_every: usize,
    pub artifacts_dir: PathBuf,
    /// Collect per-class hidden counts / loss histograms (Figs. 5-8).
    pub detailed_metrics: bool,
    /// Save a parameter checkpoint every k epochs (0 = disabled).
    pub checkpoint_every: usize,
    /// Directory for checkpoints (and resume source when `resume`).
    pub checkpoint_dir: Option<PathBuf>,
    /// Resume from the checkpoint in `checkpoint_dir` before training.
    pub resume: bool,
}

impl ExperimentConfig {
    pub fn new(name: &str, variant: &str, dataset: DatasetConfig, strategy: StrategyConfig) -> Self {
        ExperimentConfig {
            name: name.to_string(),
            variant: variant.to_string(),
            dataset,
            strategy,
            epochs: 30,
            seed: 42,
            lr: LrConfig {
                base_lr: 0.05,
                schedule: LrSchedule::Step { milestones: vec![], rate: 0.1 },
                warmup_epochs: 2,
            },
            momentum: 0.9,
            workers: 1,
            eval_every: 1,
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            detailed_metrics: false,
            checkpoint_every: 0,
            checkpoint_dir: None,
            resume: false,
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.epochs > 0, "epochs must be positive");
        anyhow::ensure!(self.workers > 0, "workers must be positive");
        anyhow::ensure!((0.0..=1.0).contains(&(self.momentum as f64)), "momentum");
        if let StrategyConfig::Kakurenbo { max_fraction, tau, .. } = &self.strategy {
            anyhow::ensure!((0.0..1.0).contains(max_fraction), "max_fraction");
            anyhow::ensure!((0.0..=1.0).contains(&(*tau as f64)), "tau");
        }
        if let StrategyConfig::Forget { prune_epoch, .. } = &self.strategy {
            anyhow::ensure!(*prune_epoch < self.epochs, "prune_epoch >= epochs");
        }
        Ok(())
    }

    /// Apply `--key=value` CLI overrides (a subset of fields that sweeps
    /// and the launcher need).
    pub fn apply_override(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        match key {
            "epochs" => self.epochs = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "workers" => self.workers = value.parse()?,
            "eval_every" => self.eval_every = value.parse()?,
            "base_lr" => self.lr.base_lr = value.parse()?,
            "warmup_epochs" => self.lr.warmup_epochs = value.parse()?,
            "momentum" => self.momentum = value.parse()?,
            "variant" => self.variant = value.to_string(),
            "detailed_metrics" => self.detailed_metrics = value.parse()?,
            "checkpoint_every" => self.checkpoint_every = value.parse()?,
            "checkpoint_dir" => self.checkpoint_dir = Some(PathBuf::from(value)),
            "resume" => self.resume = value.parse()?,
            "max_fraction" => match &mut self.strategy {
                StrategyConfig::Kakurenbo { max_fraction, .. } => *max_fraction = value.parse()?,
                StrategyConfig::Forget { fraction, .. }
                | StrategyConfig::GradMatch { fraction, .. }
                | StrategyConfig::El2n { fraction, .. }
                | StrategyConfig::RandomHiding { fraction } => *fraction = value.parse()?,
                StrategyConfig::InfoBatch { r } => *r = value.parse()?,
                _ => anyhow::bail!("strategy has no fraction"),
            },
            "tau" => match &mut self.strategy {
                StrategyConfig::Kakurenbo { tau, .. } => *tau = value.parse()?,
                _ => anyhow::bail!("strategy has no tau"),
            },
            "drop_top" => match &mut self.strategy {
                StrategyConfig::Kakurenbo { drop_top, .. } => *drop_top = value.parse()?,
                _ => anyhow::bail!("strategy has no drop_top"),
            },
            _ => anyhow::bail!("unknown override key {key:?}"),
        }
        Ok(())
    }

    /// Summary for logs / result JSON.
    pub fn to_json(&self) -> Json {
        crate::jobj![
            ("name", self.name.as_str()),
            ("variant", self.variant.as_str()),
            ("dataset", self.dataset.kind()),
            ("strategy", self.strategy.name()),
            ("epochs", self.epochs),
            ("seed", self.seed as usize),
            ("workers", self.workers),
            ("base_lr", self.lr.base_lr),
            ("momentum", self.momentum),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn components_bits_roundtrip() {
        for name in ["v1000", "v1011", "v1111", "v1100"] {
            let c = Components::from_bits(name).unwrap();
            assert_eq!(c.label(), name);
        }
        assert!(Components::from_bits("v10").is_err());
    }

    #[test]
    fn overrides_apply() {
        let mut c = ExperimentConfig::new(
            "t",
            "cnn_c32_b64",
            DatasetConfig::ImagenetProxy(Default::default()),
            StrategyConfig::kakurenbo(0.3),
        );
        c.apply_override("epochs", "7").unwrap();
        c.apply_override("max_fraction", "0.4").unwrap();
        c.apply_override("tau", "0.9").unwrap();
        assert_eq!(c.epochs, 7);
        match c.strategy {
            StrategyConfig::Kakurenbo { max_fraction, tau, .. } => {
                assert_eq!(max_fraction, 0.4);
                assert_eq!(tau, 0.9);
            }
            _ => unreachable!(),
        }
        assert!(c.apply_override("bogus", "1").is_err());
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = ExperimentConfig::new(
            "t",
            "cnn_c32_b64",
            DatasetConfig::ImagenetProxy(Default::default()),
            StrategyConfig::kakurenbo(0.3),
        );
        assert!(c.validate().is_ok());
        c.epochs = 0;
        assert!(c.validate().is_err());
        c.epochs = 10;
        c.strategy = StrategyConfig::Forget { prune_epoch: 20, fraction: 0.3 };
        assert!(c.validate().is_err());
    }
}
