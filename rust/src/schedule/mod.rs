//! Base learning-rate schedulers mirroring the paper's Appendix B
//! hyper-parameter tables: step decay (ResNet-50 (A)), cosine annealing
//! (ResNet-50 / (B), DeiT), exponential decay (EfficientNet-b3), all
//! wrapped in the Goyal et al. linear warmup used everywhere in the paper.
//!
//! KAKURENBO's 1/(1-F_e) factor (hiding/lr.rs) multiplies *on top of*
//! whatever these produce — it is scheduler-independent by construction.

/// Which decay shape the base learning rate follows.
#[derive(Clone, Debug)]
pub enum LrSchedule {
    /// Constant base LR.
    Constant,
    /// Multiply by `rate` at each epoch milestone ("step" in App. B).
    Step {
        /// Epochs at which the decay step applies.
        milestones: Vec<usize>,
        /// Multiplicative decay applied at each milestone.
        rate: f64,
    },
    /// Cosine annealing to ~0 over `total` epochs.
    Cosine {
        /// Annealing horizon in epochs.
        total: usize,
    },
    /// Decay by `rate` every `every` epochs (EfficientNet: 0.9 every 2).
    ExpEvery {
        /// Epochs between decay steps.
        every: usize,
        /// Multiplicative decay per step.
        rate: f64,
    },
}

/// Base learning rate + decay schedule + warmup.
#[derive(Clone, Debug)]
pub struct LrConfig {
    /// Peak learning rate (after warmup, before decay).
    pub base_lr: f64,
    /// Decay shape applied on top of `base_lr`.
    pub schedule: LrSchedule,
    /// Linear warmup from 0 over this many epochs (Goyal et al. [34]).
    pub warmup_epochs: usize,
}

impl LrConfig {
    /// A constant schedule at `base_lr` with no warmup.
    pub fn constant(base_lr: f64) -> Self {
        LrConfig { base_lr, schedule: LrSchedule::Constant, warmup_epochs: 0 }
    }

    /// Base learning rate for an epoch, before KAKURENBO's adjustment.
    pub fn at(&self, epoch: usize) -> f64 {
        let warm = if self.warmup_epochs > 0 && epoch < self.warmup_epochs {
            (epoch + 1) as f64 / self.warmup_epochs as f64
        } else {
            1.0
        };
        let sched = match &self.schedule {
            LrSchedule::Constant => 1.0,
            LrSchedule::Step { milestones, rate } => {
                let k = milestones.iter().filter(|&&m| epoch >= m).count();
                rate.powi(k as i32)
            }
            LrSchedule::Cosine { total } => {
                let t = (epoch as f64 / (*total).max(1) as f64).min(1.0);
                0.5 * (1.0 + (std::f64::consts::PI * t).cos())
            }
            LrSchedule::ExpEvery { every, rate } => rate.powi((epoch / (*every).max(1)) as i32),
        };
        self.base_lr * warm * sched
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let c = LrConfig { base_lr: 1.0, schedule: LrSchedule::Constant, warmup_epochs: 5 };
        assert!((c.at(0) - 0.2).abs() < 1e-12);
        assert!((c.at(4) - 1.0).abs() < 1e-12);
        assert!((c.at(10) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn step_decays_at_milestones() {
        let c = LrConfig {
            base_lr: 0.1,
            schedule: LrSchedule::Step { milestones: vec![30, 60, 80], rate: 0.1 },
            warmup_epochs: 0,
        };
        assert!((c.at(29) - 0.1).abs() < 1e-12);
        assert!((c.at(30) - 0.01).abs() < 1e-12);
        assert!((c.at(85) - 0.0001).abs() < 1e-12);
    }

    #[test]
    fn cosine_endpoints() {
        let c = LrConfig { base_lr: 1.0, schedule: LrSchedule::Cosine { total: 100 }, warmup_epochs: 0 };
        assert!((c.at(0) - 1.0).abs() < 1e-9);
        assert!(c.at(99) < 0.01);
        assert!(c.at(50) < c.at(25));
    }

    #[test]
    fn exp_every() {
        let c = LrConfig {
            base_lr: 0.016,
            schedule: LrSchedule::ExpEvery { every: 2, rate: 0.9 },
            warmup_epochs: 0,
        };
        assert!((c.at(0) - 0.016).abs() < 1e-12);
        assert!((c.at(2) - 0.016 * 0.9).abs() < 1e-12);
        assert!((c.at(5) - 0.016 * 0.81).abs() < 1e-12);
    }
}
