//! Sampling primitives used by the training strategies.
//!
//! * `uniform` — without-replacement epoch permutation (baseline, KAKURENBO).
//! * `alias`   — Walker alias table: O(N) build, O(1) per draw; ISWR's
//!   loss-proportional with-replacement sampling (paper [11]).
//! * `fenwick` — Fenwick-tree weighted sampler with O(log N) draws *and*
//!   O(log N) online weight updates; used when importance weights change
//!   within an epoch (Selective-Backprop style selection).

/// Walker alias table: O(1) weighted draws with replacement.
pub mod alias;
/// Fenwick-tree sampler: O(log N) draws with online weight updates.
pub mod fenwick;

use crate::util::rng::Rng;

/// A shuffled epoch permutation of 0..n (uniform without replacement).
pub fn epoch_permutation(n: usize, rng: &mut Rng) -> Vec<u32> {
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    order
}

/// A shuffled copy of an index list.
pub fn shuffled(indices: &[u32], rng: &mut Rng) -> Vec<u32> {
    let mut v = indices.to_vec();
    rng.shuffle(&mut v);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permutation_is_complete() {
        let mut rng = Rng::new(1);
        let p = epoch_permutation(100, &mut rng);
        let mut s = p.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn permutations_differ_across_draws() {
        let mut rng = Rng::new(1);
        let a = epoch_permutation(50, &mut rng);
        let b = epoch_permutation(50, &mut rng);
        assert_ne!(a, b);
    }
}
