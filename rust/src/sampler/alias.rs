//! Walker alias method: O(N) construction, O(1) weighted draws with
//! replacement.  This is ISWR's sampling engine — the paper draws every
//! sample of every epoch proportionally to its (lagging) loss, so draw
//! cost matters at N = millions.

use crate::util::rng::Rng;

/// A built Walker alias table over a fixed weight vector.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,   // acceptance probability per bucket
    alias: Vec<u32>,  // fallback index per bucket
}

impl AliasTable {
    /// Build from non-negative weights (not necessarily normalized).
    /// All-zero weight vectors degrade to uniform.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "empty weight vector");
        let total: f64 = weights.iter().sum();
        let uniform = total <= 0.0;
        let scale = if uniform { 1.0 } else { n as f64 / total };

        let mut prob = vec![0.0f64; n];
        let mut alias = vec![0u32; n];
        // scaled weights; "small" stack has p < 1, "large" has p >= 1
        let mut p: Vec<f64> = weights
            .iter()
            .map(|&w| if uniform { 1.0 } else { w * scale })
            .collect();
        let mut small: Vec<u32> = Vec::with_capacity(n);
        let mut large: Vec<u32> = Vec::with_capacity(n);
        for (i, &pi) in p.iter().enumerate() {
            if pi < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while !small.is_empty() && !large.is_empty() {
            let s = small.pop().unwrap();
            let l = *large.last().unwrap(); // peek: l may stay large
            prob[s as usize] = p[s as usize];
            alias[s as usize] = l;
            p[l as usize] = (p[l as usize] + p[s as usize]) - 1.0;
            if p[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        for i in small.into_iter().chain(large) {
            prob[i as usize] = 1.0;
        }
        AliasTable { prob, alias }
    }

    /// Number of buckets (the weight-vector length).
    #[inline]
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// Whether the table was built over zero buckets.
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// One O(1) draw.
    #[inline]
    pub fn draw(&self, rng: &mut Rng) -> u32 {
        let i = rng.below(self.len());
        if rng.f64() < self.prob[i] {
            i as u32
        } else {
            self.alias[i]
        }
    }

    /// k draws with replacement.
    pub fn draw_many(&self, k: usize, rng: &mut Rng) -> Vec<u32> {
        (0..k).map(|_| self.draw(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical(weights: &[f64], draws: usize, seed: u64) -> Vec<f64> {
        let t = AliasTable::new(weights);
        let mut rng = Rng::new(seed);
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..draws {
            counts[t.draw(&mut rng) as usize] += 1;
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    #[test]
    fn matches_target_distribution() {
        let w = [1.0, 2.0, 3.0, 4.0];
        let f = empirical(&w, 100_000, 1);
        for (i, &wi) in w.iter().enumerate() {
            let target = wi / 10.0;
            assert!((f[i] - target).abs() < 0.01, "i={i} f={} target={target}", f[i]);
        }
    }

    #[test]
    fn zero_weight_entries_never_drawn() {
        let w = [0.0, 5.0, 0.0, 5.0];
        let f = empirical(&w, 20_000, 2);
        assert_eq!(f[0], 0.0);
        assert_eq!(f[2], 0.0);
    }

    #[test]
    fn all_zero_degrades_to_uniform() {
        let f = empirical(&[0.0, 0.0, 0.0], 30_000, 3);
        for &fi in &f {
            assert!((fi - 1.0 / 3.0).abs() < 0.01);
        }
    }

    #[test]
    fn single_element() {
        let t = AliasTable::new(&[7.0]);
        let mut rng = Rng::new(4);
        assert_eq!(t.draw(&mut rng), 0);
    }

    #[test]
    fn heavy_skew() {
        let mut w = vec![1e-6; 100];
        w[42] = 1e6;
        let f = empirical(&w, 10_000, 5);
        assert!(f[42] > 0.99);
    }
}
