//! Fenwick (binary indexed) tree weighted sampler.
//!
//! O(log N) draw + O(log N) single-weight update, which the alias table
//! cannot do (it needs a full O(N) rebuild per change).  Used where
//! importance weights mutate *during* an epoch: Selective-Backprop's
//! loss-CDF selection and the ISWR variant that refreshes weights with
//! every batch's fresh losses (Katharopoulos & Fleuret keep a live
//! importance store; Mercury [22] does the same per shard).

use crate::util::rng::Rng;

/// A Fenwick-tree weighted sampler with mutable per-index weights.
#[derive(Clone, Debug)]
pub struct FenwickSampler {
    tree: Vec<f64>, // 1-based partial sums
    weights: Vec<f64>,
}

impl FenwickSampler {
    /// Build from non-negative initial weights.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        let mut s = FenwickSampler { tree: vec![0.0; n + 1], weights: vec![0.0; n] };
        for (i, &w) in weights.iter().enumerate() {
            s.set(i, w);
        }
        s
    }

    /// Number of weights.
    pub fn len(&self) -> usize {
        self.weights.len()
    }

    /// Whether the sampler holds zero weights.
    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    /// Sum of all current weights.
    pub fn total(&self) -> f64 {
        self.prefix_sum(self.len())
    }

    /// Current weight of index `i`.
    pub fn weight(&self, i: usize) -> f64 {
        self.weights[i]
    }

    /// Sum of weights[0..i].
    fn prefix_sum(&self, i: usize) -> f64 {
        let mut i = i;
        let mut s = 0.0;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }

    /// Set weight i to w (must be >= 0).
    pub fn set(&mut self, i: usize, w: f64) {
        assert!(w >= 0.0 && w.is_finite(), "weight {w}");
        let delta = w - self.weights[i];
        self.weights[i] = w;
        let mut j = i + 1;
        while j < self.tree.len() {
            self.tree[j] += delta;
            j += j & j.wrapping_neg();
        }
    }

    /// Draw an index with probability proportional to its weight.
    /// Returns None when total weight is zero.
    pub fn draw(&self, rng: &mut Rng) -> Option<u32> {
        let total = self.total();
        if total <= 0.0 {
            return None;
        }
        let mut t = rng.f64() * total;
        // descend the implicit tree
        let mut pos = 0usize;
        let mut mask = self.tree.len().next_power_of_two() >> 1;
        while mask > 0 {
            let next = pos + mask;
            if next < self.tree.len() && self.tree[next] < t {
                t -= self.tree[next];
                pos = next;
            }
            mask >>= 1;
        }
        // pos is now the largest index with prefix_sum(pos) < t
        Some((pos.min(self.len() - 1)) as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_sums_match_naive() {
        let w = [1.0, 0.5, 2.0, 0.0, 3.0];
        let s = FenwickSampler::new(&w);
        let mut acc = 0.0;
        for i in 0..=w.len() {
            assert!((s.prefix_sum(i) - acc).abs() < 1e-12);
            if i < w.len() {
                acc += w[i];
            }
        }
    }

    #[test]
    fn draws_match_distribution() {
        let w = [1.0, 2.0, 3.0, 4.0];
        let s = FenwickSampler::new(&w);
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 4];
        for _ in 0..100_000 {
            counts[s.draw(&mut rng).unwrap() as usize] += 1;
        }
        for (i, &wi) in w.iter().enumerate() {
            let f = counts[i] as f64 / 100_000.0;
            assert!((f - wi / 10.0).abs() < 0.01, "i={i} f={f}");
        }
    }

    #[test]
    fn online_updates() {
        let mut s = FenwickSampler::new(&[1.0, 1.0, 1.0]);
        s.set(0, 0.0);
        s.set(2, 9.0);
        let mut rng = Rng::new(2);
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[s.draw(&mut rng).unwrap() as usize] += 1;
        }
        assert_eq!(counts[0], 0);
        let f2 = counts[2] as f64 / 20_000.0;
        assert!((f2 - 0.9).abs() < 0.01, "f2={f2}");
    }

    #[test]
    fn zero_total_returns_none() {
        let s = FenwickSampler::new(&[0.0, 0.0]);
        let mut rng = Rng::new(3);
        assert!(s.draw(&mut rng).is_none());
    }

    #[test]
    fn non_power_of_two_sizes() {
        for n in [1usize, 2, 3, 5, 17, 100, 1000] {
            let w: Vec<f64> = (0..n).map(|i| (i % 7) as f64 + 0.1).collect();
            let s = FenwickSampler::new(&w);
            let mut rng = Rng::new(n as u64);
            for _ in 0..200 {
                let i = s.draw(&mut rng).unwrap() as usize;
                assert!(i < n);
                assert!(w[i] > 0.0);
            }
        }
    }
}
