//! The training coordinator: runs a full experiment (epochs x batches)
//! against the PJRT runtime, driving the configured strategy, schedules,
//! stat bookkeeping, evaluation, and the cost model.
//!
//! This is the L3 "request path": after construction no Python and no
//! compilation happens — only artifact execution and host-side
//! coordination.  The coordinator *plans* (strategy selection, sharding,
//! learning rate); each epoch executes through the staged
//! [`EpochPipeline`] (`coordinator/epoch.rs`):
//!
//! ```text
//!   Plan -> Train -> Refresh -> Eval -> Checkpoint -> Metrics
//! ```
//!
//! All per-step execution — batch gather, device steps, stat recording —
//! routes through the pipelined `engine` module, which overlaps host-side
//! gather with device execution.  With `cfg.workers > 1` the plain
//! training pass and the hidden-stat refresh run through the engine's
//! `WorkerPool` behind a deterministic bulk-synchronous reduction, and
//! `cfg.dp` picks the schedule (serial-equivalent vs `--dp average`
//! parameter averaging — docs/worker-model.md).  Weighted plans and the
//! SB candidate stream stay single-stream, matching the paper's W = 1
//! setup for those baselines.
//!
//! With `cfg.service_lane` on, the Eval and Checkpoint phases leave the
//! critical path entirely: they export an exact typed snapshot — the
//! params-only tier for eval-only epochs, the full tier when a
//! checkpoint is due — and enqueue the job on the split background
//! [`ServiceLanes`] (an eval lane with its own replica of the executor,
//! and an independent checkpoint lane), whose results this trainer folds
//! back into the epoch records at the next barrier, keyed by epoch.
//! Async eval is bitwise identical to sync eval
//! (`tests/service_lane_determinism.rs`); snapshot tiers and the lane
//! lifecycle are documented in docs/snapshots.md.

use crate::config::{ExperimentConfig, FaultPolicy, StrategyConfig};
use crate::coordinator::costmodel::CostModel;
use crate::coordinator::epoch::EpochPipeline;
use crate::data::shard::shard_order_aligned;
use crate::data::TrainVal;
use crate::engine::{
    execute_feature_harvest, execute_sharded_harvest, CheckpointWriter, Engine, EvalSink,
    RefreshSink, ServeBatching, ServeFleet, ServiceEvent, ServiceLanes, SharedSnapshot,
    SnapshotHub, StepMode, WorkerPool,
};
use crate::serve::{InferenceServer, ServingShape};
use crate::metrics::{EpochRecord, RunResult};
use crate::runtime::{ModelExecutor, XlaRuntime};
use crate::state::{FeatureCache, SampleState};
use crate::strategies::sb::SbSelector;
use crate::strategies::Strategy;
use crate::util::rng::Rng;
use std::sync::Arc;

/// The online inference lane's moving parts, held together so they spawn
/// and shut down as one unit: the HTTP front end, the serving replica
/// fleet, and the snapshot hub the epoch pipeline publishes into.
///
/// Field order is drop order: the HTTP server drains first (no new
/// queries), then the fleet's lanes join, then the hub's retained
/// publications release.
pub struct ServeRuntime {
    /// The HTTP front end (`--serve <addr>`); reports the bound address.
    pub server: InferenceServer,
    /// The serving replica fleet (`--serve-replicas R` lanes with
    /// `--serve-batch` coalescing); lane failures fold in as serve-lane
    /// [`ServiceEvent::Error`]s.
    pub fleet: ServeFleet,
    /// The publication hub: the live params snapshot plus the
    /// `--serve-retain` most recent publications.
    pub hub: Arc<SnapshotHub>,
}

/// Runs one experiment end to end: plans every epoch (strategy, LR,
/// sharding) and drives the engine / worker pool through the PJRT
/// executor, producing per-epoch records.
pub struct Trainer {
    /// The full experiment configuration the run was built from.
    pub cfg: ExperimentConfig,
    /// The PJRT executor holding model parameters as device literals.
    pub exec: ModelExecutor,
    /// Train + validation datasets (generated once per run).
    pub data: TrainVal,
    /// Per-sample lagging loss / PA / PC store.
    pub state: SampleState,
    /// Penultimate-layer feature cache for pre-forward pruning strategies
    /// (PFB): filled by the Refresh phase's embedding harvest every
    /// `Strategy::feature_refresh_every` epochs, read by `plan_epoch`,
    /// and carried through the exact-resume payload.  Empty (not-ready)
    /// for strategies that never score from features.
    pub feat_cache: FeatureCache,
    /// Calibrated paper-scale cost model.
    pub cost: CostModel,
    /// The pipelined step-execution driver (owns the reusable batch
    /// buffers shared by training, refresh, and eval passes).
    pub engine: Engine,
    /// The multi-worker execution driver used when `cfg.workers > 1`
    /// (N gather lanes behind a deterministic bulk-synchronous reduction).
    pub pool: WorkerPool,
    /// The async eval + checkpoint lanes (spawned lazily on first use
    /// when `cfg.service_lane`; `None` otherwise).
    pub(crate) service: Option<ServiceLanes>,
    /// The online inference lane (hub + serving replica + HTTP server),
    /// spawned when `cfg.serve` names an address; `None` otherwise.
    /// Public so the serving test battery can substitute a scripted
    /// [`ServeRuntime`] (e.g. a fault-injected replica) under a real run.
    pub serve: Option<ServeRuntime>,
    pub(crate) strategy: Box<dyn Strategy>,
    pub(crate) rng: Rng,
    pub(crate) sb: SbSelector,
    /// Pending SB-selected samples waiting to fill a training batch.
    pub(crate) sb_queue: Vec<u32>,
    /// Cached 0..val.n index list (reused across evals).
    eval_idx: Vec<u32>,
    /// Epoch at which training last (re)started — FORGET resets the LR
    /// schedule when it restarts from scratch (paper §4: "training then
    /// restarts from epoch 0").
    pub(crate) schedule_offset: usize,
    /// Persistent leaf write pool for the *sync* checkpoint path,
    /// created lazily at the first checkpoint (the async lane's writer
    /// owns its own pool on the lane thread).
    pub(crate) ckpt_pool: Option<crate::util::artifact::WritePool>,
}

impl Trainer {
    /// Build a trainer: generate the dataset, compile the variant's
    /// artifacts, calibrate the cost model, and size the execution
    /// engine + worker pool.
    pub fn new(rt: &XlaRuntime, cfg: ExperimentConfig) -> anyhow::Result<Self> {
        cfg.validate()?;
        let data = cfg.dataset.generate(cfg.seed);
        let mut exec = ModelExecutor::new(rt, &cfg.variant, cfg.seed)?;
        exec.momentum = cfg.momentum;
        anyhow::ensure!(
            exec.meta.sample_dim() == data.train.sample_dim,
            "variant {} expects sample dim {}, dataset {} provides {}",
            cfg.variant,
            exec.meta.sample_dim(),
            data.train.name,
            data.train.sample_dim
        );
        anyhow::ensure!(
            exec.meta.label_len() == data.train.label_len,
            "label shape mismatch between variant and dataset"
        );
        anyhow::ensure!(
            exec.meta.classes == data.train.classes,
            "variant {} has {} classes, dataset {} has {}",
            cfg.variant,
            exec.meta.classes,
            data.train.name,
            data.train.classes
        );
        let state = SampleState::new(data.train.n);
        let feat_cache = FeatureCache::new(data.train.n);
        let cost = rt.cost_model(&mut exec)?;
        // calibration perturbs params: reset to the seeded init
        exec.reset_params(cfg.seed)?;
        let strategy = crate::strategies::build(&cfg.strategy, cfg.epochs);
        let beta = match cfg.strategy {
            StrategyConfig::SelectiveBackprop { beta } => beta,
            _ => 1.0,
        };
        let engine = Engine::new(&data.train, exec.meta.batch);
        let mut pool = WorkerPool::new(&data.train, exec.meta.batch);
        pool.set_fault_policy(
            cfg.fault_policy == FaultPolicy::Elastic,
            cfg.straggler_timeout_ms,
        );
        let eval_idx: Vec<u32> = (0..data.val.n as u32).collect();
        Ok(Trainer {
            rng: Rng::new(cfg.seed ^ 0x7472_6169),
            sb: SbSelector::new(beta, 4096),
            sb_queue: Vec::new(),
            eval_idx,
            schedule_offset: 0,
            ckpt_pool: None,
            service: None,
            serve: None,
            cfg,
            exec,
            data,
            state,
            feat_cache,
            cost,
            engine,
            pool,
            strategy,
        })
    }

    /// Run the configured number of epochs; returns the full RunResult.
    pub fn run(&mut self) -> anyhow::Result<RunResult> {
        let mut start_epoch = 0;
        if self.cfg.resume {
            let dir = self.cfg.checkpoint_dir.clone().ok_or_else(|| {
                anyhow::anyhow!("resume requested without checkpoint_dir")
            })?;
            let ckpt_epoch = crate::runtime::checkpoint::load_with(
                &mut self.exec,
                &dir,
                self.cfg.checkpoint_verify,
            )?;
            start_epoch = ckpt_epoch + 1;
            // exact resume when the trainer-side state rode along with the
            // checkpoint *and* carries the same epoch stamp; legacy or
            // crash-torn directories fall back to params-only (fresh
            // stats + fresh RNG — see coordinator/resume.rs)
            match super::resume::load(
                &dir,
                ckpt_epoch,
                &mut self.state,
                &mut self.rng,
                &mut self.sb,
                &mut self.feat_cache,
            )? {
                Some(offset) => {
                    self.schedule_offset = offset;
                    crate::info!("resumed from {dir:?} at epoch {start_epoch} (exact)");
                }
                None => {
                    crate::info!("resumed from {dir:?} at epoch {start_epoch} (params only)");
                }
            }
        }
        // Spawn the service lanes before the epoch loop: the one-time
        // eval-replica build (its own PJRT client + compiled executables)
        // is paid here, outside every epoch's timed phases, instead of
        // landing on the first Eval phase's critical path — and build
        // failures surface before any training happens.
        if self.cfg.service_lane {
            self.ensure_service()?;
        }
        // Same reasoning for the inference lane: the serving replica and
        // the HTTP bind both happen before epoch 0, so `--serve` failures
        // (bad address, port in use) abort up front and /healthz is
        // reachable (503 "starting") from the first training step.
        if self.cfg.serve.is_some() {
            self.ensure_serve()?;
        }
        let mut records = Vec::with_capacity(self.cfg.epochs.saturating_sub(start_epoch));
        for epoch in start_epoch..self.cfg.epochs {
            let rec = self.run_epoch(epoch)?;
            if crate::util::logging::enabled(crate::util::logging::Level::Info) {
                crate::info!(
                    "[{}] epoch {:>3}  loss {:.4}  acc {}  hidden {:>5} (mb {:>4})  lr {:.4}  {:.2}s",
                    self.strategy.name(),
                    rec.epoch,
                    rec.train_loss,
                    if rec.val_acc.is_finite() { format!("{:.4}", rec.val_acc) } else { "  -  ".into() },
                    rec.hidden,
                    rec.moved_back,
                    rec.lr,
                    rec.time_total,
                );
            }
            records.push(rec);
            // barrier: fold any service-lane results that have completed
            // (merged in (epoch, eval-before-checkpoint) order and keyed
            // by epoch, so fold-in is deterministic whichever of the two
            // lanes finished first)
            self.fold_service(&mut records, start_epoch, false)?;
            self.fold_serve(&mut records, start_epoch)?;
        }
        // final barrier: every outstanding async eval/checkpoint completes
        // before the run result is assembled
        self.fold_service(&mut records, start_epoch, true)?;
        self.fold_serve(&mut records, start_epoch)?;
        Ok(RunResult::from_records(
            &self.cfg.name,
            &self.strategy.name(),
            records,
        ))
    }

    /// Run one epoch through the staged pipeline
    /// (`Plan -> Train -> Refresh -> Eval -> Checkpoint -> Metrics`).
    pub fn run_epoch(&mut self, epoch: usize) -> anyhow::Result<EpochRecord> {
        EpochPipeline::run(self, epoch)
    }

    /// Spawn the service lanes if `cfg.service_lane` asked for them and
    /// they are not up yet.  The eval lane gets its own replica of the
    /// executor (built on the lane thread via the `ReplicaBuilder`
    /// contract) and a clone of the validation set; the checkpoint lane
    /// spawns only when checkpointing is configured, with a writer that
    /// serializes full-state snapshots through `runtime/checkpoint.rs`.
    pub(crate) fn ensure_service(&mut self) -> anyhow::Result<()> {
        if self.service.is_some() {
            return Ok(());
        }
        let builder = crate::engine::DataParallel::replica_builder(&self.exec)?;
        let pool_threads = self.cfg.checkpoint_pool;
        let compress = self.cfg.checkpoint_compress;
        let writer = self.cfg.checkpoint_dir.clone().map(|dir| {
            let meta = self.exec.meta.clone();
            // the lane thread owns a persistent write pool: leaf jobs fan
            // out per save and join before the manifest flip
            let pool = crate::util::artifact::WritePool::new(pool_threads);
            Box::new(move |snap: SharedSnapshot, epoch: usize| {
                crate::runtime::checkpoint::save_snapshot(&meta, &snap, &dir, epoch, &pool, compress)
            }) as CheckpointWriter
        });
        self.service = Some(ServiceLanes::spawn(
            builder,
            self.data.val.clone(),
            self.engine.batch(),
            writer,
        )?);
        Ok(())
    }

    /// Spawn the online inference lane if `cfg.serve` names an address
    /// and it is not up yet: a retention-bounded snapshot hub,
    /// `--serve-replicas` serving replicas each on its own lane thread
    /// (the same `ReplicaBuilder` contract the eval lane uses) with
    /// `--serve-batch` query coalescing, and the HTTP front end.  The
    /// dataset's geometry becomes the serving shape, so malformed query
    /// payloads are rejected at the HTTP layer and never reach a
    /// replica.
    pub(crate) fn ensure_serve(&mut self) -> anyhow::Result<()> {
        if self.serve.is_some() {
            return Ok(());
        }
        let Some(addr) = self.cfg.serve.clone() else { return Ok(()) };
        let hub = Arc::new(SnapshotHub::with_retain(self.cfg.serve_retain));
        let builders = (0..self.cfg.serve_replicas)
            .map(|_| crate::engine::DataParallel::replica_builder(&self.exec))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let batching = ServeBatching {
            max_batch: self.cfg.serve_batch,
            max_wait: std::time::Duration::from_micros(self.cfg.serve_batch_wait_us),
        };
        let fleet = ServeFleet::spawn(builders, hub.clone(), batching)?;
        let shape = ServingShape {
            input_dim: self.data.train.sample_dim,
            classes: self.data.train.classes,
        };
        let server = InferenceServer::start(
            &addr,
            self.cfg.serve_threads,
            hub.clone(),
            fleet.client(),
            Some(shape),
        )?;
        crate::info!(
            "[serve] listening on {} ({} replica lanes, batch {})",
            server.addr(),
            fleet.lanes(),
            self.cfg.serve_batch
        );
        self.serve = Some(ServeRuntime { server, fleet, hub });
        Ok(())
    }

    /// The inference server's bound address (`None` when `--serve` is
    /// off or the lane has not spawned yet).  Port 0 resolves to the
    /// actual port here.
    pub fn serve_addr(&self) -> Option<std::net::SocketAddr> {
        self.serve.as_ref().map(|s| s.server.addr())
    }

    /// Fold the inference lane's activity into the epoch records at a
    /// barrier: queries / device batches answered since the last fold
    /// attribute to the newest record (with the mean batch fill and the
    /// per-lane query split), and serving-replica failures ride the
    /// same fault-policy contract as the eval/checkpoint lanes — named
    /// abort under `fail`, count-and-continue (that lane down on
    /// `/healthz`) under `elastic`.
    fn fold_serve(
        &mut self,
        records: &mut [EpochRecord],
        start_epoch: usize,
    ) -> anyhow::Result<()> {
        let Some(serve) = self.serve.as_mut() else { return Ok(()) };
        let queries = serve.hub.take_queries();
        let batches = serve.hub.take_batches();
        let lane_queries = serve.hub.take_lane_queries();
        if let Some(rec) = records.last_mut() {
            rec.serve_queries += queries;
            rec.serve_batches += batches;
            rec.serve_batch_fill = if rec.serve_batches > 0 {
                rec.serve_queries as f64 / rec.serve_batches as f64
            } else {
                0.0
            };
            if queries > 0 {
                if rec.serve_lane_queries.len() < lane_queries.len() {
                    rec.serve_lane_queries.resize(lane_queries.len(), 0);
                }
                for (slot, q) in rec.serve_lane_queries.iter_mut().zip(&lane_queries) {
                    *slot += q;
                }
            }
        }
        for ev in serve.fleet.try_events() {
            if let ServiceEvent::Error { epoch, lane, message, secs } = ev {
                anyhow::ensure!(
                    self.cfg.fault_policy == FaultPolicy::Elastic,
                    "service {} lane failed at epoch {epoch}: {message} \
                     (--fault-policy fail aborts; elastic counts the \
                     failure and continues)",
                    lane.name()
                );
                if let Some(rec) = records
                    .get_mut(epoch.saturating_sub(start_epoch).min(records.len().saturating_sub(1)))
                {
                    rec.service_errors += 1;
                    rec.time_service += secs;
                }
                crate::info!(
                    "[serve] epoch {epoch:>3}  {} lane error: {message}",
                    lane.name()
                );
            }
        }
        Ok(())
    }

    /// Fold completed service-lane events into their epochs' records.
    /// `block` waits for every outstanding job (the end-of-run barrier);
    /// otherwise only already-completed events fold.
    fn fold_service(
        &mut self,
        records: &mut [EpochRecord],
        start_epoch: usize,
        block: bool,
    ) -> anyhow::Result<()> {
        let Some(lanes) = self.service.as_mut() else { return Ok(()) };
        let events = if block { lanes.drain()? } else { lanes.try_events()? };
        for ev in events {
            let idx = ev.epoch() - start_epoch;
            anyhow::ensure!(idx < records.len(), "service event for unknown epoch");
            let rec = &mut records[idx];
            rec.time_service += ev.secs();
            match ev {
                ServiceEvent::Eval { epoch, acc, loss, .. } => {
                    rec.val_acc = acc;
                    rec.val_loss = loss;
                    // the per-epoch log line printed before this result
                    // came back; surface the folded accuracy so async
                    // runs keep live accuracy monitoring
                    crate::info!("[service] epoch {epoch:>3}  acc {acc:.4}  val loss {loss:.4}");
                }
                ServiceEvent::Checkpoint { stats, .. } => rec.fold_ckpt_stats(&stats),
                ServiceEvent::Error { epoch, lane, message, .. } => {
                    // a failed lane job is a lane fault: the configured
                    // fault policy decides between a named abort and
                    // count-and-continue (the lane itself survived and
                    // keeps serving its queue either way)
                    anyhow::ensure!(
                        self.cfg.fault_policy == FaultPolicy::Elastic,
                        "service {} lane failed at epoch {epoch}: {message} \
                         (--fault-policy fail aborts; elastic counts the \
                         failure and continues)",
                        lane.name()
                    );
                    rec.service_errors += 1;
                    crate::info!(
                        "[service] epoch {epoch:>3}  {} lane error: {message}",
                        lane.name()
                    );
                }
            }
        }
        Ok(())
    }

    /// Forward-only stat refresh over `indices` (hidden list), sharded
    /// across the worker pool when `cfg.workers > 1` and the list spans
    /// at least one batch per worker — smaller lists stay single-stream,
    /// since batch-aligned wrap padding would multiply the forward count
    /// for no gather parallelism.  (Wrap-padding duplicates re-record
    /// identical values, so the resulting state is unchanged either way.)
    /// Returns the pool's gather stall (0 single-stream).
    pub(crate) fn refresh_stats(&mut self, indices: &[u32], epoch: u32) -> anyhow::Result<f64> {
        let mut sink = RefreshSink::new(&mut self.state, epoch);
        if self.cfg.workers > 1 && indices.len() >= self.cfg.workers * self.engine.batch() {
            let shards =
                shard_order_aligned(indices, self.cfg.workers, self.engine.batch());
            let pout = self.pool.run_serial_equivalent(
                &mut self.exec,
                &self.data.train,
                &shards,
                StepMode::Forward,
                &mut sink,
            )?;
            Ok(pout.workers.iter().map(|w| w.wait_s).sum())
        } else {
            self.engine.run(
                &mut self.exec,
                &self.data.train,
                indices,
                None,
                StepMode::Forward,
                &mut sink,
            )?;
            Ok(0.0)
        }
    }

    /// Full-dataset embedding harvest into the feature cache (PFB's
    /// scoring pass), sharded across the worker pool under the same
    /// threshold rule as [`Trainer::refresh_stats`] — at least one batch
    /// per worker, else single-stream.  One `fwd_embed` sweep fills the
    /// cache *and* refreshes every sample's lagging stats; the commit
    /// stamps the rows with `epoch`.  Returns the pool's gather stall
    /// (0 single-stream).
    pub(crate) fn harvest_features(&mut self, epoch: u32) -> anyhow::Result<f64> {
        let n = self.data.train.n;
        let all: Vec<u32> = (0..n as u32).collect();
        if self.cfg.workers > 1 && n >= self.cfg.workers * self.engine.batch() {
            let shards = shard_order_aligned(&all, self.cfg.workers, self.engine.batch());
            let pout = execute_sharded_harvest(
                &mut self.pool,
                &mut self.exec,
                &self.data.train,
                &shards,
                epoch,
                &mut self.state,
                &mut self.feat_cache,
            )?;
            Ok(pout.workers.iter().map(|w| w.wait_s).sum())
        } else {
            execute_feature_harvest(
                &mut self.engine,
                &mut self.exec,
                &self.data.train,
                &all,
                epoch,
                &mut self.state,
                &mut self.feat_cache,
            )?;
            Ok(0.0)
        }
    }

    /// Validation top-1 accuracy + mean loss (synchronous path; the async
    /// service lane computes the bitwise-identical result off-path).
    pub fn evaluate(&mut self) -> anyhow::Result<(f64, f64)> {
        let mut sink = EvalSink::default();
        self.engine.run(
            &mut self.exec,
            &self.data.val,
            &self.eval_idx,
            None,
            StepMode::Forward,
            &mut sink,
        )?;
        Ok(sink.result())
    }

    /// Display name of the configured strategy.
    pub fn strategy_name(&self) -> String {
        self.strategy.name()
    }
}
