//! The training coordinator: runs a full experiment (epochs x batches)
//! against the PJRT runtime, driving the configured strategy, schedules,
//! stat bookkeeping, evaluation, and the cost model.
//!
//! This is the L3 "request path": after construction no Python and no
//! compilation happens — only artifact execution and host-side
//! coordination.  The coordinator *plans* (strategy selection, sharding,
//! learning rate); all per-step execution — batch gather, device steps,
//! stat recording — routes through the pipelined `engine` module, which
//! overlaps host-side gather with device execution.
//!
//! With `cfg.workers > 1` the plain training pass and the hidden-stat
//! refresh run through the engine's `WorkerPool`: the epoch order is
//! sharded batch-aligned across N concurrent gather lanes behind a
//! bulk-synchronous barrier with a deterministic `(step, worker)`
//! reduction.  `cfg.dp` picks the training schedule: the default
//! serial-equivalent schedule is bitwise identical to the single-stream
//! interleaved run; `--dp average` trains per-worker replicas of the real
//! executor and averages parameters at every step barrier — true
//! synchronous SGD (docs/worker-model.md).  The hidden-stat refresh is
//! forward-only, so it always uses the serial-equivalent schedule (both
//! schedules produce identical bits there; serial-equivalent skips the
//! state export).  Weighted plans (ISWR / InfoBatch / GradMatch) and the
//! SB candidate stream stay single-stream, matching the paper's W = 1
//! setup for those baselines — `--dp average` with such a strategy is
//! rejected at config validation.

use crate::config::{DpMode, ExperimentConfig, StrategyConfig};
use crate::coordinator::costmodel::CostModel;
use crate::data::shard::shard_order_aligned;
use crate::data::TrainVal;
use crate::engine::{
    execute_plan, execute_sharded_average, execute_sharded_plain, Engine, EvalSink, RefreshSink,
    StepMode, WorkerPool,
};
use crate::metrics::{EpochRecord, RunResult};
use crate::runtime::{ModelExecutor, XlaRuntime};
use crate::state::SampleState;
use crate::strategies::sb::SbSelector;
use crate::strategies::{BatchMode, PlanCtx, Strategy};
use crate::util::rng::Rng;
use crate::util::stats::Histogram;
use crate::util::timer::Timer;

/// Runs one experiment end to end: plans every epoch (strategy, LR,
/// sharding) and drives the engine / worker pool through the PJRT
/// executor, producing per-epoch records.
pub struct Trainer {
    /// The full experiment configuration the run was built from.
    pub cfg: ExperimentConfig,
    /// The PJRT executor holding model parameters as device literals.
    pub exec: ModelExecutor,
    /// Train + validation datasets (generated once per run).
    pub data: TrainVal,
    /// Per-sample lagging loss / PA / PC store.
    pub state: SampleState,
    /// Calibrated paper-scale cost model.
    pub cost: CostModel,
    /// The pipelined step-execution driver (owns the reusable batch
    /// buffers shared by training, refresh, and eval passes).
    pub engine: Engine,
    /// The multi-worker execution driver used when `cfg.workers > 1`
    /// (N gather lanes behind a deterministic bulk-synchronous reduction).
    pub pool: WorkerPool,
    strategy: Box<dyn Strategy>,
    rng: Rng,
    sb: SbSelector,
    /// Pending SB-selected samples waiting to fill a training batch.
    sb_queue: Vec<u32>,
    /// Cached 0..val.n index list (reused across evals).
    eval_idx: Vec<u32>,
    /// Epoch at which training last (re)started — FORGET resets the LR
    /// schedule when it restarts from scratch (paper §4: "training then
    /// restarts from epoch 0").
    schedule_offset: usize,
}

impl Trainer {
    /// Build a trainer: generate the dataset, compile the variant's
    /// artifacts, calibrate the cost model, and size the execution
    /// engine + worker pool.
    pub fn new(rt: &XlaRuntime, cfg: ExperimentConfig) -> anyhow::Result<Self> {
        cfg.validate()?;
        let data = cfg.dataset.generate(cfg.seed);
        let mut exec = ModelExecutor::new(rt, &cfg.variant, cfg.seed)?;
        exec.momentum = cfg.momentum;
        anyhow::ensure!(
            exec.meta.sample_dim() == data.train.sample_dim,
            "variant {} expects sample dim {}, dataset {} provides {}",
            cfg.variant,
            exec.meta.sample_dim(),
            data.train.name,
            data.train.sample_dim
        );
        anyhow::ensure!(
            exec.meta.label_len() == data.train.label_len,
            "label shape mismatch between variant and dataset"
        );
        anyhow::ensure!(
            exec.meta.classes == data.train.classes,
            "variant {} has {} classes, dataset {} has {}",
            cfg.variant,
            exec.meta.classes,
            data.train.name,
            data.train.classes
        );
        let state = SampleState::new(data.train.n);
        let cost = rt.cost_model(&mut exec)?;
        // calibration perturbs params: reset to the seeded init
        exec.reset_params(cfg.seed)?;
        let strategy = crate::strategies::build(&cfg.strategy, cfg.epochs);
        let beta = match cfg.strategy {
            StrategyConfig::SelectiveBackprop { beta } => beta,
            _ => 1.0,
        };
        let engine = Engine::new(&data.train, exec.meta.batch);
        let pool = WorkerPool::new(&data.train, exec.meta.batch);
        let eval_idx: Vec<u32> = (0..data.val.n as u32).collect();
        Ok(Trainer {
            rng: Rng::new(cfg.seed ^ 0x7472_6169),
            sb: SbSelector::new(beta, 4096),
            sb_queue: Vec::new(),
            eval_idx,
            schedule_offset: 0,
            cfg,
            exec,
            data,
            state,
            cost,
            engine,
            pool,
            strategy,
        })
    }

    /// Run the configured number of epochs; returns the full RunResult.
    pub fn run(&mut self) -> anyhow::Result<RunResult> {
        let mut start_epoch = 0;
        if self.cfg.resume {
            let dir = self.cfg.checkpoint_dir.clone().ok_or_else(|| {
                anyhow::anyhow!("resume requested without checkpoint_dir")
            })?;
            start_epoch = crate::runtime::checkpoint::load(&mut self.exec, &dir)? + 1;
            crate::info!("resumed from {dir:?} at epoch {start_epoch}");
        }
        let mut records = Vec::with_capacity(self.cfg.epochs);
        for epoch in start_epoch..self.cfg.epochs {
            let rec = self.run_epoch(epoch)?;
            if self.cfg.checkpoint_every > 0
                && (epoch % self.cfg.checkpoint_every == 0 || epoch + 1 == self.cfg.epochs)
            {
                if let Some(dir) = &self.cfg.checkpoint_dir {
                    crate::runtime::checkpoint::save(&self.exec, dir, epoch)?;
                }
            }
            if crate::util::logging::enabled(crate::util::logging::Level::Info) {
                crate::info!(
                    "[{}] epoch {:>3}  loss {:.4}  acc {}  hidden {:>5} (mb {:>4})  lr {:.4}  {:.2}s",
                    self.strategy.name(),
                    rec.epoch,
                    rec.train_loss,
                    if rec.val_acc.is_finite() { format!("{:.4}", rec.val_acc) } else { "  -  ".into() },
                    rec.hidden,
                    rec.moved_back,
                    rec.lr,
                    rec.time_total,
                );
            }
            records.push(rec);
        }
        Ok(RunResult::from_records(
            &self.cfg.name,
            &self.strategy.name(),
            records,
        ))
    }

    /// Run one epoch: plan (strategy selection) -> train (engine / pool)
    /// -> hidden-stat refresh -> evaluation -> metrics + cost model.
    pub fn run_epoch(&mut self, epoch: usize) -> anyhow::Result<EpochRecord> {
        let mut rec = EpochRecord { epoch, val_acc: f64::NAN, ..Default::default() };

        // --- plan (selection) -------------------------------------------
        let t = Timer::start();
        let plan = {
            let mut ctx = PlanCtx {
                epoch,
                total_epochs: self.cfg.epochs,
                data: &self.data.train,
                state: &mut self.state,
                rng: &mut self.rng,
                exec: Some(&mut self.exec),
            };
            self.strategy.plan_epoch(&mut ctx)?
        };
        rec.time_select = t.elapsed_s();

        if plan.reset_params {
            self.exec.reset_params(self.cfg.seed)?;
            self.schedule_offset = epoch;
        }

        // --- learning rate -----------------------------------------------
        rec.base_lr = self.cfg.lr.at(epoch - self.schedule_offset);
        rec.lr = rec.base_lr * plan.lr_scale;
        rec.fraction_ceiling = self.strategy.fraction_ceiling(epoch);
        rec.max_hidden = plan.max_hidden;
        rec.hidden = plan.hidden.len();
        rec.moved_back = plan.moved_back;

        // --- train (through the step engine / worker pool) -----------------
        let t = Timer::start();
        // Data-parallel execution: shard the epoch batch-aligned across
        // the worker pool (weighted plans skip this — they are W=1 per
        // paper; SB consumes its candidate stream unsharded).  `--dp`
        // picks the pool schedule: the bitwise serial-equivalent default,
        // or true parameter-averaging synchronous SGD on per-worker
        // replicas of the executor.
        let outcome = match plan.batch_mode {
            BatchMode::Plain if self.cfg.workers > 1 && plan.weights.is_none() => {
                let shards = shard_order_aligned(
                    &plan.order,
                    self.cfg.workers,
                    self.engine.batch(),
                );
                let (outcome, pout) = match self.cfg.dp {
                    DpMode::SerialEquivalent => execute_sharded_plain(
                        &mut self.pool,
                        &mut self.exec,
                        &self.data.train,
                        &shards,
                        rec.lr as f32,
                        epoch as u32,
                        &mut self.state,
                    )?,
                    DpMode::Average => execute_sharded_average(
                        &mut self.pool,
                        &mut self.exec,
                        &self.data.train,
                        &shards,
                        rec.lr as f32,
                        epoch as u32,
                        &mut self.state,
                    )?,
                };
                rec.worker_samples = pout.workers.iter().map(|w| w.samples).collect();
                rec.time_barrier += pout.workers.iter().map(|w| w.wait_s).sum::<f64>();
                rec.dp_syncs = pout.sync_steps;
                rec.time_average = pout.time_average;
                rec.modeled_sync =
                    self.cost.sync_overhead(pout.sync_steps, self.cfg.workers);
                outcome
            }
            _ => execute_plan(
                &mut self.engine,
                &mut self.exec,
                &self.data.train,
                &plan.order,
                plan.weights.as_deref(),
                plan.batch_mode,
                rec.lr as f32,
                epoch as u32,
                &mut self.state,
                &mut self.sb,
                &mut self.rng,
                &mut self.sb_queue,
            )?,
        };
        rec.trained_samples = outcome.trained_samples;
        rec.backprop_samples = outcome.backprop_samples;
        rec.train_loss = outcome.train_loss;
        rec.time_train = t.elapsed_s();

        // --- hidden-list stat refresh (paper step D.1) ---------------------
        let t = Timer::start();
        let mut refreshed = 0usize;
        if self.strategy.refresh_hidden_stats() && !plan.hidden.is_empty() {
            refreshed = plan.hidden.len();
            rec.time_barrier += self.refresh_stats(&plan.hidden, epoch as u32)?;
        }
        rec.time_refresh = t.elapsed_s();
        rec.hidden_again = self.state.hidden_again_count();

        // --- evaluation ----------------------------------------------------
        let eval_due =
            epoch % self.cfg.eval_every.max(1) == 0 || epoch + 1 == self.cfg.epochs;
        if eval_due {
            let t = Timer::start();
            let (acc, loss) = self.evaluate()?;
            rec.val_acc = acc;
            rec.val_loss = loss;
            rec.time_eval = t.elapsed_s();
        }

        // --- detailed metrics (Figs. 5-8) ----------------------------------
        if self.cfg.detailed_metrics {
            rec.hidden_per_class = self.state.hidden_per_class(&self.data.train);
            let finite: Vec<f32> = self
                .state
                .loss
                .iter()
                .copied()
                .filter(|l| l.is_finite())
                .collect();
            if !finite.is_empty() {
                let hi = crate::util::stats::percentile(&finite, 99.5).max(0.1);
                rec.loss_hist = Some(Histogram::of(&finite, 0.0, hi, 40));
            }
        }

        // Training time excludes eval (the paper's epoch timing measures
        // the training pipeline; top-1 curves are checkpoint evals).
        rec.time_total = rec.time_select + rec.time_train + rec.time_refresh;

        // --- cost model: paper-scale projection -----------------------------
        let select_n = match &self.cfg.strategy {
            StrategyConfig::Baseline => 0,
            _ => self.data.train.n,
        };
        rec.modeled_time = self.cost.epoch_time(
            rec.backprop_samples,
            refreshed + rec.trained_samples.saturating_sub(rec.backprop_samples),
            select_n,
            self.cfg.workers,
        );
        Ok(rec)
    }

    /// Forward-only stat refresh over `indices` (hidden list), sharded
    /// across the worker pool when `cfg.workers > 1` and the list spans
    /// at least one batch per worker — smaller lists stay single-stream,
    /// since batch-aligned wrap padding would multiply the forward count
    /// for no gather parallelism.  (Wrap-padding duplicates re-record
    /// identical values, so the resulting state is unchanged either way.)
    /// Returns the pool's gather stall (0 single-stream).
    fn refresh_stats(&mut self, indices: &[u32], epoch: u32) -> anyhow::Result<f64> {
        let mut sink = RefreshSink::new(&mut self.state, epoch);
        if self.cfg.workers > 1 && indices.len() >= self.cfg.workers * self.engine.batch() {
            let shards =
                shard_order_aligned(indices, self.cfg.workers, self.engine.batch());
            let pout = self.pool.run_serial_equivalent(
                &mut self.exec,
                &self.data.train,
                &shards,
                StepMode::Forward,
                &mut sink,
            )?;
            Ok(pout.workers.iter().map(|w| w.wait_s).sum())
        } else {
            self.engine.run(
                &mut self.exec,
                &self.data.train,
                indices,
                None,
                StepMode::Forward,
                &mut sink,
            )?;
            Ok(0.0)
        }
    }

    /// Validation top-1 accuracy + mean loss.
    pub fn evaluate(&mut self) -> anyhow::Result<(f64, f64)> {
        let mut sink = EvalSink::default();
        self.engine.run(
            &mut self.exec,
            &self.data.val,
            &self.eval_idx,
            None,
            StepMode::Forward,
            &mut sink,
        )?;
        Ok(sink.result())
    }

    /// Display name of the configured strategy.
    pub fn strategy_name(&self) -> String {
        self.strategy.name()
    }
}
