//! The training coordinator: runs a full experiment (epochs x batches)
//! against the PJRT runtime, driving the configured strategy, schedules,
//! stat bookkeeping, evaluation, and the cost model.
//!
//! This is the L3 "request path": after construction no Python and no
//! compilation happens — only artifact execution and host-side
//! coordination.  The coordinator *plans* (strategy selection, sharding,
//! learning rate); all per-step execution — batch gather, device steps,
//! stat recording — routes through the pipelined `engine` module, which
//! overlaps host-side gather with device execution.

use crate::config::{ExperimentConfig, StrategyConfig};
use crate::coordinator::costmodel::CostModel;
use crate::data::shard::{global_step_order, shard_order};
use crate::data::TrainVal;
use crate::engine::{execute_plan, Engine, EvalSink, RefreshSink, StepMode};
use crate::metrics::{EpochRecord, RunResult};
use crate::runtime::{ModelExecutor, XlaRuntime};
use crate::state::SampleState;
use crate::strategies::sb::SbSelector;
use crate::strategies::{BatchMode, PlanCtx, Strategy};
use crate::util::rng::Rng;
use crate::util::stats::Histogram;
use crate::util::timer::Timer;

pub struct Trainer {
    pub cfg: ExperimentConfig,
    pub exec: ModelExecutor,
    pub data: TrainVal,
    pub state: SampleState,
    pub cost: CostModel,
    /// The pipelined step-execution driver (owns the reusable batch
    /// buffers shared by training, refresh, and eval passes).
    pub engine: Engine,
    strategy: Box<dyn Strategy>,
    rng: Rng,
    sb: SbSelector,
    /// Pending SB-selected samples waiting to fill a training batch.
    sb_queue: Vec<u32>,
    /// Cached 0..val.n index list (reused across evals).
    eval_idx: Vec<u32>,
    /// Epoch at which training last (re)started — FORGET resets the LR
    /// schedule when it restarts from scratch (paper §4: "training then
    /// restarts from epoch 0").
    schedule_offset: usize,
}

impl Trainer {
    pub fn new(rt: &XlaRuntime, cfg: ExperimentConfig) -> anyhow::Result<Self> {
        cfg.validate()?;
        let data = cfg.dataset.generate(cfg.seed);
        let mut exec = ModelExecutor::new(rt, &cfg.variant, cfg.seed)?;
        exec.momentum = cfg.momentum;
        anyhow::ensure!(
            exec.meta.sample_dim() == data.train.sample_dim,
            "variant {} expects sample dim {}, dataset {} provides {}",
            cfg.variant,
            exec.meta.sample_dim(),
            data.train.name,
            data.train.sample_dim
        );
        anyhow::ensure!(
            exec.meta.label_len() == data.train.label_len,
            "label shape mismatch between variant and dataset"
        );
        anyhow::ensure!(
            exec.meta.classes == data.train.classes,
            "variant {} has {} classes, dataset {} has {}",
            cfg.variant,
            exec.meta.classes,
            data.train.name,
            data.train.classes
        );
        let state = SampleState::new(data.train.n);
        let cost = rt.cost_model(&mut exec)?;
        // calibration perturbs params: reset to the seeded init
        exec.reset_params(cfg.seed)?;
        let strategy = crate::strategies::build(&cfg.strategy, cfg.epochs);
        let beta = match cfg.strategy {
            StrategyConfig::SelectiveBackprop { beta } => beta,
            _ => 1.0,
        };
        let engine = Engine::new(&data.train, exec.meta.batch);
        let eval_idx: Vec<u32> = (0..data.val.n as u32).collect();
        Ok(Trainer {
            rng: Rng::new(cfg.seed ^ 0x7472_6169),
            sb: SbSelector::new(beta, 4096),
            sb_queue: Vec::new(),
            eval_idx,
            schedule_offset: 0,
            cfg,
            exec,
            data,
            state,
            cost,
            engine,
            strategy,
        })
    }

    /// Run the configured number of epochs; returns the full RunResult.
    pub fn run(&mut self) -> anyhow::Result<RunResult> {
        let mut start_epoch = 0;
        if self.cfg.resume {
            let dir = self.cfg.checkpoint_dir.clone().ok_or_else(|| {
                anyhow::anyhow!("resume requested without checkpoint_dir")
            })?;
            start_epoch = crate::runtime::checkpoint::load(&mut self.exec, &dir)? + 1;
            crate::info!("resumed from {dir:?} at epoch {start_epoch}");
        }
        let mut records = Vec::with_capacity(self.cfg.epochs);
        for epoch in start_epoch..self.cfg.epochs {
            let rec = self.run_epoch(epoch)?;
            if self.cfg.checkpoint_every > 0
                && (epoch % self.cfg.checkpoint_every == 0 || epoch + 1 == self.cfg.epochs)
            {
                if let Some(dir) = &self.cfg.checkpoint_dir {
                    crate::runtime::checkpoint::save(&self.exec, dir, epoch)?;
                }
            }
            if crate::util::logging::enabled(crate::util::logging::Level::Info) {
                crate::info!(
                    "[{}] epoch {:>3}  loss {:.4}  acc {}  hidden {:>5} (mb {:>4})  lr {:.4}  {:.2}s",
                    self.strategy.name(),
                    rec.epoch,
                    rec.train_loss,
                    if rec.val_acc.is_finite() { format!("{:.4}", rec.val_acc) } else { "  -  ".into() },
                    rec.hidden,
                    rec.moved_back,
                    rec.lr,
                    rec.time_total,
                );
            }
            records.push(rec);
        }
        Ok(RunResult::from_records(
            &self.cfg.name,
            &self.strategy.name(),
            records,
        ))
    }

    pub fn run_epoch(&mut self, epoch: usize) -> anyhow::Result<EpochRecord> {
        let mut rec = EpochRecord { epoch, val_acc: f64::NAN, ..Default::default() };

        // --- plan (selection) -------------------------------------------
        let t = Timer::start();
        let plan = {
            let mut ctx = PlanCtx {
                epoch,
                total_epochs: self.cfg.epochs,
                data: &self.data.train,
                state: &mut self.state,
                rng: &mut self.rng,
                exec: Some(&mut self.exec),
            };
            self.strategy.plan_epoch(&mut ctx)?
        };
        rec.time_select = t.elapsed_s();

        if plan.reset_params {
            self.exec.reset_params(self.cfg.seed)?;
            self.schedule_offset = epoch;
        }

        // --- learning rate -----------------------------------------------
        rec.base_lr = self.cfg.lr.at(epoch - self.schedule_offset);
        rec.lr = rec.base_lr * plan.lr_scale;
        rec.fraction_ceiling = self.strategy.fraction_ceiling(epoch);
        rec.max_hidden = plan.max_hidden;
        rec.hidden = plan.hidden.len();
        rec.moved_back = plan.moved_back;

        // --- train (through the step engine) -------------------------------
        let t = Timer::start();
        // Distributed fidelity: interleave worker shards into the global
        // batch order (weighted plans skip this — they are W=1 per paper;
        // SB consumes its candidate stream unsharded).  Avoid cloning the
        // epoch order in the common single-worker / unweighted case
        // (§Perf: saves an O(N) copy per epoch).
        let sharded: Option<Vec<u32>> = match plan.batch_mode {
            BatchMode::Plain if self.cfg.workers > 1 && plan.weights.is_none() => {
                Some(global_step_order(&shard_order(&plan.order, self.cfg.workers)))
            }
            _ => None,
        };
        let order: &[u32] = sharded.as_deref().unwrap_or(&plan.order);
        let outcome = execute_plan(
            &mut self.engine,
            &mut self.exec,
            &self.data.train,
            order,
            plan.weights.as_deref(),
            plan.batch_mode,
            rec.lr as f32,
            epoch as u32,
            &mut self.state,
            &mut self.sb,
            &mut self.rng,
            &mut self.sb_queue,
        )?;
        rec.trained_samples = outcome.trained_samples;
        rec.backprop_samples = outcome.backprop_samples;
        rec.train_loss = outcome.train_loss;
        rec.time_train = t.elapsed_s();

        // --- hidden-list stat refresh (paper step D.1) ---------------------
        let t = Timer::start();
        let mut refreshed = 0usize;
        if self.strategy.refresh_hidden_stats() && !plan.hidden.is_empty() {
            refreshed = plan.hidden.len();
            self.refresh_stats(&plan.hidden, epoch as u32)?;
        }
        rec.time_refresh = t.elapsed_s();
        rec.hidden_again = self.state.hidden_again_count();

        // --- evaluation ----------------------------------------------------
        let eval_due =
            epoch % self.cfg.eval_every.max(1) == 0 || epoch + 1 == self.cfg.epochs;
        if eval_due {
            let t = Timer::start();
            let (acc, loss) = self.evaluate()?;
            rec.val_acc = acc;
            rec.val_loss = loss;
            rec.time_eval = t.elapsed_s();
        }

        // --- detailed metrics (Figs. 5-8) ----------------------------------
        if self.cfg.detailed_metrics {
            rec.hidden_per_class = self.state.hidden_per_class(&self.data.train);
            let finite: Vec<f32> = self
                .state
                .loss
                .iter()
                .copied()
                .filter(|l| l.is_finite())
                .collect();
            if !finite.is_empty() {
                let hi = crate::util::stats::percentile(&finite, 99.5).max(0.1);
                rec.loss_hist = Some(Histogram::of(&finite, 0.0, hi, 40));
            }
        }

        // Training time excludes eval (the paper's epoch timing measures
        // the training pipeline; top-1 curves are checkpoint evals).
        rec.time_total = rec.time_select + rec.time_train + rec.time_refresh;

        // --- cost model: paper-scale projection -----------------------------
        let select_n = match &self.cfg.strategy {
            StrategyConfig::Baseline => 0,
            _ => self.data.train.n,
        };
        rec.modeled_time = self.cost.epoch_time(
            rec.backprop_samples,
            refreshed + rec.trained_samples.saturating_sub(rec.backprop_samples),
            select_n,
            self.cfg.workers,
        );
        Ok(rec)
    }

    /// Forward-only stat refresh over `indices` (hidden list).
    fn refresh_stats(&mut self, indices: &[u32], epoch: u32) -> anyhow::Result<()> {
        let mut sink = RefreshSink::new(&mut self.state, epoch);
        self.engine.run(
            &mut self.exec,
            &self.data.train,
            indices,
            None,
            StepMode::Forward,
            &mut sink,
        )
    }

    /// Validation top-1 accuracy + mean loss.
    pub fn evaluate(&mut self) -> anyhow::Result<(f64, f64)> {
        let mut sink = EvalSink::default();
        self.engine.run(
            &mut self.exec,
            &self.data.val,
            &self.eval_idx,
            None,
            StepMode::Forward,
            &mut sink,
        )?;
        Ok(sink.result())
    }

    pub fn strategy_name(&self) -> String {
        self.strategy.name()
    }
}
