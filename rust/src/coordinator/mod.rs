//! L3 coordination: the planning layer of the training stack.
//!
//! The [`Trainer`] *plans* each epoch — strategy selection (hide /
//! move-back / prune / weights), LR + fraction schedules, worker
//! sharding, checkpointing, metrics — and drives it through the staged
//! [`EpochPipeline`] (`Plan -> Train -> Refresh -> Eval -> Checkpoint ->
//! Metrics`, each phase timed).  Execution belongs to the `engine`
//! layer: single-stream epochs go through the pipelined `Engine`,
//! multi-worker epochs (`cfg.workers > 1`) through the `WorkerPool`'s
//! deterministic bulk-synchronous schedule, and — with `--service-lane
//! on` — eval and checkpointing leave the critical path entirely via the
//! engine's split `ServiceLanes`, riding typed snapshot tiers
//! (docs/snapshots.md, docs/worker-model.md).  The [`CostModel`]
//! projects measured single-host step latencies to the paper's
//! multi-GPU scale; [`resume`] persists the coordinator-side state that
//! makes `--resume` bit-exact.

pub mod costmodel;
pub mod epoch;
pub mod resume;
pub mod trainer;

pub use costmodel::CostModel;
pub use epoch::{EpochPipeline, Phase};
pub use trainer::{ServeRuntime, Trainer};

use crate::config::ExperimentConfig;
use crate::metrics::RunResult;
use crate::runtime::XlaRuntime;

/// Convenience: build + run one experiment.
pub fn run_experiment(rt: &XlaRuntime, cfg: ExperimentConfig) -> anyhow::Result<RunResult> {
    Trainer::new(rt, cfg)?.run()
}

/// Run the same experiment once per strategy (shared runtime; fresh
/// dataset/executor per run) — the pattern behind every comparison table.
pub fn run_comparison(
    rt: &XlaRuntime,
    base: &ExperimentConfig,
    strategies: &[crate::config::StrategyConfig],
) -> anyhow::Result<Vec<RunResult>> {
    strategies
        .iter()
        .map(|s| {
            let mut cfg = base.clone();
            cfg.strategy = s.clone();
            cfg.name = format!("{}/{}", base.name, s.name());
            run_experiment(rt, cfg)
        })
        .collect()
}
