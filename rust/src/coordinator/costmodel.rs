//! Analytic epoch-time cost model: projects measured single-host step
//! latencies to the paper's multi-GPU scale (DESIGN.md §3, substitution
//! for the 32-1024 V100 testbed).
//!
//! T_epoch(W) = ceil(steps/W) · (t_fwd + t_bwd+upd + t_allreduce(W))
//!            + t_refresh (hidden-list forward, parallel over W)
//!            + t_select (sort/selection on the leader)
//!
//! with a ring-allreduce model t_allreduce = α·log2(W) + 2(W-1)/W · bytes/BW.
//! Per-sample compute constants are *calibrated* by timing the real PJRT
//! executables; the network constants default to the paper's EDR IB
//! (2 x 100 Gbps) system.

use crate::runtime::ModelExecutor;
use crate::util::timer::Timer;

/// Calibrated per-sample/step cost constants plus the network model used
/// to project epoch time to `W` data-parallel workers.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Seconds per sample, forward-only (measured).
    pub t_fwd: f64,
    /// Seconds per sample, fwd+bwd+update (measured).
    pub t_train: f64,
    /// Per-batch fixed dispatch overhead (measured).
    pub t_dispatch: f64,
    /// Batch size the constants were measured at.
    pub batch: usize,
    /// Model parameter count (allreduce volume = 4 bytes each).
    pub params: usize,
    /// Allreduce latency constant per ring step (s).
    pub net_alpha: f64,
    /// Network bandwidth (bytes/s) — default 2x100 Gbps EDR.
    pub net_bw: f64,
    /// Host-side selection cost per sample (sort/partition; measured).
    pub t_select_per_sample: f64,
}

impl CostModel {
    /// Time the real executables to calibrate per-sample constants.
    pub fn calibrate(exec: &mut ModelExecutor, reps: usize) -> anyhow::Result<Self> {
        let b = exec.meta.batch;
        let sd = exec.meta.sample_dim();
        let ll = exec.meta.label_len();
        let x = vec![0.1f32; b * sd];
        let y = vec![0i32; b * ll];
        let sw = vec![1.0f32; b];
        // warmup
        exec.train_step(&x, &y, &sw, 0.0)?;
        exec.fwd_stats(&x, &y)?;
        let t = Timer::start();
        for _ in 0..reps {
            exec.train_step(&x, &y, &sw, 0.0)?;
        }
        let t_train_batch = t.elapsed_s() / reps as f64;
        let t = Timer::start();
        for _ in 0..reps {
            exec.fwd_stats(&x, &y)?;
        }
        let t_fwd_batch = t.elapsed_s() / reps as f64;
        // dispatch overhead approximated as the fwd batch floor at B=1
        // equivalents; use 10% of fwd batch as a conservative floor.
        Ok(CostModel {
            t_fwd: t_fwd_batch / b as f64,
            t_train: t_train_batch / b as f64,
            t_dispatch: t_fwd_batch * 0.1,
            batch: b,
            params: exec.meta.param_count,
            net_alpha: 5e-6,
            net_bw: 2.0 * 100e9 / 8.0,
            t_select_per_sample: 11e-9, // measured: bench_hotpath quickselect, 10.7 ns/elem @ N=1M
        })
    }

    /// Paper-scale projection of the `--dp average` schedule's averaging
    /// overhead: one parameter allreduce per sync (the host-side fold the
    /// pool performs maps to a ring allreduce of the same volume on the
    /// paper's testbed).  Returns modeled seconds for `syncs` reductions
    /// at `workers` ranks.
    pub fn sync_overhead(&self, syncs: usize, workers: usize) -> f64 {
        syncs as f64 * self.allreduce(workers)
    }

    /// Ring allreduce time for this model's gradients across W workers.
    pub fn allreduce(&self, workers: usize) -> f64 {
        if workers <= 1 {
            return 0.0;
        }
        let bytes = (self.params * 4) as f64;
        self.net_alpha * (workers as f64).log2().ceil()
            + 2.0 * (workers as f64 - 1.0) / workers as f64 * bytes / self.net_bw
    }

    /// Modeled epoch time at `workers` data-parallel workers.
    ///
    /// * `train_samples`   — samples receiving fwd+bwd+update
    /// * `fwd_only_samples`— SB's rejected forwards + hidden-list refresh
    /// * `select_n`        — samples the leader sorts/partitions over
    pub fn epoch_time(
        &self,
        train_samples: usize,
        fwd_only_samples: usize,
        select_n: usize,
        workers: usize,
    ) -> f64 {
        let w = workers.max(1) as f64;
        let steps = (train_samples as f64 / self.batch as f64 / w).ceil();
        let per_step =
            self.batch as f64 * self.t_train + self.t_dispatch + self.allreduce(workers);
        let train = steps * per_step;
        let fwd = (fwd_only_samples as f64 * self.t_fwd) / w
            + (fwd_only_samples as f64 / self.batch as f64 / w).ceil() * self.t_dispatch;
        let select = select_n as f64 * self.t_select_per_sample;
        train + fwd + select
    }
}

impl Default for CostModel {
    /// Uncalibrated defaults (unit costs); tests only.
    fn default() -> Self {
        CostModel {
            t_fwd: 1e-5,
            t_train: 3e-5,
            t_dispatch: 1e-4,
            batch: 64,
            params: 10_000,
            net_alpha: 5e-6,
            net_bw: 25e9,
            t_select_per_sample: 11e-9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hiding_reduces_epoch_time_proportionally() {
        let m = CostModel::default();
        let full = m.epoch_time(10_000, 0, 0, 1);
        // hide 30%: train 7000, refresh 3000 forward-only
        let hid = m.epoch_time(7_000, 3_000, 10_000, 1);
        assert!(hid < full, "hid={hid} full={full}");
        // savings bounded by backward+update share
        let lower = full * 0.6;
        assert!(hid > lower);
    }

    #[test]
    fn more_workers_faster_but_sublinear() {
        let m = CostModel::default();
        let t1 = m.epoch_time(100_000, 0, 0, 1);
        let t8 = m.epoch_time(100_000, 0, 0, 8);
        let t64 = m.epoch_time(100_000, 0, 0, 64);
        assert!(t8 < t1 / 4.0);
        assert!(t64 < t8);
        // speedup degrades vs ideal due to allreduce
        assert!(t64 > t1 / 80.0);
    }

    #[test]
    fn sync_overhead_scales_with_syncs_and_workers() {
        let m = CostModel::default();
        assert_eq!(m.sync_overhead(0, 8), 0.0);
        assert_eq!(m.sync_overhead(10, 1), 0.0); // W=1 never allreduces
        assert!(m.sync_overhead(10, 8) > m.sync_overhead(5, 8));
        assert!(m.sync_overhead(10, 64) > m.sync_overhead(10, 8));
    }

    #[test]
    fn allreduce_grows_with_workers() {
        let m = CostModel::default();
        assert_eq!(m.allreduce(1), 0.0);
        assert!(m.allreduce(4) > 0.0);
        assert!(m.allreduce(64) > m.allreduce(4));
    }

    #[test]
    fn iswr_style_full_epoch_plus_bookkeeping_slower_than_baseline() {
        // ISWR trains N samples AND pays selection over N every epoch.
        let m = CostModel::default();
        let baseline = m.epoch_time(50_000, 0, 0, 4);
        let iswr = m.epoch_time(50_000, 0, 50_000, 4) + 50_000 as f64 * m.t_select_per_sample;
        assert!(iswr > baseline);
    }
}
