//! Exact-resume trainer state: the coordinator-side mutable state that
//! must ride along with the model checkpoint for a resumed run to replay
//! the uninterrupted run bit for bit.
//!
//! The model checkpoint (`runtime/checkpoint.rs`) restores parameters and
//! momentum, but the *planning* layer is stateful too: per-sample lagging
//! loss / PA / PC drive the hiding selector, the trainer's RNG stream
//! positions every shuffle, and `schedule_offset` anchors the LR schedule
//! after a FORGET restart.  This module persists all three next to the
//! checkpoint (`trainer_state.json` + `state_*.npy`) and restores them on
//! `--resume`, so epoch `k+1` of a resumed run plans from exactly the
//! state epoch `k+1` of the uninterrupted run would have seen — pinned by
//! `rust/tests/checkpoint_resume.rs`.
//!
//! Scope: exact resume covers every strategy.  Planning that is a pure
//! function of `(epoch, SampleState, rng)` — baseline, KAKURENBO (all
//! component grids), random hiding, FORGET, EL2N, InfoBatch — replays
//! from the persisted arrays + RNG stream alone, and Selective-Backprop's
//! per-run selector history (its rolling loss-CDF reservoir plus the
//! overwrite cursor) rides along as `state_sb_history.e<epoch>.npy` +
//! the manifest's `sb_cursor`, so an SB `--resume` replays the
//! acceptance stream bit-exactly too.  PFB's feature cache is likewise
//! stateful across epochs (plans between harvests score from rows
//! harvested epochs ago): a committed cache rides along as
//! `state_pfb_feats.e<epoch>.npy` (shape `[n, dim]`) plus the manifest's
//! `pfb_dim`/`pfb_epoch`, so a `--resume` mid-cache-lifetime scores the
//! resumed epochs from bit-identical rows.  Legacy checkpoints without a
//! trainer-state file still load: [`load`] returns `None` and the
//! trainer falls back to params-only resume (fresh stats, fresh RNG);
//! trainer-state files from before SB or feature-cache persistence
//! restore everything else and simply leave the selector re-warming /
//! the cache cold (PFB then trains a full epoch and re-harvests), the
//! old behavior.

use std::path::Path;

use crate::state::{FeatureCache, SampleState};
use crate::strategies::sb::SbSelector;
use crate::util::fsutil::{gc_files, write_atomic};
use crate::util::json::{parse_file, Json};
use crate::util::npy;
use crate::util::rng::Rng;

const STATE_FILE: &str = "trainer_state.json";

/// The per-sample array stems, in the fixed order [`save`] writes and
/// [`load`] reads them.
const STEMS: [&str; 9] = [
    "loss",
    "conf",
    "correct",
    "hidden",
    "hidden_prev",
    "ever_correct",
    "forget_events",
    "last_update",
    "hide_count",
];

/// Payload file name for one array stem at one epoch generation.  The
/// epoch suffix means a save never overwrites the files the current
/// `trainer_state.json` points at — the same crash-safety scheme as
/// `runtime/checkpoint.rs`.
fn state_file(stem: &str, epoch: usize) -> String {
    format!("state_{stem}.e{epoch}.npy")
}

/// Whether a directory entry is a trainer-state payload file (any
/// generation) — the set the post-save sweep may touch.  Disjoint from
/// the model checkpoint's `p###_`/`v###_` leaf files, so the two writers
/// (trainer thread, service lane) never sweep each other's files.
fn is_state_file(name: &str) -> bool {
    name.starts_with("state_") && name.ends_with(".npy")
}

fn bools_to_f32(v: &[bool]) -> Vec<f32> {
    v.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect()
}

fn u32s_to_f32(v: &[u32]) -> Vec<f32> {
    // epochs and per-sample counters stay far below 2^24, where f32 is
    // exact over the integers
    v.iter().map(|&x| x as f32).collect()
}

/// Persist the trainer-side state next to the model checkpoint in `dir`,
/// stamped with the checkpoint's `epoch` so [`load`] can detect a
/// mixed-epoch directory (e.g. a crash between the async model write and
/// this synchronous one).  Crash-safe: payload files are epoch-suffixed,
/// the manifest is replaced atomically after they are all on disk, and
/// the superseded generation is swept last.
pub fn save(
    dir: &Path,
    epoch: usize,
    state: &SampleState,
    rng: &Rng,
    sb: &SbSelector,
    feats: &FeatureCache,
    schedule_offset: usize,
) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    let n = state.n;
    let correct = bools_to_f32(&state.correct);
    let hidden = bools_to_f32(&state.hidden);
    let hidden_prev = bools_to_f32(&state.hidden_prev);
    let ever_correct = bools_to_f32(&state.ever_correct);
    let forget_events = u32s_to_f32(&state.forget_events);
    let last_update = u32s_to_f32(&state.last_update_epoch);
    let hide_count = u32s_to_f32(&state.hide_count);
    let arrays: [&[f32]; 9] = [
        &state.loss,
        &state.conf,
        &correct,
        &hidden,
        &hidden_prev,
        &ever_correct,
        &forget_events,
        &last_update,
        &hide_count,
    ];
    let mut keep = Vec::with_capacity(STEMS.len() + 1);
    for (stem, data) in STEMS.iter().zip(arrays) {
        let fname = state_file(stem, epoch);
        npy::write_f32(&dir.join(&fname), data, &[n])?;
        keep.push(fname);
    }
    // the SB selector's rolling loss reservoir (length varies — its own
    // payload, not one of the n-sized arrays); the cursor goes in the
    // manifest
    let (sb_history, sb_cursor) = sb.export_history();
    let sb_file = state_file("sb_history", epoch);
    npy::write_f32(&dir.join(&sb_file), sb_history, &[sb_history.len()])?;
    keep.push(sb_file);
    // PFB's feature cache, when a harvest has committed: the [n, dim]
    // rows as their own payload, the dim + harvest-epoch stamps in the
    // manifest.  Runs without a cache (every non-PFB strategy) write
    // neither, keeping their manifests byte-compatible with before.
    let mut pfb_meta: Vec<(&str, Json)> = Vec::new();
    if let Some((dim, harvest_epoch, rows)) = feats.export() {
        let feats_file = state_file("pfb_feats", epoch);
        npy::write_f32(&dir.join(&feats_file), rows, &[n, dim])?;
        keep.push(feats_file);
        pfb_meta.push(("pfb_dim", Json::from(dim)));
        pfb_meta.push(("pfb_epoch", Json::from(harvest_epoch as usize)));
    }
    // RNG words as hex strings: u64 state does not survive a JSON f64
    let rng_hex: Vec<Json> =
        rng.state().iter().map(|w| Json::Str(format!("{w:016x}"))).collect();
    let mut manifest = crate::jobj![
        ("n", n),
        ("epoch", epoch),
        ("schedule_offset", schedule_offset),
        ("sb_cursor", sb_cursor),
        ("rng", Json::Arr(rng_hex)),
    ];
    if let Json::Obj(m) = &mut manifest {
        for (k, v) in pfb_meta {
            m.insert(k.into(), v);
        }
    }
    // payloads reach stable storage before the manifest points at them
    for f in &keep {
        crate::util::fsutil::sync_file(&dir.join(f))?;
    }
    write_atomic(&dir.join(STATE_FILE), &manifest.to_pretty())?;
    gc_files(dir, &keep, is_state_file);
    Ok(())
}

/// Restore the trainer-side state saved by [`save`].  Returns
/// `Some(schedule_offset)` when a trainer-state snapshot was found,
/// matches the model checkpoint's `expected_epoch`, and was restored;
/// `None` for legacy (params-only) checkpoint directories *or* when the
/// epoch stamps disagree — a crash between the model write and the
/// trainer-state write leaves a mixed-epoch directory, and restoring
/// mismatched planner state would silently diverge from the
/// uninterrupted run while claiming bit-exactness.
pub fn load(
    dir: &Path,
    expected_epoch: usize,
    state: &mut SampleState,
    rng: &mut Rng,
    sb: &mut SbSelector,
    feats: &mut FeatureCache,
) -> anyhow::Result<Option<usize>> {
    let path = dir.join(STATE_FILE);
    if !path.exists() {
        return Ok(None);
    }
    let m = parse_file(&path)?;
    match m.get("epoch").and_then(|e| e.as_usize()) {
        Some(epoch) if epoch == expected_epoch => {}
        stamped => {
            crate::warn_!(
                "trainer state in {dir:?} is stamped {stamped:?} but the model \
                 checkpoint is epoch {expected_epoch}; falling back to \
                 params-only resume"
            );
            return Ok(None);
        }
    }
    let n = m.req("n")?.as_usize().unwrap_or(0);
    anyhow::ensure!(
        n == state.n,
        "trainer state is for {n} samples, this run has {}",
        state.n
    );
    let read = |stem: &str| -> anyhow::Result<Vec<f32>> {
        let name = state_file(stem, expected_epoch);
        let (data, _shape) = npy::read_f32(&dir.join(&name))?;
        anyhow::ensure!(data.len() == n, "{name}: {} values for {n} samples", data.len());
        Ok(data)
    };
    let to_bools = |v: Vec<f32>| -> Vec<bool> { v.into_iter().map(|x| x != 0.0).collect() };
    let to_u32s = |v: Vec<f32>| -> Vec<u32> { v.into_iter().map(|x| x as u32).collect() };
    state.loss = read("loss")?;
    state.conf = read("conf")?;
    state.correct = to_bools(read("correct")?);
    state.hidden = to_bools(read("hidden")?);
    state.hidden_prev = to_bools(read("hidden_prev")?);
    state.ever_correct = to_bools(read("ever_correct")?);
    state.forget_events = to_u32s(read("forget_events")?);
    state.last_update_epoch = to_u32s(read("last_update")?);
    state.hide_count = to_u32s(read("hide_count")?);
    state.rebuild_counters();

    let words = m.req("rng")?.as_arr().unwrap_or(&[]);
    anyhow::ensure!(words.len() == 4, "rng state must hold 4 words");
    let mut s = [0u64; 4];
    for (slot, j) in s.iter_mut().zip(words) {
        let hex = j.as_str().ok_or_else(|| anyhow::anyhow!("rng word not a string"))?;
        *slot = u64::from_str_radix(hex, 16)
            .map_err(|e| anyhow::anyhow!("rng word {hex:?}: {e}"))?;
    }
    *rng = Rng::from_state(s);

    // SB selector history: present since `sb_cursor` joined the
    // manifest.  Older trainer-state files restore everything else and
    // leave the selector re-warming (the pre-persistence behavior).
    if let Some(cursor) = m.get("sb_cursor").and_then(|c| c.as_usize()) {
        let name = state_file("sb_history", expected_epoch);
        let (history, _shape) = npy::read_f32(&dir.join(&name))?;
        sb.import_history(&history, cursor);
    }

    // PFB feature cache: present since `pfb_dim` joined the manifest (and
    // only when a harvest had committed at save time).  Anything else —
    // legacy manifests, or a save taken before the first harvest — leaves
    // the cache cold, and PFB falls back to a full epoch + re-harvest.
    match (
        m.get("pfb_dim").and_then(|d| d.as_usize()),
        m.get("pfb_epoch").and_then(|e| e.as_usize()),
    ) {
        (Some(dim), Some(pfb_epoch)) => {
            let name = state_file("pfb_feats", expected_epoch);
            let (rows, _shape) = npy::read_f32(&dir.join(&name))?;
            feats.import(dim, pfb_epoch as u32, rows)?;
        }
        _ => feats.invalidate(),
    }
    Ok(Some(m.req("schedule_offset")?.as_usize().unwrap_or(0)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("kakurenbo_resume_{name}_{}", std::process::id()))
    }

    /// A cold cache of size `n` — what every non-PFB run carries.
    fn no_feats(n: usize) -> FeatureCache {
        FeatureCache::new(n)
    }

    #[test]
    fn roundtrip_restores_state_rng_and_offset() {
        let dir = tmp("rt");
        let mut s = SampleState::new(10);
        for i in 0..10 {
            s.record(i, i as f32 * 0.5, i % 2 == 0, 0.1 * i as f32, 3);
        }
        s.roll_epoch();
        s.set_hidden(&[1, 4, 7]);
        let mut rng = Rng::new(42);
        for _ in 0..23 {
            rng.next_u64();
        }
        save(&dir, 7, &s, &rng, &SbSelector::new(1.0, 8), &no_feats(10), 5).unwrap();

        let mut s2 = SampleState::new(10);
        let mut rng2 = Rng::new(0);
        let mut sb2 = SbSelector::new(1.0, 8);
        let mut f2 = no_feats(10);
        let off = load(&dir, 7, &mut s2, &mut rng2, &mut sb2, &mut f2).unwrap();
        assert_eq!(off, Some(5));
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&s.loss), bits(&s2.loss));
        assert_eq!(bits(&s.conf), bits(&s2.conf));
        assert_eq!(s.correct, s2.correct);
        assert_eq!(s.hidden, s2.hidden);
        assert_eq!(s.hidden_prev, s2.hidden_prev);
        assert_eq!(s.ever_correct, s2.ever_correct);
        assert_eq!(s.forget_events, s2.forget_events);
        assert_eq!(s.last_update_epoch, s2.last_update_epoch);
        assert_eq!(s.hide_count, s2.hide_count);
        assert_eq!(s2.hidden_count(), 3);
        // the restored RNG continues the original stream bit-exactly
        let mut orig = rng;
        for _ in 0..50 {
            assert_eq!(orig.next_u64(), rng2.next_u64());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_state_file_is_legacy_none() {
        let dir = tmp("legacy");
        std::fs::create_dir_all(&dir).unwrap();
        let mut s = SampleState::new(4);
        let mut rng = Rng::new(1);
        let mut sb = SbSelector::new(1.0, 8);
        let mut f = no_feats(4);
        assert_eq!(load(&dir, 0, &mut s, &mut rng, &mut sb, &mut f).unwrap(), None);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A crash between the model-checkpoint write and the trainer-state
    /// write leaves the two stamped with different epochs; resume must
    /// fall back to params-only instead of restoring mismatched state.
    #[test]
    fn mixed_epoch_directory_falls_back_to_params_only() {
        let dir = tmp("mixed");
        let mut s = SampleState::new(5);
        s.set_hidden(&[1]);
        save(&dir, 4, &s, &Rng::new(3), &SbSelector::new(1.0, 8), &no_feats(5), 2).unwrap();
        let mut restored = SampleState::new(5);
        let mut rng = Rng::new(0);
        let mut sb = SbSelector::new(1.0, 8);
        let mut f = no_feats(5);
        let before = rng.state();
        assert_eq!(load(&dir, 2, &mut restored, &mut rng, &mut sb, &mut f).unwrap(), None);
        // nothing was restored on the mismatch path
        assert_eq!(restored.hidden_count(), 0);
        assert_eq!(rng.state(), before);
        // the matching epoch still restores
        assert_eq!(load(&dir, 4, &mut restored, &mut rng, &mut sb, &mut f).unwrap(), Some(2));
        assert_eq!(restored.hidden_count(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sample_count_mismatch_rejected() {
        let dir = tmp("mismatch");
        let s = SampleState::new(6);
        save(&dir, 0, &s, &Rng::new(2), &SbSelector::new(1.0, 8), &no_feats(6), 0).unwrap();
        let mut other = SampleState::new(7);
        let mut rng = Rng::new(2);
        let mut sb = SbSelector::new(1.0, 8);
        let mut f = no_feats(7);
        assert!(load(&dir, 0, &mut other, &mut rng, &mut sb, &mut f).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The SB selector's loss reservoir and cursor survive the roundtrip,
    /// so a restored selector replays the acceptance stream bit-exactly.
    #[test]
    fn sb_history_roundtrips() {
        let dir = tmp("sb");
        let s = SampleState::new(3);
        let mut sb = SbSelector::new(1.0, 16);
        for i in 0..40 {
            sb.record((i % 7) as f32); // overfilled: cursor has wrapped
        }
        save(&dir, 9, &s, &Rng::new(5), &sb, &no_feats(3), 0).unwrap();

        let mut s2 = SampleState::new(3);
        let mut rng2 = Rng::new(5);
        let mut sb2 = SbSelector::new(1.0, 16);
        let mut f2 = no_feats(3);
        assert_eq!(load(&dir, 9, &mut s2, &mut rng2, &mut sb2, &mut f2).unwrap(), Some(0));
        let (h1, c1) = sb.export_history();
        let (h2, c2) = sb2.export_history();
        assert_eq!(c1, c2);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(h1), bits(h2));
        let mut ra = Rng::new(17);
        let mut rb = Rng::new(17);
        for i in 0..100 {
            let loss = (i % 11) as f32;
            assert_eq!(sb.accept(loss, &mut ra), sb2.accept(loss, &mut rb), "step {i}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Trainer-state manifests written before SB persistence have no
    /// `sb_cursor`; they still restore everything else and leave the
    /// selector untouched.
    #[test]
    fn legacy_manifest_without_sb_cursor_loads() {
        let dir = tmp("sb_legacy");
        let mut s = SampleState::new(4);
        s.set_hidden(&[2]);
        let mut warm = SbSelector::new(1.0, 8);
        warm.record(3.0);
        save(&dir, 2, &s, &Rng::new(4), &warm, &no_feats(4), 6).unwrap();
        // rewrite the manifest as the pre-SB format: drop sb_cursor
        let path = dir.join(STATE_FILE);
        let m = parse_file(&path).unwrap();
        let legacy = crate::jobj![
            ("n", m.req("n").unwrap().as_usize().unwrap()),
            ("epoch", 2usize),
            ("schedule_offset", 6usize),
            ("rng", m.req("rng").unwrap().clone()),
        ];
        write_atomic(&path, &legacy.to_pretty()).unwrap();

        let mut s2 = SampleState::new(4);
        let mut rng2 = Rng::new(0);
        let mut sb2 = SbSelector::new(1.0, 8);
        let mut f2 = no_feats(4);
        assert_eq!(load(&dir, 2, &mut s2, &mut rng2, &mut sb2, &mut f2).unwrap(), Some(6));
        assert_eq!(s2.hidden_count(), 1);
        // selector untouched: still empty
        assert!(sb2.export_history().0.is_empty());
        // a legacy manifest leaves the cache cold, not half-restored
        assert!(!f2.ready());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A committed feature cache rides the roundtrip bit-exactly: rows,
    /// dim, and the harvest-epoch stamp all survive, and a cold cache at
    /// save time stays cold at load time.
    #[test]
    fn pfb_feature_cache_roundtrips_bitwise() {
        let dir = tmp("pfb");
        let s = SampleState::new(3);
        let mut warm = FeatureCache::new(3);
        warm.begin(2).unwrap();
        warm.store_row(0, &[0.125, -3.5]).unwrap();
        warm.store_row(1, &[1.0e-7, 42.0]).unwrap();
        warm.store_row(2, &[-0.0, 7.25]).unwrap();
        warm.commit(4);
        save(&dir, 6, &s, &Rng::new(8), &SbSelector::new(1.0, 8), &warm, 0).unwrap();

        let mut s2 = SampleState::new(3);
        let mut rng2 = Rng::new(0);
        let mut sb2 = SbSelector::new(1.0, 8);
        // pre-seed the restored cache with junk: import must replace it
        let mut f2 = FeatureCache::new(3);
        f2.begin(5).unwrap();
        f2.commit(1);
        assert_eq!(load(&dir, 6, &mut s2, &mut rng2, &mut sb2, &mut f2).unwrap(), Some(0));
        assert!(f2.ready());
        assert_eq!(f2.dim(), 2);
        assert_eq!(f2.harvest_epoch(), Some(4));
        let bits = |f: &FeatureCache| -> Vec<u32> {
            (0..3).flat_map(|i| f.row(i).iter().map(|v| v.to_bits())).collect::<Vec<_>>()
        };
        assert_eq!(bits(&warm), bits(&f2));

        // a save with a cold cache invalidates any stale restored cache
        save(&dir, 7, &s, &Rng::new(8), &SbSelector::new(1.0, 8), &no_feats(3), 0).unwrap();
        assert_eq!(load(&dir, 7, &mut s2, &mut rng2, &mut sb2, &mut f2).unwrap(), Some(0));
        assert!(!f2.ready());
        std::fs::remove_dir_all(&dir).ok();
    }
}
