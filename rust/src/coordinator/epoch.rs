//! The staged epoch pipeline: one epoch decomposed into named phases.
//!
//! `Trainer::run_epoch` used to be a monolithic serial function; the
//! pipeline makes the stages explicit —
//!
//! ```text
//!   Plan -> Train -> Refresh -> Eval -> Checkpoint -> Metrics
//! ```
//!
//! — times each one, and owns the epoch's typed snapshot cache
//! ([`crate::engine::Snapshot`]): each async phase requests the
//! [`crate::engine::SnapshotTier`] it needs — `Eval` the cheap
//! params-only tier, `Checkpoint` the full tier — and the cache exports
//! **exactly once per epoch**, at the highest tier any phase of the
//! epoch will ask for, so an epoch that both evals and checkpoints
//! shares one full export while an eval-only epoch pays only the halved
//! params export (see docs/snapshots.md).  The trainer shrinks to
//! orchestration: it loops epochs, delegates each one here, and folds
//! async service-lane results back into records.
//!
//! # The async lanes
//!
//! With `cfg.service_lane` on, `Eval` and `Checkpoint` do not execute on
//! the critical path at all: each exports (or reuses) the epoch's exact
//! snapshot and enqueues the job on the engine's split
//! [`crate::engine::ServiceLanes`] — evals on the eval lane's replica,
//! checkpoint serialization on the independent checkpoint lane — while
//! the primary executor trains the next epoch.  Results fold back into
//! the epoch's record at the next barrier — after each `Trainer::run`
//! loop iteration, and a final blocking drain before the run returns —
//! merged in `(epoch, eval-before-checkpoint)` order and keyed by epoch,
//! so fold-in is deterministic whichever lane finishes first.  Because
//! the eval lane evaluates an exact snapshot with the identical
//! accumulation order, async eval is bitwise identical to sync eval
//! (`tests/service_lane_determinism.rs`).

use std::sync::Arc;

use crate::config::{DpMode, StrategyConfig};
use crate::coordinator::trainer::Trainer;
use crate::data::shard::shard_order_aligned;
use crate::engine::{
    execute_plan, execute_sharded_average, execute_sharded_plain, SharedSnapshot, SnapshotTier,
    StateExchange,
};
use crate::metrics::EpochRecord;
use crate::strategies::{BatchMode, EpochPlan, PlanCtx};
use crate::util::stats::Histogram;
use crate::util::timer::Timer;

/// The named stages one epoch passes through, in execution order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Strategy selection: hide / move-back / weights / LR scaling.
    Plan,
    /// The training pass (engine or worker pool).
    Train,
    /// Hidden-list stat refresh (paper step D.1).
    Refresh,
    /// Validation eval — sync, or snapshot + submit when the service lane
    /// is on.
    Eval,
    /// Checkpoint serialization — sync, or snapshot + submit when the
    /// service lane is on (trainer-side resume state is always written
    /// synchronously; it is small and must match the epoch boundary).
    Checkpoint,
    /// Detailed metrics + cost-model projection roll-up.
    Metrics,
}

impl Phase {
    /// Display name (logs, phase tables).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Plan => "plan",
            Phase::Train => "train",
            Phase::Refresh => "refresh",
            Phase::Eval => "eval",
            Phase::Checkpoint => "checkpoint",
            Phase::Metrics => "metrics",
        }
    }
}

/// One epoch's per-phase wall-clock accounting, in execution order.
/// The canonical per-phase numbers live in `EpochRecord`'s `time_*`
/// fields (mirrored by [`EpochPipeline`]'s phase closer); this ledger
/// only feeds the debug-level phase table.
#[derive(Clone, Debug, Default)]
pub(crate) struct PhaseTimings {
    spans: Vec<(Phase, f64)>,
}

impl PhaseTimings {
    fn push(&mut self, phase: Phase, secs: f64) {
        self.spans.push((phase, secs));
    }

    fn render(&self) -> String {
        self.spans
            .iter()
            .map(|(p, s)| format!("{} {:.4}s", p.name(), s))
            .collect::<Vec<_>>()
            .join("  ")
    }
}

/// Drives one epoch through the staged pipeline (see the module docs).
pub struct EpochPipeline {
    epoch: usize,
    /// The epoch's exported typed snapshot, shared by the Eval and
    /// Checkpoint phases so two async jobs cost one export.
    snapshot: Option<SharedSnapshot>,
    /// Whether any phase of this epoch will need the full tier (an async
    /// checkpoint is due) — decided up front so the first `snapshot()`
    /// call exports at the right tier and later phases reuse it.
    full_needed: bool,
    timings: PhaseTimings,
}

impl EpochPipeline {
    /// Run epoch `epoch` of `trainer` through every phase; returns the
    /// epoch's record (val fields pending when the service lane is on —
    /// the trainer folds them in at the next barrier).
    pub fn run(trainer: &mut Trainer, epoch: usize) -> anyhow::Result<EpochRecord> {
        let full_needed = trainer.cfg.service_lane
            && trainer.cfg.checkpoint_dir.is_some()
            && Self::checkpoint_due(trainer, epoch);
        let mut pipe = EpochPipeline {
            epoch,
            snapshot: None,
            full_needed,
            timings: PhaseTimings::default(),
        };
        let mut rec = EpochRecord { epoch, val_acc: f64::NAN, ..Default::default() };

        let t = Timer::start();
        let plan = pipe.plan(trainer, &mut rec)?;
        pipe.close(Phase::Plan, t, &mut rec);

        let t = Timer::start();
        pipe.train(trainer, &plan, &mut rec)?;
        pipe.close(Phase::Train, t, &mut rec);

        let t = Timer::start();
        let refreshed = pipe.refresh(trainer, &plan, &mut rec)?;
        pipe.close(Phase::Refresh, t, &mut rec);

        let t = Timer::start();
        pipe.eval(trainer, &mut rec)?;
        pipe.close(Phase::Eval, t, &mut rec);

        let t = Timer::start();
        pipe.checkpoint(trainer, &mut rec)?;
        pipe.close(Phase::Checkpoint, t, &mut rec);

        let t = Timer::start();
        pipe.metrics(trainer, refreshed, &mut rec)?;
        pipe.close(Phase::Metrics, t, &mut rec);

        if crate::util::logging::enabled(crate::util::logging::Level::Debug) {
            crate::debug!("epoch {epoch} phases: {}", pipe.timings.render());
        }
        Ok(rec)
    }

    /// Close a phase: record its span and mirror it into the epoch
    /// record's per-component timing fields.
    fn close(&mut self, phase: Phase, t: Timer, rec: &mut EpochRecord) {
        let secs = t.elapsed_s();
        self.timings.push(phase, secs);
        match phase {
            Phase::Plan => rec.time_select = secs,
            Phase::Train => rec.time_train = secs,
            Phase::Refresh => rec.time_refresh = secs,
            Phase::Eval => rec.time_eval = secs,
            Phase::Checkpoint => rec.time_checkpoint = secs,
            Phase::Metrics => {}
        }
    }

    /// Whether the Eval phase fires this epoch.
    fn eval_due(t: &Trainer, epoch: usize) -> bool {
        epoch % t.cfg.eval_every.max(1) == 0 || epoch + 1 == t.cfg.epochs
    }

    /// Whether the Checkpoint phase fires this epoch.
    fn checkpoint_due(t: &Trainer, epoch: usize) -> bool {
        t.cfg.checkpoint_every > 0
            && (epoch % t.cfg.checkpoint_every == 0 || epoch + 1 == t.cfg.epochs)
    }

    /// The epoch's exported typed snapshot, exported **at most once per
    /// epoch**: the first caller triggers the export — at `Full` when an
    /// async checkpoint is also due this epoch, else at the tier it asked
    /// for — and every later caller whose tier the cached snapshot
    /// satisfies shares the same `Arc`.
    fn snapshot(
        &mut self,
        t: &Trainer,
        tier: SnapshotTier,
    ) -> anyhow::Result<SharedSnapshot> {
        if let Some(s) = &self.snapshot {
            if s.tier() >= tier {
                return Ok(s.clone());
            }
        }
        let want = if self.full_needed { SnapshotTier::Full } else { tier };
        let snap: SharedSnapshot = Arc::new(t.exec.export_snapshot(want)?);
        self.snapshot = Some(snap.clone());
        Ok(snap)
    }

    // --- Plan: strategy selection + LR -----------------------------------
    fn plan(&mut self, t: &mut Trainer, rec: &mut EpochRecord) -> anyhow::Result<EpochPlan> {
        let epoch = self.epoch;
        rec.feature_cache_age = t.feat_cache.age(epoch as u32);
        let plan = {
            let mut ctx = PlanCtx {
                epoch,
                total_epochs: t.cfg.epochs,
                data: &t.data.train,
                state: &mut t.state,
                rng: &mut t.rng,
                exec: Some(&mut t.exec),
                features: Some(&t.feat_cache),
            };
            t.strategy.plan_epoch(&mut ctx)?
        };
        if plan.reset_params {
            t.exec.reset_params(t.cfg.seed)?;
            t.schedule_offset = epoch;
            // cached features came from the discarded parameters; the
            // strategy's refresh cadence re-harvests from the new ones
            t.feat_cache.invalidate();
        }
        rec.base_lr = t.cfg.lr.at(epoch - t.schedule_offset);
        rec.lr = rec.base_lr * plan.lr_scale;
        rec.fraction_ceiling = t.strategy.fraction_ceiling(epoch);
        rec.max_hidden = plan.max_hidden;
        rec.hidden = plan.hidden.len();
        rec.moved_back = plan.moved_back;
        rec.pruned_pre_forward = plan.pruned_pre_forward;
        Ok(plan)
    }

    // --- Train: through the step engine / worker pool ---------------------
    // Data-parallel execution: shard the epoch batch-aligned across the
    // worker pool (weighted plans skip this — they are W=1 per paper; SB
    // consumes its candidate stream unsharded).  `--dp` picks the pool
    // schedule: the bitwise serial-equivalent default, or true
    // parameter-averaging synchronous SGD on per-worker replicas.
    fn train(
        &mut self,
        t: &mut Trainer,
        plan: &EpochPlan,
        rec: &mut EpochRecord,
    ) -> anyhow::Result<()> {
        let epoch = self.epoch;
        let outcome = match plan.batch_mode {
            BatchMode::Plain if t.cfg.workers > 1 && plan.weights.is_none() => {
                let shards =
                    shard_order_aligned(&plan.order, t.cfg.workers, t.engine.batch());
                let (outcome, pout) = match t.cfg.dp {
                    DpMode::SerialEquivalent => execute_sharded_plain(
                        &mut t.pool,
                        &mut t.exec,
                        &t.data.train,
                        &shards,
                        rec.lr as f32,
                        epoch as u32,
                        &mut t.state,
                    )?,
                    DpMode::Average => execute_sharded_average(
                        &mut t.pool,
                        &mut t.exec,
                        &t.data.train,
                        &shards,
                        rec.lr as f32,
                        epoch as u32,
                        &mut t.state,
                    )?,
                };
                rec.worker_samples = pout.workers.iter().map(|w| w.samples).collect();
                rec.time_barrier += pout.workers.iter().map(|w| w.wait_s).sum::<f64>();
                rec.dp_syncs = pout.sync_steps;
                rec.time_average = pout.time_average;
                rec.lanes_dropped = pout.dropped_lanes;
                rec.lanes_rejoined = pout.rejoined_lanes;
                rec.time_reissue = pout.time_reissue;
                rec.modeled_sync = t.cost.sync_overhead(pout.sync_steps, t.cfg.workers);
                outcome
            }
            _ => execute_plan(
                &mut t.engine,
                &mut t.exec,
                &t.data.train,
                &plan.order,
                plan.weights.as_deref(),
                plan.batch_mode,
                rec.lr as f32,
                epoch as u32,
                &mut t.state,
                &mut t.sb,
                &mut t.rng,
                &mut t.sb_queue,
            )?,
        };
        rec.trained_samples = outcome.trained_samples;
        rec.backprop_samples = outcome.backprop_samples;
        rec.train_loss = outcome.train_loss;
        Ok(())
    }

    // --- Refresh: hidden-list stat refresh (paper step D.1) ---------------
    fn refresh(
        &mut self,
        t: &mut Trainer,
        plan: &EpochPlan,
        rec: &mut EpochRecord,
    ) -> anyhow::Result<usize> {
        let mut refreshed = 0usize;
        if t.strategy.refresh_hidden_stats() && !plan.hidden.is_empty() {
            refreshed = plan.hidden.len();
            // the refresh pass's gather stall gets its own bucket — it is
            // not train-barrier time
            rec.time_refresh_stall += t.refresh_stats(&plan.hidden, self.epoch as u32)?;
        }
        // Feature-cache harvest cadence (PFB): re-harvest when the cache
        // is cold (first epoch, post-restart, legacy resume) or its rows
        // have aged `refresh_every` epochs.  The `fwd_embed` sweep fills
        // the cache with post-training-pass embeddings *and* refreshes
        // every sample's lagging stats in the same pass; the N-1 plans in
        // between score from the cache with zero extra device forwards.
        if let Some(every) = t.strategy.feature_refresh_every() {
            let epoch = self.epoch as u32;
            let due = !t.feat_cache.ready() || t.feat_cache.age(epoch) >= every;
            if due {
                let th = Timer::start();
                rec.time_refresh_stall += t.harvest_features(epoch)?;
                rec.time_feature_refresh = th.elapsed_s();
                refreshed = t.data.train.n;
            }
        }
        rec.hidden_again = t.state.hidden_again_count();
        Ok(refreshed)
    }

    // --- Eval: sync forward pass, or snapshot + async submit --------------
    fn eval(&mut self, t: &mut Trainer, rec: &mut EpochRecord) -> anyhow::Result<()> {
        let epoch = self.epoch;
        if !Self::eval_due(t, epoch) {
            return Ok(());
        }
        if t.cfg.service_lane {
            // the eval lane reads only parameters, so an eval-only epoch
            // exports the halved params tier; when a checkpoint is also
            // due this epoch the cache hands back the shared full export
            let snap = self.snapshot(t, SnapshotTier::Params)?;
            t.ensure_service()?;
            let lanes = t.service.as_mut().expect("ensure_service populated the lanes");
            lanes.submit_eval(epoch, snap)?;
            // rec.val_acc stays NaN-pending; the trainer folds the lane's
            // result in at the next barrier (bitwise identical to the
            // sync value below)
        } else {
            let (acc, loss) = t.evaluate()?;
            rec.val_acc = acc;
            rec.val_loss = loss;
        }
        Ok(())
    }

    // --- Checkpoint: sync serialization, or snapshot + async submit -------
    fn checkpoint(&mut self, t: &mut Trainer, rec: &mut EpochRecord) -> anyhow::Result<()> {
        let epoch = self.epoch;
        if !Self::checkpoint_due(t, epoch) {
            return Ok(());
        }
        let Some(dir) = t.cfg.checkpoint_dir.clone() else { return Ok(()) };
        if t.cfg.service_lane {
            // a resumable checkpoint needs the optimizer trajectory: the
            // full tier, shared with this epoch's eval when one was due
            let snap = self.snapshot(t, SnapshotTier::Full)?;
            t.ensure_service()?;
            let lanes = t.service.as_mut().expect("ensure_service populated the lanes");
            lanes.submit_checkpoint(epoch, snap)?;
            // write-pool stats fold in at the next barrier with the event
        } else {
            // the sync path shares one persistent write pool across the
            // run (created at the first checkpoint; pool size 1 stays a
            // plain inline serial writer)
            if t.ckpt_pool.is_none() {
                t.ckpt_pool =
                    Some(crate::util::artifact::WritePool::new(t.cfg.checkpoint_pool));
            }
            let snap = self.snapshot(t, SnapshotTier::Full)?;
            let pool = t.ckpt_pool.as_ref().expect("pool initialized above");
            let stats = crate::runtime::checkpoint::save_snapshot(
                &t.exec.meta,
                &snap,
                &dir,
                epoch,
                pool,
                t.cfg.checkpoint_compress,
            )?;
            rec.fold_ckpt_stats(&stats);
        }
        // The coordinator-side resume state (per-sample stats, RNG stream,
        // SB selector history, schedule offset) is small, host-only, and
        // must match this exact epoch boundary — always written
        // synchronously, stamped with the epoch so resume can detect a
        // crash-torn directory.
        super::resume::save(
            &dir,
            epoch,
            &t.state,
            &t.rng,
            &t.sb,
            &t.feat_cache,
            t.schedule_offset,
        )?;
        Ok(())
    }

    // --- Metrics: detailed diagnostics + cost-model projection ------------
    fn metrics(
        &mut self,
        t: &mut Trainer,
        refreshed: usize,
        rec: &mut EpochRecord,
    ) -> anyhow::Result<()> {
        // Publish this epoch's params snapshot to the inference lane's
        // hub.  The publication rides the epoch's snapshot cache: an
        // epoch that already exported for async eval or a checkpoint
        // shares that Arc, so serving adds at most one params export per
        // epoch and never forces the full tier.  The swap itself is one
        // atomic pointer store — in-flight queries keep the snapshot they
        // started with.
        if t.cfg.serve.is_some() {
            let tp = Timer::start();
            t.ensure_serve()?;
            let snap = self.snapshot(t, SnapshotTier::Params)?;
            let serve = t.serve.as_ref().expect("ensure_serve populated the lane");
            serve.hub.publish(self.epoch, snap);
            rec.serve_publishes += 1;
            rec.time_publish = tp.elapsed_s();
        }
        if t.cfg.detailed_metrics {
            rec.hidden_per_class = t.state.hidden_per_class(&t.data.train);
            let finite: Vec<f32> =
                t.state.loss.iter().copied().filter(|l| l.is_finite()).collect();
            if !finite.is_empty() {
                let hi = crate::util::stats::percentile(&finite, 99.5).max(0.1);
                rec.loss_hist = Some(Histogram::of(&finite, 0.0, hi, 40));
            }
        }

        // Training time excludes eval (the paper's epoch timing measures
        // the training pipeline; top-1 curves are checkpoint evals).
        rec.time_total = rec.time_select + rec.time_train + rec.time_refresh;

        let select_n = match &t.cfg.strategy {
            StrategyConfig::Baseline => 0,
            _ => t.data.train.n,
        };
        rec.modeled_time = t.cost.epoch_time(
            rec.backprop_samples,
            refreshed + rec.trained_samples.saturating_sub(rec.backprop_samples),
            select_n,
            t.cfg.workers,
        );
        Ok(())
    }
}
