//! KAKURENBO: Adaptively Hiding Samples in Deep Neural Network Training
//! (NeurIPS 2023) — full-system reproduction.
//!
//! Three-layer architecture (see README.md + docs/worker-model.md):
//!   * L3 (this crate): training coordinator + step-execution engine —
//!     the coordinator plans epochs (selection, schedules, sharding); the
//!     `engine` module owns the pipelined per-step hot path (double-
//!     buffered gather overlapped with device execution) and the
//!     data-parallel worker pool (N gather lanes behind a deterministic
//!     bulk-synchronous reduction); plus per-sample state, baselines,
//!     metrics, bench harness.
//!   * L2/L1 (python/, build time only): JAX models + Pallas kernels,
//!     AOT-lowered to `artifacts/*.hlo.txt`.
//!   * runtime: PJRT CPU client executing the AOT artifacts — Python is
//!     never on the training path.

// Crate-wide documentation gate: every public item in every module must
// carry rustdoc (CI builds docs with `-D warnings -D missing-docs`).
#![warn(missing_docs)]

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod hiding;
pub mod metrics;
pub mod runtime;
pub mod report;
pub mod sampler;
pub mod schedule;
pub mod serve;
pub mod state;
pub mod strategies;
pub mod util;
