//! Serving throughput sweep (criterion-free harness): concurrency ×
//! coalescing batch × replica count, over a mock backend with an
//! enforced per-dispatch latency floor that stands in for the PJRT
//! device-call overhead batching amortizes.
//!
//! Reports QPS, p50/p99 query latency and mean batch fill per
//! configuration, plus the headline speedup of coalescing + 2 replicas
//! over per-query single-lane serving at the same concurrency, and
//! records everything in results/serving.json (BENCH_serving.json in
//! the CI perf-trajectory artifact).
//!
//! The sweep is PJRT-free on purpose: the serving fleet's batching and
//! routing are host-side, and the floor makes the device economics
//! explicit — so this bench runs anywhere, artifacts or not.

use std::sync::Arc;
use std::time::{Duration, Instant};

use kakurenbo::engine::testbed::MockBackend;
use kakurenbo::engine::{
    ReplicaBackend, ReplicaBuilder, ServeBatching, ServeFleet, Snapshot, SnapshotHub,
    StateExchange, StepBackend,
};
use kakurenbo::runtime::{BatchStats, EmbedStats};
use kakurenbo::util::json::Json;
use kakurenbo::util::rng::Rng;
use kakurenbo::util::table::Table;

/// A mock backend whose every device call costs at least `floor` —
/// the stand-in for the fixed PJRT dispatch + transfer overhead that
/// makes coalescing profitable on real hardware.  Row semantics are
/// exactly `MockBackend`'s, so batched answers stay bitwise checkable.
struct FloorBackend {
    inner: MockBackend,
    floor: Duration,
}

impl FloorBackend {
    fn spin(&self) {
        let t = Instant::now();
        while t.elapsed() < self.floor {
            std::hint::spin_loop();
        }
    }

    /// A `Send` constructor for a fresh floored replica.
    fn builder(floor: Duration) -> ReplicaBuilder {
        Box::new(move || {
            Ok(Box::new(FloorBackend { inner: MockBackend::new(), floor })
                as Box<dyn ReplicaBackend>)
        })
    }
}

impl StepBackend for FloorBackend {
    fn train_step(
        &mut self,
        x: &[f32],
        y: &[i32],
        sw: &[f32],
        lr: f32,
    ) -> anyhow::Result<BatchStats> {
        self.spin();
        self.inner.train_step(x, y, sw, lr)
    }

    fn fwd_stats(&mut self, x: &[f32], y: &[i32]) -> anyhow::Result<BatchStats> {
        self.spin();
        self.inner.fwd_stats(x, y)
    }

    fn fwd_embed(&mut self, x: &[f32], y: &[i32]) -> anyhow::Result<EmbedStats> {
        self.spin();
        self.inner.fwd_embed(x, y)
    }
}

impl StateExchange for FloorBackend {
    fn export_state(&self) -> anyhow::Result<Vec<Vec<f32>>> {
        self.inner.export_state()
    }

    fn import_state(&mut self, state: &[Vec<f32>]) -> anyhow::Result<()> {
        self.inner.import_state(state)
    }
}

struct SweepResult {
    qps: f64,
    p50_us: f64,
    p99_us: f64,
    fill: f64,
    queries: usize,
    batches: usize,
}

/// Hammer one fleet configuration with `concurrency` closed-loop
/// clients issuing `n_queries` single-sample stats queries in total.
fn run_config(
    replicas: usize,
    max_batch: usize,
    concurrency: usize,
    n_queries: usize,
    floor: Duration,
) -> anyhow::Result<SweepResult> {
    const DIM: usize = 16;
    let hub = Arc::new(SnapshotHub::new());
    let builders = (0..replicas).map(|_| FloorBackend::builder(floor)).collect();
    let batching = ServeBatching { max_batch, max_wait: Duration::from_micros(200) };
    let fleet = ServeFleet::spawn(builders, hub.clone(), batching)?;
    hub.publish(0, Arc::new(Snapshot::params_only(vec![vec![0.5]])));
    let published = hub.latest().unwrap();

    let wall = Instant::now();
    let threads: Vec<_> = (0..concurrency)
        .map(|c| {
            let client = fleet.client();
            let published = published.clone();
            let mine = n_queries / concurrency + usize::from(c < n_queries % concurrency);
            std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
                let mut rng = Rng::new(c as u64 + 1);
                let mut lat = Vec::with_capacity(mine);
                for _ in 0..mine {
                    let x: Vec<f32> = (0..DIM).map(|_| rng.f32()).collect();
                    let y = vec![rng.below(DIM) as i32];
                    let t = Instant::now();
                    client.query(published.clone(), x, y, false)?;
                    lat.push(t.elapsed().as_secs_f64() * 1e6);
                }
                Ok(lat)
            })
        })
        .collect();
    let mut lat: Vec<f64> = Vec::with_capacity(n_queries);
    for t in threads {
        lat.extend(t.join().unwrap()?);
    }
    let secs = wall.elapsed().as_secs_f64();
    drop(fleet);

    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |q: f64| lat[((lat.len() - 1) as f64 * q) as usize];
    let queries = hub.queries_total();
    let batches = hub.batches_total();
    Ok(SweepResult {
        qps: n_queries as f64 / secs,
        p50_us: pick(0.50),
        p99_us: pick(0.99),
        fill: queries as f64 / batches.max(1) as f64,
        queries,
        batches,
    })
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("KAKURENBO_QUICK").is_ok();
    println!("=== serving throughput sweep{} ===", if quick { " (quick)" } else { "" });
    let floor = Duration::from_micros(if quick { 150 } else { 300 });
    let n_queries = if quick { 192 } else { 512 };

    // (replicas, batch, concurrency); (1,1,8) is the per-query
    // single-lane baseline the headline speedup is measured against
    let configs = [(1usize, 1usize, 1usize), (1, 1, 8), (1, 8, 8), (2, 1, 8), (2, 8, 8)];
    let mut t = Table::new(format!(
        "serving sweep (floor {}µs, {n_queries} queries)",
        floor.as_micros()
    ))
    .header(&["replicas", "batch", "clients", "QPS", "p50 µs", "p99 µs", "fill"]);
    let mut rows = Vec::new();
    let mut by_config = std::collections::HashMap::new();
    for &(replicas, batch, clients) in &configs {
        let r = run_config(replicas, batch, clients, n_queries, floor)?;
        t.row(vec![
            replicas.to_string(),
            batch.to_string(),
            clients.to_string(),
            format!("{:.0}", r.qps),
            format!("{:.0}", r.p50_us),
            format!("{:.0}", r.p99_us),
            format!("{:.2}", r.fill),
        ]);
        rows.push(kakurenbo::jobj![
            ("replicas", replicas),
            ("batch", batch),
            ("concurrency", clients),
            ("qps", r.qps),
            ("p50_us", r.p50_us),
            ("p99_us", r.p99_us),
            ("fill", r.fill),
            ("queries", r.queries),
            ("batches", r.batches)
        ]);
        by_config.insert((replicas, batch, clients), r.qps);
    }
    t.print();

    let speedup = by_config[&(2, 8, 8)] / by_config[&(1, 1, 8)];
    println!("  batching + 2 replicas vs per-query single lane (8 clients): {speedup:.2}x");
    let payload = kakurenbo::jobj![
        ("quick", quick),
        ("floor_us", floor.as_micros() as usize),
        ("n_queries", n_queries),
        ("speedup_batched_vs_per_query", speedup),
        ("rows", Json::Arr(rows))
    ];
    let out = std::path::Path::new("results");
    std::fs::create_dir_all(out)?;
    let path = out.join("serving.json");
    std::fs::write(&path, payload.to_pretty())?;
    println!("[saved {}]", path.display());
    Ok(())
}
