//! Table 11: global batch-size scaling.  The paper fixes the per-GPU
//! minibatch at 32 and scales workers 32->256 (global batch 1024->8192);
//! we scale the artifact batch 64->256 with 2->8 virtual workers.
//!
//! Paper shape: baseline accuracy roughly flat; KAKURENBO-0.4 degrades
//! mildly as global batch grows (73.60 -> 72.84) but stays usable.

use kakurenbo::config::{presets, StrategyConfig};
use kakurenbo::coordinator::run_experiment;
use kakurenbo::report::BenchCtx;
use kakurenbo::util::table::{pct, Table};

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Table 11: batch-size scaling (virtual workers)")?;
    let mut base = presets::by_name("imagenet_resnet50")?;
    ctx.scale_config(&mut base);

    let grid = [("cnn_c32_b64", 2usize), ("cnn_c32_b128", 4), ("cnn_c32_b256", 8)];
    let mut t = Table::new("Table 11 — global batch scaling (ImageNet proxy)").header(&[
        "Batch", "Workers", "Baseline acc", "KAKURENBO-0.4 acc", "Diff",
    ]);
    let mut payload = Vec::new();
    for (variant, workers) in grid {
        let batch: usize = variant.rsplit('b').next().unwrap().parse()?;
        let mut b_cfg = base.clone();
        b_cfg.variant = variant.into();
        b_cfg.workers = workers;
        // keep the linear-scaling rule: lr ∝ global batch (Goyal et al.)
        b_cfg.lr.base_lr = base.lr.base_lr * batch as f64 / 64.0;
        b_cfg.strategy = StrategyConfig::Baseline;
        b_cfg.name = format!("bs{batch}/baseline");
        let rb = run_experiment(&ctx.rt, b_cfg.clone())?;

        let mut k_cfg = b_cfg.clone();
        k_cfg.strategy = StrategyConfig::kakurenbo(0.4);
        k_cfg.name = format!("bs{batch}/kakurenbo");
        let rk = run_experiment(&ctx.rt, k_cfg)?;
        println!("  batch {batch} x{workers}w: base {:.4} kakur {:.4}", rb.best_acc, rk.best_acc);
        t.row(vec![
            batch.to_string(),
            workers.to_string(),
            pct(rb.best_acc),
            pct(rk.best_acc),
            format!("{:+.2}", (rk.best_acc - rb.best_acc) * 100.0),
        ]);
        payload.push(kakurenbo::jobj![
            ("batch", batch),
            ("workers", workers),
            ("baseline_acc", rb.best_acc),
            ("kakurenbo_acc", rk.best_acc),
            ("baseline_modeled_s", rb.total_modeled_time),
            ("kakurenbo_modeled_s", rk.total_modeled_time),
        ]);
    }
    t.print();
    ctx.save_json("table11_batchsize", &kakurenbo::util::json::Json::Arr(payload))?;
    Ok(())
}
