//! Table 2 + Figure 2: max top-1 accuracy and convergence/speedup of
//! {Baseline, ISWR, FORGET, SB, KAKURENBO} across the four workloads
//! (CIFAR-100/WRN, ImageNet/ResNet-50, ImageNet/EfficientNet, DeepCAM).
//!
//! Paper shape being reproduced:
//!   * KAKURENBO within ~0.3-0.9% of baseline accuracy, with a measured
//!     wall-clock reduction tracking the hiding fraction;
//!   * ISWR offers no wall-clock win despite converging in fewer epochs;
//!   * SB degrades accuracy notably on the large (proxy-ImageNet) tasks;
//!   * FORGET pays a pruning prologue and loses accuracy.
//!
//! Output: printed table per workload + results/table2_<workload>.json and
//! results/fig2_<workload>.json (convergence series).

use kakurenbo::config::presets;
use kakurenbo::report::{comparison_table, convergence_json, paper_strategies, BenchCtx};

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Table 2 / Fig 2: accuracy & convergence, all workloads")?;

    // (preset, kakurenbo max fraction F): CIFAR uses F=0.1 (paper: small
    // datasets only tolerate small fractions), the rest use F=0.3.
    let workloads = [
        ("cifar100_wrn", 0.1),
        ("imagenet_resnet50", 0.3),
        ("imagenet_efficientnet", 0.3),
        ("deepcam", 0.3),
    ];

    for (preset, fraction) in workloads {
        let mut cfg = presets::by_name(preset)?;
        ctx.scale_config(&mut cfg);
        let prune_epoch = (cfg.epochs / 5).max(2); // paper: 20 of ~100
        let strategies = paper_strategies(fraction, prune_epoch);
        let runs = comparison_table(
            &ctx,
            &format!("Table 2 — {preset} (F={fraction})"),
            &cfg,
            &strategies,
        )?;
        ctx.save_runs(&format!("table2_{preset}"), &runs)?;
        ctx.save_json(&format!("fig2_{preset}"), &convergence_json(&runs))?;

        // Fig. 2's speedup metric: time to reach 98% of baseline best acc.
        let target = runs[0].best_acc * 0.98;
        println!("  time-to-accuracy (target {:.4}):", target);
        for r in &runs {
            match r.time_to_accuracy(target) {
                Some(t) => println!("    {:<12} {:>7.1}s", r.strategy, t),
                None => println!("    {:<12}  never", r.strategy),
            }
        }
    }
    Ok(())
}
