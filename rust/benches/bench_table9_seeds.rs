//! Table 9: seed robustness — Baseline / KAKURENBO / Random-hiding across
//! 3 random seeds (mean ± std), CIFAR-100 proxy.
//!
//! Paper shape: KAKURENBO's mean within ~0.3% of baseline with comparable
//! std; Random hiding lands clearly below both.

use kakurenbo::config::{presets, StrategyConfig};
use kakurenbo::coordinator::run_experiment;
use kakurenbo::report::BenchCtx;
use kakurenbo::util::table::Table;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Table 9: robustness across random seeds")?;
    let mut base = presets::by_name("cifar100_wrn")?;
    ctx.scale_config(&mut base);
    let seeds: &[u64] = if ctx.quick { &[1, 2] } else { &[1, 2, 3] };

    let strategies = [
        ("Baseline", StrategyConfig::Baseline),
        ("KAKURENBO", StrategyConfig::kakurenbo(0.1)),
        ("Random", StrategyConfig::RandomHiding { fraction: 0.1 }),
    ];

    let mut t = Table::new("Table 9 — accuracy over seeds (CIFAR-100 proxy)")
        .header(&["Setting", "Acc. mean", "± std", "runs"]);
    let mut payload = Vec::new();
    for (label, strat) in strategies {
        let mut accs = Vec::new();
        for &seed in seeds {
            let mut cfg = base.clone();
            cfg.strategy = strat.clone();
            cfg.seed = seed;
            cfg.name = format!("seeds/{label}/{seed}");
            let r = run_experiment(&ctx.rt, cfg)?;
            println!("  {label} seed {seed}: {:.4}", r.best_acc);
            accs.push(r.best_acc as f32);
        }
        let mean = kakurenbo::util::stats::mean(&accs);
        let std = kakurenbo::util::stats::std_dev(&accs);
        t.row(vec![
            label.to_string(),
            format!("{:.2}", mean * 100.0),
            format!("{:.2}", std * 100.0),
            format!("{}", accs.len()),
        ]);
        payload.push(kakurenbo::jobj![
            ("strategy", label),
            ("mean", mean),
            ("std", std),
            ("accs", accs.iter().map(|&a| a as f64).collect::<Vec<f64>>()),
        ]);
    }
    t.print();
    ctx.save_json("table9_seeds", &kakurenbo::util::json::Json::Arr(payload))?;
    Ok(())
}
