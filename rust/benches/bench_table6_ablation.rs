//! Table 6: component ablation of KAKURENBO on the ImageNet proxy with
//! F=0.4 — HE (hide), MB (move back), RF (reduce fraction), LR (adjust LR).
//!
//! Paper shape: v1000 (HE only) loses ~1.8%; adding LR recovers most of
//! it; RF and MB each add a little; the full v1111 sits within ~0.1% of
//! the baseline.

use kakurenbo::config::{presets, Components, StrategyConfig};
use kakurenbo::coordinator::run_experiment;
use kakurenbo::hiding::selector::SelectMode;
use kakurenbo::report::BenchCtx;
use kakurenbo::util::table::{diff_pct, pct, Table};

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Table 6: HE/MB/RF/LR component ablation (F=0.4)")?;
    let mut base = presets::by_name("imagenet_resnet50")?;
    ctx.scale_config(&mut base);

    // Baseline first.
    let mut cfg = base.clone();
    cfg.strategy = StrategyConfig::Baseline;
    cfg.name = "ablation/baseline".into();
    let baseline = run_experiment(&ctx.rt, cfg)?;
    println!("  baseline acc {:.4}", baseline.best_acc);

    let variants = ["v1000", "v1001", "v1010", "v1011", "v1100", "v1101", "v1110", "v1111"];
    let mut t = Table::new("Table 6 — ablation (ImageNet proxy, F=0.4)").header(&[
        "Variant", "HE", "MB", "RF", "LR", "Accuracy", "vs baseline",
    ]);
    t.row(vec![
        "Baseline".into(), "x".into(), "x".into(), "x".into(), "x".into(),
        pct(baseline.best_acc), "-".into(),
    ]);
    let mut out = vec![baseline.clone()];
    for v in variants {
        let comps = Components::from_bits(v)?;
        let mut cfg = base.clone();
        cfg.strategy = StrategyConfig::Kakurenbo {
            max_fraction: 0.4,
            tau: 0.7,
            components: comps,
            drop_top: 0.0,
            select_mode: SelectMode::QuickSelect,
        };
        cfg.name = format!("ablation/{v}");
        let r = run_experiment(&ctx.rt, cfg)?;
        println!("  {v} acc {:.4} ({:+.2})", r.best_acc, (r.best_acc - baseline.best_acc) * 100.0);
        let mark = |b: bool| if b { "ok".to_string() } else { "x".to_string() };
        t.row(vec![
            if v == "v1111" { "KAKUR. (v1111)".into() } else { v.to_string() },
            mark(comps.hide), mark(comps.move_back), mark(comps.reduce_fraction), mark(comps.adjust_lr),
            pct(r.best_acc),
            diff_pct(r.best_acc, baseline.best_acc),
        ]);
        out.push(r);
    }
    t.print();
    ctx.save_runs("table6_ablation", &out)?;
    Ok(())
}
