//! Figure 4: evolution of the hiding fraction and the resulting per-epoch
//! speedup (EfficientNet workload).
//!
//! Paper shape: the move-back rule suppresses hiding early (model still
//! inaccurate), the effective rate approaches the F_e ceiling as
//! confidence rises, the ceiling steps down with the RF schedule, and the
//! measured per-epoch speedup tracks (but does not reach) the hiding rate
//! because of selection + refresh overhead.

use kakurenbo::config::{presets, StrategyConfig};
use kakurenbo::coordinator::run_experiment;
use kakurenbo::report::BenchCtx;
use kakurenbo::util::table::Table;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Fig 4: hiding-rate evolution + per-epoch speedup")?;
    let mut base = presets::by_name("imagenet_efficientnet")?;
    ctx.scale_config(&mut base);

    let mut b_cfg = base.clone();
    b_cfg.strategy = StrategyConfig::Baseline;
    b_cfg.name = "fig4/baseline".into();
    let rb = run_experiment(&ctx.rt, b_cfg)?;
    let base_epoch_time: f64 =
        rb.records.iter().map(|r| r.time_total).sum::<f64>() / rb.records.len() as f64;

    let mut cfg = base.clone();
    cfg.strategy = StrategyConfig::kakurenbo(0.3);
    cfg.name = "fig4/kakurenbo".into();
    let rk = run_experiment(&ctx.rt, cfg)?;

    let n = match &base.dataset {
        kakurenbo::config::DatasetConfig::ImagenetProxy(c) => c.n_train,
        _ => unreachable!(),
    };
    let mut t = Table::new("Fig 4 — per-epoch hiding rate & speedup").header(&[
        "Epoch", "F_e ceiling", "Hiding rate", "Moved back", "Speedup vs base epoch",
    ]);
    let mut series = Vec::new();
    for r in &rk.records {
        let rate = r.hidden as f64 / n as f64;
        let speedup = 1.0 - r.time_total / base_epoch_time;
        t.row(vec![
            r.epoch.to_string(),
            format!("{:.2}", r.fraction_ceiling),
            format!("{:.3}", rate),
            r.moved_back.to_string(),
            format!("{:+.1}%", speedup * 100.0),
        ]);
        series.push(kakurenbo::jobj![
            ("epoch", r.epoch),
            ("ceiling", r.fraction_ceiling),
            ("hiding_rate", rate),
            ("moved_back", r.moved_back),
            ("speedup", speedup),
        ]);
    }
    t.print();
    // paper's qualitative checks
    let early_rate = rk.records[1].hidden as f64 / n as f64;
    let late = &rk.records[rk.records.len() - 1];
    let late_rate = late.hidden as f64 / n as f64;
    println!(
        "move-back dominates early: rate(e1)={early_rate:.3} vs ceiling {:.2}; late rate {late_rate:.3} vs ceiling {:.2}",
        rk.records[1].fraction_ceiling, late.fraction_ceiling
    );
    ctx.save_json("fig4_hiding_rate", &kakurenbo::util::json::Json::Arr(series))?;
    Ok(())
}
