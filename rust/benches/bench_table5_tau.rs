//! Table 5: impact of the prediction-confidence threshold τ on
//! KAKURENBO accuracy/time (paper: τ∈{0.5,0.7,0.9} on CIFAR-100/WRN;
//! higher τ -> fewer hidden samples -> better accuracy, less speedup).

use kakurenbo::config::{presets, Components, StrategyConfig};
use kakurenbo::coordinator::run_experiment;
use kakurenbo::hiding::selector::SelectMode;
use kakurenbo::report::BenchCtx;
use kakurenbo::util::table::{pct, Table};

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Table 5: prediction-confidence threshold τ")?;
    let mut base = presets::by_name("cifar100_wrn")?;
    ctx.scale_config(&mut base);

    let mut t = Table::new("Table 5 — τ sweep (CIFAR-100 proxy, F=0.3)").header(&[
        "Setting", "Acc.", "Time (s)", "Mean hidden/epoch",
    ]);
    let mut out = Vec::new();
    for tau in [0.5f32, 0.7, 0.9] {
        let mut cfg = base.clone();
        cfg.strategy = StrategyConfig::Kakurenbo {
            max_fraction: 0.3,
            tau,
            components: Components::ALL,
            drop_top: 0.0,
            select_mode: SelectMode::QuickSelect,
        };
        cfg.name = format!("tau_{tau}");
        let r = run_experiment(&ctx.rt, cfg)?;
        let mean_hidden: f64 = r.records.iter().map(|x| x.hidden as f64).sum::<f64>()
            / r.records.len() as f64;
        println!("  tau={tau}: acc {:.4} time {:.1}s hidden/epoch {:.0}", r.best_acc, r.total_time, mean_hidden);
        t.row(vec![
            format!("tau = {tau}"),
            pct(r.best_acc),
            format!("{:.1}", r.total_time),
            format!("{mean_hidden:.0}"),
        ]);
        out.push(r);
    }
    t.print();
    ctx.save_runs("table5_tau", &out)?;
    Ok(())
}
