//! §4.2 epoch-time accounting + distributed cost-model projection.
//!
//! Reports (a) the measured per-epoch breakdown (select / train / refresh)
//! for each strategy, (b) the service lanes' removal of eval time from the
//! epoch critical path (`--service-lane on` vs `off`), (c) the snapshot
//! export tiers — the params-only tier eval-only epochs ride vs the full
//! (params + momentum) tier checkpoints need (docs/snapshots.md), (d) the
//! worker pool's measured scaling and barrier overhead at W ∈ {1, 2, 4},
//! and (e) the calibrated cost model's projection of epoch time across
//! worker counts — reproducing the paper's claims that KAKURENBO's
//! overheads are amortized at scale while single-GPU runs can lose
//! (Table 3), and that the speedup cannot reach the hiding rate because
//! of the hidden-list forward refresh (Fig. 4).

use kakurenbo::config::{presets, StrategyConfig};
use kakurenbo::coordinator::{CostModel, Trainer};
use kakurenbo::data::shard::shard_order_aligned;
use kakurenbo::data::synth::{gauss_mixture, GaussMixtureCfg};
use kakurenbo::engine::testbed::MockBackend;
use kakurenbo::engine::{
    EvalSink, SharedSnapshot, Snapshot, SnapshotTier, StateExchange, StepMode, WorkerPool,
};
use kakurenbo::report::BenchCtx;
use kakurenbo::runtime::artifact::{ParamMeta, VariantMeta};
use kakurenbo::runtime::checkpoint::save_snapshot;
use kakurenbo::util::artifact::WritePool;
use kakurenbo::util::table::Table;
use kakurenbo::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Overhead breakdown + distributed projection")?;
    let mut base = presets::by_name("imagenet_resnet50")?;
    ctx.scale_config(&mut base);

    // --- measured breakdown -------------------------------------------------
    let mut t = Table::new("Measured epoch-time breakdown (s/epoch)").header(&[
        "Strategy", "select", "train", "refresh", "total", "vs baseline",
    ]);
    let mut base_total = 0.0;
    for (label, strat) in [
        ("Baseline", StrategyConfig::Baseline),
        ("KAKURENBO", StrategyConfig::kakurenbo(0.3)),
        ("ISWR", StrategyConfig::Iswr),
        ("SB", StrategyConfig::SelectiveBackprop { beta: 1.0 }),
    ] {
        let mut cfg = base.clone();
        cfg.strategy = strat;
        cfg.name = format!("overhead/{label}");
        let r = kakurenbo::coordinator::run_experiment(&ctx.rt, cfg)?;
        let n = r.records.len() as f64;
        let sel: f64 = r.records.iter().map(|x| x.time_select).sum::<f64>() / n;
        let tr: f64 = r.records.iter().map(|x| x.time_train).sum::<f64>() / n;
        let rf: f64 = r.records.iter().map(|x| x.time_refresh).sum::<f64>() / n;
        let tot = sel + tr + rf;
        if label == "Baseline" {
            base_total = tot;
        }
        println!("  {label}: select {sel:.4} train {tr:.4} refresh {rf:.4}");
        t.row(vec![
            label.to_string(),
            format!("{sel:.4}"),
            format!("{tr:.4}"),
            format!("{rf:.4}"),
            format!("{tot:.4}"),
            format!("{:+.1}%", (tot / base_total - 1.0) * 100.0),
        ]);
    }
    t.print();

    // --- service lane: eval on vs off the epoch critical path ---------------
    // With `--service-lane on` the Eval phase's critical-path cost shrinks
    // to a snapshot export + submit; the forward passes themselves run on
    // the background replica (`time_service`) overlapped with the next
    // epoch's training.  Results are bitwise identical either way
    // (tests/service_lane_determinism.rs), so this row is pure schedule.
    let mut t = Table::new("Eval placement (KAKURENBO, s/epoch)").header(&[
        "service lane", "eval critical path", "lane async", "epoch incl. eval",
    ]);
    let mut service_payload = Vec::new();
    for on in [false, true] {
        let mut cfg = base.clone();
        cfg.strategy = StrategyConfig::kakurenbo(0.3);
        cfg.eval_every = 1;
        cfg.service_lane = on;
        cfg.name = format!("overhead/service_{}", if on { "on" } else { "off" });
        let r = kakurenbo::coordinator::run_experiment(&ctx.rt, cfg)?;
        let n = r.records.len() as f64;
        let ev: f64 = r.records.iter().map(|x| x.time_eval).sum::<f64>() / n;
        let lane: f64 = r.records.iter().map(|x| x.time_service).sum::<f64>() / n;
        // time_total deliberately excludes eval (paper epoch timing), so
        // the wall-clock column must add the eval/checkpoint phases back
        // in — that's where the two modes actually differ.
        let wall: f64 = r
            .records
            .iter()
            .map(|x| x.time_total + x.time_eval + x.time_checkpoint)
            .sum::<f64>()
            / n;
        t.row(vec![
            if on { "on" } else { "off" }.to_string(),
            format!("{ev:.4}"),
            format!("{lane:.4}"),
            format!("{wall:.4}"),
        ]);
        service_payload.push(kakurenbo::jobj![
            ("service_lane", on),
            ("eval_critical_s", ev),
            ("lane_async_s", lane),
            ("epoch_wall_s", wall),
        ]);
    }
    t.print();

    // --- snapshot export tiers: what one critical-path export costs ---------
    // The service lanes hide the eval/checkpoint *work*, but the snapshot
    // export itself stays on the critical path.  The typed tiers
    // (docs/snapshots.md) make eval-only epochs pay the params tier —
    // half the leaves, and measurably less device→host traffic, than the
    // full (params + momentum) tier a checkpoint epoch needs.
    let mut xcfg = base.clone();
    xcfg.strategy = StrategyConfig::Baseline;
    xcfg.name = "overhead/export".into();
    let xtr = Trainer::new(&ctx.rt, xcfg)?;
    let reps = 20usize;
    let mut t = Table::new("Snapshot export tier (critical-path cost per export)")
        .header(&["tier", "leaves", "elems", "time (s)", "vs full"]);
    let mut full_s = 0.0;
    let mut export_payload = Vec::new();
    for tier in [SnapshotTier::Full, SnapshotTier::Params] {
        // one warm-up export outside the timer, which also reports the
        // tier's leaf/element footprint
        let snap = xtr.exec.export_snapshot(tier)?;
        let timer = Timer::start();
        for _ in 0..reps {
            std::hint::black_box(xtr.exec.export_snapshot(tier)?);
        }
        let secs = timer.elapsed_s() / reps as f64;
        if tier == SnapshotTier::Full {
            full_s = secs;
        }
        t.row(vec![
            tier.name().to_string(),
            snap.leaves().to_string(),
            snap.elems().to_string(),
            format!("{secs:.6}"),
            if tier == SnapshotTier::Full {
                "-".into()
            } else {
                format!("{:+.1}%", (secs / full_s - 1.0) * 100.0)
            },
        ]);
        export_payload.push(kakurenbo::jobj![
            ("tier", tier.name()),
            ("leaves", snap.leaves()),
            ("elems", snap.elems()),
            ("export_s", secs),
        ]);
    }
    t.print();

    // --- checkpoint write: pooled vs serial, compressed vs raw --------------
    // The checkpoint store serializes each leaf (encode → optional LZSS →
    // sha256 → atomic write) through a write pool; this section measures a
    // Full-tier write of a synthetic variant under all four configs.  Each
    // config gets a fresh directory — the store is content-addressed, so
    // reusing one would dedup every leaf after the first config and
    // measure nothing.
    let ck_leaves = ctx.scale(24usize, 6);
    let ck_numel = ctx.scale(48_000usize, 8_000);
    let ck_meta = VariantMeta {
        name: "bench_ckpt".into(),
        family: "bench".into(),
        batch: 8,
        input_shape: vec![4],
        label_shape: vec![1],
        classes: 2,
        embed_dim: 0,
        param_count: ck_leaves * ck_numel,
        params: (0..ck_leaves)
            .map(|i| ParamMeta {
                name: format!("block{i}/w"),
                shape: vec![ck_numel],
                init_std: 0.1,
            })
            .collect(),
        artifacts: Default::default(),
    };
    let ck_params: Vec<Vec<f32>> = (0..ck_leaves)
        .map(|i| (0..ck_numel).map(|j| ((i * 31 + j * 7) % 997) as f32 * 0.013).collect())
        .collect();
    // momentum decays toward sparse repetitive values — the compressible
    // half of a real Full-tier snapshot
    let ck_vels: Vec<Vec<f32>> =
        (0..ck_leaves).map(|i| vec![i as f32 * 0.5; ck_numel]).collect();
    let ck_snap: SharedSnapshot =
        std::sync::Arc::new(Snapshot::full(ck_params, Some(ck_vels)));
    let mut t = Table::new(format!(
        "Checkpoint write ({ck_leaves} leaves x {ck_numel} f32)"
    ))
    .header(&["pool", "codec", "MB written", "write (s)", "hash (s)", "lzss (s)", "wall (s)", "vs serial/raw"]);
    let mut ckpt_payload = Vec::new();
    let mut ck_base_wall = 0.0;
    for (pool_label, threads) in [("serial", 1usize), ("pooled", 0usize)] {
        for (codec_label, compress) in [("raw", false), ("lzss", true)] {
            let dir = std::env::temp_dir().join(format!(
                "kakurenbo_bench_ckpt_{pool_label}_{codec_label}_{}",
                std::process::id()
            ));
            std::fs::remove_dir_all(&dir).ok();
            let pool = WritePool::new(threads);
            let timer = Timer::start();
            let stats = save_snapshot(&ck_meta, &ck_snap, &dir, 0, &pool, compress)?;
            let wall = timer.elapsed_s();
            if pool_label == "serial" && codec_label == "raw" {
                ck_base_wall = wall;
            }
            t.row(vec![
                pool_label.to_string(),
                codec_label.to_string(),
                format!("{:.2}", stats.written_bytes as f64 / 1e6),
                format!("{:.4}", stats.write_s),
                format!("{:.4}", stats.hash_s),
                format!("{:.4}", stats.compress_s),
                format!("{wall:.4}"),
                format!("{:+.1}%", (wall / ck_base_wall - 1.0) * 100.0),
            ]);
            ckpt_payload.push(kakurenbo::jobj![
                ("pool", pool_label),
                ("codec", codec_label),
                ("leaves", stats.leaves),
                ("written_bytes", stats.written_bytes),
                ("raw_bytes", stats.raw_bytes),
                ("write_s", stats.write_s),
                ("hash_s", stats.hash_s),
                ("compress_s", stats.compress_s),
                ("wall_s", wall),
            ]);
            std::fs::remove_dir_all(&dir).ok();
        }
    }
    t.print();

    // --- engine schedule: host gather on vs off the critical path -----------
    // The paper's overhead argument (§5, Fig. 9) needs the non-GPU epoch
    // work overlapped with device execution; measure the engine's two
    // schedules on a full-train forward sweep (the refresh/eval shape).
    let mut ecfg = base.clone();
    ecfg.strategy = StrategyConfig::Baseline;
    ecfg.name = "overhead/engine".into();
    let mut etr = Trainer::new(&ctx.rt, ecfg)?;
    let sweep: Vec<u32> = (0..etr.data.train.n as u32).collect();
    let mut t = Table::new("Engine schedule (full-train fwd sweep)")
        .header(&["schedule", "time (s)", "vs serial"]);
    let mut serial_s = 0.0;
    let mut engine_payload = Vec::new();
    for (label, overlap) in [("serial", false), ("pipelined", true)] {
        etr.engine.overlap = overlap;
        let timer = Timer::start();
        let mut sink = EvalSink::default();
        etr.engine.run(
            &mut etr.exec,
            &etr.data.train,
            &sweep,
            None,
            StepMode::Forward,
            &mut sink,
        )?;
        let secs = timer.elapsed_s();
        if !overlap {
            serial_s = secs;
        }
        t.row(vec![
            label.to_string(),
            format!("{secs:.4}"),
            if overlap { format!("{:+.1}%", (secs / serial_s - 1.0) * 100.0) } else { "-".into() },
        ]);
        engine_payload.push(kakurenbo::jobj![("schedule", label), ("seconds", secs)]);
    }
    t.print();

    // --- worker pool: measured scaling + coordination stall (mock) ----------
    // The pool's two schedules on a host-only backend isolate the
    // coordination cost from PJRT dispatch.  `stall` is the serial-
    // equivalent reduction loop's total wait on worker gather lanes —
    // time the (single) device stream spends starved, the overhead the
    // paper's bulk-synchronous model charges per step.  (The data-
    // parallel schedule's lane-0 wait would just re-measure each step's
    // full compute latency, so it is not reported as overhead.)
    let pdata = gauss_mixture(
        &GaussMixtureCfg { n_train: 8192, n_val: 8, dim: 192, classes: 32, ..Default::default() },
        3,
    )
    .train;
    let order: Vec<u32> = (0..pdata.n as u32).collect();
    let mut t = Table::new("Worker pool (mock fwd sweep, B=64, 8192 samples)").header(&[
        "W", "serial-equiv (s)", "gather stall (s)", "data-parallel (s)", "vs W=1",
    ]);
    let mut pool_payload = Vec::new();
    let mut w1_dp = 0.0;
    for wk in [1usize, 2, 4] {
        let shards = shard_order_aligned(&order, wk, 64);
        let mut pool = WorkerPool::new(&pdata, 64);
        let timer = Timer::start();
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        let pout =
            pool.run_serial_equivalent(&mut be, &pdata, &shards, StepMode::Forward, &mut sink)?;
        let se_s = timer.elapsed_s();
        let stall: f64 = pout.workers.iter().map(|r| r.wait_s).sum();
        let timer = Timer::start();
        let mut be = MockBackend::new();
        let mut sink = EvalSink::default();
        pool.run_data_parallel(&mut be, &pdata, &shards, StepMode::Forward, &mut sink)?;
        let dp_s = timer.elapsed_s();
        if wk == 1 {
            w1_dp = dp_s;
        }
        t.row(vec![
            wk.to_string(),
            format!("{se_s:.4}"),
            format!("{stall:.4}"),
            format!("{dp_s:.4}"),
            if wk == 1 { "-".into() } else { format!("{:.2}x", w1_dp / dp_s) },
        ]);
        pool_payload.push(kakurenbo::jobj![
            ("workers", wk),
            ("serial_equiv_s", se_s),
            ("gather_stall_s", stall),
            ("data_parallel_s", dp_s),
        ]);
    }
    t.print();

    // --- cost-model projection ----------------------------------------------
    let mut cal_cfg = base.clone();
    cal_cfg.strategy = StrategyConfig::Baseline;
    let mut trainer = Trainer::new(&ctx.rt, cal_cfg)?;
    let cost: CostModel = CostModel::calibrate(&mut trainer.exec, 5)?;
    let n = trainer.data.train.n;
    println!(
        "\ncalibrated: t_train {:.2}us/sample, t_fwd {:.2}us/sample, dispatch {:.1}us, {} params",
        cost.t_train * 1e6,
        cost.t_fwd * 1e6,
        cost.t_dispatch * 1e6,
        cost.params
    );

    let mut t = Table::new("Cost-model epoch time vs workers (ImageNet proxy scale)").header(&[
        "Workers", "Baseline (s)", "KAKURENBO F=0.3 (s)", "saving", "ISWR (s)", "vs base",
    ]);
    let mut payload = Vec::new();
    for w in [1usize, 4, 16, 64, 256] {
        let tb = cost.epoch_time(n, 0, 0, w);
        // kakurenbo: train 70%, refresh 30% forward-only, select over N
        let tk = cost.epoch_time(n * 7 / 10, n * 3 / 10, n, w);
        // ISWR: full N training + per-epoch weight rebuild over N
        let ti = cost.epoch_time(n, 0, n, w) + n as f64 * cost.t_select_per_sample;
        t.row(vec![
            w.to_string(),
            format!("{tb:.3}"),
            format!("{tk:.3}"),
            format!("{:+.1}%", (tk / tb - 1.0) * 100.0),
            format!("{ti:.3}"),
            format!("{:+.1}%", (ti / tb - 1.0) * 100.0),
        ]);
        payload.push(kakurenbo::jobj![
            ("workers", w),
            ("baseline_s", tb),
            ("kakurenbo_s", tk),
            ("iswr_s", ti),
        ]);
    }
    t.print();
    payload.push(kakurenbo::jobj![(
        "engine_schedules",
        kakurenbo::util::json::Json::Arr(engine_payload)
    )]);
    payload.push(kakurenbo::jobj![(
        "worker_pool",
        kakurenbo::util::json::Json::Arr(pool_payload)
    )]);
    payload.push(kakurenbo::jobj![(
        "service_lane",
        kakurenbo::util::json::Json::Arr(service_payload)
    )]);
    payload.push(kakurenbo::jobj![(
        "export_tiers",
        kakurenbo::util::json::Json::Arr(export_payload)
    )]);
    payload.push(kakurenbo::jobj![(
        "checkpoint_write",
        kakurenbo::util::json::Json::Arr(ckpt_payload)
    )]);
    ctx.save_json("overhead_breakdown", &kakurenbo::util::json::Json::Arr(payload))?;
    Ok(())
}
