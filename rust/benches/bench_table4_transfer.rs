//! Table 4: transfer learning (DeiT-Tiny / Fractal-3K stand-in).
//!
//! Upstream: pretrain on the fractal proxy under each strategy, reporting
//! final training loss and wall-clock time (paper: KAKURENBO -15.1% time).
//! Downstream: import the pretrained trunk into fresh classifiers for the
//! CIFAR-10/100 proxies and fine-tune with the *baseline* regime,
//! reporting accuracy deltas (paper: KAKURENBO within ±0.35%).

use kakurenbo::config::{presets, DatasetConfig, StrategyConfig};
use kakurenbo::coordinator::Trainer;
use kakurenbo::data::synth::GaussMixtureCfg;
use kakurenbo::report::{paper_strategies, BenchCtx};
use kakurenbo::util::table::{diff_pct, pct, speedup_pct, Table};

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Table 4: transfer learning (fractal -> downstream)")?;

    let mut up_cfg = presets::by_name("fractal_pretrain")?;
    ctx.scale_config(&mut up_cfg);
    let prune_epoch = (up_cfg.epochs / 5).max(2);

    struct Row {
        label: String,
        up_loss: f64,
        up_time: f64,
        down: Vec<(String, f64)>, // (dataset, acc)
    }
    let mut rows: Vec<Row> = Vec::new();

    for (label, strat) in paper_strategies(0.3, prune_epoch) {
        let mut cfg = up_cfg.clone();
        cfg.strategy = strat.clone();
        cfg.name = format!("fractal/{label}");
        if let StrategyConfig::Forget { prune_epoch, .. } = &strat {
            cfg.epochs += prune_epoch;
        }
        let mut up = Trainer::new(&ctx.rt, cfg)?;
        let up_run = up.run()?;
        let trunk = up.exec.export_named_params()?;

        // Downstream: two class-count proxies, baseline fine-tuning.
        let mut down = Vec::new();
        for (dname, classes, variant) in
            [("CIFAR-10*", 10usize, "mlp_c10_b64"), ("CIFAR-100*", 100usize, "mlp_c100_b64")]
        {
            let mut dcfg = presets::by_name("transfer_downstream")?;
            dcfg.variant = variant.to_string();
            dcfg.dataset = DatasetConfig::GaussMixture(GaussMixtureCfg {
                classes,
                n_train: ctx.scale(3072, 512),
                n_val: ctx.scale(1024, 256),
                ..Default::default()
            });
            ctx.scale_config(&mut dcfg);
            dcfg.name = format!("down_{dname}/{label}");
            let mut ft = Trainer::new(&ctx.rt, dcfg)?;
            // Import the pretrained trunk (head shapes differ -> re-init).
            let imported = ft.exec.import_named_params(&trunk)?;
            assert!(imported >= 4, "trunk transfer failed: {imported} leaves");
            let run = ft.run()?;
            down.push((dname.to_string(), run.best_acc));
        }
        let up_loss = up_run
            .records
            .last()
            .map(|r| r.train_loss)
            .unwrap_or(f64::NAN);
        println!(
            "  {label:<10} upstream loss {up_loss:.3} time {:.1}s  downstream {:?}",
            up_run.total_time,
            down.iter().map(|(_, a)| (a * 1e4).round() / 1e2).collect::<Vec<_>>()
        );
        rows.push(Row { label, up_loss, up_time: up_run.total_time, down });
    }

    let base = &rows[0];
    let mut t = Table::new("Table 4 — transfer learning").header(&[
        "Setting", "Up loss", "Up time (s)", "Impr.", "C10 acc", "Diff", "C100 acc", "Diff",
    ]);
    for r in &rows {
        let is_base = r.label == base.label;
        t.row(vec![
            r.label.clone(),
            format!("{:.3}", r.up_loss),
            format!("{:.1}", r.up_time),
            if is_base { "-".into() } else { speedup_pct(r.up_time, base.up_time) },
            pct(r.down[0].1),
            if is_base { "-".into() } else { diff_pct(r.down[0].1, base.down[0].1) },
            pct(r.down[1].1),
            if is_base { "-".into() } else { diff_pct(r.down[1].1, base.down[1].1) },
        ]);
    }
    t.print();

    let j = kakurenbo::util::json::Json::Arr(
        rows.iter()
            .map(|r| {
                kakurenbo::jobj![
                    ("strategy", r.label.as_str()),
                    ("up_loss", r.up_loss),
                    ("up_time", r.up_time),
                    ("down_c10_acc", r.down[0].1),
                    ("down_c100_acc", r.down[1].1),
                ]
            })
            .collect(),
    );
    ctx.save_json("table4_transfer", &j)?;
    Ok(())
}
