//! Table 10 + Figure 9: maximum hiding fraction sweep on the two
//! ResNet-50 training recipes — (A) step-LR and (B) cosine-LR.
//!
//! Paper shape: accuracy degrades gently as F grows (76.58 -> 75.62 for
//! F=0.2..0.4 on (B)); training time falls roughly with F.

use kakurenbo::config::{presets, StrategyConfig};
use kakurenbo::coordinator::run_experiment;
use kakurenbo::report::BenchCtx;
use kakurenbo::util::table::{pct, speedup_pct, Table};

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Table 10: hiding-fraction sweep, ResNet-50 (A)/(B) recipes")?;

    for preset in ["imagenet_resnet50", "imagenet_resnet50_b"] {
        let mut base = presets::by_name(preset)?;
        ctx.scale_config(&mut base);

        let mut cfg = base.clone();
        cfg.strategy = StrategyConfig::Baseline;
        cfg.name = format!("{preset}/baseline");
        let baseline = run_experiment(&ctx.rt, cfg)?;

        let mut t = Table::new(format!("Table 10 — {preset}")).header(&[
            "Setting", "Accuracy", "Time (s)", "Impr.",
        ]);
        t.row(vec![
            "Baseline".into(),
            pct(baseline.best_acc),
            format!("{:.1}", baseline.total_time),
            "-".into(),
        ]);
        let mut out = vec![baseline.clone()];
        for f in [0.2, 0.3, 0.4] {
            let mut cfg = base.clone();
            cfg.strategy = StrategyConfig::kakurenbo(f);
            cfg.name = format!("{preset}/kakurenbo-{f}");
            let r = run_experiment(&ctx.rt, cfg)?;
            println!("  {preset} F={f}: acc {:.4} time {:.1}", r.best_acc, r.total_time);
            t.row(vec![
                format!("KAKURENBO-{f}"),
                pct(r.best_acc),
                format!("{:.1}", r.total_time),
                speedup_pct(r.total_time, baseline.total_time),
            ]);
            out.push(r);
        }
        t.print();
        ctx.save_runs(&format!("table10_{preset}"), &out)?;
    }
    Ok(())
}
