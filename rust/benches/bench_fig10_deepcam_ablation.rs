//! Figure 10 (Appendix D): component ablation on DeepCAM, plus DropTop.
//!
//! Paper shape: v1000 (HE only) degrades; v1001 (+LR) recovers most of
//! it; full KAKURENBO ~= baseline; DropTop (cutting the top-2% highest
//! loss each epoch) *improves* accuracy over plain KAKURENBO because the
//! DeepCAM tail is noise (Fig. 11).

use kakurenbo::config::{presets, Components, StrategyConfig};
use kakurenbo::coordinator::run_experiment;
use kakurenbo::hiding::selector::SelectMode;
use kakurenbo::report::BenchCtx;
use kakurenbo::util::table::{diff_pct, pct, Table};

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::init("Fig 10: DeepCAM ablation incl. DropTop")?;
    let mut base = presets::by_name("deepcam")?;
    ctx.scale_config(&mut base);
    // DropTop matters when the tail is noisy: use a visible corruption rate
    if let kakurenbo::config::DatasetConfig::DeepcamProxy(ref mut c) = base.dataset {
        c.corrupt_frac = 0.02;
    }

    let fracs = [0.2, 0.3, 0.4];
    let kakurenbo = |f: f64, comps: &str, droptop: f64| StrategyConfig::Kakurenbo {
        max_fraction: f,
        tau: 0.7,
        components: Components::from_bits(comps).unwrap(),
        drop_top: droptop,
        select_mode: SelectMode::QuickSelect,
    };

    let mut b_cfg = base.clone();
    b_cfg.strategy = StrategyConfig::Baseline;
    b_cfg.name = "fig10/baseline".into();
    let rb = run_experiment(&ctx.rt, b_cfg)?;
    println!("  baseline acc {:.4}", rb.best_acc);

    let mut t = Table::new("Fig 10 — DeepCAM ablation").header(&[
        "F", "v1000 (HE)", "v1001 (HE+LR)", "KAKURENBO", "KAKUR.+DropTop2%",
    ]);
    let mut payload = Vec::new();
    for f in fracs {
        let mut accs = Vec::new();
        for (label, comps, dt) in [
            ("v1000", "v1000", 0.0),
            ("v1001", "v1001", 0.0),
            ("kakurenbo", "v1111", 0.0),
            ("droptop", "v1111", 0.02),
        ] {
            let mut cfg = base.clone();
            cfg.strategy = kakurenbo(f, comps, dt);
            cfg.name = format!("fig10/{label}-{f}");
            let r = run_experiment(&ctx.rt, cfg)?;
            println!("  F={f} {label}: {:.4}", r.best_acc);
            accs.push(r.best_acc);
        }
        t.row(vec![
            format!("{f}"),
            format!("{} {}", pct(accs[0]), diff_pct(accs[0], rb.best_acc)),
            format!("{} {}", pct(accs[1]), diff_pct(accs[1], rb.best_acc)),
            format!("{} {}", pct(accs[2]), diff_pct(accs[2], rb.best_acc)),
            format!("{} {}", pct(accs[3]), diff_pct(accs[3], rb.best_acc)),
        ]);
        payload.push(kakurenbo::jobj![
            ("fraction", f),
            ("baseline", rb.best_acc),
            ("v1000", accs[0]),
            ("v1001", accs[1]),
            ("kakurenbo", accs[2]),
            ("droptop", accs[3]),
        ]);
    }
    t.print();
    ctx.save_json("fig10_deepcam_ablation", &kakurenbo::util::json::Json::Arr(payload))?;
    Ok(())
}
